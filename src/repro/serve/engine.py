"""Serving engine: prefill/decode step builders + a batched request loop.

``make_prefill_step`` / ``make_decode_step`` return (fn, in/out shardings)
pairs — the same contract as ``train.trainer.make_train_step`` — consumed by
both the real server below and the multi-pod dry-run (``decode_*`` shapes
lower ``serve_step``, NOT ``train_step``, per the assignment).

``ServeEngine`` is the runnable engine (CPU examples, tests): continuous
batching over a fixed-size slot table, greedy/temperature sampling, and
per-request stop handling.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ShapeConfig
from ..models import params as pr
from ..models.lm import LM
from ..parallel.sharding import MeshRules, use_rules
from .kvcache import cache_shardings


def sample_logits(logits: jax.Array, key: jax.Array, *,
                  temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits: (B, V) -> tokens (B,).  temperature 0 = greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -1e30, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)


# --------------------------------------------------------------- step builders
def make_prefill_step(model: LM, rules: Optional[MeshRules]):
    """(params, batch) -> (last-position logits, cache)."""

    def prefill_step(params, batch):
        with use_rules(rules):
            return model.prefill_fn(params, batch)

    return prefill_step


def make_decode_step(model: LM, rules: Optional[MeshRules],
                     temperature: float = 0.0):
    """(params, cache, batch{tokens(B,1), pos()}) -> (next_token, new_cache).

    This is the ``serve_step`` the decode_32k / long_500k cells lower: one
    new token against a seq_len-deep cache.
    """

    def decode_step(params, cache, batch):
        with use_rules(rules):
            logits, new_cache = model.decode_fn(params, cache, batch)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, new_cache

    return decode_step


def serve_shardings(model: LM, shape: ShapeConfig, rules: MeshRules,
                    param_dtype=jnp.bfloat16):
    """(param_shardings, cache_shardings, batch_shardings) for a decode cell."""
    p_sh = pr.shardings(model.param_specs(), rules)
    c_sh = cache_shardings(model, shape.global_batch, shape.seq_len, rules)
    b_axes = model.batch_logical_axes(shape)
    specs = model.input_specs(shape, param_dtype)
    b_sh = {k: rules.act_sharding(b_axes.get(k, ()), s.shape)
            for k, s in specs.items()}
    return p_sh, c_sh, b_sh


# ------------------------------------------------------------------ the engine
@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class DecodeState:
    cache: Any
    pos: int          # tokens already in cache
    last_token: jax.Array


class ServeEngine:
    """Small batched server over a fixed decode batch (CPU-runnable).

    Prefill is per-request (right-padded to ``prefill_pad``); decode runs the
    whole active batch each step.  This mirrors the production design
    (separate prefill/decode graphs, slot table) at example scale.
    """

    def __init__(self, model: LM, params, *, max_seq: int = 512,
                 batch_slots: int = 4, rules: Optional[MeshRules] = None,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.slots = batch_slots
        self.rules = rules
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        cfg = model.cfg

        def prefill(params, batch):
            with use_rules(rules):
                logits, cache = model.prefill_fn(params, batch)
            return logits, cache

        def decode(params, cache, batch):
            with use_rules(rules):
                logits, new_cache = model.decode_fn(params, cache, batch)
            return logits, new_cache

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    # -------------------------------------------------------------- prefill
    def _prefill_one(self, prompt: List[int], extra: Dict[str, Any]):
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        batch = {"tokens": toks, **extra}
        logits, cache = self._prefill(self.params, batch)
        # grow cache KV seq axis to max_seq so decode can write into it
        cache = self._pad_cache(cache, len(prompt))
        return logits, cache

    def _pad_cache(self, cache, cur_len: int):
        target = self.max_seq

        def pad_leaf(x, p):
            # The sequence axis is the one the spec declares as 'kvseq' —
            # scanning for an axis sized cur_len instead would pad the
            # wrong axis whenever another dimension (layers, batch, kv
            # heads) happens to equal the prompt length.  Leaves whose
            # kvseq axis is fixed-length in the spec (audio cross-attn at
            # enc_seq) and leaves with no kvseq axis (SSM conv/state) pass
            # through untouched.
            if "kvseq" not in p.axes:
                return x
            ax = p.axes.index("kvseq")
            if p.shape[ax] != target or x.shape[ax] == target:
                return x
            widths = [(0, 0)] * x.ndim
            widths[ax] = (0, target - x.shape[ax])
            return jnp.pad(x, widths)

        if self.model.cfg.family in ("ssm",):
            return cache           # O(1) state, nothing seq-shaped
        specs = self.model.cache_specs(1, target)
        return jax.tree.map(pad_leaf, cache, specs)

    # ---------------------------------------------------------------- serve
    def generate(self, prompts: List[List[int]], max_new_tokens: int = 16,
                 extra_inputs: Optional[Dict[str, Any]] = None,
                 eos_id: Optional[int] = None) -> List[List[int]]:
        """Sequentially prefill, then batch-decode all requests together."""
        extra = extra_inputs or {}
        outs: List[List[int]] = []
        for prompt in prompts:
            logits, cache = self._prefill_one(prompt, extra)
            if self.temperature > 0:
                self.key, k = jax.random.split(self.key)
                tok = sample_logits(logits, k, temperature=self.temperature)
            else:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            pos = len(prompt)
            toks = [int(tok[0])]
            for _ in range(max_new_tokens - 1):
                if eos_id is not None and toks[-1] == eos_id:
                    break
                batch = {"tokens": tok[:, None], "pos": jnp.asarray(pos, jnp.int32)}
                logits, cache = self._decode(self.params, cache, batch)
                if self.temperature > 0:
                    self.key, k = jax.random.split(self.key)
                    tok = sample_logits(logits, k, temperature=self.temperature)
                else:
                    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                toks.append(int(tok[0]))
                pos += 1
            outs.append(toks)
        return outs
