"""Serving: KV-cache management, prefill/decode step builders, batching."""
from .engine import (  # noqa: F401
    DecodeState,
    ServeEngine,
    make_decode_step,
    make_prefill_step,
    sample_logits,
)
from .kvcache import cache_abstract, cache_shardings  # noqa: F401
