"""KV-cache sharding/spec helpers.

The cache tree is declared once in ``LM.cache_specs`` as P-leaves (shape +
logical axes).  Decode-time sharding puts the *sequence* axis of the cache on
the 'model' mesh axis ('kvseq' rule): GQA KV-head counts (1/2/8) rarely
divide a 16-way tensor axis, but 32k/500k sequences always do — so sequence
parallelism is what keeps a 32k-token cache x 128-request batch inside
per-chip HBM (see DESIGN.md §5).  The softmax over a sequence-sharded cache
lowers to two small all-reduces (max, sum) instead of an all-gather of the
cache itself.
"""
from __future__ import annotations


import jax.numpy as jnp

from ..models import params as pr
from ..models.lm import LM
from ..parallel.sharding import MeshRules


def cache_abstract(model: LM, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree for the cache (dry-run stand-in, no allocation)."""
    return pr.abstract(model.cache_specs(batch, max_seq), dtype)


def cache_shardings(model: LM, batch: int, max_seq: int, rules: MeshRules):
    specs = model.cache_specs(batch, max_seq)
    return pr.tree_map(lambda p: rules.act_sharding(p.axes, p.shape), specs)


def cache_bytes(model: LM, batch: int, max_seq: int,
                dtype=jnp.bfloat16) -> int:
    specs = model.cache_specs(batch, max_seq)
    return pr.bytes_of(specs, dtype)


def kv_token_bytes(model: LM, dtype=jnp.bfloat16) -> tuple[float, float]:
    """Affine decomposition of :func:`cache_bytes` over the sequence axis:
    ``(bytes_per_token, bytes_per_request)`` such that for one request

        cache_bytes(model, 1, seq) == bytes_per_request
                                      + bytes_per_token * seq

    exactly, for every ``seq >= 1``.  ``cache_bytes`` is affine in
    ``max_seq`` by construction (every cache leaf's shape is either
    proportional to the sequence axis — dense/GQA/hybrid KV, int8 scale
    leaves — or independent of it — SSM conv/state, audio cross-attention
    at ``n_frames``), so two evaluations recover both coefficients.  SSM
    models get ``bytes_per_token == 0`` (O(1) state); this is the sizing
    the serving simulator's paged-KV accounting (``core.serving``,
    DESIGN.md §21) charges per admitted request.
    """
    span = 128
    b_lo = cache_bytes(model, 1, 1, dtype)
    b_hi = cache_bytes(model, 1, 1 + span, dtype)
    per_token = (b_hi - b_lo) / span
    return float(per_token), float(b_lo - per_token)
