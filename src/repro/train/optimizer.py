"""Optimizers with sharded state: AdamW and Adafactor.

State trees mirror the parameter tree leaf-for-leaf, so the parameter
sharding tree applies verbatim to optimizer state (ZeRO-3: state lives where
the param shard lives).  Pure-functional: ``init(params) -> state``,
``update(grads, state, params, lr) -> (new_params, new_state)``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"           # adamw | adafactor
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    min_dim_size_to_factor: int = 128
    state_dtype: Any = jnp.float32


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# ------------------------------------------------------------------- AdamW
class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params, cfg: OptConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def adamw_update(grads, state: AdamWState, params, lr, cfg: OptConfig):
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(cfg.state_dtype)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m2 / c1
        vhat = v2 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(cfg.state_dtype)
        p2 = p.astype(cfg.state_dtype) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


# --------------------------------------------------------------- Adafactor
class AdafactorState(NamedTuple):
    step: jax.Array
    # per-leaf: either (vr, vc) factored or (v,) full; encoded as dicts
    vr: Any
    vc: Any
    v: Any


def _factored(shape, cfg: OptConfig) -> bool:
    return (len(shape) >= 2 and shape[-1] >= cfg.min_dim_size_to_factor
            and shape[-2] >= cfg.min_dim_size_to_factor)


def adafactor_init(params, cfg: OptConfig) -> AdafactorState:
    def vr_leaf(p):
        if _factored(p.shape, cfg):
            return jnp.zeros(p.shape[:-1], cfg.state_dtype)
        return jnp.zeros((1,), cfg.state_dtype)

    def vc_leaf(p):
        if _factored(p.shape, cfg):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], cfg.state_dtype)
        return jnp.zeros((1,), cfg.state_dtype)

    def v_leaf(p):
        if _factored(p.shape, cfg):
            return jnp.zeros((1,), cfg.state_dtype)
        return jnp.zeros(p.shape, cfg.state_dtype)

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(vr_leaf, params),
                          vc=jax.tree.map(vc_leaf, params),
                          v=jax.tree.map(v_leaf, params))


def adafactor_update(grads, state: AdafactorState, params, lr, cfg: OptConfig):
    step = state.step + 1
    beta = 1.0 - (step.astype(jnp.float32)) ** (-cfg.decay_rate)

    def upd(p, g, vr, vc, v):
        gf = g.astype(cfg.state_dtype)
        g2 = jnp.square(gf) + 1e-30
        if _factored(p.shape, cfg):
            vr2 = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
            vc2 = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = (vr2[..., None] * vc2[..., None, :]
                     / jnp.maximum(jnp.mean(vr2, axis=-1, keepdims=True)
                                   [..., None], 1e-30))
            update = gf * jax.lax.rsqrt(denom + cfg.eps)
            v2 = v
        else:
            v2 = beta * v + (1 - beta) * g2
            update = gf * jax.lax.rsqrt(v2 + cfg.eps)
            vr2, vc2 = vr, vc
        # update clipping (RMS <= 1) as in the adafactor paper
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        p2 = (p.astype(cfg.state_dtype)
              - lr * update - lr * cfg.weight_decay * p.astype(cfg.state_dtype))
        return p2.astype(p.dtype), vr2, vc2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_vr = treedef.flatten_up_to(state.vr)
    flat_vc = treedef.flatten_up_to(state.vc)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(*t) for t in zip(flat_p, flat_g, flat_vr, flat_vc, flat_v)]
    return (treedef.unflatten([o[0] for o in out]),
            AdafactorState(step=step,
                           vr=treedef.unflatten([o[1] for o in out]),
                           vc=treedef.unflatten([o[2] for o in out]),
                           v=treedef.unflatten([o[3] for o in out])))


# ------------------------------------------------------------------ facade
def make_optimizer(name: str, cfg: Optional[OptConfig] = None):
    cfg = cfg or OptConfig(name=name)
    if name == "adamw":
        return (lambda p: adamw_init(p, cfg),
                lambda g, s, p, lr: adamw_update(g, s, p, lr, cfg), cfg)
    if name == "adafactor":
        return (lambda p: adafactor_init(p, cfg),
                lambda g, s, p, lr: adafactor_update(g, s, p, lr, cfg), cfg)
    raise ValueError(f"unknown optimizer {name}")


def state_spec_tree(name: str, param_specs, cfg: Optional[OptConfig] = None):
    """Optimizer-state tree of P-leaves (shapes + logical axes) derived from
    the parameter spec tree — ZeRO-3: state shards exactly like its param.
    Used to build dry-run input ShapeDtypeStructs and shardings."""
    from ..models.params import P, tree_map

    cfg = cfg or OptConfig(name=name)
    scalar = P((), (), "zeros")
    if name == "adamw":
        mirror = tree_map(lambda p: P(p.shape, p.axes, "zeros"), param_specs)
        return AdamWState(step=scalar, mu=mirror, nu=mirror)
    if name == "adafactor":
        def vr(p):
            if _factored(p.shape, cfg):
                return P(p.shape[:-1], p.axes[:-1], "zeros")
            return P((1,), (None,), "zeros")

        def vc(p):
            if _factored(p.shape, cfg):
                return P(p.shape[:-2] + p.shape[-1:],
                         p.axes[:-2] + p.axes[-1:], "zeros")
            return P((1,), (None,), "zeros")

        def v(p):
            if _factored(p.shape, cfg):
                return P((1,), (None,), "zeros")
            return P(p.shape, p.axes, "zeros")

        return AdafactorState(step=scalar, vr=tree_map(vr, param_specs),
                              vc=tree_map(vc, param_specs),
                              v=tree_map(v, param_specs))
    raise ValueError(f"unknown optimizer {name}")
