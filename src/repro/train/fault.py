"""Fault tolerance: retry-from-checkpoint step loop + straggler watchdog.

``run_with_retries`` wraps the training loop the way a cluster runner must:

* every step runs under a **deadline watchdog** — a step exceeding
  ``deadline_factor`` x the trailing-median step time marks a *straggler
  event*; after ``straggler_patience`` consecutive events the step is
  treated as a failure (on a real pod: the slow host is evicted and the job
  resumes on the survivors — here: the loop restarts from the last
  checkpoint, optionally on a different mesh = elastic restart),
* any exception in the step (device OOM, injected fault, preemption signal)
  triggers **restore-from-latest-checkpoint** and replay; the data pipeline
  is seekable so the token stream resumes exactly at the restored step,
* checkpoints are written every ``ckpt_every`` steps via the atomic
  protocol in ``checkpoint.py``.

The loop is deliberately synchronous-SPMD-shaped: state is (params,
opt_state), the step is a pure donated function, and *restart is the only
recovery mechanism* — the same contract a 1000-node synchronous job has.

``FaultInjector`` provides deterministic failures for tests/examples.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from . import checkpoint as ckpt_lib


class InjectedFault(RuntimeError):
    pass


@dataclass
class FaultInjector:
    """Deterministic fault schedule: fail the *execution* of listed steps
    (once each) — models preemptions/node loss in tests."""
    fail_at_steps: Tuple[int, ...] = ()
    straggle_at_steps: Tuple[int, ...] = ()
    straggle_s: float = 0.0
    _fired: set = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.straggle_at_steps and ("s", step) not in self._fired:
            self._fired.add(("s", step))
            time.sleep(self.straggle_s)
        if step in self.fail_at_steps and ("f", step) not in self._fired:
            self._fired.add(("f", step))
            raise InjectedFault(f"injected fault at step {step}")


@dataclass
class LoopReport:
    steps_done: int
    restarts: int
    straggler_events: int
    losses: List[float]
    step_times: List[float]


def run_with_retries(
    *,
    step_fn: Callable,                   # (state, batch) -> (state, metrics)
    init_state: Callable[[], Any],       # builds fresh state at step 0
    batch_fn: Callable[[int], Any],      # step -> batch (seekable pipeline)
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    max_restarts: int = 5,
    deadline_factor: float = 10.0,
    straggler_patience: int = 3,
    injector: Optional[FaultInjector] = None,
    state_like: Optional[Any] = None,    # pytree for restore structure
    shardings: Optional[Any] = None,     # restart-mesh shardings (elastic)
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
) -> LoopReport:
    restarts = 0
    straggler_events = 0
    losses: List[float] = []
    times: List[float] = []

    def restore_or_init():
        last = ckpt_lib.latest_step(ckpt_dir)
        if last is None:
            return 0, init_state()
        like = state_like if state_like is not None else init_state()
        step, state, _ = ckpt_lib.restore(ckpt_dir, like, step=last,
                                          shardings=shardings)
        return step, state

    step, state = restore_or_init()
    consecutive_straggles = 0
    while step < n_steps:
        try:
            batch = batch_fn(step)
            t0 = time.perf_counter()
            if injector is not None:
                injector.check(step)
            state, metrics = step_fn(state, batch)
            dt = time.perf_counter() - t0

            # ---- straggler watchdog
            if len(times) >= 3:
                med = statistics.median(times[-20:])
                if dt > deadline_factor * med:
                    straggler_events += 1
                    consecutive_straggles += 1
                    if consecutive_straggles >= straggler_patience:
                        raise InjectedFault(
                            f"straggler limit at step {step}: {dt:.3f}s vs "
                            f"median {med:.3f}s")
                else:
                    consecutive_straggles = 0
            times.append(dt)
            if "loss" in metrics:
                losses.append(float(metrics["loss"]))
            if on_metrics is not None:
                on_metrics(step, metrics)

            step += 1
            if step % ckpt_every == 0 or step == n_steps:
                ckpt_lib.save(ckpt_dir, step, state)
        except Exception:  # noqa: BLE001 — any failure -> restart protocol
            restarts += 1
            if restarts > max_restarts:
                raise
            step, state = restore_or_init()
            consecutive_straggles = 0
    return LoopReport(steps_done=step, restarts=restarts,
                      straggler_events=straggler_events, losses=losses,
                      step_times=times)
