"""Learning-rate schedules (pure functions of the step counter).

All schedules are jax-traceable (used inside the jitted train step) and
return fp32 scalars.  ``make_schedule`` is the registry entry point.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp


@dataclass(frozen=True)
class ScheduleConfig:
    name: str = "cosine"             # constant | linear | cosine | rsqrt
    base_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1        # floor as a fraction of base_lr


def _warmup(step, cfg: ScheduleConfig):
    w = jnp.maximum(cfg.warmup_steps, 1)
    return jnp.minimum(1.0, (step + 1) / w)


def constant(step, cfg: ScheduleConfig):
    return cfg.base_lr * _warmup(step, cfg)


def linear(step, cfg: ScheduleConfig):
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    decay = 1.0 - (1.0 - cfg.min_lr_ratio) * t
    return cfg.base_lr * _warmup(step, cfg) * decay


def cosine(step, cfg: ScheduleConfig):
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) \
        * 0.5 * (1.0 + jnp.cos(math.pi * t))
    return cfg.base_lr * _warmup(step, cfg) * decay


def rsqrt(step, cfg: ScheduleConfig):
    s = jnp.maximum(step.astype(jnp.float32), 1.0)
    w = max(cfg.warmup_steps, 1)
    return cfg.base_lr * _warmup(step, cfg) * jnp.sqrt(w / jnp.maximum(s, w))


_SCHEDULES: dict[str, Callable] = {
    "constant": constant,
    "linear": linear,
    "cosine": cosine,
    "rsqrt": rsqrt,
}


def make_schedule(cfg: ScheduleConfig) -> Callable:
    if cfg.name not in _SCHEDULES:
        raise ValueError(f"unknown schedule {cfg.name!r}; "
                         f"known: {sorted(_SCHEDULES)}")
    fn = _SCHEDULES[cfg.name]
    return lambda step: jnp.asarray(fn(jnp.asarray(step, jnp.float32), cfg),
                                    jnp.float32)
