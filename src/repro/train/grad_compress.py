"""Cross-pod gradient compression — a collective-bytes lever for §Perf.

Within a pod, parameters are FSDP-sharded and XLA manages reductions on fast
intra-pod ICI.  *Across* pods, parameters are replicated and gradients must
be all-reduced over the slower pod axis — that is the collective we control
and compress:

    all-reduce(f32/bf16)  ->  reduce-scatter(bf16) + all-gather(int8)

Per-block (128-lane) scales keep quantization error ~0.4% RMS; the
reduce-scatter half stays bf16 so the *sum* is exact, only the broadcast of
the already-reduced result is quantized.  Payload per element: bf16 AR moves
2*(g-1)/g*2B; RS(bf16)+AG(int8) moves (g-1)/g*2B + (g-1)/g*1B — a 40%
collective-byte cut on the pod axis (visible in the dry-run HLO).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as PS

BLOCK = 128


def _quantize_int8(x: jax.Array):
    """Per-128-block symmetric int8 quantization along the last axis."""
    n = x.shape[-1]
    pad = (-n) % BLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = xp.reshape(xp.shape[:-1] + (-1, BLOCK))
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize_int8(q: jax.Array, scale: jax.Array, n: int):
    x = q.astype(jnp.float32) * scale
    return x.reshape(x.shape[:-2] + (-1,))[..., :n]


def compressed_pod_sync(grads, mesh: Mesh):
    """Mean-reduce gradient tree across the 'pod' mesh axis with int8
    compression of the broadcast half.  No-op for single-pod meshes."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    g = axis_sizes.get("pod", 1)
    if g <= 1:
        return grads

    def sync_leaf(x):
        # f32 on the scatter half: exact sum, and it sidesteps an XLA:CPU
        # AllReducePromotion crash on bf16 reductions inside shard_map
        # (the TPU path may use bf16 here; wire bytes are dominated by the
        # int8 broadcast half either way).
        flat = x.reshape(-1).astype(jnp.float32)
        n = flat.shape[0]
        pad = (-n) % g
        flat = jnp.pad(flat, (0, pad))

        def inner(chunked):
            # chunked: this pod's shard view (n/g,) after psum_scatter
            part = jax.lax.psum_scatter(chunked, "pod", scatter_dimension=0,
                                        tiled=True) / g
            q, s = _quantize_int8(part.astype(jnp.float32))
            q_all = jax.lax.all_gather(q, "pod", axis=0, tiled=True)
            s_all = jax.lax.all_gather(s, "pod", axis=0, tiled=True)
            return _dequantize_int8(q_all, s_all, part.shape[0] * g)

        # partial-manual shard_map: only 'pod' is manual (grads are
        # replicated across pods = pure DP); 'data'/'model' sharding stays
        # under GSPMD control.
        out = jax.shard_map(
            inner, mesh=mesh,
            in_specs=PS(),
            out_specs=PS(),
            axis_names={"pod"},
            check_vma=False,
        )(flat)
        return out[:n].reshape(x.shape).astype(x.dtype)

    return jax.tree.map(sync_leaf, grads)
