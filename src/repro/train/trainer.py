"""Train-step builders: grad accumulation, mixed precision, pjit shardings.

``make_train_step`` returns (step_fn, in_shardings, out_shardings, specs):
exactly what both the real trainer (launch/train.py) and the multi-pod
dry-run (launch/dryrun.py) need.  The step is a pure function

    (params, opt_state, batch) -> (params, opt_state, metrics)

with parameters/optimizer state donated.  Gradient accumulation scans over
microbatches; gradients accumulate in fp32 and are optionally compressed
across the 'pod' axis (grad_compress.compressed_pod_sync).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import RunConfig
from ..models import params as pr
from ..models.lm import LM
from ..parallel.sharding import MeshRules, use_rules
from .optimizer import OptConfig, make_optimizer, state_spec_tree
from . import grad_compress


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, tree)


def batch_shardings(model: LM, shape, rules: MeshRules, specs: dict):
    out = {}
    axes = model.batch_logical_axes(shape)
    for k, s in specs.items():
        out[k] = rules.act_sharding(axes.get(k, ()), s.shape)
    return out


def make_train_step(model: LM, run: RunConfig, rules: Optional[MeshRules]):
    """Builds the jit-able train step + sharding trees."""
    cfg = model.cfg
    opt_cfg = OptConfig(name=cfg.optimizer, weight_decay=run.weight_decay,
                        grad_clip=run.grad_clip)
    opt_init, opt_update, _ = make_optimizer(cfg.optimizer, opt_cfg)
    n_micro = run.microbatches()

    param_sh_tree = (pr.shardings(model.param_specs(), rules)
                     if rules is not None else None)

    def constrain_like_params(tree):
        """Pin the grad accumulator to the FSDP param layout: without this,
        GSPMD keeps per-microbatch grads replicated on 'data' and emits a
        full-size all-reduce per layer per microbatch; with it the sync is
        a reduce-scatter into the shard (measured 8x collective-byte cut on
        the mamba2 train cell — see EXPERIMENTS.md §Perf)."""
        if param_sh_tree is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            param_sh_tree)

    def loss_fn(p, batch):
        loss, metrics = model.loss_fn(p, batch)
        return loss, metrics

    def train_step(params, opt_state, batch):
        with use_rules(rules):
            compute_params = cast_tree(params, jnp.dtype(run.compute_dtype))

            if n_micro == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(compute_params, batch)
                grads = cast_tree(grads, jnp.float32)
            else:
                def micro(batch_slice, acc):
                    (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        compute_params, batch_slice)
                    acc = jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                    return l, m, constrain_like_params(acc)

                def scan_body(acc, batch_slice):
                    l, m, acc = micro(batch_slice, acc)
                    return acc, (l, m)

                split = jax.tree.map(
                    lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                        + x.shape[1:]), batch)
                acc0 = constrain_like_params(jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), compute_params))
                grads, (losses, metricses) = jax.lax.scan(scan_body, acc0, split)
                grads = jax.tree.map(lambda g: g / n_micro, grads)
                loss = jnp.mean(losses)
                metrics = jax.tree.map(jnp.mean, metricses)

            if run.grad_compression == "int8_ef" and rules is not None and \
                    "pod" in rules.mesh.axis_names:
                grads = grad_compress.compressed_pod_sync(grads, rules.mesh)

            from .optimizer import clip_by_global_norm
            grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
            new_params, new_opt = opt_update(grads, opt_state, params,
                                             run.learning_rate)
            out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
            return new_params, new_opt, out_metrics

    # ---------------------------------------------------------- shardings
    param_specs = model.param_specs()
    opt_specs = state_spec_tree(cfg.optimizer, param_specs, opt_cfg)
    if rules is not None:
        p_sh = pr.shardings(param_specs, rules)
        o_sh = pr.shardings(opt_specs, rules)
    else:
        p_sh = o_sh = None
    return train_step, param_specs, opt_specs, p_sh, o_sh, opt_init


def make_eval_step(model: LM, run: RunConfig, rules: Optional[MeshRules]):
    def eval_step(params, batch):
        with use_rules(rules):
            compute_params = cast_tree(params, jnp.dtype(run.compute_dtype))
            loss, metrics = model.loss_fn(compute_params, batch)
            return {"loss": loss, **metrics}

    return eval_step
