"""Sharded checkpoints with atomic commit, resume and elastic reshard.

Layout (one directory per step):

    <dir>/step_000123/
        shard_00000.npz     # this host's leaves (flattened tree indices)
        manifest.json       # step, tree structure, leaf shapes/dtypes, rng
    <dir>/LATEST            # atomically-replaced pointer file

Fault-tolerance properties:

* **Atomic commit** — shards are written to ``step_x.tmp/`` and the
  directory is renamed, then ``LATEST`` is replaced via ``os.replace``
  (POSIX-atomic).  A crash mid-write never corrupts the latest checkpoint.
* **Elastic reshard** — checkpoints store *unsharded* leaf arrays (gathered
  per leaf, at example scale) plus the tree structure; ``restore`` lays the
  leaves out on whatever mesh/sharding the restart mesh provides, so a job
  can come back on a different device count (the elastic-scaling path).
* **Garbage collection** — ``keep_last`` old steps retained.

At 1000+-node scale the same protocol applies per-host with
fully-replicated manifests and per-host shard files; the single-process
container collapses hosts to one without changing the commit protocol.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _tree_flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save(ckpt_dir: str | Path, step: int, tree: Any, *,
         extra: Optional[Dict[str, Any]] = None, keep_last: int = 3) -> Path:
    """Write one checkpoint atomically.  Returns the committed directory."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:09d}"
    tmp = ckpt_dir / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat, _ = _tree_flatten_with_paths(tree)
    arrays = {}
    meta = []
    for i, leaf in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"leaf_{i:05d}"] = arr
        meta.append({"shape": list(arr.shape), "dtype": str(arr.dtype)})
    np.savez(tmp / "shard_00000.npz", **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(flat),
        "leaves": meta,
        "time": time.time(),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    os.replace(tmp, final)                      # atomic dir swap

    latest_tmp = ckpt_dir / "LATEST.tmp"
    latest_tmp.write_text(final.name)
    os.replace(latest_tmp, ckpt_dir / "LATEST") # atomic pointer swap

    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: Path, keep_last: int) -> None:
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    for p in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


class AsyncCheckpointer:
    """Overlap checkpoint I/O with the next training steps.

    ``save`` snapshots the (device) tree to host memory synchronously —
    cheap, and required for correctness since the step donates/overwrites
    buffers — then serializes + commits on a background thread (the
    serialization and fsync are what actually cost seconds at scale).
    ``wait`` joins the in-flight write; it is called automatically before
    the next save, so at most one write is in flight (bounded memory).
    The atomic commit protocol is unchanged: a crash mid-write never
    corrupts LATEST.
    """

    def __init__(self) -> None:
        import threading
        self._threading = threading
        self._thread: Optional["threading.Thread"] = None
        self._error: Optional[BaseException] = None

    def save(self, ckpt_dir: str | Path, step: int, tree: Any, *,
             extra: Optional[Dict[str, Any]] = None,
             keep_last: int = 3) -> None:
        self.wait()
        # device -> host snapshot on the caller's thread (fast, and makes
        # the tree immune to donation by subsequent steps)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def _write() -> None:
            try:
                save(ckpt_dir, step, host_tree, extra=extra,
                     keep_last=keep_last)
            except BaseException as e:  # noqa: BLE001 — surfaced in wait()
                self._error = e

        self._thread = self._threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    pointer = ckpt_dir / "LATEST"
    if not pointer.exists():
        return None
    name = pointer.read_text().strip()
    if not (ckpt_dir / name / "manifest.json").exists():
        # pointer ahead of a crashed commit: fall back to newest complete dir
        steps = sorted(p.name for p in ckpt_dir.iterdir()
                       if p.is_dir() and (p / "manifest.json").exists())
        if not steps:
            return None
        name = steps[-1]
    return int(name.split("_")[1])


def restore(ckpt_dir: str | Path, like: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[int, Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (optional pytree) lays leaves out on
    the restart mesh — pass the *new* sharding tree to reshard elastically.

    Returns (step, tree, extra).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    src = ckpt_dir / f"step_{step:09d}"
    manifest = json.loads((src / "manifest.json").read_text())
    data = np.load(src / "shard_00000.npz")

    flat_like, treedef = jax.tree.flatten(like)
    assert len(flat_like) == manifest["n_leaves"], \
        (len(flat_like), manifest["n_leaves"])
    flat_sh = (treedef.flatten_up_to(shardings)
               if shardings is not None else [None] * len(flat_like))
    out = []
    for i, (ref, sh) in enumerate(zip(flat_like, flat_sh)):
        arr = data[f"leaf_{i:05d}"]
        want_dtype = getattr(ref, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return step, jax.tree.unflatten(treedef, out), manifest.get("extra", {})
