"""Collective helpers + byte accounting (per-collective payload math).

The analytic ring-model here is the napkin-math side of the engine's
collective port: given a mesh and a payload, predict the per-device bytes
and time a collective should cost.  §Perf hypotheses quote these numbers;
the dry-run's parsed HLO then confirms or refutes them.

The byte math itself lives in ``core.cost`` (``collective_factor`` /
``collective_links``) — this module is a thin mesh-aware veneer over the
ONE canonical collective model, so its numbers can never drift from what
the engines charge (the cross-implementation parity test in
``tests/test_cluster.py`` pins the delegation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from jax.sharding import Mesh

from ..core.cost import collective_factor, collective_links

_MISSING = object()


@dataclass(frozen=True)
class CollectiveCost:
    kind: str
    group_size: int
    payload_bytes: float         # per-device operand bytes
    link_bw: float               # bytes/s per direction
    links: int = 2               # bidirectional ring
    startup_us: float = 0.0      # per-collective latency (cost_op convention)

    @property
    def wire_bytes(self) -> float:
        """Per-device bytes on the wire: ``collective_factor`` applied to
        the payload (all-reduce 2(g-1)/g, all-gather g-1 over shard
        bytes, reduce-scatter/all-to-all (g-1)/g, permute 1x; g<=1 moves
        nothing)."""
        return collective_factor(self.kind, self.group_size) \
            * self.payload_bytes

    @property
    def t_seconds(self) -> float:
        """Wire time under the effective link bandwidth + startup.

        Matches ``core.cost.cost_op``'s collective branch: a permute is
        one unidirectional send (no 2-link ring credit —
        ``collective_links``), zero moved bytes charge startup only, and
        a real payload over a zero-bandwidth link is cleanly infeasible
        (``inf``)."""
        moved = self.wire_bytes
        bw = collective_links(self.kind, self.links) * self.link_bw
        if moved > 0.0:
            return (moved / bw if bw > 0.0 else float("inf")) \
                + self.startup_us * 1e-6
        return self.startup_us * 1e-6


def axis_size(mesh: Mesh, name: str, default=_MISSING) -> int:
    """Size of mesh axis ``name``; raises ``KeyError`` for unknown axes.

    The old ``.get(name, 1)`` fallback silently priced typo'd axes as
    group size 1 — i.e. zero collective cost.  Pass ``default=`` to opt
    back into a fallback where absence is genuinely meaningful (e.g. a
    'pod' axis that single-pod meshes simply don't have).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if name in sizes:
        return sizes[name]
    if default is not _MISSING:
        return default
    raise KeyError(f"mesh has no axis {name!r}; known axes: "
                   f"{tuple(mesh.axis_names)}")


def grad_sync_bytes(param_bytes: float, mesh: Mesh,
                    compressed: bool = False,
                    axis: str = "pod") -> Dict[str, float]:
    """Cross-``axis`` gradient sync cost: bf16 all-reduce vs int8-EF scheme.

    Returns per-device wire bytes for both schemes (the §Perf comparison).
    ``axis`` names the data-parallel mesh axis the sync rides (the old
    hardcoded ``"pod"`` is now just the default) and must exist on the
    mesh — a typo raises instead of silently reporting zero bytes.
    """
    g = axis_size(mesh, axis)
    if g <= 1:
        return {"all_reduce": 0.0, "compressed": 0.0}
    ar = 2.0 * (g - 1) / g * param_bytes                     # bf16 AR
    rs = (g - 1) / g * param_bytes                           # bf16 RS half
    ag = (g - 1) / g * (param_bytes / 2 + param_bytes / 2 / 128 * 4)
    # ^ int8 payload (half of bf16 bytes) + fp32 scale per 128 block
    return {"all_reduce": ar, "compressed": rs + ag}
