"""Collective helpers + byte accounting (per-collective payload math).

The analytic ring-model here is the napkin-math side of the engine's
collective port: given a mesh and a payload, predict the per-device bytes
and time a collective should cost.  §Perf hypotheses quote these numbers;
the dry-run's parsed HLO then confirms or refutes them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from jax.sharding import Mesh


@dataclass(frozen=True)
class CollectiveCost:
    kind: str
    group_size: int
    payload_bytes: float         # per-device operand bytes
    link_bw: float               # bytes/s per direction
    links: int = 2               # bidirectional ring

    @property
    def wire_bytes(self) -> float:
        g = self.group_size
        if g <= 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * (g - 1) / g * self.payload_bytes
        if self.kind == "all-gather":
            return (g - 1) * self.payload_bytes      # payload = shard bytes
        if self.kind == "reduce-scatter":
            return (g - 1) / g * self.payload_bytes  # payload = full buffer
        if self.kind == "all-to-all":
            return (g - 1) / g * self.payload_bytes
        if self.kind == "collective-permute":
            return self.payload_bytes
        return self.payload_bytes

    @property
    def t_seconds(self) -> float:
        return self.wire_bytes / (self.links * self.link_bw)


def axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def grad_sync_bytes(param_bytes: float, mesh: Mesh,
                    compressed: bool = False) -> Dict[str, float]:
    """Cross-pod gradient sync cost: bf16 all-reduce vs int8-EF scheme.

    Returns per-device wire bytes for both schemes (the §Perf comparison).
    """
    g = axis_size(mesh, "pod")
    if g <= 1:
        return {"all_reduce": 0.0, "compressed": 0.0}
    ar = 2.0 * (g - 1) / g * param_bytes                     # bf16 AR
    rs = (g - 1) / g * param_bytes                           # bf16 RS half
    ag = (g - 1) / g * (param_bytes / 2 + param_bytes / 2 / 128 * 4)
    # ^ int8 payload (half of bf16 bytes) + fp32 scale per 128 block
    return {"all_reduce": ar, "compressed": rs + ag}
