"""Logical-axis sharding: one table maps logical axes -> mesh axes.

Model code never names mesh axes.  It annotates parameters and activations
with *logical* axes ('batch', 'heads', 'mlp', ...).  A ``MeshRules`` object —
installed as a context — resolves logical axes to ``PartitionSpec``s against
the active mesh, with two safety rails:

* **divisibility fallback**: an assignment is dropped (dim left replicated)
  when the dim size is not divisible by the product of assigned mesh axes —
  e.g. qwen1.5-32b's 40 heads on a 16-way 'model' axis, or batch=1 in
  long_500k.  This is what lets one rule table drive all 10 architectures.
* **uniqueness**: a mesh axis is used at most once per spec (GSPMD rule);
  later dims silently lose a conflicting assignment.

Scaling out = changing the mesh tuple + this table; nothing in the model.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


# --------------------------------------------------------------------------- rules
# Parameter logical axes.  'embed' rides the FSDP axis (ZeRO-3 within a pod);
# tensor-parallel axes ride 'model'.
PARAM_RULES = {
    "embed": ("data",),          # FSDP
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "inner": ("model",),         # mamba d_inner / conv channels
    "ssm_heads": ("model",),
    "experts": ("model",),       # EP (dropped automatically when E % 16 != 0 -> expert-TP via 'mlp')
    "head_dim": ("model",),      # fallback TP when head counts don't divide (qwen32b/whisper/paligemma)
    "state": None,
    "layers": None,
    "kwidth": None,
}

# Activation logical axes.
ACT_RULES = {
    "batch": ("pod", "data"),    # 'pod' silently absent on single-pod meshes
    "seq": None,
    # KV-cache sequence sharding (decode SP): 'model' first — GQA KV-head
    # counts (1/2/8) rarely divide the 16-way tensor axis but 32k/500k
    # sequences always do; 'data' joins when batch is too small to use it
    # (long_500k's batch=1 leaves 'data' free -> 256-way cache sharding).
    "kvseq": ("model", "data"),
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "inner": ("model",),
    "ssm_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    # head_dim is a CONTRACTION dim of the attention score matmul: sharding
    # it turns every score block into a partial-sum all-reduce (measured:
    # whisper prefill_32k 58.9 s collective term).  Activations therefore
    # never shard head_dim; archs whose head counts don't divide the tensor
    # axis fall back to sequence parallelism ('sp_seq'/'rseq', enabled per
    # arch in launch/cell.py).
    "head_dim": None,
    "sp_seq": None,              # attention q/out seq axis, SP fallback
    "rseq": None,                # residual-stream seq axis, SP fallback
    "state": None,
    "frames": None,
    "capacity": None,
    "q_group": None,             # GQA group axis of decode scores (tiny)
    "chunks": None,              # SSD chunk axis
    "layers": None,              # stacked-layer axis of cache trees
    "kwidth": None,              # conv-cache kernel-width axis
}


@dataclass
class MeshRules:
    mesh: Mesh
    param_rules: dict = field(default_factory=lambda: dict(PARAM_RULES))
    act_rules: dict = field(default_factory=lambda: dict(ACT_RULES))

    def _axis_size(self, name: str) -> int:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape)).get(name, 0)

    def _resolve(self, rules: dict, axes: Sequence[Optional[str]], shape) -> PartitionSpec:
        used: set[str] = set()
        out = []
        for i, ax in enumerate(axes):
            assignment: Optional[tuple] = None
            if ax is not None:
                want = rules.get(ax)
                if want:
                    picked = []
                    prod = 1
                    for m in want:
                        sz = self._axis_size(m)
                        if sz and m not in used:
                            picked.append(m)
                            prod *= sz
                    if picked and shape is not None and shape[i] % prod == 0 and shape[i] > 0:
                        assignment = tuple(picked)
                        used.update(picked)
                    elif picked and shape is not None:
                        # try a prefix of the requested axes (e.g. drop 'pod')
                        for j in range(len(picked) - 1, 0, -1):
                            sub = picked[:j]
                            p = 1
                            for m in sub:
                                p *= self._axis_size(m)
                            if shape[i] % p == 0:
                                assignment = tuple(sub)
                                used.update(sub)
                                break
            if assignment is None:
                out.append(None)
            elif len(assignment) == 1:
                out.append(assignment[0])
            else:
                out.append(assignment)
        return PartitionSpec(*out)

    def param_spec(self, axes, shape) -> PartitionSpec:
        return self._resolve(self.param_rules, axes, shape)

    def act_spec(self, axes, shape) -> PartitionSpec:
        return self._resolve(self.act_rules, axes, shape)

    def param_sharding(self, axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.param_spec(axes, shape))

    def act_sharding(self, axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.act_spec(axes, shape))


# --------------------------------------------------------------------- context
_STATE = threading.local()


def current_rules() -> Optional[MeshRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def lsc(x, *axes):
    """Logical sharding constraint (activation rules); no-op outside a
    MeshRules context."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.act_spec(axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def lsc_param(x, *axes):
    """Logical sharding constraint under the PARAMETER rules (FSDP layout).
    Used inside scan bodies to pin per-layer weights — and, via the
    transpose, their cotangents — to the FSDP shard."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.param_spec(axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def make_rules(mesh: Mesh, overrides: Optional[dict] = None,
               act_overrides: Optional[dict] = None) -> MeshRules:
    r = MeshRules(mesh)
    if overrides:
        r.param_rules.update(overrides)
    if act_overrides:
        r.act_rules.update(act_overrides)
    return r
