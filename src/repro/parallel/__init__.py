from .sharding import MeshRules, current_rules, lsc, make_rules, use_rules

__all__ = ["MeshRules", "current_rules", "lsc", "make_rules", "use_rules"]
