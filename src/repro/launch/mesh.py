"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* the first
jax initialization, and smoke tests/benches must keep seeing 1 device.

Mesh axes:
  single-pod:  (16, 16)      -> ("data", "model")          256 chips
  multi-pod:   (2, 16, 16)   -> ("pod", "data", "model")   512 chips

The axis-order convention follows TPU ICI reality: 'model' is the innermost
(fastest-varying) axis so tensor-parallel collectives ride nearest-neighbour
links; 'pod' is outermost (slowest links, data-parallel only).  Scaling to
1000+ nodes = more pods on the 'pod' axis (pure DP + compressed grad sync)
or a larger per-pod torus — the sharding rules are expressed against logical
axes and never name mesh sizes.
"""
from __future__ import annotations

import math
import warnings

import jax
from jax.sharding import Mesh


def _take_devices(devices, n: int, shape, hint: str = ""):
    """Validate + slice the device list for an ``n``-device mesh.

    Under-provision is fatal (a mesh cannot be built).  Over-provision is
    legal but loud: the silent ``devices[:n]`` slice used to strand the
    surplus devices without a trace — a 512-device dry-run pointed at a
    (16, 16) mesh quietly computed on half the machine.
    """
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {tuple(shape)}, "
            f"have {len(devices)}{hint}")
    if len(devices) > n:
        warnings.warn(
            f"mesh {tuple(shape)} uses {n} of {len(devices)} devices; "
            f"the remaining {len(devices) - n} are idle",
            RuntimeWarning, stacklevel=3)
    return devices[:n]


def make_production_mesh(*, multi_pod: bool = False,
                         devices=None) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    if devices is None:
        devices = jax.devices()
    devices = _take_devices(
        devices, n, shape,
        hint="; the dry-run must set XLA_FLAGS="
             "--xla_force_host_platform_device_count=512 "
             "before importing jax")
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over whatever devices exist (CPU smoke tests / examples)."""
    shape = (data, model)
    devices = _take_devices(jax.devices(), data * model, shape)
    return jax.make_mesh(shape, ("data", "model"), devices=devices)


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def n_chips(mesh: Mesh) -> int:
    return int(mesh.devices.size)
