"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* the first
jax initialization, and smoke tests/benches must keep seeing 1 device.

Mesh axes:
  single-pod:  (16, 16)      -> ("data", "model")          256 chips
  multi-pod:   (2, 16, 16)   -> ("pod", "data", "model")   512 chips

The axis-order convention follows TPU ICI reality: 'model' is the innermost
(fastest-varying) axis so tensor-parallel collectives ride nearest-neighbour
links; 'pod' is outermost (slowest links, data-parallel only).  Scaling to
1000+ nodes = more pods on the 'pod' axis (pure DP + compressed grad sync)
or a larger per-pod torus — the sharding rules are expressed against logical
axes and never name mesh sizes.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False,
                         devices=None) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    if devices is None:
        devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "the dry-run must set XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 before importing jax")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over whatever devices exist (CPU smoke tests / examples)."""
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devices[:n])


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def n_chips(mesh: Mesh) -> int:
    return int(mesh.devices.size)
