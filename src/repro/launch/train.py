"""End-to-end training driver (real execution, CPU-scale configs).

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-1.3b \
        --reduced --steps 50 --batch 8 --seq 128

Uses the full production stack — logical-axis sharding over a host mesh,
grad accumulation, checkpointing, fault-tolerant loop, seekable synthetic
data — at a width that runs on the container.  The same driver drives the
~100M-parameter end-to-end example (examples/train_lm.py).
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, RunConfig, ShapeConfig, reduced_config
from ..data.synthetic import SyntheticLMDataset
from ..models import params as pr
from ..models.lm import LM, build_model
from ..parallel.sharding import make_rules
from ..train import fault
from ..train.trainer import make_train_step
from .mesh import make_host_mesh


def build_training(model: LM, run: RunConfig, mesh=None):
    """Returns (jitted step, init_fn, shardings) for real execution."""
    rules = make_rules(mesh) if mesh is not None else None
    step_fn, param_specs, opt_specs, p_sh, o_sh, opt_init = \
        make_train_step(model, run, rules)
    jit_kwargs = {}
    if rules is not None:
        jit_kwargs = dict(in_shardings=(p_sh, o_sh, None),
                          out_shardings=(p_sh, o_sh, None))
    jitted = jax.jit(step_fn, donate_argnums=(0, 1), **jit_kwargs)

    def init_state(seed: int = 0):
        params = model.init(jax.random.PRNGKey(seed),
                            dtype=jnp.dtype(run.param_dtype))
        opt_state = opt_init(params)
        return params, opt_state

    return jitted, init_state, (p_sh, o_sh)


def train_loop(model: LM, run: RunConfig, *, n_steps: int,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 20,
               mesh=None, seed: int = 0, log_every: int = 10,
               injector: Optional[fault.FaultInjector] = None,
               lr_schedule=None) -> fault.LoopReport:
    shape = run.shape
    jitted, init_state, _ = build_training(model, run, mesh)
    ds = SyntheticLMDataset(vocab_size=model.cfg.vocab_size,
                            seq_len=shape.seq_len,
                            global_batch=shape.global_batch, seed=seed)
    sched = lr_schedule or (lambda s: run.learning_rate)

    extra: Dict[str, Any] = {}
    if model.cfg.family == "vlm":
        extra["img_embeds"] = jnp.zeros(
            (shape.global_batch, model.cfg.n_img_tokens, model.cfg.d_model),
            jnp.dtype(run.compute_dtype))
    if model.cfg.family == "audio":
        extra["frames"] = jnp.zeros(
            (shape.global_batch, model.cfg.n_frames, model.cfg.d_model),
            jnp.dtype(run.compute_dtype))

    def batch_fn(step: int):
        b = ds.batch(step)
        return {"tokens": jnp.asarray(b["tokens"]), **extra}

    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, metrics = jitted(params, opt_state, batch)
        return (params, opt_state), metrics

    def on_metrics(step: int, metrics: Dict) -> None:
        if step % log_every == 0:
            loss = float(metrics.get("loss", float("nan")))
            gn = float(metrics.get("grad_norm", float("nan")))
            print(f"  step {step:>5d}  loss {loss:8.4f}  grad_norm {gn:8.3f}",
                  flush=True)

    if ckpt_dir is None:
        # plain loop, no fault tolerance (quick experiments)
        state = init_state(seed)
        losses, times = [], []
        for step in range(n_steps):
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch_fn(step))
            jax.block_until_ready(metrics["loss"])
            times.append(time.perf_counter() - t0)
            losses.append(float(metrics["loss"]))
            on_metrics(step, metrics)
        return fault.LoopReport(steps_done=n_steps, restarts=0,
                                straggler_events=0, losses=losses,
                                step_times=times)

    return fault.run_with_retries(
        step_fn=step_fn, init_state=lambda: init_state(seed),
        batch_fn=batch_fn, n_steps=n_steps, ckpt_dir=ckpt_dir,
        ckpt_every=ckpt_every, injector=injector, on_metrics=on_metrics)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (smoke) config")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", default="none",
                    help="'none' or 'DxM' (e.g. 1x1) host mesh")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced_config(cfg)
    shape = ShapeConfig(name="cli", seq_len=args.seq,
                        global_batch=args.batch, kind="train")
    run = RunConfig(model=cfg, shape=shape, microbatch=args.microbatch,
                    learning_rate=args.lr, param_dtype="float32",
                    compute_dtype="float32")
    mesh = None
    if args.mesh != "none":
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_host_mesh(d, m)
    model = build_model(cfg)
    print(f"training {cfg.name} ({pr.count(model.param_specs()):,} params) "
          f"for {args.steps} steps, batch {args.batch} x seq {args.seq}")
    rep = train_loop(model, run, n_steps=args.steps, ckpt_dir=args.ckpt_dir,
                     mesh=mesh, seed=args.seed)
    print(f"done: {rep.steps_done} steps, loss {rep.losses[0]:.4f} -> "
          f"{rep.losses[-1]:.4f}, median step "
          f"{np.median(rep.step_times):.3f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
