"""Cell builder: one (architecture x input-shape x mesh) dry-run unit.

``build_cell`` assembles everything needed to lower one cell:
the step function (train_step / prefill / serve_step per the shape's kind),
abstract input trees (ShapeDtypeStruct — no device allocation), and the
in/out sharding trees resolved against the mesh.  It is shared by the
multi-pod dry-run, the roofline benchmarks and the §Perf iterations, so a
perf experiment is exactly "rebuild the cell with one knob changed".
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..configs import ARCHS, SHAPES, RunConfig, shapes_for
from ..configs.base import ModelConfig, ShapeConfig
from ..models import params as pr
from ..models.lm import LM, build_model
from ..parallel.sharding import MeshRules, make_rules
from ..serve.engine import make_decode_step, make_prefill_step
from ..serve.kvcache import cache_abstract, cache_shardings
from ..train.trainer import make_train_step


# Per-arch training policy: microbatch size (0 = whole batch in one shot).
# Set so the per-microbatch activation footprint fits 16 GiB/chip on the
# single-pod mesh (validated by the dry-run's memory_analysis).
TRAIN_MICROBATCH = {
    "nemotron-4-340b": 32,      # §Perf iteration G: frac 0.654 -> 0.716
    "qwen1.5-110b": 32,
    "grok-1-314b": 32,
    "llama4-scout-17b-a16e": 64,
    "qwen1.5-32b": 64,
    "mamba2-1.3b": 32,
    "zamba2-1.2b": 32,
}

# Archs whose q/kv-head counts do not divide the 16-way tensor axis run
# attention (and the residual stream) sequence-parallel instead of
# head-parallel — §Perf iteration A.  whisper: 20 heads; qwen-32b: 40;
# paligemma: 8 q / 1 kv; llama4-scout: 40 q heads.
SP_ARCHS = {"whisper-large-v3", "qwen1.5-32b", "paligemma-3b",
            "llama4-scout-17b-a16e"}

# int8 KV cache for decode (§Perf iteration E): qwen1.5-32b is full MHA
# (40 KV heads), the only arch whose bf16 32k-cache genuinely exceeds
# per-chip HBM on the single-pod mesh.
KV_INT8_ARCHS = {"qwen1.5-32b"}
SP_ACT_RULES = {"sp_seq": ("model",), "rseq": ("model",)}


@dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    kind: str                      # train | prefill | decode
    model: LM
    run: RunConfig
    rules: MeshRules
    fn: Callable
    args: Tuple[Any, ...]          # abstract inputs (ShapeDtypeStructs)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    static_argnums: Tuple[int, ...] = ()

    @property
    def name(self) -> str:
        return f"{self.arch}__{self.shape.name}"

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        with self.rules.mesh:
            return jitted.lower(*self.args)


def default_run_config(cfg: ModelConfig, shape: ShapeConfig,
                       **overrides) -> RunConfig:
    mb = TRAIN_MICROBATCH.get(cfg.name, 0) if shape.kind == "train" else 0
    base = RunConfig(model=cfg, shape=shape, microbatch=mb)
    return dataclasses.replace(base, **overrides) if overrides else base


def batch_abstract(model: LM, shape: ShapeConfig, dtype=jnp.bfloat16) -> dict:
    return model.input_specs(shape, dtype)


def batch_shardings(model: LM, shape: ShapeConfig, rules: MeshRules,
                    dtype=jnp.bfloat16) -> dict:
    axes = model.batch_logical_axes(shape)
    specs = model.input_specs(shape, dtype)
    return {k: rules.act_sharding(axes.get(k, ()), s.shape)
            for k, s in specs.items()}


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               run_overrides: Optional[dict] = None,
               rule_overrides: Optional[dict] = None,
               act_rule_overrides: Optional[dict] = None,
               model_overrides: Optional[dict] = None,
               attn_impl: str = "blocked",
               ssd_impl: Optional[str] = None) -> Cell:
    cfg = ARCHS[arch]
    if model_overrides:
        cfg = dataclasses.replace(cfg, **model_overrides)
    shape = SHAPES[shape_name]
    if shape not in shapes_for(cfg):
        raise ValueError(f"{shape_name} is skipped for {arch} "
                         "(see DESIGN.md §Arch-applicability)")
    run = default_run_config(cfg, shape, **(run_overrides or {}))
    if act_rule_overrides is None and arch in SP_ARCHS \
            and shape.kind != "decode":
        act_rule_overrides = SP_ACT_RULES
    rules = make_rules(mesh, rule_overrides, act_rule_overrides)
    if ssd_impl is None:
        # On TPU the Pallas SSD kernel is the production path; the DRY-RUN
        # keeps the jnp lowering because interpret-mode pallas emulates the
        # grid as a while loop with full-buffer copies per step — an
        # artifact Mosaic does not have (§Perf iteration C quantifies the
        # kernel's true cost with benchmarks/ssd_kernel_cost.py instead).
        ssd_impl = "jnp"
    kv_dtype = ("int8" if arch in KV_INT8_ARCHS and shape.kind == "decode"
                else "bf16")
    model = build_model(cfg, attn_impl=attn_impl, ssd_impl=ssd_impl,
                        kv_cache_dtype=kv_dtype)
    pdt = jnp.dtype(run.param_dtype)

    param_specs = model.param_specs()
    p_abs = pr.abstract(param_specs, pdt)
    p_sh = pr.shardings(param_specs, rules)
    b_abs = batch_abstract(model, shape, pdt)
    b_sh = batch_shardings(model, shape, rules, pdt)
    repl = NamedSharding(mesh, PartitionSpec())

    if shape.kind == "train":
        step, _, opt_specs, p_sh2, o_sh, _ = make_train_step(model, run, rules)
        o_abs = pr.abstract(opt_specs, jnp.dtype(run.optimizer_dtype))
        return Cell(arch=arch, shape=shape, kind="train", model=model,
                    run=run, rules=rules, fn=step,
                    args=(p_abs, o_abs, b_abs),
                    in_shardings=(p_sh, o_sh, b_sh),
                    out_shardings=(p_sh, o_sh, None),
                    donate_argnums=(0, 1))

    if shape.kind == "prefill":
        step = make_prefill_step(model, rules)
        c_sh = cache_shardings(model, shape.global_batch, shape.seq_len, rules)
        return Cell(arch=arch, shape=shape, kind="prefill", model=model,
                    run=run, rules=rules, fn=step,
                    args=(p_abs, b_abs),
                    in_shardings=(p_sh, b_sh),
                    out_shardings=(None, c_sh),
                    donate_argnums=())

    # decode: one new token against a seq_len-deep cache (serve_step)
    step = make_decode_step(model, rules)
    c_abs = cache_abstract(model, shape.global_batch, shape.seq_len, pdt)
    c_sh = cache_shardings(model, shape.global_batch, shape.seq_len, rules)
    return Cell(arch=arch, shape=shape, kind="decode", model=model,
                run=run, rules=rules, fn=step,
                args=(p_abs, c_abs, b_abs),
                in_shardings=(p_sh, c_sh, b_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(1,))


def all_cells() -> list[tuple[str, str]]:
    """Every (arch, shape) pair that runs (32 cells; skips documented)."""
    out = []
    for name, cfg in ARCHS.items():
        for s in shapes_for(cfg):
            out.append((name, s.name))
    return out


def model_flops_for(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference fwd)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch          # decode: one token per seq
