import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) needs 512 placeholder host devices so
# jax.make_mesh can build the production meshes; smoke tests and benches see
# the normal single device.
"""Multi-pod dry-run: lower + compile EVERY (arch x shape) cell on the
single-pod (16, 16) mesh AND the 2-pod (2, 16, 16) mesh, prove it fits
(memory_analysis), extract roofline terms (cost_analysis + collective bytes
from the partitioned HLO), and feed the RIKEN-style simulator.

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-1.3b
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --force

Artifacts: experiments/dryrun/<mesh>/<arch>__<shape>.json — consumed by
EXPERIMENTS.md §Dry-run/§Roofline and by benchmarks/roofline_table.py.

(No ``from __future__ import annotations`` here: the XLA_FLAGS lines above
must stay the first statements in the file, which PEP 236 disallows for
__future__ imports.  Plain py3.9+ annotations only.)
"""
import argparse
import json
import time
import traceback
from pathlib import Path


from ..configs import ARCHS, SHAPES, skipped_shapes_for
from ..core.hwspec import TPU_V5E
from ..core.simulate import simulate
from .cell import all_cells, build_cell, model_flops_for
from .mesh import make_production_mesh, n_chips

HBM_PER_CHIP = TPU_V5E.hbm_bytes
OUT_DIR = Path("experiments/dryrun")


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: Path = OUT_DIR, force: bool = False,
             run_overrides: dict | None = None,
             act_rule_overrides: dict | None = None,
             tag: str = "") -> dict:
    mesh_name = "multi_pod" if multi_pod else "single_pod"
    dest = out_dir / mesh_name / f"{arch}__{shape_name}{tag}.json"
    if dest.exists() and not force:
        return json.loads(dest.read_text())

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, run_overrides=run_overrides,
                      act_rule_overrides=act_rule_overrides)
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cfg = ARCHS[arch]
    mf = model_flops_for(cfg, SHAPES[shape_name])
    rep = simulate(compiled, hw=TPU_V5E, n_chips=n_chips(mesh),
                   model_flops_global=mf,
                   title=f"{arch} {shape_name} {mesh_name}")

    mem = rep.memory_analysis or {}
    peak = mem.get("peak_bytes_est", 0.0)
    result = {
        "arch": arch,
        "shape": shape_name,
        "kind": cell.kind,
        "mesh": mesh_name,
        "n_chips": n_chips(mesh),
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "fits_hbm": bool(peak and peak <= HBM_PER_CHIP) if peak else None,
        "hbm_per_chip": HBM_PER_CHIP,
        "model_flops_global": mf,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "microbatch": cell.run.microbatch,
        "roofline": rep.roofline.as_dict(),
        "engine": {
            "t_est": rep.engine.t_est,
            "t_roofline": rep.engine.t_roofline,
            "port_busy": rep.engine.port_busy,
            "bound_by": rep.engine.bound_by,
            "mxu_utilization": rep.engine.mxu_utilization,
            "collective_time_by_kind": rep.engine.collective_time_by_kind,
        },
        "program": rep.program_summary,
        "memory_analysis": rep.memory_analysis,
        "xla_cost_analysis": rep.xla_cost_analysis,
        "pa_report": rep.pa,
    }
    dest.parent.mkdir(parents=True, exist_ok=True)
    dest.write_text(json.dumps(result, indent=1, sort_keys=True))
    return result


def fmt_row(r: dict) -> str:
    rf = r["roofline"]
    mem = r.get("memory_analysis") or {}
    peak_gib = (mem.get("peak_bytes_est") or 0) / 2**30
    return (f"{r['arch']:<24s}{r['shape']:<13s}{r['mesh']:<11s}"
            f"{rf['compute_s']:>10.4f}{rf['memory_s']:>10.4f}"
            f"{rf['collective_s']:>11.4f}  {rf['dominant']:<10s}"
            f"{rf['useful_flops_ratio']:>7.2f}{peak_gib:>9.2f}GiB"
            f"{r['t_compile_s']:>8.1f}s")


HEADER = (f"{'arch':<24s}{'shape':<13s}{'mesh':<11s}{'compute_s':>10s}"
          f"{'memory_s':>10s}{'collect_s':>11s}  {'dominant':<10s}"
          f"{'MF/HF':>7s}{'peak':>12s}{'compile':>9s}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one architecture id")
    ap.add_argument("--shape", default=None, help="one shape name")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    cells = all_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    if args.list:
        for a, s in cells:
            print(f"{a:<26s}{s}")
        for name, cfg in ARCHS.items():
            for shape, why in skipped_shapes_for(cfg):
                print(f"{name:<26s}{shape.name:<13s}SKIP: {why}")
        return 0

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)
    print(HEADER)
    failures = []
    for multi_pod in meshes:
        for arch, shape in cells:
            try:
                r = run_cell(arch, shape, multi_pod=multi_pod,
                             out_dir=out_dir, force=args.force)
                print(fmt_row(r), flush=True)
            except Exception as e:  # noqa: BLE001 — report all failures at end
                failures.append((arch, shape, multi_pod, repr(e)))
                print(f"{arch:<24s}{shape:<13s}"
                      f"{'multi_pod' if multi_pod else 'single_pod':<11s}"
                      f"FAILED: {e}", flush=True)
                traceback.print_exc()
    # skipped cells, accounted
    for name, cfg in ARCHS.items():
        for shape, why in skipped_shapes_for(cfg):
            print(f"{name:<24s}{shape.name:<13s}{'(both)':<11s}SKIPPED: {why[:60]}...")
    if failures:
        print(f"\n{len(failures)} FAILURES")
        for f in failures:
            print("  ", f)
        return 1
    print("\nall cells compiled")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
