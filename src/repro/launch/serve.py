"""Serving driver (real execution, CPU-scale configs).

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --reduced \
        --requests 4 --prompt-len 16 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, reduced_config
from ..models.lm import build_model
from ..serve.engine import ServeEngine


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="mamba2-1.3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed), dtype=jnp.float32)

    rng = np.random.default_rng(args.seed)
    prompts = [list(rng.integers(0, cfg.vocab_size,
                                 size=args.prompt_len).astype(int))
               for _ in range(args.requests)]
    extra = {}
    if cfg.family == "vlm":
        extra["img_embeds"] = jnp.zeros((1, cfg.n_img_tokens, cfg.d_model),
                                        jnp.float32)
    if cfg.family == "audio":
        extra["frames"] = jnp.zeros((1, cfg.n_frames, cfg.d_model),
                                    jnp.float32)

    engine = ServeEngine(model, params,
                         max_seq=args.prompt_len + args.max_new,
                         temperature=args.temperature, seed=args.seed)
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new_tokens=args.max_new,
                           extra_inputs=extra)
    dt = time.perf_counter() - t0
    total_new = sum(len(o) for o in outs)
    for i, o in enumerate(outs):
        print(f"req {i}: prompt[:8]={prompts[i][:8]} -> {o}")
    print(f"{args.requests} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
