import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
# ^ same device-count contract as dryrun.py (first lines, before jax init).
"""PA-data deep-dive for one cell: top ops by time/bytes, trip counts,
collective schedule — the RIKEN simulator's per-section profiling applied to
a compiled (arch x shape x mesh) cell.

    PYTHONPATH=src python -m repro.launch.analyze --arch chatglm3-6b \
        --shape decode_32k [--multi-pod] [--dump-hlo /tmp/x.hlo]
"""
import argparse
import collections

from ..core.hwspec import TPU_V5E
from ..core.simulate import simulate
from ..configs import ARCHS, SHAPES
from .cell import build_cell, model_flops_for
from .mesh import make_production_mesh, n_chips


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dump-hlo", default=None)
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--microbatch", type=int, default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    run_overrides = {}
    if args.microbatch is not None:
        run_overrides["microbatch"] = args.microbatch
    cell = build_cell(args.arch, args.shape, mesh,
                      run_overrides=run_overrides or None)
    lowered = cell.lower()
    compiled = lowered.compile()
    text = compiled.as_text()
    if args.dump_hlo:
        with open(args.dump_hlo, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars of HLO to {args.dump_hlo}")

    mf = model_flops_for(ARCHS[args.arch], SHAPES[args.shape])
    # one simulate() call: the report carries the parsed program and the
    # engine result, so the deep-dive below reuses the single costing pass
    rep = simulate(compiled, hw=TPU_V5E, n_chips=n_chips(mesh),
                   model_flops_global=mf,
                   title=f"{args.arch} {args.shape}")
    prog, eng = rep.program, rep.engine
    print(rep.pa)
    print(f"\nmemory_analysis: {rep.memory_analysis}")

    print(f"\n== top {args.top} ops by modeled time ==")
    print(f"{'op':<44s}{'opcode':<18s}{'count':>9s}{'GF':>8s}{'GB':>9s}"
          f"{'commGB':>9s}{'t_total_ms':>11s}")
    for t in eng.top_ops[:args.top]:
        o = t.op
        print(f"{o.name[:43]:<44s}{o.opcode:<18s}{o.count:>9.0f}"
              f"{o.flops * o.count / 1e9:>8.1f}"
              f"{o.bytes_accessed * o.count / 1e9:>9.2f}"
              f"{o.comm_bytes * o.count / 1e9:>9.2f}"
              f"{t.t_op * o.count * 1e3:>11.2f}")

    # trip-count audit: group op counts
    counts = collections.Counter(o.count for o in prog.ops)
    print("\n== op-count histogram (multiplier -> n_ops) ==")
    for c, n in sorted(counts.items()):
        print(f"  x{c:<10.0f} {n}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
