"""The language models: one class, six families.

``LM`` builds parameter-spec trees, initializes/abstracts them, and provides
the three entry points every (arch x shape) cell lowers:

* ``loss_fn(params, batch)``            — train_4k
* ``prefill_fn(params, batch)``         — prefill_32k (logits + cache)
* ``decode_fn(params, cache, batch)``   — decode_32k / long_500k (1 new token)

Homogeneous stacks (dense / moe / ssm / whisper enc+dec) are ``lax.scan``-ed
over stacked layer parameters (small HLO, fast SPMD partitioning); the zamba2
hybrid uses a python loop (38 layers, heterogeneous: shared attention block
every 6th layer).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..parallel.sharding import lsc, lsc_param
from . import params as pr
from .attention import attn_params, attention_block
from .layers import (
    apply_mlp,
    apply_norm,
    embed_params,
    embed_tokens,
    logits_from_hidden,
    mlp_params,
    next_token_loss,
    norm_params,
)
from .moe import apply_moe, moe_params
from .params import P
from .ssm import apply_mamba, mamba_params


def stack_specs(tree, n: int):
    """Prepend a 'layers' axis to every leaf of a layer spec tree."""
    return pr.tree_map(
        lambda p: P((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale), tree)


def constrain_params(param_tree, spec_tree):
    """Pin a (per-layer) parameter tree to its logical sharding INSIDE the
    scan body.  The forward effect is a no-op (params already arrive FSDP-
    sharded and get gathered for the matmuls); the payoff is the TRANSPOSE:
    ``with_sharding_constraint`` is linear, so each layer's weight cotangent
    is constrained to the same FSDP layout — the per-layer grad partial is
    reduce-scattered into its shard instead of all-reduced at full size
    (measured: 94% collective-byte cut on qwen1.5-110b train_4k — see
    EXPERIMENTS.md §Perf iteration 1)."""
    return jax.tree.map(lambda a, p: lsc_param(a, *p.axes), param_tree,
                        spec_tree)


def _sinusoidal(positions: jax.Array, d: int, dtype) -> jax.Array:
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


class LM:
    def __init__(self, cfg: ModelConfig, attn_impl: str = "blocked",
                 kv_block: int = 1024, ssd_impl: str = "jnp",
                 kv_cache_dtype: str = "bf16"):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.kv_block = kv_block
        self.ssd_impl = ssd_impl
        self.kv_cache_dtype = kv_cache_dtype   # 'bf16' | 'int8' (decode)

    # ------------------------------------------------------------- param specs
    def _dense_layer_specs(self) -> dict:
        cfg = self.cfg
        out = {"ln1": norm_params(cfg), "attn": attn_params(cfg),
               "ln2": norm_params(cfg)}
        if cfg.moe is not None:
            out["moe"] = moe_params(cfg)
        else:
            out["mlp"] = mlp_params(cfg)
        return out

    def _encoder_layer_specs(self) -> dict:
        cfg = self.cfg
        return {"ln1": norm_params(cfg), "attn": attn_params(cfg),
                "ln2": norm_params(cfg), "mlp": mlp_params(cfg)}

    def _decoder_xattn_layer_specs(self) -> dict:
        out = self._encoder_layer_specs()
        out["ln_x"] = norm_params(self.cfg)
        out["xattn"] = attn_params(self.cfg)
        return out

    def param_specs(self) -> dict:
        cfg = self.cfg
        specs: dict[str, Any] = {"embed": embed_params(cfg),
                                 "final_norm": norm_params(cfg)}
        if cfg.family == "ssm":
            layer = {"ln": norm_params(cfg), "mamba": mamba_params(cfg)}
            specs["layers"] = stack_specs(layer, cfg.n_layers)
        elif cfg.family == "hybrid":
            layer = {"ln": norm_params(cfg), "mamba": mamba_params(cfg)}
            specs["layers"] = stack_specs(layer, cfg.n_layers)
            specs["shared_attn"] = {
                "ln1": norm_params(cfg), "attn": attn_params(cfg),
                "ln2": norm_params(cfg), "mlp": mlp_params(cfg),
            }
        elif cfg.family == "audio":
            specs["layers"] = stack_specs(self._decoder_xattn_layer_specs(),
                                          cfg.n_layers)
            specs["encoder"] = {
                "layers": stack_specs(self._encoder_layer_specs(),
                                      cfg.n_encoder_layers),
                "final_norm": norm_params(cfg),
            }
        else:  # dense / moe / vlm
            specs["layers"] = stack_specs(self._dense_layer_specs(),
                                          cfg.n_layers)
        return specs

    def abstract_params(self, dtype=jnp.bfloat16):
        return pr.abstract(self.param_specs(), dtype)

    def init(self, key, dtype=jnp.float32):
        return pr.init(self.param_specs(), key, dtype)

    # --------------------------------------------------------------- caches
    def n_shared_invocations(self) -> int:
        cfg = self.cfg
        if cfg.family != "hybrid":
            return 0
        return len(range(0, cfg.n_layers, cfg.shared_attn_every))

    def cache_specs(self, batch: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
        """Cache tree as P-leaves (shape + logical axes) for dry-run specs."""
        cfg = self.cfg
        L = cfg.n_layers
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        kv_axes = ("layers", "batch", "kvseq", "kv_heads", "head_dim")

        q8 = self.kv_cache_dtype == "int8"

        def kv_leaf(seq):
            return P((L, batch, seq, kv, hd), kv_axes, "zeros",
                     dtype="int8" if q8 else None)

        def scale_leaf(seq):
            return P((L, batch, seq, kv), kv_axes[:-1], "zeros",
                     dtype="float16")

        if cfg.family == "ssm":
            s = cfg.ssm
            di, nh = s.d_inner(cfg.d_model), s.n_heads(cfg.d_model)
            gn = s.n_groups * s.d_state
            return {
                "conv_x": P((L, batch, s.d_conv - 1, di),
                            ("layers", "batch", "kwidth", "inner"), "zeros"),
                "conv_B": P((L, batch, s.d_conv - 1, gn),
                            ("layers", "batch", "kwidth", "state"), "zeros"),
                "conv_C": P((L, batch, s.d_conv - 1, gn),
                            ("layers", "batch", "kwidth", "state"), "zeros"),
                "state": P((L, batch, nh, s.head_dim, s.d_state),
                           ("layers", "batch", "ssm_heads", "head_dim", "state"),
                           "zeros"),
            }
        if cfg.family == "hybrid":
            s = cfg.ssm
            di, nh = s.d_inner(cfg.d_model), s.n_heads(cfg.d_model)
            gn = s.n_groups * s.d_state
            ninv = self.n_shared_invocations()
            return {
                "mamba": {
                    "conv_x": P((L, batch, s.d_conv - 1, di),
                                ("layers", "batch", "kwidth", "inner"), "zeros"),
                    "conv_B": P((L, batch, s.d_conv - 1, gn),
                                ("layers", "batch", "kwidth", "state"), "zeros"),
                    "conv_C": P((L, batch, s.d_conv - 1, gn),
                                ("layers", "batch", "kwidth", "state"), "zeros"),
                    "state": P((L, batch, nh, s.head_dim, s.d_state),
                               ("layers", "batch", "ssm_heads", "head_dim",
                                "state"), "zeros"),
                },
                "shared_k": P((ninv, batch, max_seq, kv, hd), kv_axes, "zeros"),
                "shared_v": P((ninv, batch, max_seq, kv, hd), kv_axes, "zeros"),
            }
        if cfg.family == "audio":
            enc_seq = cfg.n_frames
            return {
                "k": kv_leaf(max_seq), "v": kv_leaf(max_seq),
                "xk": P((L, batch, enc_seq, kv, hd), kv_axes, "zeros"),
                "xv": P((L, batch, enc_seq, kv, hd), kv_axes, "zeros"),
            }
        out = {"k": kv_leaf(max_seq), "v": kv_leaf(max_seq)}
        if q8:
            out["k_scale"] = scale_leaf(max_seq)
            out["v_scale"] = scale_leaf(max_seq)
        return out

    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        return pr.tree_map(lambda p: jnp.zeros(p.shape, p.dtype or dtype),
                           self.cache_specs(batch, max_seq, dtype))

    # --------------------------------------------------------------- forward
    def _embed_inputs(self, params, batch: dict, mode: str) -> jax.Array:
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed_tokens(params["embed"], tokens, cfg)
        if cfg.family == "vlm" and mode != "decode":
            img = batch["img_embeds"].astype(x.dtype)
            n_img = img.shape[1]
            x = jnp.concatenate([img, x[:, n_img:]], axis=1)
        if cfg.family == "audio":
            B, S = tokens.shape
            pos0 = batch.get("pos", None)
            start = 0 if pos0 is None else pos0
            positions = start + jnp.arange(S)
            x = x + _sinusoidal(positions, cfg.d_model, x.dtype)[None]
        return x

    def _run_encoder(self, params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = frames + _sinusoidal(jnp.arange(frames.shape[1]), cfg.d_model,
                                 frames.dtype)[None]
        enc_specs = self._encoder_layer_specs()

        def body(h, lp):
            lp = constrain_params(lp, enc_specs)
            a = apply_norm(lp["ln1"], h)
            a, _ = attention_block(lp["attn"], a, cfg, mode="train",
                                   causal=False, impl=self.attn_impl,
                                   kv_block=self.kv_block)
            h = h + a
            f = apply_norm(lp["ln2"], h)
            f = apply_mlp(lp["mlp"], f, cfg.mlp_kind)
            return h + f, None

        if cfg.remat == "full":
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
        return apply_norm(params["encoder"]["final_norm"], x)

    def _dense_stack(self, params, x, mode, cache, pos, cross_x):
        """Scan over homogeneous decoder layers (dense/moe/vlm/audio)."""
        cfg = self.cfg
        has_moe = cfg.moe is not None
        has_xattn = cfg.family == "audio"
        B, S = x.shape[:2]
        positions = (jnp.arange(S)[None, :] if pos is None
                     else pos + jnp.zeros((B, 1), jnp.int32))

        layer_specs = (self._decoder_xattn_layer_specs() if has_xattn
                       else self._dense_layer_specs())

        def body(carry, scanned):
            h, aux = carry
            lp, lc = scanned
            lp = constrain_params(lp, layer_specs)
            a_in = apply_norm(lp["ln1"], h)
            new_lc = {}
            self_cache = None
            if lc is not None:
                self_cache = {k: lc[k] for k in
                              ("k", "v", "k_scale", "v_scale") if k in lc}
                self_cache["cross"] = False
            a, kvout = attention_block(
                lp["attn"], a_in, cfg, mode=mode, positions=positions,
                cache=self_cache,
                cache_pos=pos, impl=self.attn_impl, kv_block=self.kv_block)
            h = h + a
            if kvout is not None and mode != "train":
                for kk in ("k", "v", "k_scale", "v_scale"):
                    if kk in kvout:
                        new_lc[kk] = kvout[kk]
            if has_xattn:
                xa_in = apply_norm(lp["ln_x"], h)
                xa, xkv = attention_block(
                    lp["xattn"], xa_in, cfg, mode=mode,
                    cross_x=(cross_x if mode != "decode" else None),
                    cache=(None if lc is None else
                           {"k": lc["xk"], "v": lc["xv"], "cross": True}),
                    impl=self.attn_impl, kv_block=self.kv_block)
                h = h + xa
                if xkv is not None and mode != "train":
                    new_lc["xk"], new_lc["xv"] = xkv["k"], xkv["v"]
            f_in = apply_norm(lp["ln2"], h)
            if has_moe:
                f, a_loss = apply_moe(lp["moe"], f_in, cfg, mode == "train")
                aux = aux + a_loss
            else:
                f = apply_mlp(lp["mlp"], f_in, cfg.mlp_kind)
            h = lsc(h + f, "batch", "rseq", "embed")
            return (h, aux), new_lc

        if (self.cfg.remat == "full") and mode == "train":
            body = jax.checkpoint(body)

        if mode == "train":
            (x, aux), _ = jax.lax.scan(body, (x, 0.0),
                                       (params["layers"], None))
            return x, aux, None
        if mode == "prefill":
            # caches are emitted per layer (k/v of full prefix)
            (x, aux), caches = jax.lax.scan(body, (x, 0.0),
                                            (params["layers"], None))
            return x, aux, caches
        (x, aux), caches = jax.lax.scan(body, (x, 0.0),
                                        (params["layers"], cache))
        return x, aux, caches

    def _ssm_stack(self, params, x, mode, cache, pos):
        cfg = self.cfg
        layer_specs = {"ln": norm_params(cfg), "mamba": mamba_params(cfg)}

        def body(h, scanned):
            lp, lc = scanned
            lp = constrain_params(lp, layer_specs)
            a_in = apply_norm(lp["ln"], h)
            a, new_lc = apply_mamba(lp["mamba"], a_in, cfg, mode=mode,
                                    cache=lc, impl=self.ssd_impl)
            h = lsc(h + a, "batch", "rseq", "embed")
            return h, new_lc

        if cfg.remat == "full" and mode == "train":
            body = jax.checkpoint(body)
        x, caches = jax.lax.scan(body, x, (params["layers"], cache))
        return x, 0.0, caches

    def _hybrid_stack(self, params, x, mode, cache, pos):
        """zamba2: python loop; shared attn block every k layers."""
        cfg = self.cfg
        every = cfg.shared_attn_every
        sp = constrain_params(
            params["shared_attn"],
            {"ln1": norm_params(cfg), "attn": attn_params(cfg),
             "ln2": norm_params(cfg), "mlp": mlp_params(cfg)})
        B, S = x.shape[:2]
        positions = (jnp.arange(S)[None, :] if pos is None
                     else pos + jnp.zeros((B, 1), jnp.int32))
        new_cache = {"mamba": {k: [] for k in
                               ("conv_x", "conv_B", "conv_C", "state")},
                     "shared_k": [], "shared_v": []} if mode != "train" else None

        def layer(h, lp, lc, inv_cache, use_attn):
            if use_attn:
                a_in = apply_norm(sp["ln1"], h)
                a, kvout = attention_block(
                    sp["attn"], a_in, cfg, mode=mode, positions=positions,
                    cache=inv_cache, cache_pos=pos, impl=self.attn_impl,
                    kv_block=self.kv_block)
                h = h + a
                f_in = apply_norm(sp["ln2"], h)
                h = h + apply_mlp(sp["mlp"], f_in, cfg.mlp_kind)
            else:
                kvout = None
            m_in = apply_norm(lp["ln"], h)
            m, new_lc = apply_mamba(lp["mamba"], m_in, cfg, mode=mode,
                                    cache=lc, impl=self.ssd_impl)
            return h + m, new_lc, kvout

        if cfg.remat == "full" and mode == "train":
            layer = jax.checkpoint(layer, static_argnums=(4,))

        inv = 0
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, i=i: a[i], params["layers"])
            use_attn = (i % every == 0)
            lc = None
            inv_cache = None
            if cache is not None:
                lc = jax.tree.map(lambda a, i=i: a[i], cache["mamba"])
                if use_attn:
                    inv_cache = {"k": cache["shared_k"][inv],
                                 "v": cache["shared_v"][inv], "cross": False}
            elif mode == "prefill":
                lc = None
            x, new_lc, kvout = layer(x, lp, lc, inv_cache, use_attn)
            if new_cache is not None:
                if new_lc is not None:
                    for k in new_cache["mamba"]:
                        new_cache["mamba"][k].append(new_lc[k])
                if use_attn and kvout is not None:
                    new_cache["shared_k"].append(kvout["k"])
                    new_cache["shared_v"].append(kvout["v"])
            if use_attn:
                inv += 1

        if new_cache is not None:
            new_cache["mamba"] = {k: jnp.stack(v) for k, v in
                                  new_cache["mamba"].items()}
            new_cache["shared_k"] = jnp.stack(new_cache["shared_k"])
            new_cache["shared_v"] = jnp.stack(new_cache["shared_v"])
        return x, 0.0, new_cache

    def forward(self, params, batch: dict, mode: str, cache=None,
                pos=None):
        """Returns (logits, aux_loss, new_cache)."""
        cfg = self.cfg
        x = self._embed_inputs(params, dict(batch, pos=pos), mode)
        cross_x = None
        if cfg.family == "audio" and mode != "decode":
            cross_x = self._run_encoder(params, batch["frames"])

        if cfg.family == "ssm":
            x, aux, caches = self._ssm_stack(params, x, mode, cache, pos)
        elif cfg.family == "hybrid":
            x, aux, caches = self._hybrid_stack(params, x, mode, cache, pos)
        else:
            x, aux, caches = self._dense_stack(params, x, mode, cache, pos,
                                               cross_x)
        x = apply_norm(params["final_norm"], x)
        logits = logits_from_hidden(params["embed"], x, cfg)
        return logits, aux, caches

    # ------------------------------------------------------------ entry points
    def loss_fn(self, params, batch: dict):
        logits, aux, _ = self.forward(params, batch, "train")
        loss = next_token_loss(logits, batch["tokens"], self.cfg.vocab_size)
        return loss + aux, {"ce": loss, "aux": aux}

    def prefill_fn(self, params, batch: dict, max_seq: Optional[int] = None):
        """Returns (last-position logits, cache sized to the prefix)."""
        logits, _, caches = self.forward(params, batch, "prefill")
        return logits[:, -1], caches

    def decode_fn(self, params, cache, batch: dict):
        """batch: {'tokens': (B,1), 'pos': scalar int32}.  One new token."""
        pos = batch["pos"]
        logits, _, new_cache = self.forward(params, batch, "decode",
                                            cache=cache, pos=pos)
        return logits[:, -1], new_cache

    # ------------------------------------------------------------- input specs
    def input_specs(self, shape: ShapeConfig, dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct
        if shape.kind == "decode":
            batch = {"tokens": tok((B, 1), jnp.int32),
                     "pos": tok((), jnp.int32)}
        else:
            batch = {"tokens": tok((B, S), jnp.int32)}
        if cfg.family == "vlm" and shape.kind != "decode":
            batch["img_embeds"] = tok((B, cfg.n_img_tokens, cfg.d_model), dtype)
        if cfg.family == "audio" and shape.kind != "decode":
            batch["frames"] = tok((B, cfg.n_frames, cfg.d_model), dtype)
        return batch

    def batch_logical_axes(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        out = {"tokens": ("batch", "seq")}
        if shape.kind == "decode":
            out = {"tokens": ("batch", "seq"), "pos": ()}
        if cfg.family == "vlm" and shape.kind != "decode":
            out["img_embeds"] = ("batch", "seq", "embed")
        if cfg.family == "audio" and shape.kind != "decode":
            out["frames"] = ("batch", "frames", "embed")
        return out


def build_model(cfg: ModelConfig, attn_impl: str = "blocked",
                kv_block: int = 1024, ssd_impl: str = "jnp",
                kv_cache_dtype: str = "bf16") -> LM:
    return LM(cfg, attn_impl=attn_impl, kv_block=kv_block, ssd_impl=ssd_impl,
              kv_cache_dtype=kv_cache_dtype)
