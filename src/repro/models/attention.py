"""GQA attention: train/prefill (blocked, flash-equivalent) + cached decode.

Two implementations share one math definition:

* ``blocked_attention`` — pure-jnp online-softmax over KV blocks (the flash
  algorithm expressed in XLA ops).  This is what the multi-pod dry-run lowers:
  the host platform is CPU, so the Pallas TPU kernel cannot be compiled there;
  the blocked path has the same O(S·block) memory and the same collective
  pattern.  On TPU the ``kernels.flash_attention`` Pallas kernel is selected
  via ``impl='flash'``.
* ``decode_attention`` — single-token attention against a KV cache.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import current_rules, lsc
from .layers import apply_rope
from .params import P


def _attn_seq_axis(q_shape) -> str:
    """'sp_seq' when neither heads nor head_dim can ride the tensor axis
    (e.g. whisper's 20 heads or qwen-32b's 40 on a 16-way mesh): attention
    activations then shard their SEQUENCE instead (Megatron-style sequence
    parallelism) — the §Perf fix for the score-all-reduce disease."""
    rules = current_rules()
    if rules is None:
        return "seq"
    spec = rules.act_spec(("batch", "seq", "heads", "head_dim"), q_shape)
    return "seq" if spec[2] is not None else "sp_seq"

NEG_INF = -1e30


def attn_params(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    out = {
        "wq": P((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        out["bq"] = P((h, hd), ("heads", "head_dim"), "zeros")
        out["bk"] = P((kv, hd), ("kv_heads", "head_dim"), "zeros")
        out["bv"] = P((kv, hd), ("kv_heads", "head_dim"), "zeros")
    return out


def project_qkv(p: dict, x: jax.Array, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return q, k, v


def project_kv(p: dict, x: jax.Array):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bk" in p:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return k, v


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, q_offset: int = 0,
                      block: int = 1024) -> jax.Array:
    """Online-softmax attention over KV blocks.

    q: (B, Sq, H, D); k, v: (B, Sk, KVH, D); H % KVH == 0.
    Returns (B, Sq, H, D).  fp32 accumulation.
    """
    B, Sq, H, D = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    qg = (q * scale).reshape(B, Sq, KVH, G, D)

    block = min(block, max(Sk, 1))
    kp = _pad_to(k, 1, block)
    vp = _pad_to(v, 1, block)
    nb = kp.shape[1] // block
    # (nb, B, block, KVH, D)
    ks = jnp.moveaxis(kp.reshape(B, nb, block, KVH, D), 1, 0)
    vs = jnp.moveaxis(vp.reshape(B, nb, block, KVH, D), 1, 0)

    qpos = q_offset + jnp.arange(Sq)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, bidx = inp
        kpos = bidx * block + jnp.arange(block)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb,
                       preferred_element_type=jnp.float32)
        valid = kpos < Sk
        if causal:
            valid = valid[None, :] & (qpos[:, None] >= kpos[None, :])
            s = jnp.where(valid[None, None, None], s, NEG_INF)
        else:
            s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KVH, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KVH, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (ks, vs, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, D)  # (B,Sq,KVH,G,D)->(B,Sq,H,D)
    return out.astype(q.dtype)


def naive_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, q_offset: int = 0) -> jax.Array:
    """Reference O(S^2)-memory attention (oracle for tests)."""
    B, Sq, H, D = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, D) / math.sqrt(D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32)
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        mask = qpos[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def quantize_kv(x: jax.Array):
    """Per-(token, head) symmetric int8 quantization of a K/V tensor
    (..., S, KV, HD) -> (int8 tensor, f16 scale (..., S, KV))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def decode_attention_q8(q: jax.Array, ck: jax.Array, cv: jax.Array,
                        k_scale: jax.Array, v_scale: jax.Array,
                        length: jax.Array) -> jax.Array:
    """Decode attention over an int8-quantized cache (production serving
    feature; §Perf iteration E).  Exact math: per-(token, head) scales are
    applied to the *scores* and the *probabilities*, so the int8 tensors
    feed the dots directly — on TPU the int8->bf16 convert fuses into the
    MXU operand stream (cost-model rule I-5) and the cache streams at half
    the bf16 bytes."""
    B, _, H, D = q.shape
    Smax, KVH = ck.shape[1], ck.shape[2]
    G = H // KVH
    ck = lsc(ck, "batch", "kvseq", "kv_heads", "head_dim")
    cv = lsc(cv, "batch", "kvseq", "kv_heads", "head_dim")
    qg = q.reshape(B, KVH, G, D) / math.sqrt(D)
    qg = lsc(qg, "batch", "kv_heads", "q_group", "head_dim")
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   ck.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    s = s * jnp.moveaxis(k_scale.astype(jnp.float32), 1, 2)[:, :, None, :]
    s = lsc(s, "batch", "kv_heads", "q_group", "kvseq")
    valid = jnp.arange(Smax) < length
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = p * jnp.moveaxis(v_scale.astype(jnp.float32), 1, 2)[:, :, None, :]
    p = lsc(p, "batch", "kv_heads", "q_group", "kvseq")
    out = jnp.einsum("bhgk,bkhd->bhgd", p, cv.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def decode_attention(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                     length: jax.Array) -> jax.Array:
    """q: (B, 1, H, D) against cache (B, Smax, KVH, D); positions >= length
    are masked.  fp32 softmax.

    Decode is sequence-parallel (flash-decode style): the cache stays
    sharded on its *sequence* axis ('kvseq' -> tensor axis), the tiny q is
    replicated across it, and the softmax reductions over the sharded axis
    lower to two small all-reduces.  Without the explicit constraints GSPMD
    resolves the q(heads)-vs-cache(seq) sharding mismatch by materializing
    full per-layer cache copies every step (measured: 0.5 GB/layer copies
    on chatglm3 decode_32k — see EXPERIMENTS.md §Perf)."""
    B, _, H, D = q.shape
    Smax, KVH = cache_k.shape[1], cache_k.shape[2]
    G = H // KVH
    cache_k = lsc(cache_k, "batch", "kvseq", "kv_heads", "head_dim")
    cache_v = lsc(cache_v, "batch", "kvseq", "kv_heads", "head_dim")
    qg = q.reshape(B, KVH, G, D) / math.sqrt(D)
    qg = lsc(qg, "batch", "kv_heads", "q_group", "head_dim")
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, cache_k,
                   preferred_element_type=jnp.float32)
    s = lsc(s, "batch", "kv_heads", "q_group", "kvseq")
    valid = jnp.arange(Smax) < length
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = lsc(p, "batch", "kv_heads", "q_group", "kvseq")
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(cache_v.dtype), cache_v)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def attention_block(p: dict, x: jax.Array, cfg: ModelConfig, *,
                    mode: str,
                    positions: Optional[jax.Array] = None,
                    cache: Optional[dict] = None,
                    cache_pos=None,
                    cross_x: Optional[jax.Array] = None,
                    causal: bool = True,
                    impl: str = "blocked",
                    kv_block: int = 1024):
    """Full attention sub-block: projections + rope + core + output proj.

    Returns (out, new_cache).  ``cache`` is a dict {k, v} (+ filled length
    tracked by the caller); for cross-attention the cache holds the encoder
    K/V and is never updated after prefill.
    """
    B, S, _ = x.shape
    is_cross = cross_x is not None or (cache is not None and cache.get("cross", False))

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    seq_ax = _attn_seq_axis(q.shape)
    q = lsc(q, "batch", seq_ax, "heads", "head_dim")

    if positions is None:
        positions = jnp.arange(S)[None, :]

    if not is_cross and cfg.rope_fraction > 0:
        q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)

    new_cache = cache
    if is_cross:
        if cross_x is not None:  # prefill: build the cross cache
            k, v = project_kv(p, cross_x)
            new_cache = {"k": k, "v": v, "cross": True}
        else:
            k, v = cache["k"], cache["v"]
        if mode == "decode":
            out = decode_attention(q, k, v, jnp.asarray(k.shape[1]))
        else:
            out = (blocked_attention(q, k, v, causal=False, block=kv_block)
                   if impl != "naive"
                   else naive_attention(q, k, v, causal=False))
    elif mode == "decode":
        k, v = project_kv(p, x)
        if cfg.rope_fraction > 0:
            k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
        if "k_scale" in cache:                     # int8-quantized cache
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            dus = jax.lax.dynamic_update_slice_in_dim
            ck = dus(cache["k"], kq, cache_pos, axis=1)
            cv = dus(cache["v"], vq, cache_pos, axis=1)
            cks = dus(cache["k_scale"], ks.astype(cache["k_scale"].dtype),
                      cache_pos, axis=1)
            cvs = dus(cache["v_scale"], vs.astype(cache["v_scale"].dtype),
                      cache_pos, axis=1)
            ck = lsc(ck, "batch", "kvseq", "kv_heads", "head_dim")
            cv = lsc(cv, "batch", "kvseq", "kv_heads", "head_dim")
            new_cache = dict(cache, k=ck, v=cv, k_scale=cks, v_scale=cvs)
            out = decode_attention_q8(q, ck, cv, cks, cvs, cache_pos + 1)
        else:
            dus = jax.lax.dynamic_update_slice_in_dim
            ck = dus(cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
            cv = dus(cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
            ck = lsc(ck, "batch", "kvseq", "kv_heads", "head_dim")
            cv = lsc(cv, "batch", "kvseq", "kv_heads", "head_dim")
            new_cache = dict(cache, k=ck, v=cv)
            out = decode_attention(q, ck, cv, cache_pos + 1)
    else:  # train / prefill self-attention
        k, v = project_kv(p, x)
        if cfg.rope_fraction > 0:
            k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
        if mode == "prefill":
            new_cache = {"k": k, "v": v, "cross": False}
        if impl == "naive":
            out = naive_attention(q, k, v, causal=causal)
        elif impl == "flash":
            from ..kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=causal)
        else:
            out = blocked_attention(q, k, v, causal=causal, block=kv_block)

    out = lsc(out, "batch", seq_ax, "heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return lsc(y, "batch", "rseq", "embed"), new_cache
