"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

Train/prefill use the chunked SSD algorithm (quadratic within Q-length
chunks, linear state passing across chunks); decode uses the O(1) recurrence.
The pure-jnp chunked path below is the dry-run/lowering path and the oracle
for the ``kernels.ssd_scan`` Pallas kernel.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import current_rules, lsc
from .params import P


def ssd_pallas_sharded(x, dt, A, Bh, Ch, chunk, initial_state=None):
    """SSD scan through the Pallas kernel, shard_mapped over the mesh.

    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bh/Ch: (B,S,H,N) head-broadcast.
    Batch rides ('pod','data'), heads ride 'model'; the sequence stays whole
    per shard (the inter-chunk recurrence is sequential).  pallas_call has
    no SPMD partitioning rule, so shard_map supplies the per-device view —
    the production pattern for custom kernels.  Outside a rules context the
    kernel runs unsharded (tests, single-host training).
    """
    from ..kernels import ops as kops

    rules = current_rules()
    if rules is None:
        return kops.ssd_scan(x, dt.astype(x.dtype), A, Bh, Ch, chunk=chunk,
                             initial_state=initial_state)
    mesh = rules.mesh
    x_spec = rules.act_spec(("batch", "seq", "ssm_heads", "head_dim"),
                            x.shape)
    dt_spec = rules.act_spec(("batch", "seq", "ssm_heads"), dt.shape)
    a_spec = rules.act_spec(("ssm_heads",), A.shape)
    b_spec = rules.act_spec(("batch", "seq", "ssm_heads", "state"), Bh.shape)
    st_spec = rules.act_spec(("batch", "ssm_heads", "head_dim", "state"),
                             (x.shape[0], x.shape[2], x.shape[3],
                              Bh.shape[-1]))

    if initial_state is None:
        def run(xl, dtl, al, bl, cl):
            return kops.ssd_scan(xl, dtl, al, bl, cl, chunk=chunk)

        return jax.shard_map(
            run, mesh=mesh,
            in_specs=(x_spec, dt_spec, a_spec, b_spec, b_spec),
            out_specs=(x_spec, st_spec), check_vma=False,
        )(x, dt.astype(x.dtype), A, Bh, Ch)

    def run_init(xl, dtl, al, bl, cl, sl):
        return kops.ssd_scan(xl, dtl, al, bl, cl, chunk=chunk,
                             initial_state=sl)

    return jax.shard_map(
        run_init, mesh=mesh,
        in_specs=(x_spec, dt_spec, a_spec, b_spec, b_spec, st_spec),
        out_specs=(x_spec, st_spec), check_vma=False,
    )(x, dt.astype(x.dtype), A, Bh, Ch, initial_state)


def mamba_params(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.d_state
    return {
        "wz": P((d, di), ("embed", "inner")),
        "wx": P((d, di), ("embed", "inner")),
        "wB": P((d, gn), ("embed", "state")),
        "wC": P((d, gn), ("embed", "state")),
        "wdt": P((d, nh), ("embed", "ssm_heads")),
        "conv_x_w": P((di, s.d_conv), ("inner", "kwidth"), "conv"),
        "conv_x_b": P((di,), ("inner",), "zeros"),
        "conv_B_w": P((gn, s.d_conv), ("state", "kwidth"), "conv"),
        "conv_B_b": P((gn,), ("state",), "zeros"),
        "conv_C_w": P((gn, s.d_conv), ("state", "kwidth"), "conv"),
        "conv_C_b": P((gn,), ("state",), "zeros"),
        "dt_bias": P((nh,), ("ssm_heads",), "dt_bias"),
        "A_log": P((nh,), ("ssm_heads",), "a_log"),
        "D": P((nh,), ("ssm_heads",), "ones"),
        "norm": P((di,), ("inner",), "ones"),
        "out_proj": P((di, d), ("inner", "embed")),
    }


def causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                cache: Optional[jax.Array] = None):
    """Depthwise causal conv.  u: (B,S,C), w: (C,K).  Returns (y, new_cache)
    where new_cache holds the last K-1 inputs."""
    Bsz, S, C = u.shape
    K = w.shape[1]
    if cache is None:
        up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([cache.astype(u.dtype), u], axis=1)
    y = jnp.zeros_like(u)
    for k in range(K):
        y = y + up[:, k:k + S, :] * w[:, k].astype(u.dtype)
    y = jax.nn.silu(y + b.astype(u.dtype))
    return y, up[:, -(K - 1):, :]


def _segsum(cs: jax.Array) -> jax.Array:
    """cs: (..., Q) inclusive cumsum of dA.  Returns (..., Q, Q) matrix
    T[i, j] = cs[i] - cs[j] for i >= j, -inf otherwise."""
    Q = cs.shape[-1]
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                initial_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (B, L, H, P); dt: (B, L, H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B, L, G, N) with H % G == 0.
    Returns (y (B, L, H, P), final_state (B, H, P, N)).
    """
    Bsz, L, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // Q

    xc = x.reshape(Bsz, nc, Q, H, Pd)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Q, G, N)
    Cc = Cm.reshape(Bsz, nc, Q, G, N)

    dA = dtc * A.astype(jnp.float32)                      # (B,nc,Q,H)
    cs = jnp.cumsum(dA, axis=2)                           # inclusive

    # ---- intra-chunk (quadratic within chunk)
    Lmat = jnp.exp(_segsum(jnp.moveaxis(cs, -1, -2)))     # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcigs,bcjgs->bcgij", Cc, Bc,
                        preferred_element_type=jnp.float32)  # (B,nc,G,Q,Q)
    scores = jnp.repeat(scores, hpg, axis=2)              # (B,nc,H,Q,Q)
    M = scores * Lmat * jnp.moveaxis(dtc, -1, -2)[..., None, :]
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", M.astype(x.dtype), xc)

    # ---- per-chunk end states: sum_j exp(cs_last - cs_j) dt_j B_j (x) x_j
    decay_st = jnp.exp(cs[:, :, -1:, :] - cs) * dtc       # (B,nc,Q,H)
    Bh = jnp.repeat(Bc, hpg, axis=3)                      # (B,nc,Q,H,N)
    S_c = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn",
                     decay_st.astype(x.dtype), Bh.astype(x.dtype), xc)

    # ---- inter-chunk recurrence over nc (linear)
    gamma = jnp.exp(cs[:, :, -1, :])                      # (B,nc,H) chunk decay

    def step(carry, inp):
        s_c, g = inp                                      # (B,H,P,N), (B,H)
        new = carry * g[..., None, None].astype(carry.dtype) + s_c
        return new, carry                                 # emit state ENTERING chunk

    init = (jnp.zeros((Bsz, H, Pd, N), x.dtype) if initial_state is None
            else initial_state.astype(x.dtype))
    final_state, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(gamma, 1, 0).astype(x.dtype)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # (B,nc,H,P,N)

    # ---- inter-chunk contribution: exp(cs_i) * C_i . prev_state
    Ch = jnp.repeat(Cc, hpg, axis=3)                      # (B,nc,Q,H,N)
    y_off = jnp.einsum("bcihn,bchpn->bcihp", Ch.astype(x.dtype), prev_states)
    y_off = y_off * jnp.exp(cs)[..., None].astype(x.dtype)

    y = (y_diag + y_off).reshape(Bsz, Lp, H, Pd)[:, :L]
    return y, final_state


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    A: jax.Array, Bm: jax.Array, Cm: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence.  state: (B,H,P,N); x: (B,H,P); dt: (B,H);
    Bm, Cm: (B,G,N).  Returns (y (B,H,P), new_state)."""
    H = x.shape[1]
    G = Bm.shape[1]
    hpg = H // G
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A.astype(jnp.float32))             # (B,H)
    Bh = jnp.repeat(Bm, hpg, axis=1)                      # (B,H,N)
    Ch = jnp.repeat(Cm, hpg, axis=1)
    upd = (dtf[..., None] * Bh.astype(jnp.float32))[:, :, None, :] \
        * x.astype(jnp.float32)[..., None]                # (B,H,P,N)
    new_state = state.astype(jnp.float32) * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch.astype(jnp.float32))
    return y.astype(x.dtype), new_state.astype(state.dtype)


def apply_mamba(p: dict, x_in: jax.Array, cfg: ModelConfig, *, mode: str,
                cache: Optional[dict] = None, impl: str = "jnp"):
    """Full Mamba2 mixer.  x_in: (B, S, d).  Returns (out, new_cache).
    ``impl``: 'jnp' (chunked XLA path, the oracle) or 'pallas' (VMEM-tiled
    kernel via shard_map — the §Perf-tuned production path)."""
    from .layers import rms_norm_gated

    s = cfg.ssm
    Bsz, S, d = x_in.shape
    di = s.d_inner(d)
    nh = s.n_heads(d)
    G, N, Pd = s.n_groups, s.d_state, s.head_dim

    z = jnp.einsum("bsd,de->bse", x_in, p["wz"])
    xr = jnp.einsum("bsd,de->bse", x_in, p["wx"])
    Br = jnp.einsum("bsd,de->bse", x_in, p["wB"])
    Cr = jnp.einsum("bsd,de->bse", x_in, p["wC"])
    dt_raw = jnp.einsum("bsd,de->bse", x_in, p["wdt"])
    xr = lsc(xr, "batch", "seq", "inner")

    cx = cache.get("conv_x") if cache else None
    cB = cache.get("conv_B") if cache else None
    cC = cache.get("conv_C") if cache else None
    xr, ncx = causal_conv(xr, p["conv_x_w"], p["conv_x_b"], cx)
    Br, ncB = causal_conv(Br, p["conv_B_w"], p["conv_B_b"], cB)
    Cr, ncC = causal_conv(Cr, p["conv_C_w"], p["conv_C_b"], cC)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    # shard SSD heads on 'model': the (B, nc, H, Q, Q) intra-chunk matrices
    # (the memory hot-spot the Pallas kernel tiles away) ride the tensor axis
    xh = lsc(xr.reshape(Bsz, S, nh, Pd), "batch", "seq", "ssm_heads",
             "head_dim")
    dt = lsc(dt, "batch", "seq", "ssm_heads")
    Bm = Br.reshape(Bsz, S, G, N)
    Cm = Cr.reshape(Bsz, S, G, N)

    if mode == "decode":
        assert S == 1
        y, new_state = ssd_decode_step(
            cache["state"], xh[:, 0], dt[:, 0].astype(x_in.dtype),
            A, Bm[:, 0], Cm[:, 0])
        y = y[:, None]                                     # (B,1,H,P)
        new_cache = dict(cache, conv_x=ncx, conv_B=ncB, conv_C=ncC,
                         state=new_state)
    else:
        init = cache["state"] if cache else None
        if impl == "pallas":
            hpg = nh // G
            Bh = jnp.repeat(Bm, hpg, axis=2)              # (B,S,H,N)
            Ch = jnp.repeat(Cm, hpg, axis=2)
            y, final_state = ssd_pallas_sharded(xh, dt, A, Bh, Ch, s.chunk,
                                                initial_state=init)
        else:
            y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk, init)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv_x": ncx, "conv_B": ncB, "conv_C": ncC,
                         "state": final_state}

    y = y + xh * p["D"].astype(y.dtype)[:, None]
    y = y.reshape(Bsz, S, di)
    y = rms_norm_gated(y, p["norm"], z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return lsc(out, "batch", "rseq", "embed"), new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.d_state
    return {
        "conv_x": jnp.zeros((batch, s.d_conv - 1, di), dtype),
        "conv_B": jnp.zeros((batch, s.d_conv - 1, gn), dtype),
        "conv_C": jnp.zeros((batch, s.d_conv - 1, gn), dtype),
        "state": jnp.zeros((batch, nh, s.head_dim, s.d_state), dtype),
    }
