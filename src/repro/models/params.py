"""Parameter-tree machinery: declare shapes+logical axes once, then derive
abstract trees (for dry-run lowering), initialized trees (for real runs) and
sharding trees (for pjit in/out shardings) from the same declaration.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class P:
    """One parameter leaf: shape + logical axes + init recipe."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | embed | conv | a_log | dt_bias
    scale: float = 1.0         # fan-in style scale override (0 -> auto)
    dtype: Optional[str] = None  # leaf dtype override (int8 KV caches etc.)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_leaf(x) -> bool:
    return isinstance(x, P)


def tree_map(f: Callable, tree):
    return jax.tree.map(f, tree, is_leaf=is_leaf)


def abstract(tree, dtype=jnp.bfloat16):
    def mk(p: P):
        return jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype or dtype))

    return tree_map(mk, tree)


def logical_axes(tree):
    return tree_map(lambda p: p.axes, tree)


def shardings(tree, rules, dtype=jnp.bfloat16):
    """NamedSharding tree from a spec tree + MeshRules."""
    return tree_map(lambda p: rules.param_sharding(p.axes, p.shape), tree)


def _init_leaf(key, p: P, dtype):
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "a_log":
        # mamba2: A in [1, 16) -> log
        u = jax.random.uniform(key, p.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if p.init == "dt_bias":
        # softplus^-1 of dt ~ U[1e-3, 1e-1]
        dt = jnp.exp(
            jax.random.uniform(key, p.shape, jnp.float32)
            * (math.log(0.1) - math.log(1e-3))
            + math.log(1e-3)
        )
        inv = dt + jnp.log(-jnp.expm1(-dt))
        return inv.astype(dtype)
    if p.init == "embed":
        return (jax.random.normal(key, p.shape, jnp.float32) * 0.02).astype(dtype)
    # 'normal' / 'conv': truncated-normal, fan-in scaled
    fan_in = p.shape[0] if len(p.shape) > 1 else p.shape[-1]
    if p.init == "conv":
        fan_in = p.shape[-1] * 1
    std = p.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, p.shape, jnp.float32) * std).astype(dtype)


def init(tree, key, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_leaf)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, p, dtype) for k, p in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def count(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_leaf)
    return sum(int(np.prod(p.shape)) for p in leaves)


def bytes_of(tree, dtype=jnp.bfloat16) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_leaf)
    return sum(int(np.prod(p.shape))
               * jnp.dtype(p.dtype or dtype).itemsize for p in leaves)
