"""Shared layers: norms, rotary embeddings, MLP variants, embeddings, loss."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import lsc
from .params import P


# ------------------------------------------------------------------- norms
def norm_params(cfg: ModelConfig) -> dict:
    if cfg.norm_kind == "layernorm":
        return {"scale": P((cfg.d_model,), ("embed",), "ones"),
                "bias": P((cfg.d_model,), ("embed",), "zeros")}
    return {"scale": P((cfg.d_model,), ("embed",), "ones")}


def apply_norm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(dt)


def rms_norm_gated(x: jax.Array, scale: jax.Array, gate: jax.Array,
                   eps: float = 1e-6) -> jax.Array:
    """Mamba2 output norm: RMSNorm(x * silu(gate))."""
    dt = x.dtype
    xf = x.astype(jnp.float32) * jax.nn.silu(gate.astype(jnp.float32))
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, fraction: float, theta: float) -> Optional[jax.Array]:
    rot = int(head_dim * fraction)
    rot -= rot % 2
    if rot == 0:
        return None
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, fraction: float,
               theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, fraction, theta)
    if inv is None:
        return x
    rot = inv.shape[0] * 2
    xr, xp = x[..., :rot], x[..., rot:]
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., seq, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1 = xr[..., 0::2].astype(jnp.float32)
    x2 = xr[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1)


# ---------------------------------------------------------------------- MLP
def mlp_params(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "wi_gate": P((d, f), ("embed", "mlp")),
            "wi_up": P((d, f), ("embed", "mlp")),
            "wo": P((f, d), ("mlp", "embed")),
        }
    return {"wi": P((d, f), ("embed", "mlp")), "wo": P((f, d), ("mlp", "embed"))}


def apply_mlp(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        g = jnp.einsum("...d,df->...f", x, p["wi_gate"])
        u = jnp.einsum("...d,df->...f", x, p["wi_up"])
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"])
        if kind == "sq_relu":
            h = jnp.square(jax.nn.relu(h))
        else:
            h = jax.nn.gelu(h)
    h = lsc(h, "batch", "rseq", "mlp")
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ----------------------------------------------------------------- embedding
def embed_params(cfg: ModelConfig) -> dict:
    V, d = cfg.padded_vocab, cfg.d_model
    out = {"table": P((V, d), ("vocab", "embed"), "embed")}
    if not cfg.tie_embeddings:
        out["head"] = P((d, V), ("embed", "vocab"))
    return out


def embed_tokens(p: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(p["table"], tokens, axis=0)
    if cfg.name.startswith("paligemma"):  # gemma scales embeddings
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return lsc(x, "batch", "rseq", "embed")


def logits_from_hidden(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        out = jnp.einsum("...d,vd->...v", x, p["table"])
    else:
        out = jnp.einsum("...d,dv->...v", x, p["head"])
    return lsc(out, "batch", "rseq", "vocab")


# --------------------------------------------------------------------- loss
def next_token_loss(logits: jax.Array, tokens: jax.Array,
                    vocab_size: int) -> jax.Array:
    """Mean next-token CE.  logits: (B,S,Vp) for tokens (B,S); padded vocab
    entries are excluded by masking labels >= vocab_size (never produced)."""
    lg = logits[:, :-1].astype(jnp.float32)
    tg = tokens[:, 1:]
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)
