"""Mixture-of-Experts FFN: sort-based capacity routing (gather/scatter form).

Design notes (vs the GShard one-hot-einsum formulation):

* Dispatch/combine are *gathers/scatters*, not one-hot matmuls — the one-hot
  einsum would add O(T·E·C·d) fake FLOPs that swamp the roofline compute term
  with work no deployed system performs.
* Tokens are routed within *groups* (one group per sequence; one global group
  for decode).  The group axis carries the data sharding, so routing math is
  fully local; only the (G, E, C, d) dispatched tensor reshards from
  G-sharded to E-sharded (EP) — the all-to-all the paper('s roofline) sees.
* EP vs expert-TP is decided by divisibility in the sharding rules:
  llama4 (16e on a 16-way 'model' axis) -> EP; grok (8e) -> experts
  replicated, each expert's d_ff sharded 16-way ('mlp' -> 'model').
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import lsc
from .params import P


def moe_params(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, cfg.d_ff, m.n_experts
    out = {"router": P((d, E), ("embed", "experts"))}
    if cfg.mlp_kind in ("swiglu", "geglu"):
        out["wi_gate"] = P((E, d, f), ("experts", "embed", "mlp"))
        out["wi_up"] = P((E, d, f), ("experts", "embed", "mlp"))
        out["wo"] = P((E, f, d), ("experts", "mlp", "embed"))
    else:
        out["wi"] = P((E, d, f), ("experts", "embed", "mlp"))
        out["wo"] = P((E, f, d), ("experts", "mlp", "embed"))
    if m.n_shared_experts:
        fs = f * m.n_shared_experts
        out["shared_wi_gate"] = P((d, fs), ("embed", "mlp"))
        out["shared_wi_up"] = P((d, fs), ("embed", "mlp"))
        out["shared_wo"] = P((fs, d), ("mlp", "embed"))
    return out


def capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = math.ceil(tokens_per_group * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 lanes


def _route_group(x: jax.Array, expert_idx: jax.Array, gates: jax.Array,
                 C: int, E: int):
    """Per-group routing. x: (T, d); expert_idx/gates: (T, k).
    Returns (dispatched (E, C, d), st (T*k,), dest (T*k,), keep (T*k,))."""
    T, k = expert_idx.shape
    e_flat = expert_idx.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(e_flat, stable=True)
    se, st = e_flat[order], t_flat[order]
    # rank within expert = index - first index of that expert in sorted order
    expert_start = jnp.searchsorted(se, jnp.arange(E), side="left")
    rank = jnp.arange(T * k) - expert_start[se]
    keep = rank < C
    dest = jnp.where(keep, se * C + jnp.minimum(rank, C - 1), E * C)
    # slot -> source token (E*C+1 with trash row)
    src = jnp.full((E * C + 1,), T, jnp.int32).at[dest].set(st.astype(jnp.int32))
    xpad = jnp.concatenate([x, jnp.zeros((1, x.shape[-1]), x.dtype)], axis=0)
    dispatched = jnp.take(xpad, src[: E * C], axis=0).reshape(E, C, -1)
    return dispatched, st, dest, keep, order


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig,
              train: bool) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss). Groups = sequences (train/prefill) or
    one global group (decode, S == 1)."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.n_experts, m.top_k
    if S == 1:
        xg = x.reshape(1, B, d)                       # one group for decode
    else:
        xg = x                                        # (G=B, S, d)
    G, T, _ = xg.shape
    C = capacity(T, cfg)

    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(xg.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)   # (G, T, k)
    if k > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))                               # (E,)
    fe = jnp.mean(
        (jax.nn.one_hot(expert_idx, E).sum(axis=2) > 0).astype(jnp.float32),
        axis=(0, 1),
    )
    aux = E * jnp.sum(me * fe) * m.aux_loss_coef

    dispatched, st, dest, keep, order = jax.vmap(
        lambda xx, ee, gg: _route_group(xx, ee, gg, C, E)
    )(xg, expert_idx, gate_vals)
    dispatched = lsc(dispatched, "batch", "experts", "capacity", "embed")

    # expert FFN: (G, E, C, d) x (E, d, f)
    if "wi_gate" in p:
        g = jnp.einsum("gecd,edf->gecf", dispatched, p["wi_gate"])
        u = jnp.einsum("gecd,edf->gecf", dispatched, p["wi_up"])
        act = jax.nn.silu(g) if cfg.mlp_kind == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jnp.einsum("gecd,edf->gecf", dispatched, p["wi"])
        h = jnp.square(jax.nn.relu(h)) if cfg.mlp_kind == "sq_relu" else jax.nn.gelu(h)
    h = lsc(h, "batch", "experts", "capacity", "mlp")
    ys = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    ys = lsc(ys, "batch", "experts", "capacity", "embed")

    # combine: gather expert outputs back to tokens, weighted by gates
    def _combine(ys_g, st_g, dest_g, keep_g, gates_g, order_g):
        ys_flat = ys_g.reshape(E * C, d)
        ys_flat = jnp.concatenate([ys_flat, jnp.zeros((1, d), ys_g.dtype)], axis=0)
        rows = jnp.take(ys_flat, dest_g, axis=0)                    # (T*k, d)
        w = gates_g.reshape(-1)[order_g] * keep_g
        rows = rows * w[:, None].astype(ys_g.dtype)
        return jnp.zeros((T, d), ys_g.dtype).at[st_g].add(rows)

    out = jax.vmap(_combine)(ys, st, dest, keep, gate_vals, order)

    if m.n_shared_experts:
        g = jnp.einsum("gtd,df->gtf", xg, p["shared_wi_gate"])
        u = jnp.einsum("gtd,df->gtf", xg, p["shared_wi_up"])
        act = jax.nn.silu(g) if cfg.mlp_kind == "swiglu" else jax.nn.gelu(g)
        out = out + jnp.einsum("gtf,fd->gtd", act * u, p["shared_wo"])

    return out.reshape(B, S, d), aux
