"""Public jit'd wrappers for the Pallas kernels.

On the CPU container the kernels run in ``interpret=True`` mode (the kernel
body executes as traced python — correct semantics, no Mosaic); on a real TPU
``interpret=False`` compiles through Mosaic.  The switch is automatic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import ssd_scan as _ssd
from . import stream as _stream


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128) -> jax.Array:
    """(B, S, H, D)-layout flash attention (matches models.attention)."""
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    out = _fa.flash_attention_bhsd(qt, kt, vt, causal=causal,
                                   block_q=block_q, block_k=block_k,
                                   interpret=_interpret())
    return jnp.transpose(out, (0, 2, 1, 3))


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, chunk: int = 128,
             initial_state: Optional[jax.Array] = None):
    """Full SSD scan = Pallas intra-chunk kernel + jnp inter-chunk recurrence.

    x: (B,L,H,P); dt: (B,L,H) post-softplus; A: (H,); Bm, Cm: (B,L,H,N)
    (head-broadcast).  Returns (y, final_state (B,H,P,N)).
    """
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // Q
    xc = x.reshape(B, nc, Q, H, P)
    dtc = dt.reshape(B, nc, Q, H)
    Bc = Bm.reshape(B, nc, Q, H, N)
    Cc = Cm.reshape(B, nc, Q, H, N)

    y_diag, states, gamma = _ssd.ssd_chunk_pallas(
        xc, dtc, A, Bc, Cc, interpret=_interpret())

    # inter-chunk recurrence (linear in nc)
    def step(carry, inp):
        s_c, g = inp                                       # (B,H,N,P), (B,H)
        new = carry * g[..., None, None] + s_c
        return new, carry

    init = (jnp.zeros((B, H, N, P), jnp.float32) if initial_state is None
            else jnp.moveaxis(initial_state, -1, -2).astype(jnp.float32))
    final, prev = jax.lax.scan(step, init,
                               (jnp.moveaxis(states, 1, 0),
                                jnp.moveaxis(gamma, 1, 0)))
    prev = jnp.moveaxis(prev, 0, 1)                        # (B,nc,H,N,P)

    # inter-chunk output: exp(cs_i) * C_i . prev_state
    dA = dtc.astype(jnp.float32) * A.astype(jnp.float32)
    cs = jnp.cumsum(dA, axis=2)                            # (B,nc,Q,H)
    y_off = jnp.einsum("bcihn,bchnp->bcihp", Cc.astype(jnp.float32), prev)
    y_off = y_off * jnp.exp(cs)[..., None]

    y = (y_diag.astype(jnp.float32) + y_off).reshape(B, Lp, H, P)[:, :L]
    return y.astype(x.dtype), jnp.moveaxis(final, -1, -2).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("name", "block"))
def elementwise(name: str, x1: jax.Array, x2: Optional[jax.Array] = None,
                y0: Optional[jax.Array] = None, block: int = 2048):
    return _stream.elementwise(name, x1, x2, y0, block=block,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block",))
def stream_triad(a: jax.Array, b: jax.Array, scalar: float = 3.0,
                 block: int = 8192):
    return _stream.stream_triad(a, b, scalar, block=block,
                                interpret=_interpret())
