"""Pure-jnp oracles for every Pallas kernel (independent formulations)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .stream import EXPRS, _DTYPES


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """Naive O(S^2) attention.  q: (B,H,Sq,D); k,v: (B,KVH,Sk,D)."""
    B, H, Sq, D = q.shape
    KVH, Sk = k.shape[1], k.shape[2]
    G = H // KVH
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
            Cm: jax.Array, initial_state: Optional[jax.Array] = None):
    """Sequential (token-by-token) SSD recurrence — the ground truth the
    chunked algorithm and the Pallas kernel must reproduce.

    x: (B,L,H,P); dt: (B,L,H); A: (H,); Bm,Cm: (B,L,H,N) (head-broadcast).
    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(state, t):
        xt, dtt, bt, ct = t
        da = jnp.exp(dtt * Af)                              # (B,H)
        upd = (dtt[..., None] * bt)[:, :, None, :] * xt[..., None]
        state = state * da[..., None, None] + upd           # (B,H,P,N)
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    init = (jnp.zeros((Bsz, H, P, N), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))
    final, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    return y, final.astype(x.dtype)


def elementwise_ref(name: str, x1: jax.Array, x2: Optional[jax.Array] = None,
                    y0: Optional[jax.Array] = None) -> jax.Array:
    fn, n_in, din, dout = EXPRS[name]
    if x2 is None:
        x2 = x1
    if y0 is None:
        y0 = jnp.zeros(x1.shape, _DTYPES[dout])
    return fn(x1, x2, y0).astype(_DTYPES[dout])


def stream_triad_ref(a: jax.Array, b: jax.Array, scalar: float = 3.0):
    return a + scalar * b
