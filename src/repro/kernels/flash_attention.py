"""Flash attention Pallas TPU kernel (online softmax, causal, GQA).

TPU-native design (vs the CUDA flash-attention algorithm):
* the KV axis is the innermost grid dimension — on TPU the grid is executed
  sequentially per core, so VMEM scratch (m, l, acc) carries across KV steps
  (the OoO-window analogue gem5 would model; here it is a software pipeline),
* BlockSpec tiles are (Bq, D) / (Bk, D) with D kept whole — MXU-aligned
  (D in {64, 128, 192, 256}; Bq, Bk multiples of 128 lanes),
* GQA is expressed in the *index map* (query head h reads KV head h // G) —
  no KV repetition through HBM.

Validated in interpret mode against ``ref.flash_attention_ref`` (CPU is the
container's only backend; TPU is the deployment target).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  seq_k: int):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale           # (Bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                   # (Bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Bq, Bk)

    iq = pl.program_id(2)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    valid = kpos < seq_k
    if causal:
        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        valid = valid & (qpos >= kpos)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, block_q: int = 128,
                         block_k: int = 128,
                         interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, KVH, Sk, D).  Returns (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    KVH, Sk = k.shape[1], k.shape[2]
    assert H % KVH == 0
    G = H // KVH
    scale = 1.0 / math.sqrt(D)

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = qp.shape[2] // block_q
    nk = kp.shape[2] // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq]
