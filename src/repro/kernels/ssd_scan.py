"""Mamba2 SSD intra-chunk Pallas TPU kernel.

Computes, per (batch, head, chunk) grid cell, entirely in VMEM:
  * the cumulative decay ``cs = cumsum(dt * A)``,
  * the intra-chunk quadratic contribution
    ``y[i] = sum_{j<=i} (C_i . B_j) exp(cs_i - cs_j) dt_j x_j``,
  * the per-chunk end state ``S = sum_j exp(cs_last - cs_j) dt_j B_j (x) x_j``
  * and the chunk decay ``gamma = exp(cs_last)``.

The O(nc) inter-chunk recurrence and the rank-1 inter-chunk output correction
stay in jnp (``ops.ssd_scan`` composes them): they are tiny and XLA fuses
them well — matching the paper's division of labour between the simulated
pipeline (hot loop) and the surrounding infrastructure.

Block shapes: (Q, P) and (Q, N) tiles with Q=chunk (128/256) — MXU-aligned
on the (Q, Q) score matmul and the (N, P) state outer product.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, state_ref, gamma_ref, *, chunk: int):
    # blocks: x (1,1,Q,P), dt (1,1,Q), a (1,), b/c (1,1,Q,N)
    x = x_ref[0, 0].astype(jnp.float32)                   # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)                 # (Q,)
    A = a_ref[0].astype(jnp.float32)                      # scalar
    Bm = b_ref[0, 0].astype(jnp.float32)                  # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)                  # (Q, N)

    dA = dt * A
    cs = jnp.cumsum(dA)                                   # (Q,)

    # intra-chunk: M[i,j] = (C_i.B_j) * exp(cs_i - cs_j) * dt_j, j <= i
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    decay = jnp.exp(cs[:, None] - cs[None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    M = jnp.where(ii >= jj, scores * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (Q,P)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # chunk end state: sum_j exp(cs_last - cs_j) dt_j B_j (x) x_j -> (N, P)
    w = jnp.exp(cs[-1] - cs) * dt                         # (Q,)
    state = jax.lax.dot_general(Bm * w[:, None], x, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)   # (N,P)
    state_ref[0, 0] = state.astype(state_ref.dtype)
    gamma_ref[0, 0] = jnp.exp(cs[-1]).astype(gamma_ref.dtype)


def _ssd_chunk_bwd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                          dy_ref, dstate_ref, dgamma_ref,
                          dx_ref, ddt_ref, db_ref, dc_ref, da_ref, *,
                          chunk: int):
    """Intra-chunk SSD backward, entirely in VMEM per (b, c·h) block.

    Recomputes cs/Γ/s/M (flash-attention-style recompute-in-bwd), then
    forms the five cotangents with ~8 (Q,Q)/(Q,N)/(Q,P) matmuls.  The
    inter-chunk scan and the y_off term are differentiated by JAX outside
    (they are jnp code in ops.ssd_scan)."""
    x = x_ref[0, 0].astype(jnp.float32)                   # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)                 # (Q,)
    A = a_ref[0].astype(jnp.float32)
    Bm = b_ref[0, 0].astype(jnp.float32)                  # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)
    dy = dy_ref[0, 0].astype(jnp.float32)                 # (Q, P)
    dstate = dstate_ref[0, 0].astype(jnp.float32)         # (N, P)
    dgamma = dgamma_ref[0, 0].astype(jnp.float32)         # scalar

    cs = jnp.cumsum(dt * A)
    decay = jnp.exp(cs[:, None] - cs[None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tril = ii >= jj
    G = jnp.where(tril, decay, 0.0)                       # Γ
    s = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    K = s * G                                             # s∘Γ
    M = K * dt[None, :]

    dM = jax.lax.dot_general(dy, x, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    dx = jax.lax.dot_general(M, dy, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # M^T dy

    U = dM * K                                            # for ddt (÷dt form)
    T1 = U * dt[None, :]                                  # dM∘M
    dcs = jnp.sum(T1, axis=1) - jnp.sum(T1, axis=0)       # Γ path
    ddt = jnp.sum(U, axis=0)                              # dt_j factor of M

    V = dM * G * dt[None, :]                              # ds
    dc = jax.lax.dot_general(V, Bm, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    db = jax.lax.dot_general(V, Cm, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # ---- state path: state = B^T diag(w) X, w = exp(cs[-1]-cs)·dt
    expw = jnp.exp(cs[-1] - cs)
    w = expw * dt
    R = jax.lax.dot_general(Bm, dstate, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q,P)
    dx = dx + w[:, None] * R
    dw = jnp.sum(R * x, axis=1)                           # (Q,)
    db = db + jax.lax.dot_general(w[:, None] * x, dstate,
                                  (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    dcs = dcs - dw * w
    dcs = dcs.at[-1].add(jnp.sum(dw * w))
    ddt = ddt + dw * expw

    # ---- gamma path: γ = exp(cs[-1])
    dcs = dcs.at[-1].add(dgamma * jnp.exp(cs[-1]))

    # ---- cumsum transpose + A
    ddA = jnp.cumsum(dcs[::-1])[::-1]                     # reverse cumsum
    ddt = ddt + ddA * A
    da = jnp.sum(ddA * dt)

    dx_ref[0, 0] = dx.astype(dx_ref.dtype)
    ddt_ref[0, 0] = ddt.astype(ddt_ref.dtype)
    db_ref[0, 0] = db.astype(db_ref.dtype)
    dc_ref[0, 0] = dc.astype(dc_ref.dtype)
    da_ref[0, 0] = da.astype(da_ref.dtype)


def ssd_chunk_bwd_pallas(xt, dtt, a_tiled, bt, ct, dy, dstate, dgamma, *,
                         interpret: bool = True):
    """Backward pass over (B, CH) blocks.  Layouts match ssd_chunk_pallas's
    internal (B, CH, Q, -) form.  Returns (dx, ddt, db, dc, da_blocks)."""
    B, CH, Q, P = xt.shape
    N = bt.shape[-1]
    kernel = functools.partial(_ssd_chunk_bwd_kernel, chunk=Q)
    return pl.pallas_call(
        kernel,
        grid=(B, CH),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, ch: (b, ch, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, ch: (b, ch, 0)),
            pl.BlockSpec((1,), lambda b, ch: (ch,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, ch: (b, ch, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, ch: (b, ch, 0, 0)),
            pl.BlockSpec((1, 1, Q, P), lambda b, ch: (b, ch, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, ch: (b, ch, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, ch: (b, ch)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, ch: (b, ch, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, ch: (b, ch, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, ch: (b, ch, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, ch: (b, ch, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, ch: (b, ch)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, CH, Q, P), xt.dtype),
            jax.ShapeDtypeStruct((B, CH, Q), jnp.float32),
            jax.ShapeDtypeStruct((B, CH, Q, N), jnp.float32),
            jax.ShapeDtypeStruct((B, CH, Q, N), jnp.float32),
            jax.ShapeDtypeStruct((B, CH), jnp.float32),
        ],
        interpret=interpret,
    )(xt, dtt, a_tiled, bt, ct, dy, dstate, dgamma)


def _interp() -> bool:
    return jax.default_backend() != "tpu"


def _chunks_fwd_impl(xt, dtt, a_tiled, bt, ct):
    B, CH, Q, P = xt.shape
    N = bt.shape[-1]
    kernel = functools.partial(_ssd_chunk_kernel, chunk=Q)
    return pl.pallas_call(
        kernel,
        grid=(B, CH),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, ch: (b, ch, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, ch: (b, ch, 0)),
            pl.BlockSpec((1,), lambda b, ch: (ch,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, ch: (b, ch, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, ch: (b, ch, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, ch: (b, ch, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, ch: (b, ch, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, ch: (b, ch)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, CH, Q, P), xt.dtype),
            jax.ShapeDtypeStruct((B, CH, N, P), jnp.float32),
            jax.ShapeDtypeStruct((B, CH), jnp.float32),
        ],
        interpret=_interp(),
    )(xt, dtt, a_tiled, bt, ct)


@jax.custom_vjp
def ssd_chunks_flat(xt, dtt, a_tiled, bt, ct):
    """(B, CH=nc·H, Q, -) layout intra-chunk pass with a Pallas backward
    (pallas_call has no autodiff rule; the custom VJP recomputes cs/Γ/M in
    VMEM, flash-attention-style)."""
    return _chunks_fwd_impl(xt, dtt, a_tiled, bt, ct)


def _chunks_fwd(xt, dtt, a_tiled, bt, ct):
    out = _chunks_fwd_impl(xt, dtt, a_tiled, bt, ct)
    return out, (xt, dtt, a_tiled, bt, ct)


def _chunks_bwd(res, cts):
    xt, dtt, a_tiled, bt, ct = res
    dy, dstates, dgamma = cts
    dx, ddt, db, dc, da_blocks = ssd_chunk_bwd_pallas(
        xt, dtt, a_tiled, bt, ct,
        dy.astype(xt.dtype), dstates.astype(jnp.float32),
        dgamma.astype(jnp.float32), interpret=_interp())
    da_tiled = jnp.sum(da_blocks, axis=0)                 # (CH,)
    return (dx.astype(xt.dtype), ddt.astype(dtt.dtype),
            da_tiled.astype(a_tiled.dtype), db.astype(bt.dtype),
            dc.astype(ct.dtype))


ssd_chunks_flat.defvjp(_chunks_fwd, _chunks_bwd)


def ssd_chunk_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                     Bm: jax.Array, Cm: jax.Array, *,
                     interpret: bool = True):
    """Intra-chunk SSD pass.

    x: (B, nc, Q, H, P); dt: (B, nc, Q, H) (post-softplus, fp32-ok);
    A: (H,); Bm, Cm: (B, nc, Q, H, N) (already broadcast from groups).
    Returns (y_diag (B,nc,Q,H,P), states (B,nc,H,N,P), gamma (B,nc,H)).
    Differentiable (custom VJP -> Pallas backward kernel).
    """
    B, nc, Q, H, P = x.shape
    N = Bm.shape[-1]
    # rearrange to put (Q, feature) in the last two dims per (b, c, h) cell
    xt = jnp.transpose(x, (0, 1, 3, 2, 4)).reshape(B, nc * H, Q, P)
    dtt = jnp.transpose(dt, (0, 1, 3, 2)).reshape(B, nc * H, Q)
    bt = jnp.transpose(Bm, (0, 1, 3, 2, 4)).reshape(B, nc * H, Q, N)
    ct = jnp.transpose(Cm, (0, 1, 3, 2, 4)).reshape(B, nc * H, Q, N)
    a_tiled = jnp.tile(A, nc)                              # (nc*H,)

    y, states, gamma = ssd_chunks_flat(xt, dtt, a_tiled, bt, ct)
    y = jnp.transpose(y.reshape(B, nc, H, Q, P), (0, 1, 3, 2, 4))
    states = states.reshape(B, nc, H, N, P)
    gamma = gamma.reshape(B, nc, H)
    return y, states, gamma
