"""The paper's evaluation kernels as Pallas TPU kernels.

Table 1's 28 single-core kernels (basic arithmetic / type conversion /
numeric / mathematical) plus Stream Triad (§5.2).  Each is a blocked
elementwise Pallas kernel with explicit VMEM tiling — the TPU analogue of
the paper's 8-wide SVE SIMD loops (here the VPU's (8, 128) vregs).

These serve three roles:
 1. paper-faithful reproduction of the evaluation workload (Figs 3-5),
 2. calibration targets for ``core.calibrate`` (simulator vs measured, the
    paper's test-chip comparison),
 3. simple, sweep-friendly kernels for the per-kernel allclose test suite.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

C0 = 1.6180339887  # the paper's scalar constant (value irrelevant)
LOG2_10 = 3.321928094887362


def _exp10(x):
    return jnp.exp2(x * LOG2_10)


# name -> (fn(x1, x2, y), n_inputs, in_dtype, out_dtype)
# Fortran semantics: aint=trunc, nint=round-to-int, anint=round-to-float,
# sign(a,b)=|a|*sgn(b), mod(a,b)=a-int(a/b)*b (Fortran MOD, not modulo).
EXPRS: dict[str, tuple[Callable, int, str, str]] = {
    "add":   (lambda a, b, y: a + b, 2, "f8", "f8"),
    "sub":   (lambda a, b, y: a - b, 2, "f8", "f8"),
    "mul":   (lambda a, b, y: a * b, 2, "f8", "f8"),
    "fma":   (lambda a, b, y: y + C0 * a, 1, "f8", "f8"),
    "div":   (lambda a, b, y: a / b, 2, "f8", "f8"),
    "rev":   (lambda a, b, y: 1.0 / a, 1, "f8", "f8"),
    "sqrt":  (lambda a, b, y: jnp.sqrt(a), 1, "f8", "f8"),
    "f2d":   (lambda a, b, y: a.astype(jnp.float64), 1, "f4", "f8"),
    "i2d":   (lambda a, b, y: a.astype(jnp.float64), 1, "i4", "f8"),
    "d2f":   (lambda a, b, y: a.astype(jnp.float32), 1, "f8", "f4"),
    "d2i":   (lambda a, b, y: a.astype(jnp.int32), 1, "f8", "i4"),
    "aint":  (lambda a, b, y: jnp.trunc(a), 1, "f8", "f8"),
    "nint":  (lambda a, b, y: jnp.rint(a).astype(jnp.int32), 1, "f8", "i4"),
    "anint": (lambda a, b, y: jnp.rint(a), 1, "f8", "f8"),
    "abs":   (lambda a, b, y: jnp.abs(a), 1, "f8", "f8"),
    "max":   (lambda a, b, y: jnp.maximum(a, b), 2, "f8", "f8"),
    "min":   (lambda a, b, y: jnp.minimum(a, b), 2, "f8", "f8"),
    "mod":   (lambda a, b, y: a - jnp.trunc(a / b) * b, 2, "f8", "f8"),
    "sign":  (lambda a, b, y: jnp.copysign(jnp.abs(a), b), 2, "f8", "f8"),
    "atan":  (lambda a, b, y: jnp.arctan(a), 1, "f8", "f8"),
    "atan2": (lambda a, b, y: jnp.arctan2(a, b), 2, "f8", "f8"),
    "cos":   (lambda a, b, y: jnp.cos(a), 1, "f8", "f8"),
    "sin":   (lambda a, b, y: jnp.sin(a), 1, "f8", "f8"),
    "exp":   (lambda a, b, y: jnp.exp(a), 1, "f8", "f8"),
    "exp10": (lambda a, b, y: _exp10(a), 1, "f8", "f8"),
    "log":   (lambda a, b, y: jnp.log(a), 1, "f8", "f8"),
    "log10": (lambda a, b, y: jnp.log10(a), 1, "f8", "f8"),
    "pwr":   (lambda a, b, y: jnp.exp(b * jnp.log(a)), 2, "f8", "f8"),
}

_DTYPES = {"f8": jnp.float64, "f4": jnp.float32, "i4": jnp.int32,
           "bf16": jnp.bfloat16}


def dtypes_for(name: str):
    fn, n_in, din, dout = EXPRS[name]
    return _DTYPES[din], _DTYPES[dout]


def _ew_kernel(x1_ref, x2_ref, yin_ref, y_ref, *, fn):
    y_ref[...] = fn(x1_ref[...], x2_ref[...], yin_ref[...]).astype(y_ref.dtype)


def elementwise(name: str, x1: jax.Array, x2: Optional[jax.Array] = None,
                y0: Optional[jax.Array] = None, *, block: int = 2048,
                interpret: bool = True) -> jax.Array:
    """Run one Table-1 kernel.  1-D inputs; blocked over ``block`` lanes."""
    fn, n_in, din, dout = EXPRS[name]
    n = x1.shape[0]
    block = min(block, n)
    assert n % block == 0, (n, block)
    grid = (n // block,)
    if x2 is None:
        x2 = x1
    if y0 is None:
        y0 = jnp.zeros(n, _DTYPES[dout])

    spec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(_ew_kernel, fn=fn),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), _DTYPES[dout]),
        interpret=interpret,
    )(x1, x2, y0)
    return out


def _triad_kernel(a_ref, b_ref, y_ref, *, scalar: float):
    y_ref[...] = a_ref[...] + scalar * b_ref[...]


def stream_triad(a: jax.Array, b: jax.Array, scalar: float = 3.0, *,
                 block: int = 8192, interpret: bool = True) -> jax.Array:
    """y = a + scalar * b (STREAM Triad), blocked HBM->VMEM tiles."""
    n = a.shape[0]
    block = min(block, n)
    assert n % block == 0
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_triad_kernel, scalar=scalar),
        grid=(n // block,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=interpret,
    )(a, b)
