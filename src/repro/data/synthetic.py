"""Deterministic synthetic LM token pipeline.

Properties a real cluster pipeline needs, kept here at example scale:

* **Deterministic & seekable** — batch ``i`` is a pure function of
  ``(seed, i)``, so restart-from-checkpoint resumes the stream exactly
  (fault tolerance requires the data pipeline to be restartable, not just
  the model state).
* **Per-host sharding** — each host materializes only its slice of the
  global batch (``host_id/n_hosts``); the global batch is assembled by the
  runtime via sharding, never allocated on one host.
* **Prefetch** — a small lookahead queue built on a background thread,
  hiding generation latency behind the step (the paper's SW-prefetch lever
  at the pipeline level).

The token stream is a mixture of structured sequences (ramps, repeats,
n-gram-ish state machines) so a ~100M model trained on it shows a real,
monotonically falling loss — useful for the end-to-end example and the
fault-tolerance tests (loss continuity across restarts).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def _rules(self):
        """Per-DATASET generative rules (fixed across steps, so the model can
        learn them; per-sequence randomness is only in starts/phases)."""
        r = np.random.default_rng(np.random.SeedSequence([self.seed, 9999]))
        return {
            "strides": r.integers(1, 7, size=4),          # ramp strides
            "mult": int(r.integers(2, 6)),                # markov multiplier
            "motifs": [r.integers(0, self.vocab_size, size=p)
                       for p in r.integers(3, 9, size=8)],  # shared motifs
        }

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Batch ``step`` for this host — pure function of (seed, step, host)."""
        rules = self._rules()
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        B, S, V = self.host_batch, self.seq_len, self.vocab_size
        toks = np.empty((B, S), np.int32)
        kind = rng.integers(0, 3, size=B)
        for b in range(B):
            if kind[b] == 0:
                # arithmetic ramp; stride from the dataset's fixed set
                start = int(rng.integers(0, V))
                stride = int(rules["strides"][rng.integers(0, 4)])
                toks[b] = (start + stride * np.arange(S)) % V
            elif kind[b] == 1:
                # one of the dataset's shared motifs, at a random phase
                motif = rules["motifs"][rng.integers(0, len(rules["motifs"]))]
                period = len(motif)
                reps = -(-S // period) + 1
                phase = int(rng.integers(0, period))
                toks[b] = np.tile(motif, reps)[phase:phase + S]
            else:
                # affine markov chain with the dataset's FIXED multiplier:
                # achievable loss ~ ln(3) once f(prev) is learned
                x = np.empty(S, np.int64)
                x[0] = rng.integers(0, V)
                noise = rng.integers(0, 3, size=S)
                for t in range(1, S):
                    x[t] = (rules["mult"] * x[t - 1] + noise[t]) % V
                toks[b] = x
        return {"tokens": toks}


def make_batch_iterator(ds: SyntheticLMDataset, start_step: int = 0,
                        prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
    """Prefetching iterator over batches, resumable at ``start_step``."""
    q: "queue.Queue[Optional[Dict[str, np.ndarray]]]" = queue.Queue(prefetch)
    stop = threading.Event()

    def producer() -> None:
        step = start_step
        while not stop.is_set():
            try:
                q.put(ds.batch(step), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
