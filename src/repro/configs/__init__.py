from .base import ModelConfig, MoEConfig, RunConfig, ShapeConfig, SSMConfig
from .registry import ARCHS, get_arch, reduced_config
from .shapes import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ZOO_PHASES,
    ZOO_SHAPES,
    shapes_for,
    skipped_shapes_for,
    zoo_phases_for,
)

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "RunConfig",
    "ShapeConfig",
    "SSMConfig",
    "ARCHS",
    "get_arch",
    "reduced_config",
    "SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "ZOO_PHASES",
    "ZOO_SHAPES",
    "shapes_for",
    "skipped_shapes_for",
    "zoo_phases_for",
]
