"""nemotron-4-340b [dense] — GQA, squared-ReLU [arXiv:2402.16819; unverified].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
Biggest-memory cell in the sweep: defaults to adafactor + full remat so the
train_4k cell fits 16 GiB/chip HBM on the 16x16 mesh (see DESIGN.md §5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73_728,
    vocab_size=256_000,
    mlp_kind="sq_relu",
    norm_kind="layernorm",
    optimizer="adafactor",
    source="arXiv:2402.16819; unverified",
)
