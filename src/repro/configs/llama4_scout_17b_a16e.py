"""llama4-scout-17b-a16e [moe] — MoE 16e top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 (per expert) vocab=202048,
16 experts top-1 + 1 shared expert (llama4-style).  EP over the model axis
(16 experts / 16-way axis = 1 expert per shard).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    moe=MoEConfig(n_experts=16, top_k=1, n_shared_experts=1),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
