"""mamba2-1.3b [ssm] — SSD, attention-free [arXiv:2405.21060; unverified].

48L d_model=2048 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
d_inner = 2*d_model = 4096, head_dim=64 -> 64 SSD heads.  Runs long_500k.
Vocab padded to 50432 so it shards on a 16-way axis (DESIGN.md §8).
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    norm_kind="rmsnorm",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    source="arXiv:2405.21060; unverified",
)
