"""whisper-large-v3 [audio] — enc-dec, conv frontend STUB [arXiv:2212.04356].

32L (decoder; + 32 encoder layers) d_model=1280 20H (kv=20) d_ff=5120
vocab=51866.  The mel-spectrogram conv stem is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, 1500, d_model).
LayerNorm (not RMS), GELU MLP, learned positions (we use rope_fraction=0 and
a learned positional table).  Vocab padded to 51968 for sharding.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    mlp_kind="gelu",
    norm_kind="layernorm",
    rope_fraction=0.0,
    n_encoder_layers=32,
    n_frames=1500,
    source="arXiv:2212.04356; unverified",
)
