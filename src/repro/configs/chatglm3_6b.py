"""chatglm3-6b [dense] — RoPE 2d (partial rotary), GQA [arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
GLM applies rotary to half the head dim (rope_fraction=0.5) and uses QKV bias.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13_696,
    vocab_size=65_024,
    mlp_kind="swiglu",
    norm_kind="rmsnorm",
    qkv_bias=True,
    rope_fraction=0.5,
    source="arXiv:2406.12793; hf",
)
