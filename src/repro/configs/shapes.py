"""The four assigned input-shape sets (identical across LM-family archs)."""
from __future__ import annotations

from .base import ShapeConfig

TRAIN_4K = ShapeConfig(name="train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig(name="prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig(name="decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig(name="long_500k", seq_len=524_288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shapes_for(model) -> list[ShapeConfig]:
    """Applicable shapes for a model (long_500k only for sub-quadratic archs)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if model.supports_long_context:
        out.append(LONG_500K)
    return out


def skipped_shapes_for(model) -> list[tuple[ShapeConfig, str]]:
    out = []
    if not model.supports_long_context:
        out.append(
            (
                LONG_500K,
                "full-attention arch: 500k-token KV cache across all layers "
                "exceeds per-chip HBM; assignment says skip for pure "
                "full-attention archs",
            )
        )
    return out
