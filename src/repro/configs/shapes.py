"""The four assigned input-shape sets (identical across LM-family archs)."""
from __future__ import annotations

from .base import ShapeConfig

TRAIN_4K = ShapeConfig(name="train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig(name="prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig(name="decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig(name="long_500k", seq_len=524_288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# ---------------------------------------------------------------- model zoo
# Representative phases for the model-zoo estimation pipeline
# (core.zoo, DESIGN.md §15): one train step, one prefill, one decode step
# at shapes small enough that every registry architecture compiles on the
# single host device in seconds.  The zoo pairs these with
# ``reduced_config`` (structure-preserving toy width) — the full-size
# sharded cells stay the dry-run's job; the zoo's job is the paper's
# *relative* evaluation of one-node applications across architectures.
ZOO_TRAIN = ShapeConfig(name="zoo_train", seq_len=128, global_batch=2, kind="train")
ZOO_PREFILL = ShapeConfig(name="zoo_prefill", seq_len=256, global_batch=2, kind="prefill")
ZOO_DECODE = ShapeConfig(name="zoo_decode", seq_len=256, global_batch=2, kind="decode")

ZOO_SHAPES = {s.kind: s for s in (ZOO_TRAIN, ZOO_PREFILL, ZOO_DECODE)}
ZOO_PHASES = tuple(ZOO_SHAPES)           # ("train", "prefill", "decode")


def zoo_phases_for(model) -> tuple[str, ...]:
    """Representative phases the zoo traces for ``model`` (every registry
    family supports all three; the hook exists so a future frontend-only
    or encoder-only config can opt out of a phase)."""
    return ZOO_PHASES


def shapes_for(model) -> list[ShapeConfig]:
    """Applicable shapes for a model (long_500k only for sub-quadratic archs)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if model.supports_long_context:
        out.append(LONG_500K)
    return out


def skipped_shapes_for(model) -> list[tuple[ShapeConfig, str]]:
    out = []
    if not model.supports_long_context:
        out.append(
            (
                LONG_500K,
                "full-attention arch: 500k-token KV cache across all layers "
                "exceeds per-chip HBM; assignment says skip for pure "
                "full-attention archs",
            )
        )
    return out
