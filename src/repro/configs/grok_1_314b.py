"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 (per expert) vocab=131072,
MoE 8e top-2.  8 experts do not divide the 16-way model axis, so grok uses
expert-TENSOR parallelism (each expert's FFN sharded 16-way over 'model')
instead of expert parallelism — see parallel/sharding.py.
Defaults to adafactor (314B params; AdamW fp32 moments + fp32 grads would
not leave activation headroom at 16 GiB/chip).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32_768,
    vocab_size=131_072,
    mlp_kind="geglu",
    norm_kind="rmsnorm",
    moe=MoEConfig(n_experts=8, top_k=2),
    optimizer="adafactor",
    source="hf:xai-org/grok-1; unverified",
)
