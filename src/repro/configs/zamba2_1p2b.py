"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention [arXiv:2411.15242; hf].

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
38 Mamba2 layers; ONE weight-shared attention+MLP block applied every 6
layers (simplified from the paper's two alternating shared blocks with
per-invocation LoRA — see DESIGN.md §8).  Runs long_500k.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32_000,
    mlp_kind="gelu",
    norm_kind="rmsnorm",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    shared_attn_every=6,
    source="arXiv:2411.15242; hf",
)
