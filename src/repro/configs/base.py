"""Configuration dataclasses for models, shapes and runs.

Every assigned architecture is expressed as a ``ModelConfig``; every assigned
input shape as a ``ShapeConfig``.  Configs are plain frozen dataclasses so they
can be hashed, diffed and serialized without pulling in jax.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    n_shared_experts: int = 0          # shared (always-on) experts, llama4-style
    capacity_factor: float = 1.25      # train-time per-expert capacity factor
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128                 # N (SSD state size per head)
    d_conv: int = 4                    # depthwise conv kernel width
    expand: int = 2                    # d_inner = expand * d_model
    head_dim: int = 64                 # P (SSD head dim)
    chunk: int = 256                   # SSD chunk length
    n_groups: int = 1                  # B/C groups (1 = shared across heads)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Field names follow the assignment sheet."""

    name: str
    family: str                        # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                       # query heads (0 for attn-free)
    n_kv_heads: int                    # KV heads (GQA); == n_heads for MHA
    d_ff: int                          # FFN hidden (per-expert for MoE); 0 for attn-free
    vocab_size: int
    d_head: int = 0                    # 0 -> d_model // n_heads
    mlp_kind: str = "swiglu"           # swiglu | geglu | sq_relu | gelu
    norm_kind: str = "rmsnorm"         # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_fraction: float = 1.0         # fraction of head_dim carrying rotary (chatglm: 0.5)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one weight-shared attention+MLP block applied every k layers
    shared_attn_every: int = 0
    # encoder-decoder (whisper): n_layers is the decoder depth
    n_encoder_layers: int = 0
    n_frames: int = 0                  # stub frontend: precomputed frame embeddings
    # vlm (paligemma): stub frontend: precomputed patch embeddings
    n_img_tokens: int = 0
    # training-policy knobs (per-arch defaults; overridable per run)
    optimizer: str = "adamw"           # adamw | adafactor
    remat: str = "full"                # full | none
    # provenance
    source: str = ""

    # ------------------------------------------------------------------ derived
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards on any mesh axis."""
        return _round_up(self.vocab_size, 256)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.ssm is not None

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic + O(1)-ish state: SSM and hybrid run long_500k."""
        return self.family in ("ssm", "hybrid")

    @property
    def n_dense_layers(self) -> int:
        return self.n_layers

    # ------------------------------------------------------------- param count
    def param_count(self) -> int:
        """Exact parameter count of the model as built (padded vocab)."""
        d, V = self.d_model, self.padded_vocab
        norm_size = 2 * d if self.norm_kind == "layernorm" else d
        total = V * d                                    # embed
        if not self.tie_embeddings:
            total += V * d                               # lm head
        total += norm_size                               # final norm

        def attn_params() -> int:
            hd = self.head_dim
            p = d * self.n_heads * hd                    # q
            p += 2 * d * self.n_kv_heads * hd            # k, v
            p += self.n_heads * hd * d                   # o
            if self.qkv_bias:
                p += (self.n_heads + 2 * self.n_kv_heads) * hd
            return p

        def mlp_params(d_ff: int) -> int:
            if self.mlp_kind in ("swiglu", "geglu"):
                return 3 * d * d_ff
            return 2 * d * d_ff

        def block_norms() -> int:
            return 2 * norm_size

        if self.family == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            conv_dim = di + 2 * s.n_groups * s.d_state
            per_layer = d * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj
            per_layer += conv_dim * s.d_conv + conv_dim                 # conv + bias
            per_layer += nh * 3                                         # dt_bias, A_log, D (per head)
            per_layer += di                                             # out gate norm
            per_layer += di * d                                         # out_proj
            per_layer += d                                              # pre-norm
            return total + self.n_layers * per_layer

        if self.family == "hybrid":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            conv_dim = di + 2 * s.n_groups * s.d_state
            per_layer = d * (2 * di + 2 * s.n_groups * s.d_state + nh)
            per_layer += conv_dim * s.d_conv + conv_dim
            per_layer += nh * 3 + di + di * d + d
            total += self.n_layers * per_layer
            # one shared attn+MLP block
            total += attn_params() + mlp_params(self.d_ff) + block_norms()
            return total

        per_layer = attn_params() + block_norms()
        if self.moe is not None:
            m = self.moe
            per_layer += d * m.n_experts                                  # router
            per_layer += m.n_experts * mlp_params(self.d_ff)
            per_layer += m.n_shared_experts * mlp_params(self.d_ff)
        else:
            per_layer += mlp_params(self.d_ff)

        total += self.n_layers * per_layer
        if self.n_encoder_layers:
            # encoder self-attn + mlp, and decoder cross-attn
            enc_layer = attn_params() + mlp_params(self.d_ff) + block_norms()
            total += self.n_encoder_layers * enc_layer + norm_size      # enc final norm
            total += self.n_layers * (attn_params() + norm_size)        # cross attn + its norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top_k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d = self.d_model

        def mlp_params(d_ff: int) -> int:
            if self.mlp_kind in ("swiglu", "geglu"):
                return 3 * d * d_ff
            return 2 * d * d_ff

        inactive_per_layer = (m.n_experts - m.top_k) * mlp_params(self.d_ff)
        return self.param_count() - self.n_layers * inactive_per_layer


@dataclass(frozen=True)
class ShapeConfig:
    """Assigned input shape. ``kind`` picks which step gets lowered."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode
    # decode: one new token against a KV cache of ``seq_len``

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


@dataclass(frozen=True)
class RunConfig:
    """Execution policy for one (arch x shape x mesh) cell."""

    model: ModelConfig
    shape: ShapeConfig
    microbatch: int = 0                # 0 -> no grad accumulation (single shot)
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"
    remat: str = ""                    # '' -> model default
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    grad_compression: str = "none"     # none | int8_ef
    seed: int = 0

    def resolved_remat(self) -> str:
        return self.remat or self.model.remat

    def microbatches(self) -> int:
        if self.microbatch <= 0:
            return 1
        assert self.shape.global_batch % self.microbatch == 0
        return self.shape.global_batch // self.microbatch
