"""paligemma-3b [vlm] — SigLIP + gemma decoder [arXiv:2407.07726; hf].

18L d_model=2048 8H (GQA kv=1 = MQA) d_ff=16384 vocab=257216.
The SigLIP tower is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (B, 256, d_model).  Gemma-style: GeGLU MLP,
head_dim=256, tied embeddings, RMSNorm.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab_size=257_216,
    mlp_kind="geglu",
    norm_kind="rmsnorm",
    tie_embeddings=True,
    n_img_tokens=256,
    source="arXiv:2407.07726; hf",
)
