"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses

from .base import ModelConfig, MoEConfig, SSMConfig
from . import (
    paligemma_3b,
    zamba2_1p2b,
    nemotron_4_340b,
    qwen1p5_32b,
    qwen1p5_110b,
    chatglm3_6b,
    mamba2_1p3b,
    llama4_scout_17b_a16e,
    grok_1_314b,
    whisper_large_v3,
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        paligemma_3b.CONFIG,
        zamba2_1p2b.CONFIG,
        nemotron_4_340b.CONFIG,
        qwen1p5_32b.CONFIG,
        qwen1p5_110b.CONFIG,
        chatglm3_6b.CONFIG,
        mamba2_1p3b.CONFIG,
        llama4_scout_17b_a16e.CONFIG,
        grok_1_314b.CONFIG,
        whisper_large_v3.CONFIG,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (shapes + no-NaN asserts).

    Preserves every structural feature (GQA ratio, MoE routing, SSD, hybrid
    sharing, enc-dec, stub frontends, partial rotary, biases) at toy width.
    """
    kv_ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1)) if cfg.n_heads else 1
    n_heads = 4 if cfg.n_heads else 0
    n_kv = max(1, n_heads // kv_ratio) if cfg.n_heads else 0
    updates = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family in ("ssm", "hybrid") else 2),
        d_model=128,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=32 if cfg.n_heads else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        optimizer="adamw",
    )
    if cfg.ssm is not None:
        updates["ssm"] = SSMConfig(
            d_state=min(cfg.ssm.d_state, 16),
            d_conv=cfg.ssm.d_conv,
            expand=cfg.ssm.expand,
            head_dim=16,
            chunk=16,
            n_groups=cfg.ssm.n_groups,
        )
    if cfg.moe is not None:
        updates["moe"] = MoEConfig(
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            n_shared_experts=cfg.moe.n_shared_experts,
            capacity_factor=cfg.moe.capacity_factor,
        )
    if cfg.shared_attn_every:
        updates["shared_attn_every"] = 2
    if cfg.n_encoder_layers:
        updates["n_encoder_layers"] = 2
    if cfg.n_frames:
        updates["n_frames"] = 8
    if cfg.n_img_tokens:
        updates["n_img_tokens"] = 4
    return dataclasses.replace(cfg, **updates)
