"""The paper's own evaluation workload (Table 1 + Figs 4/5).

28 single-core kernels (basic arithmetic / type conversion / numeric /
mathematical) with array sizes chosen as 3/4 of L1 as in the paper, plus
Stream Triad at L2-resident and 2x-L2 sizes.  Consumed by
``benchmarks/kernel_suite.py`` and ``repro.core.calibrate``.

Each entry: (name, type, n, expression-id).  ``n`` follows Table 1.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Kernel:
    name: str
    ktype: str           # arith | conv | numeric | math
    n: int               # innermost array length (Table 1 'Size')
    expr: str            # expression id understood by kernels/stream.py


# Table 1, verbatim.
KERNELS = [
    Kernel("add",   "arith",   2048, "y = x1 + x2"),
    Kernel("sub",   "arith",   2048, "y = x1 - x2"),
    Kernel("mul",   "arith",   2048, "y = x1 * x2"),
    Kernel("fma",   "arith",   3072, "y = y + c0 * x1"),
    Kernel("div",   "arith",   2048, "y = x1 / x2"),
    Kernel("rev",   "arith",   3072, "y = 1 / x1"),
    Kernel("sqrt",  "arith",   3072, "y = sqrt(x1)"),
    Kernel("f2d",   "conv",    4096, "y_r8 = dble(x1_r4)"),
    Kernel("i2d",   "conv",    4096, "y_r8 = dble(x1_i4)"),
    Kernel("d2f",   "conv",    4096, "y_r4 = real(x1_r8)"),
    Kernel("d2i",   "conv",    4096, "y_i4 = int(x1_r8)"),
    Kernel("aint",  "conv",    3072, "y_r8 = aint(x1_r8)"),
    Kernel("nint",  "conv",    4096, "y_i4 = nint(x1_r8)"),
    Kernel("anint", "conv",    3072, "y_r8 = anint(x1_r8)"),
    Kernel("abs",   "numeric", 3072, "y = abs(x1)"),
    Kernel("max",   "numeric", 2048, "y = max(x1, x2)"),
    Kernel("min",   "numeric", 2048, "y = min(x1, x2)"),
    Kernel("mod",   "numeric", 2048, "y = mod(x1, x2)"),
    Kernel("sign",  "numeric", 2048, "y = sign(x1, x2)"),
    Kernel("atan",  "math",    3072, "y = atan(x1)"),
    Kernel("atan2", "math",    2048, "y = atan2(x1, x2)"),
    Kernel("cos",   "math",    3072, "y = cos(x1)"),
    Kernel("sin",   "math",    3072, "y = sin(x1)"),
    Kernel("exp",   "math",    3072, "y = exp(x1)"),
    Kernel("exp10", "math",    3072, "y = exp10(x1)"),
    Kernel("log",   "math",    3072, "y = log(x1)"),
    Kernel("log10", "math",    3072, "y = log10(x1)"),
    Kernel("pwr",   "math",    2048, "y = x1 ** x2"),
]

KERNELS_BY_NAME = {k.name: k for k in KERNELS}

# Stream Triad sizes (paper §5.2): L2-resident and 2x L2.  The paper's L2 is
# 8 MiB/CMG; we keep the same footprint ratios and scale per-"core" with
# thread count in the benchmark (1..12 threads as in Figs 4/5).
TRIAD_L2_BYTES = 6 * 2**20        # 3 arrays fit in 8 MiB L2 with headroom
TRIAD_MEM_BYTES = 16 * 2**20      # 2x the L2 capacity
TRIAD_THREADS = list(range(1, 13))

# Paper's measured accuracy (Fig. 3 summary) — targets the calibration
# benchmark reproduces: mean diff 1.3%, stddev 7.8%, mean |diff| 6.6%,
# >=80% of kernels within +-10%.
PAPER_MEAN_DIFF_PCT = 1.3
PAPER_STD_DIFF_PCT = 7.8
PAPER_MEAN_ABS_DIFF_PCT = 6.6
PAPER_WITHIN_10PCT_FRACTION = 23 / 28
