"""repro: a multi-pod JAX training/serving framework built around a
Post-K-style target-hardware performance simulator (RIKEN simulator, CS.DC
2019, adapted gem5/A64FX -> XLA/TPU)."""

__version__ = "0.1.0"
