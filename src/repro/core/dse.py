"""Hardware design-space exploration over the model zoo (DESIGN.md §19).

The paper's whole point is *relative* evaluation of an unbuilt chip —
gem5 tuned until rankings, not absolute cycles, are trustworthy.  This
module is that what-if service at HLO altitude: a parameterized
generator of A64FX-like candidate architectures (CMG count, cores per
CMG, HBM stacks, inter-CMG ring latency, VPU width), materialized into
``HardwareSpec``/``NodeTopology`` pairs, swept over zoo workloads as ONE
fused spec batch (``compile_node_grid`` + ``schedule_spec_sweep``) —
hundreds of candidates per program without re-running the interpreter
pipeline per spec.

``run_dse`` emits the ``BENCH_dse.json`` payload (schema in DESIGN.md
§16): per-workload per-candidate estimates, Pareto fronts over
(cycles, HBM bytes, cores), and a Kendall-tau ranking-stability matrix
across workloads — if the candidate ranking holds across the zoo, the
design decision does not depend on which model you benchmarked, the
property the RIKEN evaluation leaned on.
"""
from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import dataclasses

import numpy as np

from .hwspec import A64FX_CORE, HardwareSpec, NodeTopology, SpecGrid
from .memory import MemLevel
from .node import compile_node_grid, schedule_spec_sweep
from .zoo import DEFAULT_CLOCK_HZ, kendall_tau

DSE_SCHEMA = 1

# the A64FX baseline the axes scale from
_BASE_VPU_LANES = 2            # 2x512-bit FMA pipes per core
_HBM_STACK_BW = 256e9          # one HBM2 stack's aggregate per CMG
_HBM_STACK_BYTES = 8 * 2**30
_L2_READ_AGG = 900e9           # per-CMG L2 aggregates (paper values)
_L2_WRITE_AGG = 450e9


@dataclass(frozen=True)
class SpecPoint:
    """One candidate architecture: the DSE generator's coordinate tuple
    (everything else is inherited from the base spec)."""
    n_cmgs: int                  # CMGs per node
    cores_per_cmg: int
    hbm_stacks: int              # HBM2 stacks per CMG (aggregate scales)
    ring_latency_ns: float       # inter-CMG coherence hop (0 = free)
    vpu_lanes: int               # 512-bit FMA pipes per core (base: 2)
    l2_mib: float = 8.0          # per-CMG L2 capacity

    @property
    def n_cores(self) -> int:
        return self.n_cmgs * self.cores_per_cmg

    @property
    def name(self) -> str:
        return (f"c{self.n_cmgs}x{self.cores_per_cmg}"
                f"_hbm{self.hbm_stacks}_r{self.ring_latency_ns:g}"
                f"_v{self.vpu_lanes}")


def materialize(point: SpecPoint,
                base: HardwareSpec = A64FX_CORE) -> HardwareSpec:
    """Turn a :class:`SpecPoint` into a per-core spec + node topology.

    Per-core compute scales with ``vpu_lanes``; the L2/HBM *aggregates*
    scale with the topology axes while the per-core draw limits stay the
    base chip's (one core cannot saturate a stack — extra stacks pay off
    through the contention model at scale, exactly the effect the node
    engine exists to capture).  Level ``shared_by`` follows
    ``cores_per_cmg`` so the sharing domains match the candidate's CMG
    shape."""
    vs = point.vpu_lanes / _BASE_VPU_LANES
    l1 = base.memory_hierarchy()[0]
    levels = (
        l1,
        MemLevel("l2", point.l2_mib * 2**20 / point.cores_per_cmg,
                 200e9, 100e9, 20e-9, shared_by=point.cores_per_cmg),
        MemLevel("hbm2", float(point.hbm_stacks * _HBM_STACK_BYTES),
                 base.hbm_read_bw, base.hbm_write_bw, 120e-9,
                 shared_by=point.cores_per_cmg),
    )
    topo = NodeTopology(
        name=point.name, n_cmgs=point.n_cmgs,
        cores_per_cmg=point.cores_per_cmg,
        shared_read_bw={"l2": _L2_READ_AGG,
                        "hbm2": point.hbm_stacks * _HBM_STACK_BW},
        shared_write_bw={"l2": _L2_WRITE_AGG,
                         "hbm2": point.hbm_stacks * _HBM_STACK_BW},
        ring_latency_s=point.ring_latency_ns * 1e-9,
        ring_bw=115e9)
    return base.with_(
        name=point.name,
        peak_flops={k: v * vs for k, v in base.peak_flops.items()},
        vpu_flops={k: v * vs for k, v in base.vpu_flops.items()},
        mem_levels=levels,
        hbm_bytes=int(point.hbm_stacks * _HBM_STACK_BYTES),
        topology=topo)


def generate_grid(n_cmgs: Sequence[int] = (1, 2, 4, 6),
                  cores_per_cmg: Sequence[int] = (8, 12),
                  hbm_stacks: Sequence[int] = (1, 2),
                  ring_latency_ns: Sequence[float] = (0.0, 130.0),
                  vpu_lanes: Sequence[int] = (2, 4)) -> List[SpecPoint]:
    """The default DSE grid: the cross product of the five axes
    (4*2*2*2*2 = 64 candidates), A64FX at ``(4, 12, 1, 130, 2)``."""
    return [SpecPoint(c, k, h, r, v)
            for c in n_cmgs for k in cores_per_cmg for h in hbm_stacks
            for r in ring_latency_ns for v in vpu_lanes]


def spec_grid(points: Sequence[SpecPoint],
              base: HardwareSpec = A64FX_CORE) -> SpecGrid:
    """Materialize a point list into the fused sweep's ``SpecGrid``."""
    return SpecGrid([materialize(p, base) for p in points])


def pareto_front(costs: np.ndarray) -> List[int]:
    """Indices of the non-dominated rows of ``costs [N, D]`` (all axes
    minimized), in input order.  A row is dominated when some other row
    is <= everywhere and < somewhere."""
    n = len(costs)
    keep: List[int] = []
    for i in range(n):
        dominated = False
        for j in range(n):
            if j == i:
                continue
            if (costs[j] <= costs[i]).all() and (costs[j] < costs[i]).any():
                dominated = True
                break
        if not dominated:
            keep.append(i)
    return keep


def sweep_workload(prog, grid: SpecGrid,
                   compute_dtype: str = "f32") -> Dict[str, np.ndarray]:
    """Fused spec sweep of one program: each candidate at its full core
    count.  Returns per-spec ``t_est [S]``, outermost-level (HBM) bytes
    moved ``hbm_bytes [S]`` and core counts ``n_cores [S]`` — the three
    Pareto axes."""
    ngc = compile_node_grid(prog, grid, compute_dtype=compute_dtype)
    t = schedule_spec_sweep(ngc)[:, 0, 0]                       # [S]
    bc = ngc.bc
    hbm = ((bc.rd[:, -1, :] + bc.wr[:, -1, :])
           * bc.count[:, None]).sum(axis=0)                     # [S]
    cores = np.array([grid.topology_of(s).n_cores
                      for s in range(grid.S)], dtype=float)
    return {"t_est": t, "hbm_bytes": hbm, "n_cores": cores}


def run_dse(workloads: Sequence[Tuple[str, str]],
            points: Optional[Sequence[SpecPoint]] = None,
            base: HardwareSpec = A64FX_CORE,
            compute_dtype: str = "f32",
            param_dtype: str = "float32",
            clock_hz: float = DEFAULT_CLOCK_HZ,
            hlo_cache_dir: Optional[Path] = None,
            progress=None) -> dict:
    """Drive the candidate grid through zoo workloads; return the
    ``BENCH_dse.json`` payload (schema ``dse`` in DESIGN.md §16).

    ``workloads`` are ``(arch, phase)`` zoo cells (traced via
    ``trace_phase``, disk-cached HLO under ``hlo_cache_dir``).  Per
    workload: per-candidate estimates and the Pareto front over
    (cycles, HBM bytes, cores); across workloads: the Kendall-tau
    matrix of candidate rankings.  The ``throughput`` block is filled
    by ``benchmarks/dse_sweep.py``, which times this fused path against
    the per-spec loop."""
    from .zoo import trace_phase
    points = list(points) if points is not None else generate_grid()
    grid = spec_grid(points, base)
    S = grid.S
    out: dict = {
        "schema": DSE_SCHEMA,
        "base_spec": base.name,
        "compute_dtype": compute_dtype,
        "clock_hz": clock_hz,
        "n_specs": S,
        "spec_points": [{**dataclasses.asdict(p),
                         "name": p.name, "n_cores": p.n_cores}
                        for p in points],
        "workloads": [f"{a}/{ph}" for a, ph in workloads],
        "per_workload": {},
    }
    t_cols: List[np.ndarray] = []
    for arch, phase in workloads:
        key = f"{arch}/{phase}"
        if progress:
            progress(f"dse {key}")
        prog = trace_phase(arch, phase, param_dtype=param_dtype,
                           hlo_cache_dir=hlo_cache_dir)
        sw = sweep_workload(prog, grid, compute_dtype)
        t = sw["t_est"]
        t_cols.append(t)
        cyc = t * clock_hz
        axes = np.stack([cyc, sw["hbm_bytes"], sw["n_cores"]], axis=1)
        front = pareto_front(axes)
        best = int(np.argmin(t))
        out["per_workload"][key] = {
            "n_ops": len(prog.ops),
            "t_est_s": t.tolist(),
            "cycles": cyc.tolist(),
            "hbm_bytes": sw["hbm_bytes"].tolist(),
            "n_cores": sw["n_cores"].tolist(),
            "best_spec": points[best].name,
            "pareto": front,
            "pareto_specs": [points[i].name for i in front],
        }
    W = len(t_cols)
    taus = np.ones((W, W))
    for i in range(W):
        for j in range(i + 1, W):
            taus[i, j] = taus[j, i] = kendall_tau(
                list(t_cols[i]), list(t_cols[j]))
    off = [taus[i, j] for i in range(W) for j in range(W) if i != j]
    out["rank_stability"] = {
        "tau_matrix": [[float(v) for v in row] for row in taus],
        "mean_tau": float(np.mean(off)) if off else 1.0,
        "min_tau": float(np.min(off)) if off else 1.0,
    }
    return out
