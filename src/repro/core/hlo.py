"""Post-SPMD HLO text parser -> per-op cost records.

The simulator consumes ``compiled.as_text()`` — the *partitioned* module, so
every shape is per-device and every inter-device transfer is an explicit
collective op.  This is the gem5-"binary" of our world.

Why parse ourselves instead of trusting ``cost_analysis()``:
* XLA's HloCostAnalysis visits each computation ONCE — a ``lax.scan`` over 96
  layers is a ``while`` whose body is counted a single time.  We extract while
  trip counts (from the loop-condition's integer constants) and multiply.
* cost_analysis has no per-op / per-class breakdown and no collective bytes.
* Fusions are costed at their *boundary* bytes (operands + outputs), modeling
  VMEM-resident intermediates — the cache-hierarchy insight of the paper.

Everything here is pure-python string processing; no jax dependency.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = {
    "all-reduce": "all-reduce", "all-reduce-start": "all-reduce",
    "all-gather": "all-gather", "all-gather-start": "all-gather",
    "reduce-scatter": "reduce-scatter",
    "all-to-all": "all-to-all", "ragged-all-to-all": "all-to-all",
    "collective-permute": "collective-permute",
    "collective-permute-start": "collective-permute",
    "collective-broadcast": "all-gather",
}

TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "sine", "cosine", "tan", "atan2", "power", "sqrt", "rsqrt", "cbrt",
    "logistic", "erf", "erf-inv", "divide", "remainder",
}

ELEMENTWISE = {
    "add", "subtract", "multiply", "maximum", "minimum", "and", "or", "xor",
    "not", "negate", "abs", "compare", "select", "clamp", "convert", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "shift-left", "shift-right-arithmetic", "shift-right-logical", "iota",
    "broadcast", "map", "is-finite", "popcnt", "clz", "stochastic-convert",
    "real", "imag", "complex",
}

REDUCE = {"reduce", "reduce-window", "select-and-scatter"}

DATA_MOVEMENT = {
    "gather", "scatter", "dynamic-slice", "dynamic-update-slice", "sort",
    "transpose", "reshape", "copy", "concatenate", "pad", "slice", "reverse",
    "rng", "rng-bit-generator", "rng-get-and-update-state", "copy-start",
    "cholesky", "triangular-solve", "fft", "custom-call",
}

FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "domain", "opt-barrier",
    "copy-done", "all-reduce-done", "all-gather-done",
    "collective-permute-done", "async-done", "async-update", "bitcast-convert",
    "get-dimension-size", "add-dependency", "send", "send-done", "recv",
    "recv-done",
}


@dataclass
class Instr:
    """One parsed HLO instruction (pre-aggregation; see OpStat)."""
    name: str
    dtype: str
    shape: Tuple[int, ...]
    out_bytes: float
    opcode: str
    operands: List[str]
    attrs: str
    is_tuple: bool = False
    tuple_bytes: float = 0.0


@dataclass
class Computation:
    """One HLO computation: params + instructions, fusion bodies included."""
    name: str
    params: Dict[str, Tuple[str, Tuple[int, ...]]]
    instrs: Dict[str, Instr]
    order: List[str]
    is_entry: bool = False


@dataclass
class OpStat:
    """One costed HLO op (already multiplied by enclosing loop trips)."""
    name: str
    opcode: str
    opclass: str                 # matmul | elementwise | transcendental |
                                 # reduce | data | collective | free
    dtype: str
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes_accessed: float = 0.0  # boundary bytes: read_bytes + write_bytes
    read_bytes: float = 0.0      # boundary bytes loaded (operand streams)
    write_bytes: float = 0.0     # boundary bytes stored (outputs); the
                                 # memory model routes reads and writes
                                 # separately (asymmetric load/store paths)
    comm_bytes: float = 0.0      # collective payload bytes (per device)
    group_size: int = 1
    count: float = 1.0
    dot_dims: Optional[Tuple[int, int, int]] = None   # (M, N, K) for padding waste
    # transcendental element counts by HLO opcode (survives fusion), so the
    # engine can apply the paper-style per-opcode latency table
    trans_by_opcode: Dict[str, float] = field(default_factory=dict)
    # plain-elementwise element counts by HLO opcode (survives fusion):
    # lets `opcode_factor` distinguish e.g. minimum/round/convert from a
    # 1-flop add — the per-OpClass VPU latency table for non-
    # transcendental opcodes (DESIGN.md §14 satellite)
    vpu_by_opcode: Dict[str, float] = field(default_factory=dict)
    # def-use edges: indices into Program.ops of the producers this op
    # consumes (resolved through free/pass-through ops and computation
    # boundaries).  The schedule engine turns these into issue constraints;
    # the occupancy engine ignores them.
    deps: List[int] = field(default_factory=list)
    # bytes consumed along each dep edge (aligned with ``deps``): operand
    # sizes, split evenly when one operand resolves to several producers.
    # core.memory turns these into reuse-distance-routed reads.
    dep_bytes: List[float] = field(default_factory=list)


@dataclass
class Program:
    """The parsed program: entry-computation op stats, fusion-inlined.

    This is every engine's input artifact; compiled/node/costed forms
    are memoized on it (DESIGN.md §2-§3).
    """
    ops: List[OpStat]
    entry: str
    n_partitions: int

    # ---- aggregates
    def total(self, attr: str) -> float:
        return sum(getattr(o, attr) * o.count for o in self.ops)

    @property
    def flops(self) -> float:
        return self.total("flops")

    @property
    def bytes_accessed(self) -> float:
        return self.total("bytes_accessed")

    @property
    def comm_bytes(self) -> float:
        return self.total("comm_bytes")

    def bytes_normalized(self, compute_dtype: str) -> float:
        """Bytes with XLA:CPU float-normalization inverted: f32 ops count at
        16-bit width when the model computes in bf16/f16 (see engine)."""
        if compute_dtype not in ("bf16", "f16"):
            return self.bytes_accessed
        return sum((0.5 if o.dtype == "f32" else 1.0)
                   * o.bytes_accessed * o.count for o in self.ops)

    def comm_normalized(self, compute_dtype: str) -> float:
        if compute_dtype not in ("bf16", "f16"):
            return self.comm_bytes
        return sum((0.5 if o.dtype == "f32" else 1.0)
                   * o.comm_bytes * o.count for o in self.ops)

    def by_class(self) -> Dict[str, Dict[str, float]]:
        agg: Dict[str, Dict[str, float]] = defaultdict(
            lambda: {"flops": 0.0, "bytes": 0.0, "comm": 0.0, "n": 0.0,
                     "transcendentals": 0.0})
        for o in self.ops:
            a = agg[o.opclass]
            a["flops"] += o.flops * o.count
            a["bytes"] += o.bytes_accessed * o.count
            a["comm"] += o.comm_bytes * o.count
            a["transcendentals"] += o.transcendentals * o.count
            a["n"] += o.count
        return dict(agg)

    def comm_by_collective(self) -> Dict[str, float]:
        agg: Dict[str, float] = defaultdict(float)
        for o in self.ops:
            if o.opclass == "collective":
                agg[o.opcode] += o.comm_bytes * o.count
        return dict(agg)

    def matmul_utilization(self, tile=(128, 128, 128)) -> float:
        """Useful-lane accounting (paper's predicate-aware SIMD counting):
        fraction of MXU-tile-padded matmul FLOPs that are useful."""
        useful, padded = 0.0, 0.0
        for o in self.ops:
            if o.opclass != "matmul" or not o.dot_dims:
                continue
            m, n, k = o.dot_dims
            batch = (o.flops / max(2 * m * n * k, 1))
            pm = math.ceil(m / tile[0]) * tile[0]
            pk = math.ceil(k / tile[1]) * tile[1]
            pn = math.ceil(n / tile[2]) * tile[2]
            useful += o.flops * o.count
            padded += 2.0 * pm * pk * pn * batch * o.count
        return useful / padded if padded else 1.0


# ------------------------------------------------------------------ parsing
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONST_INT_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")
_NPART_RE = re.compile(r"num_partitions=(\d+)")


def _parse_type(s: str) -> Tuple[str, Tuple[int, ...], float, bool, float]:
    """Returns (dtype, shape, bytes, is_tuple, tuple_bytes)."""
    s = s.strip()
    if s.startswith("("):
        total = 0.0
        first = None
        for m in _TYPE_RE.finditer(s):
            dt, dims = m.group(1), m.group(2)
            if dt not in DTYPE_BYTES:
                continue
            shape = tuple(int(x) for x in dims.split(",") if x)
            b = DTYPE_BYTES[dt] * max(1, math.prod(shape)) if dt != "token" else 0
            total += b
            if first is None:
                first = (dt, shape, b)
        if first is None:
            return "f32", (), 0.0, True, 0.0
        return first[0], first[1], first[2], True, total
    m = _TYPE_RE.match(s)
    if not m:
        return "f32", (), 0.0, False, 0.0
    dt, dims = m.group(1), m.group(2)
    shape = tuple(int(x) for x in dims.split(",") if x)
    nbytes = DTYPE_BYTES.get(dt, 4) * max(1, math.prod(shape))
    if dt == "token":
        nbytes = 0
    return dt, shape, nbytes, False, nbytes


def _split_top_level(s: str) -> List[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return [x for x in out if x]


def _parse_rhs(rhs: str):
    """rhs like: 'f32[8,256]{1,0} dot(%a, %b), lhs_contracting_dims={1}, ...'
    Returns (type_str, opcode, operand_names, attrs)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str = rhs[: i + 1]
        rest = rhs[i + 1:].strip()
    else:
        sp = rhs.index(" ")
        type_str = rhs[:sp]
        rest = rhs[sp + 1:].strip()
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return type_str, rest.split("(")[0], [], ""
    opcode = m.group(1)
    depth = 0
    start = m.end() - 1
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            break
    args = rest[start + 1: i]
    attrs = rest[i + 1:]
    operands = []
    for a in _split_top_level(args):
        # strip /*index=N*/ positional comments (emitted for >5 operands) —
        # losing an operand here shifts every later parameter index.
        a = re.sub(r"/\*.*?\*/", "", a).strip()
        # compiled modules annotate operands with their full (layout-bearing)
        # type: ``copy(f32[32,32]{1,0:T(8,128)} %Arg_0.1)``.  The name is the
        # %-sigiled token; fall back to the last whitespace token for sigil-
        # free dumps (and bare constant literals like ``constant(0)``).
        toks = re.findall(r"%([\w.\-]+)", a)
        if toks:
            operands.append(toks[-1])
            continue
        parts = a.split()
        am = re.match(r"%?([\w.\-]+)", parts[-1] if parts else a)
        if am:
            operands.append(am.group(1))
    return type_str, opcode, operands, attrs


def parse_computations(text: str) -> Tuple[Dict[str, Computation], str, int]:
    comps: Dict[str, Computation] = {}
    entry_name = ""
    npart = 1
    m = _NPART_RE.search(text)
    if m:
        npart = int(m.group(1))

    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            hm = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.*?)\s*\{",
                          stripped)
            if hm and ("=" not in stripped.split("(")[0]):
                is_entry = bool(hm.group(1))
                name = hm.group(2)
                params: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
                for pdef in _split_top_level(hm.group(3)):
                    pm = re.match(r"([\w.\-]+)\s*:\s*(.*)", pdef)
                    if pm:
                        dt, shape, b, _, _ = _parse_type(pm.group(2))
                        params[pm.group(1)] = (dt, shape)
                cur = Computation(name, params, {}, [], is_entry)
                if is_entry:
                    entry_name = name
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR_RE.match(stripped)
        if not im or "=" not in stripped:
            continue
        name, rhs = im.group(1), im.group(2)
        try:
            type_str, opcode, operands, attrs = _parse_rhs(rhs)
        except (ValueError, IndexError):
            continue
        dt, shape, nbytes, is_tuple, tbytes = _parse_type(type_str)
        cur.instrs[name] = Instr(name, dt, shape, nbytes, opcode, operands,
                                 attrs, is_tuple, tbytes)
        cur.order.append(name)
    return comps, entry_name, npart


# ------------------------------------------------------------------ costing
def _single_operand_bytes(name: str, comp: Computation) -> float:
    if name in comp.instrs:
        o = comp.instrs[name]
        return o.tuple_bytes if o.is_tuple else o.out_bytes
    if name in comp.params:
        dt, shape = comp.params[name]
        return DTYPE_BYTES.get(dt, 4) * max(1, math.prod(shape))
    return 0.0


def _operand_bytes(instr: Instr, comp: Computation) -> float:
    return sum(_single_operand_bytes(op, comp) for op in instr.operands)


_PASSTHROUGH = {"convert", "bitcast", "copy", "reshape", "bitcast-convert"}


def _chain_source(comp: Computation, name: str) -> str:
    """Follow convert/bitcast/copy/reshape chains to the producing op."""
    seen = set()
    while name in comp.instrs and name not in seen:
        seen.add(name)
        instr = comp.instrs[name]
        if instr.opcode in _PASSTHROUGH and instr.operands:
            name = instr.operands[0]
        else:
            break
    return name


def _fusion_boundary_bytes(instr: Instr, comp: Computation,
                           callee: Optional[Computation]
                           ) -> Tuple[float, float]:
    """Boundary (read, write) bytes a fusion actually moves — the
    cache-hierarchy insight:

    * a fusion parameter consumed ONLY by (dynamic-)slice/gather ops reads
      just the sliced region, not the buffer (lax.scan slices the stacked
      layer weights / caches per iteration),
    * a fusion whose root is a dynamic-update-slice of a parameter updates
      IN PLACE (XLA aliases loop carries): the write costs the update
      region, and the aliased parameter is not streamed at all.

    Without these two rules every scan iteration appears to re-read and
    re-write entire stacked buffers (measured 26x overcount on the decode
    KV cache; see EXPERIMENTS.md §Perf).
    """
    out_full = instr.tuple_bytes if instr.is_tuple else instr.out_bytes
    if callee is None:
        return _operand_bytes(instr, comp), out_full

    # callee parameter name -> fusion operand name (by parameter index)
    param_of: Dict[str, str] = {}
    for nm, ci in callee.instrs.items():
        if ci.opcode == "parameter" and ci.operands:
            try:
                idx = int(ci.operands[0])
            except ValueError:
                continue
            if idx < len(instr.operands):
                param_of[nm] = instr.operands[idx]

    # in-place DUS detection on the root chain
    root_name = callee.order[-1] if callee.order else ""
    aliased_param: Optional[str] = None
    read_eff, write_eff = 0.0, out_full
    dus = callee.instrs.get(_chain_source(callee, root_name))
    if dus is not None and dus.opcode == "dynamic-update-slice":
        target = _chain_source(callee, dus.operands[0])
        tgt = callee.instrs.get(target)
        upd_bytes = _single_operand_bytes(
            dus.operands[1] if len(dus.operands) > 1 else "", callee)
        if tgt is not None and tgt.opcode == "parameter":
            aliased_param = target
            # read + write the update region, in place
            read_eff, write_eff = upd_bytes, upd_bytes
        # DUS of a freshly-sliced buffer (slice -> update -> emit): the
        # emit is real, but only slice-sized — out_full is already that.

    total = 0.0
    for pname, _ in param_of.items():
        if pname == aliased_param:
            continue
        uses = [ci for ci in callee.instrs.values()
                if pname in ci.operands and ci.opcode != "parameter"]
        if uses and all(u.opcode in ("dynamic-slice", "slice", "gather")
                        for u in uses):
            total += sum(u.out_bytes for u in uses)
        else:
            total += _single_operand_bytes(param_of[pname], comp)
    return total + read_eff, write_eff


def _dot_cost(instr: Instr, comp: Computation):
    """Returns (flops, (M, N, K))."""
    out_elems = max(1, math.prod(instr.shape))
    lhs = instr.operands[0] if instr.operands else None
    lhs_shape: Tuple[int, ...] = ()
    if lhs in comp.instrs:
        lhs_shape = comp.instrs[lhs].shape
    elif lhs in comp.params:
        lhs_shape = comp.params[lhs][1]
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    bm = re.search(r"lhs_batch_dims=\{([\d,]*)\}", instr.attrs)
    cdims = [int(x) for x in cm.group(1).split(",") if x] if cm else []
    bdims = [int(x) for x in bm.group(1).split(",") if x] if bm else []
    K = 1
    for d in cdims:
        if d < len(lhs_shape):
            K *= lhs_shape[d]
    batch = 1
    for d in bdims:
        if d < len(lhs_shape):
            batch *= lhs_shape[d]
    M = 1
    for i, d in enumerate(lhs_shape):
        if i not in cdims and i not in bdims:
            M *= d
    N = out_elems // max(M * batch, 1)
    flops = 2.0 * out_elems * K
    return flops, (M, N, K)


def _conv_cost(instr: Instr, comp: Computation) -> float:
    out_elems = max(1, math.prod(instr.shape))
    rhs = instr.operands[1] if len(instr.operands) > 1 else None
    k_elems = 1
    if rhs in comp.instrs:
        k_elems = max(1, math.prod(comp.instrs[rhs].shape))
    elif rhs in comp.params:
        k_elems = max(1, math.prod(comp.params[rhs][1]))
    # flops ~= 2 * out * (kernel elems / out_channels)
    out_ch = instr.shape[-1] if instr.shape else 1
    return 2.0 * out_elems * max(1, k_elems // max(out_ch, 1))


def _group_size(attrs: str, npart: int) -> int:
    m = _GROUPS_ITOTA_RE.search(attrs)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip()]))
    return npart


def _while_trip_count(cond: Computation, comps: Dict[str, Computation]) -> int:
    """Heuristic: largest integer constant in the condition computation
    (transitively through fusions).  XLA loop conditions compare the
    induction variable against the trip-count constant."""
    best = 1
    text_consts = []
    for instr in cond.instrs.values():
        if instr.opcode == "constant" and not instr.shape and \
                instr.dtype in ("s32", "s64", "u32", "u64"):
            # the constant literal was captured into operands by _parse_rhs
            for op in instr.operands:
                if op.isdigit():
                    text_consts.append(int(op))
        callee = _called(instr.attrs)
        if callee and callee in comps:
            for i2 in comps[callee].instrs.values():
                if i2.opcode == "constant" and not i2.shape and \
                        i2.dtype in ("s32", "s64", "u32", "u64"):
                    for op in i2.operands:
                        if op.isdigit():
                            text_consts.append(int(op))
    if text_consts:
        best = max(best, max(text_consts))
    return best


def _called(attrs: str) -> Optional[str]:
    m = re.search(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)", attrs)
    return m.group(1) if m else None


def _classify(opcode: str) -> str:
    if opcode in ("dot", "convolution"):
        return "matmul"
    if opcode in COLLECTIVES:
        return "collective"
    if opcode in TRANSCENDENTAL:
        return "transcendental"
    if opcode in ELEMENTWISE:
        return "elementwise"
    if opcode in REDUCE:
        return "reduce"
    if opcode in DATA_MOVEMENT:
        return "data"
    if opcode in FREE or opcode.endswith("-done"):
        return "free"
    return "elementwise"


def _consumers(comp: Computation) -> Dict[str, List[str]]:
    cons: Dict[str, List[str]] = defaultdict(list)
    for nm, instr in comp.instrs.items():
        for op in instr.operands:
            cons[op].append(nm)
    return cons


def _group_sinks(out: List[OpStat], start: int) -> List[int]:
    """Indices in out[start:] not consumed by another op of the same group —
    the group's dataflow outputs (what a downstream consumer waits on)."""
    group = range(start, len(out))
    if not group:
        return []
    referenced = set()
    for i in group:
        referenced.update(d for d in out[i].deps if d >= start)
    sinks = [i for i in group if i not in referenced]
    return sinks or list(group)


def _callee_param_deps(callee: Computation,
                       operand_deps: List[List[int]]) -> Dict[str, List[int]]:
    """Map callee parameter instr names to the call-site operands' producer
    indices (positionally, via the parameter(N) index)."""
    pd: Dict[str, List[int]] = {}
    for nm, ci in callee.instrs.items():
        if ci.opcode == "parameter" and ci.operands:
            try:
                k = int(ci.operands[0])
            except ValueError:
                continue
            if k < len(operand_deps):
                pd[nm] = operand_deps[k]
    return pd


def _cost_computation(comp: Computation, comps: Dict[str, Computation],
                      npart: int, mult: float, out: List[OpStat],
                      inline_fusions: bool,
                      param_deps: Optional[Dict[str, List[int]]] = None):
    consumers = _consumers(comp)
    param_deps = param_deps or {}
    # instr name -> indices into ``out`` that produce it (def-use edges)
    producer: Dict[str, List[int]] = {}
    resolved: Dict[str, List[int]] = {}

    def _resolve(nm: str) -> List[int]:
        if nm in producer:
            return producer[nm]
        if nm in resolved:
            return resolved[nm]
        if nm in param_deps:
            resolved[nm] = param_deps[nm]
            return resolved[nm]
        got: List[int] = []
        ci = comp.instrs.get(nm)
        if ci is not None:
            resolved[nm] = []            # guard (HLO is SSA; belt & braces)
            s: set = set()
            for o2 in ci.operands:
                s.update(_resolve(o2))
            got = sorted(s)
        resolved[nm] = got
        return got

    def _union_deps(names: List[str]) -> List[int]:
        s: set = set()
        for o2 in names:
            s.update(_resolve(o2))
        return sorted(s)

    def _dep_edges(names: List[str]) -> Tuple[List[int], List[float]]:
        """deps + per-edge operand bytes (split evenly when one operand
        resolves to several producers, e.g. a while's dataflow sinks)."""
        acc: Dict[int, float] = {}
        for o2 in names:
            idxs = _resolve(o2)
            if not idxs:
                continue
            share = _single_operand_bytes(o2, comp) / len(idxs)
            for j in idxs:
                acc[j] = acc.get(j, 0.0) + share
        deps = sorted(acc)
        return deps, [acc[j] for j in deps]

    for name in comp.order:
        instr = comp.instrs[name]
        opcode = instr.opcode
        cls = _classify(opcode)
        if cls == "free":
            continue
        if opcode == "fusion":
            callee = _called(instr.attrs)
            flops = trans = 0.0
            dot_dims = None
            tbo: Dict[str, float] = defaultdict(float)
            vbo: Dict[str, float] = defaultdict(float)
            callee_comp = comps.get(callee) if callee else None
            if callee_comp is not None:
                inner: List[OpStat] = []
                _cost_computation(callee_comp, comps, npart, 1.0, inner,
                                  inline_fusions)
                for o in inner:
                    flops += o.flops * o.count
                    trans += o.transcendentals * o.count
                    for k, v in o.trans_by_opcode.items():
                        tbo[k] += v * o.count
                    for k, v in o.vpu_by_opcode.items():
                        vbo[k] += v * o.count
                    if o.dot_dims is not None:
                        dot_dims = o.dot_dims
            rd_b, wr_b = _fusion_boundary_bytes(instr, comp, callee_comp)
            deps, dep_b = _dep_edges(instr.operands)
            out.append(OpStat(name, "fusion",
                              "matmul" if dot_dims else "elementwise",
                              instr.dtype, flops=flops, transcendentals=trans,
                              bytes_accessed=rd_b + wr_b, read_bytes=rd_b,
                              write_bytes=wr_b, count=mult,
                              dot_dims=dot_dims, trans_by_opcode=dict(tbo),
                              vpu_by_opcode=dict(vbo),
                              deps=deps, dep_bytes=dep_b))
            producer[name] = [len(out) - 1]
            continue
        if opcode in ("while",):
            body = None
            cond = None
            bm = re.search(r"body=%?([\w.\-]+)", instr.attrs)
            cm = re.search(r"condition=%?([\w.\-]+)", instr.attrs)
            if bm:
                body = bm.group(1)
            if cm:
                cond = cm.group(1)
            trips = 1
            if cond and cond in comps:
                trips = _while_trip_count(comps[cond], comps)
            if body and body in comps:
                start = len(out)
                odeps = [_resolve(o2) for o2 in instr.operands]
                _cost_computation(comps[body], comps, npart, mult * trips, out,
                                  inline_fusions,
                                  param_deps=_callee_param_deps(comps[body],
                                                                odeps))
                producer[name] = (_group_sinks(out, start)
                                  or _union_deps(instr.operands))
            else:
                producer[name] = _union_deps(instr.operands)
            continue
        if opcode in ("call", "async-start"):
            callee = _called(instr.attrs)
            if callee and callee in comps:
                start = len(out)
                odeps = [_resolve(o2) for o2 in instr.operands]
                _cost_computation(comps[callee], comps, npart, mult, out,
                                  inline_fusions,
                                  param_deps=_callee_param_deps(comps[callee],
                                                                odeps))
                producer[name] = (_group_sinks(out, start)
                                  or _union_deps(instr.operands))
            else:
                producer[name] = _union_deps(instr.operands)
            continue
        if opcode == "conditional":
            # cost the most expensive branch (throwaway flops-only pass to
            # pick it, then re-cost into ``out`` so dep indices are global)
            branches = re.findall(r"branch_computations=\{([^}]*)\}", instr.attrs)
            names = []
            if branches:
                names = [b.strip().lstrip("%") for b in branches[0].split(",")]
            else:
                names = [x for x in
                         re.findall(r"(?:true|false)_computation=%?([\w.\-]+)",
                                    instr.attrs)]
            best_nm: Optional[str] = None
            best_j = -1
            best_f = -1.0
            for j, nm in enumerate(names):
                if nm in comps:
                    cand: List[OpStat] = []
                    _cost_computation(comps[nm], comps, npart, mult, cand,
                                      inline_fusions)
                    f = sum(o.flops * o.count for o in cand)
                    if f > best_f:
                        best_nm, best_j, best_f = nm, j, f
            if best_nm is not None:
                start = len(out)
                # branch k consumes conditional operand k+1 (0 is the pred)
                if best_j + 1 < len(instr.operands):
                    odeps = [_resolve(instr.operands[best_j + 1])]
                else:
                    odeps = [_union_deps(instr.operands)]
                _cost_computation(comps[best_nm], comps, npart, mult, out,
                                  inline_fusions,
                                  param_deps=_callee_param_deps(comps[best_nm],
                                                                odeps))
                producer[name] = (_group_sinks(out, start)
                                  or _union_deps(instr.operands))
            else:
                producer[name] = _union_deps(instr.operands)
            continue

        in_b = _operand_bytes(instr, comp)
        out_b = instr.tuple_bytes if instr.is_tuple else instr.out_bytes
        # sliced-access ops touch the region, not the buffer (and XLA
        # in-places DUS): same modeling as _fusion_boundary_bytes.
        if opcode in ("dynamic-slice", "slice"):
            in_b = out_b
        elif opcode == "dynamic-update-slice":
            upd = (_single_operand_bytes(instr.operands[1], comp)
                   if len(instr.operands) > 1 else out_b)
            in_b, out_b = upd, upd
        elif opcode == "gather":
            in_b = out_b + sum(_single_operand_bytes(o, comp)
                               for o in instr.operands[1:])
        elif opcode == "convert":
            # a convert whose only consumers are dots is fused into the
            # MXU operand read stream on TPU (int8/bf16 KV caches, bf16
            # weights into f32-accumulating dots): the widened copy is
            # never written to HBM (modeling rule I-5, DESIGN.md §9).
            cons = consumers.get(name, ())
            if cons and all(comp.instrs[c].opcode in ("dot", "convolution")
                            for c in cons if c in comp.instrs):
                out_b = 0.0
        deps, dep_b = _dep_edges(instr.operands)
        stat = OpStat(name, opcode, cls, instr.dtype,
                      bytes_accessed=in_b + out_b, read_bytes=in_b,
                      write_bytes=out_b, count=mult,
                      deps=deps, dep_bytes=dep_b)
        nelems = max(1, math.prod(instr.shape))
        if cls == "matmul":
            if opcode == "dot":
                stat.flops, stat.dot_dims = _dot_cost(instr, comp)
            else:
                stat.flops = _conv_cost(instr, comp)
        elif cls == "transcendental":
            stat.flops = float(nelems)
            stat.transcendentals = float(nelems)
            stat.trans_by_opcode = {opcode: float(nelems)}
        elif cls == "elementwise":
            stat.flops = float(nelems)
            stat.vpu_by_opcode = {opcode: float(nelems)}
        elif cls == "reduce":
            stat.flops = float(in_b / max(DTYPE_BYTES.get(instr.dtype, 4), 1))
        elif cls == "collective":
            stat.comm_bytes = in_b
            stat.group_size = _group_size(instr.attrs, npart)
            stat.opcode = COLLECTIVES[opcode]
        out.append(stat)
        producer[name] = [len(out) - 1]


def parse_program(text: str) -> Program:
    comps, entry, npart = parse_computations(text)
    # fallback: entry = computation containing while/largest
    if entry not in comps and comps:
        entry = max(comps, key=lambda c: len(comps[c].order))
    ops: List[OpStat] = []
    if entry in comps:
        _cost_computation(comps[entry], comps, npart, 1.0, ops, True)
    return Program(ops=ops, entry=entry, n_partitions=npart)
