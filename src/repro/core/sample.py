"""SimPoint-style sampled estimation — schedule ~10% of a long program.

Long programs (full-depth training steps, token-by-token decode traces)
repeat near-identical iterations; scheduling every op through the node
engine's in-order pass wastes a wall-clock factor proportional to the
repetition.  This module is the gem5-lineage answer (SimPoint/LoopPoint
checkpoint sampling) at HLO altitude (DESIGN.md §18):

1. **Slice** — the costed :class:`~.hlo.Program` is cut into intervals of
   ~``interval_ops`` op *instances* (``OpStat.count``-weighted, so a
   collapsed 96-trip loop body weighs 96x its list length), with cuts
   snapped to *phase boundaries* — indices where the collapsed-loop
   ``count`` changes, i.e. entry/exit of a scanned layer stack — so an
   interval never straddles a loop edge when a boundary is near.
2. **Featurize** — each interval gets an op-mix/traffic signature built
   from the SAME arrays the node engine schedules (``NodeCompiled``):
   instance-weighted opclass histogram, per-port duration pressure,
   compute/ICI time, and per-level routed read+write bytes from
   ``memory.route_program``'s residency split.  Columns are max-scaled so
   no unit dominates the distance metric.
3. **Cluster** — deterministic seeded k-means (numpy; farthest-point++
   init off a fixed ``numpy.random.RandomState``), k chosen by a
   BIC-style elbow (smallest k whose score reaches ``bic_frac`` of the
   best over 1..max_k) unless pinned.
4. **Schedule only representatives** — the member nearest each centroid
   runs through the node engine (``schedule_node`` scalar, or the fused
   ``schedule_node_sweep`` core-count x knob grid); every other interval
   is never scheduled.
5. **Reconstruct** — ``t_est = sum_c w_c * t(rep_c)`` with
   ``w_c = cluster instances / rep instances``; per-level traffic and the
   binding port blend the same way.

**Warm-up handling**: the program is costed ONCE, whole — reuse
distances and residency levels come from ``route_program`` over the
*full* op sequence, and each interval is scheduled on a slice of that
costed list.  An interval's boundary reads therefore keep the residency
the full trace gave them (data produced by the preceding interval is
still level-resident); re-routing intervals standalone would charge
those as cold misses twice — once in the producing interval's writes and
once at the consumer — which is exactly the double-count this avoids.

**Exactness anchor**: scheduling an interval in isolation replays the
full in-order pass between barriers — every pre-boundary constraint
(dep finishes, pipe lanes, ROB retire ring, queue history) is dominated
by the preceding intervals' makespan, so the sum over ALL intervals
equals the barriered full pass.  ``k >= n_intervals`` short-circuits to
one-cluster-per-interval and is therefore bit-identical to that full
interval scheduling (pinned by ``tests/test_sampling.py``); the residual
vs the *monolithic* (barrier-free) pass is the cross-boundary overlap
the ROB window spans, a few percent for intervals >> window (pinned at
5% on the suite programs).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .compiled import PORTS
from .cost import OpTime, cost_program
from .hlo import OpStat, Program
from .hwspec import HardwareSpec, NodeTopology
from .node import NodeCompiled, compile_node, schedule_node, \
    schedule_node_sweep

#: opclass axis of the signature vector (stable order)
OPCLASSES: Tuple[str, ...] = ("matmul", "elementwise", "transcendental",
                              "reduce", "data", "collective")


@dataclass(frozen=True)
class SamplingConfig:
    """Knobs of the sampled estimator (DESIGN.md §18).

    ``interval_ops`` is the target op-*instance* count per interval
    (``OpStat.count``-weighted).  ``k=None`` selects k by the BIC-style
    elbow over ``1..max_k``; ``k >= n_intervals`` degenerates to exact
    full interval scheduling.  Everything is deterministic for a fixed
    ``seed``.
    """
    interval_ops: float = 512.0
    k: Optional[int] = None
    max_k: int = 16
    seed: int = 0
    bic_frac: float = 0.9
    phase_aware: bool = True
    #: snap radius for phase-boundary cuts, as a fraction of interval_ops
    snap_frac: float = 0.25


@dataclass
class Interval:
    """One contiguous op range ``[start, end)`` of the sliced program."""
    start: int
    end: int
    n_instances: float           # sum of OpStat.count over the range


@dataclass
class SamplePlan:
    """The sampling decision for one (program, spec, dtype) cell:
    intervals, signatures, cluster assignment, representatives and
    weights — everything downstream scheduling needs, with the costed
    slices already attached (full-program routing, DESIGN.md §18)."""
    config: SamplingConfig
    intervals: List[Interval]
    signatures: np.ndarray       # [n_intervals, d] scaled feature rows
    labels: np.ndarray           # [n_intervals] cluster id
    reps: np.ndarray             # [k] interval index of each representative
    weights: np.ndarray          # [k] cluster instances / rep instances
    k: int
    n_ops: int                   # list ops in the program
    n_instances: float           # total op instances
    # sub-programs + costed slices for the representative intervals only
    rep_programs: List[Program] = field(default_factory=list, repr=False)
    rep_costed: List[List[Optional[OpTime]]] = field(
        default_factory=list, repr=False)

    @property
    def n_intervals(self) -> int:
        return len(self.intervals)

    @property
    def scheduled_ops(self) -> int:
        """List ops actually scheduled (the representatives')."""
        return sum(self.intervals[int(r)].end - self.intervals[int(r)].start
                   for r in self.reps)

    @property
    def scheduled_instances(self) -> float:
        return float(sum(self.intervals[int(r)].n_instances
                         for r in self.reps))

    @property
    def frac_ops_scheduled(self) -> float:
        """Fraction of op instances scheduled — the sampling cost knob
        (<= 0.2 at the CI floor)."""
        return self.scheduled_instances / max(self.n_instances, 1e-30)


@dataclass
class SampledNodeResult:
    """Weight-blended reconstruction of a node estimate from the
    representative intervals (the sampled counterpart of
    :class:`~.node.NodeResult`; DESIGN.md §18)."""
    t_est: float
    n_cores: int
    partition: str
    plan: SamplePlan
    t_rep: np.ndarray            # [k] representative interval makespans
    traffic_by_level: Dict[str, Dict[str, float]]
    port_busy: Dict[str, float]
    bound_by: str
    t_zero_contention: float
    # exact blend: sum_c w_c busy_c / (cores * sum_c w_c t_c)
    parallel_efficiency: float = 0.0

    @property
    def frac_ops_scheduled(self) -> float:
        return self.plan.frac_ops_scheduled


# ------------------------------------------------------------------ slicing
def phase_boundaries(prog: Program) -> np.ndarray:
    """Indices where the collapsed-loop ``count`` changes between
    adjacent ops — entry/exit points of scanned layer stacks, the
    natural phase edges of an XLA program."""
    counts = np.array([o.count for o in prog.ops], dtype=np.float64)
    if len(counts) < 2:
        return np.zeros(0, dtype=np.intp)
    return np.nonzero(counts[1:] != counts[:-1])[0] + 1


def slice_intervals(prog: Program, interval_ops: float,
                    phase_aware: bool = True,
                    snap_frac: float = 0.25) -> List[Interval]:
    """Cut the program into contiguous intervals of ~``interval_ops``
    instances.  With ``phase_aware`` the nominal cut snaps to the nearest
    phase boundary within ``snap_frac * interval_ops`` instances, so
    intervals don't straddle a loop edge when one is near."""
    n = len(prog.ops)
    if n == 0:
        return []
    counts = np.array([o.count for o in prog.ops], dtype=np.float64)
    cum = np.concatenate(([0.0], np.cumsum(counts)))   # cum[i] = before op i
    total = cum[-1]
    step = max(float(interval_ops), 1.0)
    bounds = set(phase_boundaries(prog).tolist()) if phase_aware else set()
    out: List[Interval] = []
    start = 0
    while start < n:
        target = cum[start] + step
        if target >= total:
            end = n
        else:
            # first index whose cumulative start reaches the target
            end = int(np.searchsorted(cum, target, side="left"))
            end = max(start + 1, min(end, n))
            if bounds:
                lo, hi = cum[end] - snap_frac * step, cum[end] + snap_frac * step
                near = [b for b in bounds
                        if start < b < n and lo <= cum[b] <= hi]
                if near:
                    end = min(near, key=lambda b: abs(cum[b] - cum[end]))
        out.append(Interval(start, end, float(cum[end] - cum[start])))
        start = end
    return out


# --------------------------------------------------------------- signatures
@dataclass
class _FeatureArrays:
    """Per-op arrays pulled straight from the costed list — the lean
    extraction (no full-program ``compile_node``; it would dominate the
    sampled wall on long traces)."""
    count: np.ndarray            # [n] instances per list op
    cls: np.ndarray              # [n] OPCLASSES index
    port: np.ndarray             # [n] PORTS index, -1 = uncosted
    dur: np.ndarray              # [n] per-instance op time (max of ports)
    t_comp: np.ndarray           # [n] per-instance compute time
    t_ici: np.ndarray            # [n] per-instance ICI time
    rdwr: np.ndarray             # [n, L] per-instance routed read+write B
    level_names: Tuple[str, ...]


def _feature_arrays(prog: Program, hw: HardwareSpec,
                    costed: Sequence[Optional[OpTime]]) -> _FeatureArrays:
    n = len(prog.ops)
    names = tuple(lv.name for lv in hw.mem_levels)
    lvl = {nm: i for i, nm in enumerate(names)}
    cls_id = {c: i for i, c in enumerate(OPCLASSES)}
    pid = {p: i for i, p in enumerate(PORTS)}
    count = np.empty(n)
    cls = np.empty(n, dtype=np.intp)
    port = np.full(n, -1, dtype=np.intp)
    dur = np.zeros(n)
    t_comp = np.zeros(n)
    t_ici = np.zeros(n)
    rdwr = np.zeros((n, len(names)))
    for i, o in enumerate(prog.ops):
        count[i] = o.count
        cls[i] = cls_id.get(o.opclass, 1)
        ot = costed[i]
        if ot is None:
            continue
        port[i] = pid.get(ot.port, -1)
        dur[i] = ot.t_op
        t_comp[i] = ot.t_compute
        t_ici[i] = ot.t_ici
        tr = ot.traffic
        if tr is not None:
            row = rdwr[i]
            for nm, b in tr.read_by_level.items():
                row[lvl[nm]] += b
            for nm, b in tr.write_by_level.items():
                row[lvl[nm]] += b
    return _FeatureArrays(count, cls, port, dur, t_comp, t_ici, rdwr, names)


def interval_signatures(fa: _FeatureArrays,
                        intervals: Sequence[Interval]) -> np.ndarray:
    """Per-interval op-mix/traffic signature matrix, max-scaled columns.

    Features (all per-instance-normalized so interval length drops out
    and only the *mix* clusters): opclass histogram, per-port duration
    pressure, compute/ICI time, per-level routed read+write bytes."""
    n_iv = len(intervals)
    L = fa.rdwr.shape[1]
    d = len(OPCLASSES) + len(PORTS) + 2 + L
    X = np.zeros((n_iv, d))
    for ii, iv in enumerate(intervals):
        s, e = iv.start, iv.end
        inst = max(iv.n_instances, 1e-30)
        c = fa.count[s:e]
        row = X[ii]
        np.add.at(row, fa.cls[s:e], c)
        row[:len(OPCLASSES)] /= inst
        pm = fa.port[s:e]
        live = pm >= 0
        np.add.at(row, len(OPCLASSES) + pm[live],
                  (fa.dur[s:e] * c)[live])
        row[len(OPCLASSES):len(OPCLASSES) + len(PORTS)] /= inst
        off = len(OPCLASSES) + len(PORTS)
        row[off] = float((fa.t_comp[s:e] * c).sum()) / inst
        row[off + 1] = float((fa.t_ici[s:e] * c).sum()) / inst
        row[off + 2:] = (fa.rdwr[s:e] * c[:, None]).sum(axis=0) / inst
    scale = np.abs(X).max(axis=0)
    scale[scale <= 0] = 1.0
    return X / scale


# ------------------------------------------------------------------ k-means
def kmeans(X: np.ndarray, k: int, seed: int = 0,
           n_iter: int = 64) -> Tuple[np.ndarray, np.ndarray, float]:
    """Deterministic seeded Lloyd k-means with farthest-point++ init.
    Returns ``(labels, centers, wcss)``.  Empty clusters are reseeded to
    the point farthest from its center (keeps k populated when k <= the
    number of distinct rows)."""
    n = len(X)
    k = max(1, min(k, n))
    rng = np.random.RandomState(seed)
    centers = np.empty((k, X.shape[1]))
    centers[0] = X[int(rng.randint(n))]
    d2 = ((X - centers[0]) ** 2).sum(axis=1)
    for c in range(1, k):
        centers[c] = X[int(d2.argmax())]
        d2 = np.minimum(d2, ((X - centers[c]) ** 2).sum(axis=1))
    labels = np.zeros(n, dtype=np.intp)
    for _ in range(n_iter):
        dist = ((X[:, None, :] - centers[None, :, :]) ** 2).sum(axis=2)
        new = dist.argmin(axis=1)
        for c in range(k):
            sel = new == c
            if sel.any():
                centers[c] = X[sel].mean(axis=0)
            else:
                far = int(dist[np.arange(n), new].argmax())
                centers[c] = X[far]
                new[far] = c
        if (new == labels).all():
            labels = new
            break
        labels = new
    wcss = float(((X - centers[labels]) ** 2).sum())
    return labels, centers, wcss


def choose_k(X: np.ndarray, max_k: int, seed: int = 0,
             bic_frac: float = 0.9) -> Tuple[int, np.ndarray, np.ndarray]:
    """SimPoint's k selection: score each k in ``1..max_k`` with a
    BIC-style criterion (spherical-Gaussian log-likelihood minus a
    ``k``-proportional complexity penalty) and keep the smallest k whose
    score reaches ``bic_frac`` of the best.  Returns ``(k, labels,
    centers)``."""
    n, d = X.shape
    best: Dict[int, Tuple[np.ndarray, np.ndarray, float]] = {}
    scores: Dict[int, float] = {}
    for k in range(1, min(max_k, n) + 1):
        labels, centers, wcss = kmeans(X, k, seed)
        var = wcss / max(n * d, 1)
        loglik = -0.5 * n * d * np.log(var + 1e-12)
        scores[k] = float(loglik - 0.5 * k * (d + 1) * np.log(max(n, 2)))
        best[k] = (labels, centers, wcss)
    top = max(scores.values())
    lo = min(scores.values())
    cut = lo + bic_frac * (top - lo)
    for k in sorted(scores):
        if scores[k] >= cut:
            labels, centers, _ = best[k]
            return k, labels, centers
    k = max(scores, key=scores.__getitem__)
    labels, centers, _ = best[k]
    return k, labels, centers


# ------------------------------------------------------------ sub-programs
def _sub_program(prog: Program, costed: Sequence[Optional[OpTime]],
                 iv: Interval) -> Tuple[Program, List[Optional[OpTime]]]:
    """Slice ``[start, end)`` into a standalone Program + costed list.
    Deps are remapped into the interval; cross-boundary edges drop (their
    producers' finishes are dominated by the preceding intervals'
    makespan — the barrier argument in the module docstring).  The costed
    slice is reused as-is: durations keep the FULL-program routing."""
    s, e = iv.start, iv.end
    ops: List[OpStat] = []
    for i in range(s, e):
        o = prog.ops[i]
        if o.deps and (o.deps[0] < s or o.deps[-1] >= e):
            deps, dep_b = [], []
            for j, b in zip(o.deps, o.dep_bytes):
                if s <= j < e:
                    deps.append(j - s)
                    dep_b.append(b)
            o = dataclasses.replace(o, deps=deps, dep_bytes=dep_b)
        elif o.deps:
            o = dataclasses.replace(o, deps=[j - s for j in o.deps],
                                    dep_bytes=list(o.dep_bytes))
        ops.append(o)
    sub = Program(ops=ops, entry=f"{prog.entry}[{s}:{e}]",
                  n_partitions=prog.n_partitions)
    return sub, list(costed[s:e])


# ------------------------------------------------------------------ the plan
def sample_program(prog: Program, hw: HardwareSpec,
                   config: Optional[SamplingConfig] = None,
                   compute_dtype: Optional[str] = None,
                   costed: Optional[List[Optional[OpTime]]] = None
                   ) -> SamplePlan:
    """Slice + featurize + cluster one costed program into a
    :class:`SamplePlan`.  The program is costed once, whole (full-trace
    reuse distances — the warm-up rule); representatives carry slices of
    that costed list."""
    config = config or SamplingConfig()
    if costed is None:
        costed = cost_program(prog, hw, compute_dtype=compute_dtype)
    fa = _feature_arrays(prog, hw, costed)
    intervals = slice_intervals(prog, config.interval_ops,
                                config.phase_aware, config.snap_frac)
    n_iv = len(intervals)
    X = interval_signatures(fa, intervals)
    if n_iv == 0:
        labels = np.zeros(0, dtype=np.intp)
        k = 0
    elif config.k is not None and config.k >= n_iv:
        # exact mode: every interval its own cluster (identity assignment
        # sidesteps k-means degeneracy on duplicate signatures)
        k = n_iv
        labels = np.arange(n_iv, dtype=np.intp)
    elif config.k is not None:
        k = max(1, config.k)
        labels, _, _ = kmeans(X, k, config.seed)
        k = int(labels.max()) + 1 if n_iv else 0
    else:
        k, labels, _ = choose_k(X, min(config.max_k, n_iv), config.seed,
                                config.bic_frac)

    inst = np.array([iv.n_instances for iv in intervals])
    reps = np.zeros(k, dtype=np.intp)
    weights = np.zeros(k)
    for c in range(k):
        members = np.nonzero(labels == c)[0]
        centroid = X[members].mean(axis=0)
        d2 = ((X[members] - centroid) ** 2).sum(axis=1)
        rep = int(members[int(d2.argmin())])
        reps[c] = rep
        weights[c] = inst[members].sum() / max(inst[rep], 1e-30)

    plan = SamplePlan(config=config, intervals=intervals, signatures=X,
                      labels=labels, reps=reps, weights=weights, k=k,
                      n_ops=len(prog.ops), n_instances=float(inst.sum()))
    for r in reps:
        sub, sub_costed = _sub_program(prog, costed, intervals[int(r)])
        plan.rep_programs.append(sub)
        plan.rep_costed.append(sub_costed)
    return plan


# --------------------------------------------------------------- estimation
def _rep_node_forms(plan: SamplePlan, hw: HardwareSpec,
                    compute_dtype: Optional[str]) -> List[NodeCompiled]:
    return [compile_node(sub, hw, compute_dtype=compute_dtype, costed=ct)
            for sub, ct in zip(plan.rep_programs, plan.rep_costed)]


def sampled_schedule_node(prog: Program, hw: HardwareSpec, n_cores: int,
                          topology: Optional[NodeTopology] = None,
                          partition: str = "shard",
                          config: Optional[SamplingConfig] = None,
                          compute_dtype: Optional[str] = None,
                          costed: Optional[List[Optional[OpTime]]] = None,
                          plan: Optional[SamplePlan] = None,
                          **kw) -> SampledNodeResult:
    """Sampled node estimate at one core count: schedule each cluster's
    representative through :func:`~.node.schedule_node` and blend by the
    instance weights.  A precomputed ``plan`` (e.g. shared across a
    core-count sweep) skips re-clustering."""
    if plan is None:
        plan = sample_program(prog, hw, config, compute_dtype, costed)
    forms = _rep_node_forms(plan, hw, compute_dtype)
    t_rep = np.zeros(plan.k)
    t_zero = busy = 0.0
    port_busy: Dict[str, float] = {}
    traffic: Dict[str, Dict[str, float]] = {}
    for c, nc in enumerate(forms):
        nr = schedule_node(nc, hw, n_cores, topology=topology,
                           partition=partition, **kw)
        w = plan.weights[c]
        t_rep[c] = nr.t_est
        t_zero += w * nr.t_zero_contention
        # busy-time blend => exact reconstructed parallel efficiency
        busy += w * nr.parallel_efficiency * n_cores * nr.t_est
        for p, b in nr.schedule.port_busy.items():
            port_busy[p] = port_busy.get(p, 0.0) + w * b
        # per-level routed bytes of the representative, weight-blended
        rd = (nc.rd * nc.count[:, None]).sum(axis=0)
        wr = (nc.wr * nc.count[:, None]).sum(axis=0)
        for li, nm in enumerate(nc.level_names):
            t = traffic.setdefault(nm, {"read_bytes": 0.0,
                                        "write_bytes": 0.0})
            t["read_bytes"] += w * float(rd[li])
            t["write_bytes"] += w * float(wr[li])
    bound = max(port_busy, key=port_busy.__getitem__) if port_busy else ""
    t_est = float((plan.weights * t_rep).sum())
    return SampledNodeResult(
        t_est=t_est, n_cores=n_cores,
        partition=partition, plan=plan, t_rep=t_rep,
        traffic_by_level=traffic, port_busy=port_busy, bound_by=bound,
        t_zero_contention=t_zero,
        parallel_efficiency=busy / max(n_cores * t_est, 1e-30))


def sampled_node_sweep(prog: Program, hw: HardwareSpec, knobs,
                       core_counts: Sequence[int],
                       topology: Optional[NodeTopology] = None,
                       partition: str = "shard",
                       config: Optional[SamplingConfig] = None,
                       compute_dtype: Optional[str] = None,
                       costed: Optional[List[Optional[OpTime]]] = None,
                       plan: Optional[SamplePlan] = None,
                       backend: str = "numpy"
                       ) -> Tuple[np.ndarray, SamplePlan]:
    """Sampled core-count x knob sweep: each representative rides the
    batched node engine (``schedule_node_sweep``), and the ``[C, B]``
    grids blend by the instance weights — the zoo's sampled path."""
    if plan is None:
        plan = sample_program(prog, hw, config, compute_dtype, costed)
    core_counts = list(core_counts)
    out = np.zeros((len(core_counts), knobs.batch))
    for c, nc in enumerate(_rep_node_forms(plan, hw, compute_dtype)):
        t = schedule_node_sweep(nc, hw, knobs, core_counts,
                                topology=topology, partition=partition,
                                backend=backend)
        out += plan.weights[c] * t
    return out, plan


def full_interval_estimate(prog: Program, hw: HardwareSpec, n_cores: int,
                           topology: Optional[NodeTopology] = None,
                           partition: str = "shard",
                           config: Optional[SamplingConfig] = None,
                           compute_dtype: Optional[str] = None,
                           costed: Optional[List[Optional[OpTime]]] = None
                           ) -> SampledNodeResult:
    """The sampler's exact-coverage baseline: EVERY interval scheduled
    (k = n_intervals), no clustering error — what ``k >= n_intervals``
    sampling must reproduce bit-for-bit (differential tests)."""
    config = dataclasses.replace(config or SamplingConfig(), k=10 ** 9)
    return sampled_schedule_node(prog, hw, n_cores, topology, partition,
                                 config, compute_dtype, costed)


# ------------------------------------------------------------- long traces
def unroll_program(prog: Program, repeats: int,
                   chain: bool = True) -> Program:
    """Concatenate ``repeats`` copies of ``prog`` into one long trace —
    the zoo's full-depth/multi-step mode (a traced step of a
    layer-homogeneous stack repeats; decode emits one near-identical
    program per generated token).  Deps shift per copy; with ``chain``,
    each copy's source ops (no in-step producers) additionally wait on
    the previous copy's dataflow sinks through zero-byte edges — pure
    scheduling order, no phantom traffic (``route_program`` ignores
    zero-byte edges, so routing per copy matches the single step)."""
    n = len(prog.ops)
    if repeats <= 1 or n == 0:
        return prog
    consumed = set()
    for o in prog.ops:
        consumed.update(o.deps)
    sinks = [i for i in range(n) if i not in consumed] if chain else []
    ops: List[OpStat] = []
    for r in range(repeats):
        off = r * n
        for i, o in enumerate(prog.ops):
            deps = [j + off for j in o.deps]
            dep_b = list(o.dep_bytes)
            if chain and r > 0 and not o.deps:
                prev = (r - 1) * n
                deps = [s + prev for s in sinks]
                dep_b = [0.0] * len(sinks)
            ops.append(dataclasses.replace(o, deps=deps, dep_bytes=dep_b))
    return Program(ops=ops, entry=f"{prog.entry}x{repeats}",
                   n_partitions=prog.n_partitions)


# ------------------------------------------------------- bench measurement
def measure_sampled_vs_full(prog: Program, hw: HardwareSpec, n_cores: int,
                            topology: Optional[NodeTopology] = None,
                            partition: str = "shard",
                            config: Optional[SamplingConfig] = None,
                            compute_dtype: Optional[str] = None) -> dict:
    """One benchmark row: monolithic full schedule vs sampled
    reconstruction — t_est error, fraction of op instances scheduled,
    end-to-end wall-clock speedup (costing excluded from both sides; it
    is shared).  ``benchmarks/sampled_estimation.py`` drives this."""
    costed = cost_program(prog, hw, compute_dtype=compute_dtype)

    t0 = time.perf_counter()
    nc = compile_node(prog, hw, compute_dtype=compute_dtype, costed=costed)
    full = schedule_node(nc, hw, n_cores, topology=topology,
                         partition=partition)
    wall_full = time.perf_counter() - t0

    t0 = time.perf_counter()
    sam = sampled_schedule_node(prog, hw, n_cores, topology, partition,
                                config, compute_dtype, costed)
    wall_sampled = time.perf_counter() - t0

    err = (sam.t_est - full.t_est) / max(full.t_est, 1e-30)
    return {
        "n_ops": len(prog.ops),
        "n_instances": sam.plan.n_instances,
        "n_intervals": sam.plan.n_intervals,
        "k": sam.plan.k,
        "frac_ops_scheduled": sam.plan.frac_ops_scheduled,
        "t_full_us": full.t_est * 1e6,
        "t_sampled_us": sam.t_est * 1e6,
        "reconstruction_error_pct": 100.0 * err,
        "bound_by_full": full.schedule.bound_by,
        "bound_by_sampled": sam.bound_by,
        "wall_full_s": wall_full,
        "wall_sampled_s": wall_sampled,
        "speedup": wall_full / max(wall_sampled, 1e-30),
    }
