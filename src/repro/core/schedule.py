"""Dependency-aware O3 scheduling engine — overlap that *emerges*.

The flat occupancy engine (``core.engine``) assumes overlap: fixed
``dma_overlap`` / ``ici_overlap`` fractions of memory and collective time
hide under compute.  This module replaces the assumption with a schedule,
following gem5's issue/reservation-station design at HLO altitude:

* every costed op is a task on one port (MXU / VPU / DMA-mem / ICI) with a
  duration from the shared ``core.cost`` pipeline (hierarchy-routed memory
  times included),
* ``parse_program`` supplies def-use edges (``OpStat.deps``), so async-DMA
  and async-collective overlap falls out of the dataflow graph — an op
  waits for its producers, not for program order,
* three O3 resource knobs bound the reordering, the reservation-station /
  ROB analogue (``HardwareSpec``):
    - ``issue_width[port]``   parallel pipes per port,
    - ``inflight_window``     ROB size: op *i* cannot issue until op
                              *i - window* has retired (in-order retire),
    - ``queue_depth[port]``   per-port reservation-station depth: op *i*
                              cannot issue until the op ``depth`` earlier
                              on the same port has issued.

The scheduler is a deterministic in-order list scheduler: ops are visited
in (topological) program order and start at the max of their constraint
times.  Every constraint time is bounded by the worst finish seen so far,
which gives the engine's defining invariant, asserted in the golden tests:

    t_roofline  <=  t_est(schedule)  <=  t_serial

where ``t_roofline`` here is the schedule-consistent bound
``max_p busy_p / width_p`` and ``t_serial`` is the fully-serialized sum.

Two execution paths share those semantics (DESIGN.md §13):

* the **fast path** (default): ``core.compiled`` compiles the costed
  program to structure-of-arrays form once and runs an allocation-free
  kernel — ``t_est``/``port_busy``/``stall_by_reason`` only, bit-identical
  to the interpreter;
* the **reference interpreter** (``schedule_reference``): builds the full
  ``ScheduledOp`` timeline and binding-chain critical path.  The fast
  path's ``ScheduleResult`` materializes it lazily the first time
  ``timeline`` / ``critical_path`` is touched (i.e. when the PA report
  asks), so sweeps never pay for it.
"""
from __future__ import annotations

import heapq
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from .cost import OpTime, cost_program
from .hlo import OpStat, Program
from .hwspec import HardwareSpec

# the binding-chain walk stops after this many entries; ScheduleResult
# raises the critical_path_truncated flag when the cap bites
CRITICAL_PATH_LIMIT = 256


@dataclass
class ScheduledOp:
    """One op placed on the timeline."""
    index: int                   # position in Program.ops
    op: OpStat
    port: str
    start: float
    finish: float
    ready: float                 # when all producers had finished
    bound_by: str                # what set the start time:
                                 #   'ready' | 'dep' | 'port' | 'window'
                                 #   | 'queue'
    bound_on: int = -1           # index of the op that imposed the bound

    @property
    def duration(self) -> float:
        return self.finish - self.start


@dataclass
class ScheduleResult:
    """Output of the dependency-aware O3 list scheduler (DESIGN.md §11).

    ``t_est`` always sits inside the sandwich ``max(t_roofline,
    t_dataflow) <= t_est <= t_serial`` (property-tested).  Timeline and
    critical-path detail are materialized lazily on first access when the
    result came from the compiled fast path (DESIGN.md §13); sweeps that
    only read ``t_est`` never pay for them.  The node engine
    (DESIGN.md §14) aggregates its per-core streams into one of these.
    """
    t_est: float                 # makespan of the schedule
    t_roofline: float            # max port busy / issue width (lower bound)
    t_serial: float              # fully serialized (upper bound)
    t_dataflow: float            # critical path, infinite resources
    port_busy: Dict[str, float]  # summed scheduled durations per port
    n_ops: float
    n_edges: int                 # def-use edges seen by the scheduler
    stall_by_reason: Dict[str, float] = field(default_factory=dict)
    issue_width: Dict[str, int] = field(default_factory=dict)
    # timeline/critical-path detail: populated eagerly by the reference
    # interpreter, lazily (via _detail) on the fast path
    _timeline: Optional[List[ScheduledOp]] = field(default=None, repr=False)
    _critical_path: Optional[List[ScheduledOp]] = field(default=None,
                                                        repr=False)
    _cp_truncated: bool = False
    _detail: Optional[Callable[[], "ScheduleResult"]] = field(default=None,
                                                              repr=False)

    def _ensure_detail(self) -> None:
        if self._timeline is None:
            if self._detail is None:
                self._timeline, self._critical_path = [], []
                return
            ref = self._detail()
            self._timeline = ref._timeline
            self._critical_path = ref._critical_path
            self._cp_truncated = ref._cp_truncated
            self._detail = None

    @property
    def timeline(self) -> List[ScheduledOp]:
        self._ensure_detail()
        return self._timeline

    @property
    def critical_path(self) -> List[ScheduledOp]:
        self._ensure_detail()
        return self._critical_path

    @property
    def critical_path_truncated(self) -> bool:
        """True when the binding-chain walk hit CRITICAL_PATH_LIMIT — the
        reported path is a suffix, not the whole chain."""
        self._ensure_detail()
        return self._cp_truncated

    @property
    def bound_by(self) -> str:
        """Binding port, normalized by issue width — consistent with how
        t_roofline picks it (raw busy would crown a 4-pipe DMA port over
        a busier single-pipe MXU)."""
        if not self.port_busy:
            return "mem"
        w = self.issue_width
        return max(self.port_busy,
                   key=lambda k: self.port_busy[k] / max(1, w.get(k, 1)))

    @property
    def overlap_fraction(self) -> float:
        """Fraction of serial time hidden by the schedule (0 = no overlap
        found, i.e. one dependence chain; -> (serial-est)/serial)."""
        if self.t_serial <= 0:
            return 0.0
        return max(0.0, (self.t_serial - self.t_est) / self.t_serial)


def _duration(ot: OpTime, hw: HardwareSpec) -> float:
    """Total task time: per-instance critical resource time + issue cost,
    times the (loop-trip) count.  Iterations of a collapsed while body are
    loop-carried, hence serial within the op."""
    per = max(ot.t_compute, ot.t_mem, ot.t_ici) + hw.op_startup_ns * 1e-9
    return per * ot.op.count


def _roofline(port_busy: Dict[str, float], widths: Dict[str, int]) -> float:
    return max((busy / max(1, widths.get(p, 1))
                for p, busy in port_busy.items()), default=0.0)


def schedule_program(prog: Program, hw: HardwareSpec,
                     links_per_collective: int = 2,
                     compute_dtype: Optional[str] = None,
                     costed: Optional[List[Optional[OpTime]]] = None,
                     detail: bool = False) -> ScheduleResult:
    """Schedule ``prog`` under ``hw``'s O3 knobs.

    Default is the compiled fast path (no ``ScheduledOp`` allocation);
    the timeline/critical-path detail is built on first access — pass
    ``detail=True`` to force the reference interpreter up front.
    """
    if detail:
        return schedule_reference(prog, hw, links_per_collective,
                                  compute_dtype, costed)
    from .compiled import compile_program, schedule_arrays
    cp = compile_program(prog, hw, links_per_collective, compute_dtype,
                         costed=costed)
    t_est, stall = schedule_arrays(cp, hw)
    return ScheduleResult(
        t_est=t_est,
        t_roofline=_roofline(cp.port_busy, hw.issue_width),
        t_serial=cp.t_serial,
        t_dataflow=cp.t_dataflow,
        port_busy=dict(cp.port_busy),
        n_ops=cp.n_ops,
        n_edges=cp.n_edges,
        stall_by_reason=stall,
        issue_width=dict(hw.issue_width),
        _detail=lambda: schedule_reference(prog, hw, links_per_collective,
                                           compute_dtype, costed),
    )


def schedule_reference(prog: Program, hw: HardwareSpec,
                       links_per_collective: int = 2,
                       compute_dtype: Optional[str] = None,
                       costed: Optional[List[Optional[OpTime]]] = None
                       ) -> ScheduleResult:
    """The per-op interpreter: same schedule as the fast path, plus the
    full timeline and binding-chain critical path.  The differential tests
    pin the fast path's ``t_est``/``port_busy``/stalls to this."""
    n = len(prog.ops)
    if costed is None:
        costed = cost_program(prog, hw, links_per_collective, compute_dtype)

    widths = hw.issue_width
    depths = hw.queue_depth
    window = max(1, hw.inflight_window)

    # port -> heap of (pipe_free_time, op_that_freed_it)
    pipes: Dict[str, List[Tuple[float, int]]] = {}
    port_hist: Dict[str, List[int]] = defaultdict(list)   # issued, per port
    finishes = [0.0] * n
    # in-order retirement: rtime[i] = time op i leaves the ROB, and the op
    # whose finish dominates it (for critical-path attribution)
    rtime: List[float] = []
    rtime_argmax: List[int] = []

    timeline: List[ScheduledOp] = []
    sched_of: Dict[int, ScheduledOp] = {}
    port_busy: Dict[str, float] = defaultdict(float)
    t_serial = 0.0
    n_ops = 0.0
    n_edges = 0
    stall: Dict[str, float] = defaultdict(float)

    for i, ot in enumerate(costed):
        if ot is None:
            # free op: propagate readiness through it at zero cost
            t_dep = max((finishes[j] for j in prog.ops[i].deps
                         if 0 <= j < i), default=0.0)
            finishes[i] = t_dep
            rtime.append(max(rtime[-1] if rtime else 0.0, t_dep))
            rtime_argmax.append(rtime_argmax[-1] if rtime_argmax else -1)
            continue
        o = ot.op
        dur = _duration(ot, hw)
        port = ot.port
        width = max(1, widths.get(port, 1))
        depth = max(1, depths.get(port, 1))
        if port not in pipes:
            pipes[port] = [(0.0, -1)] * width
            heapq.heapify(pipes[port])

        # --- constraint times
        ready, dep_src = 0.0, -1
        for j in o.deps:
            if 0 <= j < i:
                n_edges += 1
                if finishes[j] > ready:
                    ready, dep_src = finishes[j], j
        pipe_free, pipe_src = pipes[port][0]
        win_t, win_src = 0.0, -1
        if i >= window:
            win_t, win_src = rtime[i - window], rtime_argmax[i - window]
        q_t, q_src = 0.0, -1
        hist = port_hist[port]
        if len(hist) >= depth:
            q_src = hist[-depth]
            q_t = sched_of[q_src].start
        start, bound_by, bound_on = ready, ("dep" if dep_src >= 0
                                            else "ready"), dep_src
        for t, why, src in ((pipe_free, "port", pipe_src),
                            (win_t, "window", win_src),
                            (q_t, "queue", q_src)):
            if t > start:
                start, bound_by, bound_on = t, why, src
        finish = start + dur

        heapq.heapreplace(pipes[port], (finish, i))
        hist.append(i)
        finishes[i] = finish
        rt = max(rtime[-1] if rtime else 0.0, finish)
        rtime.append(rt)
        rtime_argmax.append(i if rt == finish else rtime_argmax[-1])

        s = ScheduledOp(i, o, port, start, finish, ready, bound_by, bound_on)
        sched_of[i] = s
        timeline.append(s)
        port_busy[port] += dur
        t_serial += dur
        n_ops += o.count
        if start > ready:
            stall[bound_by] += start - ready

    t_est = max((s.finish for s in timeline), default=0.0)

    # --- pure dataflow critical path (infinite resources lower bound)
    length = [0.0] * n
    for i, ot in enumerate(costed):
        d = _duration(ot, hw) if ot is not None else 0.0
        length[i] = d + max((length[j] for j in prog.ops[i].deps
                             if 0 <= j < i), default=0.0)
    t_dataflow = max(length, default=0.0)

    # --- walk the binding chain back from the makespan op
    critical: List[ScheduledOp] = []
    truncated = False
    if timeline:
        cur = max(timeline, key=lambda s: s.finish)
        seen = set()
        while cur is not None and cur.index not in seen:
            if len(critical) >= CRITICAL_PATH_LIMIT:
                truncated = True
                break
            seen.add(cur.index)
            critical.append(cur)
            cur = sched_of.get(cur.bound_on)
        critical.reverse()

    return ScheduleResult(
        t_est=t_est,
        t_roofline=_roofline(port_busy, widths),
        t_serial=t_serial,
        t_dataflow=t_dataflow,
        port_busy=dict(port_busy),
        n_ops=n_ops,
        n_edges=n_edges,
        stall_by_reason=dict(stall),
        issue_width=dict(widths),
        _timeline=timeline,
        _critical_path=critical,
        _cp_truncated=truncated,
    )
