"""The simulator core (DESIGN.md §§2-16).

HLO parsing (``hlo``), the unified cost pipeline (``cost``, ``memory``),
the three engines (``engine`` occupancy, ``schedule``/``compiled`` O3,
``node`` multi-core), hardware parameter files (``hwspec``), calibration
(``calibrate``), the model-zoo pipeline (``zoo``), and reporting
(``roofline``, ``pa``, ``simulate``).
"""
