"""Serving-at-scale simulator: continuous batching over the node engine.

The paper estimates *application* execution on unbuilt hardware well
enough for relative evaluation; the ROADMAP's millions-of-users target
needs that same machinery for the inference-serving regime.  This module
is a discrete-event serving simulator layered on the existing stack
(DESIGN.md §21):

* **Arrivals** — open-loop Poisson (:func:`poisson_requests`, per-model
  lognormal prompt/output length distributions from :data:`ZOO_TRAFFIC`)
  or a trace file (:func:`requests_from_trace` /
  :func:`load_trace_jsonl`).
* **Iteration costs** — a :class:`CostModel` prices each scheduler
  iteration.  :class:`ZooCostModel` (built by
  :func:`build_zoo_cost_model`) pulls per-phase node estimates from
  ``zoo.serving_cell_cost`` — the reduced trace through the contention-
  aware node engine, disk-cached per (arch, phase, batch) cell with the
  phase in the cache key — and scales them by the full/reduced layer
  ratio.  :class:`SyntheticCostModel` is the jax-free stand-in the test
  harness and the CI smoke drive.
* **KV residency** — per-request cache bytes come from the affine
  decomposition of ``serve/kvcache.cache_bytes``
  (``kv_token_bytes``: bytes/token + bytes/request, exact for every
  cache family including O(1) SSM state), and each decode step pays
  ``memory.stream_time`` for its batch's working set over a node-level
  hierarchy (:func:`node_kv_levels`): a batch that spills L2 streams
  from HBM2 — the KV-residency knee the throughput sweep exposes.
* **Scheduler** — iteration-level continuous batching
  (:func:`simulate_serving`) with :class:`ServingKnobs`: max batch,
  chunked prefill (0 = a prefill monopolizes the iteration and decode
  stalls), FCFS vs shortest-prompt admission, and a paged-KV policy
  (``reject`` reserves the full projected footprint at admission;
  ``evict-oldest``/``evict-newest`` admit optimistically and preempt a
  victim back to the queue — re-prefilling its prompt *plus* tokens
  generated so far — when decode growth overflows the pool).

``tests/test_serving.py`` pins the event loop differentially (closed-form
M/D/1 mean wait, a bit-identical batch-of-1 serial reference) and by
property (Little's law, percentile ordering, monotonicities, conservation,
determinism); ``benchmarks/serving_sweep.py`` emits ``BENCH_serving.json``
(schema: DESIGN.md §16) with TTFT/TPOT percentiles and tokens/s/node
Pareto fronts across batching policies.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .hwspec import A64FX_CMG, A64FX_NODE, HardwareSpec, NodeTopology
from .memory import MemLevel, stream_time

#: Decode-batch grid the zoo cost model traces (interpolated between).
DECODE_BATCH_GRID: Tuple[int, ...] = (1, 4, 16, 64)

#: Anti-thrash valve: a request evicted this many times is rejected
#: instead of re-queued (bounds the evict policies' worst case; see
#: :func:`simulate_serving`).
MAX_EVICTIONS_PER_REQUEST = 8


# ------------------------------------------------------------------ arrivals
@dataclass(frozen=True)
class LengthDist:
    """Lognormal prompt/output token-length distribution for one model.

    ``*_cv`` is the coefficient of variation (sigma/mean of the lognormal
    itself); ``cv <= 0`` degenerates to the constant ``round(mean)`` —
    the deterministic-service shape the M/D/1 differential test needs.
    Samples are clipped to ``[1, max_*]``.
    """
    prompt_mean: float
    prompt_cv: float
    out_mean: float
    out_cv: float
    max_prompt: int = 16_384
    max_out: int = 4_096

    @staticmethod
    def _sample(rng, n: int, mean: float, cv: float, hi: int):
        import numpy as np
        if cv <= 0:
            return np.full(n, max(1, round(mean)), dtype=np.int64)
        sigma2 = math.log(1.0 + cv * cv)
        mu = math.log(mean) - sigma2 / 2.0
        xs = rng.lognormal(mu, math.sqrt(sigma2), size=n)
        return np.clip(np.rint(xs).astype(np.int64), 1, hi)

    def sample(self, rng, n: int):
        """(prompt_lengths, out_lengths) as two int arrays of size n."""
        p = self._sample(rng, n, self.prompt_mean, self.prompt_cv,
                         self.max_prompt)
        o = self._sample(rng, n, self.out_mean, self.out_cv, self.max_out)
        return p, o


#: Per-model serving traffic: chat-style short contexts for the small
#: dense models, longer retrieval-style prompts for the big ones, long-
#: context summarization for the sub-quadratic SSM.  Anything not listed
#: falls back to :data:`DEFAULT_TRAFFIC` via :func:`traffic_for`.
ZOO_TRAFFIC: Dict[str, LengthDist] = {
    "chatglm3-6b": LengthDist(256, 0.8, 128, 0.6),
    "qwen1.5-32b": LengthDist(1024, 0.8, 256, 0.6),
    "llama4-scout-17b-a16e": LengthDist(2048, 1.0, 256, 0.6),
    "mamba2-1.3b": LengthDist(4096, 1.0, 128, 0.6),
    "grok-1-314b": LengthDist(1024, 1.0, 256, 0.6),
    "nemotron-4-340b": LengthDist(1024, 1.0, 256, 0.6),
}

DEFAULT_TRAFFIC = LengthDist(512, 0.8, 128, 0.6)


def traffic_for(arch: str) -> LengthDist:
    """The length distribution for ``arch`` (registry fallback)."""
    return ZOO_TRAFFIC.get(arch, DEFAULT_TRAFFIC)


@dataclass(frozen=True)
class RequestSpec:
    """One serving request: arrival time + prompt/output token counts."""
    rid: int
    t_arrival: float
    prompt_tokens: int
    out_tokens: int


def poisson_requests(n: int, rate: float, lengths: LengthDist,
                     seed: int = 0) -> List[RequestSpec]:
    """``n`` open-loop Poisson arrivals at ``rate`` requests/s with
    lengths drawn from ``lengths`` — fixed-``seed`` deterministic (the
    suite pins bit-equality across calls)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    ts = np.cumsum(rng.exponential(1.0 / rate, size=n))
    ps, os_ = lengths.sample(rng, n)
    return [RequestSpec(i, float(ts[i]), int(ps[i]), int(os_[i]))
            for i in range(n)]


def requests_from_trace(rows: Iterable[dict]) -> List[RequestSpec]:
    """Build requests from trace rows: mappings with ``t_arrival``,
    ``prompt_tokens``, ``out_tokens`` (``rid`` defaults to row order)."""
    out = []
    for i, r in enumerate(rows):
        out.append(RequestSpec(
            rid=int(r.get("rid", i)),
            t_arrival=float(r["t_arrival"]),
            prompt_tokens=int(r["prompt_tokens"]),
            out_tokens=int(r["out_tokens"])))
    return out


def load_trace_jsonl(path: Path) -> List[RequestSpec]:
    """Read a request trace from a JSONL file (one row per line)."""
    import json
    rows = [json.loads(line) for line in
            Path(path).read_text().splitlines() if line.strip()]
    return requests_from_trace(rows)


# ---------------------------------------------------------------- cost models
def node_kv_levels(hw: HardwareSpec = A64FX_CMG,
                   topology: NodeTopology = A64FX_NODE
                   ) -> Tuple[MemLevel, ...]:
    """Node-aggregate hierarchy for KV-cache streaming: every shared
    level of ``hw.mem_levels`` (those with a ``topology`` aggregate-
    bandwidth entry) scaled to the whole node — for the A64FX, 4 CMGs
    give a 32 MiB L2 at 3.6 TB/s over a 32 GiB HBM2 at 1.024 TB/s.
    Core-private levels (L1D) are skipped: a KV working set never
    persists there across decode steps."""
    out = []
    for lv in hw.mem_levels:
        if lv.name not in topology.shared_read_bw:
            continue
        out.append(MemLevel(
            lv.name, lv.capacity * topology.n_cmgs,
            topology.shared_read_bw[lv.name] * topology.n_cmgs,
            topology.shared_write_bw.get(
                lv.name, topology.shared_read_bw[lv.name])
            * topology.n_cmgs,
            lv.latency_s))
    if not out:
        raise ValueError("no shared levels in hw/topology pair")
    return tuple(out)


@dataclass
class CostModel:
    """Base iteration-cost model for :func:`simulate_serving`.

    Subclasses supply ``prefill_time`` (seconds to process N prompt
    tokens) and ``decode_compute_time`` (seconds for one decode step over
    a batch).  The base class owns the KV accounting: ``kv_bytes`` is the
    affine footprint of a request set, and ``decode_step_time`` is the
    max of compute and streaming the step's KV working set through
    ``levels`` (``memory.stream_time`` — the residency model that makes
    HBM-spilling batches pay real bandwidth).  ``kv_capacity`` bounds the
    paged-KV pool the scheduler allocates from.
    """
    bytes_per_token: float = 0.0
    bytes_per_request: float = 0.0
    levels: Tuple[MemLevel, ...] = ()
    kv_capacity: float = math.inf

    def __post_init__(self):
        if not self.levels:
            self.levels = node_kv_levels()

    def prefill_time(self, tokens: int) -> float:
        """Seconds to prefill ``tokens`` prompt tokens."""
        raise NotImplementedError

    def decode_compute_time(self, batch: int) -> float:
        """Compute seconds for one decode step over ``batch`` sequences."""
        raise NotImplementedError

    def kv_bytes(self, n_requests: int, total_tokens: int) -> float:
        """KV footprint of ``n_requests`` holding ``total_tokens``."""
        return (n_requests * self.bytes_per_request
                + total_tokens * self.bytes_per_token)

    def decode_step_time(self, batch: int, kv_bytes: float) -> float:
        """One decode step: max(compute, KV streaming at residency bw)."""
        tc = self.decode_compute_time(batch)
        tm = stream_time(self.levels, kv_bytes)
        return tc if tc >= tm else tm


@dataclass
class SyntheticCostModel(CostModel):
    """Closed-form affine cost table — the jax-free reference model.

    ``prefill_time = prefill_t0 + prefill_per_token * tokens``;
    ``decode_compute_time = decode_t0 + decode_per_seq * batch``.  With
    ``bytes_per_token == 0`` service times are deterministic, which is
    exactly the M/D/1 shape the differential suite compares against.
    """
    prefill_t0: float = 0.0
    prefill_per_token: float = 1e-5
    decode_t0: float = 1e-4
    decode_per_seq: float = 1e-5

    def prefill_time(self, tokens: int) -> float:
        return self.prefill_t0 + self.prefill_per_token * tokens

    def decode_compute_time(self, batch: int) -> float:
        return self.decode_t0 + self.decode_per_seq * batch


@dataclass
class ZooCostModel(CostModel):
    """Iteration costs from the zoo's node-engine estimates.

    ``decode_grid`` holds (batch, seconds) cells from
    ``zoo.serving_cell_cost`` — full-depth seconds (reduced-trace t_est
    x the full/reduced layer ratio) — piecewise-linearly interpolated in
    batch and extrapolated beyond the last cell with its final slope.
    Build with :func:`build_zoo_cost_model`.
    """
    arch: str = ""
    prefill_per_token: float = 0.0
    decode_grid: Tuple[Tuple[int, float], ...] = ((1, 1e-3),)
    layer_scale: int = 1

    def prefill_time(self, tokens: int) -> float:
        return self.prefill_per_token * tokens

    def decode_compute_time(self, batch: int) -> float:
        g = self.decode_grid
        if batch <= g[0][0] or len(g) == 1:
            return g[0][1]
        for (b0, t0), (b1, t1) in zip(g, g[1:]):
            if batch <= b1:
                return t0 + (t1 - t0) * (batch - b0) / (b1 - b0)
        (b0, t0), (b1, t1) = g[-2], g[-1]
        return t1 + (t1 - t0) / (b1 - b0) * (batch - b1)


def build_zoo_cost_model(arch: str, n_cores: int = 48,
                         hw: Optional[HardwareSpec] = None,
                         topology: Optional[NodeTopology] = None,
                         batch_grid: Sequence[int] = DECODE_BATCH_GRID,
                         param_dtype: str = "float32",
                         compute_dtype: str = "f32",
                         hlo_cache_dir: Optional[Path] = None,
                         cost_cache_dir: Optional[Path] = None
                         ) -> ZooCostModel:
    """Price one zoo architecture for serving via the node engine.

    Prefill seconds/token come from the reduced prefill trace at batch 1;
    decode seconds per step are traced at each ``batch_grid`` cell (the
    decode shape with its global batch swept).  Both are scaled by the
    full/reduced layer-count ratio (``zoo.long_trace_repeats``), so
    iteration times are full-depth estimates in reduced-width units —
    and, consistently, KV bytes/token come from the FULL config's real
    cache tree (``kvcache.kv_token_bytes`` against the node HBM pool),
    the same units note as the cluster engine's (DESIGN.md §20).  Every
    (arch, phase, batch) cell is disk-cached with the phase in the key
    (``zoo.serving_cell_cost``).
    """
    import dataclasses as dc

    import jax.numpy as jnp

    from ..configs import ARCHS
    from ..configs.shapes import ZOO_DECODE, ZOO_PREFILL
    from ..models.lm import build_model
    from ..serve.kvcache import kv_token_bytes
    from . import zoo
    from .hwspec import A64FX_CORE
    hw = hw or A64FX_CORE
    topo = topology or hw.topology or A64FX_NODE
    scale = zoo.long_trace_repeats(arch, "prefill")
    pre_shape = dc.replace(ZOO_PREFILL, name="serve_prefill",
                           global_batch=1)
    t_pre = zoo.serving_cell_cost(
        arch, "prefill", pre_shape, n_cores, hw, topo, compute_dtype,
        param_dtype, hlo_cache_dir, cost_cache_dir) * scale
    grid = []
    for b in batch_grid:
        sh = dc.replace(ZOO_DECODE, name=f"serve_decode_b{b}",
                        global_batch=int(b))
        t = zoo.serving_cell_cost(
            arch, "decode", sh, n_cores, hw, topo, compute_dtype,
            param_dtype, hlo_cache_dir, cost_cache_dir) * scale
        grid.append((int(b), t))
    model = build_model(ARCHS[arch])
    per_tok, per_req = kv_token_bytes(model, jnp.bfloat16)
    levels = node_kv_levels(A64FX_CMG, topo)
    return ZooCostModel(
        arch=arch, prefill_per_token=t_pre / pre_shape.seq_len,
        decode_grid=tuple(sorted(grid)), layer_scale=scale,
        bytes_per_token=per_tok, bytes_per_request=per_req,
        levels=levels, kv_capacity=levels[-1].capacity)


# ------------------------------------------------------------------ scheduler
@dataclass(frozen=True)
class ServingKnobs:
    """Scheduler policy knobs — the serving sweep's axes.

    ``max_batch`` caps concurrent slots; ``prefill_chunk`` is the prompt
    tokens one iteration may prefill (0 = whole prompt, decode stalls);
    ``admission`` is ``fcfs`` or ``spf`` (shortest prompt first);
    ``eviction`` is ``reject`` (reserve the full projected KV footprint
    at admission, reject requests that can never fit) or
    ``evict-oldest``/``evict-newest`` (optimistic admission, preempt a
    victim when decode growth overflows the pool).
    """
    max_batch: int = 8
    admission: str = "fcfs"
    prefill_chunk: int = 0
    eviction: str = "reject"

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.admission not in ("fcfs", "spf"):
            raise ValueError(f"unknown admission {self.admission!r}")
        if self.eviction not in ("reject", "evict-oldest", "evict-newest"):
            raise ValueError(f"unknown eviction {self.eviction!r}")
        if self.prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0")

    @property
    def label(self) -> str:
        """Short sweep label, e.g. ``spf_b32_chunk256_evict-oldest``."""
        parts = [self.admission, f"b{self.max_batch}"]
        if self.prefill_chunk:
            parts.append(f"chunk{self.prefill_chunk}")
        if self.eviction != "reject":
            parts.append(self.eviction)
        return "_".join(parts)


@dataclass
class RequestStats:
    """Per-request outcome: admission, first token, completion times."""
    spec: RequestSpec
    t_admit: float = math.inf
    t_first: float = math.inf
    t_done: float = math.inf
    t_reject: float = math.inf
    n_evictions: int = 0

    @property
    def completed(self) -> bool:
        return math.isfinite(self.t_done)

    @property
    def rejected(self) -> bool:
        return math.isfinite(self.t_reject)

    @property
    def ttft(self) -> float:
        """Time to first token (arrival -> first emission)."""
        return self.t_first - self.spec.t_arrival

    @property
    def wait(self) -> float:
        """Queueing delay (arrival -> first admission)."""
        return self.t_admit - self.spec.t_arrival

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first (0 if out == 1)."""
        if self.spec.out_tokens <= 1:
            return 0.0
        return (self.t_done - self.t_first) / (self.spec.out_tokens - 1)

    @property
    def sojourn(self) -> float:
        """Total time in system (arrival -> completion or rejection)."""
        leave = self.t_done if self.completed else self.t_reject
        return leave - self.spec.t_arrival


@dataclass
class _Run:
    """One active slot: prefill progress + generated-token count."""
    idx: int                    # index into the sorted request list
    prefill_target: int         # tokens to prefill (prompt [+ regen])
    done_prompt: int = 0
    generated: int = 0
    admit_seq: int = 0          # monotone admission counter


def percentile(xs: Sequence[float], q: float) -> float:
    """Numpy-style linear-interpolation percentile (``q`` in [0, 100])."""
    s = sorted(xs)
    if not s:
        return math.nan
    k = (len(s) - 1) * q / 100.0
    f = math.floor(k)
    c = min(f + 1, len(s) - 1)
    return s[f] + (s[c] - s[f]) * (k - f)


@dataclass
class ServingResult:
    """One serving run: per-request stats + aggregate counters.

    ``area_in_system`` is the event-loop-integrated ``int N(t) dt``
    (requests in system over time) — accumulated *independently* of the
    per-request timestamps, so the Little's-law identity
    ``area == sum(sojourn)`` is a real bookkeeping invariant, not a
    tautology.  :meth:`metrics` derives the BENCH row.
    """
    knobs: ServingKnobs
    stats: List[RequestStats] = field(default_factory=list)
    t_start: float = 0.0
    t_end: float = 0.0
    n_iterations: int = 0
    n_prefill_iterations: int = 0
    n_decode_iterations: int = 0
    n_evictions: int = 0
    sum_decode_batch: int = 0
    area_in_system: float = 0.0
    max_kv_bytes: float = 0.0

    def done(self) -> List[RequestStats]:
        """Completed requests (the SLO population)."""
        return [st for st in self.stats if st.completed]

    def ttfts(self) -> List[float]:
        return [st.ttft for st in self.done()]

    def tpots(self) -> List[float]:
        return [st.tpot for st in self.done()
                if st.spec.out_tokens > 1]

    @property
    def duration(self) -> float:
        return max(self.t_end - self.t_start, 1e-30)

    @property
    def tokens_out(self) -> int:
        return sum(st.spec.out_tokens for st in self.done())

    @property
    def tokens_per_s(self) -> float:
        """Output tokens per second per node over the whole run."""
        return self.tokens_out / self.duration

    def little_law_gap(self) -> float:
        """Relative gap between the integrated mean number-in-system and
        ``lambda * W`` over the run — ~1e-15 when the loop's bookkeeping
        is exact (every request leaves, so the two sides are the same
        integral accumulated two different ways)."""
        left = [st for st in self.stats
                if st.completed or st.rejected]
        if not left:
            return 0.0
        mean_l = self.area_in_system / self.duration
        lam = len(left) / self.duration
        w = sum(st.sojourn for st in left) / len(left)
        return abs(mean_l - lam * w) / max(mean_l, 1e-30)

    def metrics(self) -> dict:
        """The per-(model, policy) BENCH_serving row (DESIGN.md §16)."""
        ttfts, tpots = self.ttfts(), self.tpots()
        nd = max(self.n_decode_iterations, 1)
        return {
            "completed": len(self.done()),
            "rejected": sum(1 for st in self.stats if st.rejected),
            "n_evictions": self.n_evictions,
            "p50_ttft_ms": percentile(ttfts, 50) * 1e3,
            "p99_ttft_ms": percentile(ttfts, 99) * 1e3,
            "p50_tpot_ms": (percentile(tpots, 50) * 1e3
                            if tpots else 0.0),
            "p99_tpot_ms": (percentile(tpots, 99) * 1e3
                            if tpots else 0.0),
            "mean_wait_ms": (sum(st.wait for st in self.done())
                             / max(len(self.done()), 1) * 1e3),
            "tokens_per_s": self.tokens_per_s,
            "mean_decode_batch": self.sum_decode_batch / nd,
            "mean_in_system": self.area_in_system / self.duration,
            "little_law_gap": self.little_law_gap(),
            "max_kv_gb": self.max_kv_bytes / 2**30,
            "duration_s": self.duration,
        }


def _run_bytes(cost: CostModel, run: _Run) -> float:
    return cost.kv_bytes(1, run.done_prompt + run.generated)


def simulate_serving(requests: Sequence[RequestSpec], cost: CostModel,
                     knobs: ServingKnobs) -> ServingResult:
    """Run the continuous-batching event loop over ``requests``.

    Iteration semantics (the Orca/vLLM-style loop, DESIGN.md §21):

    1. arrivals with ``t_arrival <= t`` join the wait queue; when the
       system is idle, ``t`` jumps to the next arrival;
    2. admission fills slots up to ``max_batch`` per the admission knob,
       with KV accounting per the eviction knob (see
       :class:`ServingKnobs`); requests whose footprint can never fit
       the pool alone are rejected (terminally);
    3. under the evict policies, if actual KV bytes overflow the pool
       the victim (newest/oldest admission) is preempted back to the
       queue front and must re-prefill its prompt plus the tokens it
       already generated (emitted tokens are not re-emitted); a request
       evicted :data:`MAX_EVICTIONS_PER_REQUEST` times is rejected —
       the anti-thrash valve that bounds the loop;
    4. the iteration runs: with an unchunked prefill pending, that one
       prefill monopolizes the iteration (decode stalls — the TTFT/TPOT
       tension the chunk knob trades); with ``prefill_chunk > 0``, up to
       that many prompt tokens prefill while the decode-ready set
       advances one token in the same iteration; otherwise one decode
       step over the ready set, priced by
       :meth:`CostModel.decode_step_time` on the set's KV working set;
    5. a request emits its first token when its prompt completes and one
       token per decode step after; at ``out_tokens`` it completes and
       frees its KV.

    Determinism: the loop is pure over (requests, cost, knobs) — no RNG —
    so fixed-seed arrival generators give bit-identical results, and at
    ``max_batch=1`` with whole-prompt prefill the float-op sequence
    degenerates exactly to the serial reference the differential test
    replays.
    """
    reqs = sorted(requests, key=lambda r: (r.t_arrival, r.rid))
    n = len(reqs)
    res = ServingResult(knobs=knobs,
                        stats=[RequestStats(spec=r) for r in reqs])
    if n == 0:
        return res
    res.t_start = reqs[0].t_arrival
    optimistic = knobs.eviction != "reject"
    queue: List[int] = []       # waiting indices, FCFS order
    active: List[_Run] = []
    i = 0                       # next arrival to ingest
    t = 0.0
    committed = 0.0             # reserved bytes (reject policy)
    admit_seq = 0
    n_left = n                  # not yet completed/rejected

    def projected(k: int) -> float:
        r = reqs[k]
        return cost.kv_bytes(1, r.prompt_tokens + r.out_tokens)

    def optimistic_bytes(k: int) -> float:
        # the scheduler cannot see out_tokens (realistic optimism): it
        # reserves prompt (+ tokens to re-prefill after eviction) + 1
        return cost.kv_bytes(
            1, reqs[k].prompt_tokens + _regen_of(res, k) + 1)

    while n_left > 0:
        if not active and not queue:
            # idle: jump to the next arrival
            if reqs[i].t_arrival > t:
                t = reqs[i].t_arrival
        while i < n and reqs[i].t_arrival <= t:
            queue.append(i)
            i += 1

        # ---------------------------------------------------- admission
        while queue and len(active) < knobs.max_batch:
            if knobs.admission == "spf":
                qi = min(range(len(queue)),
                         key=lambda j: (reqs[queue[j]].prompt_tokens,
                                        queue[j]))
            else:
                qi = 0
            k = queue[qi]
            if optimistic:
                current = sum(_run_bytes(cost, r) for r in active)
                need = optimistic_bytes(k)
            else:
                current = committed
                need = projected(k)
            if current + need > cost.kv_capacity:
                if need > cost.kv_capacity:
                    # can never fit even alone: terminal rejection
                    queue.pop(qi)
                    res.stats[k].t_reject = t
                    n_left -= 1
                    continue
                break           # head-of-line blocks until space frees
            queue.pop(qi)
            st = res.stats[k]
            if st.t_admit > t:
                st.t_admit = t
            target = reqs[k].prompt_tokens + _regen_of(res, k)
            active.append(_Run(idx=k, prefill_target=target,
                               admit_seq=admit_seq))
            admit_seq += 1
            if not optimistic:
                committed += need

        if not active:
            continue            # everything rejected/blocked; loop jumps

        # ----------------------------------------------- eviction pass
        if optimistic and len(active) > 1:
            while len(active) > 1:
                cur = sum(_run_bytes(cost, r) for r in active)
                if cur <= cost.kv_capacity:
                    break
                pick = (max if knobs.eviction == "evict-newest"
                        else min)(active, key=lambda r: r.admit_seq)
                active.remove(pick)
                st = res.stats[pick.idx]
                st.n_evictions += 1
                res.n_evictions += 1
                if st.n_evictions > MAX_EVICTIONS_PER_REQUEST:
                    st.t_reject = t
                    n_left -= 1
                else:
                    _set_regen(res, pick.idx, pick.generated)
                    queue.insert(0, pick.idx)

        # ------------------------------------------- build the iteration
        pending = [r for r in active if r.done_prompt < r.prefill_target]
        ready = [r for r in active
                 if r.done_prompt >= r.prefill_target
                 and r.generated < reqs[r.idx].out_tokens]
        dt = 0.0
        finished_prefill: List[_Run] = []
        decoded: List[_Run] = []
        if pending and knobs.prefill_chunk == 0:
            run = pending[0]
            take = run.prefill_target - run.done_prompt
            run.done_prompt = run.prefill_target
            dt = cost.prefill_time(take)
            finished_prefill.append(run)
            res.n_prefill_iterations += 1
        else:
            taken = 0
            if pending:
                budget = knobs.prefill_chunk
                for run in pending:
                    room = budget - taken
                    if room <= 0:
                        break
                    step = min(room, run.prefill_target - run.done_prompt)
                    run.done_prompt += step
                    taken += step
                    if run.done_prompt >= run.prefill_target:
                        finished_prefill.append(run)
                dt += cost.prefill_time(taken)
                res.n_prefill_iterations += 1
            if ready:
                tokens = 0
                for run in ready:
                    tokens += run.done_prompt + run.generated
                kv = cost.kv_bytes(len(ready), tokens)
                dt += cost.decode_step_time(len(ready), kv)
                decoded = ready
                res.n_decode_iterations += 1
                res.sum_decode_batch += len(ready)

        t_next = t + dt
        res.n_iterations += 1

        # exact N(t) integration: everyone in system over [t, t_next),
        # plus partial spans of arrivals landing inside the iteration
        res.area_in_system += (len(active) + len(queue)) * dt
        j = i
        while j < n and reqs[j].t_arrival <= t_next:
            res.area_in_system += t_next - reqs[j].t_arrival
            j += 1
        t = t_next

        # ------------------------------------------------ apply effects
        for run in finished_prefill:
            st = res.stats[run.idx]
            if run.generated == 0:
                run.generated = 1
                if st.t_first > t:
                    st.t_first = t
        for run in decoded:
            run.generated += 1
        done_now = [r for r in active
                    if r.done_prompt >= r.prefill_target
                    and r.generated >= reqs[r.idx].out_tokens]
        for run in done_now:
            active.remove(run)
            res.stats[run.idx].t_done = t
            n_left -= 1
            if not optimistic:
                committed -= projected(run.idx)
        cur_bytes = sum(_run_bytes(cost, r) for r in active)
        if cur_bytes > res.max_kv_bytes:
            res.max_kv_bytes = cur_bytes

    res.t_end = t
    return res


# regenerated-token bookkeeping for evicted requests: the re-prefill must
# cover prompt + tokens generated before eviction (kept off RequestStats
# so the public stats stay purely observational)
_REGEN_KEY = "_regen_tokens"


def _set_regen(res: ServingResult, idx: int, generated: int) -> None:
    setattr(res.stats[idx], _REGEN_KEY, generated)


def _regen_of(res: ServingResult, idx: int) -> int:
    return getattr(res.stats[idx], _REGEN_KEY, 0)


# -------------------------------------------------------------- pareto front
def pareto_front(points: Sequence[Tuple[float, float]]) -> List[int]:
    """Indices of the non-dominated points, both coordinates minimized
    (the bench reports (p99 TTFT, -tokens/s) fronts per model)."""
    out = []
    for a, pa in enumerate(points):
        dominated = False
        for b, pb in enumerate(points):
            if b != a and pb[0] <= pa[0] and pb[1] <= pa[1] \
                    and (pb[0] < pa[0] or pb[1] < pa[1]):
                dominated = True
                break
        if not dominated:
            out.append(a)
    return out
