"""Compiled array-form scheduling core — the simulator's hot path, SoA.

``schedule_program`` was a pure-Python per-op interpreter over ``OpStat``
dataclasses (~75k scheduled ops/s on the kernel-suite bench).  The paper's
whole premise is that a tuned pipeline simulator must be *fast enough* to
sweep OoO resource parameters against a test chip — so the costed program
is compiled ONCE per ``(Program, HardwareSpec, dtype)`` into a
structure-of-arrays :class:`CompiledProgram` (durations, port ids, CSR
def-use edges, packed O3 knobs) and every downstream consumer runs on it:

* :func:`schedule_arrays` — the fast scalar kernel: ``t_est`` /
  ``port_busy`` / ``stall_by_reason`` with zero ``ScheduledOp``
  allocations (the knob-independent invariants ``t_serial`` /
  ``t_dataflow`` / ``port_busy`` / ``n_edges`` are precomputed at compile
  time and simply carried),
* :func:`schedule_batch` — the batched sweep engine: the whole O3 knob
  grid is a batch axis; one sequential pass over the ops advances every
  knob combination in lockstep with NumPy vector ops, so enlarging the
  grid (windows up to 1024, per-port widths) is ~free,
* :func:`schedule_batch_jax` — the same in-order list scheduler as a
  ``jax.lax.scan`` (``vmap``-ed over the knob axis and ``jit``-ed), so
  the simulator itself can run on the accelerator it models.

Every kernel replays the reference scheduler's float operations in the
same order, so ``t_est`` is bit-identical to ``core.schedule``'s
interpreter — asserted by the differential tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost import OpTime, cost_program
from .hlo import Program
from .hwspec import HardwareSpec

# global port-id mapping; core.cost only ever emits these four
PORTS: Tuple[str, ...] = ("mxu", "vpu", "mem", "ici")
_PORT_ID = {p: i for i, p in enumerate(PORTS)}
_COMPILE_CACHE_SIZE = 8


@dataclass
class O3Knobs:
    """A batch of packed O3 knob combinations (the grid's batch axis)."""
    window: np.ndarray           # [B] int64, already clamped >= 1
    width: np.ndarray            # [B, len(PORTS)] int64, clamped >= 1
    depth: np.ndarray            # [B, len(PORTS)] int64, clamped >= 1

    @property
    def batch(self) -> int:
        return len(self.window)

    @classmethod
    def from_specs(cls, specs: Sequence[HardwareSpec]) -> "O3Knobs":
        b = len(specs)
        window = np.empty(b, dtype=np.int64)
        width = np.empty((b, len(PORTS)), dtype=np.int64)
        depth = np.empty((b, len(PORTS)), dtype=np.int64)
        for i, hw in enumerate(specs):
            window[i] = max(1, hw.inflight_window)
            for p, pid in _PORT_ID.items():
                width[i, pid] = max(1, hw.issue_width.get(p, 1))
                depth[i, pid] = max(1, hw.queue_depth.get(p, 1))
        return cls(window, width, depth)

    @classmethod
    def single(cls, hw: HardwareSpec) -> "O3Knobs":
        return cls.from_specs([hw])

    @classmethod
    def from_grid(cls, hw: HardwareSpec,
                  combos: Sequence[Tuple[int, int, int, int]]) -> "O3Knobs":
        """Pack a (window, mem_width, vpu_width, queue_depth) grid around
        ``hw``'s remaining knobs WITHOUT materializing a HardwareSpec per
        combo (the sweep's grid is just integers)."""
        b = len(combos)
        window = np.empty(b, dtype=np.int64)
        width = np.empty((b, len(PORTS)), dtype=np.int64)
        depth = np.empty((b, len(PORTS)), dtype=np.int64)
        for p, pid in _PORT_ID.items():
            width[:, pid] = max(1, hw.issue_width.get(p, 1))
        for i, (w, mw, vw, qd) in enumerate(combos):
            window[i] = max(1, w)
            width[i, _PORT_ID["mem"]] = max(1, mw)
            width[i, _PORT_ID["vpu"]] = max(1, vw)
            depth[i, :] = max(1, qd)
        return cls(window, width, depth)

    def unique(self) -> Tuple["O3Knobs", np.ndarray]:
        """Deduplicated knob rows + the inverse map back to the full grid.

        The ``max(1, ·)`` clamps in the constructors collapse distinct
        grid points into identical combos (e.g. every window <= 1), and
        batched sweeps would schedule those rows redundantly.  Returns
        ``(uk, inv)`` with ``uk`` in FIRST-OCCURRENCE order (so argmin
        tie-breaking downstream matches the undeduped grid) and
        ``full_result = unique_result[inv]``.  Identity (``self``,
        arange) when every row is already distinct.
        """
        b = self.batch
        rows = np.concatenate(
            [self.window[:, None], self.width, self.depth], axis=1)
        _, first, inv = np.unique(rows, axis=0, return_index=True,
                                  return_inverse=True)
        inv = inv.reshape(-1)          # numpy 2.x keeps the extra axis
        if len(first) == b:
            return self, np.arange(b)
        # np.unique sorts rows; restore first-occurrence order
        order = np.argsort(first, kind="stable")
        rank = np.empty_like(order)
        rank[order] = np.arange(len(order))
        sel = first[order]
        return (O3Knobs(self.window[sel], self.width[sel], self.depth[sel]),
                rank[inv])


@dataclass
class CompiledProgram:
    """Structure-of-arrays form of one costed program.

    Arrays are aligned with ``Program.ops``; ops the cost model does not
    charge carry ``port_id == -1`` and zero duration (they still occupy a
    ROB slot and propagate readiness, exactly like the interpreter).
    Everything the O3 knobs canNOT change is precomputed here once:
    ``t_serial``, ``t_dataflow``, ``port_busy``, ``n_ops``, ``n_edges``.
    """
    n: int
    durations: np.ndarray        # [n] f64: (max(t_c,t_m,t_i)+startup)*count
    port_id: np.ndarray          # [n] int8 into PORTS; -1 = uncosted
    dep_indptr: np.ndarray       # [n+1] CSR over valid (j < i) edges
    dep_indices: np.ndarray      # [E]
    pos_in_port: np.ndarray      # [n] running issue index on the op's port
    port_counts: np.ndarray      # [len(PORTS)] ops issued per port
    # knob-independent schedule invariants
    t_serial: float
    t_dataflow: float
    n_ops: float
    n_edges: int
    port_busy: Dict[str, float]
    knobs: O3Knobs               # packed from the compiling HardwareSpec
    # python-list mirrors (scalar kernel: list indexing beats ndarray)
    _dur_l: list = field(default_factory=list, repr=False)
    _port_l: list = field(default_factory=list, repr=False)
    _indptr_l: list = field(default_factory=list, repr=False)
    _indices_l: list = field(default_factory=list, repr=False)


def compile_program(prog: Program, hw: HardwareSpec,
                    links_per_collective: int = 2,
                    compute_dtype: Optional[str] = None,
                    costed: Optional[List[Optional[OpTime]]] = None
                    ) -> CompiledProgram:
    """Compile (and memoize on the Program) the SoA form.

    The cache is keyed by ``(hw VALUE, dtype, links)``: the frozen spec
    compares by field values, so an O3-knob sweep that rebuilds a
    value-equal spec (``dataclasses.replace`` / ``with_`` round trips)
    still hits the cache and the grid shares one CompiledProgram.  Specs
    that differ in any field get their own entry (durations could differ
    via ``op_startup_ns``).

    A caller-supplied ``costed`` list bypasses the cache entirely (no
    lookup, no store): the caller may have edited the costs, and the key
    cannot see that.
    """
    if costed is None:
        cache = prog.__dict__.setdefault("_compiled_cache", [])
        for chw, cdt, clk, ccp in cache:
            if cdt == compute_dtype and clk == links_per_collective \
                    and chw == hw:
                return ccp
        costed = cost_program(prog, hw, links_per_collective, compute_dtype)
    else:
        cache = None

    n = len(prog.ops)
    startup = hw.op_startup_ns * 1e-9
    durations = np.zeros(n, dtype=np.float64)
    port_id = np.full(n, -1, dtype=np.int8)
    pos_in_port = np.zeros(n, dtype=np.int64)
    port_counts = np.zeros(len(PORTS), dtype=np.int64)
    indptr = np.zeros(n + 1, dtype=np.int64)
    indices: List[int] = []
    port_busy: Dict[str, float] = {}
    t_serial = 0.0
    n_ops = 0.0
    n_edges = 0

    for i, ot in enumerate(costed):
        o = prog.ops[i]
        for j in o.deps:
            if 0 <= j < i:
                indices.append(j)
        indptr[i + 1] = len(indices)
        if ot is None:
            continue
        # same float-op order as the interpreter: per-instance max + startup,
        # times the (loop-trip) count
        dur = (max(ot.t_compute, ot.t_mem, ot.t_ici) + startup) * o.count
        pid = _PORT_ID[ot.port]
        durations[i] = dur
        port_id[i] = pid
        pos_in_port[i] = port_counts[pid]
        port_counts[pid] += 1
        port_busy[ot.port] = port_busy.get(ot.port, 0.0) + dur
        t_serial += dur
        n_ops += o.count
        n_edges += int(indptr[i + 1] - indptr[i])

    # pure dataflow critical path (infinite resources lower bound) is
    # knob-independent: precompute once
    length = [0.0] * n
    idx_l = indices
    ptr_l = indptr.tolist()
    dur_l = durations.tolist()
    for i in range(n):
        best = 0.0
        for k in range(ptr_l[i], ptr_l[i + 1]):
            v = length[idx_l[k]]
            if v > best:
                best = v
        length[i] = dur_l[i] + best
    t_dataflow = max(length, default=0.0)

    cp = CompiledProgram(
        n=n, durations=durations, port_id=port_id,
        dep_indptr=indptr, dep_indices=np.array(indices, dtype=np.int64),
        pos_in_port=pos_in_port, port_counts=port_counts,
        t_serial=t_serial, t_dataflow=t_dataflow, n_ops=n_ops,
        n_edges=n_edges, port_busy=port_busy,
        knobs=O3Knobs.single(hw),
        _dur_l=dur_l, _port_l=port_id.tolist(),
        _indptr_l=ptr_l, _indices_l=idx_l,
    )
    if cache is not None:
        cache.append((hw, compute_dtype, links_per_collective, cp))
        if len(cache) > _COMPILE_CACHE_SIZE:
            cache.pop(0)
    return cp


# ------------------------------------------------------- fast scalar kernel
def schedule_arrays(cp: CompiledProgram, hw: HardwareSpec
                    ) -> Tuple[float, Dict[str, float]]:
    """One knob combination, no timeline: returns ``(t_est,
    stall_by_reason)``.  Bit-identical to the interpreter (same max/add
    sequence; the port 'heap' degenerates to min-of-list, which sees the
    same multiset of pipe-free times)."""
    widths = [max(1, hw.issue_width.get(p, 1)) for p in PORTS]
    depths = [max(1, hw.queue_depth.get(p, 1)) for p in PORTS]
    window = max(1, hw.inflight_window)

    durs = cp._dur_l
    ports = cp._port_l
    indptr = cp._indptr_l
    indices = cp._indices_l
    n = cp.n
    finishes = [0.0] * n
    rt = [0.0] * n
    rt_prev = 0.0
    pipes: List[Optional[List[float]]] = [None] * len(PORTS)
    hist: List[List[float]] = [[] for _ in PORTS]
    s_port = s_window = s_queue = 0.0
    t_est = 0.0

    for i in range(n):
        ready = 0.0
        for k in range(indptr[i], indptr[i + 1]):
            f = finishes[indices[k]]
            if f > ready:
                ready = f
        p = ports[i]
        if p < 0:
            # free op: propagate readiness through it at zero cost
            finishes[i] = ready
            if ready > rt_prev:
                rt_prev = ready
            rt[i] = rt_prev
            continue
        pl = pipes[p]
        if pl is None:
            pl = pipes[p] = [0.0] * widths[p]
        start = ready
        why = 0
        pf = min(pl)
        if pf > start:
            start, why = pf, 1
        if i >= window:
            wt = rt[i - window]
            if wt > start:
                start, why = wt, 2
        h = hist[p]
        d = depths[p]
        if len(h) >= d:
            qt = h[-d]
            if qt > start:
                start, why = qt, 3
        finish = start + durs[i]
        pl[pl.index(pf)] = finish
        h.append(start)
        finishes[i] = finish
        if finish > rt_prev:
            rt_prev = finish
        rt[i] = rt_prev
        if finish > t_est:
            t_est = finish
        if start > ready:
            d_t = start - ready
            if why == 1:
                s_port += d_t
            elif why == 2:
                s_window += d_t
            else:
                s_queue += d_t

    stall: Dict[str, float] = {}
    if s_port > 0:
        stall["port"] = s_port
    if s_window > 0:
        stall["window"] = s_window
    if s_queue > 0:
        stall["queue"] = s_queue
    return t_est, stall


# ------------------------------------------------------ batched numpy kernel
def schedule_batch(cp: CompiledProgram, knobs: O3Knobs,
                   backend: str = "numpy") -> np.ndarray:
    """Schedule every knob combination in ``knobs`` against the shared
    compiled program in ONE sequential pass over the ops (the knob grid is
    the vector axis of every state update).  Returns ``t_est`` per combo,
    bit-identical to running the scalar kernel per combination."""
    uk, inv = knobs.unique()
    if uk is not knobs:               # clamped grids alias rows: schedule
        return schedule_batch(cp, uk, backend)[inv]   # each combo once
    if backend == "jax":
        return schedule_batch_jax(cp, knobs)
    if backend != "numpy":
        raise ValueError(f"unknown schedule backend {backend!r}")
    B = knobs.batch
    n = cp.n
    t_est = np.zeros(B, dtype=np.float64)
    if n == 0 or B == 0:
        return t_est
    arange_b = np.arange(B)
    window = knobs.window
    finishes = np.zeros((n, B), dtype=np.float64)
    rt = np.zeros((n, B), dtype=np.float64)
    rt_prev = np.zeros(B, dtype=np.float64)
    # per-port pipes, padded to the batch's max width; lanes beyond a
    # combo's width start at +inf so min/argmin never picks them
    pipes: List[Optional[np.ndarray]] = [None] * len(PORTS)
    # per-port issue-start history: the op->port mapping is knob-independent,
    # so each port's history rows line up across the whole batch
    hist = [np.empty((int(c), B), dtype=np.float64) for c in cp.port_counts]
    hist_len = [0] * len(PORTS)

    indptr = cp.dep_indptr
    indices = cp.dep_indices
    ports = cp._port_l
    durs = cp._dur_l

    for i in range(n):
        lo, hi = indptr[i], indptr[i + 1]
        if hi > lo:
            ready = finishes[indices[lo:hi]].max(axis=0)
        else:
            ready = np.zeros(B, dtype=np.float64)
        p = ports[i]
        if p < 0:
            finishes[i] = ready
            np.maximum(rt_prev, ready, out=rt_prev)
            rt[i] = rt_prev
            continue
        pl = pipes[p]
        if pl is None:
            w = knobs.width[:, p]
            pl = np.where(np.arange(int(w.max()))[None, :] < w[:, None],
                          0.0, np.inf)
            pipes[p] = pl
        start = ready.copy()
        pf = pl.min(axis=1)
        np.maximum(start, pf, out=start)
        if i >= 1:
            idx = i - window
            valid = idx >= 0
            if valid.any():
                wt = np.where(valid, rt[np.clip(idx, 0, None), arange_b], 0.0)
                np.maximum(start, wt, out=start)
        h = hist[p]
        qidx = hist_len[p] - knobs.depth[:, p]
        qvalid = qidx >= 0
        if qvalid.any():
            qt = np.where(qvalid, h[np.clip(qidx, 0, None), arange_b], 0.0)
            np.maximum(start, qt, out=start)
        finish = start + durs[i]
        lane = pl.argmin(axis=1)
        pl[arange_b, lane] = finish
        h[hist_len[p]] = start
        hist_len[p] += 1
        finishes[i] = finish
        np.maximum(rt_prev, finish, out=rt_prev)
        rt[i] = rt_prev
        np.maximum(t_est, finish, out=t_est)
    return t_est


# --------------------------------------------------------- jax.lax.scan form
def schedule_batch_jax(cp: CompiledProgram, knobs: O3Knobs) -> np.ndarray:
    """The in-order list scheduler as a ``jax.lax.scan``, ``vmap``-ed over
    the knob batch and ``jit``-ed — the simulator running on the
    accelerator it models.  Pads the CSR edge lists to the max in-degree
    and the pipes/history state to the batch's max width/port counts.

    Runs in x64 so the result matches the NumPy kernels to float64
    precision; returns a NumPy array of ``t_est`` per combo.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    n = cp.n
    B = knobs.batch
    if n == 0 or B == 0:
        return np.zeros(B, dtype=np.float64)
    P = len(PORTS)
    indptr = cp.dep_indptr
    deg = np.diff(indptr)
    maxdeg = max(1, int(deg.max()) if n else 1)
    deps_pad = np.full((n, maxdeg), -1, dtype=np.int64)
    for i in range(n):
        lo, hi = indptr[i], indptr[i + 1]
        deps_pad[i, : hi - lo] = cp.dep_indices[lo:hi]
    port_eff = np.maximum(cp.port_id.astype(np.int64), 0)
    costed = cp.port_id >= 0
    max_hist = max(1, int(cp.port_counts.max()))
    wmax = max(1, int(knobs.width.max()))

    with enable_x64():
        xs = (jnp.asarray(cp.durations), jnp.asarray(port_eff),
              jnp.asarray(costed), jnp.asarray(deps_pad),
              jnp.asarray(cp.pos_in_port), jnp.arange(n))

        def one_combo(window, width, depth):
            pipes0 = jnp.where(jnp.arange(wmax)[None, :] < width[:, None],
                               0.0, jnp.inf)
            carry0 = (jnp.zeros(n), jnp.zeros(n), 0.0,
                      pipes0, jnp.zeros((P, max_hist)), 0.0)

            def body(carry, x):
                fin_arr, rt_arr, rt_prev, pipes, hist, t_best = carry
                dur, pid, is_costed, deps, pos, i = x
                ready = jnp.max(jnp.where(deps >= 0,
                                          fin_arr[jnp.clip(deps, 0)], 0.0))
                row = pipes[pid]
                pf = row.min()
                widx = i - window
                wt = jnp.where(widx >= 0, rt_arr[jnp.clip(widx, 0)], 0.0)
                qidx = pos - depth[pid]
                qt = jnp.where(qidx >= 0, hist[pid, jnp.clip(qidx, 0)], 0.0)
                start = jnp.maximum(jnp.maximum(ready, pf),
                                    jnp.maximum(wt, qt))
                finish = start + dur
                fin_i = jnp.where(is_costed, finish, ready)
                lane = row.argmin()
                pipes = jnp.where(is_costed,
                                  pipes.at[pid, lane].set(finish), pipes)
                hist = jnp.where(is_costed,
                                 hist.at[pid, pos].set(start), hist)
                rt_prev = jnp.maximum(rt_prev, fin_i)
                t_best = jnp.where(is_costed,
                                   jnp.maximum(t_best, finish), t_best)
                return (fin_arr.at[i].set(fin_i), rt_arr.at[i].set(rt_prev),
                        rt_prev, pipes, hist, t_best), None

            (_, _, _, _, _, t_best), _ = jax.lax.scan(body, carry0, xs)
            return t_best

        # the jitted fn closes over THIS program's arrays (and the padded
        # lane count): cache it on the CompiledProgram, keyed by wmax, so
        # it can never serve another program or a wider knob batch
        fns = getattr(cp, "_jax_fns", None)
        if fns is None:
            fns = {}
            cp._jax_fns = fns
        fn = fns.get(wmax)
        if fn is None:
            fn = jax.jit(jax.vmap(one_combo))
            fns[wmax] = fn
        out = fn(jnp.asarray(knobs.window), jnp.asarray(knobs.width),
                 jnp.asarray(knobs.depth))
        return np.asarray(out, dtype=np.float64)
