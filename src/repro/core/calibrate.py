"""Calibration & accuracy evaluation — the paper's §5 methodology.

The paper tunes gem5 with Fujitsu's parameters, then validates the simulator
against the A64FX *test chip* on 28 kernels.  Our test chip is the CPU host
(the only silicon in this container): we

  1. FIT the ``CPU_HOST`` HardwareSpec from a handful of microbenchmarks
     (add -> vector throughput, exp -> transcendental factor, triad ->
     memory bandwidth, empty-jit -> op startup), then
  2. EVALUATE the simulator on all 28 Table-1 kernels: measured wall time vs
     simulated estimate of the same compiled HLO, reporting the % difference
     exactly like Fig. 3 (mean / stddev / mean|.| / fraction within 10%).

Adaptation note (recorded): the paper scales the outer iteration count by
1/1000 because the simulator is slow; we scale the array size by 1024x
because the host's per-call dispatch would otherwise dominate the
measurement of L1-resident arrays.  Same trick, same reason.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
# jax.enable_x64 left the top-level namespace in jax 0.4.31+
from jax.experimental import enable_x64 as jax_enable_x64

from ..configs.a64fx_kernelsuite import KERNELS, Kernel
from ..kernels import ref as kref
from ..kernels.stream import EXPRS, _DTYPES
from .hlo import Program
from .hwspec import CPU_HOST, HardwareSpec
from .simulate import simulate

SIZE_SCALE = 1024     # paper: iter/1000; here: n x1024 (see module docstring)


def _median_time(fn: Callable, args, repeats: int = 15) -> float:
    out = fn(*args)
    jax.block_until_ready(out)          # compile + warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)


def _kernel_inputs(k: Kernel, n: int, key=None):
    fn, n_in, din, dout = EXPRS[k.name]
    key = key or jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    if din == "i4":
        x1 = jax.random.randint(k1, (n,), -1000, 1000, jnp.int32)
    else:
        x1 = (jnp.abs(jax.random.normal(k1, (n,), _DTYPES[din])) + 0.5)
    x2 = (jnp.abs(jax.random.normal(k2, (n,), _DTYPES["f8" if din == "i4"
                                                      else din])) + 0.5)
    if din != "i4":
        x2 = x2.astype(_DTYPES[din])
    y0 = jnp.zeros((n,), _DTYPES[dout])
    return x1, x2, y0


def _jit_kernel(name: str):
    @jax.jit
    def f(x1, x2, y0):
        return kref.elementwise_ref(name, x1, x2, y0)
    return f


def measure_dispatch_overhead() -> float:
    f = jax.jit(lambda x: x)
    x = jnp.zeros((8,), jnp.float32)
    return _median_time(f, (x,), repeats=50)


# kernels used to fit per-opcode factors and the HLO opcodes they exercise
# (the paper's per-OpClass latency table, fitted instead of NDA-supplied).
# Only *transcendental-class* opcodes are fitted; the arithmetic /
# conversion / numeric kernels are predicted purely by the bandwidth +
# vector-throughput model, so they genuinely test it (paper §5.1).
_FACTOR_FIT = {
    "exp": "exponential", "log": "log", "sin": "sine", "cos": "cosine",
    "atan": "atan2", "sqrt": "sqrt", "div": "divide", "pwr": "power",
}


def _poly16(x):
    """Horner chain, 16 fma = 32 f64 flops per element — ALU-bound."""
    y = x
    for _ in range(16):
        y = y * x + 1.25
    return y


def fit_cpu_host(n_mem: int = 1 << 21, n_fac: int = 1 << 15) -> HardwareSpec:
    """Fit the host's HardwareSpec from microbenchmarks (under x64).

    The paper's method, at our scale: separate the memory hierarchy from
    the functional units, fit each level with a benchmark that isolates it,
    then validate on all 28 kernels (§5.1).

    Each memory-hierarchy level is fitted separately (the paper's L1/L2/
    HBM2 function expansion, tuned against the test chip):

    * ``hbm_write_bw`` — a pure-store fill (``zeros_like``) on a DRAM-
      resident array isolates the store path,
    * ``hbm_read_bw``  — DRAM-resident ``add`` (2 loads + 1 store) at the
      SAME array scale the suite evaluates, with the fitted store time
      subtracted — the load path de-blended from the mixed stream,
    * ``vmem_bw``      — LLC-resident ``add`` (the inner level's stream
      rate; load/store symmetric, there is no port asymmetry to see
      through the LLC at this scale),
    * ``vpu_flops``    — a 16-deep Horner polynomial on an LLC-resident
      array: ALU-bound, so it measures the functional unit, not a cache,
    * per-opcode factors — runs with the *estimated per-level stream
      time subtracted*, so the factor is pure instruction cost (the
      paper's per-OpClass latency table, de-masked from bandwidth).
    """
    by_name = {k.name: k for k in KERNELS}
    with jax_enable_x64():
        startup = measure_dispatch_overhead()

        def t_kernel(name: str, n: int, repeats: int = 15) -> float:
            k = by_name[name]
            x1, x2, y0 = _kernel_inputs(k, n)
            return _median_time(_jit_kernel(name), (x1, x2, y0), repeats)

        # --- ALU rate: Horner poly16, L2-resident
        xp = jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (n_fac,),
                                       jnp.float64)) * 0.1 + 0.5
        t_poly = _median_time(jax.jit(_poly16), (xp,), 25)
        alu = 32.0 * n_fac / max(t_poly - startup, 1e-9)

        # --- stream rates: LLC-resident and DRAM-resident add (3 streams)
        t_add_l2 = t_kernel("add", n_fac, 25)
        l2_bw = 3 * 8 * n_fac / max(t_add_l2 - startup, 1e-9)
        t_add_mem = t_kernel("add", n_mem)
        blend_bw = 3 * 8 * n_mem / max(t_add_mem - startup, 1e-9)

        # --- DRAM store path: a pure fill isolates writes; the add stream
        # then yields the load path with the store time subtracted
        xm = jnp.zeros((n_mem,), jnp.float64)
        t_fill = _median_time(jax.jit(jnp.zeros_like), (xm,), 15)
        wr_bw = 8 * n_mem / max(t_fill - startup, 1e-9)
        t_loads = t_add_mem - startup - 8 * n_mem / wr_bw
        rd_bw = (2 * 8 * n_mem / t_loads) if t_loads > 0 else blend_bw
        # hierarchy sanity (the §12 monotonicity contract): a noisy-VM LLC
        # measurement can come out slower than DRAM because the small-array
        # run is dispatch-dominated; an inner level is never slower than
        # the level it front-ends
        l2_bw = max(l2_bw, rd_bw, wr_bw)

        # --- per-opcode factors at the EVALUATION scale, with the stream
        # time subtracted (paper: instruction latencies from Fujitsu specs;
        # here: fitted — the 9 factor kernels are fit INPUTS, the other 19
        # suite kernels are out-of-fit predictions, marked in the table).
        factors = {}
        for kname, opcode in _FACTOR_FIT.items():
            k = by_name[kname]
            _, n_in, _, _ = EXPRS[kname]
            n_eval = k.n * SIZE_SCALE
            t = t_kernel(kname, n_eval, 9)
            # per-level asymmetric stream estimate: loads + store
            t_mem = n_in * 8 * n_eval / rd_bw + 8 * n_eval / wr_bw
            factors[opcode] = max(1.0,
                                  (t - startup - t_mem) * alu / n_eval)
        # mod = divide + round-trip; remainder rides the divide entry
        factors.setdefault("remainder", factors.get("divide", 4.0))

    # the fitted two-level hierarchy (LLC -> DRAM) is derived from these
    # boundary scalars by HardwareSpec.memory_hierarchy()
    return CPU_HOST.with_(
        vpu_flops={"f64": alu, "f32": 2 * alu, "default": alu},
        peak_flops={"f64": alu, "f32": 2 * alu, "default": alu},
        transcendental_factor=max(2.0, factors.get("exponential", 4.0)),
        # fitted transcendental entries override the fallback table; the
        # non-fitted per-opcode VPU latencies (minimum/round/...) survive
        opcode_factor={**CPU_HOST.opcode_factor, **factors},
        hbm_read_bw=rd_bw,
        hbm_write_bw=wr_bw,
        vmem_bytes=24 * 2**20,      # LLC stand-in
        vmem_bw=l2_bw,
        # a CPU core stalls on the miss THEN computes: additive composition
        # (the A64FX/TPU overlap model does not transfer to the host)
        dma_overlap=0.0,
        op_startup_ns=startup * 1e9,
    )


@dataclass
class KernelRow:
    """One Table-1 kernel: measured wall time vs simulated estimates."""
    name: str
    ktype: str
    n: int
    measured_us: float
    simulated_us: float          # flat occupancy engine
    fit_input: bool = False      # this kernel informed the parameter fit
    simulated_sched_us: float = 0.0   # dependency-aware schedule engine
    bound_by: str = ""           # binding port of the occupancy engine

    @property
    def diff_pct(self) -> float:
        """Positive = simulator slower than test chip (paper convention)."""
        return 100.0 * (self.simulated_us - self.measured_us) / self.measured_us

    @property
    def sched_diff_pct(self) -> float:
        return 100.0 * (self.simulated_sched_us - self.measured_us) \
            / self.measured_us


@dataclass
class AccuracyTable:
    """Fig. 3-style accuracy summary over the kernel suite (paper §5)."""
    rows: List[KernelRow]
    # parsed per-kernel programs, aligned with rows (kept when
    # keep_programs=True so sweep_o3 can re-schedule without re-measuring)
    programs: List[Program] = dataclasses.field(default_factory=list)

    @property
    def mean_diff(self) -> float:
        return statistics.mean(r.diff_pct for r in self.rows)

    @property
    def std_diff(self) -> float:
        return statistics.pstdev(r.diff_pct for r in self.rows)

    @property
    def mean_abs_diff(self) -> float:
        return statistics.mean(abs(r.diff_pct) for r in self.rows)

    @property
    def within_10pct(self) -> float:
        return sum(abs(r.diff_pct) <= 10.0 for r in self.rows) / len(self.rows)

    @property
    def sched_mean_abs_diff(self) -> float:
        return statistics.mean(abs(r.sched_diff_pct) for r in self.rows)

    @property
    def sched_within_10pct(self) -> float:
        return sum(abs(r.sched_diff_pct) <= 10.0
                   for r in self.rows) / len(self.rows)

    def report(self) -> str:
        lines = [f"{'kernel':<8s}{'type':<10s}{'n':>9s}{'measured_us':>13s}"
                 f"{'occup_us':>10s}{'diff%':>8s}{'sched_us':>10s}"
                 f"{'diff%':>8s}  fit?"]
        for r in self.rows:
            lines.append(f"{r.name:<8s}{r.ktype:<10s}{r.n:>9d}"
                         f"{r.measured_us:>13.2f}{r.simulated_us:>10.2f}"
                         f"{r.diff_pct:>8.1f}{r.simulated_sched_us:>10.2f}"
                         f"{r.sched_diff_pct:>8.1f}"
                         f"  {'*' if r.fit_input else ''}")
        lines.append(
            f"-- all {len(self.rows)} (occupancy):  mean {self.mean_diff:+.1f}%"
            f"  std {self.std_diff:.1f}%  mean|.| {self.mean_abs_diff:.1f}%  "
            f"within+-10%: {100 * self.within_10pct:.0f}%  "
            f"(paper: +1.3%, 7.8%, 6.6%, 82%)")
        lines.append(
            f"-- all {len(self.rows)} (schedule):   "
            f"mean|.| {self.sched_mean_abs_diff:.1f}%  "
            f"within+-10%: {100 * self.sched_within_10pct:.0f}%")
        held = [r for r in self.rows if not r.fit_input]
        if held and len(held) < len(self.rows):
            ho = AccuracyTable(held)
            lines.append(
                f"-- held-out ({len(held)}): mean {ho.mean_diff:+.1f}%  "
                f"std {ho.std_diff:.1f}%  mean|.| {ho.mean_abs_diff:.1f}%  "
                f"within+-10%: {100 * ho.within_10pct:.0f}%   "
                f"(* = parameter-fit inputs, as the paper's Fujitsu-"
                f"supplied latencies were)")
        return "\n".join(lines)


def kernel_accuracy_table(hw: Optional[HardwareSpec] = None,
                          size_scale: int = SIZE_SCALE,
                          kernels: Optional[List[Kernel]] = None,
                          keep_programs: bool = False) -> AccuracyTable:
    hw = hw or fit_cpu_host()
    rows: List[KernelRow] = []
    programs: List[Program] = []
    with jax_enable_x64():
        for k in (kernels or KERNELS):
            n = k.n * size_scale
            x1, x2, y0 = _kernel_inputs(k, n)
            f = _jit_kernel(k.name)
            t = _median_time(f, (x1, x2, y0))
            compiled = f.lower(x1, x2, y0).compile()
            rep = simulate(compiled, hw=hw, n_chips=1, compute_dtype="f64",
                           engine="both")
            rows.append(KernelRow(k.name, k.ktype, n, t * 1e6,
                                  rep.engine.t_est * 1e6,
                                  fit_input=k.name in _FACTOR_FIT,
                                  simulated_sched_us=rep.schedule.t_est * 1e6,
                                  bound_by=rep.engine.bound_by))
            if keep_programs:
                programs.append(rep.program)
    return AccuracyTable(rows, programs=programs)


# ------------------------------------------------------- O3 parameter sweep
# Sweep grid for the schedule engine's resource knobs — the paper's
# "detailed parameter tuning of out-of-order resources" (§4), fitted
# against the test chip instead of taken from Fujitsu's NDA tables.
# The batched array kernel made scheduling ~free, so the default grid is
# 2.5x the old 4x3x3 one (ROB windows up to 1024, per-port VPU widths)
# at a fraction of its wall cost.
O3_WINDOWS = (4, 16, 64, 256, 1024)
O3_MEM_WIDTHS = (1, 2, 4)
O3_VPU_WIDTHS = (1, 2)
O3_QUEUE_DEPTHS = (4, 16, 64)


def default_o3_knobs(hw: HardwareSpec, windows=O3_WINDOWS,
                     mem_widths=O3_MEM_WIDTHS, vpu_widths=O3_VPU_WIDTHS,
                     queue_depths=O3_QUEUE_DEPTHS):
    """The default batched O3 knob grid as a packed :class:`~.compiled.O3Knobs`.

    One place builds the (window x mem-width x vpu-width x queue-depth)
    product for every consumer of ``schedule_batch`` — ``sweep_o3``, the
    kernel-suite throughput benchmark, and the model-zoo pipeline
    (``core.zoo``, DESIGN.md §15), which passes compact subsets to stay
    inside its wall-clock budget.
    """
    from .compiled import O3Knobs
    return O3Knobs.from_grid(hw, [(w, mw, vw, qd)
                                  for w in windows
                                  for mw in mem_widths
                                  for vw in vpu_widths
                                  for qd in queue_depths])


def _knob_spec(hw: HardwareSpec, w: int, mw: int, vw: int,
               qd: int) -> HardwareSpec:
    return hw.with_(
        inflight_window=w,
        issue_width={**hw.issue_width, "mem": mw, "vpu": vw},
        queue_depth={p: qd for p in ("mxu", "vpu", "mem", "ici")})


def sweep_o3(table: AccuracyTable, hw: HardwareSpec,
             windows=O3_WINDOWS, mem_widths=O3_MEM_WIDTHS,
             queue_depths=O3_QUEUE_DEPTHS, vpu_widths=O3_VPU_WIDTHS,
             compute_dtype: str = "f64", backend: str = "numpy",
             core_counts=(1,), topology=None) -> "O3Sweep":
    """Re-schedule already-measured programs under each knob combination
    (no re-measurement, no recompilation) and rank combos by mean |diff|
    of the schedule engine vs the measured wall times.

    The whole grid runs BATCHED (``core.compiled.schedule_batch``): each
    program is compiled once to array form, shared across every combo, and
    one sequential pass per program advances all combos in lockstep — the
    knob grid is a vector axis, not a python loop.  ``backend="jax"``
    runs the same pass as a jit-ed ``lax.scan`` on the accelerator.

    ``core_counts`` adds the node engine's core count as a sweep axis:
    each count > 1 runs the batched node engine
    (``core.node.schedule_node_batch``, shard partition), which carries
    every knob combo through its own contention fixpoint — exact
    per-knob contention, not the old one-shot ``shard_costed``
    approximation.  Rows against single-core measurements are only
    comparable at ``n_cores=1``; the extra counts chart the knob grid's
    scaling behaviour (and ``best`` is picked among the smallest swept
    core count).

    Requires a table built with ``keep_programs=True``.  Returns an
    :class:`O3Sweep` (ranked results + the tuned ``HardwareSpec``).
    See DESIGN.md §13 (the batched array kernel), §17 (the batched node
    engine behind ``core_counts``) and §11 (what the knobs mean);
    ``core.zoo.estimate_program`` is the same machinery pointed at
    whole-application programs (DESIGN.md §15)."""
    from .compiled import O3Knobs, compile_program, schedule_batch
    from .node import compile_node, schedule_node_sweep
    if not table.programs:
        raise ValueError("sweep_o3 needs kernel_accuracy_table("
                         "keep_programs=True)")
    import numpy as np
    combos = [(w, mw, vw, qd) for w in windows for mw in mem_widths
              for vw in vpu_widths for qd in queue_depths]
    knobs = O3Knobs.from_grid(hw, combos)
    core_counts = tuple(core_counts) or (1,)
    # per-op costs are independent of the O3 knobs: compile each program
    # ONCE per core count and run the shared array form across the grid
    diffs = np.empty((len(table.programs), len(core_counts), knobs.batch))
    node_counts = sorted({k for k in core_counts if k > 1})
    for r, (prog, row) in enumerate(zip(table.programs, table.rows)):
        # all node counts ride ONE fused [C*B] batch (schedule_node_sweep
        # shares the compiled batch form and the contention fixpoint
        # across the count axis); the 1-core rows keep the array engine
        t_by_count = {}
        if 1 in core_counts:
            cp = compile_program(prog, hw, compute_dtype=compute_dtype)
            t_by_count[1] = schedule_batch(cp, knobs, backend=backend)
        if node_counts:
            sw = schedule_node_sweep(
                compile_node(prog, hw, compute_dtype=compute_dtype),
                hw, knobs, node_counts, topology, partition="shard",
                backend=backend)
            t_by_count.update(zip(node_counts, sw))
        for ci, n_cores in enumerate(core_counts):
            t_us = t_by_count[n_cores] * 1e6
            diffs[r, ci] = np.abs(t_us - row.measured_us) \
                / row.measured_us * 100.0
    mean_abs = diffs.mean(axis=0)
    within = (diffs <= 10.0).mean(axis=0)
    results: List[Dict] = []
    for ci, n_cores in enumerate(core_counts):
        for k, (w, mw, vw, qd) in enumerate(combos):
            results.append({"inflight_window": w, "mem_issue_width": mw,
                            "vpu_issue_width": vw, "queue_depth": qd,
                            "n_cores": n_cores,
                            "mean_abs_diff_pct": float(mean_abs[ci, k]),
                            "within_10pct": float(within[ci, k])})
    results.sort(key=lambda r: r["mean_abs_diff_pct"])
    min_cores = min(core_counts)
    best = next(r for r in results if r["n_cores"] == min_cores)
    tuned = _knob_spec(hw, best["inflight_window"], best["mem_issue_width"],
                       best["vpu_issue_width"], best["queue_depth"])
    return O3Sweep(results=results, best=tuned)


@dataclass
class O3Sweep:
    """Ranked results of one batched O3 knob sweep (paper §4 tuning)."""
    results: List[Dict]          # ranked best-first
    best: HardwareSpec           # hw with the winning O3 knobs applied

    def report(self, top: int = 8) -> str:
        lines = [f"{'window':>7s}{'mem_w':>7s}{'vpu_w':>7s}{'qdepth':>7s}"
                 f"{'cores':>7s}{'mean|.|%':>10s}{'<=10%':>7s}"]
        for r in self.results[:top]:
            lines.append(f"{r['inflight_window']:>7d}"
                         f"{r['mem_issue_width']:>7d}"
                         f"{r.get('vpu_issue_width', 1):>7d}"
                         f"{r['queue_depth']:>7d}"
                         f"{r.get('n_cores', 1):>7d}"
                         f"{r['mean_abs_diff_pct']:>10.1f}"
                         f"{100 * r['within_10pct']:>6.0f}%")
        return "\n".join(lines)
