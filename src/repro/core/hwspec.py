"""Hardware parameter files — the gem5-parameter analogue.

The RIKEN simulator's accuracy came from *detailed parameter tuning*: per-
OpClass latencies (extended to be operand-dtype-dependent), asymmetric bus
widths, HBM2 timing, load/store port rules.  ``HardwareSpec`` carries the
same kinds of knobs for our targets:

* ``TPU_V5E``  — the deployment target (roofline constants per assignment:
  197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
* ``A64FX_CMG`` — the paper's own target, parameterized from the paper text
  (used by the paper-faithful kernel-suite benchmark).
* ``CPU_HOST`` — the machine we can actually measure (our "test chip");
  its parameters are *fitted* by ``core.calibrate`` exactly the way RIKEN
  tuned gem5 against Fujitsu's numbers.

Throughputs are per *modeled unit* — per chip for the TPU specs (meshes
scale them by chip count), per **core** for ``A64FX_CORE``/``CPU_HOST``.
A per-core spec plus a :class:`NodeTopology` (CMG counts, per-level
aggregate bandwidths shared by ``MemLevel.shared_by`` cores, inter-CMG
ring) is what the multi-core node engine (``core.node``, DESIGN.md §14)
scales up to one full processor: per-core paths stay the single-core draw
limits, the topology caps what the sharing domain can deliver in total.

Memory is a real multi-level hierarchy (``core.memory``, DESIGN.md §12):
``memory_hierarchy()`` returns the ordered ``MemLevel`` list, innermost
first.  The scalar knobs (``vmem_bytes``/``vmem_bw`` for the innermost
level, ``hbm_read_bw``/``hbm_write_bw``/``hbm_bytes`` for the outermost)
remain the calibration/tuning surface; ``mem_levels`` adds intermediate
levels (the A64FX L2) and asymmetric inner paths.  ``with_`` keeps the two
representations consistent: replacing a boundary scalar rewrites the
matching boundary level.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .memory import MemLevel

# scalar knobs that describe the hierarchy's boundary levels; with_() maps
# them onto mem_levels so that e.g. with_(hbm_write_bw=x) always matters
_INNER_SCALARS = ("vmem_bytes", "vmem_bw")
_OUTER_SCALARS = ("hbm_bytes", "hbm_read_bw", "hbm_write_bw")


@dataclass(frozen=True)
class NodeTopology:
    """Node-level structure for the multi-core engine (``core.node``).

    Cores are numbered compactly: core ``c`` lives on CMG
    ``c // cores_per_cmg`` (OpenMP "close" pinning), so a 12-core run
    fills one CMG — the paper's Figs 4/5 thread-scaling setup.  For each
    memory level whose ``MemLevel.shared_by > 1``, the sharing domain is
    the block of ``shared_by`` consecutive cores, and the aggregate
    bandwidth the domain can draw is capped by ``shared_read_bw`` /
    ``shared_write_bw`` (keyed by level name).  Levels with no entry are
    contention-free: each core keeps its full per-core path.
    """
    name: str
    n_cmgs: int
    cores_per_cmg: int
    # aggregate bytes/s one sharing domain can draw at a level; absent
    # level names mean "no shared cap" (private or never saturated)
    shared_read_bw: Dict[str, float] = field(default_factory=dict)
    shared_write_bw: Dict[str, float] = field(default_factory=dict)
    # inter-CMG ring: a def-use edge crossing CMGs delays the consumer's
    # readiness by ring_latency_s (coherence hop; bytes are not re-charged
    # — the producer already paid the store path)
    ring_latency_s: float = 0.0
    ring_bw: float = 0.0

    @property
    def n_cores(self) -> int:
        return self.n_cmgs * self.cores_per_cmg

    def cmg_of(self, core: int) -> int:
        return core // self.cores_per_cmg

    @classmethod
    def degenerate(cls, n_cores: int) -> "NodeTopology":
        """No shared caps, no ring: n identical fully-private cores.  The
        node engine under this topology with one core is bit-identical to
        the single-core schedule (the differential tests pin this)."""
        return cls(name=f"degenerate_{n_cores}", n_cmgs=1,
                   cores_per_cmg=n_cores)


@dataclass(frozen=True)
class ClusterTopology:
    """Inter-node interconnect for the multi-node cluster engine
    (``core.cluster``, DESIGN.md §20) — the TofuD-style tier above
    :class:`NodeTopology`, shaped like a ``MemLevel`` with ``shared_by``
    semantics: per-link bandwidth, per-hop latency from node-mesh
    coordinates, and a per-node injection aggregate
    (``links_per_node * link_bw``) that concurrently-active collective
    streams share through the same ``effective_bandwidth`` fixpoint the
    node engine uses for L2/HBM2 domains.

    Nodes sit on a ``mesh_shape`` torus (TofuD is a 6-D torus; three
    logical dimensions capture its routing distances at this altitude).
    Node ids map to coordinates row-major (last dimension fastest); a hop
    between adjacent coordinates costs ``hop_latency_s`` and every hop a
    flow crosses consumes one link's worth of capacity, so a g-member
    ring whose neighbours sit h hops apart sees ``link_bw / h`` per
    direction.
    """
    name: str
    mesh_shape: Tuple[int, ...]
    link_bw: float                       # bytes/s per link per direction
    links_per_node: int = 6              # TofuD: 6 TNIs (RDMA engines)
    hop_latency_s: float = 100e-9        # per switch-to-switch hop
    collective_startup_us: float = 0.54  # software put latency per step 0
    torus: bool = True                   # wraparound links on every dim

    @property
    def n_nodes(self) -> int:
        n = 1
        for d in self.mesh_shape:
            n *= d
        return n

    @classmethod
    def tofu_d(cls, n_nodes: int) -> "ClusterTopology":
        """A near-cubic TofuD-flavoured torus over ``n_nodes`` nodes:
        6.8 GB/s per link per direction, six TNIs per node (40.8 GB/s
        injection), ~0.49-0.54 us one-hop put latency split into a
        per-hop wire term and a software startup term.  The shape is the
        most balanced 3-factor decomposition of ``n_nodes`` (ties broken
        toward the larger trailing dim, where ring neighbours are one
        hop apart)."""
        best = None
        for a in range(1, int(round(n_nodes ** (1 / 3))) + 1):
            if n_nodes % a:
                continue
            rest = n_nodes // a
            for b in range(a, int(rest ** 0.5) + 1):
                if rest % b:
                    continue
                c = rest // b
                cand = (a, b, c)
                score = max(cand) / min(cand)
                if best is None or score < best[0]:
                    best = (score, cand)
        shape = best[1] if best is not None else (1, 1, n_nodes)
        return cls(name=f"tofu_d_{n_nodes}", mesh_shape=shape,
                   link_bw=6.8e9, links_per_node=6)


@dataclass(frozen=True)
class HardwareSpec:
    """One hardware parameter file (the gem5-parameter analogue,
    DESIGN.md §4): compute ports, memory hierarchy, interconnect,
    overlap model and O3 scheduling resources, per modeled unit
    (chip for TPU specs, core for A64FX_CORE/CPU_HOST).
    """
    name: str
    # ---- compute ports (paper: reservation stations / execution units)
    peak_flops: Dict[str, float]        # dtype -> FLOP/s on the matrix unit
    vpu_flops: Dict[str, float]         # dtype -> FLOP/s on the vector unit
    transcendental_factor: float        # VPU slowdown for exp/log/sin/... ops
    # ---- memory hierarchy (paper: L1/L2/HBM2 function expansion).
    # Boundary scalars: outermost level (HBM/DRAM) ...
    hbm_read_bw: float                  # bytes/s (asymmetric, like L1 ports)
    hbm_write_bw: float
    hbm_bytes: int
    # ... and innermost level (L1/VMEM):
    vmem_bytes: int
    vmem_bw: float                      # bytes/s, symmetric unless mem_levels
    # ---- interconnect
    ici_links: int
    ici_bw_per_link: float              # bytes/s each direction
    # ---- pipeline/overlap model (paper: OoO overlap of compute & memory)
    dma_overlap: float = 0.85           # fraction of mem traffic hidden under compute
    ici_overlap: float = 0.30           # fraction of collective time hidden (async)
    serialization: float = 0.10         # residual dependency serialization
    op_startup_ns: float = 2_000.0      # per-HLO-op launch/pipeline-fill cost
    collective_startup_us: float = 10.0 # per-collective latency
    # ---- O3 scheduling resources (core.schedule; the gem5 ROB / issue /
    # reservation-station analogue).  The occupancy engine ignores these.
    #   issue_width[port]: parallel pipes per port (async DMA engines, dual
    #                      VPU issue, per-direction ICI injection).
    #   inflight_window:   ROB size — op i cannot issue until op i-window
    #                      has retired (in-order retirement).
    #   queue_depth[port]: reservation-station depth — op i cannot issue
    #                      until the op `depth` earlier on its port issued.
    issue_width: Dict[str, int] = field(
        default_factory=lambda: {"mxu": 1, "vpu": 1, "mem": 2, "ici": 1})
    inflight_window: int = 64
    queue_depth: Dict[str, int] = field(
        default_factory=lambda: {"mxu": 16, "vpu": 16, "mem": 16, "ici": 8})
    # ---- OpClass overrides (paper's operand-type-dependent latency table)
    opclass_throughput: Dict[str, float] = field(default_factory=dict)
    # per-HLO-opcode slowdown factors vs plain vector ops (paper: per-OpClass
    # instruction latencies, extended per operand type). Keys like
    # 'cosine', 'exponential', 'divide'; falls back to transcendental_factor.
    opcode_factor: Dict[str, float] = field(default_factory=dict)
    # matmul efficiency depends on MXU tile alignment; dims padded to this
    mxu_tile: Tuple[int, int, int] = (128, 128, 128)   # (M, K, N) granularity
    min_matmul_dim_for_mxu: int = 8     # tiny dots fall back to VPU
    # explicit memory hierarchy, innermost first.  Empty -> the two-level
    # (vmem, hbm) hierarchy is derived from the boundary scalars above.
    # When set, the innermost/outermost levels MUST mirror the scalars
    # (with_ maintains this; see module docstring).
    mem_levels: Tuple[MemLevel, ...] = ()
    # True when the inner levels are hardware-managed caches kept warm
    # across calls (CPU, A64FX): cold reads and writes take the working-
    # set residency rule.  False for software-managed scratch (TPU VMEM):
    # cold traffic streams from the outermost level; only def-use reuse
    # is charged at inner-level bandwidth (DESIGN.md §12).
    warm_caches: bool = False
    # node structure for the multi-core engine; None = single-unit spec
    # (core.node falls back to a degenerate contention-free topology)
    topology: Optional[NodeTopology] = None

    def with_(self, **kw) -> "HardwareSpec":
        new = dataclasses.replace(self, **kw)
        if new.mem_levels and "mem_levels" not in kw \
                and any(k in kw for k in _INNER_SCALARS + _OUTER_SCALARS):
            # rewrite ONLY the level fields whose scalar was passed —
            # e.g. with_(vmem_bytes=...) must not flatten an asymmetric
            # L1 load/store pair back to the symmetric vmem_bw scalar
            lv = list(new.mem_levels)
            inner_kw = {}
            if "vmem_bytes" in kw:
                inner_kw["capacity"] = float(new.vmem_bytes)
            if "vmem_bw" in kw:
                inner_kw["read_bw"] = float(new.vmem_bw)
                inner_kw["write_bw"] = float(new.vmem_bw)
            if inner_kw:
                lv[0] = dataclasses.replace(lv[0], **inner_kw)
            outer_kw = {}
            if "hbm_bytes" in kw:
                outer_kw["capacity"] = float(new.hbm_bytes)
            if "hbm_read_bw" in kw:
                outer_kw["read_bw"] = new.hbm_read_bw
            if "hbm_write_bw" in kw:
                outer_kw["write_bw"] = new.hbm_write_bw
            if outer_kw:
                lv[-1] = dataclasses.replace(lv[-1], **outer_kw)
            new = dataclasses.replace(new, mem_levels=tuple(lv))
        return new

    def matmul_flops(self, dtype: str) -> float:
        return self.peak_flops.get(dtype, self.peak_flops.get("default", 1e12))

    def vector_flops(self, dtype: str) -> float:
        return self.vpu_flops.get(dtype, self.vpu_flops.get("default", 1e12))

    def memory_hierarchy(self) -> Tuple[MemLevel, ...]:
        """Ordered hierarchy, innermost first (L1/VMEM -> ... -> HBM).

        Memoized on the (frozen) spec: ``route_*``/``cost_program`` call
        this in hot loops and must not rebuild the tuple every time.
        ``dataclasses.replace`` (and therefore ``with_``) returns a fresh
        instance without the cache, so stale hierarchies cannot leak."""
        cached = getattr(self, "_mh_cache", None)
        if cached is not None:
            return cached
        if self.mem_levels:
            mh = self.mem_levels
        else:
            mh = (
                MemLevel("vmem", float(self.vmem_bytes),
                         float(self.vmem_bw), float(self.vmem_bw)),
                MemLevel("hbm", float(self.hbm_bytes),
                         self.hbm_read_bw, self.hbm_write_bw),
            )
        object.__setattr__(self, "_mh_cache", mh)
        return mh


class SpecGrid:
    """Structure-of-arrays over S :class:`HardwareSpec`\\ s — the spec
    batch axis of the fused DSE sweeps (DESIGN.md §19).

    A grid is *structurally uniform*: every spec shares the hierarchy
    depth and level names, ``warm_caches``, the MXU tile shape and the
    VPU-fallback threshold — everything that decides port assignment or
    program structure — while every numeric rate (flops tables, level
    capacities/bandwidths/latencies, per-opcode factors, ICI, startups,
    topology parameters) varies freely per spec.  ``cost_program_batch``
    evaluates those rates as ``[S]`` vectors per op; construction
    validates uniformity and raises ``ValueError`` otherwise.

    Grids compare by VALUE over ``(specs, topologies)`` — the compile
    caches (``compile_node_grid``) key on that, so a rebuilt equal grid
    hits and a 1-spec grid can never alias a plain single-spec entry
    (different cache, different key type).
    """

    def __init__(self, specs: Sequence[HardwareSpec],
                 topologies: Optional[Sequence[Optional[NodeTopology]]]
                 = None):
        specs = tuple(specs)
        if not specs:
            raise ValueError("empty spec grid")
        if topologies is None:
            topologies = tuple(sp.topology for sp in specs)
        else:
            topologies = tuple(topologies)
            if len(topologies) != len(specs):
                raise ValueError("topologies/specs length mismatch")
        base = specs[0]
        names = tuple(lv.name for lv in base.memory_hierarchy())
        for sp in specs:
            if tuple(lv.name for lv in sp.memory_hierarchy()) != names:
                raise ValueError(f"{sp.name}: level structure differs "
                                 f"from {base.name}")
            if sp.warm_caches != base.warm_caches:
                raise ValueError(f"{sp.name}: warm_caches differs")
            if sp.mxu_tile != base.mxu_tile:
                raise ValueError(f"{sp.name}: mxu_tile differs")
            if sp.min_matmul_dim_for_mxu != base.min_matmul_dim_for_mxu:
                raise ValueError(f"{sp.name}: min_matmul_dim_for_mxu "
                                 "differs")
        self.specs = specs
        self.topologies = topologies
        self.level_names = names
        self.warm_caches = base.warm_caches
        self.mxu_tile = base.mxu_tile
        self.min_matmul_dim_for_mxu = base.min_matmul_dim_for_mxu
        self.transcendental = np.array(
            [sp.transcendental_factor for sp in specs])
        self.ici_bw_per_link = np.array(
            [sp.ici_bw_per_link for sp in specs])
        self.collective_startup_us = np.array(
            [sp.collective_startup_us for sp in specs])
        self.op_startup_ns = np.array([sp.op_startup_ns for sp in specs])
        self._flops_cache: Dict[Tuple[str, str], np.ndarray] = {}
        self._factor_cache: Dict[Tuple[str, str], np.ndarray] = {}

    @property
    def S(self) -> int:
        return len(self.specs)

    def __eq__(self, other) -> bool:
        return (isinstance(other, SpecGrid)
                and self.specs == other.specs
                and self.topologies == other.topologies)

    def __ne__(self, other) -> bool:
        return not self.__eq__(other)

    __hash__ = None

    def topology_of(self, s: int) -> NodeTopology:
        """Spec ``s``'s node topology (degenerate single-core fallback,
        mirroring ``schedule_node``'s resolution)."""
        return self.topologies[s] or NodeTopology.degenerate(1)

    def hierarchies(self) -> List[Tuple[MemLevel, ...]]:
        """Per-spec ordered hierarchies (for the batched router)."""
        return [sp.memory_hierarchy() for sp in self.specs]

    def matmul_flops(self, dtype: str) -> np.ndarray:
        """[S] MXU peak FLOP/s at ``dtype`` (memoized per dtype)."""
        key = ("mxu", dtype)
        out = self._flops_cache.get(key)
        if out is None:
            out = self._flops_cache[key] = np.array(
                [sp.matmul_flops(dtype) for sp in self.specs])
        return out

    def vector_flops(self, dtype: str) -> np.ndarray:
        """[S] VPU peak FLOP/s at ``dtype`` (memoized per dtype)."""
        key = ("vpu", dtype)
        out = self._flops_cache.get(key)
        if out is None:
            out = self._flops_cache[key] = np.array(
                [sp.vector_flops(dtype) for sp in self.specs])
        return out

    def trans_factor(self, opcode: str) -> np.ndarray:
        """[S] per-opcode latency factor with each spec's
        ``transcendental_factor`` as its own fallback (the scalar
        ``trans_time`` lookup, vectorized)."""
        key = ("t", opcode)
        out = self._factor_cache.get(key)
        if out is None:
            out = self._factor_cache[key] = np.array(
                [sp.opcode_factor.get(opcode, sp.transcendental_factor)
                 for sp in self.specs])
        return out

    def vpu_extra_factor(self, opcode: str) -> np.ndarray:
        """[S] extra flop-equivalents factor ``f - 1`` for non-trans
        opcodes; specs without an entry contribute 0.0 — adding that 0.0
        is a float no-op, so per-spec table presence may differ while the
        scalar ``vpu_extra`` skip stays bit-reproduced."""
        key = ("v", opcode)
        out = self._factor_cache.get(key)
        if out is None:
            vals = [sp.opcode_factor.get(opcode) for sp in self.specs]
            out = self._factor_cache[key] = np.array(
                [0.0 if f is None else f - 1.0 for f in vals])
        return out

    def opclass_throughput_arr(self, opclass: str) -> np.ndarray:
        """[S] OpClass throughput override (default 1.0)."""
        key = ("o", opclass)
        out = self._factor_cache.get(key)
        if out is None:
            out = self._factor_cache[key] = np.array(
                [sp.opclass_throughput.get(opclass, 1.0)
                 for sp in self.specs])
        return out


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops={"bf16": 197e12, "f32": 49.25e12, "f16": 197e12,
                "s8": 394e12, "default": 49.25e12},
    vpu_flops={"f32": 4.9e12, "bf16": 4.9e12, "default": 2.45e12},
    transcendental_factor=8.0,
    hbm_read_bw=819e9,
    hbm_write_bw=819e9,
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
    vmem_bw=11e12,
    # mem_levels derived: (vmem 128 MiB @ 11 TB/s, hbm 16 GiB @ 819 GB/s) —
    # v5e has no intermediate cache between VMEM and HBM
    ici_links=4,                        # 2D torus on a 16x16 pod
    ici_bw_per_link=50e9,
    dma_overlap=0.85,
    ici_overlap=0.30,
    serialization=0.08,
)

TPU_V4 = HardwareSpec(
    name="tpu_v4",
    peak_flops={"bf16": 275e12, "f32": 68.75e12, "default": 68.75e12},
    vpu_flops={"f32": 4.3e12, "default": 2.2e12},
    transcendental_factor=8.0,
    hbm_read_bw=1228e9,
    hbm_write_bw=1228e9,
    hbm_bytes=32 * 2**30,
    vmem_bytes=128 * 2**20,
    vmem_bw=14e12,
    # mem_levels derived: (vmem 128 MiB @ 14 TB/s, hbm 32 GiB @ 1.23 TB/s)
    ici_links=6,                        # 3D torus
    ici_bw_per_link=50e9,
)

# The paper's processor, one CMG, parameterized from the paper text:
# 12 compute cores, 2x512-bit SIMD FMA pipes @ 1.8 GHz (test chip),
# L1D 64 KiB (load >230 GB/s, store >115 GB/s per core), L2 8 MiB
# (>900 GB/s/CMG), HBM2 256 GB/s/CMG.
_A64FX_GHZ = 1.8e9
_A64FX_CORE_F64 = 2 * 8 * 2 * _A64FX_GHZ        # 57.6 GFLOP/s per core

# Per-opcode VPU latency factors (the paper's per-OpClass instruction
# latencies, "detailed parameter tuning"): per-element cost relative to a
# pipelined SVE FMA.  fdiv/fsqrt are unpipelined on the A64FX FLA pipe
# (~40 cycles / 2 pipes vs a 4-cycle FMA); compare-select pairs take two
# µops; frint/fcvt chains cost a couple.  Without these, every
# memory-resident kernel of a class collapses to the same t_est (the
# BENCH_kernel_suite degeneracy this table fixes).
_A64FX_OPCODE_FACTOR = {
    "divide": 20.0, "remainder": 24.0, "sqrt": 18.0, "rsqrt": 18.0,
    "cbrt": 24.0, "exponential": 6.0, "exponential-minus-one": 7.0,
    "log": 8.0, "log-plus-one": 9.0, "sine": 10.0, "cosine": 10.0,
    "tan": 16.0, "atan2": 22.0, "power": 26.0, "tanh": 10.0,
    "logistic": 9.0, "erf": 9.0, "erf-inv": 14.0,
    "maximum": 2.0, "minimum": 2.0,
    "round-nearest-even": 3.0, "round-nearest-afz": 3.0,
    "floor": 3.0, "ceil": 3.0, "sign": 2.0, "convert": 2.0,
}

A64FX_CMG = HardwareSpec(
    name="a64fx_cmg",
    peak_flops={"f64": 12 * _A64FX_CORE_F64,
                "f32": 24 * _A64FX_CORE_F64,
                "default": 12 * _A64FX_CORE_F64},
    vpu_flops={"f64": 12 * _A64FX_CORE_F64,
               "f32": 24 * _A64FX_CORE_F64,
               "default": 12 * _A64FX_CORE_F64},
    transcendental_factor=6.0,          # inlined SVE math functions
    opcode_factor=dict(_A64FX_OPCODE_FACTOR),
    hbm_read_bw=256e9,
    hbm_write_bw=256e9,
    hbm_bytes=8 * 2**30,
    vmem_bytes=12 * 64 * 2**10,         # aggregate L1D across the CMG
    vmem_bw=12 * 230e9,
    # the paper's three-level function expansion; L1 load/store asymmetry
    # per the paper text, L2 store path at the same 2:1 ratio
    mem_levels=(
        MemLevel("l1d", 12 * 64 * 2**10, 12 * 230e9, 12 * 115e9, 2.8e-9),
        MemLevel("l2", 8 * 2**20, 900e9, 450e9, 20e-9),
        MemLevel("hbm2", 8 * 2**30, 256e9, 256e9, 120e-9),
    ),
    warm_caches=True,                   # real HW-managed L1/L2
    ici_links=6,                        # TofuD
    ici_bw_per_link=6.8e9,
    dma_overlap=0.7,                    # HW prefetch (K-compatible, per paper)
    serialization=0.12,
    op_startup_ns=100.0,
)

# The full-node structure the per-core spec scales up to: 4 CMGs x 12
# cores, one 8 MiB L2 (>900 GB/s aggregate) and one HBM2 stack
# (256 GB/s) per CMG, CMGs linked by the on-chip ring bus.  The node
# engine divides each shared level's aggregate among the cores actively
# streaming through it — replacing the old hardcoded "one core gets ~1/4
# of the CMG's HBM2" approximation with a contention model.
A64FX_NODE = NodeTopology(
    name="a64fx_node", n_cmgs=4, cores_per_cmg=12,
    shared_read_bw={"l2": 900e9, "hbm2": 256e9},
    shared_write_bw={"l2": 450e9, "hbm2": 256e9},
    ring_latency_s=130e-9,              # inter-CMG coherence hop
    ring_bw=115e9,
)

# One A64FX core (Fig. 3 of the paper is single-core): private L1D with the
# paper's asymmetric load/store ports, a 1/12 share of the L2 capacity, and
# the single-core draw limits on the shared CMG paths (~1/4 of the
# 256 GB/s HBM2, store path at the L1 2:1 ratio).  ``shared_by`` marks the
# L2/HBM2 paths as CMG-shared; ``topology`` carries the aggregates the
# node engine divides among active cores.
A64FX_CORE = A64FX_CMG.with_(
    name="a64fx_core",
    peak_flops={"f64": _A64FX_CORE_F64, "f32": 2 * _A64FX_CORE_F64,
                "default": _A64FX_CORE_F64},
    vpu_flops={"f64": _A64FX_CORE_F64, "f32": 2 * _A64FX_CORE_F64,
               "default": _A64FX_CORE_F64},
    hbm_read_bw=64e9,
    hbm_write_bw=32e9,
    vmem_bytes=64 * 2**10,              # private L1D
    vmem_bw=230e9,
    # per-path bandwidths decrease monotonically outward (the §12
    # residency-monotonicity contract): the single-core L2 draw is capped
    # below the L1 ports it front-ends
    mem_levels=(
        MemLevel("l1d", 64 * 2**10, 230e9, 115e9, 2.8e-9),
        MemLevel("l2", 8 * 2**20 // 12, 200e9, 100e9, 20e-9, shared_by=12),
        MemLevel("hbm2", 8 * 2**30, 64e9, 32e9, 120e-9, shared_by=12),
    ),
    topology=A64FX_NODE,
    dma_overlap=1.0,                    # loads are pipelined under FMA issue
    op_startup_ns=50.0,
)

# Fitted by core.calibrate on the actual host; these are fallback defaults.
# Two derived levels: (vmem = LLC, hbm = DRAM); calibrate fits each level's
# bandwidth from microbenchmarks that isolate it.
CPU_HOST = HardwareSpec(
    name="cpu_host",
    peak_flops={"f64": 5e10, "f32": 1e11, "default": 5e10},
    vpu_flops={"f64": 5e10, "f32": 1e11, "default": 5e10},
    transcendental_factor=10.0,
    # fallback per-opcode latency table (libm call costs dominate on a
    # host CPU); core.calibrate re-fits the transcendental entries from
    # microbenchmarks and keeps the rest
    opcode_factor={
        "divide": 40.0, "remainder": 45.0, "sqrt": 35.0, "rsqrt": 40.0,
        "exponential": 90.0, "log": 80.0, "sine": 110.0, "cosine": 110.0,
        "tan": 180.0, "atan2": 260.0, "power": 220.0, "tanh": 100.0,
        "logistic": 100.0, "erf": 100.0,
        "maximum": 1.5, "minimum": 1.5, "round-nearest-even": 3.0,
    },
    hbm_read_bw=2e10,
    hbm_write_bw=1.5e10,
    hbm_bytes=16 * 2**30,
    vmem_bytes=32 * 2**20,              # LLC
    vmem_bw=2e11,
    warm_caches=True,                   # real HW-managed cache hierarchy
    ici_links=1,
    ici_bw_per_link=1e10,
    dma_overlap=0.5,
    serialization=0.3,
    op_startup_ns=20_000.0,             # interpreter/dispatch heavy
)

SPECS = {s.name: s for s in (TPU_V5E, TPU_V4, A64FX_CMG, A64FX_CORE,
                             CPU_HOST)}
