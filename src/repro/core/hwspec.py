"""Hardware parameter files — the gem5-parameter analogue.

The RIKEN simulator's accuracy came from *detailed parameter tuning*: per-
OpClass latencies (extended to be operand-dtype-dependent), asymmetric bus
widths, HBM2 timing, load/store port rules.  ``HardwareSpec`` carries the
same kinds of knobs for our targets:

* ``TPU_V5E``  — the deployment target (roofline constants per assignment:
  197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
* ``A64FX_CMG`` — the paper's own target, parameterized from the paper text
  (used by the paper-faithful kernel-suite benchmark).
* ``CPU_HOST`` — the machine we can actually measure (our "test chip");
  its parameters are *fitted* by ``core.calibrate`` exactly the way RIKEN
  tuned gem5 against Fujitsu's numbers.

All throughputs are per chip; meshes scale them by chip count.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    # ---- compute ports (paper: reservation stations / execution units)
    peak_flops: Dict[str, float]        # dtype -> FLOP/s on the matrix unit
    vpu_flops: Dict[str, float]         # dtype -> FLOP/s on the vector unit
    transcendental_factor: float        # VPU slowdown for exp/log/sin/... ops
    # ---- memory hierarchy (paper: L1/L2/HBM2 extensions)
    hbm_read_bw: float                  # bytes/s (asymmetric, like L1<->L2 buses)
    hbm_write_bw: float
    hbm_bytes: int
    vmem_bytes: int
    vmem_bw: float                      # bytes/s, VMEM<->compute
    # ---- interconnect
    ici_links: int
    ici_bw_per_link: float              # bytes/s each direction
    # ---- pipeline/overlap model (paper: OoO overlap of compute & memory)
    dma_overlap: float = 0.85           # fraction of HBM traffic hidden under compute
    ici_overlap: float = 0.30           # fraction of collective time hidden (async)
    serialization: float = 0.10         # residual dependency serialization
    op_startup_ns: float = 2_000.0      # per-HLO-op launch/pipeline-fill cost
    collective_startup_us: float = 10.0 # per-collective latency
    # ---- O3 scheduling resources (core.schedule; the gem5 ROB / issue /
    # reservation-station analogue).  The occupancy engine ignores these.
    #   issue_width[port]: parallel pipes per port (async DMA engines, dual
    #                      VPU issue, per-direction ICI injection).
    #   inflight_window:   ROB size — op i cannot issue until op i-window
    #                      has retired (in-order retirement).
    #   queue_depth[port]: reservation-station depth — op i cannot issue
    #                      until the op `depth` earlier on its port issued.
    issue_width: Dict[str, int] = field(
        default_factory=lambda: {"mxu": 1, "vpu": 1, "mem": 2, "ici": 1})
    inflight_window: int = 64
    queue_depth: Dict[str, int] = field(
        default_factory=lambda: {"mxu": 16, "vpu": 16, "mem": 16, "ici": 8})
    # ---- OpClass overrides (paper's operand-type-dependent latency table)
    opclass_throughput: Dict[str, float] = field(default_factory=dict)
    # per-HLO-opcode slowdown factors vs plain vector ops (paper: per-OpClass
    # instruction latencies, extended per operand type). Keys like
    # 'cosine', 'exponential', 'divide'; falls back to transcendental_factor.
    opcode_factor: Dict[str, float] = field(default_factory=dict)
    # matmul efficiency depends on MXU tile alignment; dims padded to this
    mxu_tile: Tuple[int, int, int] = (128, 128, 128)   # (M, K, N) granularity
    min_matmul_dim_for_mxu: int = 8     # tiny dots fall back to VPU
    # cache model (paper's L1/L2 extensions): when True, ops whose boundary
    # working set fits vmem_bytes stream at vmem_bw instead of HBM bw.
    cache_model: bool = False

    def with_(self, **kw) -> "HardwareSpec":
        return dataclasses.replace(self, **kw)

    def matmul_flops(self, dtype: str) -> float:
        return self.peak_flops.get(dtype, self.peak_flops.get("default", 1e12))

    def vector_flops(self, dtype: str) -> float:
        return self.vpu_flops.get(dtype, self.vpu_flops.get("default", 1e12))


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops={"bf16": 197e12, "f32": 49.25e12, "f16": 197e12,
                "s8": 394e12, "default": 49.25e12},
    vpu_flops={"f32": 4.9e12, "bf16": 4.9e12, "default": 2.45e12},
    transcendental_factor=8.0,
    hbm_read_bw=819e9,
    hbm_write_bw=819e9,
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
    vmem_bw=11e12,
    ici_links=4,                        # 2D torus on a 16x16 pod
    ici_bw_per_link=50e9,
    dma_overlap=0.85,
    ici_overlap=0.30,
    serialization=0.08,
)

TPU_V4 = HardwareSpec(
    name="tpu_v4",
    peak_flops={"bf16": 275e12, "f32": 68.75e12, "default": 68.75e12},
    vpu_flops={"f32": 4.3e12, "default": 2.2e12},
    transcendental_factor=8.0,
    hbm_read_bw=1228e9,
    hbm_write_bw=1228e9,
    hbm_bytes=32 * 2**30,
    vmem_bytes=128 * 2**20,
    vmem_bw=14e12,
    ici_links=6,                        # 3D torus
    ici_bw_per_link=50e9,
)

# The paper's processor, one CMG, parameterized from the paper text:
# 12 compute cores, 2x512-bit SIMD FMA pipes @ 1.8 GHz (test chip),
# L1D 64 KiB (load >230 GB/s, store >115 GB/s per core), L2 8 MiB
# (>900 GB/s/CMG), HBM2 256 GB/s/CMG.
_A64FX_GHZ = 1.8e9
_A64FX_CORE_F64 = 2 * 8 * 2 * _A64FX_GHZ        # 57.6 GFLOP/s per core
A64FX_CMG = HardwareSpec(
    name="a64fx_cmg",
    peak_flops={"f64": 12 * _A64FX_CORE_F64,
                "f32": 24 * _A64FX_CORE_F64,
                "default": 12 * _A64FX_CORE_F64},
    vpu_flops={"f64": 12 * _A64FX_CORE_F64,
               "f32": 24 * _A64FX_CORE_F64,
               "default": 12 * _A64FX_CORE_F64},
    transcendental_factor=6.0,          # inlined SVE math functions
    hbm_read_bw=256e9,
    hbm_write_bw=256e9,
    hbm_bytes=8 * 2**30,
    vmem_bytes=8 * 2**20,               # L2 plays the VMEM role
    vmem_bw=900e9,
    ici_links=6,                        # TofuD
    ici_bw_per_link=6.8e9,
    dma_overlap=0.7,                    # HW prefetch (K-compatible, per paper)
    serialization=0.12,
    op_startup_ns=100.0,
)

# One A64FX core (Fig. 3 of the paper is single-core): 1/12 of a CMG, with
# the L1 port rule folded into the bandwidth numbers (load >230 GB/s,
# store >115 GB/s per core -> asymmetric read/write).
A64FX_CORE = A64FX_CMG.with_(
    name="a64fx_core",
    peak_flops={"f64": _A64FX_CORE_F64, "f32": 2 * _A64FX_CORE_F64,
                "default": _A64FX_CORE_F64},
    vpu_flops={"f64": _A64FX_CORE_F64, "f32": 2 * _A64FX_CORE_F64,
               "default": _A64FX_CORE_F64},
    hbm_read_bw=230e9,                  # L1 load path (the kernels are L1-resident)
    hbm_write_bw=115e9,
    vmem_bytes=64 * 2**10,              # L1D
    vmem_bw=230e9,
    dma_overlap=1.0,                    # loads are pipelined under FMA issue
    op_startup_ns=50.0,
)

# Fitted by core.calibrate on the actual host; these are fallback defaults.
CPU_HOST = HardwareSpec(
    name="cpu_host",
    peak_flops={"f64": 5e10, "f32": 1e11, "default": 5e10},
    vpu_flops={"f64": 5e10, "f32": 1e11, "default": 5e10},
    transcendental_factor=10.0,
    hbm_read_bw=2e10,
    hbm_write_bw=1.5e10,
    hbm_bytes=16 * 2**30,
    vmem_bytes=32 * 2**20,              # LLC
    vmem_bw=2e11,
    ici_links=1,
    ici_bw_per_link=1e10,
    dma_overlap=0.5,
    serialization=0.3,
    op_startup_ns=20_000.0,             # interpreter/dispatch heavy
)

SPECS = {s.name: s for s in (TPU_V5E, TPU_V4, A64FX_CMG, A64FX_CORE,
                             CPU_HOST)}
