"""Multi-core node engine — contention-aware scheduling across CMG cores.

The paper's stated target is the execution time of *one node* application;
PRs 1-3 built a per-kernel cost model (one core, or one core drawing a
hardcoded share of the CMG's bandwidth).  This module is the node layer
on top of the compiled SoA core (DESIGN.md §14):

* a per-core ``HardwareSpec`` plus a :class:`~.hwspec.NodeTopology`
  describe the node: per-core paths are single-core draw limits,
  ``MemLevel.shared_by`` marks CMG-shared levels, the topology carries
  each sharing domain's aggregate bandwidth and the inter-CMG ring;
* a costed :class:`~.hlo.Program` is partitioned across cores —
  op-level round-robin, a def-use-aware greedy graph partition, or
  OpenMP-style data-parallel sharding (every core runs the whole program
  at ``1/n_cores`` of the work, the kernel-suite mode);
* one in-order stream per core runs through the existing compiled
  machinery (per-``(core, port)`` pipes, per-core ROB windows and
  reservation queues — the same float ops as ``schedule_arrays``, which
  is why ``n_cores=1`` under a degenerate topology is bit-identical to
  the single-core fast path), with readiness propagated globally across
  cores and cross-CMG def-use edges charged the ring latency;
* a bandwidth-contention fixpoint divides each shared level's aggregate
  among the cores actively streaming through it: the concurrently-active
  estimate ``n_active = clamp(sum_c busy_c / t_node, 1, cores)`` feeds
  back into per-op memory times (reusing ``route_program``'s per-level
  residency split) until it stabilizes.

``schedule_node`` returns a :class:`NodeResult`: per-core timelines, a
node-level :class:`~.schedule.ScheduleResult`, per-CMG contention/
occupancy, and the zero-contention bound (the fixpoint's first
iteration), so every estimate ships with its own sandwich
``t_zero_contention <= t_est <= t_single_core`` (asserted by the node
test harness).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .compiled import PORTS, CompiledProgram, compile_program
from .cost import OpTime, cost_program
from .hlo import Program
from .hwspec import HardwareSpec, NodeTopology
from .schedule import ScheduleResult

_NODE_CACHE_SIZE = 8


def effective_bandwidth(core_bw, shared_bw, n_active):
    """Per-core effective bandwidth at a shared level: the single-core
    draw limit, capped by an equal share of the domain aggregate among
    the ``n_active`` cores concurrently streaming through it.  Monotone
    non-increasing in ``n_active`` (property-tested).  Scalar or
    elementwise over arrays — ``_eff_inv`` calls this, so the property
    test binds the engine's actual contention math."""
    if shared_bw is None:
        return core_bw
    return np.minimum(core_bw, shared_bw / np.maximum(n_active, 1.0))


# ------------------------------------------------------------ compiled form
@dataclass
class NodeCompiled:
    """Per-(program, spec, dtype) node form: the single-core compiled
    program plus the per-op/per-level cost decomposition the contention
    fixpoint rescales (``t_mem = rd @ inv_read + wr @ inv_write + lat``).
    """
    cp: CompiledProgram
    n: int
    t_comp: np.ndarray           # [n] per-instance compute time
    t_ici: np.ndarray            # [n]
    lat: np.ndarray              # [n] hierarchy access latency (uncontended)
    count: np.ndarray            # [n]
    rd: np.ndarray               # [n, L] routed read bytes per level
    wr: np.ndarray               # [n, L] routed write bytes per level
    level_names: Tuple[str, ...]
    core_read_bw: np.ndarray     # [L] per-core paths
    core_write_bw: np.ndarray
    shared_by: np.ndarray        # [L] sharing-domain size per level
    startup: float
    costed_mask: np.ndarray = None   # [n] bool: port_id >= 0


def compile_node(prog: Program, hw: HardwareSpec,
                 links_per_collective: int = 2,
                 compute_dtype: Optional[str] = None,
                 costed: Optional[List[Optional[OpTime]]] = None
                 ) -> NodeCompiled:
    """Compile (and memoize on the Program) the node form.  A caller-
    supplied ``costed`` list bypasses the cache, mirroring
    ``compile_program``."""
    if costed is None:
        cache = prog.__dict__.setdefault("_node_cache", [])
        for chw, cdt, clk, cnc in cache:
            if chw is hw and cdt == compute_dtype \
                    and clk == links_per_collective:
                return cnc
        costed = cost_program(prog, hw, links_per_collective, compute_dtype)
    else:
        cache = None
    cp = compile_program(prog, hw, links_per_collective, compute_dtype,
                         costed=costed)
    levels = hw.memory_hierarchy()
    L = len(levels)
    n = len(prog.ops)
    lidx = {lv.name: i for i, lv in enumerate(levels)}
    t_comp = np.zeros(n)
    t_ici = np.zeros(n)
    lat = np.zeros(n)
    count = np.ones(n)
    rd = np.zeros((n, L))
    wr = np.zeros((n, L))
    for i, ot in enumerate(costed):
        if ot is None:
            continue
        t_comp[i] = ot.t_compute
        t_ici[i] = ot.t_ici
        count[i] = ot.op.count
        tr = ot.traffic
        if tr is not None:
            lat[i] = tr.latency_s
            for nm, b in tr.read_by_level.items():
                rd[i, lidx[nm]] = b
            for nm, b in tr.write_by_level.items():
                wr[i, lidx[nm]] = b
    nc = NodeCompiled(
        cp=cp, n=n, t_comp=t_comp, t_ici=t_ici, lat=lat, count=count,
        rd=rd, wr=wr, level_names=tuple(lv.name for lv in levels),
        core_read_bw=np.array([lv.read_bw for lv in levels]),
        core_write_bw=np.array([lv.write_bw for lv in levels]),
        shared_by=np.array([max(1, lv.shared_by) for lv in levels],
                           dtype=np.int64),
        startup=hw.op_startup_ns * 1e-9,
        costed_mask=cp.port_id >= 0,
    )
    if cache is not None:
        cache.append((hw, compute_dtype, links_per_collective, nc))
        if len(cache) > _NODE_CACHE_SIZE:
            cache.pop(0)
    return nc


# ------------------------------------------------------------- partitioning
def partition_round_robin(n: int, n_cores: int) -> np.ndarray:
    """Op-level round-robin over program order (free ops included: they
    occupy their core's ROB slots exactly like the single-core kernels)."""
    return np.arange(n, dtype=np.int64) % max(1, n_cores)


def partition_graph(nc: NodeCompiled, n_cores: int,
                    balance: float = 1.25) -> np.ndarray:
    """Def-use-aware greedy partition: follow each op's heaviest producer
    onto its core while that core's load stays under ``balance`` x the
    even share, else fall to the least-loaded core.  Keeps dependence
    chains co-located (fewer cross-core readiness waits and ring hops)
    while bounding imbalance.  Deterministic."""
    n_cores = max(1, n_cores)
    durs = nc.cp._dur_l
    indptr = nc.cp._indptr_l
    indices = nc.cp._indices_l
    core_of = np.zeros(nc.n, dtype=np.int64)
    load = [0.0] * n_cores
    cap = balance * (sum(durs) / n_cores) + 1e-30
    for i in range(nc.n):
        pref = -1
        best = -1.0
        for k in range(indptr[i], indptr[i + 1]):
            j = indices[k]
            if durs[j] > best:
                best, pref = durs[j], int(core_of[j])
        if pref < 0 or load[pref] + durs[i] > cap:
            pref = min(range(n_cores), key=load.__getitem__)
        core_of[i] = pref
        load[pref] += durs[i]
    return core_of


# ------------------------------------------------------- the node scheduler
def _node_pass(durs, ports, indptr, indices, core_of, cmg_of_core,
               widths, depths, window, ring_lat):
    """One global in-order pass over the ops with per-(core, port) pipes,
    per-core ROB windows and reservation queues, and globally-propagated
    readiness (+ ring latency on cross-CMG def-use edges).  With one core
    this replays ``schedule_arrays``'s float operations in the same
    order, hence bit-identical results (the differential tests pin it).
    """
    n = len(durs)
    P = len(PORTS)
    n_cores = len(cmg_of_core)
    finishes = [0.0] * n
    starts = [0.0] * n
    rt_tail = [0.0] * n_cores                 # per-core worst retire seen
    rt_hist: List[List[float]] = [[] for _ in range(n_cores)]
    pipes: List[List[Optional[List[float]]]] = \
        [[None] * P for _ in range(n_cores)]
    hist: List[List[Optional[List[float]]]] = \
        [[None] * P for _ in range(n_cores)]
    core_busy = [[0.0] * P for _ in range(n_cores)]
    core_finish = [0.0] * n_cores
    core_nops = [0] * n_cores
    s_port = s_window = s_queue = 0.0
    t_est = 0.0
    use_ring = ring_lat > 0.0 and n_cores > 1
    # a value's home CMG: where it was produced.  Free ops (gte/bitcast/
    # tuple) are pass-throughs — they inherit their binding producer's
    # home and charge no hop themselves, so data consumed on its own CMG
    # through a scattered free op pays no phantom ring latency
    home = [0] * n if use_ring else None

    for i in range(n):
        c = core_of[i]
        p = ports[i]
        ready = 0.0
        if use_ring:
            mycmg = cmg_of_core[c]
            if p < 0:
                for k in range(indptr[i], indptr[i + 1]):
                    f = finishes[indices[k]]
                    if f > ready:
                        ready = f
                # home = first producer's (static, so the scheduler and
                # _dataflow always agree; gte/bitcast have exactly one)
                home[i] = (home[indices[indptr[i]]]
                           if indptr[i + 1] > indptr[i] else mycmg)
            else:
                for k in range(indptr[i], indptr[i + 1]):
                    j = indices[k]
                    f = finishes[j]
                    if home[j] != mycmg:
                        f += ring_lat
                    if f > ready:
                        ready = f
                home[i] = mycmg
        else:
            for k in range(indptr[i], indptr[i + 1]):
                f = finishes[indices[k]]
                if f > ready:
                    ready = f
        crt = rt_hist[c]
        if p < 0:
            # free op: propagate readiness at zero cost; occupies a ROB slot
            finishes[i] = ready
            starts[i] = ready
            rp = rt_tail[c]
            if ready > rp:
                rp = ready
                rt_tail[c] = rp
            crt.append(rp)
            continue
        pl = pipes[c][p]
        if pl is None:
            pl = pipes[c][p] = [0.0] * widths[p]
            hist[c][p] = []
        start = ready
        why = 0
        pf = min(pl)
        if pf > start:
            start, why = pf, 1
        pos = len(crt)
        if pos >= window:
            wt = crt[pos - window]
            if wt > start:
                start, why = wt, 2
        h = hist[c][p]
        d = depths[p]
        if len(h) >= d:
            qt = h[-d]
            if qt > start:
                start, why = qt, 3
        finish = start + durs[i]
        pl[pl.index(pf)] = finish
        h.append(start)
        finishes[i] = finish
        starts[i] = start
        rp = rt_tail[c]
        if finish > rp:
            rp = finish
            rt_tail[c] = rp
        crt.append(rp)
        if finish > t_est:
            t_est = finish
        if finish > core_finish[c]:
            core_finish[c] = finish
        core_busy[c][p] += durs[i]
        core_nops[c] += 1
        if start > ready:
            dt = start - ready
            if why == 1:
                s_port += dt
            elif why == 2:
                s_window += dt
            else:
                s_queue += dt

    stall: Dict[str, float] = {}
    if s_port > 0:
        stall["port"] = s_port
    if s_window > 0:
        stall["window"] = s_window
    if s_queue > 0:
        stall["queue"] = s_queue
    return (t_est, stall, starts, finishes, core_busy, core_finish,
            core_nops)


def _dataflow(durs, ports, indptr, indices, core_of, cmg_of_core, ring_lat):
    """Infinite-resource critical path of the partitioned program,
    ring-latency edges included — the node schedule can never beat it.
    Mirrors the scheduler's ring rules: hops are charged against a
    value's HOME CMG (free pass-through ops inherit, not relay), and the
    makespan is the max over *costed* ops (free ops take no time, so a
    hop into a terminal free op is phantom, exactly as in t_est)."""
    n = len(durs)
    length = [0.0] * n
    t_df = 0.0
    use_ring = ring_lat > 0.0 and len(cmg_of_core) > 1
    home = [0] * n if use_ring else None
    for i in range(n):
        best = 0.0
        if use_ring:
            mycmg = cmg_of_core[core_of[i]]
            if ports[i] < 0:
                for k in range(indptr[i], indptr[i + 1]):
                    v = length[indices[k]]
                    if v > best:
                        best = v
                home[i] = (home[indices[indptr[i]]]
                           if indptr[i + 1] > indptr[i] else mycmg)
            else:
                for k in range(indptr[i], indptr[i + 1]):
                    j = indices[k]
                    v = length[j]
                    if home[j] != mycmg:
                        v += ring_lat
                    if v > best:
                        best = v
                home[i] = mycmg
        else:
            for k in range(indptr[i], indptr[i + 1]):
                v = length[indices[k]]
                if v > best:
                    best = v
        length[i] = durs[i] + best
        if ports[i] >= 0 and length[i] > t_df:
            t_df = length[i]
    return t_df


# ------------------------------------------------------------------ results
@dataclass
class CoreStat:
    """Per-core schedule stats of one node run."""
    core: int
    cmg: int
    t_finish: float              # last finish on this core
    port_busy: Dict[str, float]
    n_ops: int


@dataclass
class CmgStat:
    """Per-CMG contention report: active-core estimates + effective
    bandwidths at each shared level (DESIGN.md §14).
    """
    cmg: int
    n_cores: int                 # cores of this CMG used by the run
    n_active: Dict[str, float]   # level -> concurrently-active estimate
    eff_read_bw: Dict[str, float]    # per-core effective bytes/s
    eff_write_bw: Dict[str, float]
    occupancy: float             # max core-busy fraction of node makespan


@dataclass
class NodeResult:
    """Per-core timelines + node-level schedule + contention report
    (the multi-core node engine's output, DESIGN.md §14).

    ``t_est`` is the contention-aware node makespan;
    ``t_zero_contention`` the fixpoint's uncontended first pass, so every
    estimate ships inside the sandwich ``t_zero_contention <= t_est <=
    t_single_core`` (pinned by ``tests/test_node_engine.py``).
    ``schedule`` aggregates the per-core streams into a
    :class:`~.schedule.ScheduleResult`; ``per_cmg`` carries each CMG's
    concurrently-active estimates and effective shared-level bandwidths.
    Produced by ``schedule_node``/``simulate_node`` and surfaced as
    ``SimReport.node`` under ``simulate(engine="node")``; the model-zoo
    pipeline (DESIGN.md §15) sweeps it across a core-count axis.
    """
    t_est: float
    n_cores: int
    partition: str
    topology: NodeTopology
    schedule: ScheduleResult     # node-level aggregate
    per_core: List[CoreStat]
    per_cmg: List[CmgStat]
    t_zero_contention: float     # fixpoint iteration 0 (all levels at the
                                 # per-core draw limit): the lower bound
    iterations: int
    core_of: np.ndarray = field(repr=False, default=None)
    starts: np.ndarray = field(repr=False, default=None)
    finishes: np.ndarray = field(repr=False, default=None)

    @property
    def parallel_efficiency(self) -> float:
        """Busy time across cores / (n_cores x makespan)."""
        if self.t_est <= 0 or not self.per_core:
            return 1.0
        busy = sum(sum(c.port_busy.values()) for c in self.per_core)
        return busy / (len(self.per_core) * self.t_est)


# --------------------------------------------------------------- the engine
def _eff_inv(nc: NodeCompiled, topo: NodeTopology, cores: np.ndarray,
             n_active: List[np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
    """[k, L] inverse effective read/write bandwidth per used core."""
    k = len(cores)
    L = len(nc.level_names)
    inv_r = np.empty((k, L))
    inv_w = np.empty((k, L))
    for li, name in enumerate(nc.level_names):
        dom = cores // nc.shared_by[li]
        na = n_active[li][dom]
        inv_r[:, li] = 1.0 / effective_bandwidth(
            nc.core_read_bw[li], topo.shared_read_bw.get(name), na)
        inv_w[:, li] = 1.0 / effective_bandwidth(
            nc.core_write_bw[li], topo.shared_write_bw.get(name), na)
    return inv_r, inv_w


def _contended_durs(nc: NodeCompiled, inv_r_op: np.ndarray,
                    inv_w_op: np.ndarray, scale: float) -> List[float]:
    """Per-op durations under the given per-op inverse bandwidths; work
    (flops/bytes/payload) scaled by ``scale`` (sharding), latency and
    startup unscaled (every core still issues its slice of each op)."""
    t_mem = ((nc.rd * inv_r_op).sum(axis=1)
             + (nc.wr * inv_w_op).sum(axis=1)) * scale + nc.lat
    per = np.maximum(np.maximum(nc.t_comp * scale, t_mem),
                     nc.t_ici * scale)
    durs = (per + nc.startup) * nc.count
    # uncosted ops must stay zero-duration free ops
    durs[~nc.costed_mask] = 0.0
    return durs.tolist()


def schedule_node(nc: NodeCompiled, hw: HardwareSpec, n_cores: int,
                  topology: Optional[NodeTopology] = None,
                  partition: str = "round-robin",
                  core_of: Optional[np.ndarray] = None,
                  max_iters: int = 8, tol: float = 1e-2) -> NodeResult:
    """Schedule the compiled node form on ``n_cores`` cores.

    ``partition``: ``"round-robin"`` | ``"graph"`` | ``"shard"`` (every
    core runs the whole program at 1/n_cores of the work — the OpenMP
    thread-scaling mode the kernel suite reports), or pass an explicit
    ``core_of`` array.  The contention fixpoint starts uncontended (its
    first pass IS the zero-contention bound); the first update jumps
    straight to the measured concurrently-active estimate (fully
    mem-bound programs converge in one step because busy and makespan
    rescale together), later updates are 0.5-damped against oscillation,
    and the loop stops when the estimate moves less than ``tol`` cores.
    """
    topo = topology or hw.topology or NodeTopology.degenerate(n_cores)
    if n_cores < 1 or n_cores > max(topo.n_cores, 1):
        raise ValueError(f"n_cores={n_cores} outside topology "
                         f"{topo.name} (max {topo.n_cores})")
    cp = nc.cp
    widths = [max(1, hw.issue_width.get(p, 1)) for p in PORTS]
    depths = [max(1, hw.queue_depth.get(p, 1)) for p in PORTS]
    window = max(1, hw.inflight_window)
    L = len(nc.level_names)
    shard = partition == "shard"
    scale = (1.0 / n_cores) if shard else 1.0

    # cores used by this run (compact pinning: CMG c//cores_per_cmg)
    cores = np.arange(n_cores, dtype=np.int64)
    cmg_of_used = (cores // max(1, topo.cores_per_cmg)).tolist()
    if shard:
        sched_core_of = np.zeros(nc.n, dtype=np.int64)
        sched_cmgs = [0]
    elif core_of is not None:
        sched_core_of = np.asarray(core_of, dtype=np.int64)
        sched_cmgs = cmg_of_used
    elif partition == "graph":
        sched_core_of = partition_graph(nc, n_cores)
        sched_cmgs = cmg_of_used
    elif partition == "round-robin":
        sched_core_of = partition_round_robin(nc.n, n_cores)
        sched_cmgs = cmg_of_used
    else:
        raise ValueError(f"unknown partition {partition!r}")
    core_of_l = sched_core_of.tolist()

    # a level is contended only when the topology caps it AND >1 core
    # shares the domain; otherwise the fixpoint is a single exact pass
    has_caps = any(nm in topo.shared_read_bw or nm in topo.shared_write_bw
                   for nm in nc.level_names)
    contended = has_caps and n_cores > 1

    # concurrently-active estimate per (level, sharing domain)
    n_active = [np.ones(int(np.ceil(n_cores / nc.shared_by[li])))
                for li in range(L)]
    # cores of each domain that actually have costed work
    port_arr = np.asarray(nc.cp._port_l)
    if shard:
        work_cores = cores          # every virtual core runs the stream
    else:
        has_work = np.zeros(n_cores, dtype=bool)
        has_work[sched_core_of[port_arr >= 0]] = True
        work_cores = cores[has_work[cores]]
    active_per_dom = [np.maximum(np.bincount(
        work_cores // nc.shared_by[li],
        minlength=len(n_active[li])).astype(float), 1.0)
        for li in range(L)]

    ring_lat = topo.ring_latency_s if not shard else 0.0
    ports_l = cp._port_l
    indptr_l = cp._indptr_l
    indices_l = cp._indices_l

    t_zero = None
    iterations = 0
    counts = nc.count
    final = not contended
    while True:
        iterations += 1
        uncontended = all(float(a.max(initial=1.0)) <= 1.0
                          for a in n_active)
        if uncontended and scale == 1.0:
            # exact path: reuse the single-core compiled durations
            # bit-for-bit (recomposing t_mem from the per-level split
            # reassociates float adds)
            durs = cp._dur_l
            inv_r = inv_w = None
        else:
            inv_r, inv_w = _eff_inv(nc, topo, cores, n_active)
            if shard:
                # every virtual core runs the stream; core 0 sits in the
                # fullest sharing domain (compact pinning), so its
                # bandwidths govern the makespan
                row, row_w = inv_r[0], inv_w[0]
            else:
                row, row_w = inv_r[sched_core_of], inv_w[sched_core_of]
            durs = _contended_durs(nc, row, row_w, scale)
        res = _node_pass(durs, ports_l, indptr_l, indices_l, core_of_l,
                         sched_cmgs, widths, depths, window, ring_lat)
        t_node = res[0]
        if t_zero is None:
            t_zero = t_node
        if final:
            break
        # analytic per-core level-busy under the bandwidths just used
        if inv_r is None:
            inv_r, inv_w = _eff_inv(nc, topo, cores, n_active)
        stream_inv_r = inv_r[0] if shard else inv_r[sched_core_of]
        stream_inv_w = inv_w[0] if shard else inv_w[sched_core_of]
        contrib = (nc.rd * stream_inv_r + nc.wr * stream_inv_w) \
            * (scale * counts)[:, None]
        if shard:
            core_level_busy = np.broadcast_to(contrib.sum(axis=0),
                                              (n_cores, L))
        else:
            core_level_busy = np.zeros((n_cores, L))
            np.add.at(core_level_busy, sched_core_of, contrib)
        delta = 0.0
        new_active = []
        damp = 0.5 if iterations > 1 else 1.0
        for li in range(L):
            dom_busy = np.bincount(cores // nc.shared_by[li],
                                   weights=core_level_busy[:, li],
                                   minlength=len(n_active[li]))
            target = np.clip(dom_busy / max(t_node, 1e-30), 1.0,
                             active_per_dom[li])
            nxt = damp * target + (1.0 - damp) * n_active[li]
            delta = max(delta, float(np.abs(nxt - n_active[li]).max(
                initial=0.0)))
            new_active.append(nxt)
        n_active = new_active
        if delta == 0.0:
            # n_active (hence durations) unchanged: the pass just taken
            # IS the converged schedule — no re-run needed (the common
            # compute-bound case, where every target clamps to 1)
            break
        # once the estimate stops moving (or the budget runs out), one
        # last pass of the same block above runs under the converged
        # n_active and breaks
        final = delta < tol or iterations >= max_iters

    t_est, stall, starts, finishes, core_busy, core_finish, core_nops = res

    # --- node-level ScheduleResult.  In shard mode the pass scheduled ONE
    # representative stream; every core runs it, so node aggregates
    # (port_busy / t_serial / n_ops) scale by n_cores — keeping their
    # semantics identical to the op-partition modes, where the pass
    # already covers all cores.
    agg = float(n_cores) if shard else 1.0
    port_busy: Dict[str, float] = {}
    for cb in core_busy:
        for pid, b in enumerate(cb):
            if b > 0:
                port_busy[PORTS[pid]] = port_busy.get(PORTS[pid], 0.0) \
                    + b * agg
    # schedule-consistent lower bound: busiest (core, port) pipe
    per_core_roof = max((b / widths[pid]
                         for cb in core_busy for pid, b in enumerate(cb)
                         if b > 0), default=0.0)
    t_serial = float(sum(durs)) * agg
    t_dataflow = _dataflow(durs, ports_l, indptr_l, indices_l, core_of_l,
                           sched_cmgs, ring_lat)
    sched = ScheduleResult(
        t_est=t_est, t_roofline=per_core_roof, t_serial=t_serial,
        t_dataflow=t_dataflow, port_busy=port_busy,
        n_ops=cp.n_ops * agg, n_edges=cp.n_edges, stall_by_reason=stall,
        issue_width=dict(hw.issue_width))

    # --- per-core stats (shard: every core runs the representative stream)
    per_core: List[CoreStat] = []
    for c in range(n_cores):
        src = 0 if shard else c
        per_core.append(CoreStat(
            core=c, cmg=int(cmg_of_used[c]),
            t_finish=core_finish[src],
            port_busy={PORTS[pid]: b for pid, b in
                       enumerate(core_busy[src]) if b > 0},
            n_ops=core_nops[src]))

    # --- per-CMG contention report
    per_cmg: List[CmgStat] = []
    inv_final = _eff_inv(nc, topo, cores, n_active)
    mk = max(t_est, 1e-30)
    for g in range(int(max(cmg_of_used)) + 1):
        gcores = [c for c in range(n_cores) if cmg_of_used[c] == g]
        na: Dict[str, float] = {}
        er: Dict[str, float] = {}
        ew: Dict[str, float] = {}
        for li, nm in enumerate(nc.level_names):
            if nm not in topo.shared_read_bw and \
                    nm not in topo.shared_write_bw:
                continue
            dom = gcores[0] // int(nc.shared_by[li])
            na[nm] = float(n_active[li][dom])
            er[nm] = 1.0 / float(inv_final[0][gcores[0], li])
            ew[nm] = 1.0 / float(inv_final[1][gcores[0], li])
        occ = max((sum(core_busy[0 if shard else c]) / mk
                   for c in gcores), default=0.0)
        per_cmg.append(CmgStat(cmg=g, n_cores=len(gcores), n_active=na,
                               eff_read_bw=er, eff_write_bw=ew,
                               occupancy=occ))

    return NodeResult(
        t_est=t_est, n_cores=n_cores, partition=partition, topology=topo,
        schedule=sched, per_core=per_core, per_cmg=per_cmg,
        t_zero_contention=t_zero, iterations=iterations,
        core_of=sched_core_of, starts=np.asarray(starts),
        finishes=np.asarray(finishes))


def simulate_node(prog: Program, hw: HardwareSpec, n_cores: int,
                  topology: Optional[NodeTopology] = None,
                  partition: str = "round-robin",
                  links_per_collective: int = 2,
                  compute_dtype: Optional[str] = None,
                  costed: Optional[List[Optional[OpTime]]] = None,
                  **kw) -> NodeResult:
    """Cost + compile + node-schedule in one call (the ``simulate``
    entry point's ``engine="node"`` backend)."""
    nc = compile_node(prog, hw, links_per_collective, compute_dtype, costed)
    return schedule_node(nc, hw, n_cores, topology, partition, **kw)


def shard_costed(prog: Program, hw: HardwareSpec, n_cores: int,
                 topology: Optional[NodeTopology] = None,
                 links_per_collective: int = 2,
                 compute_dtype: Optional[str] = None
                 ) -> List[Optional[OpTime]]:
    """The shard-mode node model as a costed list: per-op times scaled by
    1/n_cores with the converged contention applied, suitable for
    ``compile_program(costed=...)`` — this is how the O3 knob sweep rides
    ``schedule_batch`` with core count as an extra grid axis (the knob
    grid batches over one shard-contended compiled program per core
    count)."""
    nc = compile_node(prog, hw, links_per_collective, compute_dtype)
    nr = schedule_node(nc, hw, n_cores, topology, partition="shard")
    topo = nr.topology
    cores = np.arange(n_cores, dtype=np.int64)
    # rebuild the converged per-level inverse bandwidths from the report
    n_active = []
    for li, nm in enumerate(nc.level_names):
        n_dom = int(np.ceil(n_cores / nc.shared_by[li]))
        na = np.ones(n_dom)
        for cs in nr.per_cmg:
            if nm in cs.n_active:
                na[:] = cs.n_active[nm]
                break
        n_active.append(na)
    inv_r, inv_w = _eff_inv(nc, topo, cores, n_active)
    scale = 1.0 / n_cores
    t_mem = ((nc.rd * inv_r[0]).sum(axis=1)
             + (nc.wr * inv_w[0]).sum(axis=1)) * scale + nc.lat
    base = cost_program(prog, hw, links_per_collective, compute_dtype)
    out: List[Optional[OpTime]] = []
    for i, ot in enumerate(base):
        if ot is None:
            out.append(None)
            continue
        out.append(dataclasses.replace(
            ot, t_compute=ot.t_compute * scale,
            t_mem=float(t_mem[i]) if ot.traffic is not None else 0.0,
            t_ici=ot.t_ici * scale))
    return out
