"""Multi-core node engine — contention-aware scheduling across CMG cores.

The paper's stated target is the execution time of *one node* application;
PRs 1-3 built a per-kernel cost model (one core, or one core drawing a
hardcoded share of the CMG's bandwidth).  This module is the node layer
on top of the compiled SoA core (DESIGN.md §14):

* a per-core ``HardwareSpec`` plus a :class:`~.hwspec.NodeTopology`
  describe the node: per-core paths are single-core draw limits,
  ``MemLevel.shared_by`` marks CMG-shared levels, the topology carries
  each sharing domain's aggregate bandwidth and the inter-CMG ring;
* a costed :class:`~.hlo.Program` is partitioned across cores —
  op-level round-robin, a def-use-aware greedy graph partition, or
  OpenMP-style data-parallel sharding (every core runs the whole program
  at ``1/n_cores`` of the work, the kernel-suite mode);
* one in-order stream per core runs through the existing compiled
  machinery (per-``(core, port)`` pipes, per-core ROB windows and
  reservation queues — the same float ops as ``schedule_arrays``, which
  is why ``n_cores=1`` under a degenerate topology is bit-identical to
  the single-core fast path), with readiness propagated globally across
  cores and cross-CMG def-use edges charged the ring latency;
* a bandwidth-contention fixpoint divides each shared level's aggregate
  among the cores actively streaming through it: the concurrently-active
  estimate ``n_active = clamp(sum_c busy_c / t_node, 1, cores)`` feeds
  back into per-op memory times (reusing ``route_program``'s per-level
  residency split) until it stabilizes.

``schedule_node`` returns a :class:`NodeResult`: per-core timelines, a
node-level :class:`~.schedule.ScheduleResult`, per-CMG contention/
occupancy, and the zero-contention bound (the fixpoint's first
iteration), so every estimate ships with its own sandwich
``t_zero_contention <= t_est <= t_single_core`` (asserted by the node
test harness).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .compiled import PORTS, CompiledProgram, O3Knobs, compile_program
from .cost import BatchCosted, OpTime, cost_program, cost_program_batch
from .hlo import Program
from .hwspec import HardwareSpec, NodeTopology, SpecGrid
from .schedule import ScheduleResult

_NODE_CACHE_SIZE = 8


def effective_bandwidth(core_bw, shared_bw, n_active):
    """Per-core effective bandwidth at a shared level: the single-core
    draw limit, capped by an equal share of the domain aggregate among
    the ``n_active`` cores concurrently streaming through it.  Monotone
    non-increasing in ``n_active`` (property-tested).  Scalar or
    elementwise over arrays — ``_eff_inv`` calls this, so the property
    test binds the engine's actual contention math."""
    if shared_bw is None:
        return core_bw
    return np.minimum(core_bw, shared_bw / np.maximum(n_active, 1.0))


# ------------------------------------------------------------ compiled form
@dataclass
class NodeCompiled:
    """Per-(program, spec, dtype) node form: the single-core compiled
    program plus the per-op/per-level cost decomposition the contention
    fixpoint rescales (``t_mem = rd @ inv_read + wr @ inv_write + lat``).
    """
    cp: CompiledProgram
    n: int
    t_comp: np.ndarray           # [n] per-instance compute time
    t_ici: np.ndarray            # [n]
    lat: np.ndarray              # [n] hierarchy access latency (uncontended)
    count: np.ndarray            # [n]
    rd: np.ndarray               # [n, L] routed read bytes per level
    wr: np.ndarray               # [n, L] routed write bytes per level
    level_names: Tuple[str, ...]
    core_read_bw: np.ndarray     # [L] per-core paths
    core_write_bw: np.ndarray
    shared_by: np.ndarray        # [L] sharing-domain size per level
    startup: float
    costed_mask: np.ndarray = None   # [n] bool: port_id >= 0


def compile_node(prog: Program, hw: HardwareSpec,
                 links_per_collective: int = 2,
                 compute_dtype: Optional[str] = None,
                 costed: Optional[List[Optional[OpTime]]] = None
                 ) -> NodeCompiled:
    """Compile (and memoize on the Program) the node form.  The cache is
    keyed by the frozen spec's VALUE (like ``compile_program``'s), so a
    value-equal spec rebuilt via ``dataclasses.replace``/``with_`` hits
    it.  A caller-supplied ``costed`` list bypasses the cache, mirroring
    ``compile_program``."""
    if costed is None:
        cache = prog.__dict__.setdefault("_node_cache", [])
        for chw, cdt, clk, cnc in cache:
            if cdt == compute_dtype and clk == links_per_collective \
                    and chw == hw:
                return cnc
        costed = cost_program(prog, hw, links_per_collective, compute_dtype)
    else:
        cache = None
    cp = compile_program(prog, hw, links_per_collective, compute_dtype,
                         costed=costed)
    levels = hw.memory_hierarchy()
    L = len(levels)
    n = len(prog.ops)
    lidx = {lv.name: i for i, lv in enumerate(levels)}
    t_comp = np.zeros(n)
    t_ici = np.zeros(n)
    lat = np.zeros(n)
    count = np.ones(n)
    rd = np.zeros((n, L))
    wr = np.zeros((n, L))
    for i, ot in enumerate(costed):
        if ot is None:
            continue
        t_comp[i] = ot.t_compute
        t_ici[i] = ot.t_ici
        count[i] = ot.op.count
        tr = ot.traffic
        if tr is not None:
            lat[i] = tr.latency_s
            for nm, b in tr.read_by_level.items():
                rd[i, lidx[nm]] = b
            for nm, b in tr.write_by_level.items():
                wr[i, lidx[nm]] = b
    nc = NodeCompiled(
        cp=cp, n=n, t_comp=t_comp, t_ici=t_ici, lat=lat, count=count,
        rd=rd, wr=wr, level_names=tuple(lv.name for lv in levels),
        core_read_bw=np.array([lv.read_bw for lv in levels]),
        core_write_bw=np.array([lv.write_bw for lv in levels]),
        shared_by=np.array([max(1, lv.shared_by) for lv in levels],
                           dtype=np.int64),
        startup=hw.op_startup_ns * 1e-9,
        costed_mask=cp.port_id >= 0,
    )
    if cache is not None:
        cache.append((hw, compute_dtype, links_per_collective, nc))
        if len(cache) > _NODE_CACHE_SIZE:
            cache.pop(0)
    return nc


# ------------------------------------------------------------- partitioning
def partition_round_robin(n: int, n_cores: int) -> np.ndarray:
    """Op-level round-robin over program order (free ops included: they
    occupy their core's ROB slots exactly like the single-core kernels)."""
    return np.arange(n, dtype=np.int64) % max(1, n_cores)


def partition_graph(nc: NodeCompiled, n_cores: int,
                    balance: float = 1.25) -> np.ndarray:
    """Def-use-aware greedy partition: follow each op's heaviest producer
    onto its core while that core's load stays under ``balance`` x the
    even share, else fall to the least-loaded core.  Keeps dependence
    chains co-located (fewer cross-core readiness waits and ring hops)
    while bounding imbalance.  Deterministic."""
    n_cores = max(1, n_cores)
    durs = nc.cp._dur_l
    indptr = nc.cp._indptr_l
    indices = nc.cp._indices_l
    core_of = np.zeros(nc.n, dtype=np.int64)
    load = [0.0] * n_cores
    cap = balance * (sum(durs) / n_cores) + 1e-30
    for i in range(nc.n):
        pref = -1
        best = -1.0
        for k in range(indptr[i], indptr[i + 1]):
            j = indices[k]
            if durs[j] > best:
                best, pref = durs[j], int(core_of[j])
        if pref < 0 or load[pref] + durs[i] > cap:
            pref = min(range(n_cores), key=load.__getitem__)
        core_of[i] = pref
        load[pref] += durs[i]
    return core_of


# ------------------------------------------------------- the node scheduler
def _node_pass(durs, ports, indptr, indices, core_of, cmg_of_core,
               widths, depths, window, ring_lat):
    """One global in-order pass over the ops with per-(core, port) pipes,
    per-core ROB windows and reservation queues, and globally-propagated
    readiness (+ ring latency on cross-CMG def-use edges).  With one core
    this replays ``schedule_arrays``'s float operations in the same
    order, hence bit-identical results (the differential tests pin it).
    """
    n = len(durs)
    P = len(PORTS)
    n_cores = len(cmg_of_core)
    finishes = [0.0] * n
    starts = [0.0] * n
    rt_tail = [0.0] * n_cores                 # per-core worst retire seen
    # Bounded ring buffers (they were O(n)-growing lists): the ROB check
    # only ever reads the retire entry `window` positions back on the
    # op's core, and the queue check the issue start `depth` back on the
    # op's (core, port) — slot (pos - window) % window == pos % window,
    # so one window-sized ring per core (and one depth-sized ring per
    # pipe) replays the exact same reads.  A ring never needs more slots
    # than the stream has ops: when window > n the read is unreachable.
    rt_size = max(1, min(window, n))
    rt_ring: List[Optional[List[float]]] = [None] * n_cores
    rt_pos = [0] * n_cores                    # per-core ops seen (= old len)
    pipes: List[List[Optional[List[float]]]] = \
        [[None] * P for _ in range(n_cores)]
    hist: List[List[Optional[List[float]]]] = \
        [[None] * P for _ in range(n_cores)]
    hist_pos = [[0] * P for _ in range(n_cores)]
    core_busy = [[0.0] * P for _ in range(n_cores)]
    core_finish = [0.0] * n_cores
    core_nops = [0] * n_cores
    s_port = s_window = s_queue = 0.0
    t_est = 0.0
    use_ring = ring_lat > 0.0 and n_cores > 1
    # a value's home CMG: where it was produced.  Free ops (gte/bitcast/
    # tuple) are pass-throughs — they inherit their binding producer's
    # home and charge no hop themselves, so data consumed on its own CMG
    # through a scattered free op pays no phantom ring latency
    home = [0] * n if use_ring else None

    for i in range(n):
        c = core_of[i]
        p = ports[i]
        ready = 0.0
        if use_ring:
            mycmg = cmg_of_core[c]
            if p < 0:
                for k in range(indptr[i], indptr[i + 1]):
                    f = finishes[indices[k]]
                    if f > ready:
                        ready = f
                # home = first producer's (static, so the scheduler and
                # _dataflow always agree; gte/bitcast have exactly one)
                home[i] = (home[indices[indptr[i]]]
                           if indptr[i + 1] > indptr[i] else mycmg)
            else:
                for k in range(indptr[i], indptr[i + 1]):
                    j = indices[k]
                    f = finishes[j]
                    if home[j] != mycmg:
                        f += ring_lat
                    if f > ready:
                        ready = f
                home[i] = mycmg
        else:
            for k in range(indptr[i], indptr[i + 1]):
                f = finishes[indices[k]]
                if f > ready:
                    ready = f
        crt = rt_ring[c]
        if crt is None:
            crt = rt_ring[c] = [0.0] * rt_size
        pos = rt_pos[c]
        rt_pos[c] = pos + 1
        if p < 0:
            # free op: propagate readiness at zero cost; occupies a ROB slot
            finishes[i] = ready
            starts[i] = ready
            rp = rt_tail[c]
            if ready > rp:
                rp = ready
                rt_tail[c] = rp
            crt[pos % rt_size] = rp
            continue
        pl = pipes[c][p]
        d = depths[p]
        if pl is None:
            pl = pipes[c][p] = [0.0] * widths[p]
            hist[c][p] = [0.0] * d
        start = ready
        why = 0
        pf = min(pl)
        if pf > start:
            start, why = pf, 1
        if pos >= window:
            wt = crt[pos % rt_size]      # == (pos - window) % window
            if wt > start:
                start, why = wt, 2
        h = hist[c][p]
        hp = hist_pos[c][p]
        if hp >= d:
            qt = h[hp % d]               # == (hp - d) % d
            if qt > start:
                start, why = qt, 3
        finish = start + durs[i]
        pl[pl.index(pf)] = finish
        h[hp % d] = start
        hist_pos[c][p] = hp + 1
        finishes[i] = finish
        starts[i] = start
        rp = rt_tail[c]
        if finish > rp:
            rp = finish
            rt_tail[c] = rp
        crt[pos % rt_size] = rp
        if finish > t_est:
            t_est = finish
        if finish > core_finish[c]:
            core_finish[c] = finish
        core_busy[c][p] += durs[i]
        core_nops[c] += 1
        if start > ready:
            dt = start - ready
            if why == 1:
                s_port += dt
            elif why == 2:
                s_window += dt
            else:
                s_queue += dt

    stall: Dict[str, float] = {}
    if s_port > 0:
        stall["port"] = s_port
    if s_window > 0:
        stall["window"] = s_window
    if s_queue > 0:
        stall["queue"] = s_queue
    return (t_est, stall, starts, finishes, core_busy, core_finish,
            core_nops)


def _dataflow(durs, ports, indptr, indices, core_of, cmg_of_core, ring_lat):
    """Infinite-resource critical path of the partitioned program,
    ring-latency edges included — the node schedule can never beat it.
    Mirrors the scheduler's ring rules: hops are charged against a
    value's HOME CMG (free pass-through ops inherit, not relay), and the
    makespan is the max over *costed* ops (free ops take no time, so a
    hop into a terminal free op is phantom, exactly as in t_est)."""
    n = len(durs)
    length = [0.0] * n
    t_df = 0.0
    use_ring = ring_lat > 0.0 and len(cmg_of_core) > 1
    home = [0] * n if use_ring else None
    for i in range(n):
        best = 0.0
        if use_ring:
            mycmg = cmg_of_core[core_of[i]]
            if ports[i] < 0:
                for k in range(indptr[i], indptr[i + 1]):
                    v = length[indices[k]]
                    if v > best:
                        best = v
                home[i] = (home[indices[indptr[i]]]
                           if indptr[i + 1] > indptr[i] else mycmg)
            else:
                for k in range(indptr[i], indptr[i + 1]):
                    j = indices[k]
                    v = length[j]
                    if home[j] != mycmg:
                        v += ring_lat
                    if v > best:
                        best = v
                home[i] = mycmg
        else:
            for k in range(indptr[i], indptr[i + 1]):
                v = length[indices[k]]
                if v > best:
                    best = v
        length[i] = durs[i] + best
        if ports[i] >= 0 and length[i] > t_df:
            t_df = length[i]
    return t_df


# ------------------------------------------------------------------ results
@dataclass
class CoreStat:
    """Per-core schedule stats of one node run."""
    core: int
    cmg: int
    t_finish: float              # last finish on this core
    port_busy: Dict[str, float]
    n_ops: int


@dataclass
class CmgStat:
    """Per-CMG contention report: active-core estimates + effective
    bandwidths at each shared level (DESIGN.md §14).
    """
    cmg: int
    n_cores: int                 # cores of this CMG used by the run
    n_active: Dict[str, float]   # level -> concurrently-active estimate
    eff_read_bw: Dict[str, float]    # per-core effective bytes/s
    eff_write_bw: Dict[str, float]
    occupancy: float             # max core-busy fraction of node makespan


@dataclass
class NodeResult:
    """Per-core timelines + node-level schedule + contention report
    (the multi-core node engine's output, DESIGN.md §14).

    ``t_est`` is the contention-aware node makespan;
    ``t_zero_contention`` the fixpoint's uncontended first pass, so every
    estimate ships inside the sandwich ``t_zero_contention <= t_est <=
    t_single_core`` (pinned by ``tests/test_node_engine.py``).
    ``schedule`` aggregates the per-core streams into a
    :class:`~.schedule.ScheduleResult`; ``per_cmg`` carries each CMG's
    concurrently-active estimates and effective shared-level bandwidths.
    Produced by ``schedule_node``/``simulate_node`` and surfaced as
    ``SimReport.node`` under ``simulate(engine="node")``; the model-zoo
    pipeline (DESIGN.md §15) sweeps it across a core-count axis.
    """
    t_est: float
    n_cores: int
    partition: str
    topology: NodeTopology
    schedule: ScheduleResult     # node-level aggregate
    per_core: List[CoreStat]
    per_cmg: List[CmgStat]
    t_zero_contention: float     # fixpoint iteration 0 (all levels at the
                                 # per-core draw limit): the lower bound
    iterations: int
    core_of: np.ndarray = field(repr=False, default=None)
    starts: np.ndarray = field(repr=False, default=None)
    finishes: np.ndarray = field(repr=False, default=None)

    @property
    def parallel_efficiency(self) -> float:
        """Busy time across cores / (n_cores x makespan)."""
        if self.t_est <= 0 or not self.per_core:
            return 1.0
        busy = sum(sum(c.port_busy.values()) for c in self.per_core)
        return busy / (len(self.per_core) * self.t_est)


# --------------------------------------------------------------- the engine
def _eff_inv(nc: NodeCompiled, topo: NodeTopology, cores: np.ndarray,
             n_active: List[np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
    """[k, L] inverse effective read/write bandwidth per used core."""
    k = len(cores)
    L = len(nc.level_names)
    inv_r = np.empty((k, L))
    inv_w = np.empty((k, L))
    for li, name in enumerate(nc.level_names):
        dom = cores // nc.shared_by[li]
        na = n_active[li][dom]
        inv_r[:, li] = 1.0 / effective_bandwidth(
            nc.core_read_bw[li], topo.shared_read_bw.get(name), na)
        inv_w[:, li] = 1.0 / effective_bandwidth(
            nc.core_write_bw[li], topo.shared_write_bw.get(name), na)
    return inv_r, inv_w


def _contended_durs_arr(nc: NodeCompiled, inv_r_op: np.ndarray,
                        inv_w_op: np.ndarray, scale: float) -> np.ndarray:
    """Per-op durations under the given per-op inverse bandwidths; work
    (flops/bytes) scaled by ``scale`` (sharding), latency and startup
    unscaled (every core still issues its slice of each op).  Collective
    time is NOT scaled: the payload rides the node-level interconnect,
    which every core's slice serializes on — sharding an op across more
    cores does not add inter-node links (the cluster engine's degenerate
    case pins this)."""
    t_mem = ((nc.rd * inv_r_op).sum(axis=1)
             + (nc.wr * inv_w_op).sum(axis=1)) * scale + nc.lat
    per = np.maximum(np.maximum(nc.t_comp * scale, t_mem), nc.t_ici)
    durs = (per + nc.startup) * nc.count
    # uncosted ops must stay zero-duration free ops
    durs[~nc.costed_mask] = 0.0
    return durs


def _contended_durs(nc: NodeCompiled, inv_r_op: np.ndarray,
                    inv_w_op: np.ndarray, scale: float) -> List[float]:
    """List form of :func:`_contended_durs_arr` for the scalar pass."""
    return _contended_durs_arr(nc, inv_r_op, inv_w_op, scale).tolist()


def _resolve_partition(nc: NodeCompiled, topo: NodeTopology, n_cores: int,
                       partition: str, core_of: Optional[np.ndarray]):
    """Partition plumbing shared by the scalar and batched engines:
    ``(sched_core_of, sched_cmgs, shard, scale, ring_lat, cores)``."""
    shard = partition == "shard"
    # cores used by this run (compact pinning: CMG c//cores_per_cmg)
    cores = np.arange(n_cores, dtype=np.int64)
    cmg_of_used = (cores // max(1, topo.cores_per_cmg)).tolist()
    if shard:
        sched_core_of = np.zeros(nc.n, dtype=np.int64)
        sched_cmgs = [0]
    elif core_of is not None:
        sched_core_of = np.asarray(core_of, dtype=np.int64)
        sched_cmgs = cmg_of_used
    elif partition == "graph":
        sched_core_of = partition_graph(nc, n_cores)
        sched_cmgs = cmg_of_used
    elif partition == "round-robin":
        sched_core_of = partition_round_robin(nc.n, n_cores)
        sched_cmgs = cmg_of_used
    else:
        raise ValueError(f"unknown partition {partition!r}")
    ring_lat = topo.ring_latency_s if not shard else 0.0
    scale = (1.0 / n_cores) if shard else 1.0
    return sched_core_of, sched_cmgs, shard, scale, ring_lat, cores


def _work_domains(nc: NodeCompiled, n_cores: int, shard: bool,
                  sched_core_of: np.ndarray, cores: np.ndarray):
    """Initial ``n_active`` (all ones) and per-domain active-core caps
    (cores of each sharing domain that actually have costed work)."""
    L = len(nc.level_names)
    n_active = [np.ones(int(np.ceil(n_cores / nc.shared_by[li])))
                for li in range(L)]
    port_arr = np.asarray(nc.cp._port_l)
    if shard:
        work_cores = cores          # every virtual core runs the stream
    else:
        has_work = np.zeros(n_cores, dtype=bool)
        has_work[sched_core_of[port_arr >= 0]] = True
        work_cores = cores[has_work[cores]]
    active_per_dom = [np.maximum(np.bincount(
        work_cores // nc.shared_by[li],
        minlength=len(n_active[li])).astype(float), 1.0)
        for li in range(L)]
    return n_active, active_per_dom


def _update_active(nc: NodeCompiled, topo: NodeTopology, cores: np.ndarray,
                   n_active: List[np.ndarray], sched_core_of: np.ndarray,
                   shard: bool, scale: float, n_cores: int, t_node: float,
                   active_per_dom: List[np.ndarray], damp: float):
    """One fixpoint update of the concurrently-active estimates:
    analytic per-core level-busy under the current bandwidths, then
    ``n_active = damp * clamp(dom_busy / t_node, 1, active) + (1-damp) *
    prev``.  Returns ``(new_active, delta)``.  Pure function of its
    inputs — the batched driver replays the scalar trajectory with it."""
    L = len(nc.level_names)
    inv_r, inv_w = _eff_inv(nc, topo, cores, n_active)
    stream_inv_r = inv_r[0] if shard else inv_r[sched_core_of]
    stream_inv_w = inv_w[0] if shard else inv_w[sched_core_of]
    contrib = (nc.rd * stream_inv_r + nc.wr * stream_inv_w) \
        * (scale * nc.count)[:, None]
    if shard:
        core_level_busy = np.broadcast_to(contrib.sum(axis=0),
                                          (n_cores, L))
    delta = 0.0
    new_active = []
    for li in range(L):
        if shard:
            dom_busy = np.bincount(cores // nc.shared_by[li],
                                   weights=core_level_busy[:, li],
                                   minlength=len(n_active[li]))
        else:
            # domain-sum the per-op contributions directly (one weighted
            # bincount; np.add.at into per-core rows was the hot spot)
            dom_busy = np.bincount(sched_core_of // nc.shared_by[li],
                                   weights=contrib[:, li],
                                   minlength=len(n_active[li]))
        target = np.clip(dom_busy / max(t_node, 1e-30), 1.0,
                         active_per_dom[li])
        nxt = damp * target + (1.0 - damp) * n_active[li]
        delta = max(delta, float(np.abs(nxt - n_active[li]).max(
            initial=0.0)))
        new_active.append(nxt)
    return new_active, delta


def schedule_node(nc: NodeCompiled, hw: HardwareSpec, n_cores: int,
                  topology: Optional[NodeTopology] = None,
                  partition: str = "round-robin",
                  core_of: Optional[np.ndarray] = None,
                  max_iters: int = 8, tol: float = 1e-2) -> NodeResult:
    """Schedule the compiled node form on ``n_cores`` cores.

    ``partition``: ``"round-robin"`` | ``"graph"`` | ``"shard"`` (every
    core runs the whole program at 1/n_cores of the work — the OpenMP
    thread-scaling mode the kernel suite reports), or pass an explicit
    ``core_of`` array.  The contention fixpoint starts uncontended (its
    first pass IS the zero-contention bound); the first update jumps
    straight to the measured concurrently-active estimate (fully
    mem-bound programs converge in one step because busy and makespan
    rescale together), later updates are 0.5-damped against oscillation,
    and the loop stops when the estimate moves less than ``tol`` cores.
    """
    topo = topology or hw.topology or NodeTopology.degenerate(n_cores)
    if n_cores < 1 or n_cores > max(topo.n_cores, 1):
        raise ValueError(f"n_cores={n_cores} outside topology "
                         f"{topo.name} (max {topo.n_cores})")
    cp = nc.cp
    widths = [max(1, hw.issue_width.get(p, 1)) for p in PORTS]
    depths = [max(1, hw.queue_depth.get(p, 1)) for p in PORTS]
    window = max(1, hw.inflight_window)
    sched_core_of, sched_cmgs, shard, scale, ring_lat, cores = \
        _resolve_partition(nc, topo, n_cores, partition, core_of)
    cmg_of_used = (cores // max(1, topo.cores_per_cmg)).tolist()
    core_of_l = sched_core_of.tolist()

    # a level is contended only when the topology caps it AND >1 core
    # shares the domain; otherwise the fixpoint is a single exact pass
    has_caps = any(nm in topo.shared_read_bw or nm in topo.shared_write_bw
                   for nm in nc.level_names)
    contended = has_caps and n_cores > 1

    # concurrently-active estimate per (level, sharing domain) + the
    # cores of each domain that actually have costed work
    n_active, active_per_dom = _work_domains(nc, n_cores, shard,
                                             sched_core_of, cores)

    ports_l = cp._port_l
    indptr_l = cp._indptr_l
    indices_l = cp._indices_l

    t_zero = None
    iterations = 0
    final = not contended
    while True:
        iterations += 1
        uncontended = all(float(a.max(initial=1.0)) <= 1.0
                          for a in n_active)
        if uncontended and scale == 1.0:
            # exact path: reuse the single-core compiled durations
            # bit-for-bit (recomposing t_mem from the per-level split
            # reassociates float adds)
            durs = cp._dur_l
        else:
            inv_r, inv_w = _eff_inv(nc, topo, cores, n_active)
            if shard:
                # every virtual core runs the stream; core 0 sits in the
                # fullest sharing domain (compact pinning), so its
                # bandwidths govern the makespan
                row, row_w = inv_r[0], inv_w[0]
            else:
                row, row_w = inv_r[sched_core_of], inv_w[sched_core_of]
            durs = _contended_durs(nc, row, row_w, scale)
        res = _node_pass(durs, ports_l, indptr_l, indices_l, core_of_l,
                         sched_cmgs, widths, depths, window, ring_lat)
        t_node = res[0]
        if t_zero is None:
            t_zero = t_node
        if final:
            break
        damp = 0.5 if iterations > 1 else 1.0
        n_active, delta = _update_active(
            nc, topo, cores, n_active, sched_core_of, shard, scale,
            n_cores, t_node, active_per_dom, damp)
        if delta == 0.0:
            # n_active (hence durations) unchanged: the pass just taken
            # IS the converged schedule — no re-run needed (the common
            # compute-bound case, where every target clamps to 1)
            break
        # once the estimate stops moving (or the budget runs out), one
        # last pass of the same block above runs under the converged
        # n_active and breaks
        final = delta < tol or iterations >= max_iters

    t_est, stall, starts, finishes, core_busy, core_finish, core_nops = res

    # --- node-level ScheduleResult.  In shard mode the pass scheduled ONE
    # representative stream; every core runs it, so node aggregates
    # (port_busy / t_serial / n_ops) scale by n_cores — keeping their
    # semantics identical to the op-partition modes, where the pass
    # already covers all cores.
    agg = float(n_cores) if shard else 1.0
    port_busy: Dict[str, float] = {}
    for cb in core_busy:
        for pid, b in enumerate(cb):
            if b > 0:
                port_busy[PORTS[pid]] = port_busy.get(PORTS[pid], 0.0) \
                    + b * agg
    # schedule-consistent lower bound: busiest (core, port) pipe
    per_core_roof = max((b / widths[pid]
                         for cb in core_busy for pid, b in enumerate(cb)
                         if b > 0), default=0.0)
    t_serial = float(sum(durs)) * agg
    t_dataflow = _dataflow(durs, ports_l, indptr_l, indices_l, core_of_l,
                           sched_cmgs, ring_lat)
    sched = ScheduleResult(
        t_est=t_est, t_roofline=per_core_roof, t_serial=t_serial,
        t_dataflow=t_dataflow, port_busy=port_busy,
        n_ops=cp.n_ops * agg, n_edges=cp.n_edges, stall_by_reason=stall,
        issue_width=dict(hw.issue_width))

    # --- per-core stats (shard: every core runs the representative stream)
    per_core: List[CoreStat] = []
    for c in range(n_cores):
        src = 0 if shard else c
        per_core.append(CoreStat(
            core=c, cmg=int(cmg_of_used[c]),
            t_finish=core_finish[src],
            port_busy={PORTS[pid]: b for pid, b in
                       enumerate(core_busy[src]) if b > 0},
            n_ops=core_nops[src]))

    # --- per-CMG contention report
    per_cmg: List[CmgStat] = []
    inv_final = _eff_inv(nc, topo, cores, n_active)
    mk = max(t_est, 1e-30)
    for g in range(int(max(cmg_of_used)) + 1):
        gcores = [c for c in range(n_cores) if cmg_of_used[c] == g]
        na: Dict[str, float] = {}
        er: Dict[str, float] = {}
        ew: Dict[str, float] = {}
        for li, nm in enumerate(nc.level_names):
            if nm not in topo.shared_read_bw and \
                    nm not in topo.shared_write_bw:
                continue
            dom = gcores[0] // int(nc.shared_by[li])
            na[nm] = float(n_active[li][dom])
            er[nm] = 1.0 / float(inv_final[0][gcores[0], li])
            ew[nm] = 1.0 / float(inv_final[1][gcores[0], li])
        occ = max((sum(core_busy[0 if shard else c]) / mk
                   for c in gcores), default=0.0)
        per_cmg.append(CmgStat(cmg=g, n_cores=len(gcores), n_active=na,
                               eff_read_bw=er, eff_write_bw=ew,
                               occupancy=occ))

    return NodeResult(
        t_est=t_est, n_cores=n_cores, partition=partition, topology=topo,
        schedule=sched, per_core=per_core, per_cmg=per_cmg,
        t_zero_contention=t_zero, iterations=iterations,
        core_of=sched_core_of, starts=np.asarray(starts),
        finishes=np.asarray(finishes))


# ------------------------------------------------------- batched engine
@dataclass
class NodeCompiledBatch:
    """Partition-resolved node form for the batched engine (DESIGN.md
    §17): everything about the pass that does NOT depend on the knob
    combo or the duration row — stream assignment, per-stream op
    positions, per-(stream, port) costed-op positions, and the
    precomputed ring-latency addend per def-use edge (the cross-CMG edge
    mask, folded with the free-op home inheritance once at compile
    time).  ``_node_pass_batch`` runs any number of (knobs x durations)
    batch elements over one of these in lockstep."""
    nc: NodeCompiled
    topo: NodeTopology
    partition: str
    shard: bool
    ring_lat: float
    sched_core_of: np.ndarray        # [n] scheduling stream per op
    core_of_l: List[int]             # python mirror of sched_core_of
    cmg_of_stream: List[int]         # per scheduled stream
    n_streams: int
    pos_in_core: np.ndarray          # [n] running op index on its stream
    pos_in_cp: np.ndarray            # [n] costed-op index on its pipe
    cpid: np.ndarray                 # [n] stream * P + port (0 for free)
    core_ops: np.ndarray             # [S] ops per stream (free included)
    cp_counts: np.ndarray            # [S * P] costed ops per pipe
    edge_extra: Optional[np.ndarray]  # [E] ring addend per CSR edge


def compile_node_batch(nc: NodeCompiled, hw: HardwareSpec, n_cores: int,
                       topology: Optional[NodeTopology] = None,
                       partition: str = "shard",
                       core_of: Optional[np.ndarray] = None
                       ) -> NodeCompiledBatch:
    """Resolve a partition of ``nc`` into the batched pass form.  In
    shard mode the structure is core-count independent (one stream, no
    ring), so one form serves a whole core-count sweep.

    Memoized on the ``NodeCompiled`` keyed by ``(topo, partition,
    n_cores)`` — with the core count dropped for shard forms, whose
    structure does not depend on it.  The key sees the resolved
    topology VALUE, so two sweeps over equal topologies share one form
    while a spec-grid sweep with per-spec topologies can never alias
    another grid's entry.  An explicit ``core_of`` bypasses the cache
    (the key cannot see the array)."""
    topo = topology or hw.topology or NodeTopology.degenerate(n_cores)
    if n_cores < 1 or n_cores > max(topo.n_cores, 1):
        raise ValueError(f"n_cores={n_cores} outside topology "
                         f"{topo.name} (max {topo.n_cores})")
    cache = None
    if core_of is None:
        key = (topo, partition,
               None if partition == "shard" else n_cores)
        cache = nc.__dict__.setdefault("_batch_cache", [])
        for ck, cnb in cache:
            if ck == key:
                return cnb
    sched_core_of, sched_cmgs, shard, _scale, ring_lat, _cores = \
        _resolve_partition(nc, topo, n_cores, partition, core_of)
    n = nc.n
    P = len(PORTS)
    ports = nc.cp._port_l
    indptr = nc.cp._indptr_l
    indices = nc.cp._indices_l
    core_l = sched_core_of.tolist()
    S = len(sched_cmgs)
    pos_in_core = np.zeros(n, dtype=np.int64)
    pos_in_cp = np.zeros(n, dtype=np.int64)
    cpid = np.zeros(n, dtype=np.int64)
    core_ops = [0] * S
    cp_counts = [0] * (S * P)
    for i in range(n):
        c = core_l[i]
        pos_in_core[i] = core_ops[c]
        core_ops[c] += 1
        p = ports[i]
        if p >= 0:
            pid = c * P + p
            cpid[i] = pid
            pos_in_cp[i] = cp_counts[pid]
            cp_counts[pid] += 1
    edge_extra = None
    if ring_lat > 0.0 and S > 1:
        # fold the scalar pass's home-CMG walk into a per-edge addend:
        # free ops inherit their binding producer's home, costed ops
        # charge ring_lat on every edge from a foreign-home producer
        edge_extra = np.zeros(len(indices))
        home = [0] * n
        for i in range(n):
            mycmg = sched_cmgs[core_l[i]]
            if ports[i] < 0:
                home[i] = (home[indices[indptr[i]]]
                           if indptr[i + 1] > indptr[i] else mycmg)
            else:
                for k in range(indptr[i], indptr[i + 1]):
                    if home[indices[k]] != mycmg:
                        edge_extra[k] = ring_lat
                home[i] = mycmg
        if not edge_extra.any():
            edge_extra = None
    nb = NodeCompiledBatch(
        nc=nc, topo=topo, partition=partition, shard=shard,
        ring_lat=ring_lat, sched_core_of=sched_core_of, core_of_l=core_l,
        cmg_of_stream=list(sched_cmgs), n_streams=S,
        pos_in_core=pos_in_core, pos_in_cp=pos_in_cp, cpid=cpid,
        core_ops=np.asarray(core_ops, dtype=np.int64),
        cp_counts=np.asarray(cp_counts, dtype=np.int64),
        edge_extra=edge_extra)
    if cache is not None:
        cache.append((key, nb))
        if len(cache) > _NODE_CACHE_SIZE:
            cache.pop(0)
    return nb


def _node_pass_batch(nb: NodeCompiledBatch, durs_cols: np.ndarray,
                     window: np.ndarray, width: np.ndarray,
                     depth: np.ndarray) -> np.ndarray:
    """One vectorized in-order pass: M batch elements (knob combo x
    duration row) advance op-by-op in lockstep, each replaying the
    scalar ``_node_pass``'s float operations in the same order — every
    element's result is bit-identical to the reference kernel's (the
    node differential suite pins it).  ``durs_cols`` is ``[n, M]``
    (element durations as columns); ``window [M]``, ``width/depth
    [M, P]``.  Returns ``t_est [M]``."""
    nc = nb.nc
    n = nc.n
    M = len(window)
    if n == 0 or M == 0:
        return np.zeros(M)
    P = len(PORTS)
    indptr = nc.cp.dep_indptr
    indices = nc.cp.dep_indices
    ports = nc.cp._port_l
    extra = nb.edge_extra
    core_l = nb.core_of_l
    pos_core = nb.pos_in_core.tolist()
    pos_cp = nb.pos_in_cp.tolist()
    cpid_l = nb.cpid.tolist()
    S = nb.n_streams
    arange_m = np.arange(M)
    zeros_m = np.zeros(M)                          # read-only
    finishes = np.empty((n, M))
    # Rings sized EXACTLY max(window) / max(depth[:, p]) need no
    # validity masking: a read at slot (pos - window_m) % wmax either
    # hits the live entry `window_m` back (age <= wmax, never yet
    # overwritten) or — when pos < window_m — an unwritten slot still
    # holding 0.0, which is a no-op under max against a non-negative
    # start.  Read slots are precomputed per (position, element) so the
    # hot loop is pure gathers.
    wmax = int(window.max())
    rt_rings: List[Optional[np.ndarray]] = [None] * S
    rt_tail = np.zeros((S, M))
    max_pos = int(max(nb.core_ops.max(), 1))
    rob_slot = (np.arange(max_pos)[:, None] - window[None, :]) % wmax
    dmax = [max(1, int(d)) for d in depth.max(axis=0)]      # per port
    q_slot: List[Optional[np.ndarray]] = [None] * P
    for p in range(P):
        mq = int(nb.cp_counts[np.arange(S) * P + p].max(initial=0))
        if mq > 0:
            q_slot[p] = (np.arange(mq)[:, None] - depth[None, :, p]) \
                % dmax[p]
    pipes: List[Optional[np.ndarray]] = [None] * (S * P)
    hists: List[Optional[np.ndarray]] = [None] * (S * P)
    lane_arange = np.arange(max(1, int(width.max())))
    maximum = np.maximum

    for i in range(n):
        lo = indptr[i]
        hi = indptr[i + 1]
        nd = hi - lo
        if nd == 1:
            j = indices[lo]
            if extra is None or extra[lo] == 0.0:
                ready = finishes[j]        # view; never written through
            else:
                ready = finishes[j] + extra[lo]
        elif nd == 0:
            ready = zeros_m
        else:
            dep_f = finishes[indices[lo:hi]]
            if extra is not None:
                ex = extra[lo:hi]
                if ex.any():
                    dep_f = dep_f + ex[:, None]
            ready = dep_f.max(axis=0)
        c = core_l[i]
        rr = rt_rings[c]
        if rr is None:
            rr = rt_rings[c] = np.zeros((wmax, M))
        pos = pos_core[i]
        rt = rt_tail[c]
        p = ports[i]
        if p < 0:
            finishes[i] = ready
            maximum(rt, ready, out=rt)
            rr[pos % wmax] = rt
            continue
        pid = cpid_l[i]
        pl = pipes[pid]
        if pl is None:
            w = width[:, p]
            pl = pipes[pid] = np.where(
                lane_arange[None, :int(w.max())] < w[:, None], 0.0,
                np.inf)
            hists[pid] = np.zeros((dmax[p], M))
        lane = pl.argmin(axis=1)           # first-min lane, = scalar's
        start = maximum(ready, pl[arange_m, lane])
        maximum(start, rr[rob_slot[pos], arange_m], out=start)
        h = hists[pid]
        qp = pos_cp[i]
        maximum(start, h[q_slot[p][qp], arange_m], out=start)
        finish = start + durs_cols[i]
        pl[arange_m, lane] = finish
        h[qp % dmax[p]] = start
        finishes[i] = finish
        maximum(rt, finish, out=rt)
        rr[pos % wmax] = rt
    cm = nc.costed_mask
    return np.max(finishes, axis=0, where=cm[:, None], initial=0.0)


def _node_pass_batch_jax(nb: NodeCompiledBatch, durs_cols: np.ndarray,
                         window: np.ndarray, width: np.ndarray,
                         depth: np.ndarray) -> np.ndarray:
    """``jax.lax.scan`` variant of :func:`_node_pass_batch` (the
    ``schedule_batch_jax`` pattern, vmapped over batch elements in
    x64): one fused XLA program per (structure, ring sizes) — agreeing
    with the numpy kernel to float tolerance, not bit-exactly (XLA may
    reassociate).  The jitted fn is cached on the batch form."""
    import jax
    import jax.numpy as jnp

    nc = nb.nc
    n = nc.n
    M = len(window)
    if n == 0 or M == 0:
        return np.zeros(M)
    P = len(PORTS)
    wmax = max(1, int(width.max()))
    max_core_ops = max(1, int(nb.core_ops.max()))
    max_cp = max(1, int(nb.cp_counts.max()))
    S = nb.n_streams
    key = (wmax, max_core_ops, max_cp)
    fns = nb.__dict__.setdefault("_jax_fns", {})
    fn = fns.get(key)
    if fn is None:
        indptr = nc.cp.dep_indptr
        deg = np.diff(indptr)
        maxdeg = max(1, int(deg.max()) if n else 1)
        deps_pad = np.full((n, maxdeg), -1, dtype=np.int64)
        extra_pad = np.zeros((n, maxdeg))
        for i in range(n):
            lo, hi = int(indptr[i]), int(indptr[i + 1])
            deps_pad[i, :hi - lo] = nc.cp.dep_indices[lo:hi]
            if nb.edge_extra is not None:
                extra_pad[i, :hi - lo] = nb.edge_extra[lo:hi]
        port_eff = np.maximum(nc.cp.port_id.astype(np.int64), 0)
        costed = nc.cp.port_id >= 0
        row_port = np.arange(S * P, dtype=np.int64) % P

        def one(win, wid, dep, durs):
            pipes0 = jnp.where(
                jnp.arange(wmax)[None, :] < wid[row_port][:, None],
                0.0, jnp.inf)
            carry0 = (jnp.zeros(n), jnp.zeros((S, max_core_ops)),
                      jnp.zeros(S), pipes0, jnp.zeros((S * P, max_cp)),
                      0.0)
            xs = (jnp.arange(n), jnp.asarray(durs),
                  jnp.asarray(port_eff), jnp.asarray(costed),
                  jnp.asarray(deps_pad), jnp.asarray(extra_pad),
                  jnp.asarray(nb.sched_core_of), jnp.asarray(nb.cpid),
                  jnp.asarray(nb.pos_in_core), jnp.asarray(nb.pos_in_cp))

            def body(carry, x):
                fin, rt, rt_tail, pipes, hist, t_best = carry
                (i, dur, pid, is_costed, deps, extras, c, cp_i, pos,
                 poscp) = x
                ready = jnp.max(jnp.where(
                    deps >= 0, fin[jnp.clip(deps, 0)] + extras, 0.0))
                row = pipes[cp_i]
                pf = row.min()
                widx = pos - win
                wt = jnp.where(widx >= 0, rt[c, jnp.clip(widx, 0)], 0.0)
                qidx = poscp - dep[pid]
                qt = jnp.where(qidx >= 0,
                               hist[cp_i, jnp.clip(qidx, 0)], 0.0)
                start = jnp.maximum(jnp.maximum(ready, pf),
                                    jnp.maximum(wt, qt))
                finish = start + dur
                fin_i = jnp.where(is_costed, finish, ready)
                pipes = pipes.at[cp_i, row.argmin()].set(
                    jnp.where(is_costed, finish, row[row.argmin()]))
                hist = hist.at[cp_i, poscp].set(
                    jnp.where(is_costed, start, hist[cp_i, poscp]))
                tail = jnp.maximum(rt_tail[c], fin_i)
                t_best = jnp.where(is_costed,
                                   jnp.maximum(t_best, finish), t_best)
                return (fin.at[i].set(fin_i), rt.at[c, pos].set(tail),
                        rt_tail.at[c].set(tail), pipes, hist,
                        t_best), None

            (_, _, _, _, _, t), _ = jax.lax.scan(body, carry0, xs)
            return t

        fn = fns[key] = jax.jit(jax.vmap(one, in_axes=(0, 0, 0, 0)))
    from jax.experimental import enable_x64
    with enable_x64():
        out = fn(jnp.asarray(window), jnp.asarray(width),
                 jnp.asarray(depth),
                 jnp.asarray(np.ascontiguousarray(durs_cols.T)))
        return np.asarray(out)


@dataclass
class NodeBatchResult:
    """Per-element results of a batched node run: contention-aware
    makespans, the zero-contention first pass, and each element's
    fixpoint pass count (``[M]`` arrays, one entry per knob combo /
    sweep cell)."""
    t_est: np.ndarray
    t_zero_contention: np.ndarray
    iterations: np.ndarray
    # passes actually run when knob dedup collapsed the grid (the
    # expanded ``iterations`` would overcount the bench's accounting)
    scheduled_passes: Optional[int] = None

    @property
    def total_scheduled_ops(self) -> int:
        """Op-instances actually scheduled: every fixpoint pass of every
        element is a full in-order schedule of the program (the bench's
        throughput accounting)."""
        if self.scheduled_passes is not None:
            return self.scheduled_passes
        return int(self.iterations.sum())


def _batch_context(nb: NodeCompiledBatch, n_cores: int,
                   nc: Optional[NodeCompiled] = None,
                   topo: Optional[NodeTopology] = None,
                   durs0: Optional[np.ndarray] = None) -> dict:
    """Fixpoint-state template for one (core count, spec) cell on ``nb``.
    Everything but ``n_active`` is read-only and shared across batch
    elements; use :func:`_clone_context` for each element's own state
    machine.  ``nc``/``topo``/``durs0`` override the batch form's own
    cost view for the spec-batched sweeps (DESIGN.md §19): the pass
    structure (streams, CSR edges, pipe ids) stays ``nb``'s, while the
    contention math and uncontended durations come from the per-spec
    view."""
    nc = nb.nc if nc is None else nc
    topo = nb.topo if topo is None else topo
    cores = np.arange(n_cores, dtype=np.int64)
    has_caps = any(nm in topo.shared_read_bw
                   or nm in topo.shared_write_bw
                   for nm in nc.level_names)
    n_active, active_per_dom = _work_domains(
        nc, n_cores, nb.shard, nb.sched_core_of, cores)
    return {"n_cores": n_cores, "cores": cores,
            "scale": (1.0 / n_cores) if nb.shard else 1.0,
            "contended": has_caps and n_cores > 1,
            "n_active": n_active, "active_per_dom": active_per_dom,
            "nc": nc, "topo": topo,
            "durs0": nc.cp.durations if durs0 is None else durs0}


def _clone_context(tmpl: dict) -> dict:
    """Per-element copy of a context template (fresh ``n_active``)."""
    return {**tmpl, "n_active": [a.copy() for a in tmpl["n_active"]]}


def _fixpoint_batch(nb: NodeCompiledBatch, contexts: List[dict],
                    knobs, max_iters: int, tol: float,
                    backend: str) -> NodeBatchResult:
    """The bandwidth-contention fixpoint as a vectorized outer loop over
    the batched pass: every element carries its own ``n_active`` state
    machine (replaying the scalar ``schedule_node`` trajectory exactly —
    same damping, same stop rules), elements drop out of the pass as
    they converge, and each pass schedules only the still-active
    columns.  Each context may carry its own cost view (``nc``/``topo``/
    ``durs0``, see :func:`_batch_context`), which is how the spec axis
    fuses with the knob axis."""
    M = knobs.batch
    n = nb.nc.n
    t_est = np.zeros(M)
    t_zero = np.zeros(M)
    iters = np.zeros(M, dtype=np.int64)
    if n == 0 or M == 0:
        return NodeBatchResult(t_est, t_zero, iters)
    pass_fn = _node_pass_batch_jax if backend == "jax" \
        else _node_pass_batch
    # the numpy pass compacts converged elements out of later passes;
    # the jax pass keeps the full batch (a shrinking batch axis would
    # re-trace the jitted scan per distinct size)
    compact = backend != "jax"
    durs_cols = np.empty((n, M))
    done = np.zeros(M, dtype=bool)
    final = np.fromiter((not ctx["contended"] for ctx in contexts),
                        dtype=bool, count=M)
    stale = np.ones(M, dtype=bool)      # durations need (re)computing
    first = True
    while not done.all():
        active = ~done
        for m in np.nonzero(active & stale)[0]:
            ctx = contexts[m]
            nc_m = ctx["nc"]
            uncontended = all(float(a.max(initial=1.0)) <= 1.0
                              for a in ctx["n_active"])
            if uncontended and ctx["scale"] == 1.0:
                # exact path, same as the scalar engine's
                durs_cols[:, m] = ctx["durs0"]
            else:
                inv_r, inv_w = _eff_inv(nc_m, ctx["topo"], ctx["cores"],
                                        ctx["n_active"])
                row, row_w = (inv_r[0], inv_w[0]) if nb.shard else \
                    (inv_r[nb.sched_core_of], inv_w[nb.sched_core_of])
                durs_cols[:, m] = _contended_durs_arr(
                    nc_m, row, row_w, ctx["scale"])
            stale[m] = False
        idx = np.nonzero(active)[0]
        if compact:
            t = pass_fn(nb, durs_cols[:, idx], knobs.window[idx],
                        knobs.width[idx], knobs.depth[idx])
            t_est[idx] = t
        else:
            t = pass_fn(nb, durs_cols, knobs.window, knobs.width,
                        knobs.depth)
            t_est[idx] = t[idx]
        iters[idx] += 1
        if first:
            t_zero[:] = t_est           # pass 1 runs every element
            first = False
        done |= active & final
        for m in np.nonzero(active & ~final)[0]:
            ctx = contexts[m]
            damp = 0.5 if iters[m] > 1 else 1.0
            ctx["n_active"], delta = _update_active(
                ctx["nc"], ctx["topo"], ctx["cores"], ctx["n_active"],
                nb.sched_core_of, nb.shard, ctx["scale"],
                ctx["n_cores"], float(t_est[m]), ctx["active_per_dom"],
                damp)
            if delta == 0.0:
                done[m] = True          # the pass just taken converged
            else:
                stale[m] = True
                final[m] = delta < tol or iters[m] >= max_iters
    return NodeBatchResult(t_est, t_zero, iters)


def schedule_node_batch(nc: NodeCompiled, hw: HardwareSpec, knobs,
                        n_cores: int,
                        topology: Optional[NodeTopology] = None,
                        partition: str = "shard",
                        core_of: Optional[np.ndarray] = None,
                        max_iters: int = 8, tol: float = 1e-2,
                        backend: str = "numpy") -> NodeBatchResult:
    """Batched node engine: one contention-aware node estimate per knob
    combo in ``knobs`` (an :class:`~.compiled.O3Knobs` batch), all
    combos advancing in lockstep through the vectorized pass.  Each
    element is bit-identical to ``schedule_node`` under a spec carrying
    the same knobs (``backend="jax"`` trades bit-exactness for a fused
    ``lax.scan``).  Duplicate knob rows (clamp-collapsed grid points)
    are scheduled once and expanded back to the full grid."""
    uk, inv = knobs.unique()
    nb = compile_node_batch(nc, hw, n_cores, topology, partition, core_of)
    tmpl = _batch_context(nb, n_cores)
    contexts = [_clone_context(tmpl) for _ in range(uk.batch)]
    res = _fixpoint_batch(nb, contexts, uk, max_iters, tol, backend)
    if uk is knobs:
        return res
    return NodeBatchResult(res.t_est[inv], res.t_zero_contention[inv],
                           res.iterations[inv],
                           scheduled_passes=res.total_scheduled_ops)


def schedule_node_sweep(nc: NodeCompiled, hw: HardwareSpec, knobs,
                        core_counts, topology: Optional[NodeTopology] = None,
                        partition: str = "shard", max_iters: int = 8,
                        tol: float = 1e-2,
                        backend: str = "numpy") -> np.ndarray:
    """Core-count x knob-grid sweep as one fused batch: ``t_est [C, B]``
    seconds.  Shard mode (the zoo's) shares one batch form across every
    core count — the whole sweep is a single ``C*B``-element run of the
    batched pass; op partitions fall back to one batch per count (their
    stream structure depends on the count)."""
    core_counts = list(core_counts)
    if partition == "shard":
        uk, inv = knobs.unique()       # dedup BEFORE tiling across counts
        B = uk.batch
        nb = compile_node_batch(nc, hw, max(core_counts), topology,
                                partition)
        tiled = O3Knobs(window=np.tile(uk.window, len(core_counts)),
                        width=np.tile(uk.width, (len(core_counts), 1)),
                        depth=np.tile(uk.depth, (len(core_counts), 1)))
        tmpls = {k: _batch_context(nb, k) for k in core_counts}
        contexts = [_clone_context(tmpls[k])
                    for k in core_counts for _ in range(B)]
        res = _fixpoint_batch(nb, contexts, tiled, max_iters, tol,
                              backend)
        return res.t_est.reshape(len(core_counts), B)[:, inv]
    rows = [schedule_node_batch(nc, hw, knobs, k, topology, partition,
                                max_iters=max_iters, tol=tol,
                                backend=backend).t_est
            for k in core_counts]
    return np.stack(rows)


# ----------------------------------------------------- spec-grid engine
_GRID_CACHE_SIZE = 4


@dataclass
class NodeGridCompiled:
    """One program compiled against a whole :class:`~.hwspec.SpecGrid`
    (DESIGN.md §19): the shared structural ``CompiledProgram`` (CSR
    def-use edges, port ids — spec-independent by the grid's uniformity
    contract), the spec-batched cost decomposition, per-spec
    ``NodeCompiled`` views into its columns, and the ``[n, S]``
    uncontended duration matrix.  ``schedule_spec_sweep`` fuses the S
    axis of one of these with the core-count and knob axes into a single
    batched fixpoint run."""
    grid: SpecGrid
    bc: BatchCosted
    cp: CompiledProgram           # structural form (spec-0 cost columns)
    views: List[NodeCompiled]     # per-spec cost views sharing ``cp``
    durations0: np.ndarray        # [n, S] uncontended (single-core) durs


def compile_node_grid(prog: Program, grid: SpecGrid,
                      links_per_collective: int = 2,
                      compute_dtype: Optional[str] = None
                      ) -> NodeGridCompiled:
    """Compile (and memoize on the Program) the spec-grid node form.

    One ``cost_program_batch`` pass covers every spec; the structural
    compile runs once, seeded with spec 0's cost column via the
    ``costed=`` bypass — so a grid compile never reads or writes the
    single-spec ``compile_program``/``compile_node`` caches (it cannot
    alias them; the grid cache is keyed by ``SpecGrid`` VALUE, a
    distinct key type).  Column ``s`` of every per-spec view is
    bit-identical to ``compile_node(prog, grid.specs[s])``'s arrays
    (pinned by the differential suite)."""
    cache = prog.__dict__.setdefault("_node_grid_cache", [])
    for cgrid, cdt, clk, cngc in cache:
        if cdt == compute_dtype and clk == links_per_collective \
                and cgrid == grid:
            return cngc
    bc = cost_program_batch(prog, grid, links_per_collective,
                            compute_dtype)
    n = bc.n
    costed0: List[Optional[OpTime]] = []
    for i, o in enumerate(prog.ops):
        if bc.port[i] is None:
            costed0.append(None)
        else:
            costed0.append(OpTime(o, float(bc.t_compute[i, 0]),
                                  float(bc.t_mem[i, 0]),
                                  float(bc.t_ici[i, 0]), bc.port[i]))
    cp = compile_program(prog, grid.specs[0], links_per_collective,
                         compute_dtype, costed=costed0)
    # (max(t_c, t_m, t_i) + startup) * count, the compile_program rule,
    # vectorized over the spec axis; uncharged ops stay zero-duration
    startup_s = np.array([sp.op_startup_ns for sp in grid.specs]) * 1e-9
    durations0 = (bc.t_op() + startup_s[None, :]) * bc.count[:, None]
    costed_mask = cp.port_id >= 0
    durations0[~costed_mask] = 0.0
    views: List[NodeCompiled] = []
    for s, sp in enumerate(grid.specs):
        levels = sp.memory_hierarchy()
        views.append(NodeCompiled(
            cp=cp, n=n,
            t_comp=np.ascontiguousarray(bc.t_compute[:, s]),
            t_ici=np.ascontiguousarray(bc.t_ici[:, s]),
            lat=np.ascontiguousarray(bc.latency[:, s]),
            count=bc.count,
            rd=np.ascontiguousarray(bc.rd[:, :, s]),
            wr=np.ascontiguousarray(bc.wr[:, :, s]),
            level_names=grid.level_names,
            core_read_bw=np.array([lv.read_bw for lv in levels]),
            core_write_bw=np.array([lv.write_bw for lv in levels]),
            shared_by=np.array([max(1, lv.shared_by) for lv in levels],
                               dtype=np.int64),
            startup=sp.op_startup_ns * 1e-9,
            costed_mask=costed_mask))
    ngc = NodeGridCompiled(grid=grid, bc=bc, cp=cp, views=views,
                           durations0=durations0)
    cache.append((grid, compute_dtype, links_per_collective, ngc))
    if len(cache) > _GRID_CACHE_SIZE:
        cache.pop(0)
    return ngc


def schedule_spec_sweep(ngc: NodeGridCompiled,
                        knobs: Optional[O3Knobs] = None,
                        core_counts=None, max_iters: int = 8,
                        tol: float = 1e-2,
                        backend: str = "numpy") -> np.ndarray:
    """Fused spec × core-count × knob sweep: ``t_est [S, C, B]``.

    Shard partition only (the DSE mode: every core runs the stream at
    ``1/n_cores`` work) — the pass structure is then one stream with no
    ring, shared by every spec, so the whole grid runs as a single
    ``S*C*B``-element batched contention fixpoint with each element's
    per-spec bandwidths/topology threaded through its own context.
    Every element is bit-identical to the per-spec scalar pipeline
    (``compile_node`` + ``schedule_node_batch``).

    ``core_counts``: ``None`` — each spec at its full topology core
    count (``C=1``); a sequence of ints — one shared count axis; a
    length-S sequence of per-spec sequences (all length C) — e.g. DSE
    grids where the core budget varies per candidate.  ``knobs``
    defaults to spec 0's O3 resources; duplicate rows are scheduled
    once."""
    grid = ngc.grid
    S = grid.S
    if knobs is None:
        knobs = O3Knobs.single(grid.specs[0])
    uk, inv = knobs.unique()
    B = uk.batch
    topos = [grid.topology_of(s) for s in range(S)]
    if core_counts is None:
        counts = [[t.n_cores] for t in topos]
    else:
        core_counts = list(core_counts)
        if core_counts and np.ndim(core_counts[0]) > 0:
            counts = [list(c) for c in core_counts]
            if len(counts) != S:
                raise ValueError("per-spec core_counts must have one "
                                 f"row per spec ({len(counts)} != {S})")
        else:
            counts = [list(core_counts)] * S
    C = len(counts[0])
    if any(len(c) != C for c in counts):
        raise ValueError("ragged core_counts (the sweep is [S, C, B])")
    # one shard structural form serves every (spec, count) cell
    nb = compile_node_batch(ngc.views[0], grid.specs[0], 1, topos[0],
                            "shard")
    contexts: List[dict] = []
    for s in range(S):
        for k in counts[s]:
            if k < 1 or k > max(topos[s].n_cores, 1):
                raise ValueError(f"n_cores={k} outside topology "
                                 f"{topos[s].name} "
                                 f"(max {topos[s].n_cores})")
            tmpl = _batch_context(nb, int(k), nc=ngc.views[s],
                                  topo=topos[s],
                                  durs0=ngc.durations0[:, s])
            contexts.extend(_clone_context(tmpl) for _ in range(B))
    tiled = O3Knobs(window=np.tile(uk.window, S * C),
                    width=np.tile(uk.width, (S * C, 1)),
                    depth=np.tile(uk.depth, (S * C, 1)))
    res = _fixpoint_batch(nb, contexts, tiled, max_iters, tol, backend)
    return res.t_est.reshape(S, C, B)[:, :, inv]


def simulate_node(prog: Program, hw: HardwareSpec, n_cores: int,
                  topology: Optional[NodeTopology] = None,
                  partition: str = "round-robin",
                  links_per_collective: int = 2,
                  compute_dtype: Optional[str] = None,
                  costed: Optional[List[Optional[OpTime]]] = None,
                  **kw) -> NodeResult:
    """Cost + compile + node-schedule in one call (the ``simulate``
    entry point's ``engine="node"`` backend)."""
    nc = compile_node(prog, hw, links_per_collective, compute_dtype, costed)
    return schedule_node(nc, hw, n_cores, topology, partition, **kw)


def shard_costed(prog: Program, hw: HardwareSpec, n_cores: int,
                 topology: Optional[NodeTopology] = None,
                 links_per_collective: int = 2,
                 compute_dtype: Optional[str] = None
                 ) -> List[Optional[OpTime]]:
    """The shard-mode node model as a costed list: per-op times scaled by
    1/n_cores with the converged contention applied, suitable for
    ``compile_program(costed=...)`` — this is how the O3 knob sweep rides
    ``schedule_batch`` with core count as an extra grid axis (the knob
    grid batches over one shard-contended compiled program per core
    count)."""
    nc = compile_node(prog, hw, links_per_collective, compute_dtype)
    nr = schedule_node(nc, hw, n_cores, topology, partition="shard")
    topo = nr.topology
    cores = np.arange(n_cores, dtype=np.int64)
    # rebuild the converged per-level inverse bandwidths from the report
    n_active = []
    for li, nm in enumerate(nc.level_names):
        n_dom = int(np.ceil(n_cores / nc.shared_by[li]))
        na = np.ones(n_dom)
        for cs in nr.per_cmg:
            if nm in cs.n_active:
                na[:] = cs.n_active[nm]
                break
        n_active.append(na)
    inv_r, inv_w = _eff_inv(nc, topo, cores, n_active)
    scale = 1.0 / n_cores
    t_mem = ((nc.rd * inv_r[0]).sum(axis=1)
             + (nc.wr * inv_w[0]).sum(axis=1)) * scale + nc.lat
    base = cost_program(prog, hw, links_per_collective, compute_dtype)
    out: List[Optional[OpTime]] = []
    for i, ot in enumerate(base):
        if ot is None:
            out.append(None)
            continue
        out.append(dataclasses.replace(
            ot, t_compute=ot.t_compute * scale,
            t_mem=float(t_mem[i]) if ot.traffic is not None else 0.0,
            t_ici=ot.t_ici))
    return out
