"""PA-data-style report — the paper's Fujitsu-profiler analogue.

The RIKEN simulator classified 0-instruction-commit cycles into memory wait /
arithmetic wait / etc., counted SIMD elements honouring the predicate
register, and exposed cycle-by-cycle OoO resource utilization.  The HLO-level
equivalents:

  * stall classification  -> exposed (non-overlapped) time per port,
  * predicate-aware SIMD  -> MXU useful-lane fraction (tile-padding waste),
  * OoO utilization       -> per-port busy fraction + per-opclass time,
  * tuning hints          -> rule-based "what moves the dominant term down".
"""
from __future__ import annotations

from typing import List, Optional

from .engine import EngineResult
from .hlo import Program
from .node import NodeResult
from .roofline import Roofline
from .schedule import ScheduleResult


def _fmt_t(s: float) -> str:
    if s >= 1.0:
        return f"{s:8.3f} s "
    if s >= 1e-3:
        return f"{s * 1e3:8.3f} ms"
    return f"{s * 1e6:8.3f} us"


def suggestions(rf: Roofline, eng: EngineResult, prog: Program) -> List[str]:
    out = []
    dom = rf.dominant
    comm = prog.comm_by_collective()
    if dom == "collective":
        top = max(comm, key=lambda k: comm[k]) if comm else "all-gather"
        if top == "all-gather":
            out.append("collective-bound, all-gather dominant: params are "
                       "re-gathered per step — raise per-device batch, widen "
                       "FSDP axis only across faster links, or overlap via "
                       "async collectives / looped collective-einsum.")
        elif top == "all-reduce":
            out.append("collective-bound, all-reduce dominant: compress "
                       "gradients (int8 error-feedback), accumulate more "
                       "microbatches per sync, or move the reduction to a "
                       "reduce-scatter + local update (ZeRO).")
        else:
            out.append(f"collective-bound ({top}): reshard to cut payload or "
                       "use hierarchical (intra-pod first) groups.")
    elif dom == "memory":
        out.append("HBM-bound: increase arithmetic intensity — fuse "
                   "elementwise chains (bigger fusions), cast activations to "
                   "bf16, raise per-device batch, or re-tile kernels so the "
                   "working set stays VMEM-resident.")
    else:
        if rf.mxu_utilization < 0.7:
            out.append(f"compute-bound with MXU useful-lane fraction "
                       f"{rf.mxu_utilization:.2f}: pad/align matmul dims to "
                       f"128 (vocab/heads/d_ff shard sizes).")
        if rf.useful_flops_ratio < 0.45:
            out.append(f"MODEL_FLOPS/HLO_FLOPs = {rf.useful_flops_ratio:.2f}: "
                       "compiled compute is mostly non-model work — check "
                       "remat policy (recompute), routing dispatch, or "
                       "attention masking waste.")
        if not out:
            out.append("compute-bound at good utilization: this cell is near "
                       "roofline; gains must come from algorithm (sparsity, "
                       "lower precision).")
    return out


def _fmt_bytes(b: float) -> str:
    if b >= 2**30:
        return f"{b / 2**30:8.2f} GiB"
    if b >= 2**20:
        return f"{b / 2**20:8.2f} MiB"
    return f"{b / 2**10:8.2f} KiB"


def _memory_section(eng: EngineResult) -> List[str]:
    """Per-level traffic/residency — the paper's cache-hierarchy function
    expansion made visible: where each op's reads and writes were served."""
    tot = sum(a["read_bytes"] + a["write_bytes"]
              for a in eng.traffic_by_level.values())
    if tot <= 0:
        return []
    lines = ["  memory hierarchy (routed traffic | residency):"]
    for name, a in sorted(eng.traffic_by_level.items(),
                          key=lambda kv: -(kv[1]["read_bytes"]
                                           + kv[1]["write_bytes"])):
        share = (a["read_bytes"] + a["write_bytes"]) / tot
        lines.append(f"    {name:<6s} read {_fmt_bytes(a['read_bytes'])}  "
                     f"write {_fmt_bytes(a['write_bytes'])}  "
                     f"({100 * share:5.1f}% of traffic)")
    return lines


def _schedule_section(sched: ScheduleResult) -> List[str]:
    """Critical-path + per-port timeline view of the O3 schedule — the
    paper's cycle-by-cycle OoO resource utilization, at HLO altitude."""
    lines = []
    mk = max(sched.t_est, 1e-30)
    lines.append("  schedule engine (dependency-aware O3):")
    lines.append(f"    estimate: {_fmt_t(sched.t_est)}   dataflow critical "
                 f"path: {_fmt_t(sched.t_dataflow)}   serial: "
                 f"{_fmt_t(sched.t_serial)}")
    lines.append(f"    overlap from schedule: {100 * sched.overlap_fraction:.1f}%"
                 f" of serial hidden   ({sched.n_edges} def-use edges)")
    lines.append("    port timeline (busy | util of makespan):")
    for port in ("mxu", "vpu", "mem", "ici"):
        if port not in sched.port_busy:
            continue
        busy = sched.port_busy[port]
        lines.append(f"      {port:<4s} {_fmt_t(busy)}  "
                     f"({100 * busy / mk:5.1f}%)")
    if sched.stall_by_reason:
        stalls = "  ".join(f"{k}:{_fmt_t(v).strip()}"
                           for k, v in sorted(sched.stall_by_reason.items(),
                                              key=lambda kv: -kv[1]))
        lines.append(f"    issue stalls beyond data-ready: {stalls}")
    cp = sched.critical_path
    if cp:
        covered = sum(c.duration for c in cp)
        trunc = (" — TRUNCATED: binding chain longer than "
                 f"{len(cp)} entries, shown path is a suffix"
                 if sched.critical_path_truncated else "")
        lines.append(f"    critical path ({len(cp)} ops, "
                     f"{100 * covered / mk:.0f}% of makespan{trunc}):")
        for c in cp[-12:]:
            lines.append(f"      {c.op.name[:40]:<40s} {c.port:<4s} "
                         f"start {_fmt_t(c.start)}  dur "
                         f"{_fmt_t(c.duration)}  <- {c.bound_by}")
    return lines


def _node_section(node: NodeResult) -> List[str]:
    """Per-CMG contention/occupancy — the node engine's view: how many
    cores were concurrently streaming through each shared level, and the
    per-core effective bandwidth that left each of them."""
    lines = []
    lines.append(f"  node engine ({node.n_cores} cores, "
                 f"partition={node.partition}, topology="
                 f"{node.topology.name}):")
    lines.append(f"    estimate: {_fmt_t(node.t_est)}   zero-contention "
                 f"bound: {_fmt_t(node.t_zero_contention)}   "
                 f"dataflow: {_fmt_t(node.schedule.t_dataflow)}")
    lines.append(f"    parallel efficiency: "
                 f"{100 * node.parallel_efficiency:.1f}%   contention "
                 f"fixpoint: {node.iterations} iteration(s)")
    for g in node.per_cmg:
        if not g.n_active:
            lines.append(f"    cmg{g.cmg}: {g.n_cores} cores  "
                         f"occupancy {100 * g.occupancy:5.1f}%  "
                         f"(no shared-level caps)")
            continue
        cont = "  ".join(
            f"{lv}: {g.n_active[lv]:.1f} active, "
            f"{g.eff_read_bw[lv] / 1e9:.0f}/"
            f"{g.eff_write_bw[lv] / 1e9:.0f} GB/s/core"
            for lv in sorted(g.n_active))
        lines.append(f"    cmg{g.cmg}: {g.n_cores} cores  occupancy "
                     f"{100 * g.occupancy:5.1f}%  {cont}")
    if node.per_core:
        slow = max(node.per_core, key=lambda c: c.t_finish)
        fast = min(node.per_core, key=lambda c: c.t_finish)
        lines.append(f"    imbalance: core{slow.core} finishes at "
                     f"{_fmt_t(slow.t_finish)} vs core{fast.core} at "
                     f"{_fmt_t(fast.t_finish)}")
    return lines


def pa_report(rf: Roofline, eng: EngineResult, prog: Program,
              title: str = "", sched: Optional[ScheduleResult] = None,
              engine_mode: str = "occupancy",
              node: Optional[NodeResult] = None) -> str:
    lines = []
    lines.append(f"== PA report {title} ==")
    # headline matches SimReport.t_est: node-derived in node mode,
    # schedule-derived in schedule mode, occupancy otherwise (labelled
    # when several numbers are in the report)
    if engine_mode == "node" and node is not None:
        lines.append(f"  estimate (node, {node.n_cores} cores): "
                     f"{_fmt_t(node.t_est)}   occupancy (1 core): "
                     f"{_fmt_t(eng.t_est)}   zero-contention: "
                     f"{_fmt_t(node.t_zero_contention)}")
    elif engine_mode == "schedule" and sched is not None:
        lines.append(f"  estimate (schedule): {_fmt_t(sched.t_est)}   "
                     f"occupancy: {_fmt_t(eng.t_est)}   roofline-bound: "
                     f"{_fmt_t(eng.t_roofline)}   serial: "
                     f"{_fmt_t(eng.t_serial)}")
    else:
        label = "estimate (occupancy)" if sched is not None else "estimate"
        lines.append(f"  {label}: {_fmt_t(eng.t_est)}   roofline-bound: "
                     f"{_fmt_t(eng.t_roofline)}   serial: "
                     f"{_fmt_t(eng.t_serial)}")
    lines.append(f"  roofline terms: compute {_fmt_t(rf.compute_s)} | memory "
                 f"{_fmt_t(rf.memory_s)} | collective {_fmt_t(rf.collective_s)}"
                 f"  -> dominant: {rf.dominant}")
    lines.append(f"  MODEL/HLO flops: {rf.useful_flops_ratio:.3f}   "
                 f"MXU useful-lane: {rf.mxu_utilization:.3f}")
    lines.append("  port busy:")
    tot = max(eng.t_est, 1e-30)
    for port in ("mxu", "vpu", "mem", "ici"):
        t = eng.port_busy.get(port, 0.0)
        lines.append(f"    {port:<4s} {_fmt_t(t)}  ({100 * t / tot:5.1f}% of est)")
    lines.extend(_memory_section(eng))
    lines.append("  time by opclass:")
    for cls, t in sorted(eng.by_class_time.items(), key=lambda kv: -kv[1]):
        lines.append(f"    {cls:<16s} {_fmt_t(t)}")
    if eng.collective_time_by_kind:
        lines.append("  collectives:")
        comm = prog.comm_by_collective()
        for k, t in sorted(eng.collective_time_by_kind.items(),
                           key=lambda kv: -kv[1]):
            lines.append(f"    {k:<20s} {_fmt_t(t)}  payload/dev "
                         f"{comm.get(k, 0) / 2**20:9.1f} MiB")
    if sched is not None:
        lines.extend(_schedule_section(sched))
    if node is not None:
        lines.extend(_node_section(node))
    lines.append("  hints:")
    for s in suggestions(rf, eng, prog):
        lines.append(f"    - {s}")
    return "\n".join(lines)
