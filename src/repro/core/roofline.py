"""Three-term roofline analysis (assignment §ROOFLINE + the paper's purpose).

Terms, per (arch x shape x mesh) cell, following the assignment formulas with
per-device quantities (the compiled HLO is post-SPMD, i.e. per-device):

    compute    = flops_per_device    / peak_flops          [s]
    memory     = bytes_per_device    / hbm_bw              [s]
    collective = comm_bytes_per_dev  / link_bw             [s]

(equivalently  HLO_FLOPs_global / (chips x peak)  since
 HLO_FLOPs_global = chips x flops_per_device).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from .hlo import Program
from .hwspec import HardwareSpec


@dataclass
class Roofline:
    """Three-term roofline (compute / memory / collective) for one program
    on one spec (DESIGN.md §6); `as_dict` feeds reports and artifacts.
    """
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    comm_bytes_per_device: float
    model_flops_global: float          # 6ND (train) / 2ND (inference)
    hlo_flops_global: float
    n_chips: int
    mxu_utilization: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=lambda k: terms[k])

    @property
    def t_bound(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: how much compiled compute is useful
        (catches remat recompute + routing/dispatch overhead + padding)."""
        return self.model_flops_global / max(self.hlo_flops_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """compute term / bound — 1.0 means pure-compute-limited (ideal for
        a training step); the headline §Perf number."""
        return self.compute_s / max(self.t_bound, 1e-30)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(param_count_active: int, tokens: int, kind: str) -> float:
    """6*N*D for training (fwd+bwd), 2*N*D for inference forward."""
    if kind == "train":
        return 6.0 * param_count_active * tokens
    return 2.0 * param_count_active * tokens


def roofline_from_program(prog: Program, hw: HardwareSpec, n_chips: int,
                          model_flops_global: float,
                          compute_dtype: str = "bf16") -> Roofline:
    f = prog.flops
    b = prog.bytes_normalized(compute_dtype)
    c = prog.comm_normalized(compute_dtype)
    # memory roof: all traffic streamed from the hierarchy's outermost
    # level (HBM/DRAM) on the load path — the classic roofline denominator
    hbm = hw.memory_hierarchy()[-1]
    return Roofline(
        compute_s=f / hw.matmul_flops(compute_dtype),
        memory_s=b / hbm.read_bw,
        collective_s=c / hw.ici_bw_per_link,
        flops_per_device=f,
        bytes_per_device=b,
        comm_bytes_per_device=c,
        model_flops_global=model_flops_global,
        hlo_flops_global=f * n_chips,
        n_chips=n_chips,
        mxu_utilization=prog.matmul_utilization(hw.mxu_tile),
    )
