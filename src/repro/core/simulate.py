"""Top-level simulator API: compiled artifact -> SimReport.

    lowered  = jax.jit(step, ...).lower(**input_specs(arch))
    compiled = lowered.compile()
    report   = simulate(compiled, hw=TPU_V5E, n_chips=256,
                        model_flops_global=6 * N * D)
    print(report.pa)

This is the paper's end-to-end flow: application binary -> simulator ->
execution-cycle estimate + PA data, before the target hardware exists.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Dict, Optional

from .cost import cost_program
from .engine import EngineResult, simulate_program
from .hlo import Program, parse_program
from .hwspec import HardwareSpec, NodeTopology, TPU_V5E
from .node import NodeResult, simulate_node
from .pa import pa_report
from .roofline import Roofline, roofline_from_program
from .sample import SampledNodeResult, SamplingConfig, sampled_schedule_node
from .schedule import ScheduleResult, schedule_program


@dataclass
class SimReport:
    """Everything ``simulate()`` produced for one compiled program:
    roofline terms (DESIGN.md §6), the engine result(s), program summary,
    the rendered PA report, and the parsed ``program`` for re-costing.
    """
    hw: str
    n_chips: int
    roofline: Roofline
    engine: EngineResult
    program_summary: Dict[str, Any]
    pa: str
    xla_cost_analysis: Optional[Dict[str, float]] = None
    memory_analysis: Optional[Dict[str, float]] = None
    # dependency-aware O3 schedule (engine="schedule"|"both"); None for the
    # fast flat-occupancy path
    schedule: Optional[ScheduleResult] = None
    engine_mode: str = "occupancy"
    # the parsed per-op program (not serialized in to_json) so callers can
    # re-cost/re-schedule without re-parsing the HLO text
    program: Optional[Program] = None
    # multi-core node engine result (engine="node")
    node: Optional[NodeResult] = None
    # sampled node estimation (engine="node" + sampling=; DESIGN.md §18)
    sampled: Optional[SampledNodeResult] = None

    @property
    def t_est(self) -> float:
        """Headline estimate: sampled-node or node-derived in node mode,
        schedule-derived when the O3 engine ran as the primary mode,
        flat-occupancy otherwise (both always carried)."""
        if self.engine_mode == "node" and self.sampled is not None:
            return self.sampled.t_est
        if self.engine_mode == "node" and self.node is not None:
            return self.node.t_est
        if self.engine_mode == "schedule" and self.schedule is not None:
            return self.schedule.t_est
        return self.engine.t_est

    def to_json(self) -> str:
        d = {
            "hw": self.hw,
            "n_chips": self.n_chips,
            "roofline": self.roofline.as_dict(),
            "engine": {
                "t_est": self.engine.t_est,
                "t_roofline": self.engine.t_roofline,
                "t_serial": self.engine.t_serial,
                "port_busy": self.engine.port_busy,
                "by_class_time": self.engine.by_class_time,
                "collective_time_by_kind": self.engine.collective_time_by_kind,
                "n_ops": self.engine.n_ops,
                "mxu_utilization": self.engine.mxu_utilization,
                "traffic_by_level": self.engine.traffic_by_level,
            },
            "program": self.program_summary,
            "xla_cost_analysis": self.xla_cost_analysis,
            "memory_analysis": self.memory_analysis,
            "engine_mode": self.engine_mode,
        }
        if self.schedule is not None:
            s = self.schedule
            d["schedule"] = {
                "t_est": s.t_est,
                "t_roofline": s.t_roofline,
                "t_serial": s.t_serial,
                "t_dataflow": s.t_dataflow,
                "port_busy": s.port_busy,
                "overlap_fraction": s.overlap_fraction,
                "n_edges": s.n_edges,
                "stall_by_reason": s.stall_by_reason,
                "critical_path_truncated": s.critical_path_truncated,
                "critical_path": [
                    {"op": c.op.name, "port": c.port, "start": c.start,
                     "finish": c.finish, "bound_by": c.bound_by}
                    for c in s.critical_path[:32]],
            }
        if self.node is not None:
            nr = self.node
            d["node"] = {
                "t_est": nr.t_est,
                "n_cores": nr.n_cores,
                "partition": nr.partition,
                "topology": nr.topology.name,
                "t_zero_contention": nr.t_zero_contention,
                "iterations": nr.iterations,
                "parallel_efficiency": nr.parallel_efficiency,
                "t_serial": nr.schedule.t_serial,
                "t_dataflow": nr.schedule.t_dataflow,
                "port_busy": nr.schedule.port_busy,
                "stall_by_reason": nr.schedule.stall_by_reason,
                "per_cmg": [
                    {"cmg": g.cmg, "n_cores": g.n_cores,
                     "n_active": g.n_active,
                     "eff_read_bw": g.eff_read_bw,
                     "eff_write_bw": g.eff_write_bw,
                     "occupancy": g.occupancy}
                    for g in nr.per_cmg],
            }
        if self.sampled is not None:
            sm = self.sampled
            d["sampled"] = {
                "t_est": sm.t_est,
                "n_cores": sm.n_cores,
                "partition": sm.partition,
                "k": sm.plan.k,
                "n_intervals": sm.plan.n_intervals,
                "interval_ops": sm.plan.config.interval_ops,
                "seed": sm.plan.config.seed,
                "frac_ops_scheduled": sm.frac_ops_scheduled,
                "t_zero_contention": sm.t_zero_contention,
                "bound_by": sm.bound_by,
                "port_busy": sm.port_busy,
                "traffic_by_level": sm.traffic_by_level,
            }
        return json.dumps(d, indent=1, sort_keys=True)


def _mem_stats(compiled) -> Optional[Dict[str, float]]:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_bytes": float(m.argument_size_in_bytes),
            "output_bytes": float(m.output_size_in_bytes),
            "temp_bytes": float(m.temp_size_in_bytes),
            "alias_bytes": float(m.alias_size_in_bytes),
            "peak_bytes_est": float(m.argument_size_in_bytes
                                    + m.output_size_in_bytes
                                    + m.temp_size_in_bytes
                                    - m.alias_size_in_bytes),
        }
    except Exception:
        return None


def _cost_stats(compiled) -> Optional[Dict[str, float]]:
    try:
        ca = compiled.cost_analysis()
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and not k.startswith("utilization")}
    except Exception:
        return None


def simulate(compiled, hw: HardwareSpec = TPU_V5E, n_chips: int = 1,
             model_flops_global: float = 0.0, compute_dtype: str = "bf16",
             title: str = "", engine: str = "occupancy",
             n_cores: int = 1,
             topology: Optional[NodeTopology] = None,
             node_partition: str = "round-robin",
             sampling: Optional[SamplingConfig] = None) -> SimReport:
    """Simulate one compiled program on ``hw``: the paper's end-to-end flow
    (application binary -> execution-time estimate + PA data, DESIGN.md §2).

    ``compiled`` is a jax ``Compiled`` object, or raw HLO text.  The
    program is parsed once (DESIGN.md §9 byte-accounting rules) and costed
    once through the unified cost pipeline and memory hierarchy
    (DESIGN.md §3/§12); every engine shares that costed list.

    ``engine`` selects the overlap model:
      * ``"occupancy"`` (default) — the flat multi-port sum with assumed
        ``dma_overlap``/``ici_overlap`` fractions; fastest.
      * ``"schedule"``  — the dependency-aware O3 list scheduler
        (``core.schedule``): overlap is derived from the def-use graph and
        the hw issue/window/queue knobs; ``report.t_est`` comes from it.
      * ``"both"``      — run both; ``t_est`` stays occupancy-derived, the
        schedule rides along in ``report.schedule`` for comparison.
      * ``"node"``      — the multi-core node engine (``core.node``): the
        program runs on ``n_cores`` cores of ``topology`` (default: the
        spec's own, else a degenerate contention-free one) under
        ``node_partition`` ("round-robin" | "graph" | "shard");
        ``report.t_est`` is the contention-aware node makespan and the PA
        report gains the per-CMG contention section.

    ``sampling`` (node mode only) switches the node estimate to the
    SimPoint-style sampled path (``core.sample``, DESIGN.md §18): the
    program is sliced into intervals, clustered by signature, and only
    cluster representatives are scheduled; ``report.sampled`` carries the
    reconstruction and ``report.t_est`` comes from it.  Use for long
    traces (full-depth steps, multi-token decode) where scheduling every
    op is the bottleneck.

    Returns a :class:`SimReport`; ``report.pa`` is the human-readable PA
    report, ``report.to_json()`` the machine-readable artifact.  For
    sweeping many configurations prefer the batched paths
    (``calibrate.sweep_o3``, ``core.zoo`` — DESIGN.md §13/§15) over
    repeated ``simulate`` calls: they share parse/cost/compile work.
    """
    if engine not in ("occupancy", "schedule", "both", "node"):
        raise ValueError(f"unknown engine mode {engine!r}")
    if sampling is not None and engine != "node":
        raise ValueError("sampling= requires engine='node'")
    if isinstance(compiled, str):
        text = compiled
        cost = mem = None
    else:
        text = compiled.as_text()
        cost = _cost_stats(compiled)
        mem = _mem_stats(compiled)
    prog = parse_program(text)
    # one costing pass (hierarchy routing included); both engines share it
    costed = cost_program(prog, hw, compute_dtype=compute_dtype)
    eng = simulate_program(prog, hw, compute_dtype=compute_dtype,
                           costed=costed)
    # the PA report below renders the timeline/critical path, so ask the
    # scheduler for full detail up front (sweeps use the fast path instead)
    sched = (schedule_program(prog, hw, compute_dtype=compute_dtype,
                              costed=costed, detail=True)
             if engine in ("schedule", "both") else None)
    node = sampled = None
    if engine == "node":
        if sampling is not None:
            sampled = sampled_schedule_node(
                prog, hw, n_cores, topology=topology,
                partition=node_partition, config=sampling,
                compute_dtype=compute_dtype, costed=costed)
        else:
            node = simulate_node(prog, hw, n_cores, topology=topology,
                                 partition=node_partition,
                                 compute_dtype=compute_dtype, costed=costed)
    rf = roofline_from_program(prog, hw, n_chips, model_flops_global,
                               compute_dtype)
    summary = {
        "flops_per_device": prog.flops,
        "bytes_per_device": prog.bytes_accessed,
        "comm_bytes_per_device": prog.comm_bytes,
        "comm_by_collective": prog.comm_by_collective(),
        "by_class": prog.by_class(),
        "n_partitions": prog.n_partitions,
    }
    return SimReport(hw=hw.name, n_chips=n_chips, roofline=rf, engine=eng,
                     program_summary=summary,
                     pa=pa_report(rf, eng, prog, title, sched=sched,
                                  engine_mode=engine, node=node),
                     xla_cost_analysis=cost, memory_analysis=mem,
                     schedule=sched, engine_mode=engine, program=prog,
                     node=node, sampled=sampled)
