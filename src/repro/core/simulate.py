"""Top-level simulator API: compiled artifact -> SimReport.

    lowered  = jax.jit(step, ...).lower(**input_specs(arch))
    compiled = lowered.compile()
    report   = simulate(compiled, hw=TPU_V5E, n_chips=256,
                        model_flops_global=6 * N * D)
    print(report.pa)

This is the paper's end-to-end flow: application binary -> simulator ->
execution-cycle estimate + PA data, before the target hardware exists.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Any, Dict, Optional

from .engine import EngineResult, simulate_program
from .hlo import Program, parse_program
from .hwspec import HardwareSpec, TPU_V5E
from .pa import pa_report
from .roofline import Roofline, roofline_from_program


@dataclass
class SimReport:
    hw: str
    n_chips: int
    roofline: Roofline
    engine: EngineResult
    program_summary: Dict[str, Any]
    pa: str
    xla_cost_analysis: Optional[Dict[str, float]] = None
    memory_analysis: Optional[Dict[str, float]] = None

    @property
    def t_est(self) -> float:
        return self.engine.t_est

    def to_json(self) -> str:
        d = {
            "hw": self.hw,
            "n_chips": self.n_chips,
            "roofline": self.roofline.as_dict(),
            "engine": {
                "t_est": self.engine.t_est,
                "t_roofline": self.engine.t_roofline,
                "t_serial": self.engine.t_serial,
                "port_busy": self.engine.port_busy,
                "by_class_time": self.engine.by_class_time,
                "collective_time_by_kind": self.engine.collective_time_by_kind,
                "n_ops": self.engine.n_ops,
                "mxu_utilization": self.engine.mxu_utilization,
            },
            "program": self.program_summary,
            "xla_cost_analysis": self.xla_cost_analysis,
            "memory_analysis": self.memory_analysis,
        }
        return json.dumps(d, indent=1, sort_keys=True)


def _mem_stats(compiled) -> Optional[Dict[str, float]]:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_bytes": float(m.argument_size_in_bytes),
            "output_bytes": float(m.output_size_in_bytes),
            "temp_bytes": float(m.temp_size_in_bytes),
            "alias_bytes": float(m.alias_size_in_bytes),
            "peak_bytes_est": float(m.argument_size_in_bytes
                                    + m.output_size_in_bytes
                                    + m.temp_size_in_bytes
                                    - m.alias_size_in_bytes),
        }
    except Exception:
        return None


def _cost_stats(compiled) -> Optional[Dict[str, float]]:
    try:
        ca = compiled.cost_analysis()
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and not k.startswith("utilization")}
    except Exception:
        return None


def simulate(compiled, hw: HardwareSpec = TPU_V5E, n_chips: int = 1,
             model_flops_global: float = 0.0, compute_dtype: str = "bf16",
             title: str = "") -> SimReport:
    """``compiled`` is a jax Compiled object, or raw HLO text."""
    if isinstance(compiled, str):
        text = compiled
        cost = mem = None
    else:
        text = compiled.as_text()
        cost = _cost_stats(compiled)
        mem = _mem_stats(compiled)
    prog = parse_program(text)
    eng = simulate_program(prog, hw, compute_dtype=compute_dtype)
    rf = roofline_from_program(prog, hw, n_chips, model_flops_global,
                               compute_dtype)
    summary = {
        "flops_per_device": prog.flops,
        "bytes_per_device": prog.bytes_accessed,
        "comm_bytes_per_device": prog.comm_bytes,
        "comm_by_collective": prog.comm_by_collective(),
        "by_class": prog.by_class(),
        "n_partitions": prog.n_partitions,
    }
    return SimReport(hw=hw.name, n_chips=n_chips, roofline=rf, engine=eng,
                     program_summary=summary, pa=pa_report(rf, eng, prog, title),
                     xla_cost_analysis=cost, memory_analysis=mem)
