"""Unified per-op cost pipeline — one costing pass, two engines.

Extracted from ``core.engine`` so that the flat occupancy engine, the
dependency-aware schedule engine, calibration, and the PA report all
consume the SAME costed op list (``cost_program``) instead of re-running
the cost model per engine:

* port assignment (MXU / VPU / DMA-mem / ICI) and compute time from the
  dtype-dependent peak FLOP/s tables,
* memory time from the multi-level hierarchy router (``core.memory``):
  per-op reads and writes are split and charged at the level the
  reuse-distance/working-set model says the data lives at,
* collective time from ring-algorithm factors over ``group_size``.

``cost_op`` stays available for costing a single op out of program
context (traffic falls back to the working-set rule).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from .hlo import OpStat, Program
from .hwspec import HardwareSpec, SpecGrid
from .memory import (MemTraffic, route_program, route_program_batch,
                     route_standalone)


@dataclass
class OpTime:
    """Per-op cost decomposition: compute/memory/ICI times + routed traffic
    (the unified cost pipeline's unit, shared by all engines; DESIGN.md §3).
    """
    op: OpStat
    t_compute: float
    t_mem: float
    t_ici: float
    port: str
    useful_flops: float = 0.0     # matmul lane accounting (MXU utilization)
    padded_flops: float = 0.0
    traffic: Optional[MemTraffic] = None   # per-level routed bytes/times

    @property
    def t_op(self) -> float:
        return max(self.t_compute, self.t_mem, self.t_ici)


# ring-algorithm bandwidth factors: time = factor(g) * payload / bw
def collective_factor(kind: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g
    if kind == "all-gather":
        return float(g - 1)          # payload = shard bytes
    if kind == "reduce-scatter":
        return (g - 1) / g           # payload = full buffer
    if kind == "all-to-all":
        return (g - 1) / g
    if kind == "collective-permute":
        return 1.0
    return 1.0


def collective_links(kind: str, links: int) -> int:
    """Links a collective can drive concurrently: ring algorithms stream
    both ring directions (``links``, conventionally 2), but a
    collective-permute is ONE unidirectional send and gets no multi-link
    credit.  The single place this distinction lives — ``cost_op``,
    ``cost_program_batch``, the cluster engine and
    ``parallel.collectives.CollectiveCost`` all divide by it."""
    return 1 if kind == "collective-permute" else links


def collective_steps(kind: str, g: int) -> int:
    """Serial ring steps of a collective (the latency multiplier): an
    all-reduce is reduce-scatter + all-gather (2(g-1) steps), the
    single-phase collectives take g-1, a permute is one hop."""
    if g <= 1:
        return 0
    if kind == "all-reduce":
        return 2 * (g - 1)
    if kind == "collective-permute":
        return 1
    return g - 1


def cost_op(o: OpStat, hw: HardwareSpec, ici_bw: float,
            compute_dtype: Optional[str] = None,
            traffic: Optional[MemTraffic] = None,
            links_per_collective: int = 2) -> Optional[OpTime]:
    """Per-op port assignment + per-instance times.  ``traffic`` is the
    hierarchy-routed memory traffic from ``cost_program``; when absent the
    op is routed standalone (working-set rule only).  Returns None for ops
    the cost model does not charge."""
    denorm = compute_dtype in ("bf16", "f16")

    def eff_dtype() -> str:
        if denorm and o.dtype == "f32":
            return compute_dtype
        return o.dtype

    def trans_time() -> float:
        """Per-opcode latency table (paper's OpClass extension)."""
        if not o.trans_by_opcode:
            return o.transcendentals * hw.transcendental_factor
        return sum(v * hw.opcode_factor.get(k, hw.transcendental_factor)
                   for k, v in o.trans_by_opcode.items())

    def vpu_extra() -> float:
        """Extra flop-equivalents for non-transcendental opcodes with a
        per-opcode latency entry (minimum/round/convert/...): each element
        already contributes 1 flop to ``o.flops``; a factor f adds the
        remaining f-1.  Opcodes without an entry cost exactly 1 flop, so
        an empty table reproduces the old times bit-for-bit."""
        extra = 0.0
        for k, v in o.vpu_by_opcode.items():
            f = hw.opcode_factor.get(k)
            if f is not None:
                extra += v * (f - 1.0)
        return extra

    if traffic is None and o.opclass != "collective":
        traffic = route_standalone(o, hw.memory_hierarchy(), compute_dtype,
                                   warm_caches=hw.warm_caches)

    t_c = t_m = t_i = 0.0
    useful = padded_f = 0.0
    port = "vpu"
    if o.opclass == "matmul":
        port, util = _matmul_port_util(o, hw)
        padded = o.flops / max(util, 1e-9)
        useful = o.flops * o.count
        padded_f = padded * o.count
        peak = (hw.matmul_flops(eff_dtype()) if port == "mxu"
                else hw.vector_flops(eff_dtype()))
        t_c = padded / peak
        t_m = traffic.t_mem
    elif o.opclass in ("elementwise", "reduce"):
        base = o.flops - o.transcendentals
        t_c = (base + vpu_extra() + trans_time()) / hw.vector_flops(eff_dtype())
        t_m = traffic.t_mem
    elif o.opclass == "transcendental":
        t_c = trans_time() / hw.vector_flops(eff_dtype())
        t_m = traffic.t_mem
    elif o.opclass == "data":
        t_m = traffic.t_mem
        port = "mem"
    elif o.opclass == "collective":
        f = collective_factor(o.opcode, o.group_size)
        payload = (0.5 * o.comm_bytes
                   if denorm and o.dtype == "f32" else o.comm_bytes)
        # zero moved bytes (g<=1 collectives, empty payloads) must charge
        # startup only — on extreme specs with ici_bw == 0 the old
        # unconditional division made this 0/0 (raise/NaN) instead of the
        # finite startup time (the DSE spec-fuzz edge case).  A real
        # payload over a zero-bandwidth link is cleanly infeasible: inf,
        # never a ZeroDivisionError.
        moved = f * payload
        links = collective_links(o.opcode, links_per_collective)
        bw = ici_bw if links == links_per_collective \
            else links * hw.ici_bw_per_link
        if moved > 0.0:
            t_i = (moved / bw if bw > 0.0 else math.inf) \
                + hw.collective_startup_us * 1e-6
        else:
            t_i = hw.collective_startup_us * 1e-6
        port = "ici"
        traffic = None
    else:
        return None

    # OpClass throughput overrides (the paper's operand-type table)
    t_c *= hw.opclass_throughput.get(o.opclass, 1.0)
    return OpTime(o, t_c, t_m, t_i, port,
                  useful_flops=useful, padded_flops=padded_f,
                  traffic=traffic)


def cost_program(prog: Program, hw: HardwareSpec,
                 links_per_collective: int = 2,
                 compute_dtype: Optional[str] = None
                 ) -> List[Optional[OpTime]]:
    """Cost every op once, with hierarchy routing done in program context
    (reuse distances over the def-use edges).  Both engines consume this
    list; ``simulate(engine="both")`` computes it exactly once."""
    ici_bw = links_per_collective * hw.ici_bw_per_link
    traffic = route_program(prog, hw.memory_hierarchy(), compute_dtype,
                            warm_caches=hw.warm_caches)
    return [cost_op(o, hw, ici_bw, compute_dtype, traffic=tr,
                    links_per_collective=links_per_collective)
            for o, tr in zip(prog.ops, traffic)]


# ------------------------------------------------- spec-batched costing
@dataclass
class BatchCosted:
    """Spec-batched cost decomposition over a :class:`~.hwspec.SpecGrid`
    (DESIGN.md §19): ``[n_ops, S]`` time components and ``[n_ops, L, S]``
    routed bytes.

    Structure (port assignment, which ops are charged, loop counts) is
    spec-independent by the grid's uniformity contract, so it is stored
    once; column ``s`` of every array is bit-identical to the per-spec
    scalar pipeline (``cost_program`` under ``grid.specs[s]``, pinned by
    the differential suite).  Collective and uncharged rows carry zero
    memory traffic/latency, matching the scalar ``traffic=None`` rule.
    """
    grid: SpecGrid
    level_names: Tuple[str, ...]
    port: List[Optional[str]]    # [n]; None = uncharged by the cost model
    t_compute: np.ndarray        # [n, S]
    t_mem: np.ndarray            # [n, S]
    t_ici: np.ndarray            # [n, S]
    latency: np.ndarray          # [n, S] hierarchy access latency share
    rd: np.ndarray               # [n, L, S] routed read bytes (instance)
    wr: np.ndarray               # [n, L, S]
    count: np.ndarray            # [n] loop-trip counts (1.0 if uncharged)

    @property
    def n(self) -> int:
        return len(self.port)

    def t_op(self) -> np.ndarray:
        """[n, S] per-instance op time (max over components, the scalar
        ``OpTime.t_op`` order)."""
        return np.maximum(np.maximum(self.t_compute, self.t_mem),
                          self.t_ici)


def _matmul_port_util(o: OpStat, hw) -> Tuple[str, float]:
    """Port + utilization of one matmul op — shared between the scalar
    and batched pipelines (``hw`` needs only ``mxu_tile`` and
    ``min_matmul_dim_for_mxu``, uniform across a grid)."""
    port = "mxu"
    util = 1.0
    if o.dot_dims:
        m, n, k = o.dot_dims
        if min(m, n, k) < hw.min_matmul_dim_for_mxu:
            # tiny contraction/row dims: XLA emits a VPU multiply-
            # reduce, NOT an MXU matmul — no 128-tile quantization
            # (8-lane sublane padding only).
            port = "vpu"
            util = m * n * k / (max(m, 8 * math.ceil(m / 8), 1)
                                * n * k) if m else 1.0
        else:
            tm, tk, tn = hw.mxu_tile
            pm = math.ceil(m / tm) * tm
            pk = math.ceil(k / tk) * tk
            pn = math.ceil(n / tn) * tn
            util = (m * n * k) / max(pm * pn * pk, 1)
    return port, util


def cost_program_batch(prog: Program, grid: SpecGrid,
                       links_per_collective: int = 2,
                       compute_dtype: Optional[str] = None) -> BatchCosted:
    """Cost every op against every spec of the grid in one pass.

    Routing runs spec-batched (``route_program_batch``: def-use edges,
    opclasses and effective bytes computed once); per-op rate lookups
    (flops tables, per-opcode latency factors, transfer rates) become
    ``[S]`` vectors.  Bit-identity with the per-spec scalar loop is the
    contract: every accumulation replays ``cost_op``'s float ops in the
    same order per element — the per-opcode tables are folded in dict
    order, ``(base + vpu_extra) + trans`` keeps its association, and the
    collective guard matches the fixed scalar path.
    """
    S = grid.S
    n = len(prog.ops)
    L = len(grid.level_names)
    denorm = compute_dtype in ("bf16", "f16")
    tb = route_program_batch(prog, grid.hierarchies(), compute_dtype,
                             warm_caches=grid.warm_caches)
    t_mem_all = tb.t_mem                       # [n, S]
    ici_bw = links_per_collective * grid.ici_bw_per_link
    coll_start = grid.collective_startup_us * 1e-6

    port: List[Optional[str]] = [None] * n
    t_comp = np.zeros((n, S))
    t_ici = np.zeros((n, S))
    count = np.ones(n)
    zeros_s = np.zeros(S)                      # read-only template

    for i, o in enumerate(prog.ops):
        eff = (compute_dtype if denorm and o.dtype == "f32" else o.dtype)

        if o.opclass == "matmul":
            p, util = _matmul_port_util(o, grid)
            padded = o.flops / max(util, 1e-9)
            peak = (grid.matmul_flops(eff) if p == "mxu"
                    else grid.vector_flops(eff))
            tc = padded / peak
        elif o.opclass in ("elementwise", "reduce", "transcendental"):
            p = "vpu"
            if not o.trans_by_opcode:
                tt = o.transcendentals * grid.transcendental
            else:
                tt = zeros_s
                for k, v in o.trans_by_opcode.items():
                    tt = tt + v * grid.trans_factor(k)
            if o.opclass == "transcendental":
                tc = tt / grid.vector_flops(eff)
            else:
                base = o.flops - o.transcendentals
                extra = zeros_s
                for k, v in o.vpu_by_opcode.items():
                    extra = extra + v * grid.vpu_extra_factor(k)
                tc = (base + extra + tt) / grid.vector_flops(eff)
        elif o.opclass == "data":
            p = "mem"
            tc = zeros_s
        elif o.opclass == "collective":
            p = "ici"
            f = collective_factor(o.opcode, o.group_size)
            payload = (0.5 * o.comm_bytes
                       if denorm and o.dtype == "f32" else o.comm_bytes)
            moved = f * payload
            links = collective_links(o.opcode, links_per_collective)
            bw = ici_bw if links == links_per_collective \
                else links * grid.ici_bw_per_link
            if moved > 0.0:
                with np.errstate(divide="ignore"):
                    t_ici[i] = np.where(bw > 0.0, moved / bw,
                                        np.inf) + coll_start
            else:
                t_ici[i] = coll_start
            tc = zeros_s
        else:
            continue
        port[i] = p
        count[i] = o.count
        t_comp[i] = tc * grid.opclass_throughput_arr(o.opclass)

    # memory traffic applies only to charged, non-collective ops (the
    # scalar path drops ``traffic`` for collectives and never costs the
    # rest); zero their rows so downstream per-level tallies agree
    keep = np.array([p is not None and p != "ici" for p in port],
                    dtype=bool)
    t_mem = np.where(keep[:, None], t_mem_all, 0.0)
    latency = np.where(keep[:, None], tb.latency, 0.0)
    rd = tb.read_by_level
    wr = tb.write_by_level
    rd[~keep] = 0.0
    wr[~keep] = 0.0

    return BatchCosted(grid=grid, level_names=grid.level_names, port=port,
                       t_compute=t_comp, t_mem=t_mem, t_ici=t_ici,
                       latency=latency, rd=rd, wr=wr, count=count)
