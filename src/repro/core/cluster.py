"""Multi-node cluster engine: TofuD-style links over the node engine.

The paper stops at one node; the ROADMAP's first open item scales the
same methodology to a Fugaku-shaped mesh (DESIGN.md §20).  This module
layers a :class:`~.hwspec.ClusterTopology` — per-link bandwidth, hop
latency from node-mesh coordinates, a per-node injection aggregate —
on top of the §17 batched node engine, the way the node engine layered
CMG ring + shared L2/HBM2 domains on the single-core schedule:

1. **Plan** — a :class:`ParallelPlan` factors the node count into
   data x tensor x pipeline parallelism.  Shard-axis resolution is
   delegated to the ``parallel.sharding`` MeshRules table (via a
   resolver callback, see ``zoo.mesh_rules_resolver``): a component
   whose dims don't divide the tensor axis stays replicated, exactly as
   the GSPMD-rule fallback would leave it.
2. **Program** — the per-node program is the traced step with work
   scaled to its shard (tensor fraction, layers-per-stage count scale)
   and the plan's collectives injected as REAL scheduled ops
   (``opclass="collective"`` riding the ``ici`` port with def-use
   edges), so they overlap compute under the node engine's O3 model
   instead of being summed analytically.
3. **Price** — every collective is priced by the ONE canonical model
   (``core.cost.collective_factor`` / ``collective_links`` /
   ``collective_steps``): ring bytes over the per-direction link
   bandwidth divided by the ring's mean hop distance (a flow crossing h
   hops occupies h links), plus per-step hop latency and the software
   startup.  Concurrent collective streams (tp/dp/pp) share the node's
   TNIs through the same :func:`~.node.effective_bandwidth` fixpoint
   the node engine uses for shared memory levels.
4. **Schedule** — cells that share a (tp, pp) structure across node
   counts differ only in durations, so a whole scaling sweep runs as
   ONE batch of the §17 vectorized pass (``_node_pass_batch``), each
   element carrying its own memory- AND link-contention state machine.

Estimates are in the zoo's reduced-trace units: the claim is *relative*
(which plan wins, how efficiency decays with scale), not absolute
seconds — the same altitude as the rest of the zoo (DESIGN.md §15).
``zoo.run_cluster`` drives this over registry models and
``benchmarks/cluster_scaling.py`` emits ``BENCH_cluster.json``.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .compiled import PORTS, O3Knobs
from .cost import (collective_factor, collective_links, collective_steps,
                   cost_program)
from .hlo import OpStat, Program
from .hwspec import (A64FX_CORE, ClusterTopology, HardwareSpec,
                     NodeTopology)
from .node import (_eff_inv, _node_pass_batch, _update_active,
                   _work_domains, compile_node, compile_node_batch,
                   effective_bandwidth)

#: Ring collectives stream both torus directions; a permute gets no such
#: credit (``collective_links`` makes the distinction).
LINKS_PER_RING = 2


# ------------------------------------------------------------ plans & hops
@dataclass(frozen=True)
class ParallelPlan:
    """One (data, tensor, pipeline) factorization of the node count.

    Logical placement is row-major (pp, dp, tp) with tp fastest — tensor
    rings ride nearest-neighbour links, the pipeline axis gets the long
    strides — mirroring the TPU-mesh convention in ``launch.mesh``."""
    dp: int
    tp: int
    pp: int
    microbatches: int = 8

    @property
    def n_nodes(self) -> int:
        return self.dp * self.tp * self.pp

    @property
    def label(self) -> str:
        return f"dp{self.dp}xtp{self.tp}xpp{self.pp}"

    @property
    def bubble_fraction(self) -> float:
        """GPipe-style pipeline bubble: (pp-1)/m of the step exposed."""
        if self.pp <= 1:
            return 0.0
        return (self.pp - 1) / max(self.microbatches, 1)


@dataclass(frozen=True)
class ShardDecision:
    """Which tensor-parallel components actually shard at this tp (the
    MeshRules divisibility fallback decides; replicated components keep
    full compute and emit no collective)."""
    attn: bool = True
    mlp: bool = True
    experts: bool = False        # EP won the 'model' axis: all-to-all MoE

    def compute_scale(self, frac_attn: float, tp: int) -> float:
        """Per-node compute fraction under tensor parallelism: sharded
        components scale 1/tp, replicated ones don't (frac_attn is the
        attention share of per-layer work)."""
        if tp <= 1:
            return 1.0
        frac = frac_attn * float(self.attn) \
            + (1.0 - frac_attn) * float(self.mlp or self.experts)
        return frac / tp + (1.0 - frac)


def plan_shapes(max_tp: int = 16, max_pp: int = 16
                ) -> List[Tuple[int, int]]:
    """Candidate (tp, pp) structures: powers of two up to the caps.  dp
    is whatever the node count leaves over, so one structure serves a
    whole scaling sweep (same program, different durations)."""
    tps = [2 ** i for i in range(int(math.log2(max(max_tp, 1))) + 1)]
    pps = [2 ** i for i in range(int(math.log2(max(max_pp, 1))) + 1)]
    return [(t, p) for t in tps for p in pps]


def node_coords(cluster: ClusterTopology, ids: np.ndarray) -> np.ndarray:
    """Torus coordinates of node ids (row-major, last dim fastest)."""
    return np.stack(np.unravel_index(np.asarray(ids), cluster.mesh_shape),
                    axis=-1)


def torus_distance(cluster: ClusterTopology, a: np.ndarray,
                   b: np.ndarray) -> np.ndarray:
    """Manhattan hop count between node ids, with wraparound links."""
    d = np.abs(node_coords(cluster, a) - node_coords(cluster, b))
    if cluster.torus:
        d = np.minimum(d, np.asarray(cluster.mesh_shape) - d)
    return d.sum(axis=-1)


def axis_hops(cluster: ClusterTopology, plan: ParallelPlan
              ) -> Dict[str, float]:
    """Mean torus hop distance between ring neighbours, per logical
    axis, from the (pp, dp, tp) row-major placement — the "hop latency
    from node-mesh coordinates" term.  The pipeline axis is a chain, so
    its wraparound pair is excluded."""
    if plan.n_nodes != cluster.n_nodes:
        raise ValueError(f"plan {plan.label} places {plan.n_nodes} nodes "
                         f"on a {cluster.n_nodes}-node cluster")
    ids = np.arange(plan.n_nodes).reshape(plan.pp, plan.dp, plan.tp)
    out: Dict[str, float] = {}
    for name, ax, g, ring in (("tp", 2, plan.tp, True),
                              ("dp", 1, plan.dp, True),
                              ("pp", 0, plan.pp, False)):
        if g <= 1:
            out[name] = 0.0
            continue
        d = torus_distance(cluster, ids, np.roll(ids, -1, axis=ax))
        if not ring:
            sl = [slice(None)] * 3
            sl[ax] = slice(0, g - 1)
            d = d[tuple(sl)]
        out[name] = float(d.mean())
    return out


# --------------------------------------------------------- link-tier cost
def collective_time(kind: str, g: int, payload_bytes: float,
                    cluster: ClusterTopology, hops: float = 1.0,
                    n_active: float = 1.0) -> float:
    """Canonical inter-node collective time — the cluster engine's ONLY
    pricing path (the 2-node degenerate test recomputes it by hand).

    Wire term: ``collective_factor`` bytes over the effective link
    bandwidth — ``collective_links`` directions of ``link_bw``, divided
    by the ring's mean hop distance (a flow crossing h hops occupies h
    links), shared among ``n_active`` concurrent collective streams via
    the node engine's :func:`~.node.effective_bandwidth` against the
    TNI aggregate.  Latency term: ring steps x hops x hop latency +
    the software startup.  Zero moved bytes (g<=1, empty payload)
    charge latency only; a payload over zero bandwidth is ``inf`` —
    ``cost_op``'s conventions, one tier up.
    """
    moved = collective_factor(kind, g) * payload_bytes
    h = max(hops, 1.0)
    draw = collective_links(kind, LINKS_PER_RING) * cluster.link_bw / h
    agg = cluster.links_per_node * cluster.link_bw / h
    bw = float(effective_bandwidth(draw, agg, n_active))
    lat = collective_steps(kind, g) * hops * cluster.hop_latency_s \
        + cluster.collective_startup_us * 1e-6
    if moved > 0.0:
        return (moved / bw if bw > 0.0 else math.inf) + lat
    return lat


# -------------------------------------------------------- program building
@dataclass(frozen=True)
class CollectiveSite:
    """One injected collective of the per-node program.  ``axis`` names
    the logical ring it rides ('tp' | 'dp' | 'pp'); group size and hop
    distance are resolved per (plan, cluster) cell at pricing time, so
    one program structure serves a whole node-count sweep."""
    index: int                   # op index in the cluster program
    axis: str
    kind: str
    payload_bytes: float
    count: float


@dataclass(frozen=True)
class ClusterWorkload:
    """Everything the cluster engine needs to know about one traced
    model: the reduced one-step program plus the (reduced-unit) shape
    facts that size payloads.  ``zoo.cluster_workload`` builds these
    from registry configs; the quick bench builds synthetic ones."""
    name: str
    prog: Program
    repeats: int                 # full/reduced depth ratio (trace copies)
    layers: int                  # layers IN the reduced trace
    d_model: int
    seq_len: int
    batch: int                   # traced per-node batch
    param_bytes: float
    frac_attn: float = 0.4       # attention share of per-layer work
    moe_top_k: int = 0

    @property
    def act_bytes(self) -> float:
        """One residual-stream activation (f32, the traced dtype)."""
        return self.batch * self.seq_len * self.d_model * 4.0


def _scale_op(o: OpStat, s: float, count_scale: float) -> OpStat:
    """One op's shard copy: work fields scaled by ``s`` (tensor shard),
    loop count by ``count_scale`` (layers per stage).  ``dot_dims`` is
    left alone — MXU tile utilization is a per-tile property the shard
    keeps."""
    return dataclasses.replace(
        o,
        flops=o.flops * s,
        transcendentals=o.transcendentals * s,
        bytes_accessed=o.bytes_accessed * s,
        read_bytes=o.read_bytes * s,
        write_bytes=o.write_bytes * s,
        comm_bytes=o.comm_bytes * s,
        trans_by_opcode={k: v * s for k, v in o.trans_by_opcode.items()},
        vpu_by_opcode={k: v * s for k, v in o.vpu_by_opcode.items()},
        count=o.count * count_scale,
        deps=list(o.deps),
        dep_bytes=[b * s for b in o.dep_bytes],
    )


def _inject(ops: List[OpStat],
            protos: List[Tuple[float, OpStat, bool, str]]
            ) -> Tuple[List[OpStat], List[CollectiveSite]]:
    """Insert collective ops into the program at fractional positions.

    Each proto is ``(frac, op, blocking, axis)``: the op lands before
    the original op at ``int(frac * n)``, depends on its program-order
    predecessor through a zero-byte scheduling edge (no phantom
    traffic — the ``unroll_program`` convention), and when ``blocking``
    the displaced op gains a zero-byte dep on it (a consumer cannot
    proceed without the reduced/received activation).  Deps of the
    original ops are remapped to their shifted indices."""
    n = len(ops)
    by_pos: Dict[int, List[Tuple[OpStat, bool, str]]] = {}
    for frac, op, blocking, axis in protos:
        pos = min(n, max(0, int(frac * n)))
        by_pos.setdefault(pos, []).append((op, blocking, axis))
    new_ops: List[OpStat] = []
    old2new = np.empty(n, dtype=np.int64)
    extra_deps: Dict[int, List[int]] = {}
    sites: List[CollectiveSite] = []
    for i in range(n + 1):
        for op, blocking, axis in by_pos.get(i, ()):
            idx = len(new_ops)
            deps = [idx - 1] if idx > 0 else []
            new_ops.append(dataclasses.replace(
                op, deps=deps, dep_bytes=[0.0] * len(deps)))
            sites.append(CollectiveSite(
                index=idx, axis=axis, kind=op.opcode,
                payload_bytes=op.comm_bytes, count=op.count))
            if blocking and i < n:
                extra_deps.setdefault(i, []).append(idx)
        if i < n:
            old2new[i] = len(new_ops)
            new_ops.append(ops[i])
    for i in range(n):
        o = new_ops[old2new[i]]
        deps = [int(old2new[d]) for d in o.deps]
        dep_b = list(o.dep_bytes)
        for e in extra_deps.get(i, ()):
            deps.append(e)
            dep_b.append(0.0)
        new_ops[old2new[i]] = dataclasses.replace(o, deps=deps,
                                                  dep_bytes=dep_b)
    return new_ops, sites


def _coll(name: str, kind: str, payload: float, count: float) -> OpStat:
    return OpStat(name=name, opcode=kind, opclass="collective",
                  dtype="f32", comm_bytes=payload, group_size=0,
                  count=count)


def make_cluster_program(w: ClusterWorkload, tp: int, pp: int,
                         decision: Optional[ShardDecision] = None,
                         microbatches: int = 8
                         ) -> Tuple[Program, List[CollectiveSite]]:
    """The per-node program of one (tp, pp) structure + its collectives.

    Work scaling: every op's work fields shrink by the tensor-shard
    fraction; loop counts scale by ``repeats / pp`` (this node's share
    of the full depth, the ``trace_long_phase`` unit).  Injected ops,
    placed by position heuristics over the fwd (first half) / bwd
    (second half) regions of a traced train step:

    * tensor axis — per traced layer, a forward and backward all-reduce
      per sharded component (attention out-projection, FFN down-
      projection); MoE under expert parallelism emits dispatch+combine
      all-to-alls of ``top_k`` routed activations instead, fwd + bwd.
      Blocking: the next op consumes the reduced activation.
    * data axis — per-layer gradient-bucket all-reduces of this node's
      parameter shard, hanging off the backward region, non-blocking
      (they overlap the remaining backward and gate only the makespan).
    * pipeline axis — one forward and one backward boundary permute,
      ``microbatches`` sends of the per-microbatch activation, blocking.
      The (pp-1)/m bubble is applied analytically by the scheduler
      (:class:`ParallelPlan.bubble_fraction`).

    dp is NOT needed here: group sizes and hop distances resolve at
    pricing time, so this one structure serves every node count with
    ``n % (tp * pp) == 0`` — that is what lets a whole scaling curve run
    as one batch of the §17 engine.
    """
    if pp > max(w.repeats, 1):
        raise ValueError(f"pp={pp} exceeds the {w.repeats} trace copies "
                         f"of {w.name} (a stage needs >= 1)")
    decision = decision or ShardDecision()
    s_tp = decision.compute_scale(w.frac_attn, tp)
    cs = w.repeats / pp
    ops = [_scale_op(o, s_tp, cs) for o in w.prog.ops]
    L = max(w.layers, 1)
    act = w.act_bytes
    protos: List[Tuple[float, OpStat, bool, str]] = []
    if tp > 1:
        comps = []
        if decision.attn:
            comps.append(("attn", "all-reduce", act))
        if decision.experts and w.moe_top_k > 0:
            comps.append(("moe_dispatch", "all-to-all",
                          act * w.moe_top_k))
            comps.append(("moe_combine", "all-to-all",
                          act * w.moe_top_k))
        elif decision.mlp:
            comps.append(("mlp", "all-reduce", act))
        for li in range(L):
            for ci, (nm, kind, payload) in enumerate(comps):
                off = (li + (ci + 1.0) / (len(comps) + 1)) / L
                protos.append((0.05 + 0.40 * off,
                               _coll(f"tp_{nm}_fwd_l{li}", kind,
                                     payload, cs), True, "tp"))
                protos.append((0.50 + 0.40 * off,
                               _coll(f"tp_{nm}_bwd_l{li}", kind,
                                     payload, cs), True, "tp"))
    # data-parallel grad sync: this node's parameter bytes (tensor shard
    # of the sharded fraction, 1/pp of the depth), per-layer buckets
    grad_bytes = w.param_bytes * decision.compute_scale(
        w.frac_attn, tp) / pp
    for li in range(L):
        protos.append((0.55 + 0.40 * (li + 0.5) / L,
                       _coll(f"dp_grads_l{li}", "all-reduce",
                             grad_bytes / L, 1.0), False, "dp"))
    if pp > 1:
        m = max(microbatches, 1)
        protos.append((0.46, _coll("pp_fwd", "collective-permute",
                                   act / m, float(m)), True, "pp"))
        protos.append((0.92, _coll("pp_bwd", "collective-permute",
                                   act / m, float(m)), True, "pp"))
    new_ops, sites = _inject(ops, protos)
    prog = Program(ops=new_ops, entry=f"{w.prog.entry}@tp{tp}pp{pp}",
                   n_partitions=w.prog.n_partitions)
    return prog, sites


# ------------------------------------------------------------- scheduling
@dataclass
class ClusterResult:
    """One (workload, node count, plan) estimate."""
    workload: str
    n_nodes: int
    plan: ParallelPlan
    cluster: str                     # interconnect name (e.g. tofu_d_64)
    mesh_shape: Tuple[int, ...]
    t_step_s: float                  # makespan incl. pipeline bubble
    t_sched_s: float                 # scheduled makespan (no bubble)
    t_floor_s: float                 # compute-only floor (collectives free)
    parallel_efficiency: float       # t_floor / t_step
    tokens_per_s: float              # dp-weak-scaled global throughput
    ici_n_active: float              # converged concurrent-stream estimate
    iterations: int
    hops: Dict[str, float] = field(default_factory=dict)
    comm_s_by_kind: Dict[str, float] = field(default_factory=dict)
    decision: Optional[ShardDecision] = None


def _price_sites(sites: Sequence[CollectiveSite], plan: ParallelPlan,
                 cluster: ClusterTopology, hops: Dict[str, float],
                 n_active: float) -> np.ndarray:
    """[K] per-instance collective times under the current stream count."""
    g_of = {"tp": plan.tp, "dp": plan.dp, "pp": 2 if plan.pp > 1 else 1}
    return np.array([
        collective_time(s.kind, g_of[s.axis], s.payload_bytes, cluster,
                        hops=hops[s.axis], n_active=n_active)
        for s in sites])


def _stream_cap(sites: Sequence[CollectiveSite],
                plan: ParallelPlan) -> float:
    """Concurrent-collective cap: one stream per logical axis that
    actually moves bytes (the fixpoint's ``active_per_dom`` analogue)."""
    g_of = {"tp": plan.tp, "dp": plan.dp, "pp": 2 if plan.pp > 1 else 1}
    axes = {s.axis for s in sites
            if g_of[s.axis] > 1 and s.payload_bytes > 0.0}
    return float(max(len(axes), 1))


def schedule_cluster(prog: Program, sites: Sequence[CollectiveSite],
                     cells: Sequence[Tuple[ParallelPlan,
                                           ClusterTopology]],
                     hw: HardwareSpec = A64FX_CORE,
                     n_cores: int = 1,
                     topology: Optional[NodeTopology] = None,
                     compute_dtype: str = "f32",
                     knobs: Optional[O3Knobs] = None,
                     max_iters: int = 8, tol: float = 1e-2) -> List[dict]:
    """Schedule one cluster program for every (plan, cluster) cell, as
    ONE batch of the §17 vectorized pass, plus a shared compute-only
    floor element (collectives zeroed).

    Each element runs the node engine's memory-contention state machine
    (same damping/stop rules as ``schedule_node``) AND a link-tier
    fixpoint: collective durations are re-priced each round under
    ``n_active = clamp(ici_busy / makespan, 1, streams)`` — the
    :func:`~.node.effective_bandwidth` sharing rule applied to the
    TofuD injection aggregate.  Returns one dict per cell:
    ``t_sched/t_floor/ici_n_active/iterations/t_ici`` (converged
    per-site times).
    """
    topo = topology or hw.topology or NodeTopology.degenerate(n_cores)
    costed = cost_program(prog, hw, compute_dtype=compute_dtype)
    nc = compile_node(prog, hw, compute_dtype=compute_dtype,
                      costed=costed)
    nb = compile_node_batch(nc, hw, n_cores, topo, "shard")
    base_t_mem = np.array([ot.t_mem if ot is not None else 0.0
                           for ot in costed])
    coll_idx = np.array([s.index for s in sites], dtype=np.int64)
    M = len(cells) + 1                       # + the shared floor element
    kn = knobs or O3Knobs.single(hw)
    if kn.batch != 1:
        raise ValueError("schedule_cluster batches over cells; pass a "
                         "single knob combo (O3Knobs.single)")
    window = np.repeat(kn.window, M)
    width = np.repeat(kn.width, M, axis=0)
    depth = np.repeat(kn.depth, M, axis=0)
    ici_port = PORTS.index("ici")
    cores = np.arange(n_cores, dtype=np.int64)
    scale = 1.0 / n_cores
    has_caps = any(nm in topo.shared_read_bw or nm in topo.shared_write_bw
                   for nm in nc.level_names)
    mem_contended = has_caps and n_cores > 1

    # per-element state
    hops_l: List[Dict[str, float]] = []
    caps = np.ones(M)
    t_ici_el = [nc.t_ici.copy() for _ in range(M)]
    for m, (plan, cluster) in enumerate(cells):
        h = axis_hops(cluster, plan)
        hops_l.append(h)
        caps[m] = _stream_cap(sites, plan)
        # a TofuD node carries several TNIs: one in-flight collective per
        # active logical axis can drive the wire concurrently — raise the
        # ici issue width/depth to that axis count so the schedule can
        # overlap them, and let the n_active fixpoint below re-share the
        # injection bandwidth among whatever actually overlaps
        k = max(int(caps[m]), 1)
        width[m, ici_port] = max(width[m, ici_port], k)
        depth[m, ici_port] = max(depth[m, ici_port], k)
        if len(coll_idx):
            t_ici_el[m][coll_idx] = _price_sites(sites, plan, cluster,
                                                 h, 1.0)
    floor_m = M - 1
    if len(coll_idx):
        t_ici_el[floor_m][coll_idx] = 0.0
    ici_active = np.ones(M)
    mem_state = []
    for m in range(M):
        n_active, active_per_dom = _work_domains(
            nc, n_cores, True, nb.sched_core_of, cores)
        mem_state.append({"n_active": n_active,
                          "active_per_dom": active_per_dom})
    ici_contended = (caps > 1.0) & (np.arange(M) != floor_m)
    contended = np.full(M, mem_contended) | ici_contended

    t_est = np.zeros(M)
    iters = np.zeros(M, dtype=np.int64)
    done = np.zeros(M, dtype=bool)
    final = ~contended
    stale = np.ones(M, dtype=bool)
    durs_cols = np.empty((nc.n, M))

    def _durs(m: int) -> np.ndarray:
        st = mem_state[m]
        uncontended = all(float(a.max(initial=1.0)) <= 1.0
                          for a in st["n_active"])
        t_ici = t_ici_el[m]
        if uncontended and scale == 1.0:
            per = np.maximum(np.maximum(nc.t_comp, base_t_mem), t_ici)
        else:
            inv_r, inv_w = _eff_inv(nc, topo, cores, st["n_active"])
            t_mem = ((nc.rd * inv_r[0]).sum(axis=1)
                     + (nc.wr * inv_w[0]).sum(axis=1)) * scale + nc.lat
            per = np.maximum(np.maximum(nc.t_comp * scale, t_mem), t_ici)
        durs = (per + nc.startup) * nc.count
        durs[~nc.costed_mask] = 0.0
        if m == floor_m and len(coll_idx):
            durs[coll_idx] = 0.0            # compute-only floor
        return durs

    while not done.all():
        active = ~done
        for m in np.nonzero(active & stale)[0]:
            durs_cols[:, m] = _durs(m)
            stale[m] = False
        idx = np.nonzero(active)[0]
        t_est[idx] = _node_pass_batch(nb, durs_cols[:, idx], window[idx],
                                      width[idx], depth[idx])
        iters[idx] += 1
        done |= active & final
        for m in np.nonzero(active & ~final)[0]:
            damp = 0.5 if iters[m] > 1 else 1.0
            delta = 0.0
            if mem_contended:
                st = mem_state[m]
                st["n_active"], delta = _update_active(
                    nc, topo, cores, st["n_active"], nb.sched_core_of,
                    True, scale, n_cores, float(t_est[m]),
                    st["active_per_dom"], damp)
            if ici_contended[m] and len(coll_idx):
                busy = float((t_ici_el[m][coll_idx]
                              * nc.count[coll_idx]).sum())
                target = min(max(busy / max(float(t_est[m]), 1e-30),
                                 1.0), caps[m])
                nxt = damp * target + (1.0 - damp) * ici_active[m]
                delta = max(delta, abs(nxt - ici_active[m]))
                ici_active[m] = nxt
                plan, cluster = cells[m]
                t_ici_el[m][coll_idx] = _price_sites(
                    sites, plan, cluster, hops_l[m], float(nxt))
            if delta == 0.0:
                done[m] = True
            else:
                stale[m] = True
                final[m] = delta < tol or iters[m] >= max_iters

    t_floor = float(t_est[floor_m])
    out = []
    for m, (plan, cluster) in enumerate(cells):
        out.append({
            "plan": plan, "cluster": cluster, "hops": hops_l[m],
            "t_sched": float(t_est[m]), "t_floor": t_floor,
            "ici_n_active": float(ici_active[m]),
            "iterations": int(iters[m]),
            "t_ici": (t_ici_el[m][coll_idx].copy()
                      if len(coll_idx) else np.zeros(0)),
        })
    return out


def default_resolver(w: ClusterWorkload
                     ) -> Callable[[int], ShardDecision]:
    """Shard-everything resolver for synthetic workloads; real models go
    through ``zoo.mesh_rules_resolver`` (the MeshRules table + its
    divisibility fallback)."""
    def resolve(tp: int) -> ShardDecision:
        return ShardDecision(attn=True, mlp=True, experts=False)
    return resolve


def cluster_sweep(w: ClusterWorkload,
                  node_counts: Sequence[int],
                  hw: HardwareSpec = A64FX_CORE,
                  n_cores: int = 48,
                  topology: Optional[NodeTopology] = None,
                  compute_dtype: str = "f32",
                  resolver: Optional[Callable[[int],
                                              ShardDecision]] = None,
                  microbatches: int = 8,
                  max_tp: int = 16, max_pp: int = 16,
                  cluster_factory: Callable[[int], ClusterTopology]
                  = ClusterTopology.tofu_d,
                  max_iters: int = 8, tol: float = 1e-2,
                  progress: Optional[Callable[[str], None]] = None
                  ) -> List[ClusterResult]:
    """Sweep one workload over node counts x parallel plans.

    Plans are grouped by (tp, pp) structure: each group compiles ONE
    per-node program and schedules every node count (plus the shared
    compute-only floor) as one batch.  Returns every feasible cell; the
    report layer picks winners and ranks."""
    resolver = resolver or default_resolver(w)
    results: List[ClusterResult] = []
    for tp, pp in plan_shapes(max_tp, max_pp):
        if pp > max(w.repeats, 1):
            continue
        cells = []
        for n in node_counts:
            if n % (tp * pp) == 0 and n // (tp * pp) >= 1:
                plan = ParallelPlan(dp=n // (tp * pp), tp=tp, pp=pp,
                                    microbatches=microbatches)
                cells.append((plan, cluster_factory(n)))
        if not cells:
            continue
        decision = resolver(tp)
        prog, sites = make_cluster_program(w, tp, pp, decision,
                                           microbatches)
        if progress is not None:
            progress(f"{w.name} tp{tp}xpp{pp}: {len(cells)} node counts, "
                     f"{len(sites)} collectives, {len(prog.ops)} ops")
        rows = schedule_cluster(prog, sites, cells, hw, n_cores,
                                topology, compute_dtype,
                                max_iters=max_iters, tol=tol)
        for row in rows:
            plan = row["plan"]
            bubble = plan.bubble_fraction
            t_step = row["t_sched"] * (1.0 + bubble)
            by_kind: Dict[str, float] = {}
            for s, t in zip(sites, row["t_ici"]):
                by_kind[s.kind] = by_kind.get(s.kind, 0.0) \
                    + float(t) * s.count
            results.append(ClusterResult(
                workload=w.name, n_nodes=plan.n_nodes, plan=plan,
                cluster=row["cluster"].name,
                mesh_shape=tuple(row["cluster"].mesh_shape),
                t_step_s=t_step, t_sched_s=row["t_sched"],
                t_floor_s=row["t_floor"],
                parallel_efficiency=row["t_floor"] / max(t_step, 1e-30),
                tokens_per_s=plan.dp * w.batch * w.seq_len
                / max(t_step, 1e-30),
                ici_n_active=row["ici_n_active"],
                iterations=row["iterations"], hops=row["hops"],
                comm_s_by_kind=by_kind, decision=decision))
    return results
