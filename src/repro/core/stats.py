"""Section-scoped statistics — the paper's stats.txt region extension.

gem5 dumps whole-run statistics; the RIKEN simulator added *section*
statistics (stats over a program region), implemented via a two-pass script.
Here sections are first-class: a ``Stats`` object holds named counters;
``section(name)`` scopes every update (and wall time) to that region, and
``delta(a, b)`` gives region differences without any two-pass dance.
"""
from __future__ import annotations

import contextlib
import json
import time
from collections import defaultdict
from typing import Dict, Iterator


class Stats:
    """Sectioned counter sink for PA-style accounting (DESIGN.md §2)."""

    def __init__(self) -> None:
        self._sections: Dict[str, Dict[str, float]] = defaultdict(
            lambda: defaultdict(float))
        self._stack: list[str] = ["__global__"]

    # ------------------------------------------------------------- sections
    @contextlib.contextmanager
    def section(self, name: str) -> Iterator[None]:
        """Scope updates (and wall time) to ``name`` until exit.

        Wall-time attribution matches :meth:`add`'s counter semantics:
        an enclosing section's ``wall_s`` covers its nested sections
        (its own dt spans them); a section re-entered recursively is
        credited once, at the outermost exit (an inner exit would
        otherwise double-count — its dt is inside the outer one); and
        ``__global__`` accumulates the wall time of top-level sections.
        """
        self._stack.append(name)
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self._stack.pop()
            if name not in self._stack:
                self._sections[name]["wall_s"] += dt
                self._sections[name]["entries"] += 1
                if all(s == "__global__" for s in self._stack):
                    self._sections["__global__"]["wall_s"] += dt

    def add(self, counter: str, value: float = 1.0) -> None:
        """Adds to EVERY active section (the full nesting stack).

        Enclosing sections see their nested sections' counters — a
        ``steady`` region that wraps per-batch subsections still reports
        the total — and ``__global__`` (always the stack's base) keeps
        accumulating across sections.  A section re-entered recursively
        on the stack is credited once.
        """
        seen = set()
        for name in self._stack:
            if name not in seen:
                seen.add(name)
                self._sections[name][counter] += value

    # -------------------------------------------------------------- queries
    def get(self, counter: str, section: str = "__global__") -> float:
        return self._sections[section].get(counter, 0.0)

    def section_counters(self, section: str) -> Dict[str, float]:
        return dict(self._sections[section])

    def sections(self) -> list[str]:
        return [s for s in self._sections if s != "__global__"]

    def delta(self, a: str, b: str) -> Dict[str, float]:
        """Counter-wise difference between two sections."""
        keys = set(self._sections[a]) | set(self._sections[b])
        return {k: self._sections[a].get(k, 0.0) - self._sections[b].get(k, 0.0)
                for k in sorted(keys)}

    # --------------------------------------------------------------- output
    def report(self) -> str:
        lines = []
        for sec in ["__global__"] + self.sections():
            lines.append(f"[{sec}]")
            for k, v in sorted(self._sections[sec].items()):
                lines.append(f"  {k:<32s} {v:.6g}")
        return "\n".join(lines)

    def to_json(self) -> str:
        return json.dumps({k: dict(v) for k, v in self._sections.items()},
                          indent=1, sort_keys=True)


GLOBAL_STATS = Stats()
