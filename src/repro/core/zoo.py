"""Model-zoo estimation pipeline: every registry config through the node engine.

The paper's end goal is estimating execution cycles of *one-node
applications* — not isolated kernels — with accuracy good enough for
relative evaluation and tuning.  This module is that step (DESIGN.md §15):
it drives the whole ``configs.registry`` model zoo through the existing
kernels/HLO path and the multi-core node engine, one pipeline:

1. **Trace** — each architecture's representative phases (one train step,
   one prefill, one decode step; ``configs.shapes.ZOO_SHAPES``) are lowered
   and compiled through the real model/kernel stack at structure-preserving
   reduced width (``reduced_config``), and the compiled HLO is parsed into
   a costed :class:`~.hlo.Program`.  Traces are memoized in-process (the
   built model and abstract params are shared across a config's phases)
   and optionally on disk, so tests and sweeps never recompile.
2. **Estimate** — each program is sharded over the
   :class:`~.hwspec.NodeTopology` and scheduled by the contention-aware
   node engine (``core.node``, DESIGN.md §14) across a core-count axis,
   and the batched O3 knob grid runs as one fused core-count x knob
   sweep through the batched node engine
   (``core.node.schedule_node_sweep``, DESIGN.md §17) — per model, per
   phase, per core count: cycle estimates, the zero-contention bound,
   bound-by classification and roofline terms.
3. **Rank** — per phase, models are ranked by estimated time at every core
   count, and Kendall-tau rank correlations across the core-count axis
   (plus against active parameter count) quantify rank *stability* — the
   paper's relative-evaluation claim, gem5-style (per-workload error/rank
   reporting over a benchmark suite).

``benchmarks/model_zoo.py`` is the CLI: it emits ``BENCH_model_zoo.json``
(schema: DESIGN.md §16) under a CI-enforceable wall-clock budget, and
``tests/test_zoo.py`` pins the round-trip and the rank-stability floor.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from ..configs import ARCHS, ZOO_SHAPES, reduced_config, zoo_phases_for
from ..configs.base import ModelConfig, ShapeConfig
from .cluster import ClusterResult, ClusterWorkload, ShardDecision, \
    cluster_sweep
from .cost import cost_program
from .hlo import Program, parse_program
from .hwspec import A64FX_CORE, ClusterTopology, HardwareSpec, NodeTopology
from .node import compile_node, schedule_node, schedule_node_sweep
from .roofline import roofline_from_program
from .sample import SamplePlan, SamplingConfig, sample_program, \
    sampled_node_sweep, sampled_schedule_node, unroll_program

#: Core counts the default sweep estimates at: one core, one full CMG,
#: the whole 4-CMG node (mirrors the kernel suite's node section).
DEFAULT_CORE_COUNTS: Tuple[int, ...] = (1, 12, 48)

#: Node counts the cluster sweep scales over (powers of two to a rack-
#: scale 1024; the ROADMAP's "Fugaku-shaped mesh" open item).
DEFAULT_NODE_COUNTS: Tuple[int, ...] = (2, 4, 8, 16, 32, 64, 128, 256,
                                        512, 1024)

#: The cluster bench's default models: the largest MoE (expert
#: parallelism in play) and the largest dense config in the registry.
DEFAULT_CLUSTER_MODELS: Tuple[str, ...] = ("grok-1-314b",
                                           "nemotron-4-340b")

#: A64FX clock — node times convert to the paper's execution-cycle unit.
DEFAULT_CLOCK_HZ = 1.8e9

# compact O3 knob subsets for the zoo's batched grid (12 combos; the full
# calibrate grid is 90 — overkill per (model, phase, core count) cell)
ZOO_O3_WINDOWS = (16, 64, 256)
ZOO_O3_MEM_WIDTHS = (1, 2)
ZOO_O3_VPU_WIDTHS = (1, 2)
ZOO_O3_QUEUE_DEPTHS = (16,)

#: Bump to invalidate every on-disk HLO cache entry (routing/schema
#: changes that alter what a cached trace means).
HLO_CACHE_SCHEMA = 2

#: Bump to invalidate the on-disk serving cost cells (``serving_cell_cost``)
#: when the node engine's estimates change meaning.
SERVING_COST_SCHEMA = 1

# ----------------------------------------------------------------- tracing
# (arch, param_dtype) -> (model, abstract params); shared across phases so
# one build serves train + prefill + decode
_MODEL_CACHE: Dict[tuple, tuple] = {}
# (arch, phase, seq_len, global_batch, param_dtype) -> Program
_PROGRAM_CACHE: Dict[tuple, Program] = {}


def clear_trace_caches() -> None:
    """Drop the in-process model/program memos (tests use this)."""
    _MODEL_CACHE.clear()
    _PROGRAM_CACHE.clear()


def zoo_config(arch: str) -> ModelConfig:
    """The config the zoo traces for ``arch``: the structure-preserving
    reduced form (same family/MoE/SSM/GQA/enc-dec features, toy width).

    Full-size sharded cells remain ``launch.dryrun``'s job; the zoo's
    question is *relative* cross-architecture behaviour on the node model,
    which the reduced forms preserve at a compile cost of seconds.
    """
    return reduced_config(ARCHS[arch])


def phase_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS for the roofline: 6·N_active·D (train), 2·N_active·D
    (prefill), 2·N_active·B (decode: one token per sequence)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch


def _traced_model(arch: str, param_dtype: str):
    import jax.numpy as jnp

    from ..models import params as pr
    from ..models.lm import build_model
    key = (arch, param_dtype)
    hit = _MODEL_CACHE.get(key)
    if hit is not None:
        return hit
    cfg = zoo_config(arch)
    model = build_model(cfg)
    p_abs = pr.abstract(model.param_specs(), jnp.dtype(param_dtype))
    _MODEL_CACHE[key] = (cfg, model, p_abs)
    return _MODEL_CACHE[key]


def hlo_cache_key(arch: str, phase: str, shape: ShapeConfig,
                  param_dtype: str) -> str:
    """Content hash of everything the cached HLO depends on: the FULL
    reduced model config, the shape, the dtype, and ``HLO_CACHE_SCHEMA``.
    A name-only key (the pre-schema-2 scheme) silently served stale HLO
    when a registry config or zoo shape changed under the same name."""
    cfg = zoo_config(arch)
    payload = json.dumps({
        "schema": HLO_CACHE_SCHEMA,
        "config": dataclasses.asdict(cfg),
        "shape": dataclasses.asdict(shape),
        "phase": phase,
        "param_dtype": param_dtype,
    }, sort_keys=True, default=str)
    return hashlib.sha256(payload.encode()).hexdigest()[:12]


def hlo_cache_path(cache_dir: Path, arch: str, phase: str,
                   shape: ShapeConfig, param_dtype: str) -> Path:
    """Cache file for one (arch, phase) cell: human-readable prefix +
    content hash, so a config/shape/schema change misses instead of
    reading a stale trace."""
    h = hlo_cache_key(arch, phase, shape, param_dtype)
    return Path(cache_dir) / (
        f"{arch}__{phase}_s{shape.seq_len}b{shape.global_batch}"
        f"_{param_dtype}.{h}.hlo.txt")


def _phase_hlo(arch: str, phase: str, shape: ShapeConfig,
               param_dtype: str) -> str:
    """Lower + compile one (arch, phase) cell on the host device and
    return the compiled HLO text (the simulator's input artifact)."""
    import jax
    import jax.numpy as jnp

    from ..models import params as pr
    from ..serve.engine import make_decode_step, make_prefill_step
    from ..serve.kvcache import cache_abstract
    from ..train.trainer import make_train_step
    from ..configs.base import RunConfig

    cfg, model, p_abs = _traced_model(arch, param_dtype)
    pdt = jnp.dtype(param_dtype)
    b_abs = model.input_specs(shape, pdt)
    if phase == "train":
        run = RunConfig(model=cfg, shape=shape, param_dtype=param_dtype,
                        compute_dtype=param_dtype)
        step, _, opt_specs, *_ = make_train_step(model, run, rules=None)
        o_abs = pr.abstract(opt_specs, jnp.dtype(run.optimizer_dtype))
        lowered = jax.jit(step).lower(p_abs, o_abs, b_abs)
    elif phase == "prefill":
        step = make_prefill_step(model, rules=None)
        lowered = jax.jit(step).lower(p_abs, b_abs)
    elif phase == "decode":
        step = make_decode_step(model, rules=None)
        c_abs = cache_abstract(model, shape.global_batch, shape.seq_len, pdt)
        lowered = jax.jit(step).lower(p_abs, c_abs, b_abs)
    else:
        raise ValueError(f"unknown zoo phase {phase!r}")
    return lowered.compile().as_text()


def trace_phase(arch: str, phase: str,
                shape: Optional[ShapeConfig] = None,
                param_dtype: str = "float32",
                hlo_cache_dir: Optional[Path] = None) -> Program:
    """Trace one (architecture, phase) cell into a parsed ``Program``.

    Memoized in-process on (arch, phase, shape, dtype); ``hlo_cache_dir``
    additionally persists the compiled HLO text across processes (the
    model-zoo benchmark's warm path — parsing is milliseconds, the jax
    compile is the seconds that would blow the wall-clock budget).
    """
    if phase not in ZOO_SHAPES and shape is None:
        raise ValueError(f"unknown zoo phase {phase!r}; "
                         f"known: {sorted(ZOO_SHAPES)}")
    shape = shape or ZOO_SHAPES[phase]
    key = (arch, phase, shape.seq_len, shape.global_batch, param_dtype)
    prog = _PROGRAM_CACHE.get(key)
    if prog is not None:
        return prog
    text = None
    cache_file = None
    if hlo_cache_dir is not None:
        cache_file = hlo_cache_path(Path(hlo_cache_dir), arch, phase,
                                    shape, param_dtype)
        if cache_file.exists():
            text = cache_file.read_text()
    if text is None:
        text = _phase_hlo(arch, phase, shape, param_dtype)
        if cache_file is not None:
            cache_file.parent.mkdir(parents=True, exist_ok=True)
            cache_file.write_text(text)
    prog = parse_program(text)
    _PROGRAM_CACHE[key] = prog
    return prog


def long_trace_repeats(arch: str, phase: str,
                       decode_steps: int = 64) -> int:
    """How many copies of the traced step the full-width/full-depth trace
    concatenates: the full/reduced layer-count ratio for ``train`` and
    ``prefill`` (the reduced trace collapses the stack to <= 4 layers),
    ``decode_steps`` near-identical token steps for ``decode``."""
    if phase == "decode":
        return max(1, int(decode_steps))
    full = ARCHS[arch].n_layers
    reduced = zoo_config(arch).n_layers
    return max(1, -(-full // max(reduced, 1)))      # ceil div


def trace_long_phase(arch: str, phase: str,
                     shape: Optional[ShapeConfig] = None,
                     param_dtype: str = "float32",
                     hlo_cache_dir: Optional[Path] = None,
                     decode_steps: int = 64,
                     repeats: Optional[int] = None) -> Tuple[Program, int]:
    """The full-depth/multi-step trace of one zoo cell: the reduced trace
    of :func:`trace_phase` unrolled ``repeats`` times
    (:func:`~.sample.unroll_program` — deps shift per copy, copies chain
    through zero-byte scheduling edges).  ~100x more op instances than
    the reduced trace, which only the sampled estimator
    (DESIGN.md §18) schedules inside a CI budget.  Returns
    ``(program, repeats)``."""
    step = trace_phase(arch, phase, shape, param_dtype, hlo_cache_dir)
    r = repeats if repeats is not None else \
        long_trace_repeats(arch, phase, decode_steps)
    return unroll_program(step, r), r


# ------------------------------------------------------- serving cost cells
def serving_cost_key(arch: str, phase: str, shape: ShapeConfig,
                     n_cores: int, compute_dtype: str,
                     param_dtype: str) -> str:
    """Content hash for one serving cost cell (``serving_cell_cost``).

    The hash covers everything the cached estimate depends on — the full
    reduced config, the shape, the core count, both dtypes, both schema
    counters — and the ``phase`` string itself.  The phase MUST be in the
    key: the zoo's reduced prefill and decode shapes are deliberately
    identical (``ZOO_PREFILL``/``ZOO_DECODE``: seq 256, batch 2), so a
    shape-only key would silently serve a prefill estimate for a decode
    cell (the aliasing ``tests/test_serving.py`` pins against).
    """
    cfg = zoo_config(arch)
    payload = json.dumps({
        "schema": SERVING_COST_SCHEMA,
        "hlo_schema": HLO_CACHE_SCHEMA,
        "config": dataclasses.asdict(cfg),
        "shape": dataclasses.asdict(shape),
        "phase": phase,
        "n_cores": n_cores,
        "compute_dtype": compute_dtype,
        "param_dtype": param_dtype,
    }, sort_keys=True, default=str)
    return hashlib.sha256(payload.encode()).hexdigest()[:12]


def serving_cell_cost(arch: str, phase: str,
                      shape: Optional[ShapeConfig] = None,
                      n_cores: int = 48,
                      hw: HardwareSpec = A64FX_CORE,
                      topology: Optional[NodeTopology] = None,
                      compute_dtype: str = "f32",
                      param_dtype: str = "float32",
                      hlo_cache_dir: Optional[Path] = None,
                      cost_cache_dir: Optional[Path] = None) -> float:
    """Node-engine ``t_est_s`` of one (arch, phase, shape) serving cell.

    The serving simulator (``core.serving``, DESIGN.md §21) prices prefill
    and decode iterations from these cells; ``cost_cache_dir`` persists
    each estimate as a small JSON file so serving sweeps never re-trace or
    re-schedule a cell (the jax compile is seconds; the node schedule is
    tens of milliseconds; the cached read is microseconds).  The file name
    embeds the phase AND the content hash of :func:`serving_cost_key` —
    prefill/decode cells at the zoo's equal reduced shapes land in
    different files with different hashes.
    """
    shape = shape or ZOO_SHAPES[phase]
    cpath = None
    if cost_cache_dir is not None:
        key = serving_cost_key(arch, phase, shape, n_cores,
                               compute_dtype, param_dtype)
        cpath = Path(cost_cache_dir) / (
            f"{arch}__serve_{phase}_s{shape.seq_len}b{shape.global_batch}"
            f"_{n_cores}c.{key}.json")
        if cpath.exists():
            return float(json.loads(cpath.read_text())["t_est_s"])
    prog = trace_phase(arch, phase, shape, param_dtype, hlo_cache_dir)
    pe = estimate_program(prog, hw, (n_cores,),
                          topology or hw.topology, "shard", compute_dtype,
                          arch=arch, phase=phase)
    t = float(pe.at(n_cores).t_est_s)
    if cpath is not None:
        cpath.parent.mkdir(parents=True, exist_ok=True)
        cpath.write_text(json.dumps({
            "schema": SERVING_COST_SCHEMA, "arch": arch, "phase": phase,
            "seq_len": shape.seq_len, "global_batch": shape.global_batch,
            "n_cores": n_cores, "t_est_s": t}, indent=1))
    return t


# ------------------------------------------------------------- rank utility
def kendall_tau(xs: Sequence[float], ys: Sequence[float]) -> float:
    """Kendall tau-b (tie-corrected) rank correlation; O(n²), n is tiny.

    Shared by the zoo's rank-stability tables and the accuracy-regression
    tests — no scipy dependency.
    """
    n = len(xs)
    conc = disc = tie_x = tie_y = 0
    for i in range(n):
        for j in range(i + 1, n):
            dx = xs[i] - xs[j]
            dy = ys[i] - ys[j]
            if dx == 0 and dy == 0:
                tie_x += 1
                tie_y += 1
            elif dx == 0:
                tie_x += 1
            elif dy == 0:
                tie_y += 1
            elif (dx > 0) == (dy > 0):
                conc += 1
            else:
                disc += 1
    n0 = n * (n - 1) / 2
    denom = ((n0 - tie_x) * (n0 - tie_y)) ** 0.5
    return (conc - disc) / denom if denom > 0 else 0.0


# ------------------------------------------------------------------ results
@dataclass
class CoreCountEstimate:
    """Node-engine estimate of one (model, phase) program at one core count."""
    n_cores: int
    t_est_s: float                   # contention-aware node makespan
    t_zero_contention_s: float       # fixpoint iteration 0 (lower bound)
    parallel_efficiency: float       # busy / (cores x makespan)
    bound_by: str                    # binding port of the node schedule
    shared_n_active: Dict[str, float] = field(default_factory=dict)
    # batched O3 knob grid riding the same compiled form (0.0 = grid off)
    t_best_knobs_s: float = 0.0
    best_knobs: Optional[Dict[str, int]] = None

    def cycles(self, clock_hz: float = DEFAULT_CLOCK_HZ) -> float:
        """Execution cycles at ``clock_hz`` — the paper's headline unit."""
        return self.t_est_s * clock_hz


@dataclass
class PhaseEstimate:
    """One (model, phase) row: program summary + per-core-count estimates."""
    arch: str
    phase: str
    n_ops: int                       # parsed HLO ops
    n_costed: int                    # ops the cost model charges
    flops: float
    bytes_accessed: float
    roofline_dominant: str           # compute | memory | collective
    roofline_fraction: float
    per_core: List[CoreCountEstimate] = field(default_factory=list)
    # sampled-estimation metadata (None = every op scheduled); the long
    # full-depth trace mode records its unroll factor in trace_repeats
    sampling: Optional[Dict[str, float]] = None
    trace_repeats: int = 1

    def at(self, n_cores: int) -> CoreCountEstimate:
        """The estimate at one swept core count (KeyError if not swept)."""
        for ce in self.per_core:
            if ce.n_cores == n_cores:
                return ce
        raise KeyError(f"core count {n_cores} not swept for "
                       f"{self.arch}/{self.phase}")

    @property
    def node_speedup(self) -> float:
        """t_est at the smallest swept core count / at the largest."""
        if not self.per_core:
            return 1.0
        lo = min(self.per_core, key=lambda c: c.n_cores)
        hi = max(self.per_core, key=lambda c: c.n_cores)
        return lo.t_est_s / max(hi.t_est_s, 1e-30)


@dataclass
class ZooReport:
    """The full zoo sweep: estimates + rank tables + stability taus."""
    hw: str
    topology: str
    partition: str
    compute_dtype: str
    clock_hz: float
    core_counts: Tuple[int, ...]
    phases: Tuple[str, ...]
    # arch -> phase -> PhaseEstimate
    estimates: Dict[str, Dict[str, PhaseEstimate]] = field(
        default_factory=dict)
    wall_s: float = 0.0

    def rank_table(self, phase: str, n_cores: int) -> List[str]:
        """Archs ranked fastest-first by node ``t_est`` for one phase at
        one core count (archs missing the phase are omitted)."""
        rows = [(est[phase].at(n_cores).t_est_s, arch)
                for arch, est in self.estimates.items() if phase in est]
        return [arch for _, arch in sorted(rows)]

    def rank_stability(self, phase: str) -> Dict[str, float]:
        """Kendall taus for one phase: between every adjacent pair of the
        core-count axis (``"1->12"`` style keys), their ``min``, and
        ``vs_flops`` (estimate order vs traced-work order — the sanity
        rank: more compiled FLOPs should mean a slower estimate)."""
        archs = [a for a, est in self.estimates.items() if phase in est]
        t = {k: [self.estimates[a][phase].at(k).t_est_s for a in archs]
             for k in self.core_counts}
        out: Dict[str, float] = {}
        pair_taus = []
        for lo, hi in zip(self.core_counts, self.core_counts[1:]):
            tau = kendall_tau(t[lo], t[hi])
            out[f"{lo}->{hi}"] = tau
            pair_taus.append(tau)
        out["min"] = min(pair_taus) if pair_taus else 1.0
        work = [self.estimates[a][phase].flops for a in archs]
        out["vs_flops"] = kendall_tau(work, t[min(self.core_counts)])
        return out

    def to_dict(self) -> dict:
        """The ``BENCH_model_zoo.json`` payload (schema: DESIGN.md §16)."""
        models: Dict[str, dict] = {}
        for arch, by_phase in self.estimates.items():
            cfg = zoo_config(arch) if arch in ARCHS else None
            phases = {}
            for phase, pe in by_phase.items():
                phases[phase] = {
                    "n_ops": pe.n_ops,
                    "n_costed": pe.n_costed,
                    "flops": pe.flops,
                    "bytes_accessed": pe.bytes_accessed,
                    "roofline_dominant": pe.roofline_dominant,
                    "roofline_fraction": pe.roofline_fraction,
                    "node_speedup": pe.node_speedup,
                    "sampling": pe.sampling,
                    "trace_repeats": pe.trace_repeats,
                    "per_core": {
                        str(ce.n_cores): {
                            "t_est_us": ce.t_est_s * 1e6,
                            "cycles": ce.cycles(self.clock_hz),
                            "t_zero_contention_us":
                                ce.t_zero_contention_s * 1e6,
                            "parallel_efficiency": ce.parallel_efficiency,
                            "bound_by": ce.bound_by,
                            "shared_n_active": ce.shared_n_active,
                            "t_best_knobs_us": ce.t_best_knobs_s * 1e6,
                            "best_knobs": ce.best_knobs,
                        } for ce in pe.per_core},
                }
            models[arch] = {
                "family": cfg.family if cfg else "",
                "param_count": cfg.param_count() if cfg else 0,
                "active_param_count": (ARCHS[arch].active_param_count()
                                       if arch in ARCHS else 0),
                "phases": phases,
            }
        rank = {ph: {str(k): self.rank_table(ph, k)
                     for k in self.core_counts}
                for ph in self.phases}
        taus = {ph: self.rank_stability(ph) for ph in self.phases}
        return {
            "schema": 1,
            "hw": self.hw,
            "topology": self.topology,
            "partition": self.partition,
            "compute_dtype": self.compute_dtype,
            "clock_ghz": self.clock_hz / 1e9,
            "core_counts": list(self.core_counts),
            "phases": list(self.phases),
            "models": models,
            "rank": rank,
            "kendall_tau": taus,
            "wall_s": self.wall_s,
        }


# ------------------------------------------------------------- the pipeline
def estimate_program(prog: Program, hw: HardwareSpec = A64FX_CORE,
                     core_counts: Sequence[int] = DEFAULT_CORE_COUNTS,
                     topology: Optional[NodeTopology] = None,
                     partition: str = "shard",
                     compute_dtype: str = "f32",
                     model_flops: float = 0.0,
                     o3_knobs=None,
                     arch: str = "", phase: str = "",
                     sampling: Optional[SamplingConfig] = None
                     ) -> PhaseEstimate:
    """Estimate one traced program across the core-count axis.

    The program is costed once (``compile_node`` memoizes the node form on
    the ``Program``); only the node schedule reruns per core count.  When
    ``o3_knobs`` (an :class:`~.compiled.O3Knobs` batch) is given, the
    batched node engine (``core.node.schedule_node_sweep``) runs the
    whole core-count x knob grid as ONE fused batch — every cell gets
    its own exact contention fixpoint — and the best combo per count is
    recorded: the ``calibrate.sweep_o3`` machinery pointed at
    applications instead of microkernels (DESIGN.md §17).

    ``sampling`` switches every schedule in the cell to the SimPoint-style
    sampled path (``core.sample``, DESIGN.md §18): the program is sliced,
    clustered ONCE, and only cluster representatives are scheduled at
    each core count / knob combo — the mode that makes the long
    full-depth traces (:func:`trace_long_phase`) affordable.
    """
    topo = topology or hw.topology or NodeTopology.degenerate(
        max(core_counts))
    rf = roofline_from_program(prog, hw, 1, model_flops, compute_dtype)
    plan: Optional[SamplePlan] = None
    if sampling is not None:
        costed = cost_program(prog, hw, compute_dtype=compute_dtype)
        plan = sample_program(prog, hw, sampling, compute_dtype, costed)
        n_costed = sum(1 for ot in costed if ot is not None)
    else:
        nc = compile_node(prog, hw, compute_dtype=compute_dtype)
        n_costed = int(nc.costed_mask.sum())
    pe = PhaseEstimate(
        arch=arch, phase=phase, n_ops=len(prog.ops),
        n_costed=n_costed,
        flops=prog.flops, bytes_accessed=prog.bytes_accessed,
        roofline_dominant=rf.dominant,
        roofline_fraction=rf.roofline_fraction)
    if plan is not None:
        pe.sampling = {
            "k": plan.k, "n_intervals": plan.n_intervals,
            "interval_ops": plan.config.interval_ops,
            "seed": plan.config.seed,
            "frac_ops_scheduled": plan.frac_ops_scheduled,
        }
    knob_ts = None
    if o3_knobs is not None:
        if plan is not None:
            knob_ts, _ = sampled_node_sweep(
                prog, hw, o3_knobs, core_counts, topology=topo,
                partition=partition, compute_dtype=compute_dtype,
                plan=plan)
        else:
            knob_ts = schedule_node_sweep(nc, hw, o3_knobs, core_counts,
                                          topology=topo,
                                          partition=partition)
    for ki, k in enumerate(core_counts):
        if plan is not None:
            sr = sampled_schedule_node(
                prog, hw, k, topology=topo, partition=partition,
                compute_dtype=compute_dtype, plan=plan)
            ce = CoreCountEstimate(
                n_cores=k, t_est_s=sr.t_est,
                t_zero_contention_s=sr.t_zero_contention,
                parallel_efficiency=sr.parallel_efficiency,
                bound_by=sr.bound_by)
        else:
            nr = schedule_node(nc, hw, k, topology=topo,
                               partition=partition)
            ce = CoreCountEstimate(
                n_cores=k, t_est_s=nr.t_est,
                t_zero_contention_s=nr.t_zero_contention,
                parallel_efficiency=nr.parallel_efficiency,
                bound_by=nr.schedule.bound_by,
                shared_n_active=dict(nr.per_cmg[0].n_active))
        if knob_ts is not None:
            ts = knob_ts[ki]
            best = int(ts.argmin())
            ce.t_best_knobs_s = float(ts[best])
            ce.best_knobs = {
                "inflight_window": int(o3_knobs.window[best]),
                "mem_issue_width": int(o3_knobs.width[best, 2]),
                "vpu_issue_width": int(o3_knobs.width[best, 1]),
                "queue_depth": int(o3_knobs.depth[best, 2]),
            }
        pe.per_core.append(ce)
    return pe


def zoo_workloads(models: Sequence[str],
                  phases: Sequence[str]) -> List[Tuple[str, str]]:
    """Validated ``(arch, phase)`` cells for the DSE sweep (``core.dse``):
    the cross product of ``models`` and ``phases``, checked against the
    registry and each architecture's supported phases — a typo fails
    here, not 64 specs into a sweep."""
    out: List[Tuple[str, str]] = []
    for m in models:
        if m not in ARCHS:
            raise ValueError(f"unknown arch {m!r}; known: {sorted(ARCHS)}")
        supported = zoo_phases_for(ARCHS[m])
        for ph in phases:
            if ph not in ZOO_SHAPES:
                raise ValueError(f"unknown phase {ph!r}; "
                                 f"known: {sorted(ZOO_SHAPES)}")
            if ph in supported:
                out.append((m, ph))
    if not out:
        raise ValueError("no (arch, phase) cells survived filtering")
    return out


def zoo_o3_knobs(hw: HardwareSpec):
    """The zoo's compact batched knob grid (12 combos around ``hw``)."""
    from .calibrate import default_o3_knobs
    return default_o3_knobs(hw, windows=ZOO_O3_WINDOWS,
                            mem_widths=ZOO_O3_MEM_WIDTHS,
                            vpu_widths=ZOO_O3_VPU_WIDTHS,
                            queue_depths=ZOO_O3_QUEUE_DEPTHS)


def run_zoo(models: Optional[Sequence[str]] = None,
            phases: Optional[Sequence[str]] = None,
            hw: HardwareSpec = A64FX_CORE,
            core_counts: Sequence[int] = DEFAULT_CORE_COUNTS,
            topology: Optional[NodeTopology] = None,
            partition: str = "shard",
            compute_dtype: str = "f32",
            param_dtype: str = "float32",
            clock_hz: float = DEFAULT_CLOCK_HZ,
            with_o3_grid: bool = True,
            hlo_cache_dir: Optional[Path] = None,
            progress=None,
            long_traces: bool = False,
            decode_steps: int = 64,
            sampling: Optional[SamplingConfig] = None) -> ZooReport:
    """Trace + estimate + rank the model zoo end to end.

    ``models`` defaults to every config in ``configs.registry.ARCHS``;
    ``phases`` defaults to each model's ``zoo_phases_for`` set.  Returns a
    :class:`ZooReport`; ``benchmarks/model_zoo.py`` wraps this with a
    wall-clock budget and writes ``BENCH_model_zoo.json``.

    ``long_traces`` switches every cell to the full-depth/multi-step
    trace (:func:`trace_long_phase`: the reduced step unrolled by the
    full/reduced layer ratio, or ``decode_steps`` token steps) — ~100x
    more op instances, affordable under a CI budget only with
    ``sampling`` (a :class:`~.sample.SamplingConfig`; DESIGN.md §18).
    ``sampling`` also works on the reduced traces alone.  A non-positive
    ``sampling.interval_ops`` means *auto*: one interval per traced step
    (the unrolled copies land on interval boundaries, so identical steps
    collapse into one cluster).
    """
    t0 = time.perf_counter()
    names = list(models) if models is not None else sorted(ARCHS)
    topo = topology or hw.topology
    knobs = zoo_o3_knobs(hw) if with_o3_grid else None
    report = ZooReport(
        hw=hw.name, topology=(topo.name if topo else "degenerate"),
        partition=partition, compute_dtype=compute_dtype,
        clock_hz=clock_hz, core_counts=tuple(core_counts),
        phases=tuple(phases) if phases is not None
        else tuple(ZOO_SHAPES))
    for arch in names:
        cfg = zoo_config(arch)
        arch_phases = (tuple(phases) if phases is not None
                       else zoo_phases_for(cfg))
        report.estimates[arch] = {}
        for phase in arch_phases:
            tp0 = time.perf_counter()
            repeats = 1
            if long_traces:
                prog, repeats = trace_long_phase(
                    arch, phase, param_dtype=param_dtype,
                    hlo_cache_dir=hlo_cache_dir,
                    decode_steps=decode_steps)
            else:
                prog = trace_phase(arch, phase, param_dtype=param_dtype,
                                   hlo_cache_dir=hlo_cache_dir)
            cell_sampling = sampling
            if sampling is not None and sampling.interval_ops <= 0:
                step_inst = sum(o.count for o in prog.ops) / repeats
                cell_sampling = dataclasses.replace(
                    sampling, interval_ops=max(step_inst, 1.0))
            pe = estimate_program(
                prog, hw, core_counts, topo, partition, compute_dtype,
                model_flops=phase_model_flops(cfg, ZOO_SHAPES[phase]),
                o3_knobs=knobs, arch=arch, phase=phase,
                sampling=cell_sampling)
            pe.trace_repeats = repeats
            report.estimates[arch][phase] = pe
            if progress is not None:
                progress(arch, phase, pe, time.perf_counter() - tp0)
    report.wall_s = time.perf_counter() - t0
    return report


# --------------------------------------------------------- cluster driver
def cluster_workload(arch: str, phase: str = "train",
                     shape: Optional[ShapeConfig] = None,
                     param_dtype: str = "float32",
                     hlo_cache_dir: Optional[Path] = None,
                     decode_steps: int = 64) -> ClusterWorkload:
    """Build one model's :class:`~.cluster.ClusterWorkload` from the zoo
    trace: the reduced one-step program plus the shape facts the cluster
    engine sizes collective payloads with (DESIGN.md §20).

    Units are the zoo's reduced-trace units throughout — ``d_model``,
    ``param_bytes`` and the activation payloads all come from the
    reduced config, matching the traced compute so the collective/
    compute *ratio* is structure-true even though absolute bytes are
    toy-width.  ``frac_attn`` (the attention share of per-layer work,
    which decides how much compute a tensor shard removes) comes from
    the FULL config's per-layer parameter split — that ratio is what the
    reduced form does NOT preserve.
    """
    full = ARCHS[arch]
    rcfg = zoo_config(arch)
    shape = shape or ZOO_SHAPES[phase]
    prog = trace_phase(arch, phase, shape, param_dtype, hlo_cache_dir)
    repeats = long_trace_repeats(arch, phase, decode_steps)
    d, hd = full.d_model, full.head_dim
    attn = d * full.n_heads * hd + 2 * d * full.n_kv_heads * hd \
        + full.n_heads * hd * d
    glu = 3 if full.mlp_kind in ("swiglu", "geglu") else 2
    active_k = full.moe.top_k if full.moe is not None else 1
    ffn = glu * d * full.d_ff * max(active_k, 1)
    frac_attn = attn / (attn + ffn) if full.n_heads else 0.0
    return ClusterWorkload(
        name=arch, prog=prog, repeats=repeats, layers=rcfg.n_layers,
        d_model=rcfg.d_model, seq_len=shape.seq_len,
        batch=shape.global_batch,
        # full traced depth in reduced-width units (the grad-sync payload)
        param_bytes=float(rcfg.param_count()) * 4.0 * repeats,
        frac_attn=frac_attn,
        moe_top_k=full.moe.top_k if full.moe is not None else 0)


def mesh_rules_resolver(arch: str):
    """Shard-axis resolution for the cluster engine, delegated to the
    REAL sharding table: a logical (data=1, model=tp) mesh duck-type
    through ``parallel.sharding.MeshRules.param_spec`` on the FULL
    config's parameter shapes — so the cluster engine inherits the
    MeshRules divisibility fallback verbatim (grok's 8 experts ride
    expert parallelism at tp<=8 but fall back to expert-TP via 'mlp' at
    tp=16, exactly as the dry-run shards it).  Lazy-imports jax's
    sharding types; the cluster engine itself stays jax-free.
    """
    cfg = ARCHS[arch]

    def resolve(tp: int) -> ShardDecision:
        if tp <= 1:
            return ShardDecision(attn=False, mlp=False, experts=False)
        from ..parallel.sharding import MeshRules

        class _Devices:
            shape = (1, tp)

        class _Mesh:
            axis_names = ("data", "model")
            devices = _Devices()

        rules = MeshRules(mesh=_Mesh())

        def on_model(entry) -> bool:
            if entry is None:
                return False
            if isinstance(entry, tuple):
                return "model" in entry
            return entry == "model"

        d, hd = cfg.d_model, cfg.head_dim
        wq = rules.param_spec(("embed", "heads", "head_dim"),
                              (d, cfg.n_heads, hd))
        attn = on_model(wq[1]) or on_model(wq[2])
        if cfg.moe is not None:
            we = rules.param_spec(("experts", "embed", "mlp"),
                                  (cfg.moe.n_experts, d, cfg.d_ff))
            experts = on_model(we[0])
            mlp = on_model(we[2])
        else:
            experts = False
            wi = rules.param_spec(("embed", "mlp"), (d, cfg.d_ff))
            mlp = on_model(wi[1])
        return ShardDecision(attn=attn, mlp=mlp, experts=experts)

    return resolve


@dataclass
class ClusterReport:
    """The cluster sweep: every (model, node count, plan) cell + ranks."""
    hw: str
    topology: str                    # node topology name
    cluster: str                     # interconnect family (e.g. tofu_d)
    n_cores: int
    compute_dtype: str
    node_counts: Tuple[int, ...]
    # model -> every swept ClusterResult
    results: Dict[str, List[ClusterResult]] = field(default_factory=dict)
    wall_s: float = 0.0

    def cells(self, model: str, n_nodes: int) -> List[ClusterResult]:
        return [r for r in self.results.get(model, ())
                if r.n_nodes == n_nodes]

    def best(self, model: str, n_nodes: int) -> ClusterResult:
        """The winning plan (min step time) for one (model, node count)."""
        cells = self.cells(model, n_nodes)
        if not cells:
            raise KeyError(f"no cells for {model} at {n_nodes} nodes")
        return min(cells, key=lambda r: r.t_step_s)

    def rank_table(self, n_nodes: int) -> List[str]:
        """Models ranked fastest-first by their best plan's step time."""
        rows = [(self.best(m, n_nodes).t_step_s, m)
                for m in self.results if self.cells(m, n_nodes)]
        return [m for _, m in sorted(rows)]

    def plan_rank_stability(self, model: str) -> Dict[str, float]:
        """Kendall taus of the PLAN ranking between adjacent node counts,
        over the (tp, pp) structures present at both — the cluster
        analogue of the zoo's core-count rank stability: does the
        parallel-efficiency ordering of plans survive scaling?"""
        by_n: Dict[int, Dict[Tuple[int, int], float]] = {}
        for r in self.results.get(model, ()):
            by_n.setdefault(r.n_nodes, {})[(r.plan.tp, r.plan.pp)] = \
                r.t_step_s
        out: Dict[str, float] = {}
        taus = []
        for lo, hi in zip(self.node_counts, self.node_counts[1:]):
            common = sorted(set(by_n.get(lo, {})) & set(by_n.get(hi, {})))
            if len(common) < 2:
                continue
            tau = kendall_tau([by_n[lo][s] for s in common],
                              [by_n[hi][s] for s in common])
            out[f"{lo}->{hi}"] = tau
            taus.append(tau)
        out["min"] = min(taus) if taus else 1.0
        return out

    def to_dict(self) -> dict:
        """The ``BENCH_cluster.json`` payload (schema: DESIGN.md §16)."""
        models: Dict[str, dict] = {}
        for name, rows in self.results.items():
            plans: Dict[str, dict] = {}
            scaling: Dict[str, dict] = {}
            best_plan: Dict[str, str] = {}
            for r in rows:
                n = str(r.n_nodes)
                plans.setdefault(n, {})[r.plan.label] = {
                    "t_step_us": r.t_step_s * 1e6,
                    "t_sched_us": r.t_sched_s * 1e6,
                    "t_floor_us": r.t_floor_s * 1e6,
                    "parallel_efficiency": r.parallel_efficiency,
                    "tokens_per_s": r.tokens_per_s,
                    "mesh_shape": list(r.mesh_shape),
                    "microbatches": r.plan.microbatches,
                    "ici_n_active": r.ici_n_active,
                    "iterations": r.iterations,
                    "hops": r.hops,
                    "comm_s_by_kind": r.comm_s_by_kind,
                    "decision": dataclasses.asdict(r.decision)
                    if r.decision is not None else None,
                }
            for n_nodes in self.node_counts:
                if not self.cells(name, n_nodes):
                    continue
                b = self.best(name, n_nodes)
                best_plan[str(n_nodes)] = b.plan.label
                scaling[str(n_nodes)] = {
                    "plan": b.plan.label,
                    "t_step_us": b.t_step_s * 1e6,
                    "parallel_efficiency": b.parallel_efficiency,
                    "tokens_per_s": b.tokens_per_s,
                }
            models[name] = {"plans": plans, "best_plan": best_plan,
                            "scaling": scaling}
        return {
            "schema": 1,
            "hw": self.hw,
            "topology": self.topology,
            "cluster": self.cluster,
            "n_cores": self.n_cores,
            "compute_dtype": self.compute_dtype,
            "node_counts": list(self.node_counts),
            "models": models,
            "rank": {str(n): self.rank_table(n)
                     for n in self.node_counts
                     if any(self.cells(m, n) for m in self.results)},
            "kendall_tau": {m: self.plan_rank_stability(m)
                            for m in self.results},
            "wall_s": self.wall_s,
        }


def run_cluster(models: Sequence[str] = DEFAULT_CLUSTER_MODELS,
                node_counts: Sequence[int] = DEFAULT_NODE_COUNTS,
                hw: HardwareSpec = A64FX_CORE,
                n_cores: int = 48,
                topology: Optional[NodeTopology] = None,
                compute_dtype: str = "f32",
                param_dtype: str = "float32",
                phase: str = "train",
                hlo_cache_dir: Optional[Path] = None,
                microbatches: int = 8,
                max_tp: int = 16, max_pp: int = 16,
                cluster_factory=ClusterTopology.tofu_d,
                progress=None) -> ClusterReport:
    """Trace + sweep + rank the cluster scaling study end to end
    (DESIGN.md §20): each model's train step through
    :func:`~.cluster.cluster_sweep` over the node-count axis, shard
    axes resolved by the real MeshRules table.  Returns a
    :class:`ClusterReport`; ``benchmarks/cluster_scaling.py`` wraps
    this with a wall-clock budget and writes ``BENCH_cluster.json``.
    """
    t0 = time.perf_counter()
    topo = topology or hw.topology
    report = ClusterReport(
        hw=hw.name, topology=(topo.name if topo else "degenerate"),
        cluster=cluster_factory(max(node_counts)).name.rsplit("_", 1)[0],
        n_cores=n_cores, compute_dtype=compute_dtype,
        node_counts=tuple(node_counts))
    for m in models:
        if m not in ARCHS:
            raise ValueError(f"unknown arch {m!r}; known: {sorted(ARCHS)}")
        w = cluster_workload(m, phase, param_dtype=param_dtype,
                             hlo_cache_dir=hlo_cache_dir)
        report.results[m] = cluster_sweep(
            w, node_counts, hw=hw, n_cores=n_cores, topology=topo,
            compute_dtype=compute_dtype,
            resolver=mesh_rules_resolver(m), microbatches=microbatches,
            max_tp=max_tp, max_pp=max_pp,
            cluster_factory=cluster_factory,
            progress=(lambda msg: progress(m, msg)) if progress else None)
    report.wall_s = time.perf_counter() - t0
    return report
