"""Multi-level memory-hierarchy model — the paper's "function expansion".

The RIKEN simulator's accuracy came from expanding gem5's memory system
into the A64FX's real hierarchy (L1D with asymmetric load/store ports —
>230 vs >115 GB/s per core — an 8 MiB L2, HBM2) and then tuning each
level's parameters against the test chip.  This module is that expansion
at HLO altitude:

* a ``HardwareSpec`` carries an ordered hierarchy of ``MemLevel``s
  (innermost/fastest first: L1/VMEM -> L2 -> HBM), each with its own
  capacity, asymmetric read/write bandwidth, and access latency;
* per-op traffic is *routed* to a level by a reuse-distance/working-set
  residency model driven by the def-use edges the parser records:

  - **dep reads** (operand has a known producer): the reuse distance is
    the bytes written to the hierarchy between producer and consumer
    (prefix sums of per-instance write bytes).  The operand is charged at
    the innermost level whose capacity covers that distance — data
    produced "recently enough" is still level-resident.
  - **cold reads** (parameters, constants) and **writes**: on machines
    with hardware-managed caches (``warm_caches=True``: the A64FX, the
    CPU host) they are charged at the innermost level that holds the
    op's whole working set (read + write bytes) — the steady-state
    warm-cache rule.  On scratch-memory machines (TPU VMEM is software-
    managed; weights genuinely stream from HBM every step) they are
    charged at the outermost level, and only def-use reuse earns
    inner-level bandwidth.

* reads and writes are split (``OpStat.read_bytes`` / ``write_bytes``),
  so the asymmetric load/store paths finally matter: a store-heavy op on
  ``A64FX_CORE`` is slower than its load-heavy mirror, and halving
  ``hbm_write_bw`` slows store-bound programs.

The router is pure python over already-parsed programs; it knows nothing
about engines.  ``core.cost`` turns routed traffic into per-op times that
both the occupancy and the schedule engine consume.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .hlo import OpStat, Program


@dataclass(frozen=True)
class MemLevel:
    """One level of the hierarchy (the gem5 cache/memobj parameter file).

    ``read_bw``/``write_bw`` are the *per-core* paths (what one core can
    draw through the level alone).  ``shared_by`` is the size of the
    sharing domain in a node (1 = core-private, 12 = one A64FX CMG's L2/
    HBM2): the node engine (``core.node``) divides the domain's aggregate
    bandwidth — carried by ``NodeTopology`` — among the cores actively
    streaming through the level.  Single-core engines ignore it.
    """
    name: str
    capacity: float              # bytes held at this level
    read_bw: float               # bytes/s toward the core (load path)
    write_bw: float              # bytes/s away from the core (store path)
    latency_s: float = 0.0       # access latency, charged once per op
                                 # at the deepest level the op touches
    shared_by: int = 1           # cores sharing this level in a node


@dataclass
class MemTraffic:
    """Per-op routed traffic: bytes and time per hierarchy level.

    Bytes are per *instance* (not multiplied by ``OpStat.count``) and
    already dtype-normalized (DESIGN.md §7), matching the other per-op
    time components.
    """
    read_by_level: Dict[str, float] = field(default_factory=dict)
    write_by_level: Dict[str, float] = field(default_factory=dict)
    t_read: float = 0.0
    t_write: float = 0.0
    latency_s: float = 0.0

    @property
    def t_mem(self) -> float:
        return self.t_read + self.t_write + self.latency_s


def _dtype_scale(op: OpStat, compute_dtype: Optional[str]) -> float:
    """Inverted XLA:CPU float-normalization (DESIGN.md §7): f32 traffic is
    costed at 16-bit width when the model computes in bf16/f16."""
    if compute_dtype in ("bf16", "f16") and op.dtype == "f32":
        return 0.5
    return 1.0


def _split_rw(op: OpStat, scale: float) -> Tuple[float, float]:
    """Effective (read, write) bytes.  Synthetic OpStats built with only
    ``bytes_accessed`` (tests, sweeps) are treated as pure reads, which
    reproduces the old scalar model exactly."""
    if op.read_bytes or op.write_bytes:
        return op.read_bytes * scale, op.write_bytes * scale
    return op.bytes_accessed * scale, 0.0


def residency_level(levels: Sequence[MemLevel], nbytes: float) -> MemLevel:
    """Innermost level whose capacity covers ``nbytes`` (outermost level
    backstops everything — there is nowhere further to miss to)."""
    for lv in levels:
        if nbytes <= lv.capacity:
            return lv
    return levels[-1]


def stream_time(levels: Sequence[MemLevel], nbytes: float,
                write: bool = False) -> float:
    """Time to stream a ``nbytes`` working set through the hierarchy at
    its residency level's bandwidth: the level is picked by
    :func:`residency_level` (innermost fit, outermost backstop), so a
    working set that spills a level pays the next level's bandwidth.

    This is the serving simulator's KV-cache cost hook (``core.serving``,
    DESIGN.md §21): a decode batch whose cache working set no longer fits
    L2 streams from HBM2, and one that outgrows HBM2 still streams at the
    outermost level's bandwidth (there is nowhere further to miss to).
    """
    if nbytes <= 0:
        return 0.0
    lv = residency_level(levels, nbytes)
    bw = lv.write_bw if write else lv.read_bw
    return nbytes / bw


def route_standalone(op: OpStat, levels: Sequence[MemLevel],
                     compute_dtype: Optional[str] = None,
                     warm_caches: bool = False) -> MemTraffic:
    """Route one op with no program context: no producer information, so
    everything takes the cold-read/write rule (working set if the caches
    are hardware-managed and warm, outermost level otherwise)."""
    scale = _dtype_scale(op, compute_dtype)
    rb, wb = _split_rw(op, scale)
    lv = (residency_level(levels, rb + wb) if warm_caches else levels[-1])
    tr = MemTraffic()
    _charge(tr, lv, rb, wb)
    tr.latency_s = lv.latency_s
    return tr


def _charge(tr: MemTraffic, lv: MemLevel, rb: float, wb: float) -> None:
    if rb > 0:
        tr.read_by_level[lv.name] = tr.read_by_level.get(lv.name, 0.0) + rb
        tr.t_read += rb / lv.read_bw
    if wb > 0:
        tr.write_by_level[lv.name] = tr.write_by_level.get(lv.name, 0.0) + wb
        tr.t_write += wb / lv.write_bw


def _route_edges(prog: Program, compute_dtype: Optional[str]):
    """Spec-independent routing inputs, computed once per program: the
    effective (read, write) bytes per op, the budget-clamped CSR def-use
    edge shares, and each edge's reuse distance.  None of these depend on
    level capacities or bandwidths, so the spec-batched router
    (:func:`route_program_batch`) shares them across the whole grid.
    Returns ``(rb, wb, dst, e_eff, dist)``."""
    n = len(prog.ops)
    scales = [_dtype_scale(o, compute_dtype) for o in prog.ops]
    rws = [_split_rw(o, scales[i]) for i, o in enumerate(prog.ops)]
    rb = np.array([r for r, _ in rws], dtype=np.float64)
    wb = np.array([w for _, w in rws], dtype=np.float64)
    # foot[i] = effective bytes written by ops 0..i-1
    foot = np.zeros(n + 1, dtype=np.float64)
    np.cumsum(wb, out=foot[1:])

    # CSR def-use edge list (consumer-major, edges in OpStat.deps order)
    srcs: List[int] = []
    dsts: List[int] = []
    ebts: List[float] = []
    indptr = np.zeros(n + 1, dtype=np.intp)
    for i, o in enumerate(prog.ops):
        sc = scales[i]
        for j, b in zip(o.deps, o.dep_bytes):
            if 0 <= j < i and b > 0:
                srcs.append(j)
                dsts.append(i)
                ebts.append(b * sc)
        indptr[i + 1] = len(srcs)
    src = np.array(srcs, dtype=np.intp)
    dst = np.array(dsts, dtype=np.intp)
    eb = np.array(ebts, dtype=np.float64)

    # dep reads by reuse distance; shares clamped to the read budget
    # (slice/DUS refinements can make boundary reads smaller than the
    # nominal operand sizes the edges carry)
    total_share = np.bincount(dst, weights=eb,
                              minlength=n).astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        shrink = np.where((total_share > rb) & (rb > 0),
                          rb / np.where(total_share > 0, total_share, 1.0),
                          1.0)
    e_shr = eb * shrink[dst]
    # sequential budget clamp, prefix-sum form: edge k of op i gets
    # min(share_k, budget_i - sum of earlier shares of i)
    cs = np.concatenate(([0.0], np.cumsum(e_shr)))
    prev_within = cs[:-1] - cs[indptr[dst]]
    e_eff = np.clip(np.minimum(e_shr, rb[dst] - prev_within), 0.0, None)
    e_eff[rb[dst] <= 0] = 0.0

    dist = foot[dst] - foot[src]
    return rb, wb, dst, e_eff, dist


def route_program(prog: Program, levels: Sequence[MemLevel],
                  compute_dtype: Optional[str] = None,
                  warm_caches: bool = False) -> List[MemTraffic]:
    """Route every op's traffic through the hierarchy.

    Reuse distances are computed on the per-iteration op sequence: prefix
    sums of per-instance write bytes, so an edge from op *j* to op *i* sees
    the footprint written by ops *j..i-1* (including *j*'s own output —
    an operand larger than a level can never be resident there).  Edges
    that cross a collapsed loop body (count > 1) use the single-iteration
    footprint, a deliberate under-estimate recorded in DESIGN.md §12.

    Vectorized (DESIGN.md §13): one array pass over the CSR def-use edge
    list instead of a per-op/per-edge Python loop — the residency lookup
    becomes a ``searchsorted`` on the (cumulative-max) level capacities,
    the read-budget clamp a prefix-sum formulation, the per-level byte
    tallies ``np.add.at`` scatters.
    """
    if not levels:
        raise ValueError("empty memory hierarchy")
    n = len(prog.ops)
    if n == 0:
        return []
    L = len(levels)
    # residency_level scans innermost-out and takes the first fit, so a
    # (pathological) smaller-capacity outer level can never win: the
    # running max reproduces first-fit exactly under searchsorted
    caps = np.maximum.accumulate(
        np.array([lv.capacity for lv in levels], dtype=np.float64))
    read_bw = np.array([lv.read_bw for lv in levels], dtype=np.float64)
    write_bw = np.array([lv.write_bw for lv in levels], dtype=np.float64)
    lat = np.array([lv.latency_s for lv in levels], dtype=np.float64)

    rb, wb, dst, e_eff, dist = _route_edges(prog, compute_dtype)

    # cold-traffic level: warm working-set rule on cache machines,
    # outermost (HBM/DRAM) on scratch-memory machines
    if warm_caches:
        cold = np.minimum(np.searchsorted(caps, rb + wb, side="left"), L - 1)
    else:
        cold = np.full(n, L - 1, dtype=np.intp)

    elvl = np.minimum(np.searchsorted(caps, dist, side="left"), L - 1)

    dep_read = np.bincount(dst, weights=e_eff,
                           minlength=n).astype(np.float64)
    t_read = np.bincount(dst, weights=e_eff / read_bw[elvl],
                         minlength=n).astype(np.float64)
    leftover = np.clip(rb - dep_read, 0.0, None)
    has_cold_read = leftover > 0
    t_read += np.where(has_cold_read, leftover / read_bw[cold], 0.0)
    t_write = np.where(wb > 0, wb / write_bw[cold], 0.0)

    # deepest level touched (latency is charged there once per op)
    deepest = np.where(wb > 0, cold, 0)
    live = e_eff > 0
    np.maximum.at(deepest, dst[live], elvl[live])
    deepest = np.where(has_cold_read, np.maximum(deepest, cold), deepest)
    latency = lat[deepest]

    # per-(op, level) byte tallies for the PA hierarchy section
    rbl = np.zeros((n, L), dtype=np.float64)
    np.add.at(rbl, (dst[live], elvl[live]), e_eff[live])
    rbl[has_cold_read, cold[has_cold_read]] += leftover[has_cold_read]

    names = [lv.name for lv in levels]
    out: List[MemTraffic] = []
    for i in range(n):
        tr = MemTraffic(t_read=float(t_read[i]), t_write=float(t_write[i]),
                        latency_s=float(latency[i]))
        row = rbl[i]
        for k in range(L):
            if row[k] > 0:
                tr.read_by_level[names[k]] = float(row[k])
        if wb[i] > 0:
            tr.write_by_level[names[cold[i]]] = float(wb[i])
        out.append(tr)
    return out


# ------------------------------------------------- spec-batched routing
@dataclass
class BatchTraffic:
    """Spec-batched routed traffic: ``[n_ops, S]`` times and
    ``[n_ops, L, S]`` per-level bytes over a grid of S hierarchies
    (DESIGN.md §19).  Column ``s`` is bit-identical to
    :func:`route_program` under hierarchy ``s`` (the differential suite
    pins it); bytes are per instance and dtype-normalized, like
    :class:`MemTraffic`.
    """
    level_names: Tuple[str, ...]
    t_read: np.ndarray           # [n, S]
    t_write: np.ndarray          # [n, S]
    latency: np.ndarray          # [n, S]
    read_by_level: np.ndarray    # [n, L, S]
    write_by_level: np.ndarray   # [n, L, S]

    @property
    def t_mem(self) -> np.ndarray:
        """[n, S]; same add order as :meth:`MemTraffic.t_mem`."""
        return self.t_read + self.t_write + self.latency


def route_program_batch(prog: Program,
                        levels_per_spec: Sequence[Sequence[MemLevel]],
                        compute_dtype: Optional[str] = None,
                        warm_caches: bool = False) -> BatchTraffic:
    """Route every op through S hierarchies at once (the spec batch axis).

    The spec-independent inputs — effective read/write bytes, the
    budget-clamped def-use edge shares, reuse distances — are computed
    once (:func:`_route_edges`); only the residency lookups, bandwidth
    divisions and per-level tallies grow a trailing S axis.  The
    ``searchsorted``-over-cummax residency trick becomes a broadcast
    ``(caps < v).sum()`` count (identical for sorted capacities), and the
    per-``dst`` time accumulations use ``np.add.at``, which adds in edge
    order exactly like the scalar path's ``np.bincount`` — so every
    column is bit-identical to a :func:`route_program` call with that
    spec's levels.  All hierarchies must share depth and level names
    (structural uniformity; numeric parameters are free to vary).
    """
    if not levels_per_spec:
        raise ValueError("empty spec grid")
    names = tuple(lv.name for lv in levels_per_spec[0])
    L = len(names)
    if L == 0:
        raise ValueError("empty memory hierarchy")
    for levels in levels_per_spec:
        if tuple(lv.name for lv in levels) != names:
            raise ValueError(
                "spec grid hierarchies must share level structure: "
                f"{tuple(lv.name for lv in levels)} != {names}")
    S = len(levels_per_spec)
    n = len(prog.ops)
    if n == 0:
        z2 = np.zeros((0, S))
        return BatchTraffic(names, z2, z2.copy(), z2.copy(),
                            np.zeros((0, L, S)), np.zeros((0, L, S)))
    # [S, L] level parameter matrices (capacities cummax'd per spec row)
    caps = np.maximum.accumulate(np.array(
        [[lv.capacity for lv in levels] for levels in levels_per_spec],
        dtype=np.float64), axis=1)
    read_bw = np.array([[lv.read_bw for lv in levels]
                        for levels in levels_per_spec], dtype=np.float64)
    write_bw = np.array([[lv.write_bw for lv in levels]
                         for levels in levels_per_spec], dtype=np.float64)
    lat = np.array([[lv.latency_s for lv in levels]
                    for levels in levels_per_spec], dtype=np.float64)
    s_idx = np.arange(S)[None, :]

    rb, wb, dst, e_eff, dist = _route_edges(prog, compute_dtype)
    E = len(dst)

    # residency: count of levels whose (cummax) capacity is < v ==
    # searchsorted(caps, v, side="left") per spec column
    if warm_caches:
        cold = np.minimum(
            (caps[None, :, :] < (rb + wb)[:, None, None]).sum(axis=2),
            L - 1)                                   # [n, S]
    else:
        cold = np.full((n, S), L - 1, dtype=np.intp)
    elvl = np.minimum(
        (caps[None, :, :] < dist[:, None, None]).sum(axis=2), L - 1)

    t_read = np.zeros((n, S))
    if E:
        rbw_e = read_bw[s_idx, elvl]                 # [E, S]
        np.add.at(t_read, dst, e_eff[:, None] / rbw_e)
    dep_read = np.bincount(dst, weights=e_eff,
                           minlength=n).astype(np.float64)
    leftover = np.clip(rb - dep_read, 0.0, None)
    has_cold_read = leftover > 0
    t_read += np.where(has_cold_read[:, None],
                       leftover[:, None] / read_bw[s_idx, cold], 0.0)
    t_write = np.where(wb[:, None] > 0,
                       wb[:, None] / write_bw[s_idx, cold], 0.0)

    # deepest level touched (latency charged there once per op)
    deepest = np.where(wb[:, None] > 0, cold, 0)
    live = e_eff > 0
    if live.any():
        np.maximum.at(deepest, dst[live], elvl[live])
    deepest = np.where(has_cold_read[:, None],
                       np.maximum(deepest, cold), deepest)
    latency = lat[s_idx, deepest]

    # per-(op, level, spec) byte tallies (flat-index scatters: the level
    # index varies per spec column, so the scatter target does too)
    rbl = np.zeros((n, L, S))
    flat_s = np.arange(S)[None, :]
    if live.any():
        fl = (dst[live][:, None] * L + elvl[live]) * S + flat_s
        np.add.at(rbl.reshape(-1), fl, e_eff[live][:, None])
    rows = np.nonzero(has_cold_read)[0]
    if len(rows):
        fl = (rows[:, None] * L + cold[rows]) * S + flat_s
        np.add.at(rbl.reshape(-1), fl, leftover[rows][:, None])
    wbl = np.zeros((n, L, S))
    rows = np.nonzero(wb > 0)[0]
    if len(rows):
        fl = (rows[:, None] * L + cold[rows]) * S + flat_s
        np.add.at(wbl.reshape(-1), fl, wb[rows][:, None])

    return BatchTraffic(names, t_read, t_write, latency, rbl, wbl)


def aggregate_traffic(traffic: Sequence[Optional[MemTraffic]],
                      counts: Sequence[float]) -> Dict[str, Dict[str, float]]:
    """Program-level per-level totals (bytes and time, count-multiplied)
    for the PA report's hierarchy section."""
    agg: Dict[str, Dict[str, float]] = {}
    for tr, c in zip(traffic, counts):
        if tr is None:
            continue
        for kind in ("read", "write"):
            by = tr.read_by_level if kind == "read" else tr.write_by_level
            for name, b in by.items():
                a = agg.setdefault(name, {"read_bytes": 0.0,
                                          "write_bytes": 0.0})
                a[f"{kind}_bytes"] += b * c
    return agg
