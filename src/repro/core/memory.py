"""Multi-level memory-hierarchy model — the paper's "function expansion".

The RIKEN simulator's accuracy came from expanding gem5's memory system
into the A64FX's real hierarchy (L1D with asymmetric load/store ports —
>230 vs >115 GB/s per core — an 8 MiB L2, HBM2) and then tuning each
level's parameters against the test chip.  This module is that expansion
at HLO altitude:

* a ``HardwareSpec`` carries an ordered hierarchy of ``MemLevel``s
  (innermost/fastest first: L1/VMEM -> L2 -> HBM), each with its own
  capacity, asymmetric read/write bandwidth, and access latency;
* per-op traffic is *routed* to a level by a reuse-distance/working-set
  residency model driven by the def-use edges the parser records:

  - **dep reads** (operand has a known producer): the reuse distance is
    the bytes written to the hierarchy between producer and consumer
    (prefix sums of per-instance write bytes).  The operand is charged at
    the innermost level whose capacity covers that distance — data
    produced "recently enough" is still level-resident.
  - **cold reads** (parameters, constants) and **writes**: on machines
    with hardware-managed caches (``warm_caches=True``: the A64FX, the
    CPU host) they are charged at the innermost level that holds the
    op's whole working set (read + write bytes) — the steady-state
    warm-cache rule.  On scratch-memory machines (TPU VMEM is software-
    managed; weights genuinely stream from HBM every step) they are
    charged at the outermost level, and only def-use reuse earns
    inner-level bandwidth.

* reads and writes are split (``OpStat.read_bytes`` / ``write_bytes``),
  so the asymmetric load/store paths finally matter: a store-heavy op on
  ``A64FX_CORE`` is slower than its load-heavy mirror, and halving
  ``hbm_write_bw`` slows store-bound programs.

The router is pure python over already-parsed programs; it knows nothing
about engines.  ``core.cost`` turns routed traffic into per-op times that
both the occupancy and the schedule engine consume.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .hlo import OpStat, Program


@dataclass(frozen=True)
class MemLevel:
    """One level of the hierarchy (the gem5 cache/memobj parameter file)."""
    name: str
    capacity: float              # bytes held at this level
    read_bw: float               # bytes/s toward the core (load path)
    write_bw: float              # bytes/s away from the core (store path)
    latency_s: float = 0.0       # access latency, charged once per op
                                 # at the deepest level the op touches


@dataclass
class MemTraffic:
    """Per-op routed traffic: bytes and time per hierarchy level.

    Bytes are per *instance* (not multiplied by ``OpStat.count``) and
    already dtype-normalized (DESIGN.md §7), matching the other per-op
    time components.
    """
    read_by_level: Dict[str, float] = field(default_factory=dict)
    write_by_level: Dict[str, float] = field(default_factory=dict)
    t_read: float = 0.0
    t_write: float = 0.0
    latency_s: float = 0.0

    @property
    def t_mem(self) -> float:
        return self.t_read + self.t_write + self.latency_s


def _dtype_scale(op: OpStat, compute_dtype: Optional[str]) -> float:
    """Inverted XLA:CPU float-normalization (DESIGN.md §7): f32 traffic is
    costed at 16-bit width when the model computes in bf16/f16."""
    if compute_dtype in ("bf16", "f16") and op.dtype == "f32":
        return 0.5
    return 1.0


def _split_rw(op: OpStat, scale: float) -> Tuple[float, float]:
    """Effective (read, write) bytes.  Synthetic OpStats built with only
    ``bytes_accessed`` (tests, sweeps) are treated as pure reads, which
    reproduces the old scalar model exactly."""
    if op.read_bytes or op.write_bytes:
        return op.read_bytes * scale, op.write_bytes * scale
    return op.bytes_accessed * scale, 0.0


def residency_level(levels: Sequence[MemLevel], nbytes: float) -> MemLevel:
    """Innermost level whose capacity covers ``nbytes`` (outermost level
    backstops everything — there is nowhere further to miss to)."""
    for lv in levels:
        if nbytes <= lv.capacity:
            return lv
    return levels[-1]


def route_standalone(op: OpStat, levels: Sequence[MemLevel],
                     compute_dtype: Optional[str] = None,
                     warm_caches: bool = False) -> MemTraffic:
    """Route one op with no program context: no producer information, so
    everything takes the cold-read/write rule (working set if the caches
    are hardware-managed and warm, outermost level otherwise)."""
    scale = _dtype_scale(op, compute_dtype)
    rb, wb = _split_rw(op, scale)
    lv = (residency_level(levels, rb + wb) if warm_caches else levels[-1])
    tr = MemTraffic()
    _charge(tr, lv, rb, wb)
    tr.latency_s = lv.latency_s
    return tr


def _charge(tr: MemTraffic, lv: MemLevel, rb: float, wb: float) -> None:
    if rb > 0:
        tr.read_by_level[lv.name] = tr.read_by_level.get(lv.name, 0.0) + rb
        tr.t_read += rb / lv.read_bw
    if wb > 0:
        tr.write_by_level[lv.name] = tr.write_by_level.get(lv.name, 0.0) + wb
        tr.t_write += wb / lv.write_bw


def route_program(prog: Program, levels: Sequence[MemLevel],
                  compute_dtype: Optional[str] = None,
                  warm_caches: bool = False) -> List[MemTraffic]:
    """Route every op's traffic through the hierarchy.

    Reuse distances are computed on the per-iteration op sequence: prefix
    sums of per-instance write bytes, so an edge from op *j* to op *i* sees
    the footprint written by ops *j..i-1* (including *j*'s own output —
    an operand larger than a level can never be resident there).  Edges
    that cross a collapsed loop body (count > 1) use the single-iteration
    footprint, a deliberate under-estimate recorded in DESIGN.md §12.
    """
    if not levels:
        raise ValueError("empty memory hierarchy")
    n = len(prog.ops)
    scales = [_dtype_scale(o, compute_dtype) for o in prog.ops]
    # foot[i] = effective bytes written by ops 0..i-1
    foot = [0.0] * (n + 1)
    rws = []
    for i, o in enumerate(prog.ops):
        rb, wb = _split_rw(o, scales[i])
        rws.append((rb, wb))
        foot[i + 1] = foot[i] + wb

    out: List[MemTraffic] = []
    for i, o in enumerate(prog.ops):
        rb, wb = rws[i]
        tr = MemTraffic()
        # cold-traffic level: warm working-set rule on cache machines,
        # outermost (HBM/DRAM) on scratch-memory machines
        cold_level = (residency_level(levels, rb + wb) if warm_caches
                      else levels[-1])
        _charge(tr, cold_level, 0.0, wb)
        deepest = cold_level if wb > 0 else levels[0]

        # dep reads by reuse distance; shares clamped to the read budget
        # (slice/DUS refinements can make boundary reads smaller than the
        # nominal operand sizes the edges carry)
        budget = rb
        shares = [(j, b * scales[i]) for j, b in zip(o.deps, o.dep_bytes)
                  if 0 <= j < i and b > 0]
        total_share = sum(b for _, b in shares)
        shrink = (budget / total_share) if total_share > budget > 0 else 1.0
        if budget > 0:
            for j, b in shares:
                b = min(b * shrink, budget)
                if b <= 0:
                    continue
                dist = foot[i] - foot[j]
                lv = residency_level(levels, dist)
                _charge(tr, lv, b, 0.0)
                budget -= b
                if _depth(levels, lv) > _depth(levels, deepest):
                    deepest = lv
        # cold reads (parameters/constants)
        if budget > 0:
            _charge(tr, cold_level, budget, 0.0)
            if _depth(levels, cold_level) > _depth(levels, deepest):
                deepest = cold_level
        tr.latency_s = deepest.latency_s
        out.append(tr)
    return out


def _depth(levels: Sequence[MemLevel], lv: MemLevel) -> int:
    for i, cand in enumerate(levels):
        if cand.name == lv.name:
            return i
    return len(levels)


def aggregate_traffic(traffic: Sequence[Optional[MemTraffic]],
                      counts: Sequence[float]) -> Dict[str, Dict[str, float]]:
    """Program-level per-level totals (bytes and time, count-multiplied)
    for the PA report's hierarchy section."""
    agg: Dict[str, Dict[str, float]] = {}
    for tr, c in zip(traffic, counts):
        if tr is None:
            continue
        for kind in ("read", "write"):
            by = tr.read_by_level if kind == "read" else tr.write_by_level
            for name, b in by.items():
                a = agg.setdefault(name, {"read_bytes": 0.0,
                                          "write_bytes": 0.0})
                a[f"{kind}_bytes"] += b * c
    return agg
