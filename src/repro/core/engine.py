"""Multi-port occupancy engine — the O3-pipeline analogue at HLO altitude.

gem5 models instruction issue into reservation stations; at HLO altitude the
equivalent resources are *ports*: MXU (matrix), VPU (vector), DMA (HBM), ICI
(interconnect).  Every op contributes occupancy to its port; the overlap
model (paper: OoO execution hiding memory latency; here: XLA async DMA /
async collectives) combines port totals into an execution-time estimate:

    compute      = t_mxu + t_vpu
    mem_exposed  = max(0, t_mem - dma_overlap * compute)
    ici_exposed  = max(0, t_ici - ici_overlap * compute)
    t_est        = compute + mem_exposed + ici_exposed + startup
    t_roofline   = max(t_mxu + t_vpu, t_mem, t_ici)      (perfect overlap)

Collective times use ring-algorithm factors on ``group_size`` with a
bidirectional ring (2 links) per collective.
"""
from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .hlo import OpStat, Program
from .hwspec import HardwareSpec


@dataclass
class OpTime:
    op: OpStat
    t_compute: float
    t_mem: float
    t_ici: float
    port: str
    useful_flops: float = 0.0     # matmul lane accounting (MXU utilization)
    padded_flops: float = 0.0

    @property
    def t_op(self) -> float:
        return max(self.t_compute, self.t_mem, self.t_ici)


@dataclass
class EngineResult:
    port_busy: Dict[str, float]
    t_est: float
    t_roofline: float
    t_serial: float
    n_ops: float
    startup: float
    mxu_utilization: float
    by_class_time: Dict[str, float]
    top_ops: List[OpTime]
    collective_time_by_kind: Dict[str, float]

    @property
    def bound_by(self) -> str:
        return max(self.port_busy, key=lambda k: self.port_busy[k])


# ring-algorithm bandwidth factors: time = factor(g) * payload / bw
def collective_factor(kind: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g
    if kind == "all-gather":
        return float(g - 1)          # payload = shard bytes
    if kind == "reduce-scatter":
        return (g - 1) / g           # payload = full buffer
    if kind == "all-to-all":
        return (g - 1) / g
    if kind == "collective-permute":
        return 1.0
    return 1.0


def cost_op(o: OpStat, hw: HardwareSpec, ici_bw: float,
            compute_dtype: Optional[str] = None) -> Optional[OpTime]:
    """Per-op port assignment + per-instance times — shared by the flat
    occupancy engine below and by ``core.schedule``'s dependency-aware
    engine.  Returns None for ops the cost model does not charge."""
    denorm = compute_dtype in ("bf16", "f16")

    def eff_dtype() -> str:
        if denorm and o.dtype == "f32":
            return compute_dtype
        return o.dtype

    def eff_bytes() -> float:
        if denorm and o.dtype == "f32":
            return 0.5 * o.bytes_accessed
        return o.bytes_accessed

    def mem_bw(nbytes: float) -> float:
        if hw.cache_model and nbytes <= hw.vmem_bytes:
            return hw.vmem_bw
        return hw.hbm_read_bw

    def trans_time() -> float:
        """Per-opcode latency table (paper's OpClass extension)."""
        if not o.trans_by_opcode:
            return o.transcendentals * hw.transcendental_factor
        return sum(v * hw.opcode_factor.get(k, hw.transcendental_factor)
                   for k, v in o.trans_by_opcode.items())

    t_c = t_m = t_i = 0.0
    useful = padded_f = 0.0
    port = "vpu"
    if o.opclass == "matmul":
        port = "mxu"
        util = 1.0
        if o.dot_dims:
            m, n, k = o.dot_dims
            if min(m, n, k) < hw.min_matmul_dim_for_mxu:
                # tiny contraction/row dims: XLA emits a VPU multiply-
                # reduce, NOT an MXU matmul — no 128-tile quantization
                # (8-lane sublane padding only).
                port = "vpu"
                util = m * n * k / (max(m, 8 * math.ceil(m / 8), 1)
                                    * n * k) if m else 1.0
            else:
                tm, tk, tn = hw.mxu_tile
                pm = math.ceil(m / tm) * tm
                pk = math.ceil(k / tk) * tk
                pn = math.ceil(n / tn) * tn
                util = (m * n * k) / max(pm * pn * pk, 1)
        padded = o.flops / max(util, 1e-9)
        useful = o.flops * o.count
        padded_f = padded * o.count
        peak = (hw.matmul_flops(eff_dtype()) if port == "mxu"
                else hw.vector_flops(eff_dtype()))
        t_c = padded / peak
        t_m = eff_bytes() / mem_bw(eff_bytes())
    elif o.opclass in ("elementwise", "reduce"):
        base = o.flops - o.transcendentals
        t_c = (base + trans_time()) / hw.vector_flops(eff_dtype())
        t_m = eff_bytes() / mem_bw(eff_bytes())
    elif o.opclass == "transcendental":
        t_c = trans_time() / hw.vector_flops(eff_dtype())
        t_m = eff_bytes() / mem_bw(eff_bytes())
    elif o.opclass == "data":
        t_m = eff_bytes() / mem_bw(eff_bytes())
        port = "mem"
    elif o.opclass == "collective":
        f = collective_factor(o.opcode, o.group_size)
        payload = (0.5 * o.comm_bytes
                   if denorm and o.dtype == "f32" else o.comm_bytes)
        t_i = f * payload / ici_bw + hw.collective_startup_us * 1e-6
        port = "ici"
    else:
        return None

    # OpClass throughput overrides (the paper's operand-type table)
    t_c *= hw.opclass_throughput.get(o.opclass, 1.0)
    return OpTime(o, t_c, t_m, t_i, port,
                  useful_flops=useful, padded_flops=padded_f)


def simulate_program(prog: Program, hw: HardwareSpec,
                     links_per_collective: int = 2,
                     compute_dtype: Optional[str] = None) -> EngineResult:
    """``compute_dtype``: the model's intended compute dtype.  When set to a
    16-bit type, f32 ops are costed as that type (flops AND bytes AND
    collective payloads).  This inverts XLA:CPU's float-normalization pass
    (the host has no native bf16, so the partitioned module we parse holds
    f32-promoted dots/buffers that the TPU target executes natively in
    bf16) — the paper's operand-type-dependent OpClass table, applied in
    reverse.  f32-by-design state (optimizer moments, the loss) is also
    halved; it is step-frequency (not layer x microbatch frequency) traffic,
    so the error is bounded and documented in DESIGN.md §7."""
    port_busy: Dict[str, float] = defaultdict(float)
    by_class: Dict[str, float] = defaultdict(float)
    coll_kind: Dict[str, float] = defaultdict(float)
    op_times: List[OpTime] = []
    t_serial = 0.0
    startup = 0.0
    n_ops = 0.0
    useful_f, padded_f = 0.0, 0.0

    ici_bw = links_per_collective * hw.ici_bw_per_link

    for o in prog.ops:
        ot = cost_op(o, hw, ici_bw, compute_dtype)
        if ot is None:
            continue
        t_c, t_m, t_i, port = ot.t_compute, ot.t_mem, ot.t_ici, ot.port
        useful_f += ot.useful_flops
        padded_f += ot.padded_flops
        if o.opclass == "collective":
            coll_kind[o.opcode] += t_i * o.count

        if port in ("mxu", "vpu"):
            port_busy[port] += t_c * o.count
        port_busy["mem"] += t_m * o.count
        port_busy["ici"] += t_i * o.count
        by_class[o.opclass] += max(t_c, t_m, t_i) * o.count
        t_serial += max(t_c, t_m, t_i) * o.count
        startup += hw.op_startup_ns * 1e-9 * o.count
        n_ops += o.count
        op_times.append(ot)

    compute = port_busy["mxu"] + port_busy["vpu"]
    mem_exposed = max(0.0, port_busy["mem"] - hw.dma_overlap * compute)
    ici_exposed = max(0.0, port_busy["ici"] - hw.ici_overlap * compute)
    t_est = compute + mem_exposed + ici_exposed + startup
    t_roofline = max(compute, port_busy["mem"], port_busy["ici"])

    op_times.sort(key=lambda t: -(t.t_op * t.op.count))
    return EngineResult(
        port_busy=dict(port_busy),
        t_est=t_est,
        t_roofline=t_roofline,
        t_serial=t_serial + startup,
        n_ops=n_ops,
        startup=startup,
        mxu_utilization=(useful_f / padded_f) if padded_f else 1.0,
        by_class_time=dict(by_class),
        top_ops=op_times[:20],
        collective_time_by_kind=dict(coll_kind),
    )
