"""Multi-port occupancy engine — the O3-pipeline analogue at HLO altitude.

gem5 models instruction issue into reservation stations; at HLO altitude the
equivalent resources are *ports*: MXU (matrix), VPU (vector), DMA (HBM), ICI
(interconnect).  Every op contributes occupancy to its port; the overlap
model (paper: OoO execution hiding memory latency; here: XLA async DMA /
async collectives) combines port totals into an execution-time estimate:

    compute      = t_mxu + t_vpu
    mem_exposed  = max(0, t_mem - dma_overlap * compute)
    ici_exposed  = max(0, t_ici - ici_overlap * compute)
    t_est        = compute + mem_exposed + ici_exposed + startup
    t_roofline   = max(t_mxu + t_vpu, t_mem, t_ici)      (perfect overlap)

Per-op times come from the unified cost pipeline (``core.cost``): memory
time is routed through the multi-level hierarchy (``core.memory``), and
collective times use ring-algorithm factors on ``group_size`` with a
bidirectional ring (2 links) per collective.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

# re-exported for backward compatibility: the cost model used to live here
from .cost import OpTime, collective_factor, cost_op, cost_program  # noqa: F401
from .hlo import Program
from .hwspec import HardwareSpec
from .memory import aggregate_traffic


@dataclass
class EngineResult:
    """Occupancy-engine output: per-port busy sums composed with the
    configured overlap fractions (DESIGN.md §6).
    """
    port_busy: Dict[str, float]
    t_est: float
    t_roofline: float
    t_serial: float
    n_ops: float
    startup: float
    mxu_utilization: float
    by_class_time: Dict[str, float]
    top_ops: List[OpTime]
    collective_time_by_kind: Dict[str, float]
    # per-memory-level totals (count-multiplied read/write bytes), for the
    # PA report's hierarchy section
    traffic_by_level: Dict[str, Dict[str, float]] = field(default_factory=dict)

    @property
    def bound_by(self) -> str:
        if not self.port_busy:
            return "mem"
        return max(self.port_busy, key=lambda k: self.port_busy[k])


def simulate_program(prog: Program, hw: HardwareSpec,
                     links_per_collective: int = 2,
                     compute_dtype: Optional[str] = None,
                     costed: Optional[List[Optional[OpTime]]] = None
                     ) -> EngineResult:
    """``compute_dtype``: the model's intended compute dtype.  When set to a
    16-bit type, f32 ops are costed as that type (flops AND bytes AND
    collective payloads).  This inverts XLA:CPU's float-normalization pass
    (the host has no native bf16, so the partitioned module we parse holds
    f32-promoted dots/buffers that the TPU target executes natively in
    bf16) — the paper's operand-type-dependent OpClass table, applied in
    reverse.  f32-by-design state (optimizer moments, the loss) is also
    halved; it is step-frequency (not layer x microbatch frequency) traffic,
    so the error is bounded and documented in DESIGN.md §7.

    ``costed``: a precomputed ``cost_program`` list, so callers running
    both engines (or several reports) pay for costing exactly once.
    """
    if costed is None:
        costed = cost_program(prog, hw, links_per_collective, compute_dtype)
    port_busy: Dict[str, float] = defaultdict(float)
    by_class: Dict[str, float] = defaultdict(float)
    coll_kind: Dict[str, float] = defaultdict(float)
    op_times: List[OpTime] = []
    t_serial = 0.0
    startup = 0.0
    n_ops = 0.0
    useful_f, padded_f = 0.0, 0.0

    for ot in costed:
        if ot is None:
            continue
        o = ot.op
        t_c, t_m, t_i, port = ot.t_compute, ot.t_mem, ot.t_ici, ot.port
        useful_f += ot.useful_flops
        padded_f += ot.padded_flops
        if o.opclass == "collective":
            coll_kind[o.opcode] += t_i * o.count

        if port in ("mxu", "vpu"):
            port_busy[port] += t_c * o.count
        port_busy["mem"] += t_m * o.count
        port_busy["ici"] += t_i * o.count
        by_class[o.opclass] += max(t_c, t_m, t_i) * o.count
        t_serial += max(t_c, t_m, t_i) * o.count
        startup += hw.op_startup_ns * 1e-9 * o.count
        n_ops += o.count
        op_times.append(ot)

    # .get, not [] — indexing the defaultdict would materialize phantom
    # zero ports and break bound_by's empty-program fallback
    compute = port_busy.get("mxu", 0.0) + port_busy.get("vpu", 0.0)
    mem_exposed = max(0.0, port_busy.get("mem", 0.0)
                      - hw.dma_overlap * compute)
    ici_exposed = max(0.0, port_busy.get("ici", 0.0)
                      - hw.ici_overlap * compute)
    t_est = compute + mem_exposed + ici_exposed + startup
    t_roofline = max(compute, port_busy.get("mem", 0.0),
                     port_busy.get("ici", 0.0))

    traffic = aggregate_traffic([t.traffic for t in op_times],
                                [t.op.count for t in op_times])

    op_times.sort(key=lambda t: -(t.t_op * t.op.count))
    return EngineResult(
        port_busy=dict(port_busy),
        t_est=t_est,
        t_roofline=t_roofline,
        t_serial=t_serial + startup,
        n_ops=n_ops,
        startup=startup,
        mxu_utilization=(useful_f / padded_f) if padded_f else 1.0,
        by_class_time=dict(by_class),
        top_ops=op_times[:20],
        collective_time_by_kind=dict(coll_kind),
        traffic_by_level=traffic,
    )
