"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--size 100m]

Uses the full production stack on the host: logical-axis sharding, grad
accumulation, cosine schedule, atomic checkpointing, the fault-tolerant
step loop (an injected failure at step 150 demonstrates restart), and the
seekable synthetic data pipeline.  Loss falls from ~ln(V) to well below it
as the model learns the synthetic stream's structure.
"""
import argparse
import shutil
import time

import numpy as np

from repro.configs import RunConfig, ShapeConfig
from repro.configs.base import ModelConfig
from repro.launch.train import train_loop
from repro.models.lm import build_model
from repro.train.fault import FaultInjector

SIZES = {
    # ~100M params: 12L d=768 (GPT-2-small-ish), GQA 12/4, SwiGLU
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab_size=32_000),
    # ~20M for a faster demo run
    "20m": dict(n_layers=8, d_model=384, n_heads=8, n_kv_heads=4,
                d_ff=1024, vocab_size=8_000),
    # ~3M smoke
    "3m": dict(n_layers=4, d_model=192, n_heads=4, n_kv_heads=2,
               d_ff=512, vocab_size=2_000),
}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--size", default="20m", choices=sorted(SIZES))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-fault", action="store_true", default=True)
    ap.add_argument("--no-inject-fault", dest="inject_fault",
                    action="store_false")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)   # fresh demo run
    cfg = ModelConfig(name=f"demo-{args.size}", family="dense",
                      **SIZES[args.size])
    model = build_model(cfg)
    n = cfg.param_count()
    print(f"training demo LM: {n / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    shape = ShapeConfig(name="demo", seq_len=args.seq,
                        global_batch=args.batch, kind="train")
    run = RunConfig(model=cfg, shape=shape, microbatch=args.microbatch,
                    param_dtype="float32", compute_dtype="float32",
                    learning_rate=args.lr)
    injector = None
    if args.inject_fault:
        mid = args.steps // 2
        injector = FaultInjector(fail_at_steps=(mid,))
        print(f"(fault injected at step {mid}: the loop must restart from "
              f"the latest checkpoint and converge anyway)")

    t0 = time.time()
    rep = train_loop(model, run, n_steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=25, injector=injector, log_every=25)
    dt = time.time() - t0
    tok_s = args.steps * shape.tokens / dt
    print(f"\ndone in {dt:.0f}s ({tok_s:,.0f} tok/s): "
          f"loss {np.mean(rep.losses[:10]):.3f} -> "
          f"{np.mean(rep.losses[-10:]):.3f}, restarts={rep.restarts}")
    assert np.mean(rep.losses[-10:]) < np.mean(rep.losses[:10]) - 0.5, \
        "loss did not fall"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
