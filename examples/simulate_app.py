"""The paper's workflow, end to end: estimate a full-scale application's
execution profile on hardware you don't have.

    PYTHONPATH=src python examples/simulate_app.py --arch grok-1-314b \
        --shape train_4k

Lowers + compiles the FULL-size architecture for the production 256-chip
mesh (placeholder host devices — no allocation), then prints the simulator's
PA report: roofline terms, bound-by classification, collective schedule and
tuning hints.  This is what the RIKEN simulator did for Post-K applications,
adapted to XLA/TPU (DESIGN.md §2).

NOTE: spawns a subprocess so the 512-device XLA flag does not leak into the
parent (jax locks the device count at first init).
"""
import argparse
import os
import subprocess
import sys

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
r = run_cell("{arch}", "{shape}", multi_pod={multi}, force=True)
print(r["pa_report"])
mem = r.get("memory_analysis") or {{}}
print()
print("memory_analysis per device:",
      {{k: f"{{v/2**30:.2f}} GiB" for k, v in mem.items()}})
print("fits 16 GiB HBM:", r["fits_hbm"])
"""


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="grok-1-314b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    code = CHILD.format(arch=args.arch, shape=args.shape,
                        multi=args.multi_pod)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__)))).returncode


if __name__ == "__main__":
    raise SystemExit(main())
