"""Quickstart: build a model, train a few steps, simulate it for TPU v5e.

    PYTHONPATH=src python examples/quickstart.py

Walks the three things this framework does:
  1. build any of the 10 assigned architectures from its config,
  2. run real training steps on the host,
  3. feed the compiled step to the RIKEN-style simulator and read the
     PA report — the paper's "tune your app before the hardware exists"
     workflow.
"""
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, RunConfig, ShapeConfig, reduced_config
from repro.core.hwspec import TPU_V5E
from repro.core.simulate import simulate
from repro.models.lm import build_model
from repro.train.trainer import make_train_step

# ---------------------------------------------------------------- 1. build
cfg = reduced_config(ARCHS["chatglm3-6b"])       # tiny same-family config
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
n_params = sum(x.size for x in jax.tree.leaves(params))
print(f"built {cfg.name} (reduced): {n_params:,} params, "
      f"{cfg.n_layers}L d={cfg.d_model} heads={cfg.n_heads}/{cfg.n_kv_heads}")

# ---------------------------------------------------------------- 2. train
B, S = 4, 64
shape = ShapeConfig(name="quick", seq_len=S, global_batch=B, kind="train")
run = RunConfig(model=cfg, shape=shape, param_dtype="float32",
                compute_dtype="float32", learning_rate=1e-3)
step, *_, opt_init = make_train_step(model, run, rules=None)
jstep = jax.jit(step, donate_argnums=(0, 1))
opt = opt_init(params)
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
batch = {"tokens": tokens}
for i in range(5):
    params, opt, metrics = jstep(params, opt, batch)
    print(f"  step {i}: loss {float(metrics['loss']):.4f}")

# ------------------------------------------------------------- 3. simulate
compiled = jax.jit(step, donate_argnums=(0, 1)).lower(
    params, opt, batch).compile()
report = simulate(compiled, hw=TPU_V5E, n_chips=1,
                  model_flops_global=6.0 * n_params * B * S,
                  compute_dtype="f32", title=f"{cfg.name} quickstart")
print()
print(report.pa)
print("\nquickstart OK")
