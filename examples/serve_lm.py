"""Serve a small model with batched requests (prefill + cached decode).

    PYTHONPATH=src python examples/serve_lm.py

Trains a tiny LM on an affine-markov token stream with a FIXED rule
(x[t+1] = (m*x[t] + noise) mod V), then serves generation requests; the
served continuations should follow the learned rule, which we score.  This
demonstrates the prefill/decode cache path end-to-end — including for the
attention-free (mamba2) architecture, whose "cache" is the SSD state.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, RunConfig, ShapeConfig, reduced_config
from repro.launch.train import build_training
from repro.models.lm import build_model
from repro.serve.engine import ServeEngine

MULT = 3
VOCAB = 256


def markov_seq(rng, length):
    x = np.empty(length, np.int64)
    x[0] = rng.integers(0, VOCAB)
    noise = rng.integers(0, 3, size=length)
    for t in range(1, length):
        x[t] = (MULT * x[t - 1] + noise[t]) % VOCAB
    return x


def train_briefly(model, cfg, steps=500, batch=32, seq=64, lr=3e-3):
    shape = ShapeConfig(name="s", seq_len=seq, global_batch=batch,
                        kind="train")
    run = RunConfig(model=cfg, shape=shape, param_dtype="float32",
                    compute_dtype="float32", learning_rate=lr)
    jstep, init_state, _ = build_training(model, run)
    params, opt = init_state(0)
    rng = np.random.default_rng(0)
    for i in range(steps):
        b = {"tokens": jnp.asarray(
            np.stack([markov_seq(rng, seq) for _ in range(batch)]),
            jnp.int32)}
        params, opt, m = jstep(params, opt, b)
        if i % 100 == 0 or i == steps - 1:
            print(f"  train step {i}: loss {float(m['loss']):.3f}")
    return params


def rule_accuracy(prompt, out):
    """Fraction of generated transitions consistent with the markov rule."""
    seq = [prompt[-1]] + out
    ok = sum((seq[t + 1] - MULT * seq[t]) % VOCAB in (0, 1, 2)
             for t in range(len(seq) - 1))
    return ok, len(seq) - 1


def main() -> int:
    for arch in ("chatglm3-6b", "mamba2-1.3b"):
        cfg = dataclasses.replace(reduced_config(ARCHS[arch]),
                                  vocab_size=VOCAB)
        model = build_model(cfg)
        print(f"\n=== {arch} (reduced, vocab {VOCAB}) ===")
        params = train_briefly(model, cfg)

        engine = ServeEngine(model, params, max_seq=48)
        rng = np.random.default_rng(7)
        prompts = [list(markov_seq(rng, 24).astype(int)) for _ in range(4)]
        outs = engine.generate(prompts, max_new_tokens=12)
        hits = total = 0
        for p, o in zip(prompts, outs):
            ok, n = rule_accuracy(p, o)
            hits += ok
            total += n
            print(f"  served {o[:8]}... ({ok}/{n} transitions follow "
                  f"the learned rule)")
        print(f"  rule-following accuracy: {hits}/{total} "
              f"({100 * hits / total:.0f}%)")
        assert hits / total > 0.5, "served continuations ignore the rule"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
