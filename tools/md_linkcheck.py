"""Markdown link check: every relative link in the repo's *.md resolves.

    python tools/md_linkcheck.py [files...]

Defaults to every tracked-looking .md at the repo root.  Checks
``[text](target)`` links: relative targets must exist on disk (anchors
are stripped); absolute http(s)/mailto targets are not fetched (CI has
no network guarantee) — only their syntax is accepted.  Exits 1 with a
list of broken links.  Runs in CI (.github/workflows/ci.yml).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:")


def check_file(path: Path) -> list[str]:
    """Return broken-link messages for one markdown file."""
    errors = []
    text = path.read_text()
    in_code = False
    for lineno, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (path.parent / rel).resolve()
            if not resolved.exists():
                try:
                    shown = path.relative_to(ROOT)
                except ValueError:
                    shown = path
                errors.append(f"{shown}:{lineno}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    """Check the given files (default: all root-level .md) and report."""
    files = ([Path(a).resolve() for a in argv]
             or sorted(ROOT.glob("*.md")) + sorted(ROOT.glob("tools/*.md")))
    errors = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    n_files = len(files)
    if errors:
        print(f"md_linkcheck: {len(errors)} broken link(s) in {n_files} "
              f"file(s)", file=sys.stderr)
        return 1
    print(f"md_linkcheck: {n_files} file(s) OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
