"""Cluster scaling study: parallel plans over a TofuD-style mesh.

The multi-node engine (``core.cluster``, DESIGN.md §20) prices tensor/
data/pipeline-parallel train configs as REAL scheduled collectives
overlapping compute inside the batched node engine, on a TofuD-style
torus whose links contend through the same fixpoint machinery as the
node's shared memory levels.

    PYTHONPATH=src python -m benchmarks.cluster_scaling          # full
    PYTHONPATH=src python -m benchmarks.cluster_scaling --quick  # CI smoke

Full mode sweeps the registry's largest MoE (grok-1-314b: expert
parallelism in play) and largest dense config (nemotron-4-340b) from 2
to 1024 nodes and writes the committed ``BENCH_cluster.json`` (schema:
DESIGN.md §16): scaling curves, the winning plan per node count, the
model rank table and plan-rank Kendall taus.  ``--quick`` runs a
synthetic collective-free DAG as the workload at 2 and 8 nodes — no
jax, no HLO cache, seconds of wall time — and enforces sanity floors:
the 2-node DP efficiency must beat the floor (tiny grad payload, near-
free sync), efficiencies must stay in (0, 1], and every step time must
be finite and above its compute floor.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.core.cluster import ClusterWorkload, cluster_sweep
from repro.core.zoo import (DEFAULT_CLUSTER_MODELS, DEFAULT_NODE_COUNTS,
                            ClusterReport, run_cluster)

BENCH_JSON = Path("BENCH_cluster.json")
QUICK_JSON = Path("BENCH_cluster_quick.json")
HLO_CACHE = Path("experiments/zoo_hlo")
QUICK_N_OPS = 256
QUICK_NODE_COUNTS = (2, 8)
# 2-node pure-DP on the synthetic workload: one tiny grad all-reduce per
# "layer" against a 256-op step — overlap must keep efficiency high
QUICK_EFFICIENCY_FLOOR = 0.5


def quick_report() -> ClusterReport:
    """The jax-free smoke: a synthetic DAG dressed as a 4-layer model."""
    from benchmarks.sched_throughput import synthetic_program
    prog = synthetic_program(QUICK_N_OPS, seed=0)
    w = ClusterWorkload(
        name="synthetic", prog=prog, repeats=8, layers=4, d_model=512,
        seq_len=128, batch=2, param_bytes=64e6, frac_attn=0.4)
    report = ClusterReport(
        hw="a64fx_core", topology="a64fx_node", cluster="tofu_d",
        n_cores=48, compute_dtype="f32",
        node_counts=QUICK_NODE_COUNTS)
    t0 = time.perf_counter()
    report.results[w.name] = cluster_sweep(
        w, QUICK_NODE_COUNTS, n_cores=48, max_tp=4, max_pp=2)
    report.wall_s = time.perf_counter() - t0
    return report


def check_sanity(report: ClusterReport, efficiency_floor: float) -> list:
    """Invariants every sweep must satisfy; returns failure strings."""
    fails = []
    for model, rows in report.results.items():
        for r in rows:
            tag = f"{model} N={r.n_nodes} {r.plan.label}"
            if not (0.0 < r.parallel_efficiency <= 1.0 + 1e-9):
                fails.append(f"{tag}: efficiency "
                             f"{r.parallel_efficiency:.3f} outside (0, 1]")
            if not (r.t_step_s > 0.0 and r.t_step_s < float("inf")):
                fails.append(f"{tag}: non-finite step time {r.t_step_s}")
            if r.t_step_s + 1e-12 < r.t_floor_s:
                fails.append(f"{tag}: step {r.t_step_s:.3e} beats its "
                             f"compute floor {r.t_floor_s:.3e}")
        n0 = report.node_counts[0]
        dp_only = [r for r in rows
                   if r.n_nodes == n0 and r.plan.tp == 1 and r.plan.pp == 1]
        for r in dp_only:
            if r.parallel_efficiency < efficiency_floor:
                fails.append(
                    f"{model} N={n0} {r.plan.label}: DP efficiency "
                    f"{r.parallel_efficiency:.3f} below the "
                    f"{efficiency_floor:.2f} floor")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="synthetic smoke (no jax/zoo); writes "
                         f"{QUICK_JSON}")
    ap.add_argument("--efficiency-floor", type=float,
                    default=QUICK_EFFICIENCY_FLOOR,
                    help="minimum 2-node pure-DP parallel efficiency")
    ap.add_argument("--no-hlo-cache", action="store_true",
                    help="always retrace (ignore experiments/zoo_hlo/)")
    args = ap.parse_args(argv)

    if args.quick:
        print(f"== cluster scaling: synthetic smoke at "
              f"{QUICK_NODE_COUNTS} nodes ==")
        report = quick_report()
        target = QUICK_JSON
    else:
        cache = None if args.no_hlo_cache else HLO_CACHE
        print(f"== cluster scaling: {DEFAULT_CLUSTER_MODELS} over "
              f"{DEFAULT_NODE_COUNTS} nodes ==")
        report = run_cluster(
            hlo_cache_dir=cache,
            progress=lambda m, msg: print(f"  {m}: {msg}", flush=True))
        target = BENCH_JSON

    out = report.to_dict()
    out["mode"] = "quick" if args.quick else "full"
    target.write_text(json.dumps(out, indent=1))

    for model in report.results:
        print(f"{model}:")
        for n in report.node_counts:
            if not report.cells(model, n):
                continue
            b = report.best(model, n)
            print(f"  N={n:5d} best {b.plan.label:16s} "
                  f"t_step {b.t_step_s * 1e3:9.3f} ms  "
                  f"eff {b.parallel_efficiency:5.3f}  "
                  f"tok/s {b.tokens_per_s:12,.0f}")
        taus = report.plan_rank_stability(model)
        print(f"  plan-rank tau min {taus['min']:+.3f}")
    print(f"wrote {target} ({report.wall_s:.1f}s sweep)")

    fails = check_sanity(report, args.efficiency_floor)
    if fails:
        for f in fails:
            print(f"FAIL: {f}")
        return 1
    print("OK: all scaling-sanity floors hold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
