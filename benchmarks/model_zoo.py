"""Model-zoo sweep: every registry config through the node engine.

The one-node-application counterpart of ``benchmarks/kernel_suite.py``
(DESIGN.md §15): traces each architecture's train/prefill/decode phases
through the real model stack into compiled HLO, shards them over the
A64FX node topology, and reports contention-aware cycle estimates across
the 1 / 12 / 48 core axis plus rank-stability Kendall taus.

    PYTHONPATH=src python -m benchmarks.model_zoo            # full zoo
    PYTHONPATH=src python -m benchmarks.model_zoo --quick    # 5-model CI cut
    PYTHONPATH=src python -m benchmarks.model_zoo --arch mamba2-1.3b

Artifact: ``BENCH_model_zoo.json`` at the repo root (schema: DESIGN.md
§16) — committed, pinned by the rank-stability test in
``tests/test_zoo.py``, and rendered into EXPERIMENTS.md §Model-zoo by
``benchmarks/experiments_md.py``.  ``--budget`` makes the wall clock a
CI-enforceable gate: exit 1 when the sweep exceeds it.  Compiled HLO is
cached under ``experiments/zoo_hlo/`` so warm reruns skip the jax
compiles.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.configs import ARCHS
from repro.core.hwspec import A64FX_CORE
from repro.core.zoo import DEFAULT_CORE_COUNTS, run_zoo

BENCH_JSON = Path("BENCH_model_zoo.json")
HLO_CACHE = Path("experiments/zoo_hlo")

# the CI --quick cut: one model per family class that matters to the rank
# tables (dense, GQA dense, MoE, SSM, enc-dec)
QUICK_MODELS = ("chatglm3-6b", "qwen1.5-32b", "llama4-scout-17b-a16e",
                "mamba2-1.3b", "whisper-large-v3")


def _progress(arch: str, phase: str, pe, wall: float) -> None:
    by_core = "  ".join(
        f"{ce.n_cores}c {ce.t_est_s * 1e6:9.1f}us" for ce in pe.per_core)
    print(f"  {arch:<24s}{phase:<9s}{pe.n_ops:>5d} ops  {by_core}  "
          f"x{pe.node_speedup:5.1f}  {pe.roofline_dominant:<7s}"
          f"[{wall:5.1f}s]", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help=f"sweep only {len(QUICK_MODELS)} representative "
                         "models (the CI cut)")
    ap.add_argument("--arch", action="append", default=None,
                    help="sweep only this architecture (repeatable)")
    ap.add_argument("--phases", default=None,
                    help="comma-separated subset of train,prefill,decode")
    ap.add_argument("--core-counts", default=None,
                    help="comma-separated core counts "
                         f"(default {DEFAULT_CORE_COUNTS})")
    ap.add_argument("--budget", type=float, default=900.0,
                    help="wall-clock budget in seconds; exceeding it fails "
                         "the run (CI gate). 0 disables")
    ap.add_argument("--no-o3-grid", action="store_true",
                    help="skip the batched O3 knob grid per cell")
    ap.add_argument("--no-hlo-cache", action="store_true",
                    help="always recompile (ignore experiments/zoo_hlo/)")
    args = ap.parse_args(argv)

    models = args.arch
    if models is None:
        models = list(QUICK_MODELS) if args.quick else sorted(ARCHS)
    for m in models:
        if m not in ARCHS:
            ap.error(f"unknown arch {m!r}; known: {sorted(ARCHS)}")
    phases = args.phases.split(",") if args.phases else None
    core_counts = (tuple(int(c) for c in args.core_counts.split(","))
                   if args.core_counts else DEFAULT_CORE_COUNTS)

    print(f"== model zoo -> node engine ({A64FX_CORE.name}, "
          f"{len(models)} models, cores {core_counts}) ==")
    report = run_zoo(
        models=models, phases=phases, hw=A64FX_CORE,
        core_counts=core_counts, with_o3_grid=not args.no_o3_grid,
        hlo_cache_dir=None if args.no_hlo_cache else HLO_CACHE,
        progress=_progress)

    print("\n== rank tables (fastest first) & stability ==")
    for ph in report.phases:
        taus = report.rank_stability(ph)
        ranks = report.rank_table(ph, min(core_counts))
        print(f"  {ph:<9s}tau(min over core axis)={taus['min']:+.2f}  "
              f"tau(vs traced flops)={taus['vs_flops']:+.2f}")
        print(f"           @{min(core_counts)}c: {' > '.join(ranks)}")

    d = report.to_dict()
    BENCH_JSON.write_text(json.dumps(d, indent=1, sort_keys=True))
    print(f"\nwrote {BENCH_JSON} "
          f"({len(models)} models x {len(report.phases)} phases x "
          f"{len(core_counts)} core counts) in {report.wall_s:.1f}s")

    if args.budget and report.wall_s > args.budget:
        print(f"BUDGET EXCEEDED: {report.wall_s:.1f}s > {args.budget:.0f}s "
              "(tighten the zoo shapes or warm the HLO cache)",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
