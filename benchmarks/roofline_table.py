"""§Roofline table builder: reads dry-run artifacts -> markdown/CSV.

    PYTHONPATH=src python -m benchmarks.roofline_table [--mesh single_pod]

Emits, per (arch x shape) cell: the three roofline terms (seconds), the
dominant term, MODEL_FLOPS/HLO_FLOPs, MXU useful-lane fraction, per-chip
peak bytes, and the one-line tuning hint from the PA report.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

DRYRUN = Path("experiments/dryrun")


def load_rows(mesh: str):
    rows = []
    d = DRYRUN / mesh
    if not d.exists():
        return rows
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        rows.append(r)
    return rows


def hint_of(r: dict) -> str:
    pa = r.get("pa_report", "")
    for line in pa.splitlines():
        line = line.strip()
        if line.startswith("- "):
            return line[2:].split(":")[0]
    return ""


def fmt_markdown(rows) -> str:
    out = ["| arch | shape | kind | compute s | memory s | collective s | "
           "dominant | MF/HLO | MXU lanes | peak GiB | fits | hint |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rf = r["roofline"]
        mem = r.get("memory_analysis") or {}
        peak = (mem.get("peak_bytes_est") or 0) / 2**30
        fits = "Y" if r.get("fits_hbm") else "N"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | **{rf['dominant']}** "
            f"| {rf['useful_flops_ratio']:.2f} | {rf['mxu_utilization']:.2f} "
            f"| {peak:.2f} | {fits} | {hint_of(r)} |")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod"])
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args(argv)
    rows = load_rows(args.mesh)
    if not rows:
        print(f"no artifacts under {DRYRUN / args.mesh}; "
              "run `python -m repro.launch.dryrun` first")
        return 1
    if args.csv:
        print("arch,shape,kind,compute_s,memory_s,collective_s,dominant,"
              "mf_hlo,mxu_lanes,peak_gib")
        for r in rows:
            rf = r["roofline"]
            mem = r.get("memory_analysis") or {}
            print(f"{r['arch']},{r['shape']},{r['kind']},"
                  f"{rf['compute_s']:.6f},{rf['memory_s']:.6f},"
                  f"{rf['collective_s']:.6f},{rf['dominant']},"
                  f"{rf['useful_flops_ratio']:.4f},"
                  f"{rf['mxu_utilization']:.4f},"
                  f"{(mem.get('peak_bytes_est') or 0) / 2**30:.3f}")
    else:
        print(fmt_markdown(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
