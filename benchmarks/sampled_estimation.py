"""Sampled-estimation perf smoke: sampled vs full node scheduling.

Measures the SimPoint-style sampler (``core.sample``, DESIGN.md §18) on
three program families and FAILS the build when it stops paying for
itself:

* **bench DAG** — the repetitive 10k-op synthetic trace (the
  ``sched_throughput`` step unrolled 40x), monolithic full schedule vs
  sampled reconstruction at 48 cores.  CI floors: sampled wall-clock
  speedup >= 3x while scheduling <= 20% of op instances within 5%
  reconstruction error.
* **zoo long traces** — full-depth/multi-step zoo cells
  (``zoo.trace_long_phase``: the reduced step unrolled by the
  full/reduced layer ratio, 1024 decode steps) through the FULL
  ``estimate_program`` pipeline (3 core counts x 12-knob O3 grid), once
  unsampled and once sampled.  The unsampled pass is the one that blows
  the ``--budget`` gate; the sampled pass must complete under it, within
  5% of the unsampled estimate at 12 cores.  ``--quick`` restricts to
  one model (the CI cut; warm HLO cache from the model_zoo step, no new
  jax compiles).
* **kernel suite** (full mode only, jax) — every calibration kernel
  program unrolled 32x, same error/fraction pin.

Usage:  PYTHONPATH=src python -m benchmarks.sampled_estimation [--quick]

Artifact: ``BENCH_sampling.json`` at the repo root (schema: DESIGN.md
§18) — committed, rendered into EXPERIMENTS.md §Sampled-estimation, and
uploaded by CI.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from repro.configs import ARCHS, ZOO_SHAPES, zoo_phases_for
from repro.core.hwspec import A64FX_CORE
from repro.core.sample import SamplingConfig, measure_sampled_vs_full, \
    unroll_program
from repro.core.zoo import estimate_program, phase_model_flops, \
    trace_long_phase, zoo_config, zoo_o3_knobs

from .sched_throughput import synthetic_program

BENCH_JSON = Path("BENCH_sampling.json")
HLO_CACHE = Path("experiments/zoo_hlo")

SPEEDUP_FLOOR = 3.0          # sampled >= 3x full on the 10k-op bench DAG
FRAC_CEIL = 0.20             # while scheduling <= 20% of op instances
ERR_CEIL_PCT = 5.0           # within 5% reconstruction error
BENCH_CORES = 48
ZOO_CORES = (1, 12, 48)
DECODE_STEPS = 1024
KERNEL_REPEATS = 32
QUICK_MODELS = ("chatglm3-6b",)


def bench_dag_row() -> dict:
    """Monolithic vs sampled on the repetitive 10k-op bench DAG."""
    step = synthetic_program(250, seed=3)
    step_inst = sum(o.count for o in step.ops)
    prog = unroll_program(step, 40)
    cfg = SamplingConfig(interval_ops=step_inst, phase_aware=False)
    row = measure_sampled_vs_full(prog, A64FX_CORE, BENCH_CORES,
                                  config=cfg, compute_dtype="f64")
    row["n_cores"] = BENCH_CORES
    return row


def zoo_phase_row(arch: str, phase: str, budget_s: float) -> dict:
    """One full-depth zoo cell through estimate_program, unsampled vs
    sampled (the budget-gate demonstration)."""
    prog, repeats = trace_long_phase(arch, phase, hlo_cache_dir=HLO_CACHE,
                                     decode_steps=DECODE_STEPS)
    cfg = zoo_config(arch)
    flops = phase_model_flops(cfg, ZOO_SHAPES[phase])
    knobs = zoo_o3_knobs(A64FX_CORE)
    step_inst = sum(o.count for o in prog.ops) / repeats

    t0 = time.perf_counter()
    pe_full = estimate_program(prog, A64FX_CORE, ZOO_CORES,
                               model_flops=flops, o3_knobs=knobs,
                               arch=arch, phase=phase)
    wall_full = time.perf_counter() - t0

    t0 = time.perf_counter()
    pe_sam = estimate_program(
        prog, A64FX_CORE, ZOO_CORES, model_flops=flops, o3_knobs=knobs,
        arch=arch, phase=phase,
        sampling=SamplingConfig(interval_ops=step_inst,
                                phase_aware=False))
    wall_sampled = time.perf_counter() - t0

    t_full = pe_full.at(12).t_est_s
    t_sam = pe_sam.at(12).t_est_s
    return {
        "n_ops": pe_full.n_ops,
        "trace_repeats": repeats,
        "k": pe_sam.sampling["k"],
        "n_intervals": pe_sam.sampling["n_intervals"],
        "frac_ops_scheduled": pe_sam.sampling["frac_ops_scheduled"],
        "t_full_us": t_full * 1e6,
        "t_sampled_us": t_sam * 1e6,
        "reconstruction_error_pct":
            100.0 * (t_sam - t_full) / max(t_full, 1e-30),
        "wall_full_s": wall_full,
        "wall_sampled_s": wall_sampled,
        "speedup": wall_full / max(wall_sampled, 1e-30),
        "budget_s": budget_s,
        "full_exceeds_budget": wall_full > budget_s,
        "sampled_under_budget": wall_sampled <= budget_s,
    }


def kernel_rows() -> dict:
    """Full mode: the jax kernel-suite programs, unrolled 32x."""
    from repro.core.calibrate import kernel_accuracy_table
    table = kernel_accuracy_table(A64FX_CORE, keep_programs=True)
    out = {}
    for krow, prog in zip(table.rows, table.programs):
        step_inst = sum(o.count for o in prog.ops)
        long_prog = unroll_program(prog, KERNEL_REPEATS)
        row = measure_sampled_vs_full(
            long_prog, A64FX_CORE, 12,
            config=SamplingConfig(interval_ops=step_inst,
                                  phase_aware=False),
            compute_dtype="f64")
        row["repeats"] = KERNEL_REPEATS
        out[krow.name] = row
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help=f"bench DAG + {len(QUICK_MODELS)} zoo model(s) "
                         "only, no jax kernel suite (the CI cut)")
    ap.add_argument("--speedup-floor", type=float, default=SPEEDUP_FLOOR,
                    help="fail if bench-DAG sampled speedup drops below")
    ap.add_argument("--budget", type=float, default=10.0,
                    help="per-phase wall budget (s) a sampled full-depth "
                         "zoo estimate must stay under (the gate the "
                         "unsampled pass blows). 0 disables")
    args = ap.parse_args(argv)

    t_start = time.perf_counter()
    print(f"== sampled estimation ({A64FX_CORE.name}) ==")
    dag = bench_dag_row()
    print(f"  bench DAG   {dag['n_ops']:>6d} ops  k={dag['k']}/"
          f"{dag['n_intervals']}  frac={dag['frac_ops_scheduled']:.3f}  "
          f"err={dag['reconstruction_error_pct']:+.3f}%  "
          f"speedup={dag['speedup']:.1f}x")

    models = QUICK_MODELS if args.quick else tuple(sorted(ARCHS))
    zoo: dict = {}
    for arch in models:
        zoo[arch] = {}
        for phase in zoo_phases_for(zoo_config(arch)):
            row = zoo_phase_row(arch, phase, args.budget)
            zoo[arch][phase] = row
            print(f"  {arch:<24s}{phase:<9s}{row['n_ops']:>6d} ops "
                  f"x{row['trace_repeats']:<3d} k={row['k']}/"
                  f"{row['n_intervals']:<4d} "
                  f"frac={row['frac_ops_scheduled']:.3f}  "
                  f"err={row['reconstruction_error_pct']:+.3f}%  "
                  f"full={row['wall_full_s']:5.1f}s  "
                  f"sampled={row['wall_sampled_s']:5.2f}s", flush=True)

    kernels = {} if args.quick else kernel_rows()
    for name, row in kernels.items():
        print(f"  kernel:{name:<17s}{row['n_ops']:>6d} ops  "
              f"frac={row['frac_ops_scheduled']:.3f}  "
              f"err={row['reconstruction_error_pct']:+.3f}%")

    out = {
        "schema": 1,
        "hw": A64FX_CORE.name,
        "quick": bool(args.quick),
        "floors": {"speedup": args.speedup_floor, "frac": FRAC_CEIL,
                   "error_pct": ERR_CEIL_PCT, "budget_s": args.budget},
        "bench_dag": dag,
        "zoo": zoo,
        "kernels": kernels,
        "wall_s": time.perf_counter() - t_start,
    }
    BENCH_JSON.write_text(json.dumps(out, indent=1, sort_keys=True))
    print(f"wrote {BENCH_JSON} in {out['wall_s']:.1f}s")

    ok = True
    if dag["speedup"] < args.speedup_floor:
        print(f"FAIL: bench DAG sampled speedup {dag['speedup']:.2f}x is "
              f"below the {args.speedup_floor:.1f}x floor",
              file=sys.stderr)
        ok = False
    if dag["frac_ops_scheduled"] > FRAC_CEIL:
        print(f"FAIL: bench DAG scheduled "
              f"{100 * dag['frac_ops_scheduled']:.1f}% of instances "
              f"(> {100 * FRAC_CEIL:.0f}%)", file=sys.stderr)
        ok = False
    if abs(dag["reconstruction_error_pct"]) > ERR_CEIL_PCT:
        print(f"FAIL: bench DAG reconstruction error "
              f"{dag['reconstruction_error_pct']:+.2f}% exceeds "
              f"{ERR_CEIL_PCT:.0f}%", file=sys.stderr)
        ok = False
    for arch, by_phase in zoo.items():
        for phase, row in by_phase.items():
            cell = f"{arch}/{phase}"
            if abs(row["reconstruction_error_pct"]) > ERR_CEIL_PCT:
                print(f"FAIL: {cell} error "
                      f"{row['reconstruction_error_pct']:+.2f}% exceeds "
                      f"{ERR_CEIL_PCT:.0f}%", file=sys.stderr)
                ok = False
            if row["frac_ops_scheduled"] > FRAC_CEIL:
                print(f"FAIL: {cell} scheduled "
                      f"{100 * row['frac_ops_scheduled']:.1f}% of "
                      f"instances (> {100 * FRAC_CEIL:.0f}%)",
                      file=sys.stderr)
                ok = False
            if args.budget and not row["sampled_under_budget"]:
                print(f"FAIL: {cell} sampled estimate took "
                      f"{row['wall_sampled_s']:.1f}s "
                      f"(> {args.budget:.0f}s budget)", file=sys.stderr)
                ok = False
    for name, row in kernels.items():
        if abs(row["reconstruction_error_pct"]) > ERR_CEIL_PCT or \
                row["frac_ops_scheduled"] > FRAC_CEIL:
            print(f"FAIL: kernel {name} "
                  f"err={row['reconstruction_error_pct']:+.2f}% "
                  f"frac={row['frac_ops_scheduled']:.2f}",
                  file=sys.stderr)
            ok = False
    if not ok:
        return 1
    n_over = sum(r["full_exceeds_budget"]
                 for by in zoo.values() for r in by.values())
    print(f"OK: bench DAG {dag['speedup']:.1f}x >= "
          f"{args.speedup_floor:.1f}x at "
          f"{100 * dag['frac_ops_scheduled']:.1f}% ops, all errors within "
          f"{ERR_CEIL_PCT:.0f}%; {n_over} full-depth cell(s) over the "
          f"{args.budget:.0f}s budget completed sampled")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
