"""Hardware DSE sweep: the candidate-architecture grid over the zoo.

The fused spec-axis engine (``core.dse``, DESIGN.md §19) drives the
64-point candidate grid (CMG count x cores/CMG x HBM stacks x ring
latency x VPU width) through zoo workloads as ONE batched costing +
contention fixpoint per program, and times that path against the
per-spec Python loop (``cost_program`` + ``compile_node`` +
``schedule_node_batch`` once per candidate).  The two are bit-identical
per element (``tests/test_spec_batch.py`` pins it); the sweep exists to
make the loop's wall time go away, so the build FAILS when the fused
path drops below ``--floor`` times the loop.

    PYTHONPATH=src python -m benchmarks.dse_sweep            # full, needs zoo HLO
    PYTHONPATH=src python -m benchmarks.dse_sweep --quick    # synthetic, jax-free CI smoke

Full mode writes the committed ``BENCH_dse.json`` (schema: DESIGN.md
§16): per-workload per-candidate estimates, Pareto fronts over
(cycles, HBM bytes, cores), the cross-workload Kendall-tau
ranking-stability matrix, and the measured throughput block.  ``--quick``
writes ``BENCH_dse_quick.json`` from a synthetic DAG — no jax, no HLO
cache, seconds of wall time — and enforces the same floor.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core.compiled import O3Knobs
from repro.core.dse import generate_grid, run_dse, spec_grid, sweep_workload
from repro.core.node import (compile_node, compile_node_grid,
                             schedule_node_batch, schedule_spec_sweep)

BENCH_JSON = Path("BENCH_dse.json")
QUICK_JSON = Path("BENCH_dse_quick.json")
SPEEDUP_FLOOR = 10.0
# prefill + decode for the model_zoo --quick cut: 10 workloads, one per
# family class x serving phase (train HLO is much bigger; the committed
# artifact stays regenerable in seconds from a warm cache)
FULL_MODELS = ("chatglm3-6b", "qwen1.5-32b", "llama4-scout-17b-a16e",
               "mamba2-1.3b", "whisper-large-v3")
FULL_PHASES = ("prefill", "decode")
HLO_CACHE = Path("experiments/zoo_hlo")
QUICK_N_OPS = 2_000


def _clear_caches(prog) -> None:
    """Drop the per-Program compile memos so every timed round pays the
    same cold-cache cost (the grid cache would otherwise hide the fused
    path's compile, and the 8-entry node cache thrashes at 64 specs
    anyway — clearing makes both paths honestly cold)."""
    for k in ("_node_cache", "_node_grid_cache", "_cost_cache",
              "_compile_cache"):
        prog.__dict__.pop(k, None)


def measure_throughput(prog, grid, compute_dtype="f32",
                       loop_rounds: int = 1,
                       fused_rounds: int = 3) -> dict:
    """Time the fused spec sweep against the per-spec loop on ``prog``.

    Both paths run cold (caches cleared per round) and compute the same
    [S] vector: each candidate scheduled shard-partitioned at its full
    core count with its own default O3 knobs.  Returns wall times,
    per-spec throughputs and the speedup."""
    S = grid.S

    def fused():
        _clear_caches(prog)
        ngc = compile_node_grid(prog, grid, compute_dtype=compute_dtype)
        return schedule_spec_sweep(ngc)[:, 0, 0]

    def loop():
        _clear_caches(prog)
        out = np.empty(S)
        for s, sp in enumerate(grid.specs):
            topo = grid.topology_of(s)
            nc = compile_node(prog, sp, compute_dtype=compute_dtype)
            res = schedule_node_batch(nc, sp, O3Knobs.single(sp),
                                      topo.n_cores, topology=topo,
                                      partition="shard")
            out[s] = res.t_est[0]
        return out

    t_fused = fused()          # warm numpy / allocator once
    t0 = time.perf_counter()
    for _ in range(fused_rounds):
        t_fused = fused()
    wall_fused = (time.perf_counter() - t0) / fused_rounds
    t0 = time.perf_counter()
    for _ in range(loop_rounds):
        t_loop = loop()
    wall_loop = (time.perf_counter() - t0) / loop_rounds

    if not np.array_equal(t_fused, t_loop):
        raise AssertionError(
            "fused sweep diverged from the per-spec loop "
            f"(max delta {np.abs(t_fused - t_loop).max():.3e})")
    return {
        "n_ops": len(prog.ops), "n_specs": S,
        "fused_wall_s": wall_fused, "loop_wall_s": wall_loop,
        "fused_specs_per_s": S / wall_fused,
        "loop_specs_per_s": S / wall_loop,
        "speedup": wall_loop / wall_fused,
        "bit_identical": True,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="synthetic-DAG smoke (no jax/zoo); writes "
                         f"{QUICK_JSON}")
    ap.add_argument("--floor", type=float, default=SPEEDUP_FLOOR,
                    help="fail when fused/loop speedup drops below this")
    ap.add_argument("--no-hlo-cache", action="store_true",
                    help="always retrace (ignore experiments/zoo_hlo/)")
    args = ap.parse_args(argv)

    points = generate_grid()
    grid = spec_grid(points)

    if args.quick:
        from benchmarks.sched_throughput import synthetic_program
        print(f"== DSE sweep: {grid.S} candidate specs (synthetic smoke) ==")
        prog = synthetic_program(QUICK_N_OPS)
        thr = measure_throughput(prog, grid)
        sw = sweep_workload(prog, grid)
        out = {
            "schema": 1, "mode": "quick",
            "n_specs": grid.S, "n_ops": thr["n_ops"],
            "throughput": thr, "floor_speedup": args.floor,
            "t_est_min_s": float(sw["t_est"].min()),
            "t_est_max_s": float(sw["t_est"].max()),
        }
        QUICK_JSON.write_text(json.dumps(out, indent=1))
        target = QUICK_JSON
    else:
        from repro.core.zoo import zoo_workloads
        workloads = zoo_workloads(FULL_MODELS, FULL_PHASES)
        print(f"== DSE sweep: {grid.S} candidate specs "
              f"({len(workloads)} zoo workloads) ==")
        cache = None if args.no_hlo_cache else HLO_CACHE
        out = run_dse(workloads, points=points, hlo_cache_dir=cache,
                      progress=lambda m: print(f"  {m}", flush=True))
        # time the fused-vs-loop race on the biggest traced workload
        from repro.core.zoo import trace_phase
        key = max(out["per_workload"],
                  key=lambda k: out["per_workload"][k]["n_ops"])
        arch, phase = key.split("/")
        prog = trace_phase(arch, phase, hlo_cache_dir=cache)
        thr = measure_throughput(prog, grid)
        out["throughput"] = {**thr, "workload": key,
                             "floor_speedup": args.floor}
        rs = out["rank_stability"]
        print(f"  rank stability: mean tau {rs['mean_tau']:+.3f}, "
              f"min {rs['min_tau']:+.3f} across "
              f"{len(out['workloads'])} workloads")
        BENCH_JSON.write_text(json.dumps(out, indent=1))
        target = BENCH_JSON

    print(f"fused:  {thr['fused_wall_s'] * 1e3:8.1f} ms/sweep "
          f"({thr['fused_specs_per_s']:,.0f} specs/s)")
    print(f"loop:   {thr['loop_wall_s'] * 1e3:8.1f} ms/sweep "
          f"({thr['loop_specs_per_s']:,.0f} specs/s)")
    print(f"speedup: {thr['speedup']:.1f}x (bit-identical), "
          f"floor {args.floor:.0f}x")
    print(f"wrote {target}")
    if thr["speedup"] < args.floor:
        print(f"FAIL: fused sweep speedup {thr['speedup']:.1f}x is below "
              f"the floor of {args.floor:.0f}x")
        return 1
    print("OK: fused sweep above the floor")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
