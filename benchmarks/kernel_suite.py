"""Paper Table 1 + Fig. 3: the 28-kernel suite, simulator-vs-measured.

Two outputs, mirroring the two axes of Fig. 3:

1. **Accuracy** (the orange dots): % execution-time difference between the
   RIKEN-style simulator (``core.simulate`` on the compiled HLO, with the
   *calibrated* CPU_HOST parameter file) and the host CPU — the only silicon
   in this container, playing the A64FX test chip's role.  Summary stats are
   printed against the paper's (mean +1.3%, std 7.8%, |mean| 6.6%, 82%
   within +-10%).

2. **Throughput** (the bar chart): simulated cycles per 8-element operation
   on a single A64FX core (the paper's own target), from the same compiled
   HLO costed with the ``A64FX_CORE`` parameter file.

Usage:  PYTHONPATH=src python -m benchmarks.kernel_suite [--quick]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from jax.experimental import enable_x64 as jax_enable_x64

from repro.configs.a64fx_kernelsuite import (
    KERNELS, PAPER_MEAN_ABS_DIFF_PCT, PAPER_MEAN_DIFF_PCT,
    PAPER_STD_DIFF_PCT, PAPER_WITHIN_10PCT_FRACTION)
from repro.core import calibrate
from repro.core.compiled import compile_program, schedule_arrays, \
    schedule_batch
from repro.core.cost import cost_program
from repro.core.hwspec import A64FX_CORE, HardwareSpec
from repro.core.schedule import schedule_reference
from repro.core.simulate import simulate

OUT = Path("experiments/bench")
BENCH_JSON = Path("BENCH_kernel_suite.json")


def scheduler_throughput(table: calibrate.AccuracyTable,
                         hw: HardwareSpec, min_wall_s: float = 0.2) -> dict:
    """Wall-clock throughput of the O3 scheduler over the suite's parsed
    programs (pure python/numpy, no jax): the perf number to track as the
    scheduling engine grows.  Programs are compiled to array form OUTSIDE
    the timed loops so the metric isolates the scheduler from the cost
    pipeline.

    Three numbers, one hot path: the headline ``ops_per_s`` is the
    compiled BATCHED kernel driving the full default O3 knob grid (the
    sweep engine's inner loop — every combo counts as scheduling the
    program once, because it is); ``single_ops_per_s`` is the compiled
    scalar kernel one knob set at a time; ``reference_ops_per_s`` is the
    per-op interpreter the differential tests pin both against."""
    compiled = [compile_program(p, hw, compute_dtype="f64")
                for p in table.programs]
    knobs = calibrate.default_o3_knobs(hw)

    def timed(fn, per_round: int) -> dict:
        n_ops = rounds = 0
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < min_wall_s:
            fn()
            n_ops += per_round
            rounds += 1
        wall = time.perf_counter() - t0
        return {"scheduled_ops": n_ops, "rounds": rounds, "wall_s": wall,
                "ops_per_s": n_ops / wall if wall > 0 else 0.0}

    suite_ops = sum(len(p.ops) for p in table.programs)

    def batched():
        for cp in compiled:
            schedule_batch(cp, knobs)

    def single():
        for cp in compiled:
            schedule_arrays(cp, hw)

    # reference interpreter with precomputed costed lists (the PR-2 metric)
    costed = [cost_program(p, hw, compute_dtype="f64")
              for p in table.programs]

    def reference():
        for prog, ops in zip(table.programs, costed):
            schedule_reference(prog, hw, costed=ops)

    res = timed(batched, suite_ops * knobs.batch)
    res["mode"] = "compiled_batched_o3_grid"
    res["grid_combos"] = knobs.batch
    # UNIT CHANGE vs the PR-2 number (75,143, single interpreter passes):
    # every grid combo counts as one schedule of the program — which it
    # is, bit-identically.  The like-for-like single-schedule trajectory
    # continues under single_ops_per_s / reference_ops_per_s below.
    res["pr2_baseline_single_ops_per_s"] = 75143.0
    res["single_ops_per_s"] = timed(single, suite_ops)["ops_per_s"]
    res["reference_ops_per_s"] = timed(reference, suite_ops)["ops_per_s"]
    return res


def a64fx_kernel_hlo(kernel_name: str, n: int) -> str:
    """Compile one suite kernel once; both A64FX sections reuse the text."""
    from repro.configs.a64fx_kernelsuite import KERNELS_BY_NAME
    with jax_enable_x64():
        x1, x2, y0 = calibrate._kernel_inputs(KERNELS_BY_NAME[kernel_name], n)
        f = calibrate._jit_kernel(kernel_name)
        return f.lower(x1, x2, y0).compile().as_text()


def a64fx_cycles_per_8elem(hlo_text: str, n: int) -> float:
    """Simulated single-core A64FX cycles per 8-element operation."""
    rep = simulate(hlo_text, hw=A64FX_CORE, n_chips=1, compute_dtype="f64")
    cycles = rep.engine.t_est * 1.8e9
    return cycles / (n / 8)


# node estimates: 1 core / one full CMG / the whole 4-CMG node (the old
# code's only node story was A64FX_CORE's hardcoded ~1/4-of-HBM2 draw;
# these come from the contention model instead)
NODE_CORE_COUNTS = (1, 12, 48)


def a64fx_node_estimates(hlo_text: str) -> dict:
    """Contention-aware node estimates (OpenMP-style shard partition) for
    one suite kernel on the A64FX node topology.  Parses and costs the
    program once; only the node schedule reruns per core count."""
    from repro.core.hlo import parse_program
    from repro.core.node import compile_node, schedule_node
    prog = parse_program(hlo_text)
    nc = compile_node(prog, A64FX_CORE, compute_dtype="f64")
    out = {}
    for k in NODE_CORE_COUNTS:
        nr = schedule_node(nc, A64FX_CORE, k, partition="shard")
        out[k] = {
            "t_est_us": nr.t_est * 1e6,
            "t_zero_contention_us": nr.t_zero_contention * 1e6,
            "hbm2_n_active": nr.per_cmg[0].n_active.get("hbm2", 1.0),
        }
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="subset of kernels, fewer repeats")
    ap.add_argument("--size-scale", type=int, default=calibrate.SIZE_SCALE)
    ap.add_argument("--sweep-o3", action="store_true",
                    help="grid-sweep the O3 schedule knobs (window / mem "
                         "issue width / queue depth) against the measured "
                         "kernels and report the tuned parameter file")
    args = ap.parse_args(argv)

    kernels = KERNELS[::4] if args.quick else KERNELS

    print("== calibrating CPU_HOST parameter file (the paper's Fujitsu-"
          "parameter step, fitted not NDA'd) ==")
    hw = calibrate.fit_cpu_host()
    print(f"  vpu {hw.vpu_flops['f64'] / 1e9:.2f} GFLOP/s  "
          f"hbm {hw.hbm_read_bw / 1e9:.2f} GB/s  "
          f"llc {hw.vmem_bw / 1e9:.2f} GB/s  "
          f"startup {hw.op_startup_ns / 1e3:.0f} us")
    print(f"  opcode factors: "
          f"{ {k: round(v, 1) for k, v in sorted(hw.opcode_factor.items())} }")

    print("\n== accuracy vs the host 'test chip' (Fig. 3 orange dots; "
          "occupancy vs schedule engine) ==")
    table = calibrate.kernel_accuracy_table(hw, size_scale=args.size_scale,
                                            kernels=kernels,
                                            keep_programs=True)
    print(table.report())

    thr = scheduler_throughput(table, hw)
    print(f"\n== scheduler throughput: {thr['ops_per_s']:.0f} ops/s "
          f"({thr['scheduled_ops']} ops in {thr['wall_s'] * 1e3:.0f} ms) ==")

    sweep = None
    sweep_timing = None
    if args.sweep_o3:
        print("\n== O3 resource-knob sweep (paper §4: OoO parameter "
              "tuning, fitted against the test chip; batched array "
              "kernel) ==")
        t0 = time.perf_counter()
        sweep = calibrate.sweep_o3(table, hw)
        t_new = time.perf_counter() - t0
        print(sweep.report())
        b = sweep.results[0]
        print(f"  tuned: window={b['inflight_window']} "
              f"mem_width={b['mem_issue_width']} "
              f"vpu_width={b['vpu_issue_width']} qdepth={b['queue_depth']}")
        # wall-cost comparison vs the PR-2 sweep: the OLD 4x3x3 grid run
        # serially through the reference interpreter
        old_specs = [calibrate._knob_spec(hw, w, mw, 1, qd)
                     for w in (4, 16, 64, 256)
                     for mw in calibrate.O3_MEM_WIDTHS
                     for qd in calibrate.O3_QUEUE_DEPTHS]
        costed = [cost_program(p, hw, compute_dtype="f64")
                  for p in table.programs]
        t0 = time.perf_counter()
        for cand in old_specs:
            for prog, ops in zip(table.programs, costed):
                schedule_reference(prog, cand, compute_dtype="f64",
                                   costed=ops)
        t_old = time.perf_counter() - t0
        sweep_timing = {
            "combos": len(sweep.results), "wall_s": t_new,
            "old_combos": len(old_specs), "old_wall_s": t_old,
            "speedup_vs_old_grid": t_old / t_new if t_new > 0 else 0.0,
        }
        print(f"  wall: {len(sweep.results)} combos batched in "
              f"{t_new * 1e3:.1f} ms vs old {len(old_specs)}-combo serial "
              f"grid {t_old * 1e3:.1f} ms "
              f"({sweep_timing['speedup_vs_old_grid']:.1f}x)")

    print("\n== simulated A64FX single-core throughput "
          "(Fig. 3 bars; cycles / 8-element op) ==")
    bars = {}
    hlo_texts = {k.name: a64fx_kernel_hlo(k.name, k.n * 8) for k in kernels}
    for k in kernels:
        c = a64fx_cycles_per_8elem(hlo_texts[k.name], k.n * 8)
        bars[k.name] = c
        print(f"  {k.name:<8s}{k.ktype:<10s}{c:8.2f} cyc/8elem")

    print("\n== A64FX node estimates (contention model, shard partition; "
          "1 core / 1 CMG / full node) ==")
    node_rows = {}
    for k in kernels:
        est = a64fx_node_estimates(hlo_texts[k.name])
        node_rows[k.name] = est
        t1, t12, t48 = (est[c]["t_est_us"] for c in NODE_CORE_COUNTS)
        print(f"  {k.name:<8s}1c {t1:9.2f} us  12c {t12:9.2f} us "
              f"(x{t1 / max(t12, 1e-12):5.1f})  48c {t48:9.2f} us "
              f"(x{t1 / max(t48, 1e-12):5.1f})  "
              f"hbm2 active@12c {est[12]['hbm2_n_active']:.1f}")

    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "kernel_suite.json").write_text(json.dumps({
        "rows": [{"name": r.name, "type": r.ktype, "n": r.n,
                  "measured_us": r.measured_us,
                  "simulated_us": r.simulated_us,
                  "diff_pct": r.diff_pct,
                  "simulated_sched_us": r.simulated_sched_us,
                  "sched_diff_pct": r.sched_diff_pct,
                  "bound_by": r.bound_by,
                  "fit_input": r.fit_input} for r in table.rows],
        "o3_sweep": sweep.results if sweep is not None else None,
        "o3_sweep_timing": sweep_timing,
        "summary": {
            "mean_diff_pct": table.mean_diff,
            "std_diff_pct": table.std_diff,
            "mean_abs_diff_pct": table.mean_abs_diff,
            "within_10pct": table.within_10pct,
            "sched_mean_abs_diff_pct": table.sched_mean_abs_diff,
            "sched_within_10pct": table.sched_within_10pct,
            "paper": {
                "mean_diff_pct": PAPER_MEAN_DIFF_PCT,
                "std_diff_pct": PAPER_STD_DIFF_PCT,
                "mean_abs_diff_pct": PAPER_MEAN_ABS_DIFF_PCT,
                "within_10pct": PAPER_WITHIN_10PCT_FRACTION,
            },
        },
        "a64fx_core_cycles_per_8elem": bars,
        "a64fx_node_estimates": node_rows,
        "calibrated_host": {
            "vpu_gflops": hw.vpu_flops["f64"] / 1e9,
            "hbm_gbps": hw.hbm_read_bw / 1e9,
            "llc_gbps": hw.vmem_bw / 1e9,
            "startup_us": hw.op_startup_ns / 1e3,
            "opcode_factor": hw.opcode_factor,
        },
    }, indent=1))
    print(f"wrote {OUT / 'kernel_suite.json'}")

    # perf-trajectory artifact (tracked from ISSUE 2 onward): per-kernel
    # t_est under both engines + wall-clock scheduler throughput
    BENCH_JSON.write_text(json.dumps({
        "kernels": {r.name: {"measured_us": r.measured_us,
                             "t_est_occupancy_us": r.simulated_us,
                             "t_est_schedule_us": r.simulated_sched_us,
                             "a64fx_node_us": {
                                 str(c): node_rows[r.name][c]["t_est_us"]
                                 for c in NODE_CORE_COUNTS}
                             if r.name in node_rows else None}
                    for r in table.rows},
        "scheduler_throughput": thr,
        "summary": {
            "mean_abs_diff_pct": table.mean_abs_diff,
            "sched_mean_abs_diff_pct": table.sched_mean_abs_diff,
            "within_10pct": table.within_10pct,
            "sched_within_10pct": table.sched_within_10pct,
        },
    }, indent=1))
    print(f"wrote {BENCH_JSON}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
