"""Scheduler perf-smoke: the compiled kernels on a synthetic 10k-op DAG.

CI runs this on every push (no jax, no calibration — pure python/numpy,
seconds of wall time), writes ``BENCH_sched_throughput.json``, uploads it
as an artifact, and FAILS the build when a kernel drops below its floor.

Accounting: one scheduled op-instance = one op advanced through one
in-order pass.  The batched kernels therefore count ``n_ops x combos``
per call, and the node engines ``n_ops x fixpoint_passes`` — every
fixpoint pass is a full in-order schedule of the program (the earlier
artifact counted one call as ``n_ops`` regardless of grid size or pass
count, which made the batched kernel look *slower* than the scalar one
whenever the grid was too small to amortize per-op dispatch).  The
warm-up call stays uncounted.  The batched kernels run the calibrate
sweep's full 90-combo grid — realistic amortization, same combos as
``calibrate.sweep_o3``'s defaults.

Usage:  PYTHONPATH=src python -m benchmarks.sched_throughput [--floor N]
"""
from __future__ import annotations

import argparse
import json
import random
import time
from pathlib import Path

from repro.core.compiled import O3Knobs, compile_program, schedule_arrays, \
    schedule_batch
from repro.core.cost import cost_program
from repro.core.hlo import OpStat, Program
from repro.core.hwspec import A64FX_CORE, CPU_HOST
from repro.core.node import compile_node, schedule_node, schedule_node_batch
from repro.core.schedule import schedule_reference

BENCH_JSON = Path("BENCH_sched_throughput.json")
FLOOR_OPS_PER_S = 150_000        # 2x the PR-2 baseline of 75,143
# batched node engine: the whole knob grid rides one vectorized
# contention fixpoint; 10x the old scalar-engine floor of 15k
NODE_FLOOR_OPS_PER_S = 150_000
NODE_SCALAR_FLOOR_OPS_PER_S = 15_000
NODE_CORES = 48
N_OPS = 10_000
# the calibrate.sweep_o3 default grid (90 combos), inlined so the bench
# stays import-light (core.calibrate pulls in jax)
GRID_COMBOS = [(w, mw, vw, qd)
               for w in (4, 16, 64, 256, 1024)
               for mw in (1, 2, 4) for vw in (1, 2) for qd in (4, 16, 64)]


def synthetic_program(n: int = N_OPS, seed: int = 0) -> Program:
    """Deterministic random DAG with kernel-suite-like op mix: mostly
    short-range def-use edges (XLA programs are locally dense), a mix of
    ports, and occasional collapsed-loop counts."""
    rng = random.Random(seed)
    ops = []
    for i in range(n):
        k = min(i, rng.randint(0, 3))
        lo = max(0, i - 64)
        deps = sorted(rng.sample(range(lo, i), min(k, i - lo)))
        cls = rng.choice(["elementwise", "elementwise", "data", "matmul",
                          "reduce", "transcendental"])
        ops.append(OpStat(
            f"op{i}", "fusion", cls, "f32",
            flops=rng.uniform(1e3, 1e9),
            transcendentals=rng.uniform(0, 1e3),
            bytes_accessed=rng.uniform(1e3, 1e8),
            read_bytes=rng.uniform(1e3, 5e7),
            write_bytes=rng.uniform(0, 5e7),
            count=rng.choice([1.0, 1.0, 1.0, 4.0]),
            deps=deps, dep_bytes=[rng.uniform(0, 1e6) for _ in deps]))
    return Program(ops=ops, entry="synthetic", n_partitions=1)


def _timed(fn, ops_per_round: int, min_wall_s: float) -> dict:
    fn()                                     # warm (allocations, caches)
    n_ops = rounds = 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < min_wall_s:
        fn()
        n_ops += ops_per_round
        rounds += 1
    wall = time.perf_counter() - t0
    return {"scheduled_ops": n_ops, "rounds": rounds, "wall_s": wall,
            "ops_per_s": n_ops / wall if wall > 0 else 0.0}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--floor", type=float, default=FLOOR_OPS_PER_S,
                    help="fail if fast-kernel ops/s drops below this")
    ap.add_argument("--node-floor", type=float, default=NODE_FLOOR_OPS_PER_S,
                    help="fail if the batched 48-core node engine drops "
                         "below this")
    ap.add_argument("--min-wall-s", type=float, default=1.0)
    args = ap.parse_args(argv)

    hw = CPU_HOST
    prog = synthetic_program()
    t0 = time.perf_counter()
    costed = cost_program(prog, hw, compute_dtype="f64")
    t_cost = time.perf_counter() - t0
    t0 = time.perf_counter()
    cp = compile_program(prog, hw, compute_dtype="f64", costed=costed)
    t_compile = time.perf_counter() - t0

    fast = _timed(lambda: schedule_arrays(cp, hw), cp.n, args.min_wall_s)
    grid = O3Knobs.from_grid(hw, GRID_COMBOS)
    batched = _timed(lambda: schedule_batch(cp, grid),
                     cp.n * grid.batch, args.min_wall_s)
    ref = _timed(lambda: schedule_reference(prog, hw, costed=costed),
                 cp.n, args.min_wall_s)

    # node engines on the A64FX node (costing under the A64FX_CORE spec,
    # round-robin partition over 48 cores).  One call = the whole knob
    # grid through the vectorized contention fixpoint; each element's
    # pass count is deterministic, so ops-per-call is measured once.
    node_hw = A64FX_CORE
    nc = compile_node(prog, node_hw, compute_dtype="f64")
    node_grid = O3Knobs.from_grid(node_hw, GRID_COMBOS)
    nbres = schedule_node_batch(nc, node_hw, node_grid, NODE_CORES,
                                partition="round-robin")
    node_ops_per_call = nc.n * nbres.total_scheduled_ops
    node = _timed(lambda: schedule_node_batch(nc, node_hw, node_grid,
                                              NODE_CORES,
                                              partition="round-robin"),
                  node_ops_per_call, args.min_wall_s)

    node_last = []

    def run_node_scalar():
        node_last.append(schedule_node(nc, node_hw, NODE_CORES,
                                       partition="round-robin"))
    run_node_scalar()
    scalar_iters = node_last[-1].iterations
    node_scalar = _timed(run_node_scalar, nc.n * scalar_iters,
                         args.min_wall_s)
    node_res = node_last[-1]

    out = {
        "program": {"n_ops": cp.n, "n_edges": cp.n_edges, "seed": 0},
        "cost_program_s": t_cost,
        "compile_program_s": t_compile,
        "fast_kernel": fast,
        "batched_kernel": {**batched, "grid_combos": grid.batch},
        "reference_interpreter": ref,
        "node_engine": {**node, "n_cores": NODE_CORES,
                        "grid_combos": node_grid.batch,
                        "fixpoint_passes_per_call":
                            int(nbres.total_scheduled_ops),
                        "floor_ops_per_s": args.node_floor},
        "node_engine_scalar": {**node_scalar, "n_cores": NODE_CORES,
                               "fixpoint_iterations": node_res.iterations,
                               "t_est": node_res.t_est,
                               "t_zero_contention":
                                   node_res.t_zero_contention,
                               "floor_ops_per_s":
                                   NODE_SCALAR_FLOOR_OPS_PER_S},
        "speedup_fast_vs_reference":
            fast["ops_per_s"] / max(ref["ops_per_s"], 1e-9),
        "speedup_node_batched_vs_scalar":
            node["ops_per_s"] / max(node_scalar["ops_per_s"], 1e-9),
        "floor_ops_per_s": args.floor,
    }
    BENCH_JSON.write_text(json.dumps(out, indent=1))
    print(f"fast kernel:      {fast['ops_per_s']:>12,.0f} ops/s")
    print(f"batched kernel:   {batched['ops_per_s']:>12,.0f} ops/s "
          f"({grid.batch} combos)")
    print(f"reference interp: {ref['ops_per_s']:>12,.0f} ops/s")
    print(f"node engine:      {node['ops_per_s']:>12,.0f} ops/s "
          f"({NODE_CORES} cores, {node_grid.batch} combos, "
          f"{int(nbres.total_scheduled_ops)} fixpoint passes/call)")
    print(f"node scalar:      {node_scalar['ops_per_s']:>12,.0f} ops/s "
          f"({NODE_CORES} cores, {node_res.iterations} fixpoint iters)")
    print(f"wrote {BENCH_JSON}")
    ok = True
    if fast["ops_per_s"] < args.floor:
        print(f"FAIL: fast kernel {fast['ops_per_s']:,.0f} ops/s is below "
              f"the floor of {args.floor:,.0f}")
        ok = False
    if batched["ops_per_s"] < fast["ops_per_s"]:
        print(f"FAIL: batched kernel {batched['ops_per_s']:,.0f} ops/s is "
              f"below the scalar fast kernel {fast['ops_per_s']:,.0f} — "
              "batching must amortize, not cost")
        ok = False
    if node["ops_per_s"] < args.node_floor:
        print(f"FAIL: node engine {node['ops_per_s']:,.0f} ops/s is below "
              f"the floor of {args.node_floor:,.0f}")
        ok = False
    if node_scalar["ops_per_s"] < NODE_SCALAR_FLOOR_OPS_PER_S:
        print(f"FAIL: scalar node engine {node_scalar['ops_per_s']:,.0f} "
              f"ops/s is below the floor of "
              f"{NODE_SCALAR_FLOOR_OPS_PER_S:,.0f}")
        ok = False
    if not ok:
        return 1
    print(f"OK: above the {args.floor:,.0f} (fast) and "
          f"{args.node_floor:,.0f} (node) ops/s floors; batched >= scalar")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
