"""Scheduler perf-smoke: the compiled kernels on a synthetic 10k-op DAG.

CI runs this on every push (no jax, no calibration — pure python/numpy,
seconds of wall time), writes ``BENCH_sched_throughput.json``, uploads it
as an artifact, and FAILS the build when the fast scalar kernel drops
below the floor.  The floor starts at 2x the PR-2 interpreter baseline
(75,143 ops/s on the kernel-suite bench); ratchet it as the engine gets
faster.

Usage:  PYTHONPATH=src python -m benchmarks.sched_throughput [--floor N]
"""
from __future__ import annotations

import argparse
import json
import random
import time
from pathlib import Path

from repro.core.compiled import O3Knobs, compile_program, schedule_arrays, \
    schedule_batch
from repro.core.cost import cost_program
from repro.core.hlo import OpStat, Program
from repro.core.hwspec import A64FX_CORE, CPU_HOST
from repro.core.node import compile_node, schedule_node
from repro.core.schedule import schedule_reference

BENCH_JSON = Path("BENCH_sched_throughput.json")
FLOOR_OPS_PER_S = 150_000        # 2x the PR-2 baseline of 75,143
# node engine: one schedule_node call runs the contention fixpoint (up to
# ~7 full passes over the DAG on 48 cores), so its floor is set well
# below the single-pass scalar kernel's
NODE_FLOOR_OPS_PER_S = 15_000
NODE_CORES = 48
N_OPS = 10_000


def synthetic_program(n: int = N_OPS, seed: int = 0) -> Program:
    """Deterministic random DAG with kernel-suite-like op mix: mostly
    short-range def-use edges (XLA programs are locally dense), a mix of
    ports, and occasional collapsed-loop counts."""
    rng = random.Random(seed)
    ops = []
    for i in range(n):
        k = min(i, rng.randint(0, 3))
        lo = max(0, i - 64)
        deps = sorted(rng.sample(range(lo, i), min(k, i - lo)))
        cls = rng.choice(["elementwise", "elementwise", "data", "matmul",
                          "reduce", "transcendental"])
        ops.append(OpStat(
            f"op{i}", "fusion", cls, "f32",
            flops=rng.uniform(1e3, 1e9),
            transcendentals=rng.uniform(0, 1e3),
            bytes_accessed=rng.uniform(1e3, 1e8),
            read_bytes=rng.uniform(1e3, 5e7),
            write_bytes=rng.uniform(0, 5e7),
            count=rng.choice([1.0, 1.0, 1.0, 4.0]),
            deps=deps, dep_bytes=[rng.uniform(0, 1e6) for _ in deps]))
    return Program(ops=ops, entry="synthetic", n_partitions=1)


def _timed(fn, ops_per_round: int, min_wall_s: float) -> dict:
    fn()                                     # warm (allocations, caches)
    n_ops = rounds = 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < min_wall_s:
        fn()
        n_ops += ops_per_round
        rounds += 1
    wall = time.perf_counter() - t0
    return {"scheduled_ops": n_ops, "rounds": rounds, "wall_s": wall,
            "ops_per_s": n_ops / wall if wall > 0 else 0.0}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--floor", type=float, default=FLOOR_OPS_PER_S,
                    help="fail if fast-kernel ops/s drops below this")
    ap.add_argument("--node-floor", type=float, default=NODE_FLOOR_OPS_PER_S,
                    help="fail if 48-core node-engine ops/s drops below this")
    ap.add_argument("--min-wall-s", type=float, default=1.0)
    args = ap.parse_args(argv)

    hw = CPU_HOST
    prog = synthetic_program()
    t0 = time.perf_counter()
    costed = cost_program(prog, hw, compute_dtype="f64")
    t_cost = time.perf_counter() - t0
    t0 = time.perf_counter()
    cp = compile_program(prog, hw, compute_dtype="f64", costed=costed)
    t_compile = time.perf_counter() - t0

    fast = _timed(lambda: schedule_arrays(cp, hw), cp.n, args.min_wall_s)
    grid = O3Knobs.from_grid(hw, [(w, mw, 1, qd)
                                  for w in (16, 256, 1024)
                                  for mw in (1, 4) for qd in (4, 64)])
    batched = _timed(lambda: schedule_batch(cp, grid),
                     cp.n * grid.batch, args.min_wall_s)
    ref = _timed(lambda: schedule_reference(prog, hw, costed=costed),
                 cp.n, args.min_wall_s)

    # node engine: 48-core contention-aware schedule on the A64FX node
    # (costing under the A64FX_CORE spec, round-robin partition; one call
    # = the full contention fixpoint)
    node_hw = A64FX_CORE
    nc = compile_node(prog, node_hw, compute_dtype="f64")
    node_last = []

    def run_node():
        node_last.append(schedule_node(nc, node_hw, NODE_CORES,
                                       partition="round-robin"))
    node = _timed(run_node, nc.n, args.min_wall_s)
    node_res = node_last[-1]

    out = {
        "program": {"n_ops": cp.n, "n_edges": cp.n_edges, "seed": 0},
        "cost_program_s": t_cost,
        "compile_program_s": t_compile,
        "fast_kernel": fast,
        "batched_kernel": {**batched, "grid_combos": grid.batch},
        "reference_interpreter": ref,
        "node_engine": {**node, "n_cores": NODE_CORES,
                        "fixpoint_iterations": node_res.iterations,
                        "t_est": node_res.t_est,
                        "t_zero_contention": node_res.t_zero_contention,
                        "floor_ops_per_s": args.node_floor},
        "speedup_fast_vs_reference":
            fast["ops_per_s"] / max(ref["ops_per_s"], 1e-9),
        "floor_ops_per_s": args.floor,
    }
    BENCH_JSON.write_text(json.dumps(out, indent=1))
    print(f"fast kernel:      {fast['ops_per_s']:>12,.0f} ops/s")
    print(f"batched kernel:   {batched['ops_per_s']:>12,.0f} ops/s "
          f"({grid.batch} combos)")
    print(f"reference interp: {ref['ops_per_s']:>12,.0f} ops/s")
    print(f"node engine:      {node['ops_per_s']:>12,.0f} ops/s "
          f"({NODE_CORES} cores, {node_res.iterations} fixpoint iters)")
    print(f"wrote {BENCH_JSON}")
    ok = True
    if fast["ops_per_s"] < args.floor:
        print(f"FAIL: fast kernel {fast['ops_per_s']:,.0f} ops/s is below "
              f"the floor of {args.floor:,.0f}")
        ok = False
    if node["ops_per_s"] < args.node_floor:
        print(f"FAIL: node engine {node['ops_per_s']:,.0f} ops/s is below "
              f"the floor of {args.node_floor:,.0f}")
        ok = False
    if not ok:
        return 1
    print(f"OK: above the {args.floor:,.0f} (fast) and "
          f"{args.node_floor:,.0f} (node) ops/s floors")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
