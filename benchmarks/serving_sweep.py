"""Serving sweep: SLO percentiles + Pareto fronts over scheduler policies.

Drives the trace-driven continuous-batching simulator (``core.serving``,
DESIGN.md §21) over zoo models x scheduler knobs.  Each model's phase
costs come from the §17 batched node engine (``build_zoo_cost_model``:
prefill µs/token + a decode-batch latency grid, disk-cached per
(arch, phase, batch) cell) and its KV working set from the REAL cache
pytree (``kv_token_bytes``); the open-loop Poisson arrival rate is set
to ``load_factor`` times the batch-1 service rate so batching headroom
is what the sweep measures.

    PYTHONPATH=src python -m benchmarks.serving_sweep          # full, needs zoo HLO
    PYTHONPATH=src python -m benchmarks.serving_sweep --quick  # synthetic, jax-free CI smoke

Full mode writes the committed ``BENCH_serving.json`` (schema: DESIGN.md
§16): per-model per-policy SLO metrics (p50/p99 TTFT, p50/p99 TPOT,
tokens/s/node) and the Pareto front over (p99 TTFT, -tokens/s).
``--quick`` writes ``BENCH_serving_quick.json`` from a synthetic cost
model — no jax, no HLO cache — and FAILS the build when the run blows
``--budget`` seconds, when batching stops paying (b=8 under 1.5x the
b=1 tokens/s), or when any run's Little's-law bookkeeping gap opens.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

from repro.core.serving import (LengthDist, ServingKnobs,
                                SyntheticCostModel, build_zoo_cost_model,
                                pareto_front, poisson_requests,
                                simulate_serving, traffic_for)

BENCH_JSON = Path("BENCH_serving.json")
QUICK_JSON = Path("BENCH_serving_quick.json")
HLO_CACHE = Path("experiments/zoo_hlo")
COST_CACHE = Path("experiments/serving_cost")
FULL_MODELS = ("chatglm3-6b", "qwen1.5-32b", "llama4-scout-17b-a16e",
               "mamba2-1.3b")
POLICIES = (
    ServingKnobs(max_batch=1),
    ServingKnobs(max_batch=8),
    ServingKnobs(max_batch=32),
    ServingKnobs(max_batch=32, admission="spf"),
    ServingKnobs(max_batch=32, prefill_chunk=256),
    ServingKnobs(max_batch=32, eviction="evict-oldest"),
)
N_REQUESTS = 600
LOAD_FACTOR = 2.5            # arrival rate as a multiple of the batch-1
                             # service rate: saturates b=1, leaves the
                             # batched policies finite headroom
SEED = 0
QUICK_BATCH_GAIN = 1.5       # b=8 must beat b=1 tokens/s by this factor


def batch1_service_time(cost, traffic: LengthDist) -> float:
    """Mean batch-1 service time: one prefill + (out-1) decode steps at
    the mean lengths — the rate anchor for the open-loop sweep."""
    p, o = traffic.prompt_mean, traffic.out_mean
    kv = cost.kv_bytes(1, p + o)
    return cost.prefill_time(int(p)) \
        + max(0.0, o - 1) * cost.decode_step_time(1, kv)


def sweep_model(cost, traffic: LengthDist, n: int, load: float,
                seed: int) -> dict:
    """Run every policy on one arrival trace; returns the per-model row
    (metrics per policy label + the Pareto front)."""
    s1 = batch1_service_time(cost, traffic)
    rate = load / s1
    reqs = poisson_requests(n, rate, traffic, seed=seed)
    metrics = {}
    for knobs in POLICIES:
        res = simulate_serving(reqs, cost, knobs)
        m = res.metrics()
        if m["little_law_gap"] >= 1e-6:
            raise SystemExit(f"Little's-law gap {m['little_law_gap']:.2e} "
                             f"at {knobs.label}: bookkeeping leak")
        metrics[knobs.label] = m
    labels = list(metrics)
    pts = [(metrics[lb]["p99_ttft_ms"], -metrics[lb]["tokens_per_s"])
           for lb in labels]
    return {
        "traffic": dataclasses.asdict(traffic),
        "rate_per_s": rate,
        "batch1_service_s": s1,
        "bytes_per_token": cost.bytes_per_token,
        "bytes_per_request": cost.bytes_per_request,
        "policies": metrics,
        "pareto": [labels[i] for i in pareto_front(pts)],
    }


def policy_rows() -> list:
    return [{"label": k.label, "max_batch": k.max_batch,
             "admission": k.admission, "prefill_chunk": k.prefill_chunk,
             "eviction": k.eviction} for k in POLICIES]


def run_quick(budget: float) -> dict:
    """Jax-free smoke: synthetic affine costs, two traffic mixes, full
    policy grid, with throughput/bookkeeping/wall gates."""
    t0 = time.perf_counter()
    # 20 kB/token keeps the mix compute-bound (realistic zoo KV scale);
    # at 1 MB/token the decode path is pure HBM streaming and batching
    # cannot pay by construction
    cost = SyntheticCostModel(prefill_t0=2e-4, prefill_per_token=1e-5,
                              decode_t0=1e-4, decode_per_seq=2e-5,
                              bytes_per_token=2e4, bytes_per_request=5e6)
    # both mixes are decode-weighted: batching only parallelizes decode
    # (prefill serializes an iteration), so a prompt-dominated mix caps
    # the b=8 gain at s1/prefill regardless of the scheduler
    mixes = {"chat": LengthDist(256, 0.8, 128, 0.6),
             "decode-heavy": LengthDist(512, 1.0, 256, 0.6)}
    models = {name: sweep_model(cost, tr, 2_000, LOAD_FACTOR, SEED)
              for name, tr in mixes.items()}
    wall = time.perf_counter() - t0
    for name, row in models.items():
        t1 = row["policies"]["fcfs_b1"]["tokens_per_s"]
        t8 = row["policies"]["fcfs_b8"]["tokens_per_s"]
        if t8 < QUICK_BATCH_GAIN * t1:
            raise SystemExit(f"{name}: b=8 tokens/s {t8:.0f} < "
                             f"{QUICK_BATCH_GAIN}x b=1 {t1:.0f}")
    if wall > budget:
        raise SystemExit(f"quick sweep took {wall:.1f}s > budget {budget}s")
    return {
        "schema": 1, "mode": "quick",
        "arrival": {"n_requests": 2_000, "load_factor": LOAD_FACTOR,
                    "seed": SEED},
        "policies": policy_rows(),
        "models": models,
        "wall_s": wall,
    }


def run_full(models, n: int) -> dict:
    t0 = time.perf_counter()
    rows = {}
    for arch in models:
        t1 = time.perf_counter()
        cost = build_zoo_cost_model(arch, hlo_cache_dir=HLO_CACHE,
                                    cost_cache_dir=COST_CACHE)
        rows[arch] = sweep_model(cost, traffic_for(arch), n,
                                 LOAD_FACTOR, SEED)
        rows[arch]["prefill_us_per_token"] = cost.prefill_per_token * 1e6
        rows[arch]["decode_grid_us"] = [[b, t * 1e6]
                                        for b, t in cost.decode_grid]
        print(f"{arch:28s} {time.perf_counter() - t1:6.1f}s  "
              f"pareto: {', '.join(rows[arch]['pareto'])}")
    return {
        "schema": 1, "mode": "full",
        "arrival": {"n_requests": n, "load_factor": LOAD_FACTOR,
                    "seed": SEED},
        "policies": policy_rows(),
        "models": rows,
        "wall_s": time.perf_counter() - t0,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="synthetic cost model, jax-free CI smoke")
    ap.add_argument("--budget", type=float, default=60.0,
                    help="--quick wall-clock budget in seconds")
    ap.add_argument("--models", nargs="*", default=list(FULL_MODELS))
    ap.add_argument("--n", type=int, default=N_REQUESTS,
                    help="requests per (model, policy) run")
    args = ap.parse_args()

    if args.quick:
        out = run_quick(args.budget)
        QUICK_JSON.write_text(json.dumps(out, indent=1))
        print(f"wrote {QUICK_JSON} ({out['wall_s']:.2f}s)")
        return

    out = run_full(args.models, args.n)
    BENCH_JSON.write_text(json.dumps(out, indent=1))
    print(f"wrote {BENCH_JSON} ({out['wall_s']:.1f}s)")
    for arch, row in out["models"].items():
        best = max(row["policies"].items(),
                   key=lambda kv: kv[1]["tokens_per_s"])
        print(f"{arch:28s} best {best[0]:22s} "
              f"{best[1]['tokens_per_s']:9.1f} tok/s  "
              f"p99 TTFT {best[1]['p99_ttft_ms']:9.1f} ms")


if __name__ == "__main__":
    main()
