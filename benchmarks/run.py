"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

* kernel_suite — Table 1 + Fig. 3 (28 kernels, simulator-vs-host accuracy
  + simulated A64FX-core throughput bars),
* triad       — Figs. 4/5 (Stream Triad thread scaling, two sizes),
* roofline    — §Roofline table from the dry-run artifacts (if present).

Prints a final ``name,us_per_call,derived`` CSV summary.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from . import kernel_suite, roofline_table, triad

OUT = Path("experiments/bench")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-triad", action="store_true")
    args = ap.parse_args(argv)

    rc = 0
    print("#" * 72)
    print("# kernel_suite (paper Table 1 + Fig. 3)")
    print("#" * 72)
    rc |= kernel_suite.main(["--quick"] if args.quick else [])

    if not args.skip_triad:
        print("\n" + "#" * 72)
        print("# triad (paper Figs. 4/5)")
        print("#" * 72)
        rc |= triad.main(["--quick"] if args.quick else [])

    print("\n" + "#" * 72)
    print("# roofline table (assignment §Roofline; from dry-run artifacts)")
    print("#" * 72)
    roofline_table.main([])          # informative; absent artifacts -> note

    # ------------------------------------------------- CSV summary
    print("\nname,us_per_call,derived")
    ks = OUT / "kernel_suite.json"
    if ks.exists():
        d = json.loads(ks.read_text())
        for row in d["rows"]:
            print(f"kernel.{row['name']},{row['measured_us']:.2f},"
                  f"diff_pct={row['diff_pct']:.1f}")
        s = d["summary"]
        print(f"kernel_suite.mean_abs_diff,,"
              f"{s['mean_abs_diff_pct']:.2f}pct_vs_paper_"
              f"{s['paper']['mean_abs_diff_pct']}pct")
        print(f"kernel_suite.within_10pct,,"
              f"{100 * s['within_10pct']:.0f}pct_vs_paper_82pct")
    tr = OUT / "triad.json"
    if tr.exists():
        d = json.loads(tr.read_text())
        for section in ("triad_l2", "triad_mem"):
            for row in d[section]:
                print(f"{section}.t{row['threads']},"
                      f"{row['measured_s'] * 1e6:.1f},"
                      f"gbps={row['measured_gbps']:.2f};"
                      f"diff_pct={row['diff_pct']:.1f}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
