"""Assemble EXPERIMENTS.md from experiment artifacts.

    PYTHONPATH=src python -m benchmarks.experiments_md

Sections §Dry-run and §Roofline are generated from experiments/dryrun/;
§Kernel-suite and §Triad from experiments/bench/; §Model-zoo from the
committed BENCH_model_zoo.json; §Sampled-zoo from the committed
BENCH_sampling.json; §Design-space from BENCH_dse.json;
§Cluster-scaling from BENCH_cluster.json; §Serving from
BENCH_serving.json; §Perf is included verbatim from
experiments/perf_log.md (the hand-written hypothesis->measure log), so
regeneration never clobbers analysis text.

EXPERIMENTS.md is COMMITTED and CI regenerates it from the committed
artifacts and fails on drift (`git diff --exit-code EXPERIMENTS.md`), so
this script must be deterministic: sections whose artifacts are not in
the repo render a stable "run X first" placeholder instead of data.
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(".")
DRY = ROOT / "experiments" / "dryrun"
BENCH = ROOT / "experiments" / "bench"
PERF_LOG = ROOT / "experiments" / "perf_log.md"
ZOO_JSON = ROOT / "BENCH_model_zoo.json"
SAMPLING_JSON = ROOT / "BENCH_sampling.json"
DSE_JSON = ROOT / "BENCH_dse.json"
CLUSTER_JSON = ROOT / "BENCH_cluster.json"
SERVING_JSON = ROOT / "BENCH_serving.json"
OUT = ROOT / "EXPERIMENTS.md"

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}
ZOO_PHASE_ORDER = {"train": 0, "prefill": 1, "decode": 2}


def _ranks(values) -> list[int]:
    """1-based rank of each value (ascending; ties broken by position)."""
    order = sorted(range(len(values)), key=lambda i: values[i])
    out = [0] * len(values)
    for rank, i in enumerate(order, 1):
        out[i] = rank
    return out


def rows_for(mesh: str):
    d = DRY / mesh
    rows = [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    return rows


def dryrun_table(mesh: str) -> str:
    rows = rows_for(mesh)
    if not rows:
        return ("_run `PYTHONPATH=src python -m repro.launch.dryrun` first "
                "(dry-run artifacts are not committed; this table fills in "
                "when they exist locally)_")
    out = ["| arch | shape | kind | chips | GFLOP/dev | GB/dev | commGB/dev "
           "| peak GiB/dev | fits 16 GiB | compile s |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        p = r["program"]
        mem = r.get("memory_analysis") or {}
        peak = (mem.get("peak_bytes_est") or 0) / 2**30
        comm = r["roofline"]["comm_bytes_per_device"]
        byts = r["roofline"]["bytes_per_device"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {r['n_chips']} "
            f"| {p['flops_per_device'] / 1e9:,.0f} | {byts / 1e9:,.1f} "
            f"| {comm / 1e9:,.2f} | {peak:.2f} "
            f"| {'Y' if r.get('fits_hbm') else 'N'} "
            f"| {r['t_compile_s']:.0f} |")
    return "\n".join(out)


def hint_of(r: dict) -> str:
    for line in r.get("pa_report", "").splitlines():
        line = line.strip()
        if line.startswith("- "):
            return line[2:].split(":")[0].split(",")[0]
    return ""


def roofline_table() -> str:
    rows = rows_for("single_pod")
    if not rows:
        return ("_run `PYTHONPATH=src python -m repro.launch.dryrun` first "
                "(see §Dry-run)_")
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| t_est s | roofline frac | MF/HLO | MXU lanes "
           "| what would move it |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rf = r["roofline"]
        t_est = r.get("engine", {}).get("t_est", 0.0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} "
            f"| {rf['memory_s']:.4f} | {rf['collective_s']:.4f} "
            f"| **{rf['dominant']}** | {t_est:.3f} "
            f"| {rf['roofline_fraction']:.2f} "
            f"| {rf['useful_flops_ratio']:.2f} "
            f"| {rf['mxu_utilization']:.2f} | {hint_of(r)} |")
    return "\n".join(out)


def kernel_section() -> str:
    p = BENCH / "kernel_suite.json"
    if not p.exists():
        return "_run `python -m benchmarks.kernel_suite` first_"
    d = json.loads(p.read_text())
    s = d["summary"]
    rows = d["rows"]
    meas_rank = _ranks([r["measured_us"] for r in rows])
    sim_rank = _ranks([r["simulated_us"] for r in rows])
    out = ["| kernel | type | measured µs | simulated µs | diff % "
           "| bound by | rank meas/sim | fit input |",
           "|---|---|---|---|---|---|---|---|"]
    for i, r in enumerate(rows):
        out.append(f"| {r['name']} | {r['type']} | {r['measured_us']:.0f} "
                   f"| {r['simulated_us']:.0f} | {r['diff_pct']:+.1f} "
                   f"| {r.get('bound_by', '—')} "
                   f"| {meas_rank[i]}/{sim_rank[i]} "
                   f"| {'*' if r.get('fit_input') else ''} |")
    out.append("")
    out.append(f"**Summary ({len(rows)} kernels):** "
               f"mean {s['mean_diff_pct']:+.1f}% · "
               f"std {s['std_diff_pct']:.1f}% · mean |diff| "
               f"{s['mean_abs_diff_pct']:.1f}% · within ±10%: "
               f"{100 * s['within_10pct']:.0f}%  — paper: +1.3% · 7.8% · "
               f"6.6% · 82%.  `rank meas/sim` orders the kernels by "
               f"measured vs simulated time (1 = fastest): agreement of "
               f"the two columns is the relative-evaluation story the "
               f"Kendall-tau test floor pins.")
    return "\n".join(out)


def zoo_section() -> str:
    if not ZOO_JSON.exists():
        return "_run `PYTHONPATH=src python -m benchmarks.model_zoo` first_"
    d = json.loads(ZOO_JSON.read_text())
    counts = d["core_counts"]
    ck = [str(c) for c in counts]
    mid = ck[len(ck) // 2]
    out = [f"| model | family | phase | ops | dominant | bound by @{mid}c "
           + "".join(f"| t_est {c}c µs " for c in ck)
           + f"| speedup {ck[0]}→{ck[-1]}c | rank @{mid}c |",
           "|---|---|---|---|---|---|" + "---|" * (len(ck) + 2)]
    models = sorted(d["models"])
    for name in models:
        m = d["models"][name]
        for phase in sorted(m["phases"],
                            key=lambda p: ZOO_PHASE_ORDER.get(p, 9)):
            ph = m["phases"][phase]
            pc = ph["per_core"]
            rank = d["rank"][phase][mid].index(name) + 1
            cells = "".join(f"| {pc[c]['t_est_us']:,.1f} " for c in ck)
            out.append(
                f"| {name} | {m['family']} | {phase} | {ph['n_ops']} "
                f"| {ph['roofline_dominant']} | {pc[mid]['bound_by']} "
                f"{cells}| ×{ph['node_speedup']:.1f} | {rank} |")
    out.append("")
    taus = []
    for phase in d["phases"]:
        t = d["kendall_tau"][phase]
        taus.append(f"{phase} τ_min={t['min']:+.2f} "
                    f"(vs FLOPs {t['vs_flops']:+.2f})")
    out.append(f"**Rank stability (Kendall τ across the core axis):** "
               f"{' · '.join(taus)} — floor 0.5 enforced by "
               f"`tests/test_zoo.py`.")
    return "\n".join(out)


def sampling_section() -> str:
    if not SAMPLING_JSON.exists():
        return ("_run `PYTHONPATH=src python -m benchmarks."
                "sampled_estimation` first_")
    d = json.loads(SAMPLING_JSON.read_text())
    dag = d["bench_dag"]
    out = [f"**Bench DAG** (sched-throughput step ×40, 48 cores): "
           f"scheduled {100 * dag['frac_ops_scheduled']:.1f}% of "
           f"{dag['n_instances']:,.0f} op instances, reconstruction error "
           f"{dag['reconstruction_error_pct']:+.3f}%, wall speedup "
           f"×{dag['speedup']:.1f} (floors: ≥×"
           f"{d['floors']['speedup']:.0f} at ≤"
           f"{100 * d['floors']['frac']:.0f}% within "
           f"{d['floors']['error_pct']:.0f}%).", ""]
    out += ["| model | phase | ops | ×rep | k/ivs | % ops sched "
            "| t_full µs | err % | wall full s | wall sampled s | budget |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    budget = d["floors"]["budget_s"]
    for arch in sorted(d["zoo"]):
        phases = d["zoo"][arch]
        for phase in sorted(phases, key=lambda p: ZOO_PHASE_ORDER.get(p, 9)):
            r = phases[phase]
            gate = ("**blown→ok**" if r["full_exceeds_budget"]
                    and r["sampled_under_budget"] else "ok")
            out.append(
                f"| {arch} | {phase} | {r['n_ops']} "
                f"| {r['trace_repeats']} | {r['k']}/{r['n_intervals']} "
                f"| {100 * r['frac_ops_scheduled']:.1f} "
                f"| {r['t_full_us']:,.1f} "
                f"| {r['reconstruction_error_pct']:+.3f} "
                f"| {r['wall_full_s']:.2f} | {r['wall_sampled_s']:.2f} "
                f"| {gate} |")
    if d.get("kernels"):
        errs = [abs(r["reconstruction_error_pct"])
                for r in d["kernels"].values()]
        fracs = [r["frac_ops_scheduled"] for r in d["kernels"].values()]
        out += ["", f"**Kernel suite** ({len(errs)} programs ×32): "
                f"max |error| {max(errs):.3f}%, fraction scheduled "
                f"{100 * min(fracs):.1f}–{100 * max(fracs):.1f}%."]
    out += ["", f"`budget` gates the *sampled* wall per phase at "
            f"{budget:.0f} s; **blown→ok** marks cells whose unsampled "
            f"pass exceeded it — the affordability claim in one column."]
    return "\n".join(out)


def dse_section() -> str:
    if not DSE_JSON.exists():
        return "_run `PYTHONPATH=src python -m benchmarks.dse_sweep` first_"
    d = json.loads(DSE_JSON.read_text())
    baseline = "c4x12_hbm1_r130_v2"          # the real A64FX grid point
    names = [p["name"] for p in d["spec_points"]]
    base_i = names.index(baseline) if baseline in names else None
    out = ["| workload | ops | best candidate | t_best µs "
           "| A64FX µs | best/A64FX | Pareto size |",
           "|---|---|---|---|---|---|---|"]
    for key in d["workloads"]:
        wl = d["per_workload"][key]
        ts = wl["t_est_s"]
        bi = names.index(wl["best_spec"])
        if base_i is not None:
            base_us = f"{ts[base_i] * 1e6:,.1f}"
            ratio = f"×{ts[base_i] / ts[bi]:.2f}"
        else:
            base_us = ratio = "—"
        out.append(f"| {key} | {wl['n_ops']} | {wl['best_spec']} "
                   f"| {ts[bi] * 1e6:,.1f} | {base_us} | {ratio} "
                   f"| {len(wl['pareto'])}/{d['n_specs']} |")
    rs = d["rank_stability"]
    out += ["", f"**Rank stability across workloads:** mean τ "
            f"{rs['mean_tau']:+.2f}, min {rs['min_tau']:+.2f} over "
            f"{len(d['workloads'])} workload pairs-of-rankings — the "
            f"candidate ordering barely depends on which model you "
            f"benchmark (floors 0.5/0.2, `tests/test_dse.py`)."]
    thr = d.get("throughput")
    if thr:
        out += ["", f"**Throughput** ({thr['workload']}, "
                f"{thr['n_specs']} candidates): fused sweep "
                f"{thr['fused_wall_s'] * 1e3:.0f} ms vs per-spec loop "
                f"{thr['loop_wall_s'] * 1e3:.0f} ms — "
                f"×{thr['speedup']:.1f}, bit-identical; CI pins ≥×"
                f"{thr['floor_speedup']:.0f} on the synthetic twin."]
    return "\n".join(out)


def cluster_section() -> str:
    if not CLUSTER_JSON.exists():
        return ("_run `PYTHONPATH=src python -m benchmarks."
                "cluster_scaling` first_")
    d = json.loads(CLUSTER_JSON.read_text())
    out = []
    for name in sorted(d["models"]):
        m = d["models"][name]
        out.append(f"**{name}**")
        out.append("")
        out.append("| nodes | best plan | t_step ms | efficiency "
                   "| tokens/s | plans priced |")
        out.append("|---|---|---|---|---|---|")
        for n in d["node_counts"]:
            s = m["scaling"].get(str(n))
            if s is None:
                continue
            priced = sum(1 for p in m["plans"].get(str(n), {}))
            out.append(f"| {n} | {s['plan']} "
                       f"| {s['t_step_us'] / 1e3:,.3f} "
                       f"| {s['parallel_efficiency']:.3f} "
                       f"| {s['tokens_per_s']:,.0f} | {priced} |")
        out.append("")
    taus = []
    for name in sorted(d["kendall_tau"]):
        t = d["kendall_tau"][name]
        taus.append(f"{name} τ_min={t['min']:+.2f}")
    out.append(f"**Plan-rank stability (Kendall τ between adjacent node "
               f"counts, common dp×tp×pp shapes):** {' · '.join(taus)} — "
               f"the winning-plan ordering survives the node-count axis, "
               f"so a cheap small-cluster sweep ranks plans for the big "
               f"machine (`tests/test_cluster.py` pins the 2-node "
               f"degenerate case bit-identical to the node engine).")
    return "\n".join(out)


def serving_section() -> str:
    if not SERVING_JSON.exists():
        return ("_run `PYTHONPATH=src python -m benchmarks."
                "serving_sweep` first_")
    d = json.loads(SERVING_JSON.read_text())
    a = d["arrival"]
    out = []
    for name in sorted(d["models"]):
        m = d["models"][name]
        tr = m["traffic"]
        out.append(f"**{name}** — λ={m['rate_per_s']:,.1f} req/s "
                   f"({a['load_factor']}× the batch-1 rate), prompts "
                   f"~{tr['prompt_mean']:,.0f} tok, outputs "
                   f"~{tr['out_mean']:,.0f} tok, KV "
                   f"{m['bytes_per_token'] / 1e3:,.1f} kB/token")
        out.append("")
        out.append("| policy | p50 TTFT ms | p99 TTFT ms | p99 TPOT ms "
                   "| tokens/s | mean batch | evict | rejected |")
        out.append("|---|---|---|---|---|---|---|---|")
        for p in d["policies"]:
            s = m["policies"][p["label"]]
            star = "**" if p["label"] in m["pareto"] else ""
            out.append(
                f"| {star}{p['label']}{star} | {s['p50_ttft_ms']:,.1f} "
                f"| {s['p99_ttft_ms']:,.1f} | {s['p99_tpot_ms']:,.2f} "
                f"| {s['tokens_per_s']:,.1f} "
                f"| {s['mean_decode_batch']:.1f} | {s['n_evictions']} "
                f"| {s['rejected']} |")
        out.append("")
    out.append("**Bold** = on the (p99 TTFT, tokens/s) Pareto front for "
               "that model.  Every run's Little's-law bookkeeping gap is "
               "< 1e-6 (the in-loop ∫N(t)dt vs summed sojourns — "
               "`tests/test_serving.py` pins it at 1e-9).")
    return "\n".join(out)


def triad_section() -> str:
    p = BENCH / "triad.json"
    if not p.exists():
        return "_run `python -m benchmarks.triad` first_"
    d = json.loads(p.read_text())
    out = []
    for name, title in (("triad_l2", "Fig. 4 analogue (cache-resident)"),
                        ("triad_mem", "Fig. 5 analogue (DRAM-resident)")):
        out.append(f"**{title}**")
        out.append("")
        out.append("| threads | measured GB/s | simulated GB/s | diff % |")
        out.append("|---|---|---|---|")
        for r in d[name]:
            out.append(f"| {r['threads']} | {r['measured_gbps']:.2f} "
                       f"| {r['simulated_gbps']:.2f} "
                       f"| {r['diff_pct']:+.1f} |")
        out.append("")
    return "\n".join(out)


HEADER = """# EXPERIMENTS

All numbers produced in this container (1-core CPU host; TPU v5e is the
*simulated target*: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI per
chip).  Reproduce with the commands shown in each section.  This file is
GENERATED by `benchmarks/experiments_md.py` from the committed artifacts;
CI fails when it drifts — regenerate instead of editing.

## §Dry-run — every (arch × shape) lowered + compiled on the production meshes

`PYTHONPATH=src python -m repro.launch.dryrun` — 32 live cells (+8 documented
skips = the 40 assigned) × 2 meshes, `.lower().compile()` through real GSPMD
partitioning with 256/512 placeholder host devices.  Quantities are
per-device, parsed from the partitioned HLO (loop trip counts multiplied;
fusion boundaries, sliced reads and in-place DUS modeled; f32 promotions of
bf16 buffers — an XLA:CPU float-normalization artifact — counted at bf16
width; see DESIGN.md §9).

`peak GiB/dev` is XLA's own buffer-assignment estimate for the *CPU*
executable: it holds f32-normalized copies of bf16 buffers, so it
over-estimates the TPU footprint by up to 2× on cache/activation-dominated
cells; cells marked `N` are therefore *conservative* fails — the §Perf log
addresses the real offenders.

### Single-pod mesh (16, 16) = 256 chips — ("data", "model")

{dry_single}

### Multi-pod mesh (2, 16, 16) = 512 chips — ("pod", "data", "model")

{dry_multi}

**Skipped cells (8, documented):** `long_500k` for the eight pure
full-attention architectures — a 500k-token KV cache across all layers
exceeds per-chip HBM (e.g. qwen1.5-110b: ≈172 GiB/sequence) and decode over
it is the degenerate port the assignment says to skip.  It **runs** for
mamba2-1.3b and zamba2-1.2b (SSM/hybrid, O(1)/O(shared) state).

## §Roofline — three-term analysis per cell (single-pod, baseline)

    compute    = HLO_FLOPs/dev   / 197 TFLOP/s     (bf16)
    memory     = HLO_bytes/dev   / 819 GB/s
    collective = comm_bytes/dev  / 50 GB/s/link

`roofline frac` = compute / max(terms) — 1.0 means compute-bound (the
ceiling for a training step).  `MF/HLO` = MODEL_FLOPS (6·N·D train,
2·N_active·D inference) / compiled HLO FLOPs — how much compiled compute is
"useful" (catches remat recompute, MoE dispatch, attention O(S²) work).
`MXU lanes` = useful-lane fraction of 128³-tile-padded matmul FLOPs (the
paper's predicate-aware SIMD counting, MXU edition).  `t_est` is the
engine's end-to-end step-time ESTIMATE (the paper's headline output:
execution time on hardware that does not exist yet) — port occupancies
composed with the configured DMA/ICI overlap factors plus per-op startup,
always ≥ the perfect-overlap roofline bound.

{roofline}

## §Kernel-suite — paper Table 1 + Fig. 3

`PYTHONPATH=src python -m benchmarks.kernel_suite`.  The host CPU plays the
A64FX test chip: the simulator consumes the *compiled HLO* of each kernel
and a **calibrated host parameter file** (the paper received Fujitsu's NDA
parameters; we fit ours: ALU rate from a Horner-16 polynomial, DRAM/LLC
stream rates from `add` at matched sizes, per-opcode latency factors with
stream time subtracted — kernels marked `*` informed the fit, the rest
are out-of-fit predictions).  The committed artifact below is the last
run that measured credibly in this container (a `--quick` subset; on a
1-core shared VM the measured side carries scheduling noise the paper's
dedicated test chip did not have, and full 28-kernel reruns under load
have produced unusable measurements — the Kendall-tau rank floor in
`tests/test_node_engine.py` gates which artifacts are committable).
It also predates the per-opcode VPU latency tables (which is why `add`
and `div` still share one simulated estimate below) and the per-row
bound-by emission — the `bound by` column shows `—` until the next
credible regeneration fills it.

{kernels}

Residual analysis (from the full 28-kernel run this subset was cut
from): the large misses are the f32→f64 converts (f2d/i2d, −44%) — the
paper's *own* outliers were the converts (d2f/d2i, which they attributed
to un-modeled write-merge) — plus `mod` (+82%, XLA emits a divide+trunc
chain the factor table double-counts).

## §Model-zoo — every registry architecture through the node engine

`PYTHONPATH=src python -m benchmarks.model_zoo` (DESIGN.md §15).  The
paper's end point: execution-cycle estimates of *one-node applications*.
Each of the 10 registry architectures is traced through its representative
phases (one train step / prefill / decode step, structure-preserving
reduced width — the full-size sharded cells are §Dry-run's job), compiled
to HLO, and scheduled by the contention-aware node engine (DESIGN.md §14)
over the A64FX topology (4 CMGs × 12 cores, shard partition) at 1 / 12 /
48 cores.  `dominant` is the roofline term; `bound by` the binding port of
the node schedule; `speedup` the 1-core / 48-core ratio (48 would be ideal;
contention and dependence chains take their cut).  Train/prefill phases
are compute-bound at toy width; decode is memory-bound — the KV-cache
stream dominates, exactly the regime the contention model is for.

{zoo}

## §Sampled-zoo — full-depth traces via SimPoint-style sampling

`PYTHONPATH=src python -m benchmarks.sampled_estimation` (DESIGN.md §18).
The §Model-zoo rows above estimate *reduced* traces (2–4 layers, one
decode step).  This section estimates **full-depth** cells — the reduced
step unrolled by the full/reduced layer ratio, and 1024 chained decode
steps — through the same `estimate_program` grid (3 core counts × 12 O3
knobs), twice: scheduling every op instance (`wall full`) and scheduling
only cluster-representative intervals (`wall sampled`), reconstructing
the full-trace estimate as the weighted blend.  `err %` is the sampled
estimate vs the unsampled one at 12 cores; `% ops sched` the fraction of
op instances actually scheduled.  CI runs the `--quick` cut of this
benchmark and fails on the floors shown.

{sampling}

## §Design-space — a 64-candidate hardware grid over the zoo

`PYTHONPATH=src python -m benchmarks.dse_sweep` (DESIGN.md §19).  The
paper's actual job — relative evaluation of processors that do not
exist — run as a sweep: 64 A64FX variants (CMG count × cores/CMG × HBM
stacks × ring latency × VPU width; the real chip is the
`c4x12_hbm1_r130_v2` grid point) priced against zoo workloads in ONE
fused spec-batched costing + contention fixpoint per program,
bit-identical to the per-spec loop it replaces.  `best/A64FX` is how
much the best candidate beats the real chip on that workload; `Pareto
size` counts the non-dominated set over (cycles, HBM bytes, cores).

{dse}

## §Cluster-scaling — dp×tp×pp plans over a 2–1024-node TofuD-style torus

`PYTHONPATH=src python -m benchmarks.cluster_scaling` (DESIGN.md §20).
The paper's machine was one node of a Tofu-connected system; this section
scales past it.  grok-1-314b (MoE) and nemotron-4-340b (dense GQA) train
steps are traced once, then every data/tensor/pipeline-parallel plan that
fits each node count gets its collectives (blocking TP all-reduces /
MoE all-to-alls, overlapped DP grad buckets, pipeline permutes) injected
into the trace as real scheduled ops, priced on a TofuD-style torus
(6 links/node, per-hop latency, link-contention fixpoint) and scheduled
through the §17 batched node engine — all plans for one dp×tp×pp shape
in ONE batch.  `efficiency` = scheduled compute floor / step time (the
all-compute-no-comm ideal is 1.0); `plans priced` counts the candidate
plans at that node count.  Pipeline depth wins first (the bubble
amortizes over 8 microbatches, beating grad-sync bytes), then the tensor
axis as pp saturates the trace depth, then dp weak-scales tokens/s.

{cluster}

## §Serving — trace-driven continuous batching with SLO percentiles

`PYTHONPATH=src python -m benchmarks.serving_sweep` (DESIGN.md §21).
Open-loop Poisson arrivals (per-model lognormal prompt/output mixes)
against an iteration-level continuous-batching scheduler on one A64FX
node: prefill and per-batch decode step costs come from the §17 node
engine (disk-cached per (arch, phase, batch) cell, scaled to the full
config by the layer ratio), and each admitted request holds its REAL
KV working set (`kv_token_bytes` of the actual cache pytree) against
node HBM, streamed at the residency level's bandwidth every decode
step.  Policies sweep max batch, FCFS vs shortest-prompt admission,
chunked prefill, and eviction (reject = oracle reservation; evict =
optimistic admission + preempt-and-re-prefill).

{serving}

## §Triad — paper Figs. 4/5

`PYTHONPATH=src python -m benchmarks.triad`.  The paper sweeps 1–12 A64FX
cores against shared L2/HBM2; the host analogue sweeps 1–12 XLA host
devices against the shared LLC/DRAM.  The simulator is the engine's
saturating-bandwidth model, parameters fitted at the sweep endpoints (the
paper's tuning step), interior points test the model.

{triad}

This container has **1 physical core**, so the measured curves saturate at
n=1 and *degrade* with oversubscription — the model (no contention term)
over-predicts by 10–35% at high thread counts.  The paper saw the same
class of error in mirror image: its simulator lacked the L2 fairness
control and *under*-predicted high-thread throughput (their Fig. 4, −30%
at 12 threads).  Scaling-regime edges are where bandwidth simulators break;
reproducing that failure mode is part of reproducing the paper.
(The multi-core node engine of DESIGN.md §14 has since added that
contention term — the §Model-zoo core-count axis above exercises it.)

## §Perf — hypothesis → change → measure log

{perf}
"""


def main() -> int:
    perf = PERF_LOG.read_text() if PERF_LOG.exists() else "_pending_"
    OUT.write_text(HEADER.format(
        dry_single=dryrun_table("single_pod"),
        dry_multi=dryrun_table("multi_pod"),
        roofline=roofline_table(),
        kernels=kernel_section(),
        zoo=zoo_section(),
        sampling=sampling_section(),
        dse=dse_section(),
        cluster=cluster_section(),
        serving=serving_section(),
        triad=triad_section(),
        perf=perf,
    ))
    print(f"wrote {OUT}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
