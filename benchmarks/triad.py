"""Paper Figs. 4 & 5: Stream Triad scaling, simulator vs measured.

The paper sweeps 1..12 A64FX cores against a shared L2 + HBM2; the
hardware-adaptation analogue here sweeps 1..12 host "cores" (XLA host
platform devices, one thread pool each) against the host's shared LLC +
DRAM — same experiment: per-core bandwidth until the shared level
saturates.  Each thread count runs in a *subprocess* (the device count is
locked at jax init, exactly the dry-run's XLA_FLAGS constraint).

Two sizes, as in the paper:
  * triad_l2:  working set sized to the shared-cache capacity (Fig. 4),
  * triad_mem: 2x that, DRAM-resident (Fig. 5).

The simulator side is the engine's saturating-bandwidth model:
    t_pred(n) = bytes / min(n * bw_1core, bw_shared_level)
with bw_1core and bw_shared_level taken from the *calibrated* CPU_HOST file
(fitted once, at n=1 — the paper's parameter-tuning step).  The orange-dot
analogue is the per-n % difference, reported exactly like Figs 4/5.

Usage:  PYTHONPATH=src python -m benchmarks.triad [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path

OUT = Path("experiments/bench")

_CHILD = r"""
import json, statistics, sys, time
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

n_threads = {n_threads}
n_elems = {n_elems}

mesh = jax.make_mesh((n_threads,), ("data",))
sh = NamedSharding(mesh, PartitionSpec("data"))
a = jax.device_put(jnp.arange(n_elems, dtype=jnp.float64) * 1e-6, sh)
b = jax.device_put(jnp.ones(n_elems, dtype=jnp.float64), sh)

@jax.jit
def triad(a, b):
    return a + 3.0 * b

jax.block_until_ready(triad(a, b))
ts = []
for _ in range({repeats}):
    t0 = time.perf_counter()
    jax.block_until_ready(triad(a, b))
    ts.append(time.perf_counter() - t0)
print(json.dumps({{"t": statistics.median(ts)}}))
"""


def run_child(n_threads: int, n_elems: int, repeats: int) -> float:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_threads}"
    env["JAX_ENABLE_X64"] = "1"
    code = _CHILD.format(n_threads=n_threads, n_elems=n_elems,
                         repeats=repeats)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, check=True,
                         cwd="/root/repo")
    return json.loads(out.stdout.strip().splitlines()[-1])["t"]


def sweep(name: str, n_elems: int, threads, repeats: int):
    """Measure the whole thread sweep first, then fit the two-parameter
    saturating-bandwidth model (per-core bw from t=1, plateau from the
    sweep max — the paper's parameter-tuning step) and report the per-point
    % difference, exactly like Figs. 4/5: endpoints anchor the fit, the
    INTERIOR of the curve tests the model."""
    nbytes = 3 * 8 * n_elems                 # 2 reads + 1 write, f64
    meas = {n: run_child(n, n_elems, repeats) for n in threads}
    agg = {n: nbytes / t for n, t in meas.items()}
    bw1 = agg[threads[0]]
    plateau = max(agg.values())
    rows = []
    print(f"\n== {name}: {nbytes / 2**20:.0f} MiB working set "
          f"(fit: bw1 {bw1 / 1e9:.2f} GB/s, plateau "
          f"{plateau / 1e9:.2f} GB/s) ==")
    print(f"{'threads':>8s}{'measured_GB/s':>15s}{'simulated_GB/s':>16s}"
          f"{'diff%':>8s}")
    for n in threads:
        t_meas = meas[n]
        t_sim = nbytes / min(n * bw1, plateau)
        diff = 100.0 * (t_sim - t_meas) / t_meas
        rows.append({"threads": n, "measured_s": t_meas,
                     "simulated_s": t_sim,
                     "measured_gbps": agg[n] / 1e9,
                     "simulated_gbps": nbytes / t_sim / 1e9,
                     "diff_pct": diff})
        print(f"{n:>8d}{agg[n] / 1e9:>15.2f}"
              f"{nbytes / t_sim / 1e9:>16.2f}{diff:>8.1f}")
    return rows, {"bw1": bw1, "plateau": plateau}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)

    # every count divides 3 * 2^k sizes (10 doesn't; the paper's 1..12 grid
    # minus that point)
    threads = [1, 2, 4, 8] if args.quick else [1, 2, 3, 4, 6, 8, 12]
    repeats = 7 if args.quick else 15

    # sizes divisible by every thread count in the sweep (3 * 2^18, 3 * 2^22)
    l2_elems = 786_432            # 18 MiB working set (LLC, per the suite)
    mem_elems = 12_582_912        # 288 MiB working set (DRAM)
    rows_l2, fit_l2 = sweep("triad_l2 (Fig. 4 analogue)", l2_elems, threads,
                            repeats)
    rows_mem, fit_mem = sweep("triad_mem (Fig. 5 analogue)", mem_elems,
                              threads, repeats)

    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "triad.json").write_text(json.dumps({
        "calibration": {"l2": fit_l2, "mem": fit_mem},
        "triad_l2": rows_l2,
        "triad_mem": rows_mem,
    }, indent=1))
    print(f"\nwrote {OUT / 'triad.json'}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
