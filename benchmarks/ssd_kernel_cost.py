"""§Perf iteration C evidence: SSD hot-loop cost, jnp lowering vs the
Pallas kernel's analytic TPU cost.

    PYTHONPATH=src python -m benchmarks.ssd_kernel_cost

Method: lower the per-device-local SSD computation (fwd + bwd, the exact
subgraph a mamba2-1.3b train_4k device executes per layer per microbatch)
through the jnp chunked path, parse its HBM traffic with the same cost
parser the dry-run uses; then compute the Pallas kernel's traffic
analytically from its BlockSpecs (grid x block bytes — on TPU each block
moves HBM->VMEM exactly once; intermediates live in VMEM).  The interpret-
mode lowering cannot stand in for Mosaic here: it emulates the grid as a
while loop with full-buffer copies per step.

The analytic block accounting is VALIDATED against the kernels' declared
BlockSpecs (the same shapes the interpret tests execute), and the kernel's
numerics are validated against the jnp oracle in tests/test_kernels.py.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.hlo import parse_program
from repro.core.hwspec import TPU_V5E

OUT = Path("experiments/bench")

# mamba2-1.3b train_4k per-device-local SSD shapes (single-pod mesh,
# microbatch 32): B = 32/16 data shards = 2, H = 64/16 model shards = 4
B, S, H, P, N, CHUNK = 2, 4096, 4, 64, 128, 256
L_LAYERS, N_MICRO = 48, 8


def jnp_ssd_traffic() -> dict:
    from repro.models.ssm import ssd_chunked

    def loss(x, dt, A, Bm, Cm):
        y, st = ssd_chunked(x, dt, A, Bm, Cm, CHUNK)
        return jnp.sum(y.astype(jnp.float32)) + jnp.sum(st.astype(jnp.float32))

    grad = jax.grad(loss, argnums=(0, 1, 2, 3, 4))
    args = (
        jax.ShapeDtypeStruct((B, S, H, P), jnp.bfloat16),
        jax.ShapeDtypeStruct((B, S, H), jnp.float32),
        jax.ShapeDtypeStruct((H,), jnp.float32),
        jax.ShapeDtypeStruct((B, S, 1, N), jnp.bfloat16),
        jax.ShapeDtypeStruct((B, S, 1, N), jnp.bfloat16),
    )
    compiled = jax.jit(grad).lower(*args).compile()
    prog = parse_program(compiled.as_text())
    return {
        "bytes": prog.bytes_normalized("bf16"),
        "flops": prog.flops,
    }


def kernel_analytic_traffic() -> dict:
    """Grid x block-boundary bytes for the fwd and bwd kernels (each block
    is DMA'd HBM->VMEM once; Q x Q intermediates never leave VMEM)."""
    nc = S // CHUNK
    grid = B * nc * H
    bf2, f4 = 2, 4
    q = CHUNK
    fwd_block = (q * P * bf2            # x in
                 + q * f4               # dt
                 + 2 * q * N * bf2      # B, C
                 + q * P * bf2          # y out
                 + N * P * f4           # state out
                 + f4)                  # gamma
    bwd_block = (q * P * bf2 * 2        # x, dy
                 + q * f4               # dt
                 + 2 * q * N * bf2      # B, C
                 + N * P * f4           # dstate in
                 + q * P * bf2          # dx out
                 + q * f4               # ddt out
                 + 2 * q * N * f4       # dB, dC out
                 + f4)                  # dA out
    # jnp-side residue: inter-chunk scan + y_off (per device, fwd+bwd ~3x)
    residue = 3 * (B * nc * H * (N * P + 1) * f4        # states, gamma
                   + B * S * H * (N + P) * bf2)         # y_off C/x traffic
    flops_block = (2 * q * q * N        # C B^T
                   + 2 * q * q * P      # M X
                   + 2 * q * N * P)     # state outer product
    bwd_flops_block = 4 * flops_block   # ~8 matmuls of the same shapes
    return {
        "bytes": grid * (fwd_block + bwd_block) + residue,
        "flops": grid * (flops_block + bwd_flops_block),
    }


def main() -> int:
    jnp_t = jnp_ssd_traffic()
    ker_t = kernel_analytic_traffic()
    scale = L_LAYERS * N_MICRO
    rows = {}
    for name, t in (("jnp_chunked", jnp_t), ("pallas_kernel", ker_t)):
        mem_s = t["bytes"] * scale / TPU_V5E.hbm_read_bw
        comp_s = t["flops"] * scale / TPU_V5E.peak_flops["bf16"]
        rows[name] = {"bytes_per_layer_mb": t["bytes"] / 2**20,
                      "flops_per_layer_gf": t["flops"] / 1e9,
                      "memory_term_s": mem_s, "compute_term_s": comp_s}
        print(f"{name:<16s} bytes/layer·mb {t['bytes'] / 2**20:9.1f} MiB  "
              f"flops {t['flops'] / 1e9:7.1f} GF  -> step memory term "
              f"{mem_s:7.3f} s  compute {comp_s:6.3f} s")
    cut = 1 - ker_t["bytes"] / jnp_t["bytes"]
    print(f"\nSSD hot-loop HBM traffic cut by the kernel: {100 * cut:.1f}%")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "ssd_kernel_cost.json").write_text(json.dumps(rows, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
