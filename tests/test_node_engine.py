"""Test harness for the multi-core node engine (ISSUE 4).

Four layers pin the node layer to the single-core kernels and to the
paper's relative-evaluation goal:

* **differential** — ``engine="node"`` with one core and a degenerate
  topology is BIT-identical to ``schedule_arrays`` (random DAGs x random
  O3 knobs, the golden HLO fixtures, and — slow-marked — every compiled
  kernel-suite program), extending ``tests/test_compiled_schedule.py``'s
  sweep pattern;
* **property** (via ``tests/_hypothesis_compat``) — node time is
  monotonically non-increasing in core count for the shard partition,
  per-core effective bandwidth is monotonically non-increasing in the
  number of active sharers, and the node makespan never beats the
  dataflow critical path (nor escapes the zero-contention/serial
  sandwich);
* **accuracy regression** — Kendall-tau rank correlation between
  ``measured_us`` and ``t_est_schedule_us`` over the pinned
  ``BENCH_kernel_suite.json`` kernels, with a floor so calibration/model
  changes that scramble the kernel ordering fail CI (the paper's goal is
  *relative* evaluation);
* **non-degeneracy** — per-OpClass VPU latencies must separate
  add/div/sqrt/atan2 estimates on the A64FX and CPU_HOST parameter
  files (the BENCH collapse of add/div/min to one t_est).
"""
import json
import random
from pathlib import Path

import pytest

from repro.core import calibrate
from repro.core.hlo import OpStat, Program, parse_program
from repro.core.hwspec import (A64FX_CORE, A64FX_NODE, CPU_HOST,
                               NodeTopology, TPU_V5E)
from repro.core.node import (compile_node, effective_bandwidth,
                             schedule_node, simulate_node)
from repro.core.schedule import schedule_program, schedule_reference
from repro.core.simulate import simulate
from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from tests.test_compiled_schedule import random_knobs, random_program
from tests.test_schedule_engine import CHAIN_HLO, INDEP_HLO

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_kernel_suite.json"
KENDALL_TAU_FLOOR = 0.5


def mem_bound_program(n_ops: int = 64, nbytes: float = 64 * 2**20) -> Program:
    """Independent DRAM-resident streaming ops: the Stream-Triad-like
    shape where bandwidth contention is the whole story."""
    ops = [OpStat(f"cp{i}", "copy", "data", "f64", bytes_accessed=nbytes,
                  read_bytes=0.75 * nbytes, write_bytes=0.25 * nbytes)
           for i in range(n_ops)]
    return Program(ops=ops, entry="e", n_partitions=1)


# ------------------------------------------------------------- differential
def _assert_node_matches_single(prog, hw):
    """n_cores=1 + degenerate topology: every partition mode replays
    schedule_arrays' float ops, so the results are bit-identical."""
    ref = schedule_program(prog, hw)
    topo = NodeTopology.degenerate(1)
    for part in ("round-robin", "graph", "shard"):
        nr = simulate_node(prog, hw, 1, topology=topo, partition=part)
        assert nr.t_est == ref.t_est, part                 # bit-identical
        assert nr.schedule.port_busy == ref.port_busy, part
        assert nr.schedule.stall_by_reason == ref.stall_by_reason, part
        assert nr.schedule.t_serial == ref.t_serial, part
        assert nr.t_zero_contention == ref.t_est, part
        assert nr.iterations == 1, part


def test_differential_one_core_random_dags_x_random_knobs():
    """Seeded sweep (the test_compiled_schedule pattern): 40 random
    (program, knob) pairs, node engine vs the single-core fast path."""
    rng = random.Random(4321)
    for _ in range(40):
        prog = random_program(rng, rng.randint(0, 48))
        _assert_node_matches_single(prog, random_knobs(rng))


def test_differential_one_core_golden_fixtures():
    for hlo in (CHAIN_HLO, INDEP_HLO):
        prog = parse_program(hlo)
        for hw in (TPU_V5E, A64FX_CORE, CPU_HOST):
            _assert_node_matches_single(prog, hw)


def test_one_core_under_own_topology_matches_when_uncontended():
    """A64FX_CORE carries the real node topology; a single core never
    saturates a shared cap, so even the non-degenerate topology keeps
    the 1-core node path bit-identical to schedule_arrays."""
    rng = random.Random(99)
    for _ in range(10):
        prog = random_program(rng, rng.randint(1, 40))
        ref = schedule_program(prog, A64FX_CORE)
        nr = simulate_node(prog, A64FX_CORE, 1, partition="round-robin")
        assert nr.t_est == ref.t_est
        assert nr.schedule.stall_by_reason == ref.stall_by_reason


@pytest.mark.slow
def test_differential_one_core_on_kernel_suite_programs():
    """Acceptance: the 1-core node path is bit-identical to the
    single-core scheduler on every compiled kernel-suite program."""
    from jax.experimental import enable_x64 as jax_enable_x64

    from repro.configs.a64fx_kernelsuite import KERNELS
    hw = CPU_HOST
    with jax_enable_x64():
        for k in KERNELS:
            x1, x2, y0 = calibrate._kernel_inputs(k, k.n)
            f = calibrate._jit_kernel(k.name)
            prog = parse_program(f.lower(x1, x2, y0).compile().as_text())
            ref = schedule_reference(prog, hw, compute_dtype="f64")
            for part in ("round-robin", "shard"):
                nr = simulate_node(prog, hw, 1,
                                   topology=NodeTopology.degenerate(1),
                                   partition=part, compute_dtype="f64")
                assert nr.t_est == ref.t_est, (k.name, part)
                assert nr.schedule.port_busy == ref.port_busy


# ----------------------------------------------------------------- property
def test_effective_bandwidth_monotone_in_sharers():
    """Per-core effective bandwidth never increases as more cores share
    a level, and never exceeds the single-core draw or the aggregate."""
    prev = None
    for n_active in range(1, 49):
        bw = effective_bandwidth(64e9, 256e9, n_active)
        assert bw <= 64e9 + 1e-9
        assert bw * n_active <= 256e9 * (1 + 1e-9)
        if prev is not None:
            assert bw <= prev + 1e-9
        prev = bw
    # no shared cap -> the per-core path, independent of sharers
    assert effective_bandwidth(64e9, None, 48) == 64e9


def test_node_time_monotone_in_core_count_shard():
    """Shard partition: more cores never hurt (each core gets 1/k of the
    work; contention can flatten but never invert the scaling)."""
    rng = random.Random(31)
    for _ in range(10):
        prog = random_program(rng, rng.randint(1, 50))
        prev = None
        for k in (1, 2, 4, 8, 16, 48):
            t = simulate_node(prog, A64FX_CORE, k, partition="shard",
                              compute_dtype="f64").t_est
            if prev is not None:
                assert t <= prev * (1 + 1e-9), k
            prev = t


def test_node_time_monotone_dependency_free_round_robin():
    """Dependency-free uniform ops, contention-free topology: round-robin
    across more cores is never slower."""
    ops = [OpStat(f"e{i}", "add", "elementwise", "f32", flops=1e9,
                  bytes_accessed=8.0) for i in range(48)]
    prog = Program(ops=ops, entry="e", n_partitions=1)
    prev = None
    for k in (1, 2, 4, 8, 16, 48):
        t = simulate_node(prog, TPU_V5E, k,
                          topology=NodeTopology.degenerate(48),
                          partition="round-robin").t_est
        if prev is not None:
            assert t <= prev * (1 + 1e-9), k
        prev = t


def test_node_never_beats_critical_path_and_sandwich():
    """t_dataflow <= t_est <= t_serial, and the contended estimate never
    undercuts the zero-contention bound, for every partition mode."""
    rng = random.Random(17)
    for _ in range(15):
        prog = random_program(rng, rng.randint(1, 50))
        base = schedule_program(prog, A64FX_CORE, compute_dtype="f64")
        for part in ("round-robin", "graph", "shard"):
            for k in (1, 5, 12, 48):
                nr = simulate_node(prog, A64FX_CORE, k, partition=part,
                                   compute_dtype="f64")
                s = nr.schedule
                assert nr.t_est >= s.t_dataflow * (1 - 1e-9), (part, k)
                assert nr.t_est <= s.t_serial * (1 + 1e-9), (part, k)
                assert nr.t_est >= nr.t_zero_contention * (1 - 1e-9)
                if part != "shard":
                    # op partitions never beat the single-core dataflow
                    # bound (sharding legitimately splits op work)
                    assert nr.t_est >= base.t_dataflow * (1 - 1e-9)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_property_node_invariants_hypothesis(seed):
    rng = random.Random(seed)
    prog = random_program(rng, rng.randint(0, 40))
    _assert_node_matches_single(prog, random_knobs(rng))
    k = rng.choice([2, 7, 12, 48])
    part = rng.choice(["round-robin", "graph", "shard"])
    nr = simulate_node(prog, A64FX_CORE, k, partition=part,
                       compute_dtype="f64")
    assert nr.t_est >= nr.schedule.t_dataflow * (1 - 1e-9)
    assert nr.t_est <= nr.schedule.t_serial * (1 + 1e-9)
    assert nr.t_est >= nr.t_zero_contention * (1 - 1e-9)


# ------------------------------------------------------ contention behaviour
def test_contention_strictly_between_bounds_on_mem_bound_program():
    """Acceptance shape: 48-core estimates sit STRICTLY between the
    zero-contention bound and the single-core time on a memory-bound
    program, for every partition mode."""
    prog = mem_bound_program()
    t1 = simulate_node(prog, A64FX_CORE, 1, partition="shard",
                       compute_dtype="f64").t_est
    for part in ("shard", "round-robin", "graph"):
        nr = simulate_node(prog, A64FX_CORE, 48, partition=part,
                           compute_dtype="f64")
        assert nr.t_zero_contention < nr.t_est < t1, part
        # the CMG's HBM2 really is saturated: >4 active streams
        assert nr.per_cmg[0].n_active["hbm2"] > 4.0, part
        # effective per-core bandwidth is the aggregate share, below the
        # single-core draw limit
        assert nr.per_cmg[0].eff_read_bw["hbm2"] < 64e9, part


def test_contention_free_when_compute_bound():
    """A compute-dominated program leaves the shared levels idle most of
    the time: the fixpoint keeps n_active ~1 and the zero-contention
    bound is tight."""
    prog = parse_program(CHAIN_HLO)
    nr = simulate_node(prog, A64FX_CORE, 48, partition="shard",
                       compute_dtype="f64")
    assert nr.t_est == pytest.approx(nr.t_zero_contention, rel=1e-6)


def test_cmg_saturation_plateau():
    """12 cores on ONE CMG (compact pinning) saturate its 256 GB/s: the
    12-core time is ~4x the 4-core time's ideal scaling continuation
    (4 cores x 64 GB/s already saturate the stack), while 48 cores reach
    4 stacks."""
    prog = mem_bound_program()
    t = {k: simulate_node(prog, A64FX_CORE, k, partition="shard",
                          compute_dtype="f64").t_est
         for k in (1, 4, 12, 48)}
    # 1->4 cores: near-linear (per-core 64 GB/s draws sum to the stack)
    assert t[4] == pytest.approx(t[1] / 4, rel=0.05)
    # 4->12 cores on the same stack: little gain (aggregate is capped)
    assert t[12] > t[4] * 0.6
    # 48 cores = 4 stacks: ~4x the 12-core (one-stack) time
    assert t[48] == pytest.approx(t[12] / 4, rel=0.15)


def test_ring_latency_charged_on_cross_cmg_edges():
    """A dependence chain split across CMGs pays the ring hop; the same
    chain on one CMG does not."""
    ops = [OpStat(f"e{i}", "add", "elementwise", "f32", flops=1e6,
                  bytes_accessed=8.0, deps=[i - 1] if i else [],
                  dep_bytes=[8.0] if i else []) for i in range(8)]
    prog = Program(ops=ops, entry="e", n_partitions=1)
    nc = compile_node(prog, A64FX_CORE)
    import numpy as np
    both = schedule_node(nc, A64FX_CORE, 24, core_of=np.array(
        [0, 12, 0, 12, 0, 12, 0, 12]))          # cores 0/12 = CMGs 0/1
    one = schedule_node(nc, A64FX_CORE, 24, core_of=np.array(
        [0, 1, 0, 1, 0, 1, 0, 1]))              # same CMG
    assert both.t_est > one.t_est
    assert both.t_est - one.t_est == pytest.approx(
        7 * A64FX_NODE.ring_latency_s, rel=1e-6)


# --------------------------------------------------------------- simulate()
def test_simulate_node_engine_api_and_report():
    prog_text = INDEP_HLO
    rep = simulate(prog_text, hw=A64FX_CORE, engine="node", n_cores=48,
                   node_partition="shard", compute_dtype="f64")
    assert rep.node is not None
    assert rep.t_est == rep.node.t_est
    assert rep.engine_mode == "node"
    assert "node engine (48 cores" in rep.pa
    assert "cmg0" in rep.pa and "cmg3" in rep.pa
    assert "zero-contention" in rep.pa
    d = json.loads(rep.to_json())
    assert d["node"]["n_cores"] == 48
    assert d["node"]["t_est"] == rep.node.t_est
    assert len(d["node"]["per_cmg"]) == 4
    # non-node modes keep the old shape
    rep_occ = simulate(prog_text, hw=A64FX_CORE, compute_dtype="f64")
    assert rep_occ.node is None
    assert "node engine" not in rep_occ.pa


def test_simulate_rejects_bad_node_args():
    with pytest.raises(ValueError):
        simulate(INDEP_HLO, hw=A64FX_CORE, engine="node", n_cores=49)
    with pytest.raises(ValueError):
        simulate(INDEP_HLO, hw=A64FX_CORE, engine="node", n_cores=2,
                 node_partition="zigzag")


@pytest.mark.slow
def test_node_acceptance_on_compiled_kernel_suite():
    """Acceptance: on real compiled suite kernels under the A64FX node
    topology, 1-core node == single-core schedule bit-for-bit (degenerate
    topo) and the 48-core estimate is strictly between the single-core
    and zero-contention bounds."""
    from jax.experimental import enable_x64 as jax_enable_x64

    from repro.configs.a64fx_kernelsuite import KERNELS_BY_NAME
    with jax_enable_x64():
        for name in ("add", "mul", "exp"):
            k = KERNELS_BY_NAME[name]
            n = k.n * calibrate.SIZE_SCALE       # DRAM-resident
            x1, x2, y0 = calibrate._kernel_inputs(k, n)
            f = calibrate._jit_kernel(name)
            prog = parse_program(f.lower(x1, x2, y0).compile().as_text())
            ref = schedule_program(prog, A64FX_CORE, compute_dtype="f64")
            nr1 = simulate_node(prog, A64FX_CORE, 1,
                                topology=NodeTopology.degenerate(1),
                                partition="shard", compute_dtype="f64")
            assert nr1.t_est == ref.t_est, name
            nr48 = simulate_node(prog, A64FX_CORE, 48, partition="shard",
                                 compute_dtype="f64")
            assert nr48.t_zero_contention < nr48.t_est < nr1.t_est, name


# ------------------------------------------------------- accuracy (Kendall)
# one tau-b implementation serves the whole repo (self-checked in
# tests/test_zoo.py alongside the model-zoo rank-stability floor)
from repro.core.zoo import kendall_tau as kendall_tau_b  # noqa: E402


def test_kendall_tau_rank_floor_on_bench_artifact():
    """The paper's goal is accuracy sufficient for RELATIVE evaluation:
    the schedule engine must rank the suite kernels like the test chip
    does.  Pinned floor on Kendall-tau over BENCH_kernel_suite.json so a
    calibration/model change that scrambles the ordering fails CI."""
    if not BENCH_JSON.exists():
        pytest.skip("BENCH_kernel_suite.json not generated")
    data = json.loads(BENCH_JSON.read_text())
    kernels = data["kernels"]
    assert len(kernels) >= 5, "bench artifact too small to rank"
    measured = [v["measured_us"] for v in kernels.values()]
    estimated = [v["t_est_schedule_us"] for v in kernels.values()]
    tau = kendall_tau_b(measured, estimated)
    assert tau >= KENDALL_TAU_FLOOR, (
        f"Kendall-tau {tau:.3f} below the {KENDALL_TAU_FLOOR} floor: the "
        f"model no longer ranks kernels like the measurements do")




# ------------------------------------------- per-OpClass VPU non-degeneracy
def _suite_like_op(name, opclass, n, trans_opcode=None, vpu_opcode=None):
    kw = {}
    if trans_opcode:
        kw = {"transcendentals": float(n),
              "trans_by_opcode": {trans_opcode: float(n)}}
    elif vpu_opcode:
        kw = {"vpu_by_opcode": {vpu_opcode: float(n)}}
    return OpStat(name, name, opclass, "f64", flops=float(n),
                  bytes_accessed=24.0 * n, read_bytes=16.0 * n,
                  write_bytes=8.0 * n, **kw)


@pytest.mark.parametrize("hw", [A64FX_CORE, CPU_HOST],
                         ids=["a64fx_core", "cpu_host"])
def test_opclass_estimates_not_degenerate(hw):
    """Fix for the BENCH collapse (add/div/min at one identical t_est):
    the per-opcode VPU latency tables must separate the op classes."""
    n = 2048 * 8                       # Table-1 scale: cache-resident
    kernels = {
        "add": _suite_like_op("add", "elementwise", n, vpu_opcode="add"),
        "min": _suite_like_op("min", "elementwise", n,
                              vpu_opcode="minimum"),
        "div": _suite_like_op("div", "transcendental", n,
                              trans_opcode="divide"),
        "sqrt": _suite_like_op("sqrt", "transcendental", n,
                               trans_opcode="sqrt"),
        "exp": _suite_like_op("exp", "transcendental", n,
                              trans_opcode="exponential"),
        "atan2": _suite_like_op("atan2", "transcendental", n,
                                trans_opcode="atan2"),
    }
    t = {name: schedule_program(Program([op], "e", 1), hw,
                                compute_dtype="f64").t_est
         for name, op in kernels.items()}
    distinct = len(set(t.values()))
    assert distinct >= 4, t
    # the unpipelined/libm classes are strictly slower than streaming add
    assert t["div"] > t["add"]
    assert t["sqrt"] > t["add"]
    assert t["atan2"] > t["add"]
    # and the table separates them from each other
    assert t["div"] != t["atan2"]


def test_vpu_by_opcode_survives_fusion_and_is_neutral_without_factors():
    """The parser records elementwise opcode counts; a spec without
    factor entries costs them exactly as before (bit-for-bit)."""
    prog = parse_program(CHAIN_HLO)
    by_name = {o.name: o for o in prog.ops}
    assert by_name["neg"].vpu_by_opcode.get("negate") == 4096 * 4096
    # TPU_V5E has no opcode_factor entries: unchanged costing
    assert not TPU_V5E.opcode_factor
    r = schedule_program(prog, TPU_V5E)
    assert r.t_est > 0


# ----------------------------------------------------- sweep core-count axis
def test_sweep_o3_core_count_axis():
    """core_counts adds the node engine's core count to the sweep grid;
    the n_cores=1 rows are exactly the old single-core sweep."""
    rng = random.Random(5)
    programs = [random_program(rng, 30) for _ in range(2)]
    rows = [calibrate.KernelRow(f"p{i}", "synth", 1, measured_us=50.0,
                                simulated_us=50.0)
            for i in range(len(programs))]
    table = calibrate.AccuracyTable(rows, programs=programs)
    hw = A64FX_CORE
    kw = dict(windows=(4, 64), mem_widths=(1, 2), vpu_widths=(1,),
              queue_depths=(4,))
    single = calibrate.sweep_o3(table, hw, **kw)
    multi = calibrate.sweep_o3(table, hw, core_counts=(1, 12), **kw)
    assert {r["n_cores"] for r in multi.results} == {1, 12}
    assert len(multi.results) == 2 * len(single.results)
    key = lambda r: (r["inflight_window"], r["mem_issue_width"],   # noqa: E731
                     r["queue_depth"])
    ours = {key(r): r["mean_abs_diff_pct"] for r in multi.results
            if r["n_cores"] == 1}
    for r in single.results:
        assert ours[key(r)] == pytest.approx(r["mean_abs_diff_pct"],
                                             rel=1e-12)
    # best is picked among the smallest core count (measured data is
    # single-core)
    assert multi.best.inflight_window in (4, 64)


def test_node_perf_smoke_program_schedules_deterministically():
    from benchmarks.sched_throughput import NODE_CORES, synthetic_program
    prog = synthetic_program(n=300, seed=0)
    nc = compile_node(prog, A64FX_CORE, compute_dtype="f64")
    a = schedule_node(nc, A64FX_CORE, NODE_CORES, partition="round-robin")
    b = schedule_node(nc, A64FX_CORE, NODE_CORES, partition="round-robin")
    assert a.t_est == b.t_est
    assert a.iterations == b.iterations
    assert a.t_zero_contention <= a.t_est * (1 + 1e-9)
