"""Elastic-scaling dry-run: the same cell compiles on a DEGRADED mesh.

A production job that loses a pod slice must restart on fewer chips (the
checkpoint layer already reshards state — test_checkpoint_fault).  This
test proves the sharding rules are elastic at the compile level: the same
(arch x shape) lowers and compiles on a half-pod (8, 16) = 128-chip mesh
with no code changes — only the mesh tuple differs.

Runs in a subprocess because the forced device count locks at jax init.
"""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow        # 128-device recompile in a subprocess

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=128"
import json
import jax
from repro.launch.cell import build_cell

mesh = jax.make_mesh((8, 16), ("data", "model"), devices=jax.devices())
cell = build_cell("{arch}", "{shape}", mesh)
compiled = cell.lower().compile()
m = compiled.memory_analysis()
print(json.dumps({{"ok": True,
                   "temp_gb": m.temp_size_in_bytes / 2**30}}))
"""


@pytest.mark.parametrize("arch,shape", [
    ("qwen1.5-110b", "train_4k"),
    ("mamba2-1.3b", "decode_32k"),
])
def test_cell_compiles_on_degraded_half_pod(arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    code = _CHILD.format(arch=arch, shape=shape)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["ok"]
