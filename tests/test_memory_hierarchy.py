"""Tests for the multi-level memory-hierarchy model and the unified cost
pipeline (ISSUE 2): reuse-distance routing, read/write asymmetry, residency
monotonicity, single-pass costing for engine="both", and the preserved
schedule-engine sandwich invariant under the new cost layer.
"""
import pytest

from repro.core.engine import simulate_program
from repro.core.hlo import OpStat, Program, parse_program
from repro.core.hwspec import (A64FX_CMG, A64FX_CORE, CPU_HOST, SPECS,
                               TPU_V5E)
from repro.core.cost import cost_program
from repro.core.memory import MemLevel, residency_level, route_program
from repro.core.schedule import schedule_program
from repro.core.simulate import simulate

CHAIN_HLO = """
HloModule chain, num_partitions=1

ENTRY %main (p0: f32[4096,4096]) -> f32[4096,4096] {
  %p0 = f32[4096,4096] parameter(0)
  %dot = f32[4096,4096] dot(%p0, %p0), lhs_contracting_dims={1}
  %e = f32[4096,4096] exponential(%dot)
  %dot2 = f32[4096,4096] dot(%e, %e), lhs_contracting_dims={1}
  ROOT %neg = f32[4096,4096] negate(%dot2)
}
"""

INDEP_HLO = """
HloModule indep, num_partitions=1

ENTRY %main (p0: f32[4096,4096], p1: f32[134217728]) -> (f32[4096,4096], f32[134217728]) {
  %p0 = f32[4096,4096] parameter(0)
  %p1 = f32[134217728] parameter(1)
  %big = f32[134217728] copy(%p1)
  %dot = f32[4096,4096] dot(%p0, %p0), lhs_contracting_dims={1}
  ROOT %t = (f32[4096,4096], f32[134217728]) tuple(%dot, %big)
}
"""

MIB = float(2**20)


def _data_op(name, rd, wr, deps=(), dep_bytes=()):
    return OpStat(name, "copy", "data", "f32", bytes_accessed=rd + wr,
                  read_bytes=rd, write_bytes=wr, deps=list(deps),
                  dep_bytes=list(dep_bytes))


# ------------------------------------------------- satellite: bound_by fix
def test_empty_program_bound_by_is_mem():
    """EngineResult.bound_by used to raise ValueError (max over an empty
    port_busy dict) — it must match ScheduleResult.bound_by's fallback."""
    prog = Program(ops=[], entry="e", n_partitions=1)
    eng = simulate_program(prog, TPU_V5E)
    sched = schedule_program(prog, TPU_V5E)
    assert eng.bound_by == "mem"
    assert sched.bound_by == "mem"
    assert eng.t_est == 0.0


# ------------------------------------------------------- hierarchy routing
def test_parser_records_dep_bytes():
    prog = parse_program(CHAIN_HLO)
    by_name = {o.name: o for o in prog.ops}
    assert by_name["e"].dep_bytes == [4096 * 4096 * 4.0]
    assert len(by_name["neg"].deps) == len(by_name["neg"].dep_bytes) == 1
    # read/write split covers the old aggregate
    for o in prog.ops:
        assert o.read_bytes + o.write_bytes == pytest.approx(o.bytes_accessed)


def test_residency_level_picks_innermost_fit():
    levels = TPU_V5E.memory_hierarchy()
    assert residency_level(levels, 1024).name == "vmem"
    assert residency_level(levels, 1e9).name == "hbm"
    # over-capacity traffic backstops at the outermost level
    assert residency_level(levels, 1e15).name == "hbm"


def test_reuse_distance_routes_recent_producer_to_inner_level():
    """An operand produced just before its consumer is VMEM-resident; the
    same edge with a gigabyte of intervening writes has fallen to HBM."""
    producer = _data_op("w", 0.0, 64 * MIB)
    near = _data_op("r", 64 * MIB, MIB, deps=[0], dep_bytes=[64 * MIB])
    filler = _data_op("f", 0.0, 1024 * MIB)
    far = _data_op("r2", 64 * MIB, MIB, deps=[0], dep_bytes=[64 * MIB])

    tr_near = route_program(Program([producer, near], "e", 1),
                            TPU_V5E.memory_hierarchy())
    tr_far = route_program(Program([producer, filler, far], "e", 1),
                           TPU_V5E.memory_hierarchy())
    assert tr_near[1].read_by_level == {"vmem": 64 * MIB}
    assert tr_far[2].read_by_level == {"hbm": 64 * MIB}
    assert tr_far[2].t_read > tr_near[1].t_read


def test_residency_monotonic_shrinking_l2_never_speeds_up():
    """Satellite: shrinking the mid level monotonically (weakly) increases
    t_est for BOTH engines."""
    base_levels = lambda cap: (                                 # noqa: E731
        MemLevel("l1", 64 * 2**10, 4e11, 2e11),
        MemLevel("l2", cap, 1e11, 5e10),
        MemLevel("hbm", 16 * 2**30, 2e10, 1e10),
    )
    prog = parse_program(CHAIN_HLO)
    synth = Program(
        [_data_op("w", 0.0, 4 * MIB),
         _data_op("r", 4 * MIB, 4 * MIB, deps=[0], dep_bytes=[4 * MIB]),
         _data_op("r2", 8 * MIB, 2 * MIB, deps=[1], dep_bytes=[4 * MIB])],
        "e", 1)
    for p in (prog, synth):
        prev_occ = prev_sched = 0.0
        for cap in (64 * MIB, 8 * MIB, 2 * MIB, 64 * 2**10):
            hw = TPU_V5E.with_(vmem_bytes=64 * 2**10, vmem_bw=4e11,
                               hbm_read_bw=2e10, hbm_write_bw=1e10,
                               mem_levels=base_levels(cap),
                               warm_caches=True)
            occ = simulate_program(p, hw).t_est
            sched = schedule_program(p, hw).t_est
            assert occ >= prev_occ - 1e-15
            assert sched >= prev_sched - 1e-15
            prev_occ, prev_sched = occ, sched


def test_a64fx_core_store_heavy_slower_than_load_heavy_mirror():
    """Satellite: the paper's asymmetric L1 ports (load >230, store >115
    GB/s per core) — mirroring reads<->writes must slow the store-heavy op
    at EVERY level of the A64FX_CORE hierarchy."""
    for total in (48 * 2**10, 4 * MIB, 512 * MIB):   # L1-, L2-, HBM-resident
        loads = Program([_data_op("l", 0.75 * total, 0.25 * total)], "e", 1)
        stores = Program([_data_op("s", 0.25 * total, 0.75 * total)], "e", 1)
        t_load = simulate_program(loads, A64FX_CORE).t_est
        t_store = simulate_program(stores, A64FX_CORE).t_est
        assert t_store > t_load


def test_hbm_write_bw_affects_estimate():
    """Acceptance: halving hbm_write_bw on a store-heavy program increases
    the estimate — on a derived hierarchy (TPU) AND on an explicit
    mem_levels hierarchy (A64FX), where with_() rewrites the outer level."""
    store_heavy = Program([_data_op("s", 1e6, 1e9)], "e", 1)
    for hw in (TPU_V5E, A64FX_CMG):
        halved = hw.with_(hbm_write_bw=hw.hbm_write_bw / 2)
        t0 = simulate_program(store_heavy, hw).t_est
        t1 = simulate_program(store_heavy, halved).t_est
        assert t1 > t0
        s0 = schedule_program(store_heavy, hw).t_est
        s1 = schedule_program(store_heavy, halved).t_est
        assert s1 > s0


def test_cache_model_flag_is_gone():
    for hw in SPECS.values():
        assert not hasattr(hw, "cache_model")


def test_all_specs_have_monotone_hierarchies():
    """The §12 contract: per-path bandwidths never increase outward, and
    capacities grow outward — otherwise falling out of a level could
    speed an op up."""
    for hw in SPECS.values():
        levels = hw.memory_hierarchy()
        for a, b in zip(levels, levels[1:]):
            assert a.read_bw >= b.read_bw, (hw.name, a.name, b.name)
            assert a.write_bw >= b.write_bw, (hw.name, a.name, b.name)
            assert a.capacity <= b.capacity, (hw.name, a.name, b.name)


def test_with_preserves_l1_load_store_asymmetry():
    """Regression: with_() on a scalar must rewrite ONLY the matching
    level fields — shrinking L1 capacity must not flatten the 230/115
    load/store ports back to the symmetric vmem_bw scalar (which would
    make a store-heavy program FASTER after shrinking the cache)."""
    shrunk = A64FX_CORE.with_(vmem_bytes=32 * 2**10)
    l1 = shrunk.memory_hierarchy()[0]
    assert l1.capacity == 32 * 2**10
    assert l1.read_bw == 230e9 and l1.write_bw == 115e9
    store_heavy = Program([_data_op("s", 12 * 2**10, 36 * 2**10)], "e", 1)
    assert simulate_program(store_heavy, shrunk).t_est \
        >= simulate_program(store_heavy, A64FX_CORE).t_est - 1e-15


def test_tpu_cold_reads_stream_from_hbm():
    """Regression: TPU VMEM is software-managed scratch, not a warm cache
    — a VMEM-sized op with no producers must still be charged at HBM
    bandwidth (weights stream from HBM every step); only CPU/A64FX
    (warm_caches=True) apply the working-set rule to cold traffic."""
    assert not TPU_V5E.warm_caches and CPU_HOST.warm_caches
    op = _data_op("w", 100 * MIB, 0.0)            # fits 128 MiB VMEM
    eng = simulate_program(Program([op], "e", 1), TPU_V5E)
    assert eng.traffic_by_level == {"hbm": {"read_bytes": 100 * MIB,
                                            "write_bytes": 0.0}}
    assert eng.port_busy["mem"] == pytest.approx(
        100 * MIB / TPU_V5E.hbm_read_bw, rel=1e-9)
    # the same op on the warm-cache host routes to the level it fits
    small = _data_op("w", 8 * MIB, 0.0)           # fits the 32 MiB LLC
    eng_cpu = simulate_program(Program([small], "e", 1), CPU_HOST)
    assert list(eng_cpu.traffic_by_level) == ["vmem"]


# ------------------------------------------- unified cost pipeline sharing
def test_simulate_both_costs_each_op_exactly_once(monkeypatch):
    """Satellite: engine="both" must not double-cost the program; both
    engines consume one shared costed list and agree on serial time."""
    import repro.core.cost as cost_mod
    calls = {"n": 0}
    real = cost_mod.cost_op

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(cost_mod, "cost_op", counting)
    rep = simulate(INDEP_HLO, hw=TPU_V5E, engine="both")
    assert calls["n"] == len(rep.program.ops)
    assert rep.schedule is not None
    # parity: the two engines saw identical per-op costs
    assert rep.schedule.t_serial == pytest.approx(rep.engine.t_serial,
                                                  rel=1e-9)
    assert rep.schedule.n_ops == rep.engine.n_ops


def test_shared_costed_list_matches_fresh_costing():
    prog = parse_program(CHAIN_HLO)
    costed = cost_program(prog, TPU_V5E)
    assert schedule_program(prog, TPU_V5E, costed=costed).t_est \
        == pytest.approx(schedule_program(prog, TPU_V5E).t_est, rel=1e-12)
    assert simulate_program(prog, TPU_V5E, costed=costed).t_est \
        == pytest.approx(simulate_program(prog, TPU_V5E).t_est, rel=1e-12)


# --------------------------------------------------- invariants + reporting
def test_sandwich_invariant_under_hierarchy_cost_layer():
    """t_roofline <= t_est(schedule) <= t_serial survives the new cost
    layer on every parameter file."""
    for hlo in (CHAIN_HLO, INDEP_HLO):
        prog = parse_program(hlo)
        for hw in (TPU_V5E, A64FX_CMG, A64FX_CORE, CPU_HOST):
            r = schedule_program(prog, hw)
            assert r.t_roofline <= r.t_est * (1 + 1e-9)
            assert r.t_est <= r.t_serial * (1 + 1e-9)


def test_pa_report_has_per_level_traffic_section():
    rep = simulate(INDEP_HLO, hw=TPU_V5E, engine="both")
    assert "memory hierarchy (routed traffic | residency)" in rep.pa
    assert "hbm" in rep.pa
    # engine result carries the aggregated per-level bytes
    total = sum(a["read_bytes"] + a["write_bytes"]
                for a in rep.engine.traffic_by_level.values())
    assert total > 0
    import json
    d = json.loads(rep.to_json())
    assert d["engine"]["traffic_by_level"]
