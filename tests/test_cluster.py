"""Cluster engine + unified collective model: differentials and guards.

Pins the PR-9 contracts (DESIGN.md §20):

* ONE collective byte-math implementation — ``parallel.collectives``
  delegates to ``core.cost.collective_factor``/``collective_links``, and
  the old wire-bytes table is replicated INLINE here to prove the
  unification preserved every number bit-for-bit;
* ``CollectiveCost.t_seconds`` matches ``cost_op``'s collective branch
  (permute single-link, zero-payload and zero-bandwidth conventions);
* ``axis_size`` raises on unknown axes (the silent group-size-1 bug),
  ``grad_sync_bytes`` takes the axis as a parameter;
* ``launch.mesh`` under/over-provision guards;
* the 2-node degenerate cluster is bit-identical to a node-engine run of
  the same program plus the canonical link cost of its one collective —
  in BOTH the 1-core/real-topology and 48-core/degenerate-topology
  shapes (the latter pins the collective-time-is-not-sharded fix in
  ``core.node``).
"""
import dataclasses
import json
import math
import warnings

import numpy as np
import pytest

from repro.core.cluster import (ClusterWorkload, CollectiveSite,
                                ParallelPlan, ShardDecision, _coll,
                                _inject, axis_hops, cluster_sweep,
                                collective_time, make_cluster_program,
                                node_coords, plan_shapes,
                                schedule_cluster, torus_distance)
from repro.core.cost import (collective_factor, collective_links,
                             collective_steps, cost_op)
from repro.core.hlo import OpStat, Program
from repro.core.hwspec import A64FX_CORE, ClusterTopology, NodeTopology
from repro.core.node import compile_node, schedule_node

KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")
GROUPS = (1, 2, 4, 48)
PAYLOAD = 3.7e6


def _old_wire_bytes(kind: str, g: int, payload: float) -> float:
    """The pre-unification ``CollectiveCost.wire_bytes`` table, verbatim
    (PR-9 deleted it from ``parallel.collectives``; this inline copy is
    the proof the canonical model preserved its numbers)."""
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * payload
    if kind == "all-gather":
        return (g - 1) * payload
    if kind == "reduce-scatter":
        return (g - 1) / g * payload
    if kind == "all-to-all":
        return (g - 1) / g * payload
    if kind == "collective-permute":
        return payload
    return payload


class _Devices:
    def __init__(self, shape):
        self.shape = shape


class _Mesh:
    """Duck-typed mesh: ``axis_size``/``grad_sync_bytes`` only read
    ``axis_names`` + ``devices.shape``."""

    def __init__(self, names, shape):
        self.axis_names = names
        self.devices = _Devices(shape)


# ------------------------------------------------ one collective model
class TestCollectiveParity:
    def test_wire_bytes_bit_identical_to_old_table(self):
        from repro.parallel.collectives import CollectiveCost
        for kind in KINDS + ("weird-op",):
            for g in GROUPS:
                cc = CollectiveCost(kind, g, PAYLOAD, link_bw=6.8e9)
                old = _old_wire_bytes(kind, g, PAYLOAD)
                assert cc.wire_bytes == old, (kind, g)
                assert cc.wire_bytes == \
                    collective_factor(kind, g) * PAYLOAD

    def test_t_seconds_matches_cost_op(self):
        """The veneer and the engine charge the same seconds — including
        the permute fix (1 link, not the 2-link ring credit) and the
        startup term, for every kind x group."""
        from repro.parallel.collectives import CollectiveCost
        hw = A64FX_CORE
        for kind in KINDS:
            for g in GROUPS:
                for payload in (PAYLOAD, 0.0):
                    o = OpStat(name="c", opcode=kind,
                               opclass="collective", dtype="f32",
                               comm_bytes=payload, group_size=g)
                    ot = cost_op(o, hw, ici_bw=2 * hw.ici_bw_per_link)
                    cc = CollectiveCost(kind, g, payload,
                                        link_bw=hw.ici_bw_per_link,
                                        links=2,
                                        startup_us=hw.collective_startup_us)
                    assert cc.t_seconds == ot.t_ici, (kind, g, payload)

    def test_permute_gets_one_link(self):
        from repro.parallel.collectives import CollectiveCost
        ar = CollectiveCost("all-reduce", 2, PAYLOAD, link_bw=1e9)
        pm = CollectiveCost("collective-permute", 2, PAYLOAD, link_bw=1e9)
        assert ar.wire_bytes == pm.wire_bytes    # 2(g-1)/g == 1 at g=2
        assert pm.t_seconds == 2.0 * ar.t_seconds
        assert collective_links("collective-permute", 2) == 1
        for kind in KINDS[:-1]:
            assert collective_links(kind, 2) == 2

    def test_zero_shortcircuits(self):
        from repro.parallel.collectives import CollectiveCost
        # g=1 and zero payload: startup only, even at zero bandwidth
        for kind in KINDS:
            assert CollectiveCost(kind, 1, PAYLOAD, 0.0,
                                  startup_us=7.0).t_seconds == 7.0e-6
            assert CollectiveCost(kind, 8, 0.0, 0.0,
                                  startup_us=7.0).t_seconds == 7.0e-6
        # a real payload over a dead link is infeasible, not a crash
        t = CollectiveCost("all-reduce", 8, PAYLOAD, 0.0).t_seconds
        assert math.isinf(t)

    def test_collective_steps(self):
        assert collective_steps("all-reduce", 8) == 14
        assert collective_steps("all-gather", 8) == 7
        assert collective_steps("reduce-scatter", 8) == 7
        assert collective_steps("collective-permute", 8) == 1
        for kind in KINDS:
            assert collective_steps(kind, 1) == 0


# --------------------------------------------- mesh veneer de-bugged
class TestAxisSizeGradSync:
    def test_axis_size_known(self):
        from repro.parallel.collectives import axis_size
        m = _Mesh(("data", "model"), (4, 16))
        assert axis_size(m, "data") == 4
        assert axis_size(m, "model") == 16

    def test_axis_size_unknown_raises(self):
        """The old ``.get(name, 1)`` priced typo'd axes as free."""
        from repro.parallel.collectives import axis_size
        m = _Mesh(("data", "model"), (4, 16))
        with pytest.raises(KeyError, match="no axis 'pod'.*data.*model"):
            axis_size(m, "pod")

    def test_axis_size_default_opt_in(self):
        from repro.parallel.collectives import axis_size
        m = _Mesh(("data", "model"), (4, 16))
        assert axis_size(m, "pod", default=1) == 1
        assert axis_size(m, "model", default=1) == 16

    def test_grad_sync_axis_param(self):
        from repro.parallel.collectives import grad_sync_bytes
        pb = 1e9
        multi = _Mesh(("pod", "data", "model"), (2, 16, 16))
        single = _Mesh(("data", "model"), (16, 16))
        d = grad_sync_bytes(pb, multi)                   # default "pod"
        assert d["all_reduce"] == 2.0 * (2 - 1) / 2 * pb
        assert 0.0 < d["compressed"] < d["all_reduce"]
        # the same math rides any named axis now
        g = 16
        d2 = grad_sync_bytes(pb, single, axis="data")
        assert d2["all_reduce"] == 2.0 * (g - 1) / g * pb
        # a missing axis raises instead of silently reporting zero
        with pytest.raises(KeyError):
            grad_sync_bytes(pb, single)


class TestMeshGuards:
    def test_under_provision_raises(self):
        from repro.launch.mesh import _take_devices
        with pytest.raises(RuntimeError,
                           match=r"need 6 devices for mesh \(2, 3\), "
                                 r"have 4"):
            _take_devices(list(range(4)), 6, (2, 3))

    def test_over_provision_warns_and_slices(self):
        from repro.launch.mesh import _take_devices
        with pytest.warns(RuntimeWarning, match=r"uses 4 of 7 devices.*"
                                                r"3 are idle"):
            got = _take_devices(list(range(7)), 4, (2, 2))
        assert got == [0, 1, 2, 3]

    def test_exact_provision_silent(self):
        from repro.launch.mesh import _take_devices
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert _take_devices(list(range(4)), 4, (2, 2)) == \
                [0, 1, 2, 3]

    def test_production_mesh_error_message(self):
        """The dry-run's one actionable failure names the fix."""
        from repro.launch.mesh import make_production_mesh
        with pytest.raises(RuntimeError, match="XLA_FLAGS"):
            make_production_mesh(devices=[object()] * 3)

    def test_host_mesh_guarded(self):
        from repro.launch.mesh import make_host_mesh
        with pytest.raises(RuntimeError, match="need 64 devices"):
            make_host_mesh(8, 8)


# ------------------------------------------------- link tier geometry
class TestTopologyGeometry:
    def test_tofu_d_near_cubic(self):
        assert ClusterTopology.tofu_d(2).mesh_shape == (1, 1, 2)
        assert ClusterTopology.tofu_d(64).mesh_shape == (4, 4, 4)
        assert ClusterTopology.tofu_d(1024).mesh_shape == (8, 8, 16)
        for n in (2, 4, 8, 16, 32, 64, 128, 256, 512, 1024):
            c = ClusterTopology.tofu_d(n)
            assert c.n_nodes == n
            assert math.prod(c.mesh_shape) == n

    def test_torus_distance_wraps(self):
        c = ClusterTopology.tofu_d(64)              # (4, 4, 4)
        ids = np.arange(64).reshape(4, 4, 4)
        # last-dim neighbours are 1 hop, incl. the wraparound pair
        assert torus_distance(c, ids[0, 0, 0], ids[0, 0, 3]) == 1
        assert torus_distance(c, ids[0, 0, 0], ids[0, 0, 2]) == 2
        # the far corner: 2 hops per dimension through the torus
        assert torus_distance(c, ids[0, 0, 0], ids[2, 2, 2]) == 6
        assert node_coords(c, 63).tolist() == [3, 3, 3]

    def test_axis_hops_placement(self):
        c = ClusterTopology.tofu_d(64)
        h = axis_hops(c, ParallelPlan(dp=4, tp=4, pp=4))
        # tp is the fastest logical axis -> nearest-neighbour ring
        assert h["tp"] == 1.0
        assert h["dp"] >= 1.0 and h["pp"] >= 1.0
        # unused axes cost nothing
        h1 = axis_hops(c, ParallelPlan(dp=64, tp=1, pp=1))
        assert h1["tp"] == 0.0 and h1["pp"] == 0.0
        with pytest.raises(ValueError, match="places 8 nodes"):
            axis_hops(c, ParallelPlan(dp=8, tp=1, pp=1))

    def test_collective_time_conventions(self):
        c = ClusterTopology.tofu_d(8)
        t1 = collective_time("all-reduce", 8, PAYLOAD, c)
        # more bytes, more hops, more concurrent streams: all slower
        assert collective_time("all-reduce", 8, 2 * PAYLOAD, c) > t1
        assert collective_time("all-reduce", 8, PAYLOAD, c, hops=2.0) > t1
        # contention bites only past links_per_node / ring links = 3
        # concurrent streams (below that the 2-link draw is the limiter)
        assert collective_time("all-reduce", 8, PAYLOAD, c,
                               n_active=3.0) == t1
        assert collective_time("all-reduce", 8, PAYLOAD, c,
                               n_active=6.0) > t1
        # g<=1 and zero payload: latency only
        lat = c.collective_startup_us * 1e-6
        assert collective_time("all-reduce", 1, PAYLOAD, c) == lat
        assert collective_time("all-reduce", 8, 0.0, c) > lat  # steps
        dead = dataclasses.replace(c, link_bw=0.0)
        assert math.isinf(collective_time("all-reduce", 8, PAYLOAD, dead))


# --------------------------------------------------- program building
def _base_program(n: int = 40) -> Program:
    from benchmarks.sched_throughput import synthetic_program
    return synthetic_program(n, seed=3)


def _workload(prog: Program) -> ClusterWorkload:
    return ClusterWorkload(name="t", prog=prog, repeats=8, layers=2,
                           d_model=256, seq_len=64, batch=2,
                           param_bytes=1e8, frac_attn=0.4, moe_top_k=2)


class TestMakeClusterProgram:
    def test_structure_and_deps(self):
        w = _workload(_base_program())
        prog, sites = make_cluster_program(w, tp=4, pp=2)
        # tp: 2 comps x 2 layers x fwd+bwd; dp: 2 buckets; pp: 2 permutes
        assert len(sites) == 8 + 2 + 2
        assert len(prog.ops) == 40 + len(sites)
        for s in sites:
            o = prog.ops[s.index]
            assert o.opclass == "collective" and o.opcode == s.kind
        for i, o in enumerate(prog.ops):
            assert all(0 <= d < i for d in o.deps)   # scheduler contract
            assert len(o.deps) == len(o.dep_bytes)

    def test_work_scaling(self):
        w = _workload(_base_program())
        base_flops = w.prog.flops
        prog, sites = make_cluster_program(
            w, tp=4, pp=2, decision=ShardDecision(attn=True, mlp=True))
        coll = {s.index for s in sites}
        flops = sum(o.flops * o.count
                    for i, o in enumerate(prog.ops) if i not in coll)
        s_tp = 0.4 / 4 + 0.6 / 4            # everything sharded: 1/tp
        assert flops == pytest.approx(base_flops * s_tp * 8 / 2)

    def test_replicated_components_keep_work(self):
        w = _workload(_base_program())
        prog, sites = make_cluster_program(
            w, tp=4, pp=1,
            decision=ShardDecision(attn=True, mlp=False, experts=False))
        coll = {s.index for s in sites}
        flops = sum(o.flops * o.count
                    for i, o in enumerate(prog.ops) if i not in coll)
        s_tp = 0.4 / 4 + 0.6                # mlp replicated
        assert flops == pytest.approx(w.prog.flops * s_tp * 8)

    def test_moe_emits_all_to_all(self):
        w = _workload(_base_program())
        prog, sites = make_cluster_program(
            w, tp=4, pp=1,
            decision=ShardDecision(attn=True, mlp=False, experts=True))
        kinds = {s.kind for s in sites if s.axis == "tp"}
        assert kinds == {"all-reduce", "all-to-all"}
        a2a = [s for s in sites if s.kind == "all-to-all"]
        assert a2a[0].payload_bytes == w.act_bytes * w.moe_top_k

    def test_pp_exceeding_depth_raises(self):
        w = _workload(_base_program())
        with pytest.raises(ValueError, match="pp=16 exceeds"):
            make_cluster_program(w, tp=1, pp=16)

    def test_plan_shapes(self):
        shapes = plan_shapes(max_tp=4, max_pp=2)
        assert (1, 1) in shapes and (4, 2) in shapes
        assert all(tp in (1, 2, 4) and pp in (1, 2) for tp, pp in shapes)


# ------------------------------------- 2-node degenerate bit-identity
class TestDegenerateTwoNode:
    """A 2-node pure-DP cluster whose one collective hangs off the tail
    must cost EXACTLY a node-engine run of the base program plus the
    canonical link time of that collective — no new math on the
    degenerate path."""

    @pytest.mark.parametrize("n_cores,topo", [
        (1, None),                            # real A64FX node topology
        (48, NodeTopology.degenerate(48)),    # uncapped, scale=1/48
    ], ids=["1core_real_topo", "48core_degenerate_topo"])
    def test_bit_identical(self, n_cores, topo):
        base = _base_program(48)
        payload = 1.5e6
        ops, sites = _inject(
            list(base.ops),
            [(1.0, _coll("tail_ar", "all-reduce", payload, 1.0),
              False, "dp")])
        prog = Program(ops=ops, entry="deg", n_partitions=1)
        assert sites[0].index == len(base.ops)
        cl = ClusterTopology.tofu_d(2)
        plan = ParallelPlan(dp=2, tp=1, pp=1)

        rows = schedule_cluster(prog, sites, [(plan, cl)],
                                hw=A64FX_CORE, n_cores=n_cores,
                                topology=topo)

        nr = schedule_node(compile_node(base, A64FX_CORE), A64FX_CORE,
                           n_cores, topology=topo, partition="shard")
        hops = axis_hops(cl, plan)["dp"]
        # canonical pricing + the engine's per-op startup, NOT divided by
        # core count (collectives ride node-level links; the §14 fix)
        dur = collective_time("all-reduce", 2, payload, cl,
                              hops=hops, n_active=1.0) \
            + A64FX_CORE.op_startup_ns * 1e-9
        expected = max(nr.t_est, float(nr.finishes[-1]) + dur)
        assert rows[0]["t_sched"] == expected
        # the compute-only floor is the node-engine makespan, bit-for-bit
        assert rows[0]["t_floor"] == nr.t_est
        assert rows[0]["t_ici"][0] == dur - A64FX_CORE.op_startup_ns * 1e-9


# -------------------------------------------------- sweep + report
class TestClusterSweep:
    def test_sweep_sane(self):
        w = _workload(_base_program())
        res = cluster_sweep(w, (2, 8), n_cores=12, max_tp=4, max_pp=2)
        assert res
        seen = set()
        for r in res:
            key = (r.n_nodes, r.plan.label)
            assert key not in seen
            seen.add(key)
            assert r.plan.n_nodes == r.n_nodes
            assert 0.0 < r.t_floor_s <= r.t_step_s < math.inf
            assert 0.0 < r.parallel_efficiency <= 1.0 + 1e-9
            assert r.t_step_s >= r.t_sched_s    # bubble only adds
        # a pure-DP plan exists at every node count
        assert any(r.plan.tp == 1 and r.plan.pp == 1 and r.n_nodes == 2
                   for r in res)

    def test_report_roundtrip(self):
        from repro.core.zoo import ClusterReport
        w = _workload(_base_program())
        rep = ClusterReport(hw="a64fx_core", topology="deg",
                            cluster="tofu_d", n_cores=12,
                            compute_dtype="f32", node_counts=(2, 8))
        rep.results[w.name] = cluster_sweep(w, (2, 8), n_cores=12,
                                            max_tp=4, max_pp=2)
        d = rep.to_dict()
        json.dumps(d)                          # BENCH-serializable
        assert d["schema"] == 1
        assert d["rank"]["2"] == [w.name]
        assert "min" in d["kendall_tau"][w.name]
        best = rep.best(w.name, 8)
        assert d["models"][w.name]["best_plan"]["8"] == best.plan.label
        sc = d["models"][w.name]["scaling"]["8"]
        assert sc["t_step_us"] == pytest.approx(best.t_step_s * 1e6)

    def test_ici_contention_engages(self):
        """Multi-axis plans with heavy payloads must drive the link-tier
        fixpoint above one concurrent stream."""
        prog = _base_program(24)
        w = ClusterWorkload(name="hot", prog=prog, repeats=8, layers=4,
                            d_model=4096, seq_len=512, batch=8,
                            param_bytes=5e10, frac_attn=0.4)
        res = cluster_sweep(w, (16,), n_cores=1, max_tp=4, max_pp=2)
        multi = [r for r in res if r.plan.tp > 1]
        assert any(r.ici_n_active > 1.0 for r in multi)
        assert all(r.iterations >= 1 for r in res)
