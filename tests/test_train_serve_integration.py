"""Integration: real training loop (loss falls), checkpoint-resume equality,
mesh-sharded step equivalence, serve engine consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow        # real training loops / serve engine

from repro.configs import ARCHS, RunConfig, ShapeConfig, reduced_config
from repro.data.synthetic import SyntheticLMDataset
from repro.launch.mesh import make_host_mesh
from repro.launch.train import build_training, train_loop
from repro.models.lm import build_model
from repro.serve.engine import ServeEngine
from repro.train import checkpoint as ck
from repro.train.fault import FaultInjector


def _run(vocab=256, seq=32, batch=8, micro=0):
    cfg = dataclasses.replace(
        reduced_config(ARCHS["qwen1.5-32b"]), vocab_size=vocab, n_layers=2)
    shape = ShapeConfig(name="t", seq_len=seq, global_batch=batch,
                        kind="train")
    run = RunConfig(model=cfg, shape=shape, microbatch=micro,
                    param_dtype="float32", compute_dtype="float32",
                    learning_rate=1e-3)
    return cfg, run


def test_training_loss_decreases():
    cfg, run = _run()
    run = dataclasses.replace(run, learning_rate=3e-3)
    model = build_model(cfg)
    rep = train_loop(model, run, n_steps=40, log_every=1000)
    first = np.mean(rep.losses[:5])
    last = np.mean(rep.losses[-10:])
    assert last < first - 0.15, (first, last)


def test_training_with_mesh_matches_unsharded():
    """Same seed, with and without a (1,1) host mesh (sharding machinery on)
    must agree — the lsc/rules path is numerically inert."""
    cfg, run = _run(batch=4)
    model = build_model(cfg)
    rep_a = train_loop(model, run, n_steps=5, log_every=1000)
    rep_b = train_loop(model, run, n_steps=5, mesh=make_host_mesh(1, 1),
                       log_every=1000)
    np.testing.assert_allclose(rep_a.losses, rep_b.losses, rtol=1e-4)


def test_checkpoint_resume_continues_exactly(tmp_path):
    """Train 20 steps straight vs 10 + restart + 10 — same final loss."""
    cfg, run = _run(batch=4)
    model = build_model(cfg)
    d1 = str(tmp_path / "straight")
    rep1 = train_loop(model, run, n_steps=20, ckpt_dir=d1, ckpt_every=100,
                      log_every=1000)
    d2 = str(tmp_path / "faulted")
    inj = FaultInjector(fail_at_steps=(10,))
    rep2 = train_loop(model, run, n_steps=20, ckpt_dir=d2, ckpt_every=5,
                      injector=inj, log_every=1000)
    assert rep2.restarts == 1
    np.testing.assert_allclose(rep1.losses[-1], rep2.losses[-1], rtol=1e-4)


def test_elastic_restore_different_sharding(tmp_path):
    """Checkpoint written unsharded restores onto a mesh (elastic restart)."""
    cfg, run = _run(batch=4)
    model = build_model(cfg)
    _, init_state, _ = build_training(model, run, mesh=None)
    state = init_state(0)
    ck.save(tmp_path / "ck", 3, state)

    mesh = make_host_mesh(1, 1)
    jitted, init_state2, (p_sh, o_sh) = build_training(model, run, mesh=mesh)
    like = init_state2(0)
    step, restored, _ = ck.restore(tmp_path / "ck", like,
                                   shardings=(p_sh, o_sh))
    assert step == 3
    leaves = jax.tree.leaves(restored)
    assert all(hasattr(x, "sharding") for x in leaves)
    # one step runs on the restored state
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=32,
                            global_batch=4)
    batch = {"tokens": jnp.asarray(ds.batch(0)["tokens"])}
    p2, o2, m = jitted(restored[0], restored[1], batch)
    assert jnp.isfinite(m["loss"])


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "chatglm3-6b"])
def test_serve_engine_greedy_matches_forward(arch, key):
    """The first generated token equals argmax of the full-forward logits at
    the last prompt position (prefill path == train path)."""
    cfg = reduced_config(ARCHS[arch])
    model = build_model(cfg, attn_impl="naive")
    params = model.init(key, dtype=jnp.float32)
    prompt = list(range(1, 9))
    eng = ServeEngine(model, params, max_seq=16)
    out = eng.generate([prompt], max_new_tokens=3)
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    logits, _, _ = model.forward(params, batch, "train")
    want = int(jnp.argmax(logits[0, -1]))
    assert out[0][0] == want
