"""Config sanity: param counts vs published sizes, shape applicability."""
import pytest

from repro.configs import ARCHS, SHAPES, reduced_config, shapes_for, \
    skipped_shapes_for
from repro.models import params as pr
from repro.models.lm import build_model

# name -> (published params, tolerance).  Tolerances are loose where public
# configs are ambiguous (padded vocab, biases, exact d_ff).
PUBLISHED = {
    "paligemma-3b": (2.9e9, 0.25),       # 3B incl. vision tower (ours: stub)
    "zamba2-1.2b": (1.2e9, 0.25),
    "nemotron-4-340b": (340e9, 0.10),
    "qwen1.5-32b": (32e9, 0.10),
    "qwen1.5-110b": (110e9, 0.10),
    "chatglm3-6b": (6e9, 0.15),
    "mamba2-1.3b": (1.3e9, 0.10),
    "llama4-scout-17b-a16e": (109e9, 0.30),   # 17B active / 109B total
    "grok-1-314b": (314e9, 0.10),
    "whisper-large-v3": (1.5e9, 0.25),
}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_count_matches_published(name):
    cfg = ARCHS[name]
    n = cfg.param_count()
    target, tol = PUBLISHED[name]
    assert abs(n - target) / target < tol, \
        f"{name}: {n / 1e9:.2f}B vs published {target / 1e9:.1f}B"


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_count_matches_built_tree(name):
    """param_count() (closed form) must equal the actual spec tree."""
    cfg = ARCHS[name]
    model = build_model(cfg)
    assert pr.count(model.param_specs()) == cfg.param_count()


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_active_params(name):
    cfg = ARCHS[name]
    active = cfg.active_param_count()
    assert active <= cfg.param_count()
    if cfg.moe is None:
        assert active == cfg.param_count()
    else:
        assert active < cfg.param_count()


def test_moe_actives_roughly_published():
    llama4 = ARCHS["llama4-scout-17b-a16e"]
    assert abs(llama4.active_param_count() - 17e9) / 17e9 < 0.35
    grok = ARCHS["grok-1-314b"]
    assert abs(grok.active_param_count() - 86e9) / 86e9 < 0.30


def test_shapes_accounting_40_cells():
    """10 archs x 4 shapes = 40 cells: 32 run + 8 documented skips."""
    run = sum(len(shapes_for(c)) for c in ARCHS.values())
    skipped = sum(len(skipped_shapes_for(c)) for c in ARCHS.values())
    assert run == 32
    assert skipped == 8
    assert run + skipped == len(ARCHS) * len(SHAPES)


def test_long_500k_only_subquadratic():
    for cfg in ARCHS.values():
        names = {s.name for s in shapes_for(cfg)}
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in names, cfg.name
        else:
            assert "long_500k" not in names, cfg.name


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_config_preserves_structure(name):
    cfg = ARCHS[name]
    red = reduced_config(cfg)
    assert red.family == cfg.family
    assert (red.moe is None) == (cfg.moe is None)
    assert (red.ssm is None) == (cfg.ssm is None)
    assert bool(red.shared_attn_every) == bool(cfg.shared_attn_every)
    assert bool(red.n_encoder_layers) == bool(cfg.n_encoder_layers)
    assert red.qkv_bias == cfg.qkv_bias
    assert red.mlp_kind == cfg.mlp_kind
    assert red.rope_fraction == cfg.rope_fraction
    if cfg.n_heads:
        assert red.n_heads // red.n_kv_heads == \
            max(1, cfg.n_heads // cfg.n_kv_heads) or red.n_kv_heads == 1
    assert red.param_count() < 10e6


def test_padded_vocab_shards():
    for cfg in ARCHS.values():
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
