"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# jax.enable_x64 left the top-level namespace in jax 0.4.31+
from jax.experimental import enable_x64 as jax_enable_x64

pytestmark = pytest.mark.slow        # every test here compiles through jax

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.stream import EXPRS, elementwise, stream_triad

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("sq,sk,h,kvh,d", [
    (128, 128, 4, 4, 64),        # MHA, single block
    (256, 256, 4, 1, 64),        # MQA, multi-block
    (128, 384, 8, 2, 32),        # GQA, sk > sq (prefix decode style)
    (100, 200, 4, 2, 64),        # ragged (padding path)
])
def test_flash_attention_vs_ref(sq, sk, h, kvh, d, causal, dtype, key):
    if sq != sk and causal:
        # causal with offset-free q over longer k: q token i attends k <= i
        pass
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (2, h, sq, d), dtype)
    k = jax.random.normal(k2, (2, kvh, sk, d), dtype)
    v = jax.random.normal(k3, (2, kvh, sk, d), dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, block_q=128,
                               block_k=128, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 256)])
def test_flash_attention_block_shape_invariance(block_q, block_k, key):
    q = jax.random.normal(key, (1, 2, 256, 64), jnp.float32)
    out_a = flash_attention_bhsd(q, q, q, causal=True, block_q=block_q,
                                 block_k=block_k, interpret=True)
    out_b = ref.flash_attention_ref(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_ops_layout(key):
    """ops.flash_attention uses (B, S, H, D) layout like the models."""
    q = jax.random.normal(key, (2, 128, 4, 64), jnp.float32)
    out = ops.flash_attention(q, q, q, causal=True)
    want = jnp.transpose(
        ref.flash_attention_ref(*(jnp.transpose(x, (0, 2, 1, 3))
                                  for x in (q, q, q)), causal=True),
        (0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ SSD scan
@pytest.mark.parametrize("L,H,P,N,chunk", [
    (64, 2, 16, 16, 16),
    (128, 4, 32, 32, 32),
    (96, 2, 16, 8, 32),          # L not a multiple of chunk*2
])
def test_ssd_scan_vs_sequential_ref(L, H, P, N, chunk, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    B = 2
    x = jax.random.normal(k1, (B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(k2, (B, L, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(k3, (H,), jnp.float32) * 0.5)
    Bm = jax.random.normal(k4, (B, L, H, N), jnp.float32) * 0.5
    Cm = jax.random.normal(k1, (B, L, H, N), jnp.float32) * 0.5
    y, state = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    y_ref, state_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref),
                               rtol=2e-3, atol=2e-3)


def test_ssd_scan_initial_state(key):
    """Chunked scan over [x1; x2] == scan x1 then scan x2 from its state."""
    B, L, H, P, N = 1, 64, 2, 16, 16
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(k2, (B, L, H), jnp.float32))
    A = -jnp.ones((H,), jnp.float32)
    Bm = jax.random.normal(k1, (B, L, H, N), jnp.float32) * 0.3
    Cm = jax.random.normal(k2, (B, L, H, N), jnp.float32) * 0.3
    y_full, s_full = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=16)
    y1, s1 = ops.ssd_scan(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32],
                          chunk=16)
    y2, s2 = ops.ssd_scan(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:],
                          chunk=16, initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-3, atol=2e-3)


# ------------------------------------------------- the paper's kernel suite
@pytest.mark.parametrize("name", sorted(EXPRS))
def test_elementwise_kernel_vs_ref(name, key):
    with jax_enable_x64():
        fn, n_in, din, dout = EXPRS[name]
        n = 4096
        from repro.kernels.stream import _DTYPES
        if din == "i4":
            x1 = jax.random.randint(key, (n,), -1000, 1000, jnp.int32)
        else:
            x1 = jnp.abs(jax.random.normal(key, (n,), _DTYPES[din])) + 0.5
        x2 = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (n,),
                                       _DTYPES["f8" if din == "i4" else din])
                     ) + 0.5
        if din != "i4":
            x2 = x2.astype(_DTYPES[din])
        y0 = jnp.zeros((n,), _DTYPES[dout])
        out = elementwise(name, x1, x2, y0, block=512, interpret=True)
        want = ref.elementwise_ref(name, x1, x2, y0)
        np.testing.assert_allclose(np.asarray(out, np.float64),
                                   np.asarray(want, np.float64),
                                   rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("n,block", [(1 << 14, 4096), (3 * 4096, 4096)])
def test_stream_triad_kernel(n, block, key):
    a = jax.random.normal(key, (n,), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    out = stream_triad(a, b, 3.0, block=block, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.stream_triad_ref(a, b, 3.0)),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------ SSD backward (custom VJP)
def test_ssd_kernel_gradients_match_reference(key):
    """jax.grad through the Pallas fwd+bwd kernels == grad of the
    sequential jnp recurrence."""
    B, L, H, P, N, chunk = 2, 64, 2, 16, 16, 16
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    x = jax.random.normal(k1, (B, L, H, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(k2, (B, L, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(k3, (H,)) * 0.3)
    Bm = jax.random.normal(k4, (B, L, H, N), jnp.float32) * 0.4
    Cm = jax.random.normal(k5, (B, L, H, N), jnp.float32) * 0.4

    def loss_kernel(*args):
        y, s = ops.ssd_scan(*args, chunk=chunk)
        return jnp.sum(jnp.sin(y)) + jnp.sum(s * s)

    def loss_ref(*args):
        y, s = ref.ssd_ref(*args)
        return jnp.sum(jnp.sin(y)) + jnp.sum(s * s)

    g_k = jax.grad(loss_kernel, argnums=(0, 1, 2, 3, 4))(x, dt, A, Bm, Cm)
    g_r = jax.grad(loss_ref, argnums=(0, 1, 2, 3, 4))(x, dt, A, Bm, Cm)
    for a, b in zip(g_k, g_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_apply_mamba_pallas_matches_jnp(key):
    """apply_mamba(impl='pallas') == apply_mamba(impl='jnp') in fwd and
    grad (no mesh: the shard_map wrapper falls through to the kernel)."""
    from repro.configs import ARCHS, reduced_config
    from repro.models import params as pr
    from repro.models.ssm import apply_mamba, mamba_params

    cfg = reduced_config(ARCHS["mamba2-1.3b"])
    p = pr.init(mamba_params(cfg), key)
    x = 0.3 * jax.random.normal(jax.random.fold_in(key, 1),
                                (2, 32, cfg.d_model), jnp.float32)

    def loss(p, impl):
        out, _ = apply_mamba(p, x, cfg, mode="train", impl=impl)
        return jnp.sum(out * out), out

    (l_j, out_j), g_j = jax.value_and_grad(loss, has_aux=True)(p, "jnp")
    (l_p, out_p), g_p = jax.value_and_grad(loss, has_aux=True)(p, "pallas")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_j),
                               rtol=2e-3, atol=2e-3)
    for kk in g_j:
        np.testing.assert_allclose(np.asarray(g_p[kk]), np.asarray(g_j[kk]),
                                   rtol=5e-3, atol=5e-3, err_msg=kk)
