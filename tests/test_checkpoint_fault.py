"""Fault-tolerance substrate: atomic checkpoints, resume, elastic reshard,
retry-from-checkpoint loop, straggler watchdog, injected failures."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow        # checkpoint/restart loops compile steps

from repro.train import checkpoint as ck
from repro.train.fault import FaultInjector, InjectedFault, run_with_retries


def _state(v=0.0):
    return {"w": jnp.full((4, 4), v), "opt": {"mu": jnp.zeros((4, 4)),
                                              "step": jnp.asarray(v)}}


def test_save_restore_roundtrip(tmp_path):
    d = tmp_path / "ck"
    ck.save(d, 7, _state(3.0), extra={"lr": 0.1})
    step, tree, extra = ck.restore(d, _state())
    assert step == 7
    assert extra == {"lr": 0.1}
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.full((4, 4), 3.0))


def test_latest_pointer_and_gc(tmp_path):
    d = tmp_path / "ck"
    for s in (1, 2, 3, 4, 5):
        ck.save(d, s, _state(float(s)), keep_last=2)
    assert ck.latest_step(d) == 5
    kept = sorted(p.name for p in d.iterdir() if p.name.startswith("step_"))
    assert len(kept) == 2                       # gc keeps last 2
    step, tree, _ = ck.restore(d, _state())
    assert step == 5


def test_crashed_commit_falls_back(tmp_path):
    """A LATEST pointer ahead of a missing dir must fall back to the newest
    complete checkpoint (atomic-commit protocol)."""
    d = tmp_path / "ck"
    ck.save(d, 1, _state(1.0))
    ck.save(d, 2, _state(2.0))
    shutil.rmtree(d / "step_000000002")          # simulate torn commit
    (d / "LATEST").write_text("step_000000002")
    assert ck.latest_step(d) == 1
    step, tree, _ = ck.restore(d, _state())
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.ones((4, 4)))


def test_restore_casts_dtype(tmp_path):
    d = tmp_path / "ck"
    ck.save(d, 1, {"w": jnp.ones((2,), jnp.float32)})
    like = {"w": jax.ShapeDtypeStruct((2,), jnp.bfloat16)}
    _, tree, _ = ck.restore(d, like)
    assert tree["w"].dtype == jnp.bfloat16


# ------------------------------------------------------------ the fault loop
def _quadratic_setup(tmp_path, n_steps=30, **kw):
    """Tiny 'training': state x; step x <- x - 0.1*(x - batch_mean)."""
    calls = {"n": 0}

    def init_state():
        return {"x": jnp.zeros(()), "step": jnp.asarray(0)}

    def batch_fn(step):
        return jnp.asarray(float(step % 5))

    def step_fn(state, batch):
        calls["n"] += 1
        x = state["x"] - 0.1 * (state["x"] - batch)
        loss = float((state["x"] - batch) ** 2)
        return {"x": x, "step": state["step"] + 1}, {"loss": loss}

    return dict(step_fn=step_fn, init_state=init_state, batch_fn=batch_fn,
                n_steps=n_steps, ckpt_dir=str(tmp_path / "ck"),
                ckpt_every=5, **kw), calls


def test_loop_no_faults(tmp_path):
    kw, calls = _quadratic_setup(tmp_path)
    rep = run_with_retries(**kw)
    assert rep.steps_done == 30
    assert rep.restarts == 0
    assert calls["n"] == 30
    assert ck.latest_step(tmp_path / "ck") == 30


def test_loop_recovers_from_injected_fault(tmp_path):
    inj = FaultInjector(fail_at_steps=(12, 23))
    kw, calls = _quadratic_setup(tmp_path, injector=inj)
    rep = run_with_retries(**kw)
    assert rep.steps_done == 30
    assert rep.restarts == 2
    # replayed steps: restart resumes from step 10 and 20 checkpoints
    assert calls["n"] > 30
    assert ck.latest_step(tmp_path / "ck") == 30


def test_loop_deterministic_resume(tmp_path):
    """Final state with faults == final state without (seekable data +
    checkpoint replay = exactly-once semantics)."""
    kw1, _ = _quadratic_setup(tmp_path / "a")
    rep1 = run_with_retries(**kw1)
    kw2, _ = _quadratic_setup(tmp_path / "b",
                              injector=FaultInjector(fail_at_steps=(7, 17)))
    rep2 = run_with_retries(**kw2)
    _, t1, _ = ck.restore(tmp_path / "a" / "ck",
                          {"x": jnp.zeros(()), "step": jnp.asarray(0)})
    _, t2, _ = ck.restore(tmp_path / "b" / "ck",
                          {"x": jnp.zeros(()), "step": jnp.asarray(0)})
    np.testing.assert_allclose(np.asarray(t1["x"]), np.asarray(t2["x"]),
                               rtol=1e-6)


def test_loop_gives_up_after_max_restarts(tmp_path):
    inj = FaultInjector(fail_at_steps=tuple(range(0, 100)))
    kw, _ = _quadratic_setup(tmp_path, injector=inj, max_restarts=3)
    with pytest.raises(InjectedFault):
        run_with_retries(**kw)


def test_straggler_watchdog(tmp_path):
    """A persistently slow step triggers the deadline watchdog and a
    restart (eviction analogue), and the loop still completes."""
    inj = FaultInjector(straggle_at_steps=(15, 16, 17), straggle_s=0.25)
    kw, _ = _quadratic_setup(tmp_path, injector=inj,
                             deadline_factor=5.0, straggler_patience=3)
    rep = run_with_retries(**kw)
    assert rep.steps_done == 30
    assert rep.straggler_events >= 1


def test_async_checkpointer_overlaps_and_commits(tmp_path):
    """Async save returns immediately; the commit is identical to the sync
    protocol (LATEST, restore, gc) and donation-safe (tree mutated after
    save must not affect the written checkpoint)."""
    import jax.numpy as jnp
    from repro.train.checkpoint import AsyncCheckpointer

    ck_dir = tmp_path / "ck"
    acp = AsyncCheckpointer()
    state = {"w": jnp.full((8, 8), 1.0)}
    acp.save(ck_dir, 1, state)
    # mutate the live state while the write may still be in flight
    state = {"w": state["w"] * 100.0}
    acp.save(ck_dir, 2, state)        # implies wait() on the first write
    acp.wait()
    assert ck.latest_step(ck_dir) == 2
    _, t1, _ = ck.restore(ck_dir, {"w": jnp.zeros((8, 8))}, step=1)
    np.testing.assert_array_equal(np.asarray(t1["w"]), np.full((8, 8), 1.0))
    _, t2, _ = ck.restore(ck_dir, {"w": jnp.zeros((8, 8))}, step=2)
    np.testing.assert_array_equal(np.asarray(t2["w"]), np.full((8, 8), 100.0))


def test_async_checkpointer_surfaces_errors(tmp_path):
    from repro.train.checkpoint import AsyncCheckpointer
    import jax.numpy as jnp

    acp = AsyncCheckpointer()
    # unwritable destination -> the error must surface at wait()
    acp.save("/proc/definitely/not/writable", 1, {"w": jnp.zeros((2,))})
    with pytest.raises(Exception):
        acp.wait()
