"""Differential tests for the compiled array-form scheduling core (ISSUE 3).

The contract: the fast scalar kernel, the batched grid kernel, and the
jax.lax.scan formulation all replay the reference interpreter's float
operations in the same order, so ``t_est`` / ``port_busy`` /
``stall_by_reason`` are BIT-identical — asserted here over random DAG
programs x random O3 knobs (seeded generator, plus hypothesis when it is
installed), the canned golden fixtures, and the sandwich invariant
``t_roofline <= t_est <= t_serial`` on the compiled path.
"""
import random
import time

import numpy as np
import pytest

from repro.core import calibrate
from repro.core.compiled import (O3Knobs, compile_program, schedule_arrays,
                                 schedule_batch)
from repro.core.cost import cost_program
from repro.core.hlo import OpStat, Program, parse_program
from repro.core.hwspec import A64FX_CORE, CPU_HOST, TPU_V5E
from repro.core.schedule import (CRITICAL_PATH_LIMIT, schedule_program,
                                 schedule_reference)
from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from tests.test_schedule_engine import CHAIN_HLO, INDEP_HLO

PORTS4 = ("mxu", "vpu", "mem", "ici")


def random_program(rng: random.Random, n: int) -> Program:
    """Random DAG with the op mix the cost model actually charges."""
    ops = []
    for i in range(n):
        k = min(i, rng.randint(0, 3))
        deps = sorted(rng.sample(range(i), k))
        cls = rng.choice(["elementwise", "data", "matmul", "reduce",
                          "transcendental", "unknown-class"])
        ops.append(OpStat(
            f"op{i}", "fusion", cls, "f32",
            flops=rng.uniform(1e3, 1e9),
            transcendentals=rng.uniform(0, 1e3),
            bytes_accessed=rng.uniform(1e3, 1e8),
            read_bytes=rng.uniform(1e3, 5e7),
            write_bytes=rng.uniform(0, 5e7),
            count=rng.choice([1.0, 1.0, 4.0]),
            deps=deps, dep_bytes=[rng.uniform(0, 1e6) for _ in deps]))
    return Program(ops=ops, entry="e", n_partitions=1)


def random_knobs(rng: random.Random):
    base = rng.choice([TPU_V5E, CPU_HOST, A64FX_CORE])
    return base.with_(
        inflight_window=rng.choice([1, 2, 7, 64, 1024]),
        issue_width={p: rng.randint(1, 4) for p in PORTS4},
        queue_depth={p: rng.randint(1, 32) for p in PORTS4})


def _assert_fast_matches_reference(prog, hw):
    ref = schedule_reference(prog, hw)
    fast = schedule_program(prog, hw)
    assert fast.t_est == ref.t_est                      # bit-identical
    assert fast.port_busy == ref.port_busy
    assert fast.stall_by_reason == ref.stall_by_reason
    assert fast.t_serial == ref.t_serial
    assert fast.t_dataflow == ref.t_dataflow
    assert fast.t_roofline == ref.t_roofline
    assert fast.n_edges == ref.n_edges
    assert fast.n_ops == ref.n_ops
    # sandwich invariant on the compiled path
    assert fast.t_roofline <= fast.t_est * (1 + 1e-9)
    assert fast.t_est <= fast.t_serial * (1 + 1e-9)
    assert fast.t_dataflow <= fast.t_est * (1 + 1e-9)
    return ref, fast


def test_differential_random_dags_x_random_knobs():
    """Seeded property sweep: 60 random (program, knob) pairs."""
    rng = random.Random(1234)
    for _ in range(60):
        prog = random_program(rng, rng.randint(0, 48))
        _assert_fast_matches_reference(prog, random_knobs(rng))


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_differential_hypothesis(seed):
    rng = random.Random(seed)
    prog = random_program(rng, rng.randint(0, 40))
    _assert_fast_matches_reference(prog, random_knobs(rng))


def test_differential_on_golden_hlo_fixtures():
    for hlo in (CHAIN_HLO, INDEP_HLO):
        prog = parse_program(hlo)
        for hw in (TPU_V5E, A64FX_CORE, CPU_HOST):
            _assert_fast_matches_reference(prog, hw)


def test_batched_kernel_matches_scalar_per_combo():
    rng = random.Random(7)
    prog = random_program(rng, 64)
    specs = [random_knobs(rng) for _ in range(25)]
    cp = compile_program(prog, TPU_V5E)
    got = schedule_batch(cp, O3Knobs.from_specs(specs))
    want = np.array([schedule_arrays(cp, hw)[0] for hw in specs])
    assert np.array_equal(got, want)


@pytest.mark.slow
def test_differential_on_kernel_suite_programs():
    """Acceptance: fast-path t_est equals the reference scheduler's to
    <=1e-9 relative error on every kernel-suite program (it is in fact
    bit-identical; compiled HLO of the real suite kernels, no
    measurement)."""
    from jax.experimental import enable_x64 as jax_enable_x64

    from repro.configs.a64fx_kernelsuite import KERNELS
    hw = CPU_HOST
    with jax_enable_x64():
        for k in KERNELS:
            x1, x2, y0 = calibrate._kernel_inputs(k, k.n)
            f = calibrate._jit_kernel(k.name)
            prog = parse_program(f.lower(x1, x2, y0).compile().as_text())
            ref = schedule_reference(prog, hw, compute_dtype="f64")
            fast = schedule_program(prog, hw, compute_dtype="f64")
            assert fast.t_est == pytest.approx(ref.t_est, rel=1e-9)
            assert fast.t_est == ref.t_est        # in fact bit-identical
            assert fast.port_busy == ref.port_busy
            assert fast.stall_by_reason == ref.stall_by_reason


@pytest.mark.slow
def test_jax_scan_backend_matches_numpy():
    rng = random.Random(11)
    prog = random_program(rng, 48)
    specs = [random_knobs(rng) for _ in range(8)]
    cp = compile_program(prog, TPU_V5E)
    knobs = O3Knobs.from_specs(specs)
    got = schedule_batch(cp, knobs, backend="jax")
    want = schedule_batch(cp, knobs)
    np.testing.assert_allclose(got, want, rtol=1e-9)


def test_compile_program_memoizes_on_program_and_spec_identity():
    prog = parse_program(CHAIN_HLO)
    cp1 = compile_program(prog, TPU_V5E)
    cp2 = compile_program(prog, TPU_V5E)
    assert cp1 is cp2
    other = TPU_V5E.with_(op_startup_ns=0.0)
    cp3 = compile_program(prog, other)
    assert cp3 is not cp1
    assert compile_program(prog, TPU_V5E, compute_dtype="bf16") is not cp1


def test_shared_costed_list_bypasses_recosting():
    prog = parse_program(INDEP_HLO)
    costed = cost_program(prog, TPU_V5E)
    fast = schedule_program(prog, TPU_V5E, costed=costed)
    assert fast.t_est == schedule_reference(prog, TPU_V5E,
                                            costed=costed).t_est


def test_caller_supplied_costed_list_bypasses_compile_cache():
    """Regression: a modified costed list must not hit (or poison) the
    (program, spec) memo populated by an earlier plain call — the fast
    path has to schedule the costs it was GIVEN."""
    import dataclasses
    prog = parse_program(CHAIN_HLO)
    schedule_program(prog, TPU_V5E)                  # populate the cache
    scaled = [None if ot is None
              else dataclasses.replace(ot, t_compute=ot.t_compute * 70,
                                       t_mem=ot.t_mem * 70)
              for ot in cost_program(prog, TPU_V5E)]
    fast = schedule_program(prog, TPU_V5E, costed=scaled)
    ref = schedule_reference(prog, TPU_V5E, costed=scaled)
    assert fast.t_est == ref.t_est
    # and the plain cached path is not poisoned by the scaled costs
    assert schedule_program(prog, TPU_V5E).t_est == \
        schedule_reference(prog, TPU_V5E).t_est


# ------------------------------------------------------------- satellites
def test_memory_hierarchy_is_memoized():
    hw = TPU_V5E.with_(vmem_bw=12e12)        # fresh instance, empty cache
    assert hw.memory_hierarchy() is hw.memory_hierarchy()
    # with_ returns a NEW spec whose hierarchy reflects the new scalar
    # (the cache cannot leak through dataclasses.replace)
    shrunk = hw.with_(hbm_read_bw=1e9)
    assert shrunk.memory_hierarchy()[-1].read_bw == 1e9
    assert hw.memory_hierarchy()[-1].read_bw != 1e9


def test_bound_by_normalizes_port_busy_by_issue_width():
    """A 4-wide mem port with more RAW busy than a 1-wide vpu must not be
    crowned the binding port when its per-pipe time is lower — consistent
    with how t_roofline picks the binding term."""
    ops = ([OpStat(f"cp{i}", "copy", "data", "f32", bytes_accessed=1e9)
            for i in range(4)]
           + [OpStat("v", "add", "elementwise", "f32", flops=1.5e10,
                     bytes_accessed=1.0)])
    prog = Program(ops=ops, entry="e", n_partitions=1)
    hw = TPU_V5E.with_(issue_width={"mxu": 1, "vpu": 1, "mem": 4, "ici": 1})
    r = schedule_program(prog, hw)
    busy = r.port_busy
    assert busy["mem"] > busy["vpu"]                 # raw busy says mem
    assert busy["mem"] / 4 < busy["vpu"]             # per-pipe says vpu
    assert r.bound_by == "vpu"
    # reference path agrees
    assert schedule_reference(prog, hw).bound_by == "vpu"


def test_critical_path_truncation_flag_and_pa_note():
    """A binding chain longer than CRITICAL_PATH_LIMIT raises the flag
    and the PA report says the shown path is a suffix."""
    n = CRITICAL_PATH_LIMIT + 40
    ops = [OpStat(f"e{i}", "add", "elementwise", "f32", flops=1e9,
                  bytes_accessed=8.0, deps=[i - 1] if i else [],
                  dep_bytes=[8.0] if i else [])
           for i in range(n)]
    prog = Program(ops=ops, entry="e", n_partitions=1)
    r = schedule_reference(prog, TPU_V5E)
    assert r.critical_path_truncated
    assert len(r.critical_path) == CRITICAL_PATH_LIMIT
    # the lazily-built fast-path detail carries the flag too
    fast = schedule_program(prog, TPU_V5E)
    assert fast.critical_path_truncated
    from repro.core.engine import simulate_program
    from repro.core.pa import pa_report
    from repro.core.roofline import roofline_from_program
    eng = simulate_program(prog, TPU_V5E)
    rf = roofline_from_program(prog, TPU_V5E, 1, 0.0, "bf16")
    assert "TRUNCATED" in pa_report(rf, eng, prog, sched=r,
                                    engine_mode="schedule")
    # a short chain does not raise it
    short = schedule_reference(parse_program(CHAIN_HLO), TPU_V5E)
    assert not short.critical_path_truncated


def test_fast_path_detail_is_lazy_and_correct():
    prog = parse_program(INDEP_HLO)
    r = schedule_program(prog, TPU_V5E)
    assert r._timeline is None                       # nothing built yet
    ref = schedule_reference(prog, TPU_V5E)
    assert [s.op.name for s in r.timeline] == \
        [s.op.name for s in ref.timeline]
    assert [s.op.name for s in r.critical_path] == \
        [s.op.name for s in ref.critical_path]
    assert [s.start for s in r.timeline] == [s.start for s in ref.timeline]


def test_batched_sweep_beats_old_serial_grid_wall_time():
    """Acceptance: the enlarged default grid (5x3x2x3 = 90 combos),
    batched, must cost less wall time than the OLD 36-combo grid run
    serially through the reference interpreter."""
    rng = random.Random(3)
    programs = [random_program(rng, 120) for _ in range(4)]
    rows = [calibrate.KernelRow(f"p{i}", "synth", 1, measured_us=100.0,
                                simulated_us=100.0)
            for i in range(len(programs))]
    table = calibrate.AccuracyTable(rows, programs=programs)
    hw = CPU_HOST

    t0 = time.perf_counter()
    sweep = calibrate.sweep_o3(table, hw)
    t_batched = time.perf_counter() - t0
    assert len(sweep.results) == 90

    costed = [cost_program(p, hw, compute_dtype="f64") for p in programs]
    old_specs = [calibrate._knob_spec(hw, w, mw, 1, qd)
                 for w in (4, 16, 64, 256)
                 for mw in calibrate.O3_MEM_WIDTHS
                 for qd in calibrate.O3_QUEUE_DEPTHS]
    assert len(old_specs) == 36
    t0 = time.perf_counter()
    for cand in old_specs:
        for prog, ops in zip(programs, costed):
            schedule_reference(prog, cand, compute_dtype="f64", costed=ops)
    t_old = time.perf_counter() - t0
    assert t_batched < t_old, (t_batched, t_old)


def test_sweep_o3_results_match_reference_interpreter():
    """The batched sweep's per-combo t_est must be the reference
    scheduler's, so the tuned parameter file is the same one the PR-2
    serial sweep would have picked."""
    rng = random.Random(5)
    programs = [random_program(rng, 40) for _ in range(2)]
    rows = [calibrate.KernelRow(f"p{i}", "synth", 1, measured_us=50.0,
                                simulated_us=50.0)
            for i in range(len(programs))]
    table = calibrate.AccuracyTable(rows, programs=programs)
    hw = CPU_HOST
    sweep = calibrate.sweep_o3(table, hw, windows=(4, 64),
                               mem_widths=(1, 2), vpu_widths=(1,),
                               queue_depths=(4, 16))
    for r in sweep.results:
        cand = calibrate._knob_spec(hw, r["inflight_window"],
                                    r["mem_issue_width"],
                                    r["vpu_issue_width"], r["queue_depth"])
        diffs = [abs(schedule_reference(p, cand,
                                        compute_dtype="f64").t_est * 1e6
                     - row.measured_us) / row.measured_us * 100.0
                 for p, row in zip(programs, rows)]
        assert r["mean_abs_diff_pct"] == pytest.approx(
            sum(diffs) / len(diffs), rel=1e-12)


def test_perf_smoke_bench_program_is_deterministic():
    from benchmarks.sched_throughput import synthetic_program
    a = synthetic_program(n=200, seed=0)
    b = synthetic_program(n=200, seed=0)
    assert [o.deps for o in a.ops] == [o.deps for o in b.ops]
    assert [o.flops for o in a.ops] == [o.flops for o in b.ops]
    _assert_fast_matches_reference(a, CPU_HOST)
