"""Per-architecture smoke tests (assignment requirement): reduced config,
one forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow        # compiles a train step per architecture

from repro.configs import ARCHS, RunConfig, ShapeConfig, reduced_config
from repro.models.lm import build_model
from repro.train.trainer import make_train_step

B, S = 2, 32


def tiny_batch(cfg, batch=B, seq=S, dtype=jnp.float32):
    t = jnp.arange(batch * seq, dtype=jnp.int32).reshape(batch, seq) \
        % cfg.vocab_size
    out = {"tokens": t}
    if cfg.family == "vlm":
        out["img_embeds"] = 0.01 * jnp.ones(
            (batch, cfg.n_img_tokens, cfg.d_model), dtype)
    if cfg.family == "audio":
        out["frames"] = 0.01 * jnp.ones(
            (batch, cfg.n_frames, cfg.d_model), dtype)
    return out


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_shapes_no_nans(name, key):
    cfg = reduced_config(ARCHS[name])
    model = build_model(cfg)
    params = model.init(key)
    logits, aux, _ = model.forward(params, tiny_batch(cfg), "train")
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    loss, metrics = model.loss_fn(params, tiny_batch(cfg))
    assert loss.shape == ()
    assert jnp.isfinite(loss)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_one_train_step(name, key):
    cfg = reduced_config(ARCHS[name])
    model = build_model(cfg)
    shape = ShapeConfig(name="t", seq_len=S, global_batch=B, kind="train")
    run = RunConfig(model=cfg, shape=shape, param_dtype="float32",
                    compute_dtype="float32")
    step, _, _, _, _, opt_init = make_train_step(model, run, rules=None)
    params = model.init(key)
    opt = opt_init(params)
    p2, o2, metrics = jax.jit(step)(params, opt, tiny_batch(cfg))
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, kv: a + float(jnp.abs(kv).sum()),
        jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), params, p2),
        0.0)
    assert moved > 0.0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_grad_accumulation_matches_single_shot(name, key):
    """microbatch=B/2 must give the same loss and (nearly) the same update."""
    cfg = reduced_config(ARCHS[name])
    if cfg.moe is not None:
        pytest.skip("MoE routing depends on the token group -> not "
                    "bitwise-comparable across microbatching")
    model = build_model(cfg)
    batch = tiny_batch(cfg, batch=4)
    shape = ShapeConfig(name="t", seq_len=S, global_batch=4, kind="train")
    run1 = RunConfig(model=cfg, shape=shape, param_dtype="float32",
                     compute_dtype="float32")
    run2 = RunConfig(model=cfg, shape=shape, microbatch=2,
                     param_dtype="float32", compute_dtype="float32")
    params = model.init(key)

    outs = []
    for run in (run1, run2):
        step, *_, opt_init = make_train_step(model, run, rules=None)
        p2, _, m = jax.jit(step)(params, opt_init(params), batch)
        outs.append((p2, m))
    (p_a, m_a), (p_b, m_b) = outs
    assert abs(float(m_a["loss"]) - float(m_b["loss"])) < 2e-3
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p_a, p_b)
    assert max(jax.tree.leaves(diffs)) < 5e-2      # adam normalizes scale


@pytest.mark.parametrize("name", ["qwen1.5-32b", "mamba2-1.3b",
                                  "zamba2-1.2b", "whisper-large-v3",
                                  "grok-1-314b", "paligemma-3b"])
def test_prefill_decode_consistency(name, key):
    """Greedy decode token-by-token must match teacher-forced logits."""
    cfg = reduced_config(ARCHS[name])
    model = build_model(cfg, attn_impl="naive")
    params = model.init(key)
    batch = tiny_batch(cfg, batch=1, seq=8)

    # teacher-forced full forward
    full_logits, _, _ = model.forward(params, batch, "train")

    # prefill on the first 4 tokens, then decode 4
    pre = {k: (v[:, :4] if k == "tokens" else v) for k, v in batch.items()}
    logits, cache = model.prefill_fn(params, pre)

    # grow the *self-attention* KV seq axis (axis 2 of (L,B,S,KV,HD) leaves;
    # cross-attn xk/xv and SSM state are fixed-size) from 4 to 8
    def grow(x):
        pad = [(0, 0)] * x.ndim
        pad[2] = (0, 4)
        return jnp.pad(x, pad)

    if isinstance(cache, dict):
        for kname in ("k", "v", "shared_k", "shared_v"):
            if kname in cache:
                cache[kname] = grow(cache[kname])

    errs = [float(jnp.max(jnp.abs(logits - full_logits[:, 3])))]
    for pos in range(4, 8):
        tok = batch["tokens"][:, pos:pos + 1]
        logits, cache = model.decode_fn(
            params, cache, {"tokens": tok, "pos": jnp.asarray(pos, jnp.int32)})
        if pos < 7:
            errs.append(float(jnp.max(jnp.abs(logits - full_logits[:, pos]))))
    assert max(errs) < 2e-2, errs
