"""DSE layer tests (core.dse, DESIGN.md §19).

- the candidate generator: grid size, name uniqueness, structural
  uniformity of the materialized ``SpecGrid`` (one grid must cover the
  whole cross product or the fused sweep cannot exist);
- ``materialize``: the axes land where they claim (VPU scaling on the
  flops tables, HBM stacks on the topology aggregates and capacity,
  ``shared_by`` following the CMG shape);
- ``pareto_front`` on hand-checkable toys;
- ``run_dse``'s artifact schema on synthetic programs (zoo tracing
  monkeypatched out — no jax in tier-1);
- the committed ``BENCH_dse.json``: schema, per-workload shape
  consistency, and a rank-stability floor — the artifact's whole claim
  is that candidate rankings transfer across workloads.
"""
from __future__ import annotations

import json
import random
from pathlib import Path

import numpy as np
import pytest

from repro.core.dse import (SpecPoint, generate_grid, materialize,
                            pareto_front, run_dse, spec_grid,
                            sweep_workload)
from repro.core.hwspec import A64FX_CORE
from repro.core.zoo import zoo_workloads
from tests.test_compiled_schedule import random_program

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_dse.json"


def test_generate_grid_default():
    pts = generate_grid()
    assert len(pts) == 64
    assert len({p.name for p in pts}) == 64
    # the A64FX baseline is a grid point (ranking it against candidates
    # is the point of the exercise)
    assert SpecPoint(4, 12, 1, 130.0, 2) in pts
    assert all(p.n_cores == p.n_cmgs * p.cores_per_cmg for p in pts)


def test_materialize_axes():
    base = SpecPoint(4, 12, 1, 130.0, 2)
    sp = materialize(base)
    assert [lv.name for lv in sp.memory_hierarchy()] == \
        [lv.name for lv in A64FX_CORE.memory_hierarchy()]
    # VPU width doubles every flops entry
    wide = materialize(SpecPoint(4, 12, 1, 130.0, 4))
    for dt in sp.vpu_flops:
        assert wide.vpu_flops[dt] == 2 * sp.vpu_flops[dt]
        assert wide.peak_flops[dt] == 2 * sp.peak_flops[dt]
    # HBM stacks scale the topology aggregate and the capacity, not the
    # per-core draw
    two = materialize(SpecPoint(4, 12, 2, 130.0, 2))
    assert two.topology.shared_read_bw["hbm2"] == \
        2 * sp.topology.shared_read_bw["hbm2"]
    assert two.hbm_bytes == 2 * sp.hbm_bytes
    assert two.hbm_read_bw == sp.hbm_read_bw
    # sharing domains follow the CMG shape
    eight = materialize(SpecPoint(2, 8, 1, 0.0, 2))
    assert all(lv.shared_by in (1, 8)
               for lv in eight.memory_hierarchy())
    assert eight.topology.n_cmgs == 2
    assert eight.topology.cores_per_cmg == 8
    assert eight.topology.ring_latency_s == 0.0


def test_spec_grid_covers_whole_cross_product():
    grid = spec_grid(generate_grid())
    assert grid.S == 64
    assert grid.level_names == ("l1d", "l2", "hbm2")
    assert grid.warm_caches


def test_pareto_front_toys():
    assert pareto_front(np.array([[1.0, 1.0]])) == [0]
    # (2,2) dominated by (1,1); (0,3) survives on axis 1
    assert pareto_front(np.array([[1., 1.], [2., 2.], [0., 3.]])) == [0, 2]
    # duplicates of the best row all survive (neither strictly dominates)
    assert pareto_front(np.array([[1., 1.], [1., 1.], [3., 0.]])) \
        == [0, 1, 2]
    # a single row dominating everything leaves only itself
    assert pareto_front(np.array([[5., 5.], [1., 1.], [2., 9.]])) == [1]


def test_sweep_workload_axes():
    rng = random.Random(3)
    prog = random_program(rng, 30)
    grid = spec_grid(generate_grid(n_cmgs=(1, 4), cores_per_cmg=(12,),
                                   hbm_stacks=(1,), ring_latency_ns=(0.0,),
                                   vpu_lanes=(2,)))
    sw = sweep_workload(prog, grid)
    assert sw["t_est"].shape == (2,)
    assert np.isfinite(sw["t_est"]).all() and (sw["t_est"] > 0).all()
    assert (sw["hbm_bytes"] >= 0).all()
    assert list(sw["n_cores"]) == [12.0, 48.0]


def test_run_dse_schema_synthetic(monkeypatch):
    progs = {("a", "prefill"): random_program(random.Random(0), 25),
             ("b", "prefill"): random_program(random.Random(1), 25),
             ("c", "decode"): random_program(random.Random(2), 25)}

    def fake_trace(arch, phase, shape=None, param_dtype="float32",
                   hlo_cache_dir=None):
        return progs[(arch, phase)]

    import repro.core.zoo as zoo
    monkeypatch.setattr(zoo, "trace_phase", fake_trace)
    pts = generate_grid(n_cmgs=(1, 2), cores_per_cmg=(8,),
                        hbm_stacks=(1, 2), ring_latency_ns=(0.0,),
                        vpu_lanes=(2,))
    out = run_dse(list(progs), points=pts)
    assert out["n_specs"] == 4 and len(out["spec_points"]) == 4
    assert out["workloads"] == ["a/prefill", "b/prefill", "c/decode"]
    names = {p["name"] for p in out["spec_points"]}
    for key, wl in out["per_workload"].items():
        assert key in out["workloads"]
        for f in ("t_est_s", "cycles", "hbm_bytes", "n_cores"):
            assert len(wl[f]) == 4
        assert wl["best_spec"] in names
        assert wl["pareto"] and all(0 <= i < 4 for i in wl["pareto"])
        # cycles are just clock-scaled times
        assert np.allclose(np.array(wl["cycles"]),
                           np.array(wl["t_est_s"]) * out["clock_hz"])
        # the best spec is on the Pareto front (it wins the cycles axis)
        assert int(np.argmin(wl["t_est_s"])) in wl["pareto"]
    rs = out["rank_stability"]
    M = np.array(rs["tau_matrix"])
    assert M.shape == (3, 3)
    assert np.allclose(M, M.T) and np.allclose(np.diag(M), 1.0)
    assert -1.0 <= rs["min_tau"] <= rs["mean_tau"] <= 1.0


def test_zoo_workloads_validation():
    wl = zoo_workloads(["chatglm3-6b"], ["prefill", "decode"])
    assert wl == [("chatglm3-6b", "prefill"), ("chatglm3-6b", "decode")]
    with pytest.raises(ValueError, match="unknown arch"):
        zoo_workloads(["nope"], ["prefill"])
    with pytest.raises(ValueError, match="unknown phase"):
        zoo_workloads(["chatglm3-6b"], ["warmup"])


def test_bench_dse_artifact():
    """The committed BENCH_dse.json: schema + the rank-stability floor.

    Candidate rankings must broadly transfer across zoo workloads
    (mean tau well above chance) or the DSE table is noise; the floor is
    loose enough to survive re-generation on other hosts (estimates are
    deterministic — only the throughput block varies)."""
    d = json.loads(BENCH_JSON.read_text())
    assert d["schema"] == 1
    assert d["n_specs"] >= 64
    assert len(d["workloads"]) >= 5
    assert set(d["per_workload"]) == set(d["workloads"])
    for wl in d["per_workload"].values():
        assert len(wl["t_est_s"]) == d["n_specs"]
        assert wl["pareto"], "empty Pareto front"
        ts = np.array(wl["t_est_s"])
        assert np.isfinite(ts).all() and (ts > 0).all()
    rs = d["rank_stability"]
    assert len(rs["tau_matrix"]) == len(d["workloads"])
    assert rs["mean_tau"] >= 0.5
    assert rs["min_tau"] >= 0.2
    thr = d["throughput"]
    assert thr["bit_identical"] is True
    assert thr["speedup"] >= thr["floor_speedup"]


def test_measure_throughput_bit_identity():
    from benchmarks.dse_sweep import measure_throughput
    prog = random_program(random.Random(5), 40)
    grid = spec_grid(generate_grid(n_cmgs=(1, 2), cores_per_cmg=(8,),
                                   hbm_stacks=(1,), ring_latency_ns=(0.0,),
                                   vpu_lanes=(2, 4)))
    thr = measure_throughput(prog, grid, loop_rounds=1, fused_rounds=1)
    assert thr["bit_identical"] is True
    assert thr["n_specs"] == 4 and thr["speedup"] > 0