"""Shared fixtures.  NOTE: no XLA_FLAGS device forcing here — smoke tests
and benches must see the single real device (the dry-run sets its own)."""
import jax
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: compiles through jax/XLA; deselect with -m 'not slow' for a "
        "fast pure-python simulator signal (tier-1 runs everything)")


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def assert_tree_allclose(a, b, rtol=1e-5, atol=1e-5):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb, (ta, tb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)
