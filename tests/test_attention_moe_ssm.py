"""Model-substrate numerics: attention paths, MoE routing, SSD modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow        # jax-compiling numerics sweeps

from repro.configs import ARCHS, reduced_config
from repro.configs.base import ModelConfig, MoEConfig
from repro.models.attention import (blocked_attention, decode_attention,
                                    naive_attention)
from repro.models.moe import apply_moe, capacity, moe_params
from repro.models import params as pr
from repro.models.layers import apply_mlp
from repro.models.ssm import apply_mamba


# ---------------------------------------------------------------- attention
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("sq,sk,h,kvh,block", [
    (64, 64, 4, 4, 16),
    (64, 64, 4, 1, 64),
    (32, 128, 8, 2, 48),         # block not dividing sk (padding)
    (1, 96, 4, 2, 32),           # single query row
])
def test_blocked_vs_naive(sq, sk, h, kvh, block, causal, key):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (2, sq, h, 32), jnp.float32)
    k = jax.random.normal(k2, (2, sk, kvh, 32), jnp.float32)
    v = jax.random.normal(k3, (2, sk, kvh, 32), jnp.float32)
    off = sk - sq if causal else 0
    out = blocked_attention(q, k, v, causal=causal, q_offset=off, block=block)
    want = naive_attention(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_naive_row(key):
    """decode of position p == row p of causal naive attention."""
    B, S, H, KVH, D = 2, 16, 4, 2, 32
    k1, k2, k3 = jax.random.split(key, 3)
    q_all = jax.random.normal(k1, (B, S, H, D), jnp.float32)
    k = jax.random.normal(k2, (B, S, KVH, D), jnp.float32)
    v = jax.random.normal(k3, (B, S, KVH, D), jnp.float32)
    full = naive_attention(q_all, k, v, causal=True)
    p = 7
    out = decode_attention(q_all[:, p:p + 1], k, v, jnp.asarray(p + 1))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, p]),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------- MoE
def _moe_cfg(E=4, top_k=2, cf=2.0, shared=0, kind="swiglu"):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=64, mlp_kind=kind,
        moe=MoEConfig(n_experts=E, top_k=top_k, capacity_factor=cf,
                      n_shared_experts=shared))


def test_moe_output_finite_and_aux_positive(key):
    cfg = _moe_cfg()
    p = pr.init(moe_params(cfg), key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 32))
    out, aux = apply_moe(p, x, cfg, train=True)
    assert out.shape == x.shape
    assert jnp.isfinite(out).all()
    assert float(aux) > 0.0


def test_moe_single_expert_equals_dense(key):
    """E=1 top-1 with capacity >= T must equal a plain MLP of that expert."""
    cfg = _moe_cfg(E=1, top_k=1, cf=float(1))
    # capacity rounds to >= T automatically with cf=1, E=1
    p = pr.init(moe_params(cfg), key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 32))
    out, _ = apply_moe(p, x, cfg, train=False)
    dense_p = {"wi_gate": p["wi_gate"][0], "wi_up": p["wi_up"][0],
               "wo": p["wo"][0]}
    want = apply_mlp(dense_p, x, "swiglu")       # gate prob == 1 for E=1
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens(key):
    """With tiny capacity, combine weights of dropped tokens are zero —
    output rows for dropped tokens come out as zero (plus shared expert)."""
    cfg = _moe_cfg(E=2, top_k=1, cf=0.1)
    T = 64
    C = capacity(T, cfg)
    assert C < T // 2
    p = pr.init(moe_params(cfg), key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, T, 32))
    out, _ = apply_moe(p, x, cfg, train=False)
    zero_rows = int(jnp.sum(jnp.all(jnp.abs(out[0]) < 1e-9, axis=-1)))
    assert zero_rows >= T - 2 * C


def test_moe_shared_expert_added(key):
    cfg_ns = _moe_cfg(shared=0)
    cfg_sh = _moe_cfg(shared=1)
    p = pr.init(moe_params(cfg_sh), key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, 32))
    out_sh, _ = apply_moe(p, x, cfg_sh, train=False)
    p_ns = {k: v for k, v in p.items() if not k.startswith("shared")}
    out_ns, _ = apply_moe(p_ns, x, cfg_ns, train=False)
    shared = {"wi_gate": p["shared_wi_gate"], "wi_up": p["shared_wi_up"],
              "wo": p["shared_wo"]}
    want = out_ns + apply_mlp(shared, x, "swiglu")
    np.testing.assert_allclose(np.asarray(out_sh), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_moe_decode_single_token_group(key):
    cfg = _moe_cfg()
    p = pr.init(moe_params(cfg), key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 1, 32))
    out, _ = apply_moe(p, x, cfg, train=False)
    assert out.shape == (4, 1, 32)
    assert jnp.isfinite(out).all()


# --------------------------------------------------------------------- SSD
def test_mamba_prefill_then_decode_matches_full(key):
    cfg = reduced_config(ARCHS["mamba2-1.3b"])
    m = pr.init({"m": __import__("repro.models.ssm", fromlist=["mamba_params"]
                                 ).mamba_params(cfg)}, key)["m"]
    B, S = 1, 12
    x = 0.3 * jax.random.normal(jax.random.fold_in(key, 1),
                                (B, S, cfg.d_model), jnp.float32)
    full, _ = apply_mamba(m, x, cfg, mode="train")

    pre, cache = apply_mamba(m, x[:, :8], cfg, mode="prefill")
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :8]),
                               rtol=2e-3, atol=2e-3)
    outs = []
    for t in range(8, S):
        y, cache = apply_mamba(m, x[:, t:t + 1], cfg, mode="decode",
                               cache=cache)
        outs.append(y[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, 8:]),
                               rtol=5e-3, atol=5e-3)
