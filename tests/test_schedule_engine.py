"""Golden regression tests for the dependency-aware O3 schedule engine.

Pure-python (canned HLO text, no jax compilation): fast tier-1 signal.
The two fixtures pin the engine's defining behaviours:

  (a) an *independent* DMA/compute pair overlaps — t_est < t_serial,
  (b) a strict dependency chain serializes — t_est == t_serial,

and in every case the sandwich invariant holds:

      t_roofline <= t_est(schedule) <= t_serial
"""
import pytest

from repro.core.engine import simulate_program
from repro.core.hlo import OpStat, Program, parse_program
from repro.core.hwspec import TPU_V5E
from repro.core.schedule import schedule_program
from repro.core.simulate import simulate

# (a) a big HBM copy and a big dot with no edge between them: XLA would
# issue the copy as an async DMA under the matmul.
INDEP_HLO = """
HloModule indep, num_partitions=1

ENTRY %main (p0: f32[4096,4096], p1: f32[134217728]) -> (f32[4096,4096], f32[134217728]) {
  %p0 = f32[4096,4096] parameter(0)
  %p1 = f32[134217728] parameter(1)
  %big = f32[134217728] copy(%p1)
  %dot = f32[4096,4096] dot(%p0, %p0), lhs_contracting_dims={1}
  ROOT %t = (f32[4096,4096], f32[134217728]) tuple(%dot, %big)
}
"""

# (b) dot -> exp -> dot -> reduce: every op consumes its predecessor.
CHAIN_HLO = """
HloModule chain, num_partitions=1

ENTRY %main (p0: f32[4096,4096]) -> f32[4096,4096] {
  %p0 = f32[4096,4096] parameter(0)
  %dot = f32[4096,4096] dot(%p0, %p0), lhs_contracting_dims={1}
  %e = f32[4096,4096] exponential(%dot)
  %dot2 = f32[4096,4096] dot(%e, %e), lhs_contracting_dims={1}
  ROOT %neg = f32[4096,4096] negate(%dot2)
}
"""


def _invariant(r):
    assert r.t_roofline <= r.t_est * (1 + 1e-9), (r.t_roofline, r.t_est)
    assert r.t_est <= r.t_serial * (1 + 1e-9), (r.t_est, r.t_serial)
    assert r.t_dataflow <= r.t_est * (1 + 1e-9)


def test_parser_records_def_use_edges():
    prog = parse_program(CHAIN_HLO)
    by_name = {o.name: o for o in prog.ops}
    idx = {o.name: i for i, o in enumerate(prog.ops)}
    assert by_name["dot"].deps == []
    assert by_name["e"].deps == [idx["dot"]]
    assert by_name["dot2"].deps == [idx["e"]]
    assert by_name["neg"].deps == [idx["dot2"]]


def test_independent_dma_compute_pair_overlaps():
    prog = parse_program(INDEP_HLO)
    r = schedule_program(prog, TPU_V5E)
    _invariant(r)
    # overlap must be schedule-derived and substantial: the makespan is the
    # max of the two tasks, far below their sum
    assert r.t_est < 0.8 * r.t_serial
    ports = {s.port for s in r.timeline}
    assert {"mxu", "mem"} <= ports


def test_dependency_chain_serializes():
    prog = parse_program(CHAIN_HLO)
    r = schedule_program(prog, TPU_V5E)
    _invariant(r)
    # a pure chain leaves nothing to overlap
    assert r.t_est == pytest.approx(r.t_serial, rel=1e-9)
    assert r.t_est == pytest.approx(r.t_dataflow, rel=1e-9)
    # the critical path walks the whole chain
    assert [s.op.name for s in r.critical_path] == ["dot", "e", "dot2", "neg"]
    assert all(s.bound_by in ("ready", "dep") for s in r.critical_path)


def test_sandwich_invariant_under_knob_sweep():
    """t_roofline <= t_est <= t_serial for every O3 knob combination."""
    for hlo in (INDEP_HLO, CHAIN_HLO):
        prog = parse_program(hlo)
        for window in (1, 2, 8, 1024):
            for mem_w in (1, 2, 4):
                for qd in (1, 4, 64):
                    hw = TPU_V5E.with_(
                        inflight_window=window,
                        issue_width={"mxu": 1, "vpu": 1, "mem": mem_w,
                                     "ici": 1},
                        queue_depth={"mxu": qd, "vpu": qd, "mem": qd,
                                     "ici": qd})
                    _invariant(schedule_program(prog, hw))


def test_window_of_one_forces_serial_execution():
    """inflight_window=1 is the in-order machine: nothing overlaps."""
    prog = parse_program(INDEP_HLO)
    r = schedule_program(prog, TPU_V5E.with_(inflight_window=1))
    assert r.t_est == pytest.approx(r.t_serial, rel=1e-9)


def test_mem_issue_width_gates_parallel_dma():
    """Two independent DMAs: width 2 overlaps them, width 1 serializes."""
    ops = [OpStat(f"cp{i}", "copy", "data", "f32", bytes_accessed=1e9)
           for i in range(2)]
    prog = Program(ops=ops, entry="e", n_partitions=1)
    wide = TPU_V5E.with_(issue_width={"mxu": 1, "vpu": 1, "mem": 2, "ici": 1})
    narrow = TPU_V5E.with_(issue_width={"mxu": 1, "vpu": 1, "mem": 1,
                                        "ici": 1})
    t_wide = schedule_program(prog, wide).t_est
    t_narrow = schedule_program(prog, narrow).t_est
    assert t_wide == pytest.approx(t_narrow / 2, rel=1e-6)


def test_queue_depth_throttles_lookahead():
    """Deep chains into one port: queue depth 1 makes op i wait for the
    issue of op i-1 even on a multi-pipe port."""
    ops = [OpStat(f"cp{i}", "copy", "data", "f32", bytes_accessed=1e9)
           for i in range(4)]
    prog = Program(ops=ops, entry="e", n_partitions=1)
    deep = TPU_V5E.with_(issue_width={"mem": 4}, queue_depth={"mem": 4})
    shallow = TPU_V5E.with_(issue_width={"mem": 4}, queue_depth={"mem": 1})
    assert schedule_program(prog, deep).t_est \
        <= schedule_program(prog, shallow).t_est * (1 + 1e-9)


def test_schedule_engine_through_simulate_api():
    """simulate(engine="schedule"): t_est is schedule-derived and the PA
    report gains the critical-path section (ISSUE 1 acceptance)."""
    rep = simulate(INDEP_HLO, hw=TPU_V5E, engine="schedule")
    assert rep.schedule is not None
    assert rep.t_est == rep.schedule.t_est
    assert rep.t_est < 0.8 * rep.schedule.t_serial
    assert "schedule engine (dependency-aware O3)" in rep.pa
    assert "critical path" in rep.pa
    assert "port timeline" in rep.pa

    rep_chain = simulate(CHAIN_HLO, hw=TPU_V5E, engine="schedule")
    assert rep_chain.t_est == pytest.approx(rep_chain.schedule.t_serial,
                                            rel=1e-9)

    # default stays on the fast flat path
    rep_occ = simulate(INDEP_HLO, hw=TPU_V5E)
    assert rep_occ.schedule is None
    assert rep_occ.t_est == rep_occ.engine.t_est
    # json round-trip carries the schedule block
    import json
    d = json.loads(simulate(INDEP_HLO, hw=TPU_V5E, engine="both").to_json())
    assert "schedule" in d and d["schedule"]["n_edges"] >= 0


def test_schedule_and_occupancy_agree_on_serial_time():
    for hlo in (INDEP_HLO, CHAIN_HLO):
        prog = parse_program(hlo)
        e = simulate_program(prog, TPU_V5E)
        s = schedule_program(prog, TPU_V5E)
        assert s.t_serial == pytest.approx(e.t_serial, rel=1e-9)
        assert s.n_ops == e.n_ops


def test_collective_overlap_emerges_without_fudge_factor():
    """An all-reduce independent of the dot overlaps fully in the schedule
    even with ici_overlap=0 — the knob the occupancy engine needs."""
    hlo = """
HloModule coll, num_partitions=4

ENTRY %main (p0: f32[4096,4096], p1: f32[4096,4096]) -> (f32[4096,4096], f32[4096,4096]) {
  %p0 = f32[4096,4096] parameter(0)
  %p1 = f32[4096,4096] parameter(1)
  %ar = f32[4096,4096] all-reduce(%p1), replica_groups=[4,4]<=[16]
  %dot = f32[4096,4096] dot(%p0, %p0), lhs_contracting_dims={1}
  ROOT %t = (f32[4096,4096], f32[4096,4096]) tuple(%dot, %ar)
}
"""
    hw = TPU_V5E.with_(ici_overlap=0.0)
    prog = parse_program(hlo)
    s = schedule_program(prog, hw)
    e = simulate_program(prog, hw)
    # occupancy with ici_overlap=0 adds the collective time end-to-end;
    # the schedule hides it under the dot entirely
    assert s.t_est < e.t_est
    assert s.t_est < 0.8 * s.t_serial
