"""Data pipeline, optimizers, grad compression, sharding rules."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec

from repro.data.synthetic import SyntheticLMDataset, make_batch_iterator
from repro.models.params import P
from repro.parallel.sharding import ACT_RULES, MeshRules, PARAM_RULES
from repro.train.grad_compress import _dequantize_int8, _quantize_int8
from repro.train.optimizer import (OptConfig, adamw_init, adamw_update,
                                   clip_by_global_norm, make_optimizer,
                                   state_spec_tree)
from repro.train.schedule import ScheduleConfig, make_schedule


# ---------------------------------------------------------------------- data
def test_data_deterministic_and_seekable():
    ds = SyntheticLMDataset(vocab_size=512, seq_len=32, global_batch=8)
    a = ds.batch(17)["tokens"]
    b = ds.batch(17)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, ds.batch(18)["tokens"])
    assert a.shape == (8, 32)
    assert a.min() >= 0 and a.max() < 512


def test_data_host_sharding_partitions_global_batch():
    full = SyntheticLMDataset(vocab_size=64, seq_len=8, global_batch=8)
    h0 = dataclasses.replace(full, host_id=0, n_hosts=2)
    h1 = dataclasses.replace(full, host_id=1, n_hosts=2)
    assert h0.host_batch == 4 and h1.host_batch == 4
    # host streams are decorrelated but individually deterministic
    np.testing.assert_array_equal(h0.batch(3)["tokens"],
                                  h0.batch(3)["tokens"])
    assert not np.array_equal(h0.batch(3)["tokens"], h1.batch(3)["tokens"])


def test_data_iterator_resumes():
    ds = SyntheticLMDataset(vocab_size=64, seq_len=8, global_batch=2)
    it = make_batch_iterator(ds, start_step=5, prefetch=2)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], ds.batch(5)["tokens"])


# ----------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    cfg = OptConfig(weight_decay=0.0)
    params = {"x": jnp.asarray(5.0)}
    state = adamw_init(params, cfg)
    for _ in range(300):
        grads = {"x": 2 * params["x"]}         # d/dx x^2
        params, state = adamw_update(grads, state, params, 0.05, cfg)
    assert abs(float(params["x"])) < 1e-2


def test_adafactor_runs_and_factors():
    init, update, cfg = make_optimizer("adafactor")
    params = {"big": jnp.ones((256, 256)), "small": jnp.ones((4,))}
    st_ = init(params)
    assert st_.vr["big"].shape == (256,)        # factored
    assert st_.v["small"].shape == (4,)         # unfactored
    g = jax.tree.map(jnp.ones_like, params)
    p2, st2 = update(g, st_, params, 1e-2)
    assert jnp.isfinite(p2["big"]).all()


def test_state_spec_tree_mirrors_param_sharding():
    specs = {"w": P((256, 512), ("embed", "mlp"))}
    t = state_spec_tree("adamw", specs)
    assert t.mu["w"].shape == (256, 512)
    assert t.mu["w"].axes == ("embed", "mlp")
    ta = state_spec_tree("adafactor", specs)
    assert ta.vr["w"].shape == (256,)
    assert ta.vr["w"].axes == ("embed",)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_schedules_warmup_and_decay():
    cfg = ScheduleConfig(name="cosine", base_lr=1.0, warmup_steps=10,
                         total_steps=100, min_lr_ratio=0.1)
    f = make_schedule(cfg)
    assert float(f(0)) == pytest.approx(0.1)           # warmup ramp
    assert float(f(9)) == pytest.approx(1.0)
    assert float(f(99)) == pytest.approx(0.1, rel=0.1)  # decayed to floor
    for name in ("constant", "linear", "rsqrt"):
        g = make_schedule(dataclasses.replace(cfg, name=name))
        assert 0 < float(g(50)) <= 1.0


# ----------------------------------------------------------- grad compression
@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 1000), scale=st.floats(1e-3, 1e3))
def test_property_int8_quantization_error_bound(n, scale):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    q, s = _quantize_int8(x)
    back = _dequantize_int8(q, s, n)
    err = np.abs(np.asarray(back) - np.asarray(x))
    block_max = np.abs(np.asarray(x)).max() if n else 0.0
    assert err.max() <= block_max / 127.0 + 1e-6


# ------------------------------------------------------------ sharding rules
class FakeMesh:
    """Duck-typed mesh: MeshRules only reads axis_names + devices.shape."""
    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        self.devices = np.zeros(tuple(sizes.values()))


def rules_for(sizes):
    return MeshRules(FakeMesh(sizes))


def test_param_rules_2d_sharding():
    r = rules_for({"data": 16, "model": 16})
    spec = r.param_spec(("embed", "mlp"), (8192, 32768))
    assert spec == PartitionSpec("data", "model")


def test_divisibility_fallback_drops_axis():
    r = rules_for({"data": 16, "model": 16})
    # 40 heads don't divide 16 -> replicated; head_dim picks up model TP
    spec = r.param_spec(("embed", "heads", "head_dim"), (5120, 40, 128))
    assert spec == PartitionSpec("data", None, "model")


def test_uniqueness_one_axis_once():
    r = rules_for({"data": 16, "model": 16})
    spec = r.act_spec(("batch", "kvseq", "kv_heads", "head_dim"),
                      (128, 32768, 8, 128))
    # batch takes data; kvseq takes model; kv_heads/head_dim must NOT reuse
    assert spec == PartitionSpec("data", "model", None, None)


def test_pod_axis_prefix_fallback():
    r = rules_for({"pod": 2, "data": 16, "model": 16})
    # batch 256 divides pod*data=32 -> both; batch 8 only divides... 8%32!=0
    assert r.act_spec(("batch",), (256,)) == PartitionSpec(("pod", "data"))
    # long_500k: batch=1 -> replicated, axes stay free for later dims
    spec = r.act_spec(("batch", "kvseq"), (1, 524288))
    assert spec == PartitionSpec(None, ("model", "data"))


def test_rules_cover_all_logical_axes():
    from repro.configs import ARCHS
    from repro.models.lm import build_model
    for cfg in ARCHS.values():
        model = build_model(cfg)
        for leaf in jax.tree.leaves(model.param_specs(),
                                    is_leaf=lambda x: isinstance(x, P)):
            for ax in leaf.axes:
                if ax is not None:
                    assert ax in PARAM_RULES, (cfg.name, ax)
        for leaf in jax.tree.leaves(model.cache_specs(2, 8),
                                    is_leaf=lambda x: isinstance(x, P)):
            for ax in leaf.axes:
                if ax is not None:
                    assert ax in ACT_RULES, (cfg.name, ax)


# ---------------------------------------------- rule-resolution properties
mesh_st = st.sampled_from([
    {"data": 16, "model": 16},
    {"pod": 2, "data": 16, "model": 16},
    {"data": 8, "model": 4},
    {"data": 1, "model": 1},
])
dims_st = st.lists(st.sampled_from([1, 2, 8, 16, 40, 64, 128, 256, 4096,
                                    32768]), min_size=1, max_size=4)
axes_pool = ["batch", "seq", "rseq", "heads", "kv_heads", "head_dim",
             "mlp", "embed", "vocab", "kvseq", "experts", None]


@settings(max_examples=120, deadline=None)
@given(sizes=mesh_st, dims=dims_st,
       axes=st.lists(st.sampled_from(axes_pool), min_size=4, max_size=4))
def test_property_rules_safe_and_divisible(sizes, dims, axes):
    """For ANY shape x axes combination: (1) a mesh axis is used at most
    once, (2) every assignment divides the dim size, (3) replication is
    always legal (never raises)."""
    r = rules_for(sizes)
    axes = tuple(axes[:len(dims)])
    dims = tuple(dims[:len(axes)])
    spec = r.act_spec(axes, dims)
    used = []
    for d, assignment in zip(dims, spec):
        if assignment is None:
            continue
        names = (assignment,) if isinstance(assignment, str) else assignment
        prod = 1
        for m in names:
            assert m not in used, f"mesh axis {m} used twice: {spec}"
            used.append(m)
            prod *= sizes[m]
        assert d % prod == 0, (dims, axes, spec)


@settings(max_examples=60, deadline=None)
@given(sizes=mesh_st)
def test_property_param_rules_never_shard_contraction_head_dim(sizes):
    """Activations must never shard head_dim (DESIGN.md §10): sharding a
    contraction dim of the score matmul manufactures all-reduces."""
    r = rules_for(sizes)
    spec = r.act_spec(("batch", "seq", "heads", "head_dim"),
                      (256, 4096, 20, 128))
    assert spec[3] is None
