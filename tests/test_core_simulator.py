"""The paper's contribution: HLO parser, engine, roofline, PA, stats.

Includes hypothesis property tests on the simulator's invariants (the
assignment's property-test requirement)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.engine import collective_factor, simulate_program
from repro.core.hlo import OpStat, Program, parse_program
from repro.core.hwspec import SPECS, TPU_V5E
from repro.core.roofline import model_flops, roofline_from_program
from repro.core.simulate import simulate
from repro.core.stats import Stats


# ------------------------------------------------------------ parser, real HLO
def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


@pytest.mark.slow
def test_parser_dot_flops_exact():
    M, K, N = 64, 128, 32
    a = jnp.ones((M, K), jnp.float32)
    b = jnp.ones((K, N), jnp.float32)
    prog = parse_program(_compiled(lambda a, b: a @ b, a, b).as_text())
    dots = [o for o in prog.ops if o.opclass == "matmul"]
    assert len(dots) >= 1
    assert sum(o.flops * o.count for o in dots) == 2 * M * K * N
    mnk = dots[0].dot_dims
    assert sorted(mnk) == sorted((M, N, K))


@pytest.mark.slow
def test_parser_while_trip_multiplication():
    """A scan of T steps must multiply body op costs by T."""
    T, M = 9, 32
    a = jnp.ones((M, M), jnp.float32)

    def f(a):
        def body(c, _):
            return c @ a, None
        out, _ = jax.lax.scan(body, a, None, length=T)
        return out

    prog = parse_program(_compiled(f, a).as_text())
    dot_flops = sum(o.flops * o.count for o in prog.ops
                    if o.opclass == "matmul")
    assert dot_flops == T * 2 * M * M * M


@pytest.mark.slow
def test_parser_transcendental_classification():
    x = jnp.ones((1024,), jnp.float32)
    prog = parse_program(_compiled(lambda x: jnp.exp(x) + jnp.sin(x), x)
                         .as_text())
    tb = {}
    for o in prog.ops:
        for k, v in o.trans_by_opcode.items():
            tb[k] = tb.get(k, 0) + v * o.count
    assert tb.get("exponential", 0) == 1024
    assert tb.get("sine", 0) == 1024


@pytest.mark.slow
def test_parser_dus_inplace_and_slice_reads():
    """Scan emitting per-step rows must NOT count full-buffer traffic per
    step (in-place DUS + sliced reads)."""
    T, M = 16, 256
    xs = jnp.ones((T, M, M), jnp.float32)

    def f(xs):
        def body(c, x):
            return c + x, c[0]          # ys: one row per step
        return jax.lax.scan(body, jnp.zeros((M, M)), xs)

    prog = parse_program(_compiled(f, xs).as_text())
    total_bytes = prog.bytes_accessed
    # full buffer is T*M*M*4 = 4 MiB; per-step slice traffic is ~M*M*4 (x
    # slice + carry read/write + copies).  Without slice/in-place modeling
    # the scan costs ~T * full-buffer = 67 MB; with it, well under half.
    assert total_bytes < 8 * T * M * M * 4


def test_collective_parsing_synthetic():
    hlo = """
HloModule m, num_partitions=16

ENTRY %main (p0: f32[1024,256]) -> f32[1024,256] {
  %p0 = f32[1024,256] parameter(0)
  %ag = f32[1024,256] all-reduce(%p0), replica_groups=[16,16]<=[256]
  ROOT %out = f32[1024,256] add(%ag, %ag)
}
"""
    prog = parse_program(hlo)
    colls = [o for o in prog.ops if o.opclass == "collective"]
    assert len(colls) == 1
    assert colls[0].opcode == "all-reduce"
    assert colls[0].group_size == 16
    assert colls[0].comm_bytes == 1024 * 256 * 4
    assert prog.n_partitions == 16


# ------------------------------------------------------------------- engine
def _mk_op(**kw):
    base = dict(name="x", opcode="dot", opclass="matmul", dtype="bf16")
    base.update(kw)
    return OpStat(**base)


def test_engine_matmul_time():
    o = _mk_op(flops=2 * 1024**3, bytes_accessed=1e6,
               dot_dims=(1024, 1024, 512))
    prog = Program(ops=[o], entry="e", n_partitions=1)
    r = simulate_program(prog, TPU_V5E)
    expect = 2 * 1024**3 / TPU_V5E.matmul_flops("bf16")
    assert r.port_busy["mxu"] == pytest.approx(expect, rel=1e-6)
    assert r.mxu_utilization == 1.0


def test_engine_small_dot_goes_vpu_without_tile_padding():
    o = _mk_op(flops=2 * 64 * 2 * 1000, dot_dims=(64, 1000, 2),
               bytes_accessed=1e5)
    prog = Program(ops=[o], entry="e", n_partitions=1)
    r = simulate_program(prog, TPU_V5E)
    assert r.port_busy.get("mxu", 0.0) == 0.0
    assert r.port_busy["vpu"] < 1e-4     # no 128^3 quantization blowup


def test_engine_collective_ring_factors():
    assert collective_factor("all-reduce", 1) == 0.0
    assert collective_factor("all-reduce", 4) == pytest.approx(1.5)
    assert collective_factor("all-gather", 8) == 7.0
    assert collective_factor("reduce-scatter", 8) == pytest.approx(7 / 8)


# ------------------------------------------------- hypothesis property tests
bytes_st = st.floats(min_value=0, max_value=1e13, allow_nan=False)
flops_st = st.floats(min_value=0, max_value=1e16, allow_nan=False)


@settings(max_examples=60, deadline=None)
@given(f=flops_st, b1=bytes_st, b2=bytes_st)
def test_property_memory_monotonic(f, b1, b2):
    """More bytes on the same program never reduces estimated time."""
    lo, hi = sorted((b1, b2))
    def t(b):
        o = _mk_op(opclass="elementwise", opcode="add", flops=f,
                   bytes_accessed=b, dot_dims=None)
        return simulate_program(Program([o], "e", 1), TPU_V5E).t_est
    assert t(hi) >= t(lo) - 1e-12


@settings(max_examples=60, deadline=None)
@given(g=st.integers(min_value=1, max_value=4096),
       payload=st.floats(min_value=1, max_value=1e12, allow_nan=False))
def test_property_collective_nonnegative_and_bounded(g, payload):
    for kind in ("all-reduce", "all-gather", "reduce-scatter",
                 "all-to-all", "collective-permute"):
        fac = collective_factor(kind, g)
        assert fac >= 0.0
        if kind in ("all-reduce", "reduce-scatter", "all-to-all"):
            assert fac <= 2.0            # wire bytes never exceed 2x payload


@settings(max_examples=40, deadline=None)
@given(c=st.floats(1, 1e6), m=st.floats(1, 1e6), i=st.floats(1, 1e6))
def test_property_roofline_dominant_is_max(c, m, i):
    prog = Program([], "e", 1)
    rf = roofline_from_program(prog, TPU_V5E, 1, 0.0)
    import dataclasses
    rf = dataclasses.replace(rf, compute_s=c, memory_s=m, collective_s=i)
    assert rf.t_bound == max(c, m, i)
    assert {"compute": c, "memory": m, "collective": i}[rf.dominant] \
        == max(c, m, i)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 100), d=st.integers(1, 10_000),
       kind=st.sampled_from(["train", "prefill"]))
def test_property_model_flops(n, d, kind):
    mf = model_flops(n, d, kind)
    assert mf == (6.0 if kind == "train" else 2.0) * n * d


@settings(max_examples=60, deadline=None)
@given(g1=st.integers(min_value=1, max_value=4096),
       g2=st.integers(min_value=1, max_value=4096))
def test_property_collective_factor_monotone_in_group_size(g1, g2):
    """Growing the group never cheapens a collective (ring algorithm)."""
    lo, hi = sorted((g1, g2))
    for kind in ("all-reduce", "all-gather", "reduce-scatter",
                 "all-to-all", "collective-permute"):
        assert collective_factor(kind, lo) <= collective_factor(kind, hi) \
            + 1e-12, (kind, lo, hi)


@settings(max_examples=40, deadline=None)
@given(b=st.floats(min_value=1e3, max_value=1e12, allow_nan=False),
       payload=st.floats(min_value=1e3, max_value=1e12, allow_nan=False),
       g=st.integers(min_value=2, max_value=256))
def test_property_bf16_denormalization_halves_f32_traffic(b, payload, g):
    """compute_dtype='bf16' must cost f32 bytes AND collective payloads at
    half width (the inverted XLA:CPU float-normalization, DESIGN.md §7).

    Exact 2x halving holds because TPU_V5E is a scratch-memory spec
    (warm_caches=False): cold traffic always routes to HBM, so full- and
    half-width bytes see the same bandwidth at every size (hierarchy
    routing itself is pinned by tests/test_memory_hierarchy.py)."""
    ew = _mk_op(opclass="elementwise", opcode="add", dtype="f32",
                flops=0.0, bytes_accessed=b, dot_dims=None)
    coll = _mk_op(name="ar", opclass="collective", opcode="all-reduce",
                  dtype="f32", comm_bytes=payload, group_size=g,
                  dot_dims=None)
    prog = Program([ew, coll], "e", 1)
    full = simulate_program(prog, TPU_V5E, compute_dtype=None)
    half = simulate_program(prog, TPU_V5E, compute_dtype="bf16")
    assert half.port_busy["mem"] == pytest.approx(
        0.5 * full.port_busy["mem"], rel=1e-9)
    startup = TPU_V5E.collective_startup_us * 1e-6
    assert half.port_busy["ici"] - startup == pytest.approx(
        0.5 * (full.port_busy["ici"] - startup), rel=1e-9)
    # bf16-native ops are untouched
    bf = _mk_op(opclass="elementwise", opcode="add", dtype="bf16",
                flops=0.0, bytes_accessed=b, dot_dims=None)
    prog_bf = Program([bf], "e", 1)
    assert simulate_program(prog_bf, TPU_V5E, compute_dtype="bf16").t_est \
        == pytest.approx(simulate_program(prog_bf, TPU_V5E).t_est, rel=1e-9)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 15))
def test_property_port_busy_totals_equal_sum_over_ops(seed, n):
    """port_busy is an exact per-op sum: totals must match the per-op
    OpTime decomposition (<=20 ops so top_ops holds all of them)."""
    rng = np.random.default_rng(seed)
    classes = [("matmul", "dot"), ("elementwise", "add"), ("data", "copy"),
               ("collective", "all-reduce"), ("transcendental",
                                              "exponential")]
    ops = []
    for i in range(n):
        cls, opc = classes[rng.integers(len(classes))]
        ops.append(_mk_op(
            name=f"o{i}", opclass=cls, opcode=opc, dtype="f32",
            flops=float(rng.integers(1, 10**9)),
            bytes_accessed=float(rng.integers(1, 10**9)),
            comm_bytes=float(rng.integers(1, 10**9)),
            group_size=int(rng.integers(1, 64)),
            count=float(rng.integers(1, 10)), dot_dims=None))
    r = simulate_program(Program(ops, "e", 1), TPU_V5E)
    for port in ("mxu", "vpu"):
        want = sum(t.t_compute * t.op.count for t in r.top_ops
                   if t.port == port)
        assert r.port_busy.get(port, 0.0) == pytest.approx(want, rel=1e-9, abs=1e-18)
    assert r.port_busy["mem"] == pytest.approx(
        sum(t.t_mem * t.op.count for t in r.top_ops), rel=1e-9, abs=1e-18)
    assert r.port_busy["ici"] == pytest.approx(
        sum(t.t_ici * t.op.count for t in r.top_ops), rel=1e-9, abs=1e-18)
    assert sum(r.by_class_time.values()) == pytest.approx(
        r.t_serial - r.startup, rel=1e-9)


# ------------------------------------------------------------------ simulate
@pytest.mark.slow
def test_simulate_end_to_end_small_matmul():
    a = jnp.ones((256, 256), jnp.bfloat16)
    compiled = _compiled(lambda a: a @ a, a)
    rep = simulate(compiled, hw=TPU_V5E, n_chips=1,
                   model_flops_global=2 * 256**3)
    assert rep.roofline.compute_s > 0
    assert rep.roofline.useful_flops_ratio == pytest.approx(1.0, rel=0.2)
    assert "PA report" in rep.pa
    assert rep.t_est > 0


def test_hwspec_registry():
    assert {"tpu_v5e", "tpu_v4", "a64fx_cmg", "a64fx_core",
            "cpu_host"} <= set(SPECS)
    assert SPECS["tpu_v5e"].peak_flops["bf16"] == 197e12
    assert SPECS["tpu_v5e"].hbm_read_bw == 819e9
    assert SPECS["tpu_v5e"].ici_bw_per_link == 50e9


# -------------------------------------------------------------------- stats
def test_stats_sections_and_delta():
    s = Stats()
    with s.section("warmup"):
        s.add("steps", 3)
    with s.section("steady"):
        s.add("steps", 10)
        s.add("tokens", 100)
    assert s.get("steps") == 13                  # global accumulates
    assert s.get("steps", "steady") == 10
    d = s.delta("steady", "warmup")
    assert d["steps"] == 7
    assert d["tokens"] == 100
    assert "warmup" in s.report() and "steady" in s.report()


def test_stats_nested_sections_credit_enclosing():
    """add() credits the FULL active stack: an enclosing section sees its
    nested sections' counters (regression — only the innermost section
    and __global__ used to be credited)."""
    s = Stats()
    with s.section("steady"):
        s.add("steps", 2)
        with s.section("batch"):
            s.add("steps", 5)
            s.add("tokens", 50)
    assert s.get("steps", "steady") == 7         # encloser sees nested
    assert s.get("tokens", "steady") == 50
    assert s.get("steps", "batch") == 5
    assert s.get("steps") == 7                   # global credited once
    # recursive re-entry is credited once, not twice
    with s.section("steady"):
        with s.section("steady"):
            s.add("steps", 1)
    assert s.get("steps", "steady") == 8
    assert s.get("steps") == 8


def test_stats_wall_time_matches_counter_semantics():
    """section() wall-time attribution is consistent with add():
    enclosing sections see nested wall time, recursive re-entry is
    credited once (at the outermost exit), and __global__ accumulates
    top-level wall time (regression — wall_s used to credit only the
    exited name, double-counting recursion and never reaching
    __global__)."""
    s = Stats()
    with s.section("outer"):
        time.sleep(0.01)
        with s.section("inner"):
            time.sleep(0.01)
    outer = s.get("wall_s", "outer")
    inner = s.get("wall_s", "inner")
    assert inner >= 0.01
    assert outer >= inner + 0.01          # encloser spans nested wall
    # __global__ sees exactly the top-level section's wall
    assert s.get("wall_s") == outer
    assert s.get("entries", "outer") == 1
    assert s.get("entries", "inner") == 1

    # recursive re-entry: credited once, at the outermost exit
    r = Stats()
    with r.section("loop"):
        time.sleep(0.01)
        with r.section("loop"):
            time.sleep(0.01)
    wall = r.get("wall_s", "loop")
    assert wall >= 0.02                   # the outermost dt, once
    assert wall < 0.2                     # not inner+outer double-counted
    assert r.get("entries", "loop") == 1
    assert r.get("wall_s") == wall
