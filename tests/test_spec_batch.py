"""Differential + property suite for the spec batch axis (DESIGN.md §19).

Pins the fused program × spec × knob pipeline bit-identical to the
per-spec scalar path:

* ``route_program_batch`` column s == ``route_program`` under spec s's
  hierarchy (times, per-level byte tallies) over random DAGs and both
  warm-cache and scratch-memory hierarchies;
* ``cost_program_batch`` column s == the ``cost_program`` loop (ports,
  compute/mem/ICI times), incl. collectives, per-opcode tables, denormal
  compute dtypes and degenerate 1-spec grids;
* ``schedule_spec_sweep`` == per-spec ``compile_node`` +
  ``schedule_node_batch`` loops (t_est / t_zero_contention / iterations);
* ``O3Knobs.unique`` dedup maps results back to the full grid;
* grid/structural validation, cache-identity regressions, and spec-axis
  monotonicity properties (bandwidth, flops, core count);
* spec-fuzz finiteness on extreme points (1-CMG, zero ring latency,
  g=1 collectives, zero ICI bandwidth).
"""
import dataclasses
import random

import numpy as np
import pytest

from repro.core.compiled import O3Knobs, schedule_batch
from repro.core.cost import cost_op, cost_program, cost_program_batch
from repro.core.hlo import OpStat, Program
from repro.core.hwspec import (A64FX_CORE, CPU_HOST, TPU_V5E, NodeTopology,
                               SpecGrid)
from repro.core.memory import route_program, route_program_batch
from repro.core.node import (compile_node, compile_node_batch,
                             compile_node_grid, schedule_node_batch,
                             schedule_node_sweep, schedule_spec_sweep)

from tests.test_compiled_schedule import random_knobs, random_program


# --------------------------------------------------------- generators
def rich_random_program(rng: random.Random, n: int) -> Program:
    """random_program's mix plus collectives, dot_dims matmuls and
    per-opcode latency-table entries — everything cost_op branches on."""
    prog = random_program(rng, n)
    for i, o in enumerate(prog.ops):
        r = rng.random()
        if r < 0.15:
            kind = rng.choice(["all-reduce", "all-gather", "reduce-scatter",
                               "all-to-all", "collective-permute"])
            prog.ops[i] = dataclasses.replace(
                o, opcode=kind, opclass="collective",
                comm_bytes=rng.choice([0.0, rng.uniform(1e3, 1e8)]),
                group_size=rng.choice([1, 2, 8]))
        elif o.opclass == "matmul" and r < 0.6:
            prog.ops[i] = dataclasses.replace(
                o, dot_dims=(rng.choice([1, 4, 96, 256]),
                             rng.choice([4, 128, 512]),
                             rng.choice([4, 128, 512])))
        elif o.opclass in ("elementwise", "reduce", "transcendental"):
            trans = {rng.choice(["exponential", "tanh", "sine"]):
                     rng.uniform(0, 1e3)} if rng.random() < 0.5 else {}
            vpu = {rng.choice(["minimum", "divide", "round-nearest-even"]):
                   rng.uniform(0, 1e3)} if rng.random() < 0.5 else {}
            prog.ops[i] = dataclasses.replace(
                o, trans_by_opcode=trans, vpu_by_opcode=vpu)
    return prog


def _vary(rng: random.Random, base, s: int):
    """One numeric variant of ``base`` (structure untouched)."""
    kw = dict(
        name=f"{base.name}_v{s}",
        transcendental_factor=base.transcendental_factor
        * rng.uniform(0.5, 2.0),
        peak_flops={k: v * rng.uniform(0.25, 4.0)
                    for k, v in base.peak_flops.items()},
        vpu_flops={k: v * rng.uniform(0.25, 4.0)
                   for k, v in base.vpu_flops.items()},
        hbm_read_bw=base.hbm_read_bw * rng.uniform(0.25, 4.0),
        hbm_write_bw=base.hbm_write_bw * rng.uniform(0.25, 4.0),
        vmem_bw=base.vmem_bw * rng.uniform(0.5, 2.0),
        ici_bw_per_link=base.ici_bw_per_link
        * rng.choice([0.0, 0.1, 1.0, 4.0]),
        collective_startup_us=base.collective_startup_us
        * rng.uniform(0.1, 2.0),
        op_startup_ns=base.op_startup_ns * rng.uniform(0.5, 2.0),
    )
    if rng.random() < 0.5:
        kw["opcode_factor"] = {k: v * rng.uniform(0.5, 2.0)
                               for k, v in base.opcode_factor.items()
                               if rng.random() < 0.7}
    if rng.random() < 0.5:
        kw["opclass_throughput"] = {"reduce": rng.uniform(0.5, 1.0),
                                    "elementwise": rng.uniform(0.8, 1.2)}
    sp = base.with_(**kw)
    if sp.mem_levels and rng.random() < 0.7:
        lv = tuple(dataclasses.replace(
            l, capacity=l.capacity * rng.choice([0.25, 1.0, 4.0]),
            read_bw=l.read_bw * rng.uniform(0.5, 2.0),
            write_bw=l.write_bw * rng.uniform(0.5, 2.0),
            latency_s=l.latency_s * rng.uniform(0.0, 2.0))
            for l in sp.mem_levels)
        sp = sp.with_(mem_levels=lv)
    return sp


def random_grid(rng: random.Random, S: int, base=None) -> SpecGrid:
    base = base or rng.choice([A64FX_CORE, CPU_HOST, TPU_V5E])
    return SpecGrid([_vary(rng, base, s) for s in range(S)])


# ------------------------------------------------ routing differential
@pytest.mark.parametrize("seed", range(4))
def test_route_batch_bit_identical(seed):
    rng = random.Random(seed)
    prog = rich_random_program(rng, 60)
    grid = random_grid(rng, 5)
    tb = route_program_batch(prog, grid.hierarchies(),
                             warm_caches=grid.warm_caches)
    assert tuple(tb.level_names) == grid.level_names
    names = list(grid.level_names)
    for s, sp in enumerate(grid.specs):
        ref = route_program(prog, sp.memory_hierarchy(),
                            warm_caches=sp.warm_caches)
        for i, tr in enumerate(ref):
            assert tb.t_read[i, s] == tr.t_read
            assert tb.t_write[i, s] == tr.t_write
            assert tb.latency[i, s] == tr.latency_s
            assert tb.t_mem[i, s] == tr.t_mem
            for k, nm in enumerate(names):
                assert tb.read_by_level[i, k, s] == \
                    tr.read_by_level.get(nm, 0.0)
                assert tb.write_by_level[i, k, s] == \
                    tr.write_by_level.get(nm, 0.0)


def test_route_batch_compute_dtype_and_empty():
    rng = random.Random(11)
    prog = rich_random_program(rng, 40)
    grid = random_grid(rng, 3, base=TPU_V5E)
    tb = route_program_batch(prog, grid.hierarchies(), compute_dtype="bf16",
                             warm_caches=grid.warm_caches)
    for s, sp in enumerate(grid.specs):
        ref = route_program(prog, sp.memory_hierarchy(),
                            compute_dtype="bf16",
                            warm_caches=sp.warm_caches)
        for i, tr in enumerate(ref):
            assert tb.t_mem[i, s] == tr.t_mem
    empty = route_program_batch(Program(ops=[], entry="e", n_partitions=1),
                                grid.hierarchies())
    assert empty.t_read.shape == (0, 3)
    with pytest.raises(ValueError):
        route_program_batch(prog, [])
    with pytest.raises(ValueError):
        route_program_batch(prog, [A64FX_CORE.memory_hierarchy(),
                                   TPU_V5E.memory_hierarchy()])


# --------------------------------------------------- cost differential
def _assert_cost_column_matches(prog, grid, bc, s, compute_dtype=None,
                                links=2):
    sp = grid.specs[s]
    ref = cost_program(prog, sp, links_per_collective=links,
                       compute_dtype=compute_dtype)
    names = list(grid.level_names)
    for i, ot in enumerate(ref):
        if ot is None:
            assert bc.port[i] is None
            assert bc.t_compute[i, s] == 0.0
            assert bc.t_mem[i, s] == 0.0
            assert bc.t_ici[i, s] == 0.0
            continue
        assert bc.port[i] == ot.port
        assert bc.count[i] == ot.op.count
        assert bc.t_compute[i, s] == ot.t_compute
        assert bc.t_mem[i, s] == ot.t_mem
        assert bc.t_ici[i, s] == ot.t_ici
        assert bc.t_op()[i, s] == ot.t_op
        if ot.traffic is None:        # collectives carry no memory traffic
            assert not bc.rd[i, :, s].any()
            assert not bc.wr[i, :, s].any()
        else:
            for k, nm in enumerate(names):
                assert bc.rd[i, k, s] == ot.traffic.read_by_level.get(nm, 0.0)
                assert bc.wr[i, k, s] == \
                    ot.traffic.write_by_level.get(nm, 0.0)


@pytest.mark.parametrize("seed", range(4))
def test_cost_batch_bit_identical(seed):
    rng = random.Random(100 + seed)
    prog = rich_random_program(rng, 60)
    grid = random_grid(rng, 5)
    bc = cost_program_batch(prog, grid)
    for s in range(grid.S):
        _assert_cost_column_matches(prog, grid, bc, s)


def test_cost_batch_compute_dtype_and_links():
    rng = random.Random(7)
    prog = rich_random_program(rng, 50)
    grid = random_grid(rng, 4, base=TPU_V5E)
    bc = cost_program_batch(prog, grid, links_per_collective=4,
                            compute_dtype="bf16")
    for s in range(grid.S):
        _assert_cost_column_matches(prog, grid, bc, s,
                                    compute_dtype="bf16", links=4)


def test_cost_batch_degenerate_single_spec():
    rng = random.Random(21)
    prog = rich_random_program(rng, 45)
    grid = SpecGrid([A64FX_CORE])
    bc = cost_program_batch(prog, grid)
    assert grid.S == 1
    _assert_cost_column_matches(prog, grid, bc, 0)


# ------------------------------------------------- grid validation
def test_spec_grid_rejects_structural_mismatch():
    with pytest.raises(ValueError):
        SpecGrid([])
    with pytest.raises(ValueError):
        SpecGrid([A64FX_CORE, TPU_V5E])           # level names differ
    with pytest.raises(ValueError):
        SpecGrid([CPU_HOST, CPU_HOST.with_(warm_caches=False)])
    with pytest.raises(ValueError):
        SpecGrid([TPU_V5E, TPU_V5E.with_(mxu_tile=(8, 8, 8))])
    with pytest.raises(ValueError):
        SpecGrid([TPU_V5E], topologies=[None, None])
    g1 = SpecGrid([A64FX_CORE, A64FX_CORE.with_(hbm_read_bw=1e9)])
    g2 = SpecGrid([A64FX_CORE, A64FX_CORE.with_(hbm_read_bw=1e9)])
    assert g1 == g2                                # value equality
    assert g1 != SpecGrid([A64FX_CORE])
    assert g1.topology_of(0).n_cores == 48
    assert SpecGrid([TPU_V5E]).topology_of(0).n_cores == 1


# --------------------------------------------- spec-axis monotonicity
def test_bandwidth_monotonicity_along_spec_axis():
    rng = random.Random(31)
    prog = rich_random_program(rng, 50)
    scales = [0.25, 0.5, 1.0, 2.0, 4.0]
    specs = []
    for s, sc in enumerate(scales):
        lv = tuple(dataclasses.replace(l, read_bw=l.read_bw * sc,
                                       write_bw=l.write_bw * sc)
                   for l in A64FX_CORE.mem_levels)
        specs.append(A64FX_CORE.with_(name=f"bw{s}", mem_levels=lv))
    bc = cost_program_batch(prog, SpecGrid(specs))
    # more bandwidth everywhere => per-op memory time never increases
    assert (np.diff(bc.t_mem, axis=1) <= 1e-18).all()


def test_flops_monotonicity_along_spec_axis():
    rng = random.Random(32)
    prog = rich_random_program(rng, 50)
    specs = [A64FX_CORE.with_(
        name=f"fl{s}",
        peak_flops={k: v * sc for k, v in A64FX_CORE.peak_flops.items()},
        vpu_flops={k: v * sc for k, v in A64FX_CORE.vpu_flops.items()})
        for s, sc in enumerate([0.5, 1.0, 2.0, 4.0])]
    bc = cost_program_batch(prog, SpecGrid(specs))
    assert (np.diff(bc.t_compute, axis=1) <= 1e-18).all()


# ---------------------------------------------------- spec-fuzz edges
@pytest.mark.parametrize("seed", range(6))
def test_extreme_spec_fuzz_finite(seed):
    """Extreme DSE corners (1-CMG, zero ring latency, g=1 collectives,
    zero ICI bandwidth, tiny caches) must cost finite non-negative."""
    rng = random.Random(500 + seed)
    prog = rich_random_program(rng, 40)
    base = A64FX_CORE
    lv = tuple(dataclasses.replace(
        l, capacity=max(l.capacity * rng.choice([1e-6, 1.0]), 1.0))
        for l in base.mem_levels)
    sp = base.with_(
        name=f"fuzz{seed}", mem_levels=lv,
        ici_bw_per_link=rng.choice([1e3, 1e10]),
        collective_startup_us=rng.choice([0.0, 10.0]),
        topology=NodeTopology(name="t1", n_cmgs=1,
                              cores_per_cmg=rng.choice([1, 8]),
                              ring_latency_s=0.0))
    costed = cost_program(prog, sp)
    for ot in costed:
        if ot is None:
            continue
        for v in (ot.t_compute, ot.t_mem, ot.t_ici):
            assert np.isfinite(v) and v >= 0.0
    grid = SpecGrid([sp, base])
    bc = cost_program_batch(prog, grid)
    for arr in (bc.t_compute, bc.t_mem, bc.t_ici, bc.latency):
        assert np.isfinite(arr).all() and (arr >= 0.0).all()


# ------------------------------------------------- knob-grid dedup
def test_o3knobs_unique_dedup_and_restore():
    w = np.array([1, 7, 1, 7, 3], dtype=np.int64)
    width = np.ones((5, 4), dtype=np.int64)
    depth = np.ones((5, 4), dtype=np.int64)
    width[4, 2] = 2
    k = O3Knobs(w, width, depth)
    uk, inv = k.unique()
    assert uk.batch == 3
    assert uk.window.tolist() == [1, 7, 3]       # first-occurrence order
    assert (uk.window[inv] == w).all()
    assert (uk.width[inv] == width).all()
    assert (uk.depth[inv] == depth).all()
    k2 = O3Knobs(np.array([1, 2], dtype=np.int64),
                 np.ones((2, 4), dtype=np.int64),
                 np.ones((2, 4), dtype=np.int64))
    uk2, inv2 = k2.unique()
    assert uk2 is k2 and (inv2 == np.arange(2)).all()


def test_schedule_batch_dedup_matches_per_combo():
    from repro.core.compiled import compile_program
    rng = random.Random(55)
    prog = random_program(rng, 80)
    hw = A64FX_CORE
    cp = compile_program(prog, hw)
    # (0, ...) clamps onto (1, ...): rows 0, 1, 3 alias
    knobs = O3Knobs.from_grid(hw, [(1, 1, 1, 4), (1, 1, 1, 4),
                                   (64, 2, 2, 16), (0, 1, 1, 4)])
    t = schedule_batch(cp, knobs)
    assert t[0] == t[1] == t[3]
    for b in range(knobs.batch):
        single = O3Knobs(knobs.window[b:b + 1], knobs.width[b:b + 1],
                         knobs.depth[b:b + 1])
        assert schedule_batch(cp, single)[0] == t[b]


def test_node_batch_dedup_and_pass_accounting():
    rng = random.Random(66)
    prog = random_program(rng, 50)
    sp = A64FX_CORE
    nc = compile_node(prog, sp)
    dup = O3Knobs.from_grid(sp, [(1, 1, 1, 4), (1, 1, 1, 4),
                                 (64, 2, 2, 16)])
    uniq = O3Knobs.from_grid(sp, [(1, 1, 1, 4), (64, 2, 2, 16)])
    res = schedule_node_batch(nc, sp, dup, 12, partition="shard")
    ref = schedule_node_batch(nc, sp, uniq, 12, partition="shard")
    assert res.t_est[0] == res.t_est[1] == ref.t_est[0]
    assert res.t_est[2] == ref.t_est[1]
    assert len(res.iterations) == 3
    # accounting counts passes actually run, not the expanded grid
    assert res.total_scheduled_ops == ref.total_scheduled_ops
    sw = schedule_node_sweep(nc, sp, dup, [1, 12], partition="shard")
    swu = schedule_node_sweep(nc, sp, uniq, [1, 12], partition="shard")
    assert (sw[:, [0, 2]] == swu).all()
    assert (sw[:, 0] == sw[:, 1]).all()


# ----------------------------------------------- fused spec-axis sweep
def _node_grid(rng: random.Random, S: int) -> SpecGrid:
    """A64FX-structured grid with per-spec numerics AND topologies."""
    specs = []
    for s in range(S):
        sp = _vary(rng, A64FX_CORE, s)
        topo = NodeTopology(
            name=f"t{s}", n_cmgs=rng.choice([1, 2, 4]), cores_per_cmg=12,
            shared_read_bw={"l2": rng.uniform(0.5, 2.0) * 900e9,
                            "hbm2": rng.uniform(0.5, 2.0) * 256e9},
            shared_write_bw={"l2": rng.uniform(0.5, 2.0) * 450e9,
                             "hbm2": rng.uniform(0.5, 2.0) * 256e9},
            ring_latency_s=rng.choice([0.0, 130e-9]), ring_bw=115e9)
        specs.append(sp.with_(topology=topo))
    return SpecGrid(specs)


@pytest.mark.parametrize("seed", range(3))
def test_spec_sweep_bit_identical_to_per_spec_loop(seed):
    rng = random.Random(900 + seed)
    prog = rich_random_program(rng, 40)
    grid = _node_grid(rng, 4)
    knobs = O3Knobs.from_specs([random_knobs(rng) for _ in range(3)])
    counts = [[1, min(12, grid.topology_of(s).n_cores)]
              for s in range(grid.S)]
    ngc = compile_node_grid(prog, grid)
    t = schedule_spec_sweep(ngc, knobs, core_counts=counts)
    assert t.shape == (grid.S, 2, 3)
    for s, sp in enumerate(grid.specs):
        nc = compile_node(prog, sp)
        # the grid's per-spec view carries the scalar pipeline's arrays
        assert (nc.cp.durations == ngc.durations0[:, s]).all()
        assert (nc.rd == ngc.views[s].rd).all()
        assert (nc.wr == ngc.views[s].wr).all()
        for c, k in enumerate(counts[s]):
            ref = schedule_node_batch(nc, sp, knobs, k,
                                      topology=grid.topologies[s],
                                      partition="shard")
            assert (t[s, c] == ref.t_est).all()


def test_spec_sweep_defaults_and_validation():
    rng = random.Random(44)
    prog = rich_random_program(rng, 30)
    grid = _node_grid(rng, 3)
    ngc = compile_node_grid(prog, grid)
    t = schedule_spec_sweep(ngc)          # per-spec full core count, C=1
    assert t.shape == (3, 1, 1)
    for s, sp in enumerate(grid.specs):
        nc = compile_node(prog, sp)
        ref = schedule_node_batch(nc, sp, O3Knobs.single(grid.specs[0]),
                                  grid.topology_of(s).n_cores,
                                  topology=grid.topologies[s],
                                  partition="shard")
        assert t[s, 0, 0] == ref.t_est[0]
    with pytest.raises(ValueError):
        schedule_spec_sweep(ngc, core_counts=[[1], [1]])   # ragged rows
    with pytest.raises(ValueError):
        schedule_spec_sweep(ngc, core_counts=[10_000])     # over topology


def test_spec_sweep_contention_monotone_in_shared_bandwidth():
    rng = random.Random(45)
    prog = rich_random_program(rng, 40)
    scales = [0.25, 0.5, 1.0, 2.0]
    topos = [NodeTopology(name=f"bw{i}", n_cmgs=4, cores_per_cmg=12,
                          shared_read_bw={"l2": sc * 900e9,
                                          "hbm2": sc * 256e9},
                          shared_write_bw={"l2": sc * 450e9,
                                           "hbm2": sc * 256e9})
             for i, sc in enumerate(scales)]
    grid = SpecGrid([A64FX_CORE.with_(name=f"s{i}", topology=tp)
                     for i, tp in enumerate(topos)])
    t = schedule_spec_sweep(compile_node_grid(prog, grid),
                            core_counts=[48])
    # more aggregate bandwidth at every shared level: never slower
    assert (np.diff(t[:, 0, 0]) <= 1e-12).all()


# -------------------------------------------------- compile caches
def test_compile_node_grid_cache_hit_and_no_alias():
    rng = random.Random(77)
    prog = random_program(rng, 30)
    g1 = SpecGrid([A64FX_CORE, A64FX_CORE.with_(hbm_read_bw=32e9)])
    ngc1 = compile_node_grid(prog, g1)
    # a VALUE-equal rebuilt grid hits the cache
    g1b = SpecGrid([A64FX_CORE, A64FX_CORE.with_(hbm_read_bw=32e9)])
    assert compile_node_grid(prog, g1b) is ngc1
    assert compile_node_grid(prog, g1, compute_dtype="bf16") is not ngc1
    ngc2 = compile_node_grid(prog, SpecGrid([A64FX_CORE]))
    assert ngc2 is not ngc1
    # a 1-spec grid compile never aliases the single-spec caches: the
    # scalar pipeline still compiles (and caches) its own entry, and the
    # two agree bitwise
    nc = compile_node(prog, A64FX_CORE)
    assert nc is not ngc2.views[0]
    assert nc is compile_node(prog, A64FX_CORE)       # scalar cache intact
    assert (nc.cp.durations == ngc2.durations0[:, 0]).all()
    assert (nc.t_comp == ngc2.views[0].t_comp).all()


def test_compile_node_batch_cache():
    rng = random.Random(78)
    prog = random_program(rng, 30)
    nc = compile_node(prog, A64FX_CORE)
    nb1 = compile_node_batch(nc, A64FX_CORE, 12, partition="shard")
    # shard structure is core-count independent: one cached form
    assert compile_node_batch(nc, A64FX_CORE, 48, partition="shard") \
        is nb1
    nb3 = compile_node_batch(nc, A64FX_CORE, 12, partition="round-robin")
    assert compile_node_batch(nc, A64FX_CORE, 12,
                              partition="round-robin") is nb3
    assert nb3 is not nb1
    # op partitions depend on the count: distinct entries
    assert compile_node_batch(nc, A64FX_CORE, 24,
                              partition="round-robin") is not nb3
    # a different topology VALUE gets its own entry
    assert compile_node_batch(nc, A64FX_CORE, 12,
                              topology=NodeTopology.degenerate(12),
                              partition="shard") is not nb1
    # explicit core_of bypasses the cache
    co = np.zeros(nc.n, dtype=np.int64)
    nb5 = compile_node_batch(nc, A64FX_CORE, 12, core_of=co)
    assert nb5 is not compile_node_batch(nc, A64FX_CORE, 12, core_of=co)


def test_g1_collective_zero_ici_bw_charges_startup_only():
    o = OpStat("c", "all-reduce", "collective", "f32", comm_bytes=1e6,
               group_size=1)
    sp = A64FX_CORE.with_(ici_bw_per_link=0.0)
    ot = cost_op(o, sp, ici_bw=0.0)
    assert ot.t_ici == sp.collective_startup_us * 1e-6
    # a real payload over a zero-bandwidth link is cleanly infeasible:
    # inf (never ZeroDivisionError), identically in both pipelines
    o2 = dataclasses.replace(o, group_size=8)
    ot2 = cost_op(o2, sp, ici_bw=0.0)
    assert ot2.t_ici == np.inf
    prog = Program(ops=[o, o2], entry="e", n_partitions=1)
    bc = cost_program_batch(prog, SpecGrid([sp, A64FX_CORE]))
    assert bc.t_ici[0, 0] == sp.collective_startup_us * 1e-6
    assert bc.t_ici[1, 0] == np.inf
    assert np.isfinite(bc.t_ici[:, 1]).all()


@pytest.mark.slow
def test_spec_batch_differential_on_kernel_suite_programs():
    """Acceptance: ``cost_program_batch`` columns (times, routed traffic,
    ports) are bit-identical to the scalar per-spec path on every
    compiled kernel-suite program — real XLA HLO, not just synthetic
    DAGs."""
    from jax.experimental import enable_x64 as jax_enable_x64

    from repro.configs.a64fx_kernelsuite import KERNELS
    from repro.core import calibrate
    from repro.core.hlo import parse_program

    rng = random.Random(11)
    grid = random_grid(rng, 3, base=CPU_HOST)
    with jax_enable_x64():
        for k in KERNELS:
            x1, x2, y0 = calibrate._kernel_inputs(k, k.n)
            f = calibrate._jit_kernel(k.name)
            prog = parse_program(f.lower(x1, x2, y0).compile().as_text())
            bc = cost_program_batch(prog, grid, compute_dtype="f64")
            for s in range(grid.S):
                _assert_cost_column_matches(prog, grid, bc, s,
                                            compute_dtype="f64")
