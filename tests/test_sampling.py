"""SimPoint-style sampled estimation tests (ISSUE 7, DESIGN.md §18).

Differential/property layers:

* **slicing/featurizing/clustering** — partition invariants, signature
  scaling, deterministic seeded k-means;
* **exactness** — sampling with k = n_intervals is bit-identical to full
  interval scheduling (every interval its own cluster), on synthetic and
  unrolled traces;
* **determinism** — a fixed seed reproduces the plan and the estimate
  bit-for-bit across runs;
* **convergence** — family-mean reconstruction error is monotonically
  non-increasing along a geometric k chain on a random-DAG family
  (per-instance adjacent-k monotonicity is NOT a k-means guarantee —
  the clustering optimizes signature space, not time space — so the
  property is pinned as the mean over seeds on k = 1, 4, 16, n);
* **accuracy pin** — the acceptance criterion: <= 5% reconstruction
  error vs the monolithic full schedule while scheduling <= 20% of op
  instances, on the repetitive 10k-op bench DAG and on a real unrolled
  zoo decode trace (jax);
* **plumbing** — ``simulate(engine="node", sampling=...)`` and
  ``zoo.estimate_program(sampling=...)`` carry the sampled result.
"""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro.core.compiled import O3Knobs
from repro.core.cost import cost_program
from repro.core.hlo import OpStat, Program
from repro.core.hwspec import A64FX_CORE
from repro.core.node import compile_node, schedule_node, schedule_node_sweep
from repro.core.sample import (Interval, SamplingConfig,
                               full_interval_estimate, interval_signatures,
                               kmeans, measure_sampled_vs_full,
                               phase_boundaries, sample_program,
                               sampled_node_sweep, sampled_schedule_node,
                               slice_intervals, unroll_program,
                               _feature_arrays)

HW = A64FX_CORE
DT = "f64"


def bench_dag(n=250, seed=3):
    """The perf-smoke synthetic DAG (kernel-suite-like op mix)."""
    from benchmarks.sched_throughput import synthetic_program
    return synthetic_program(n, seed=seed)


def repetitive_trace(step_ops=250, repeats=20, seed=3):
    step = bench_dag(step_ops, seed)
    inst = sum(o.count for o in step.ops)
    return unroll_program(step, repeats), inst


# ------------------------------------------------------------------ slicing
def test_slice_intervals_partition_invariants():
    prog = bench_dag(400)
    for iv_ops in (32.0, 128.0, 1e9):
        ivs = slice_intervals(prog, iv_ops, phase_aware=False)
        # contiguous, non-overlapping, complete cover
        assert ivs[0].start == 0 and ivs[-1].end == len(prog.ops)
        for a, b in zip(ivs, ivs[1:]):
            assert a.end == b.start
        total = sum(o.count for o in prog.ops)
        assert sum(iv.n_instances for iv in ivs) == pytest.approx(total)
        for iv in ivs:
            assert iv.end > iv.start
    assert len(slice_intervals(prog, 1e9, phase_aware=False)) == 1
    assert slice_intervals(Program(ops=[], entry="e", n_partitions=1),
                           64.0) == []


def test_slice_intervals_snaps_to_phase_boundaries():
    """With a count-change boundary near the nominal cut, the cut lands
    exactly on it (the interval never straddles a loop edge)."""
    ops = []
    for i in range(40):
        cnt = 8.0 if 18 <= i < 30 else 1.0   # "loop body" with count 8
        ops.append(OpStat(f"o{i}", "add", "elementwise", "f32",
                          flops=1e6, bytes_accessed=1e4, count=cnt))
    prog = Program(ops=ops, entry="e", n_partitions=1)
    bounds = set(phase_boundaries(prog).tolist())
    assert bounds == {18, 30}
    ivs = slice_intervals(prog, 20.0, phase_aware=True, snap_frac=0.5)
    cuts = {iv.start for iv in ivs[1:]}
    assert 18 in cuts                       # snapped onto the loop entry


# --------------------------------------------------------------- signatures
def test_interval_signatures_scaled_and_mix_sensitive():
    prog = bench_dag(300)
    costed = cost_program(prog, HW, compute_dtype=DT)
    fa = _feature_arrays(prog, HW, costed)
    ivs = slice_intervals(prog, 64.0, phase_aware=False)
    X = interval_signatures(fa, ivs)
    assert X.shape[0] == len(ivs)
    assert np.isfinite(X).all()
    assert np.abs(X).max() <= 1.0 + 1e-12   # max-scaled columns
    # identical intervals get identical signatures
    rep, _ = repetitive_trace(100, 4)
    costed_r = cost_program(rep, HW, compute_dtype=DT)
    fa_r = _feature_arrays(rep, HW, costed_r)
    n = 100
    ivs_r = [Interval(s, s + n, sum(o.count for o in rep.ops[s:s + n]))
             for s in range(0, 4 * n, n)]
    Xr = interval_signatures(fa_r, ivs_r)
    assert np.allclose(Xr, Xr[0][None, :])


# ------------------------------------------------------------------ k-means
def test_kmeans_deterministic_and_clamped():
    rng = np.random.RandomState(0)
    X = rng.rand(40, 6)
    l1, c1, w1 = kmeans(X, 5, seed=7)
    l2, c2, w2 = kmeans(X, 5, seed=7)
    assert np.array_equal(l1, l2) and np.allclose(c1, c2) and w1 == w2
    assert set(np.unique(l1)) == set(range(5))     # no empty clusters
    # k > n clamps to n
    l3, c3, _ = kmeans(X[:3], 10, seed=0)
    assert len(c3) == 3
    # more clusters never increase within-cluster scatter
    _, _, w_lo = kmeans(X, 2, seed=0)
    _, _, w_hi = kmeans(X, 20, seed=0)
    assert w_hi <= w_lo + 1e-12


def test_bic_elbow_collapses_duplicate_signatures():
    """On a perfectly repetitive trace with step-aligned intervals the
    elbow picks k=1 — the whole point of sampling repeated steps."""
    prog, step_inst = repetitive_trace(250, 20)
    plan = sample_program(
        prog, HW, SamplingConfig(interval_ops=step_inst,
                                 phase_aware=False), DT)
    assert plan.n_intervals == 20
    assert plan.k == 1
    assert plan.frac_ops_scheduled == pytest.approx(1 / 20)
    assert plan.weights.sum() == pytest.approx(20.0)


# ------------------------------------------------------------------- unroll
def test_unroll_program_exact_scaling_and_stationary_costs():
    step = bench_dag(120)
    rep = unroll_program(step, 5)
    assert len(rep.ops) == 5 * len(step.ops)
    assert rep.flops == pytest.approx(5 * step.flops)
    assert rep.bytes_accessed == pytest.approx(5 * step.bytes_accessed)
    # chain edges are zero-byte: routing/costing is identical per copy
    # (the scheduling-only dependency adds no phantom traffic)
    costed = cost_program(rep, HW, compute_dtype=DT)
    n = len(step.ops)
    for i in range(n):
        a, b = costed[i], costed[2 * n + i]
        assert (a is None) == (b is None)
        if a is not None:
            assert a.t_compute == b.t_compute
            assert a.t_mem == b.t_mem
    # copies are chained: copy 1's sources wait on copy 0's sinks
    src = rep.ops[n + 0]
    if not step.ops[0].deps:
        assert src.deps and all(j < n for j in src.deps)
        assert all(b == 0.0 for b in src.dep_bytes)
    assert unroll_program(step, 1) is step


# -------------------------------------------------------------- exactness
def test_k_equals_n_intervals_bit_identical_to_full_scheduling():
    """The differential anchor: k >= n_intervals (every interval its own
    cluster) reproduces full interval scheduling bit-for-bit."""
    for prog in (bench_dag(400, seed=1), repetitive_trace(100, 6)[0]):
        costed = cost_program(prog, HW, compute_dtype=DT)
        cfg = SamplingConfig(interval_ops=64.0)
        exact = full_interval_estimate(prog, HW, 12, config=cfg,
                                       compute_dtype=DT, costed=costed)
        assert exact.plan.k == exact.plan.n_intervals
        assert exact.frac_ops_scheduled == 1.0
        sam = sampled_schedule_node(
            prog, HW, 12, config=dataclasses.replace(cfg, k=10 ** 9),
            compute_dtype=DT, costed=costed)
        assert sam.t_est == exact.t_est                  # bit-identical
        assert np.array_equal(sam.t_rep, exact.t_rep)
        assert sam.port_busy == exact.port_busy
        # and the sum of isolated intervals stays near the monolithic
        # pass (the barrier-decomposition bound, DESIGN.md §18) — the
        # bound needs intervals >> the ROB window, so check it at a
        # coarser slicing than the bit-identity above
        coarse = full_interval_estimate(
            prog, HW, 12, config=SamplingConfig(interval_ops=350.0),
            compute_dtype=DT, costed=costed)
        nc = compile_node(prog, HW, compute_dtype=DT, costed=costed)
        mono = schedule_node(nc, HW, 12, partition="shard")
        assert abs(coarse.t_est - mono.t_est) / mono.t_est < 0.05


def test_fixed_seed_bit_deterministic_across_runs():
    prog = bench_dag(500, seed=2)
    costed = cost_program(prog, HW, compute_dtype=DT)
    cfg = SamplingConfig(interval_ops=48.0, seed=11)
    a = sampled_schedule_node(prog, HW, 12, config=cfg,
                              compute_dtype=DT, costed=costed)
    b = sampled_schedule_node(prog, HW, 12, config=cfg,
                              compute_dtype=DT, costed=costed)
    assert a.t_est == b.t_est
    assert np.array_equal(a.plan.labels, b.plan.labels)
    assert np.array_equal(a.plan.reps, b.plan.reps)
    assert np.array_equal(a.plan.weights, b.plan.weights)
    assert a.traffic_by_level == b.traffic_by_level


# ------------------------------------------------------------- convergence
def test_error_monotone_non_increasing_with_k_on_dag_family():
    """Family-mean reconstruction error (cancellation-free per-interval
    absolute deviation) is non-increasing along k = 1 -> 4 -> 16 -> n on
    a fixed-seed random-DAG family, and exactly 0 at k = n."""
    ks_errs = {k: [] for k in (1, 4, 16, None)}
    for seed in range(5):
        prog = bench_dag(1000, seed=seed)
        costed = cost_program(prog, HW, compute_dtype=DT)
        cfg = SamplingConfig(interval_ops=64.0)
        exact = full_interval_estimate(prog, HW, 12, config=cfg,
                                       compute_dtype=DT, costed=costed)
        t_i = exact.t_rep              # per-interval isolated makespans
        inst = np.array([iv.n_instances for iv in exact.plan.intervals])
        for k in ks_errs:
            kk = exact.plan.n_intervals if k is None else k
            plan = sample_program(prog, HW,
                                  dataclasses.replace(cfg, k=kk),
                                  DT, costed)
            rep_of = plan.reps[plan.labels]
            est_i = t_i[rep_of] * inst / inst[rep_of]
            ks_errs[k].append(float(np.abs(est_i - t_i).sum()
                                    / t_i.sum()))
    means = [float(np.mean(ks_errs[k])) for k in (1, 4, 16, None)]
    for lo, hi in zip(means, means[1:]):
        assert hi <= lo * 1.02 + 1e-12, means
    assert means[-1] < 1e-9                       # exact at k = n


# ------------------------------------------------------------ accuracy pin
def test_bench_dag_pin_5pct_error_at_20pct_ops():
    """The CI floor's accuracy half, pinned deterministically: on the
    repetitive 10k-op bench DAG, sampled reconstruction is within 5% of
    the monolithic full schedule while scheduling <= 20% of instances."""
    prog, step_inst = repetitive_trace(250, 40)
    assert len(prog.ops) == 10_000
    row = measure_sampled_vs_full(
        prog, HW, 48, config=SamplingConfig(interval_ops=step_inst,
                                            phase_aware=False),
        compute_dtype=DT)
    assert abs(row["reconstruction_error_pct"]) <= 5.0
    assert row["frac_ops_scheduled"] <= 0.20
    assert row["bound_by_sampled"] == row["bound_by_full"]


def test_real_zoo_trace_pin_5pct_error_at_20pct_ops():
    """Same pin on a real XLA program: a zoo decode step unrolled to a
    64-token trace (the long-trace mode sampling exists for)."""
    from repro.core.zoo import trace_phase
    step = trace_phase("chatglm3-6b", "decode")
    prog = unroll_program(step, 64)
    step_inst = sum(o.count for o in step.ops)
    row = measure_sampled_vs_full(
        prog, HW, 12, config=SamplingConfig(interval_ops=step_inst,
                                            phase_aware=False),
        compute_dtype="f32")
    assert abs(row["reconstruction_error_pct"]) <= 5.0
    assert row["frac_ops_scheduled"] <= 0.20


@pytest.mark.slow
def test_kernel_suite_pin_5pct_error_at_20pct_ops():
    """Nightly: the acceptance pin on the real jax kernel-suite programs,
    each unrolled into a repetitive trace."""
    from repro.core.calibrate import kernel_accuracy_table
    table = kernel_accuracy_table(HW, keep_programs=True)
    assert table.programs
    for row_k, prog in zip(table.rows, table.programs):
        long_prog = unroll_program(prog, 32)
        step_inst = sum(o.count for o in prog.ops)
        row = measure_sampled_vs_full(
            long_prog, HW, 12,
            config=SamplingConfig(interval_ops=step_inst,
                                  phase_aware=False),
            compute_dtype="f64")
        assert abs(row["reconstruction_error_pct"]) <= 5.0, row_k.name
        assert row["frac_ops_scheduled"] <= 0.20, row_k.name


# ----------------------------------------------------------------- sweeps
def test_sampled_node_sweep_consistent_with_scalar_path():
    """The fused [C, B] sweep at the spec's own knob combo matches the
    scalar sampled path at every core count (same plan, same engine)."""
    prog, step_inst = repetitive_trace(150, 8)
    costed = cost_program(prog, HW, compute_dtype=DT)
    cfg = SamplingConfig(interval_ops=step_inst, phase_aware=False)
    plan = sample_program(prog, HW, cfg, DT, costed)
    knobs = O3Knobs.single(HW)
    core_counts = (1, 12, 48)
    grid, plan_out = sampled_node_sweep(prog, HW, knobs, core_counts,
                                        compute_dtype=DT, plan=plan)
    assert plan_out is plan
    assert grid.shape == (3, 1)
    for ci, n_cores in enumerate(core_counts):
        sr = sampled_schedule_node(prog, HW, n_cores, compute_dtype=DT,
                                   plan=plan)
        assert grid[ci, 0] == pytest.approx(sr.t_est, rel=1e-9)
    # and the sampled sweep tracks the full monolithic sweep closely
    nc = compile_node(prog, HW, compute_dtype=DT, costed=costed)
    full = schedule_node_sweep(nc, HW, knobs, core_counts)
    assert np.all(np.abs(grid - full) / full < 0.05)


# --------------------------------------------------------------- plumbing
STUB_HLO = """HloModule m, is_scheduled=true

ENTRY %main (p: f32[65536]) -> f32[65536] {
  %p = f32[65536]{0} parameter(0)
  %x = f32[65536]{0} exponential(f32[65536]{0} %p)
  %d = f32[65536]{0} dot(f32[65536]{0} %x, f32[65536]{0} %p)
  ROOT %y = f32[65536]{0} add(f32[65536]{0} %d, f32[65536]{0} %x)
}
"""


def test_simulate_sampling_plumbing_and_json():
    from repro.core.simulate import simulate
    rep = simulate(STUB_HLO, hw=HW, engine="node", n_cores=12,
                   node_partition="shard", compute_dtype="f32",
                   sampling=SamplingConfig(interval_ops=1.0))
    assert rep.sampled is not None and rep.node is None
    assert rep.t_est == rep.sampled.t_est
    assert math.isfinite(rep.t_est) and rep.t_est > 0
    d = json.loads(rep.to_json())
    assert d["sampled"]["k"] == rep.sampled.plan.k
    assert d["sampled"]["t_est"] == rep.sampled.t_est
    assert 0 < d["sampled"]["frac_ops_scheduled"] <= 1.0
    with pytest.raises(ValueError):
        simulate(STUB_HLO, hw=HW, engine="occupancy",
                 sampling=SamplingConfig())


def test_estimate_program_sampling_metadata_and_grid():
    from repro.core.zoo import estimate_program, zoo_o3_knobs
    prog, step_inst = repetitive_trace(150, 8)
    pe = estimate_program(
        prog, HW, core_counts=(1, 12), compute_dtype=DT,
        o3_knobs=zoo_o3_knobs(HW), arch="syn", phase="train",
        sampling=SamplingConfig(interval_ops=step_inst,
                                phase_aware=False))
    assert pe.sampling is not None
    assert pe.sampling["k"] >= 1
    assert pe.sampling["frac_ops_scheduled"] <= 0.5
    for ce in pe.per_core:
        assert math.isfinite(ce.t_est_s) and ce.t_est_s > 0
        assert ce.t_zero_contention_s <= ce.t_est_s * (1 + 1e-9)
        assert ce.t_best_knobs_s > 0
        assert 0.0 < ce.parallel_efficiency
