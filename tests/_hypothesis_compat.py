"""Hypothesis, or graceful stand-ins when it is not installed.

The seed suite hard-imported hypothesis and died at collection.  Importing
from this module instead keeps every non-property test running in a bare
environment: @given-decorated tests are individually skipped, everything
else collects and runs.  Install hypothesis (requirements-dev.txt) to run
the property tests too.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                        # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """st.floats(...) etc. evaluate at module scope; return dummies."""
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (see requirements-dev.txt)")(f)
