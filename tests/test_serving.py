"""Differential + property suite for the serving simulator (ISSUE 10).

The event loop (``core.serving.simulate_serving``) is pinned three ways:

* **differentially** — against the closed-form M/D/1 mean wait at
  utilizations 0.3/0.6/0.9, and bit-identically against a batch-of-1
  serial reference that replays the same float operations;
* **by property** — Little's law (the loop's independently-integrated
  ``int N(t) dt`` equals the summed sojourns), percentile ordering, TTFT
  monotone in arrival rate, throughput monotone in max-batch until the
  KV-residency knee, fixed-seed determinism, and conservation (every
  request completes or is rejected exactly once, under every policy);
* **at the seams** — the KV sizing against the real cache pytrees
  (``cache_bytes`` vs ``cache_abstract`` leaves), the ServeEngine golden
  path (``_pad_cache`` pads only the spec-declared kvseq axis), the
  phase-keyed zoo cost caches (prefill/decode cells at the zoo's equal
  reduced shapes must never alias), the per-opcode VPU tables on the
  serving decode path, and the committed ``BENCH_serving.json`` schema.
"""
import dataclasses
import json
import math
import random
from pathlib import Path

import numpy as np
import pytest

from repro.core import zoo
from repro.core.hlo import OpStat, Program
from repro.core.hwspec import A64FX_CORE, A64FX_NODE
from repro.core.memory import stream_time
from repro.core.serving import (LengthDist, RequestSpec,
                                ServingKnobs, SyntheticCostModel,
                                ZooCostModel, load_trace_jsonl,
                                node_kv_levels, pareto_front, percentile,
                                poisson_requests, requests_from_trace,
                                simulate_serving, traffic_for)

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_serving.json"


def _cost(**kw):
    base = dict(prefill_t0=2e-4, prefill_per_token=1e-5,
                decode_t0=1e-4, decode_per_seq=2e-5,
                bytes_per_token=1e6, bytes_per_request=5e6)
    base.update(kw)
    return SyntheticCostModel(**base)


# --------------------------------------------------------- M/D/1 differential
@pytest.mark.parametrize("rho,n,tol", [(0.3, 50_000, 0.05),
                                       (0.6, 50_000, 0.05),
                                       (0.9, 300_000, 0.05)])
def test_md1_mean_wait_matches_analytic(rho, n, tol):
    """Batch-1 FCFS with deterministic service IS an M/D/1 queue: the
    simulated mean wait must land within 5% of rho*S/(2(1-rho))."""
    prompt = 100
    cost = _cost(prefill_t0=0.0, decode_per_seq=0.0,
                 bytes_per_token=0.0, bytes_per_request=0.0)
    s = cost.prefill_time(prompt)
    lam = rho / s
    reqs = poisson_requests(n, lam, LengthDist(prompt, 0.0, 1, 0.0), seed=0)
    res = simulate_serving(reqs, cost, ServingKnobs(max_batch=1))
    waits = [st.wait for st in res.done()]
    assert len(waits) == n
    wq = sum(waits) / n
    analytic = rho * s / (2.0 * (1.0 - rho))
    assert abs(wq - analytic) / analytic < tol


def test_md1_number_in_system_matches_analytic():
    """Little's law against the analytic M/D/1 L = lambda(Wq + S)."""
    prompt, rho, n = 100, 0.6, 50_000
    cost = _cost(prefill_t0=0.0, decode_per_seq=0.0,
                 bytes_per_token=0.0, bytes_per_request=0.0)
    s = cost.prefill_time(prompt)
    lam = rho / s
    reqs = poisson_requests(n, lam, LengthDist(prompt, 0.0, 1, 0.0), seed=1)
    res = simulate_serving(reqs, cost, ServingKnobs(max_batch=1))
    mean_l = res.area_in_system / res.duration
    analytic = lam * (rho * s / (2.0 * (1.0 - rho)) + s)
    assert abs(mean_l - analytic) / analytic < 0.05


# ------------------------------------------------- batch-of-1 serial identity
def _serial_reference(reqs, cost):
    """Replay the degenerate loop: completion_i = max(arrival, t) +
    prefill + per-step decode, same float operations in the same order."""
    out = {}
    t = 0.0
    for r in sorted(reqs, key=lambda r: (r.t_arrival, r.rid)):
        if r.t_arrival > t:
            t = r.t_arrival
        t = t + cost.prefill_time(r.prompt_tokens)
        first = t
        g = 1
        while g < r.out_tokens:
            kv = cost.kv_bytes(1, r.prompt_tokens + g)
            t = t + cost.decode_step_time(1, kv)
            g += 1
        out[r.rid] = (first, t)
    return out


def test_batch_of_1_bit_identity():
    """max_batch=1 + whole-prompt prefill degenerates to the serial
    reference EXACTLY — bit-equal first-token and completion times."""
    cost = _cost()
    reqs = poisson_requests(300, 40.0, LengthDist(120, 0.7, 12, 0.5),
                            seed=3)
    res = simulate_serving(reqs, cost, ServingKnobs(max_batch=1))
    ref = _serial_reference(reqs, cost)
    for st in res.stats:
        first, done = ref[st.spec.rid]
        assert st.t_first == first          # bit-identical, not approx
        assert st.t_done == done


# ------------------------------------------------------------------ properties
def test_littles_law_bookkeeping_identity():
    """area_in_system is integrated inside the loop, independently of the
    per-request timestamps; when every request leaves the system the two
    accumulations are the same integral -> equal to float precision, and
    the derived L = lambda*W gap collapses."""
    cost = _cost()
    reqs = poisson_requests(400, 300.0, LengthDist(100, 0.6, 16, 0.4),
                            seed=5)
    for knobs in (ServingKnobs(max_batch=1),
                  ServingKnobs(max_batch=8),
                  ServingKnobs(max_batch=8, prefill_chunk=64),
                  ServingKnobs(max_batch=8, admission="spf")):
        res = simulate_serving(reqs, cost, knobs)
        sojourn = sum(st.sojourn for st in res.stats
                      if st.completed or st.rejected)
        assert res.area_in_system == pytest.approx(sojourn, rel=1e-9)
        assert res.little_law_gap() < 1e-9


def test_percentile_matches_numpy():
    rng = random.Random(0)
    for _ in range(20):
        xs = [rng.uniform(0, 100) for _ in range(rng.randint(1, 50))]
        for q in (0, 10, 50, 90, 99, 100):
            assert percentile(xs, q) == pytest.approx(
                float(np.percentile(xs, q)), rel=1e-12, abs=1e-12)


def test_percentile_ordering_p50_le_p99():
    cost = _cost()
    reqs = poisson_requests(500, 200.0, LengthDist(100, 0.8, 20, 0.6),
                            seed=9)
    res = simulate_serving(reqs, cost, ServingKnobs(max_batch=4))
    for xs in (res.ttfts(), res.tpots()):
        assert percentile(xs, 50) <= percentile(xs, 90) <= percentile(xs, 99)
    m = res.metrics()
    assert m["p50_ttft_ms"] <= m["p99_ttft_ms"]
    assert m["p50_tpot_ms"] <= m["p99_tpot_ms"]


def test_ttft_monotone_in_arrival_rate():
    """Shrinking every inter-arrival gap can only grow each request's
    wait at batch 1 (the Lindley recursion is monotone); batched p50
    TTFT follows the same trend."""
    cost = _cost()
    base = poisson_requests(500, 1.0, LengthDist(100, 0.5, 8, 0.5), seed=7)

    def scaled(f):
        return [dataclasses.replace(r, t_arrival=r.t_arrival / f)
                for r in base]

    prev = None
    for f in (50.0, 100.0, 200.0):
        res = simulate_serving(scaled(f), cost, ServingKnobs(max_batch=1))
        ttfts = {st.spec.rid: st.ttft for st in res.done()}
        if prev is not None:
            assert all(ttfts[k] >= prev[k] - 1e-12 for k in ttfts)
        prev = ttfts
    p50s = [simulate_serving(scaled(f), cost,
                             ServingKnobs(max_batch=8)).metrics()
            ["p50_ttft_ms"] for f in (50.0, 100.0, 200.0)]
    assert p50s == sorted(p50s)


def test_throughput_monotone_in_max_batch_until_knee():
    """Under saturation, tokens/s/node grows with max_batch (within a
    0.1% discretization ripple) until the KV pool caps the effective
    batch — beyond the knee extra slots buy nothing."""
    cost = _cost()
    heavy = poisson_requests(300, 2000.0, LengthDist(100, 0.5, 16, 0.3),
                             seed=1)
    tps = []
    for b in (1, 2, 4, 8, 16, 32):
        res = simulate_serving(heavy, cost, ServingKnobs(max_batch=b))
        tps.append(res.tokens_per_s)
    for lo, hi in zip(tps, tps[1:]):
        assert hi >= lo * (1 - 1e-3)
    assert tps[2] > tps[0] * 1.05       # real gain before saturation

    # knee: capacity for ~6 requests caps the decode batch at ~6 and
    # flattens throughput for every max_batch beyond it
    cap = cost.kv_bytes(1, 130) * 6
    tight = dataclasses.replace(cost, kv_capacity=cap)
    knee = [simulate_serving(heavy, tight, ServingKnobs(max_batch=b))
            for b in (8, 16, 32)]
    assert all(r.metrics()["mean_decode_batch"] <= 6.0 + 1e-9
               for r in knee)
    t8, t16, t32 = (r.tokens_per_s for r in knee)
    assert abs(t16 - t8) / t8 < 0.02 and abs(t32 - t8) / t8 < 0.02


def test_fixed_seed_determinism():
    assert poisson_requests(50, 10.0, LengthDist(64, 0.5, 8, 0.5), seed=4) \
        == poisson_requests(50, 10.0, LengthDist(64, 0.5, 8, 0.5), seed=4)
    cost = _cost()
    reqs = poisson_requests(200, 100.0, LengthDist(64, 0.5, 8, 0.5), seed=4)
    knobs = ServingKnobs(max_batch=8, prefill_chunk=32)
    a = simulate_serving(reqs, cost, knobs)
    b = simulate_serving(reqs, cost, knobs)
    assert a.metrics() == b.metrics()
    assert [(s.t_first, s.t_done) for s in a.stats] \
        == [(s.t_first, s.t_done) for s in b.stats]


def test_conservation_every_policy():
    """Every request ends in exactly one terminal state under every
    (admission x eviction x chunk) combination, including tight pools."""
    cost = _cost(kv_capacity=_cost().kv_bytes(1, 130) * 4)
    reqs = poisson_requests(150, 500.0, LengthDist(100, 0.6, 12, 0.4),
                            seed=2)
    for admission in ("fcfs", "spf"):
        for eviction in ("reject", "evict-oldest", "evict-newest"):
            for chunk in (0, 64):
                res = simulate_serving(reqs, cost, ServingKnobs(
                    max_batch=8, admission=admission,
                    eviction=eviction, prefill_chunk=chunk))
                comp = [st for st in res.stats if st.completed]
                rej = [st for st in res.stats if st.rejected]
                assert len(comp) + len(rej) == len(reqs)
                assert not any(st.completed and st.rejected
                               for st in res.stats)
                assert all(math.isfinite(st.t_first) for st in comp)


def test_reject_policy_never_exceeds_capacity():
    cost = _cost(kv_capacity=_cost().kv_bytes(1, 130) * 3)
    reqs = poisson_requests(100, 500.0, LengthDist(100, 0.5, 12, 0.3),
                            seed=6)
    res = simulate_serving(reqs, cost, ServingKnobs(max_batch=16))
    assert res.max_kv_bytes <= cost.kv_capacity
    # a request that can never fit alone is rejected terminally
    big = reqs + [RequestSpec(999, 0.0, 100_000, 4)]
    res2 = simulate_serving(big, cost, ServingKnobs(max_batch=16))
    st = next(s for s in res2.stats if s.spec.rid == 999)
    assert st.rejected and not st.completed


def test_eviction_preempts_and_completes():
    """Evict policies admit optimistically, preempt on overflow, and the
    evicted requests still finish (re-prefilling prompt + generated)."""
    cost = _cost(kv_capacity=_cost().kv_bytes(1, 130) * 4)
    reqs = poisson_requests(300, 2000.0, LengthDist(100, 0.5, 16, 0.3),
                            seed=1)
    for pol in ("evict-oldest", "evict-newest"):
        res = simulate_serving(reqs, cost, ServingKnobs(
            max_batch=8, eviction=pol))
        m = res.metrics()
        assert m["n_evictions"] > 0
        assert m["completed"] + m["rejected"] == len(reqs)
        evicted_done = [st for st in res.stats
                        if st.n_evictions > 0 and st.completed]
        assert evicted_done, "no evicted request ever completed"


def test_chunked_prefill_reduces_tail_tpot():
    """A long prompt landing mid-decode stalls every decoding request for
    its whole prefill when unchunked; chunking bounds the stall."""
    cost = _cost()
    mix = [RequestSpec(i, 1e-6 * i, 50, 40) for i in range(6)] \
        + [RequestSpec(9, 0.01, 4000, 4)]
    un = simulate_serving(mix, cost, ServingKnobs(max_batch=8))
    ch = simulate_serving(mix, cost,
                          ServingKnobs(max_batch=8, prefill_chunk=128))
    assert ch.metrics()["p99_tpot_ms"] < un.metrics()["p99_tpot_ms"]


def test_spf_admission_beats_fcfs_on_backlog():
    """Shortest-prompt-first is SJF on a batch-1 backlog: provably
    minimal mean wait, so it must beat FCFS on a scrambled batch."""
    cost = _cost()
    back = [RequestSpec(i, 0.0, p, 1)
            for i, p in enumerate([900, 30, 500, 60, 200, 40])]
    mean_wait = {}
    for adm in ("fcfs", "spf"):
        res = simulate_serving(back, cost,
                               ServingKnobs(max_batch=1, admission=adm))
        mean_wait[adm] = sum(st.wait for st in res.done()) / len(back)
        order = sorted(res.done(), key=lambda st: st.t_done)
        if adm == "spf":
            prompts = [st.spec.prompt_tokens for st in order]
            assert prompts == sorted(prompts)
    assert mean_wait["spf"] < mean_wait["fcfs"]


def test_trace_roundtrip_and_trace_driven_run(tmp_path):
    reqs = poisson_requests(20, 5.0, LengthDist(64, 0.5, 8, 0.5), seed=8)
    path = tmp_path / "trace.jsonl"
    path.write_text("\n".join(json.dumps({
        "rid": r.rid, "t_arrival": r.t_arrival,
        "prompt_tokens": r.prompt_tokens, "out_tokens": r.out_tokens})
        for r in reqs))
    loaded = load_trace_jsonl(path)
    assert loaded == reqs
    cost = _cost()
    a = simulate_serving(reqs, cost, ServingKnobs(max_batch=4))
    b = simulate_serving(loaded, cost, ServingKnobs(max_batch=4))
    assert a.metrics() == b.metrics()


def test_trace_driven_hand_case():
    """Two-request hand-checkable timeline at batch 1."""
    cost = SyntheticCostModel(prefill_t0=0.0, prefill_per_token=1e-3,
                              decode_t0=1e-2, decode_per_seq=0.0,
                              bytes_per_token=0.0, bytes_per_request=0.0)
    reqs = requests_from_trace([
        {"t_arrival": 0.0, "prompt_tokens": 10, "out_tokens": 3},
        {"t_arrival": 0.005, "prompt_tokens": 20, "out_tokens": 1},
    ])
    res = simulate_serving(reqs, cost, ServingKnobs(max_batch=1))
    st0, st1 = res.stats
    # r0: prefill 10ms -> first token at 10ms, +2 decode steps of 10ms
    assert st0.t_first == pytest.approx(0.010)
    assert st0.t_done == pytest.approx(0.030)
    # r1 admitted at r0's completion: prefill 20ms -> done at 50ms
    assert st1.t_first == pytest.approx(0.050)
    assert st1.t_done == pytest.approx(0.050)
    assert st1.wait == pytest.approx(0.030 - 0.005)


def test_knobs_validation_and_labels():
    with pytest.raises(ValueError):
        ServingKnobs(max_batch=0)
    with pytest.raises(ValueError):
        ServingKnobs(admission="lifo")
    with pytest.raises(ValueError):
        ServingKnobs(eviction="drop")
    assert ServingKnobs(max_batch=32).label == "fcfs_b32"
    assert ServingKnobs(max_batch=8, admission="spf", prefill_chunk=256,
                        eviction="evict-oldest").label \
        == "spf_b8_chunk256_evict-oldest"


# ----------------------------------------------------------- cost-model seams
def test_stream_time_residency_switch():
    levels = node_kv_levels()
    l2, hbm = levels
    assert stream_time(levels, l2.capacity / 2) \
        == pytest.approx(l2.capacity / 2 / l2.read_bw)
    spill = l2.capacity * 4
    assert stream_time(levels, spill) == pytest.approx(spill / hbm.read_bw)
    # beyond HBM there is nowhere further to miss to: outermost backstop
    huge = hbm.capacity * 2
    assert stream_time(levels, huge) == pytest.approx(huge / hbm.read_bw)
    assert stream_time(levels, 0.0) == 0.0
    assert stream_time(levels, spill, write=True) \
        == pytest.approx(spill / hbm.write_bw)


def test_node_kv_levels_a64fx_aggregates():
    l2, hbm = node_kv_levels()
    assert (l2.name, hbm.name) == ("l2", "hbm2")
    assert l2.capacity == 4 * 8 * 2**20 and hbm.capacity == 4 * 8 * 2**30
    assert l2.read_bw == 4 * A64FX_NODE.shared_read_bw["l2"]
    assert hbm.read_bw == 4 * A64FX_NODE.shared_read_bw["hbm2"]


def test_zoo_cost_model_interpolation():
    cm = ZooCostModel(arch="x", prefill_per_token=2e-6,
                      decode_grid=((1, 1e-4), (4, 2e-4), (16, 5e-4)),
                      bytes_per_token=0.0)
    assert cm.prefill_time(100) == pytest.approx(2e-4)
    for b, t in cm.decode_grid:                  # exact at grid points
        assert cm.decode_compute_time(b) == pytest.approx(t)
    assert cm.decode_compute_time(2) == pytest.approx(
        1e-4 + (2e-4 - 1e-4) * (2 - 1) / (4 - 1))
    assert cm.decode_compute_time(32) == pytest.approx(
        5e-4 + (5e-4 - 2e-4) / 12 * 16)          # last-slope extrapolation
    ts = [cm.decode_compute_time(b) for b in range(1, 40)]
    assert ts == sorted(ts)


def test_cost_model_kv_bytes_affine():
    cm = _cost()
    assert cm.kv_bytes(3, 100) == pytest.approx(3 * 5e6 + 100 * 1e6)
    # decode step pays the max of compute and KV streaming
    kv = 64 * 2**20                               # spills the 32 MiB L2
    hbm = cm.levels[-1]
    assert cm.decode_step_time(1, kv) == pytest.approx(
        max(cm.decode_compute_time(1), kv / hbm.read_bw))


def test_traffic_table_fallback():
    assert traffic_for("chatglm3-6b").prompt_mean == 256
    assert traffic_for("no-such-model") == traffic_for("another-unknown")


def test_pareto_front_non_domination():
    pts = [(1.0, 5.0), (2.0, 2.0), (3.0, 3.0), (5.0, 1.0), (1.0, 5.0)]
    front = pareto_front(pts)
    assert 2 not in front                        # dominated by (2, 2)
    for a in front:
        assert not any(pts[b][0] <= pts[a][0] and pts[b][1] <= pts[a][1]
                       and pts[b] != pts[a] for b in range(len(pts)))


# ------------------------------------------ phase-cache aliasing (satellite 6)
def test_serving_cost_key_phase_distinct():
    """ZOO_PREFILL and ZOO_DECODE have IDENTICAL reduced shapes (seq 256,
    batch 2) — only the phase in the key separates their cost cells."""
    from repro.configs.shapes import ZOO_DECODE, ZOO_PREFILL
    shape = dataclasses.replace(ZOO_PREFILL, name="alias", kind="prefill")
    k_pre = zoo.serving_cost_key("chatglm3-6b", "prefill", shape, 48,
                                 "f32", "float32")
    k_dec = zoo.serving_cost_key("chatglm3-6b", "decode", shape, 48,
                                 "f32", "float32")
    assert k_pre != k_dec
    assert (ZOO_PREFILL.seq_len, ZOO_PREFILL.global_batch) \
        == (ZOO_DECODE.seq_len, ZOO_DECODE.global_batch)


def test_hlo_cache_key_and_path_phase_distinct(tmp_path):
    from repro.configs.shapes import ZOO_PREFILL
    shape = dataclasses.replace(ZOO_PREFILL, name="alias")
    assert zoo.hlo_cache_key("chatglm3-6b", "prefill", shape, "float32") \
        != zoo.hlo_cache_key("chatglm3-6b", "decode", shape, "float32")
    p = zoo.hlo_cache_path(tmp_path, "chatglm3-6b", "prefill", shape,
                           "float32")
    d = zoo.hlo_cache_path(tmp_path, "chatglm3-6b", "decode", shape,
                           "float32")
    assert p != d


def test_program_cache_phase_keyed(monkeypatch, tmp_path):
    """The in-process trace memo and the disk HLO cache must both key on
    phase: equal reduced shapes, different phases -> different programs
    and different cache files (regression for prefill/decode aliasing)."""
    from repro.configs.shapes import ZOO_DECODE, ZOO_PREFILL
    texts = {
        "prefill": """
HloModule pre, num_partitions=1

ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64] parameter(0)
  ROOT %dot = f32[64,64] dot(%p0, %p0), lhs_contracting_dims={1}
}
""",
        "decode": """
HloModule dec, num_partitions=1

ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64] parameter(0)
  %dot = f32[64,64] dot(%p0, %p0), lhs_contracting_dims={1}
  ROOT %e = f32[64,64] exponential(%dot)
}
""",
    }
    monkeypatch.setattr(zoo, "_phase_hlo",
                        lambda arch, phase, shape, dtype: texts[phase])
    zoo.clear_trace_caches()
    try:
        pre = zoo.trace_phase("chatglm3-6b", "prefill", ZOO_PREFILL,
                              hlo_cache_dir=tmp_path)
        dec = zoo.trace_phase("chatglm3-6b", "decode", ZOO_DECODE,
                              hlo_cache_dir=tmp_path)
        assert len(pre.ops) != len(dec.ops)
        files = sorted(f.name for f in tmp_path.glob("*.hlo.txt"))
        assert len(files) == 2 and files[0] != files[1]
    finally:
        zoo.clear_trace_caches()


def test_vpu_opcode_table_prices_decode_path():
    """The per-opcode VPU latency table must reach the node engine the
    serving cost cells use: a decode-style elementwise stream of
    `minimum` ops (A64FX factor 2.0) costs more than the identical
    stream of plain adds — without the table both collapse to one
    t_est (the degeneracy the kernel suite fixed)."""
    from repro.core.node import compile_node, schedule_node

    def prog(opcode):
        nelems = 1e6
        ops = [OpStat(f"op{i}", opcode, "elementwise", "f32",
                      flops=nelems, bytes_accessed=1e4, read_bytes=1e4,
                      vpu_by_opcode={opcode: nelems})
               for i in range(8)]
        return Program(ops=ops, entry="e", n_partitions=1)

    ts = {}
    for opcode in ("add", "minimum"):
        nc = compile_node(prog(opcode), A64FX_CORE, compute_dtype="f32")
        ts[opcode] = schedule_node(nc, A64FX_CORE, 1,
                                   topology=A64FX_NODE).t_est
    assert ts["minimum"] > ts["add"] * 1.5


# ----------------------------------------- kvcache differential (satellite 1)
@pytest.mark.parametrize("arch", sorted(__import__("repro.configs",
                                                   fromlist=["ARCHS"]).ARCHS))
def test_cache_bytes_matches_abstract_leaves(arch):
    """cache_bytes must equal the summed bytes of cache_abstract's ACTUAL
    pytree leaves for every architecture family, dtype and (batch,
    max_seq) cell — the serving layer's KV sizing cannot drift from the
    real cache shapes."""
    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduced_config
    from repro.models.lm import build_model
    from repro.serve.kvcache import cache_abstract, cache_bytes
    model = build_model(reduced_config(ARCHS[arch]))
    for dtype in (jnp.bfloat16, jnp.float32):
        for batch, max_seq in ((1, 8), (2, 16), (4, 64)):
            tree = cache_abstract(model, batch, max_seq, dtype)
            leaf_bytes = sum(x.size * x.dtype.itemsize
                             for x in jax.tree.leaves(tree))
            assert cache_bytes(model, batch, max_seq, dtype) == leaf_bytes


@pytest.mark.parametrize("arch", sorted(__import__("repro.configs",
                                                   fromlist=["ARCHS"]).ARCHS))
def test_kv_token_bytes_affine_exact(arch):
    """The serving layer's affine decomposition reproduces cache_bytes
    exactly at every sequence length (SSM: zero bytes/token)."""
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduced_config
    from repro.models.lm import build_model
    from repro.serve.kvcache import cache_bytes, kv_token_bytes
    cfg = reduced_config(ARCHS[arch])
    model = build_model(cfg)
    per_tok, per_req = kv_token_bytes(model, jnp.bfloat16)
    for seq in (1, 7, 64, 333):
        assert per_req + per_tok * seq \
            == pytest.approx(cache_bytes(model, 1, seq, jnp.bfloat16))
    if cfg.family == "ssm":
        assert per_tok == 0.0 and per_req > 0
    else:
        assert per_tok > 0


# ------------------------------------------- ServeEngine golden (satellite 2)
@pytest.mark.slow
def test_serve_engine_fixed_seed_token_pin():
    """Sampled generation is a pure function of the seed: two engines
    built identically emit identical token sequences, and a different
    seed diverges (the RNG is actually consulted)."""
    import jax

    from repro.configs import ARCHS, reduced_config
    from repro.models.lm import build_model
    from repro.serve.engine import ServeEngine
    cfg = reduced_config(ARCHS["qwen1.5-32b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [[3, 1, 4, 1, 5], [2, 7]]

    def run(seed):
        eng = ServeEngine(model, params, max_seq=32, temperature=0.8,
                          seed=seed)
        return eng.generate(prompts, max_new_tokens=6)

    assert run(0) == run(0)
    assert run(0) != run(1)


@pytest.mark.slow
def test_pad_cache_pads_only_kvseq_axis():
    """Regression for the axis-scan bug: with n_layers == prompt length
    the old heuristic padded the LAYERS axis.  The padded cache must
    keep every non-kvseq dimension and grow kvseq to max_seq."""
    import jax

    from repro.configs import ARCHS, reduced_config
    from repro.models.lm import build_model
    from repro.serve.engine import ServeEngine
    cfg = reduced_config(ARCHS["qwen1.5-32b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_seq=24)
    prompt = list(range(1, cfg.n_layers + 1))     # len(prompt) == n_layers
    _, cache = eng._prefill_one(prompt, {})
    specs = model.cache_specs(1, 24)

    def check(x, p):
        if "kvseq" in p.axes and p.shape[p.axes.index("kvseq")] == 24:
            assert x.shape[p.axes.index("kvseq")] == 24
        for ax, name in enumerate(p.axes):
            if name != "kvseq":
                assert x.shape[ax] == p.shape[ax] or name == "batch"

    jax.tree.map(check, cache, specs)


@pytest.mark.slow
def test_generate_invariant_under_max_seq():
    """Greedy generation must not depend on the cache's padded length
    (the _pad_cache length-invariance property), including the
    adversarial prompt length == n_layers case."""
    import jax

    from repro.configs import ARCHS, reduced_config
    from repro.models.lm import build_model
    from repro.serve.engine import ServeEngine
    cfg = reduced_config(ARCHS["qwen1.5-32b"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = list(range(1, cfg.n_layers + 1))

    def run(max_seq):
        eng = ServeEngine(model, params, max_seq=max_seq)
        return eng.generate([prompt], max_new_tokens=5)

    assert run(16) == run(24)


# --------------------------------------- committed artifact (satellite 3)
def test_bench_serving_artifact():
    """The committed BENCH_serving.json: schema, percentile ordering,
    finite/positive SLO fields, and Pareto non-domination per model —
    mirroring the test_dse.py committed-artifact pattern."""
    d = json.loads(BENCH_JSON.read_text())
    assert d["schema"] == 1
    assert len(d["models"]) >= 4
    assert len(d["policies"]) >= 3
    labels = {p["label"] for p in d["policies"]}
    for arch, row in d["models"].items():
        pols = row["policies"]
        assert set(pols) == labels
        for m in pols.values():
            assert m["p50_ttft_ms"] <= m["p99_ttft_ms"] + 1e-9
            assert m["p50_tpot_ms"] <= m["p99_tpot_ms"] + 1e-9
            for k in ("p50_ttft_ms", "p99_ttft_ms", "tokens_per_s"):
                assert math.isfinite(m[k]) and m[k] > 0
            assert m["little_law_gap"] < 1e-6
            assert m["completed"] + m["rejected"] == d["arrival"]["n_requests"]
        front = row["pareto"]
        assert front and set(front) <= labels
        pts = {lb: (pols[lb]["p99_ttft_ms"], -pols[lb]["tokens_per_s"])
               for lb in pols}
        for a in front:
            assert not any(
                pts[b][0] <= pts[a][0] and pts[b][1] <= pts[a][1]
                and pts[b] != pts[a] for b in pols), \
                f"{arch}: {a} dominated but on front"
        assert row["bytes_per_token"] >= 0
    assert d["wall_s"] > 0


# ------------------------------------------------------ zoo-backed smoke
@pytest.mark.slow
def test_build_zoo_cost_model_and_simulate(tmp_path):
    """End-to-end: trace one arch through the node engine, price a small
    Poisson run, and check the disk cost cells are phase-distinct files
    that make the rebuild a pure cache read."""
    from repro.core.serving import build_zoo_cost_model
    cm = build_zoo_cost_model("chatglm3-6b", batch_grid=(1, 4),
                              hlo_cache_dir=tmp_path / "hlo",
                              cost_cache_dir=tmp_path / "cost")
    assert cm.prefill_per_token > 0
    assert all(t > 0 for _, t in cm.decode_grid)
    assert cm.bytes_per_token > 0 and cm.kv_capacity == 32 * 2**30
    cells = sorted(f.name for f in (tmp_path / "cost").glob("*.json"))
    assert len(cells) == 3                   # prefill + 2 decode batches
    assert any("serve_prefill" in f for f in cells)
    assert any("serve_decode" in f for f in cells)
    cm2 = build_zoo_cost_model("chatglm3-6b", batch_grid=(1, 4),
                               hlo_cache_dir=tmp_path / "hlo",
                               cost_cache_dir=tmp_path / "cost")
    assert cm2.decode_grid == cm.decode_grid
    reqs = poisson_requests(40, 100.0, traffic_for("chatglm3-6b"), seed=0)
    res = simulate_serving(reqs, cm, ServingKnobs(max_batch=8))
    m = res.metrics()
    assert m["completed"] == 40 and m["tokens_per_s"] > 0
