"""Differential + property harness for the batched node engine (ISSUE 6).

The batched kernel's contract is *bit*-identity, not approximation: every
element of a ``schedule_node_batch`` call replays the scalar
``schedule_node`` interpreter's float ops in the same order, so ``==`` is
the assertion — any reassociation in the vectorized kernel is a bug, not
noise.  Three layers:

* **differential sweep** — random DAGs x {shard, round-robin, graph} x
  {1, 12, 48} cores x random O3 knob specs: each batch element equals the
  scalar engine on ``t_est``, ``t_zero_contention`` and ``iterations``;
  the fused core-count sweep equals per-count batched calls.
* **properties on the batched path** — the zero-contention/serial
  sandwich and shard-partition monotonicity in core count, asserted on
  whole batches at once.
* **compile caches** — ``compile_program`` / ``compile_node`` hit on
  VALUE-equal (not identical) HardwareSpecs, the regression for the
  ``chw is hw`` identity bug that made every ``with_``-derived knob spec
  recompile the program.

The jax ``lax.scan`` backend is slow-marked and held to allclose (XLA
may fuse/reassociate) rather than bit-identity.
"""
import random

import numpy as np
import pytest

from repro.core.compiled import O3Knobs, compile_program
from repro.core.hwspec import A64FX_CORE, NodeTopology
from repro.core.node import (compile_node, schedule_node,
                             schedule_node_batch, schedule_node_sweep)
from tests.test_compiled_schedule import random_knobs, random_program

PARTITIONS = ("shard", "round-robin", "graph")
CORE_COUNTS = (1, 12, 48)


def _batch_for(nc, hw, specs, cores, partition):
    return schedule_node_batch(nc, hw, O3Knobs.from_specs(specs), cores,
                               partition=partition)


# ------------------------------------------------------------- differential
def test_batched_bit_identical_to_scalar_across_partitions_and_cores():
    """The headline contract: every (partition, core count, knob spec)
    cell of a batched call == the scalar engine, bitwise."""
    hw = A64FX_CORE
    rng = random.Random(0xB47C)
    for _ in range(4):
        prog = random_program(rng, rng.randint(24, 120))
        nc = compile_node(prog, hw, compute_dtype="f64")
        specs = [random_knobs(rng) for _ in range(4)]
        for part in PARTITIONS:
            for cores in CORE_COUNTS:
                res = _batch_for(nc, hw, specs, cores, part)
                for m, sp in enumerate(specs):
                    # random_knobs bases may carry foreign topologies —
                    # pin the scalar run to the node under test
                    r = schedule_node(nc, sp, cores, partition=part,
                                      topology=hw.topology)
                    assert r.t_est == res.t_est[m], (part, cores, m)
                    assert r.t_zero_contention == res.t_zero_contention[m]
                    assert r.iterations == res.iterations[m]
                assert res.total_scheduled_ops == int(res.iterations.sum())


def test_batched_bit_identical_under_degenerate_topology():
    hw = A64FX_CORE
    topo = NodeTopology.degenerate(48)
    rng = random.Random(7)
    prog = random_program(rng, 80)
    nc = compile_node(prog, hw, compute_dtype="f64")
    specs = [random_knobs(rng) for _ in range(3)]
    for cores in CORE_COUNTS:
        res = schedule_node_batch(nc, hw, O3Knobs.from_specs(specs), cores,
                                  topology=topo, partition="round-robin")
        for m, sp in enumerate(specs):
            r = schedule_node(nc, sp, cores, partition="round-robin",
                              topology=topo)
            assert r.t_est == res.t_est[m]


def test_fused_core_sweep_equals_per_count_batches():
    """schedule_node_sweep folds the core axis into the knob batch for
    the shard partition; the [C, B] result must equal C independent
    batched calls, bitwise."""
    hw = A64FX_CORE
    rng = random.Random(21)
    prog = random_program(rng, 90)
    nc = compile_node(prog, hw, compute_dtype="f64")
    knobs = O3Knobs.from_specs([random_knobs(rng) for _ in range(5)])
    for part in ("shard", "round-robin"):
        sw = schedule_node_sweep(nc, hw, knobs, list(CORE_COUNTS),
                                 partition=part)
        assert sw.shape == (len(CORE_COUNTS), knobs.batch)
        for ki, cores in enumerate(CORE_COUNTS):
            per = schedule_node_batch(nc, hw, knobs, cores,
                                      partition=part).t_est
            assert np.array_equal(sw[ki], per), (part, cores)


# ----------------------------------------------------- batched properties
def test_batched_sandwich_and_iteration_bounds():
    hw = A64FX_CORE
    rng = random.Random(3)
    prog = random_program(rng, 100)
    nc = compile_node(prog, hw, compute_dtype="f64")
    specs = [random_knobs(rng) for _ in range(6)]
    for part in PARTITIONS:
        res = _batch_for(nc, hw, specs, 12, part)
        assert np.all(res.t_est >= res.t_zero_contention * (1 - 1e-12))
        assert np.all(res.iterations >= 1)
        # max_iters=8 fixpoint passes, plus the one final clamped pass
        assert np.all(res.iterations <= 9)
        assert np.all(np.isfinite(res.t_est))


def test_batched_shard_monotone_in_core_count():
    """More cores never hurt under the shard partition (each op's slice
    shrinks); asserted across the whole knob batch via the fused sweep."""
    hw = A64FX_CORE
    rng = random.Random(11)
    prog = random_program(rng, 100)
    nc = compile_node(prog, hw, compute_dtype="f64")
    knobs = O3Knobs.from_specs([random_knobs(rng) for _ in range(6)])
    sw = schedule_node_sweep(nc, hw, knobs, [1, 2, 4, 12, 48],
                             partition="shard")
    assert np.all(sw[1:] <= sw[:-1] * (1 + 1e-9))


# -------------------------------------------------------------- jax backend
@pytest.mark.slow
def test_jax_backend_allclose_to_numpy():
    pytest.importorskip("jax")
    hw = A64FX_CORE
    rng = random.Random(5)
    prog = random_program(rng, 60)
    nc = compile_node(prog, hw, compute_dtype="f64")
    knobs = O3Knobs.from_specs([random_knobs(rng) for _ in range(4)])
    for part in ("shard", "round-robin"):
        ref = schedule_node_batch(nc, hw, knobs, 12, partition=part,
                                  backend="numpy")
        jx = schedule_node_batch(nc, hw, knobs, 12, partition=part,
                                 backend="jax")
        np.testing.assert_allclose(jx.t_est, ref.t_est, rtol=1e-9)
        np.testing.assert_allclose(jx.t_zero_contention,
                                   ref.t_zero_contention, rtol=1e-9)


# ------------------------------------------------------------ compile cache
def test_compile_program_cache_hits_on_value_equal_spec():
    rng = random.Random(9)
    prog = random_program(rng, 40)
    hw = A64FX_CORE
    cp = compile_program(prog, hw, compute_dtype="f64")
    clone = hw.with_()                       # fresh object, equal value
    assert clone is not hw and clone == hw
    assert compile_program(prog, clone, compute_dtype="f64") is cp
    # a genuinely different spec must MISS
    other = hw.with_(inflight_window=max(2, hw.inflight_window // 2))
    assert compile_program(prog, other, compute_dtype="f64") is not cp


def test_compile_node_cache_hits_on_value_equal_spec():
    rng = random.Random(10)
    prog = random_program(rng, 40)
    hw = A64FX_CORE
    nc = compile_node(prog, hw, compute_dtype="f64")
    clone = hw.with_()
    assert clone is not hw and clone == hw
    assert compile_node(prog, clone, compute_dtype="f64") is nc
    other = hw.with_(inflight_window=max(2, hw.inflight_window // 2))
    assert compile_node(prog, other, compute_dtype="f64") is not nc
